//! DSQ controller demo (no PJRT needed): feed a synthetic validation-loss
//! trajectory to the dynamic controller and watch it climb the precision
//! ladder, with the time-weighted hardware cost after every transition —
//! the mechanism that produces the paper's 0.012×/0.20× DSQ row.
//!
//! ```bash
//! cargo run --release --example dsq_schedule_demo
//! ```

use dsq::costmodel::{self, TransformerWorkload};
use dsq::schedule::{DsqController, PrecisionConfig, Schedule};

fn main() {
    let w = TransformerWorkload::iwslt_6layer();
    let mut ctl = DsqController::paper_default("bfp").unwrap();
    let mut trace: Vec<(PrecisionConfig, usize)> = Vec::new();

    // A plausible training trajectory: strong early progress, then each
    // level's plateau (the controller should advance on each plateau).
    let mut val = 6.0;
    println!("{:>5} {:>9} {:>14} {:>11} {:>10}", "epoch", "val", "level", "arith(t)", "dram(t)");
    for epoch in 0..40 {
        // Loss improves quickly right after a precision bump, then stalls.
        let level_before = ctl.level();
        let improves = epoch < 6 || (trace.last().map_or(0, |t| t.1) < 4);
        if improves {
            val *= 0.96;
        } else {
            val *= 1.001; // plateau / tiny regression
        }
        // 100 steps per epoch at the current level.
        let pc = ctl.current();
        match trace.last_mut() {
            Some((p, n)) if *p == pc => *n += 1,
            _ => trace.push((pc, 1)),
        }
        ctl.observe_validation(val);

        let scaled: Vec<(PrecisionConfig, usize)> =
            trace.iter().map(|&(p, n)| (p, n * 100)).collect();
        let row = costmodel::tables::dsq_trace_row(&w, &scaled);
        println!(
            "{epoch:>5} {val:>9.4} {:>14} {:>10.4}x {:>9.3}x{}",
            ctl.current().notation(),
            row.arith_rel.unwrap(),
            row.dram_rel.unwrap(),
            if ctl.level() != level_before { "   <- advanced" } else { "" }
        );
    }

    println!("\ntransitions: {:?}", ctl.transitions());
    let scaled: Vec<(PrecisionConfig, usize)> =
        trace.iter().map(|&(p, n)| (p, n * 100)).collect();
    let row = costmodel::tables::dsq_trace_row(&w, &scaled);
    println!(
        "final time-weighted cost: {:.4}x arith, {:.3}x dram (paper DSQ row: 0.012x / 0.20x)",
        row.arith_rel.unwrap(),
        row.dram_rel.unwrap()
    );
}
