//! END-TO-END VALIDATION DRIVER (EXPERIMENTS.md §E2E).
//!
//! Trains the transformer on the synthetic IWSLT-style translation task
//! for several hundred steps under (a) fp32 and (b) the full DSQ
//! dynamic controller, logging the loss curves, validation losses, the
//! controller's precision transitions, BLEU, and the time-weighted
//! hardware cost of each run — proving all three layers compose:
//! Pallas quantizers (L1) inside the JAX autodiff (L2) driven by the
//! rust coordinator (L3) through PJRT.
//!
//! ```bash
//! make artifacts && cargo run --release --example train_translation [-- quick]
//! ```

use dsq::coordinator::{LrSchedule, Trainer, TrainerConfig};
use dsq::costmodel::TransformerWorkload;
use dsq::data::Variant;
use dsq::schedule::{DsqController, FormatSpec, PrecisionConfig, Schedule, StaticSchedule};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    dsq::util::logging::level_from_env();
    let quick = std::env::args().any(|a| a == "quick");
    let (epochs, bpe) = if quick { (3, 30) } else { (8, 60) };

    let base = TrainerConfig {
        epochs,
        batches_per_epoch: bpe,
        lr: LrSchedule::InverseSqrt { peak_lr: 3e-3, warmup_steps: 60 },
        variant: Variant::Iwslt,
        val_batches: 4,
        bleu_batches: 6,
        ..TrainerConfig::quick("artifacts".into())
    };
    let workload = TransformerWorkload::iwslt_6layer();

    println!("== e2e: {} steps per run ==\n", epochs * bpe);
    // Unscored (fp32 reference) costs render as "-", like the paper's tables.
    let fmt_cost = |v: Option<f64>| v.map_or("-".to_string(), |x| format!("{x:.3}x"));
    let mut summary = Vec::new();
    let runs: Vec<(&str, Box<dyn Schedule>)> = vec![
        ("fp32", Box::new(StaticSchedule(PrecisionConfig::FP32))),
        (
            "stashing-bfp [16,4,4,16]",
            Box::new(StaticSchedule(PrecisionConfig::stashing(FormatSpec::bfp(16)))),
        ),
        ("DSQ (dynamic)", Box::new(DsqController::paper_default("bfp").unwrap())),
    ];

    for (name, mut schedule) in runs {
        println!("--- {name} ---");
        let mut trainer = Trainer::new(base.clone())?;
        let report = trainer.run(schedule.as_mut())?;
        // fp32 reference traces are unscored ("-" in the paper's tables).
        let cost = report.cost_on(&workload);
        println!("loss curve (every {} steps):", bpe.max(1));
        for (step, loss) in report.loss_curve.iter().step_by(bpe.max(1)) {
            println!("  step {step:>5}: {loss:.4}");
        }
        println!("validation: {:?}", report.val_curve);
        println!(
            "result: val {:.4} | token acc {:.1}% | BLEU {} | {:.1} steps/s | cost {} arith {} dram\n",
            report.final_val_loss,
            report.final_eval_acc * 100.0,
            report.bleu().map_or("-".into(), |b| format!("{b:.2}")),
            report.steps_per_s(),
            fmt_cost(cost.map(|c| c.0)),
            fmt_cost(cost.map(|c| c.1)),
        );
        summary.push((name.to_string(), report, cost));
    }

    println!("== summary ==");
    println!(
        "{:<26} {:>8} {:>9} {:>8} {:>9} {:>9}",
        "run", "val", "acc%", "BLEU", "arith", "dram"
    );
    for (name, r, cost) in &summary {
        println!(
            "{:<26} {:>8.4} {:>8.1}% {:>8} {:>9} {:>9}",
            name,
            r.final_val_loss,
            r.final_eval_acc * 100.0,
            r.bleu().map_or("-".into(), |b| format!("{b:.2}")),
            fmt_cost(cost.map(|c| c.0)),
            fmt_cost(cost.map(|c| c.1)),
        );
    }
    // Write the JSON record for EXPERIMENTS.md.
    std::fs::create_dir_all("results")?;
    let json = dsq::util::json::Json::arr(summary.iter().map(|(name, r, cost)| {
        dsq::util::json::Json::obj(vec![
            ("run", dsq::util::json::Json::str(name)),
            ("report", r.to_json()),
            ("arith_rel", cost.map_or(dsq::util::json::Json::Null, |c| dsq::util::json::Json::num(c.0))),
            ("dram_rel", cost.map_or(dsq::util::json::Json::Null, |c| dsq::util::json::Json::num(c.1))),
        ])
    }));
    std::fs::write("results/e2e_train_translation.json", json.to_string_pretty())?;
    println!("\nwritten: results/e2e_train_translation.json");
    Ok(())
}
