//! Fine-tuning example (the paper's GLUE setup): pretrain the encoder
//! classifier on one synthetic task instance, then fine-tune it on a
//! *different* instance under DSQ — the "pre-train then fine-tune"
//! paradigm of §1, with the precision schedule applied to fine-tuning
//! exactly as the paper applies DSQ to RoBERTa-base.
//!
//! ```bash
//! make artifacts && cargo run --release --example finetune_classification
//! ```

use dsq::coordinator::{Finetuner, FinetuneConfig, LrSchedule};
use dsq::schedule::{DsqController, PrecisionConfig, Schedule, StaticSchedule};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    dsq::util::logging::level_from_env();
    let ckpt = std::env::temp_dir().join("dsq_pretrained_encoder.bin");

    // Phase 1: "pre-training" — task instance seed 100, fp32.
    println!("== phase 1: pretrain encoder (task seed 100, fp32) ==");
    let pre_cfg = FinetuneConfig {
        seed: 100,
        epochs: 3,
        batches_per_epoch: 25,
        lr: LrSchedule::Polynomial { lr: 1e-3, warmup_steps: 15, total_steps: 2000 },
        nclasses: 3,
        val_batches: 3,
        checkpoint: Some(ckpt.clone()),
        ..FinetuneConfig::quick("artifacts".into())
    };
    let mut schedule: Box<dyn Schedule> = Box::new(StaticSchedule(PrecisionConfig::FP32));
    let report = Finetuner::new(pre_cfg)?.run(schedule.as_mut())?;
    println!(
        "pretrained: val {:.4}, acc {:.1}%\n",
        report.final_val_loss,
        report.accuracy().unwrap_or(f64::NAN) * 100.0
    );

    // Phase 2: fine-tune on a new task instance (seed 200) under DSQ vs
    // from-scratch — transfer should win at equal budget.
    for (name, init) in [("fine-tune from checkpoint", Some(ckpt.clone())), ("from scratch", None)]
    {
        println!("== phase 2 ({name}, task seed 200, DSQ schedule) ==");
        let cfg = FinetuneConfig {
            seed: 200,
            epochs: 3,
            batches_per_epoch: 25,
            lr: LrSchedule::Polynomial { lr: 5e-4, warmup_steps: 10, total_steps: 2000 },
            nclasses: 3,
            val_batches: 3,
            init_checkpoint: init,
            ..FinetuneConfig::quick("artifacts".into())
        };
        let mut schedule: Box<dyn Schedule> =
            Box::new(DsqController::paper_default("bfp").unwrap());
        let report = Finetuner::new(cfg)?.run(schedule.as_mut())?;
        println!(
            "{name}: val {:.4}, acc {:.1}%, trace {:?}\n",
            report.final_val_loss,
            report.accuracy().unwrap_or(f64::NAN) * 100.0,
            report
                .trace
                .iter()
                .map(|(p, n)| format!("{}x{}", p.notation(), n))
                .collect::<Vec<_>>()
        );
    }
    std::fs::remove_file(&ckpt).ok();
    Ok(())
}
