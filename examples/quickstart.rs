//! Quickstart: the smallest complete DSQ workflow.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Loads the AOT artifacts, initializes a model, runs a handful of
//! training steps at three precision configs (fp32, static stashing,
//! DSQ level 0), and prints each step's loss plus the hardware cost the
//! cost model assigns to the configs on the paper-scale IWSLT workload.

use dsq::coordinator::{LrSchedule, Trainer, TrainerConfig};
use dsq::costmodel::{self, TransformerWorkload};
use dsq::data::Variant;
use dsq::schedule::{FormatSpec, PrecisionConfig, Schedule, StaticSchedule};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    dsq::util::logging::level_from_env();
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".to_string());

    let workload = TransformerWorkload::iwslt_6layer();
    println!("== DSQ quickstart ==\n");
    println!("precision configs and their hardware cost (paper-scale IWSLT, fixed32 = 1.00x):");
    let configs = [
        ("fp32", PrecisionConfig::FP32),
        ("stashing BFP [16,4,4,16]", PrecisionConfig::stashing(FormatSpec::bfp(16))),
        ("DSQ level 0 [2,2,2,16]", PrecisionConfig::of(FormatSpec::bfp(16), [2, 2, 2, 16])),
    ];
    for (name, p) in &configs {
        let row = costmodel::normalized_row(&workload, name, p, !p.is_fp32());
        println!("  {}", row.fmt_paper_style());
    }

    println!("\ntraining 2 epochs x 6 batches under each config (same seed):");
    for (name, p) in &configs {
        let cfg = TrainerConfig {
            epochs: 2,
            batches_per_epoch: 6,
            lr: LrSchedule::InverseSqrt { peak_lr: 3e-3, warmup_steps: 20 },
            variant: Variant::Iwslt,
            val_batches: 2,
            bleu_batches: 0,
            prefetch: 2,
            ..TrainerConfig::quick(artifacts.clone().into())
        };
        let mut schedule: Box<dyn Schedule> = Box::new(StaticSchedule(*p));
        let mut trainer = Trainer::new(cfg)?;
        let report = trainer.run(schedule.as_mut())?;
        println!(
            "  {name:<28} loss {:.4} -> {:.4} | val {:.4} | {:.1} steps/s",
            report.loss_curve.first().map(|x| x.1).unwrap_or(f64::NAN),
            report.loss_curve.last().map(|x| x.1).unwrap_or(f64::NAN),
            report.final_val_loss,
            report.steps_per_s(),
        );
    }
    println!("\nnext: cargo run --release --example train_translation  (the full e2e driver)");
    Ok(())
}
