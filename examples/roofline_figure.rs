//! Figure 1 as an ASCII roofline plot: where fp32, static quantization
//! and DSQ training sit relative to the machine balance point, on both
//! an A100-like and an edge-device profile (the paper's on-device
//! motivation).
//!
//! ```bash
//! cargo run --release --example roofline_figure
//! ```
//! (cost model only — no artifacts/PJRT needed.)

use dsq::costmodel::{roofline, Machine, TransformerWorkload};
use dsq::experiments::figure1;

fn main() {
    let w = TransformerWorkload::iwslt_6layer();
    for machine in [Machine::a100_like(), Machine::edge_like()] {
        figure1::print_roofline(&machine, &w);
        plot(&machine, &w);
        println!();
    }
}

/// Log-log ASCII plot: roofline curve + the figure's points.
fn plot(m: &Machine, w: &TransformerWorkload) {
    const COLS: usize = 72;
    const ROWS: usize = 16;
    let points = figure1::figure_points(w, m);
    let (x_lo, x_hi) = (0.1f64.ln(), 1000.0f64.ln());
    let y_hi = m.peak_macs_per_s.ln();
    let y_lo = m.attainable(0.1).ln();

    let mut grid = vec![vec![b' '; COLS]; ROWS];
    // Roofline curve.
    for c in 0..COLS {
        let x = (x_lo + (x_hi - x_lo) * c as f64 / (COLS - 1) as f64).exp();
        let y = m.attainable(x).ln();
        let r = ((y_hi - y) / (y_hi - y_lo) * (ROWS - 1) as f64).round() as usize;
        if r < ROWS {
            grid[r][c] = b'.';
        }
    }
    // Balance point marker.
    let bc = ((m.balance().ln() - x_lo) / (x_hi - x_lo) * (COLS - 1) as f64).round() as usize;
    for row in grid.iter_mut() {
        if bc < COLS && row[bc] == b' ' {
            row[bc] = b'|';
        }
    }
    // Points (1)(2)(3)...
    for (i, p) in points.iter().enumerate() {
        let c = (((p.intensity.ln() - x_lo) / (x_hi - x_lo)) * (COLS - 1) as f64)
            .round()
            .clamp(0.0, (COLS - 1) as f64) as usize;
        let y = p.attainable.ln();
        let r = ((y_hi - y) / (y_hi - y_lo) * (ROWS - 1) as f64).round() as usize;
        if r < ROWS {
            grid[r][c] = b'1' + i as u8;
        }
    }
    println!("  attainable (log)  [| = balance point I_opt = {:.0} MAC/byte]", m.balance());
    for row in &grid {
        println!("  {}", String::from_utf8_lossy(row));
    }
    println!("  0.1 {:>66}", "operational intensity (MAC/byte, log) 1000");
    for (i, p) in points.iter().enumerate() {
        println!("   {}: {} (I = {:.1})", i + 1, p.label, p.intensity);
    }
}

#[allow(unused_imports)]
use roofline as _;
