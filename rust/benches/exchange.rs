//! Bench: the replica exchange — one dequant–reduce–requant all-reduce
//! round between two in-process replicas, across the registry formats.
//!
//! One "round" is the exchange's real per-step work on both ranks:
//! encode the full (params, m, v) state into packed v2 wire records,
//! meet at the ring barrier, decode every peer frame, mean, and requant
//! at salt 0. The scoped-thread spawn that hosts the two replicas is
//! inside the timed region — that is the price the in-process design
//! actually pays per `run_replicas` call, and it is identical across
//! formats, so the per-format delta is pure codec + reduce cost.
//!
//! Every format cell runs under **both transports**: `mem` (the
//! in-memory ring, scoped-thread spawn inside the timed region as
//! above) and `socket` (a TCP-loopback [`SocketHub`] plus two
//! long-lived connected ranks — bind/connect/handshake happen once
//! per cell outside the timed region, so the socket row is the
//! steady-state per-round wire cost, framing and loopback included).
//! The transport is a column in the row label and lands in
//! `BENCH_exchange.json` like any other cell.
//!
//! `--smoke` (or `DSQ_BENCH_SMOKE=1`): a seconds-long CI profile that
//! still executes every format cell and *asserts* on each that the
//! comms meter agrees with the cost model within box-metadata slack
//! ([`dsq::stash::audit_observed_comms`]), and that the fp32 wire
//! format is bit-transparent (a mirrored 2-replica reduce leaves the
//! state untouched) — on the mem *and* the socket transport — an
//! exchange regression fails the workflow, not just a number. Leaves
//! `BENCH_exchange.json` at the repo root for `dsq bench gate`.

use std::sync::{mpsc, Arc};

use dsq::bench::{header, Bencher, JsonReport};
use dsq::model::ModelState;
use dsq::quant::{registered_specs, FormatSpec};
use dsq::runtime::HostTensor;
use dsq::stash::{audit_observed_comms, run_replicas, Exchange, SocketHub, SocketTransport};
use dsq::util::rng::Pcg32;

fn make_state(rng: &mut Pcg32, scale: usize) -> ModelState {
    // Same transformer-ish mix the stash-store bench uses: square
    // weights, a ragged projection, a bias.
    let mk = |rows: usize, cols: usize, rng: &mut Pcg32| {
        let data: Vec<f32> =
            (0..rows * cols).map(|_| rng.normal() * (rng.f32() * 6.0 - 3.0).exp2()).collect();
        if rows == 1 {
            HostTensor::f32(vec![cols], data)
        } else {
            HostTensor::f32(vec![rows, cols], data)
        }
    };
    let params = vec![
        mk(scale, scale, rng),
        mk(scale, scale + 5, rng), // minor axis not a box multiple
        mk(1, scale, rng),
    ];
    let zeros: Vec<HostTensor> = params.iter().map(HostTensor::zeros_like).collect();
    ModelState { params, m: zeros.clone(), v: zeros, step: 1 }
}

fn flat(state: &ModelState) -> Vec<f32> {
    let mut out = Vec::new();
    for group in [&state.params, &state.m, &state.v] {
        for t in group {
            out.extend_from_slice(t.as_f32().expect("dense"));
        }
    }
    out
}

/// One full 2-replica round: both ranks all-reduce `dense`, return
/// rank 0's post-reduce state.
fn one_round(spec: FormatSpec, dense: &ModelState) -> ModelState {
    run_replicas(2, spec, |_rank, ex| {
        let mut st = dense.clone();
        ex.all_reduce_state(&mut st, 1.0)?;
        Ok(st)
    })
    .expect("exchange round")
}

/// The socket column's counterpart of [`one_round`]'s host: a
/// TCP-loopback hub plus two connected ranks on long-lived threads,
/// each doing one all-reduce per command. Bind, connect, and handshake
/// happen once in [`SocketRig::start`]; [`SocketRig::round`] is the
/// timed steady-state unit.
struct SocketRig {
    cmds: Vec<mpsc::Sender<ModelState>>,
    done: mpsc::Receiver<ModelState>,
    ranks: Vec<std::thread::JoinHandle<()>>,
    hub: std::thread::JoinHandle<dsq::Result<u64>>,
}

impl SocketRig {
    fn start(spec: FormatSpec) -> SocketRig {
        let hub = SocketHub::bind("127.0.0.1:0", 2, b"bench".to_vec()).expect("bind bench hub");
        let addr = hub.addr().to_string();
        let hub = std::thread::spawn(move || hub.serve());
        let (done_tx, done) = mpsc::channel();
        let mut cmds = Vec::new();
        let mut ranks = Vec::new();
        for rank in 0..2usize {
            let (tx, rx) = mpsc::channel::<ModelState>();
            cmds.push(tx);
            let addr = addr.clone();
            let done_tx = done_tx.clone();
            ranks.push(std::thread::spawn(move || {
                let (t, _config) =
                    SocketTransport::connect(&addr, rank, 2).expect("connect bench rank");
                let ex = Exchange::with_transport(spec, Arc::new(t));
                let h = ex.handle(rank).expect("bench rank handle");
                for mut st in rx {
                    h.all_reduce_state(&mut st, 1.0).expect("socket exchange round");
                    if rank == 0 {
                        done_tx.send(st).expect("report bench round");
                    }
                }
            }));
        }
        SocketRig { cmds, done, ranks, hub }
    }

    /// One mirrored 2-replica round over the wire; returns rank 0's
    /// post-reduce state.
    fn round(&self, dense: &ModelState) -> ModelState {
        for tx in &self.cmds {
            tx.send(dense.clone()).expect("dispatch bench round");
        }
        self.done.recv().expect("collect bench round")
    }

    /// Drop the command lanes, letting both ranks EOF their streams so
    /// the hub winds down cleanly.
    fn shutdown(self) {
        drop(self.cmds);
        for t in self.ranks {
            t.join().expect("bench rank thread");
        }
        self.hub.join().expect("bench hub thread").expect("bench hub serve");
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("DSQ_BENCH_SMOKE").is_ok_and(|v| v == "1");
    header(if smoke {
        "Replica exchange: 2-replica all-reduce round (smoke profile)"
    } else {
        "Replica exchange: 2-replica all-reduce round latency + traffic"
    });
    let b = if smoke {
        Bencher {
            warmup: std::time::Duration::from_millis(10),
            measure: std::time::Duration::from_millis(40),
            min_iters: 2,
            max_iters: 1_000,
        }
    } else {
        Bencher::default()
    };
    let mut json = JsonReport::new("exchange", if smoke { "smoke" } else { "full" });
    let scale = if smoke { 48 } else { 128 };
    let mut rng = Pcg32::new(7);

    let widths = [4u32, 8, 16];
    let mut specs = vec![FormatSpec::Fp32];
    specs.extend(registered_specs(&widths).into_iter().filter(|s| *s != FormatSpec::Fp32));
    for spec in specs {
        let dense = make_state(&mut rng, scale);
        let elems: usize = dense.params.iter().map(HostTensor::len).sum::<usize>() * 3;
        if smoke {
            // Correctness gates (the reason CI runs this in smoke mode):
            // meter-vs-model agreement on every format cell, and fp32
            // bit-transparency of the mirrored reduce on both transports.
            audit_observed_comms(&spec)
                .unwrap_or_else(|e| panic!("{spec}: comms meter disagrees: {e}"));
            if spec == FormatSpec::Fp32 {
                let reduced = one_round(spec, &dense);
                assert_eq!(
                    flat(&reduced).iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    flat(&dense).iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    "fp32 mirrored all-reduce must be bit-transparent"
                );
            }
        }
        let r = b.bench(&format!("{spec:<8} mem    2-replica round ({elems} elems)"), || {
            std::hint::black_box(one_round(spec, &dense));
        });
        println!("{}", r.report());
        json.push(&r, Some(elems as f64));

        let rig = SocketRig::start(spec);
        if smoke && spec == FormatSpec::Fp32 {
            let reduced = rig.round(&dense);
            assert_eq!(
                flat(&reduced).iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                flat(&dense).iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "fp32 mirrored all-reduce must be bit-transparent over the socket transport"
            );
        }
        let r = b.bench(&format!("{spec:<8} socket 2-replica round ({elems} elems)"), || {
            std::hint::black_box(rig.round(&dense));
        });
        println!("{}", r.report());
        json.push(&r, Some(elems as f64));
        rig.shutdown();
    }
    match json.write() {
        Ok(p) => println!("\nwrote {}", p.display()),
        Err(e) => eprintln!("could not write bench json: {e}"),
    }
}
