//! Bench: the tiered stash store — per-step latency and traffic of the
//! resident tier vs the all-spill tier, across the registry formats.
//!
//! One "step" is the store's real per-step work: take a dense state
//! (as `absorb_step_output` leaves it), stash it (pack + budget
//! enforcement + index write), then fetch it back for dispatch — so
//! the spilled profile pays the encode, the segment write, *and* the
//! readback, exactly like a budget-0 training run. The dense clone
//! that resets the state each iteration is included in both profiles,
//! so the resident/spilled delta is pure tier cost.
//!
//! `--smoke` (or `DSQ_BENCH_SMOKE=1`): a seconds-long CI profile that
//! still executes every (format, budget) cell and *asserts* on each
//! cell that the traffic meter agrees with the cost model within
//! box-metadata slack and that spill readback reproduced the resident
//! bytes — a stash-store regression fails the workflow, not just a
//! number. CI runs both budget extremes by construction: every cell
//! pair is one all-resident run and one all-spill run.

use dsq::bench::{header, Bencher, JsonReport};
use dsq::model::ModelState;
use dsq::quant::registered_specs;
use dsq::runtime::HostTensor;
use dsq::stash::{StashBudget, StashStore};
use dsq::util::rng::Pcg32;

fn make_state(rng: &mut Pcg32, scale: usize) -> ModelState {
    // A transformer-ish mix: square weights, a ragged projection, a bias.
    let mk = |rows: usize, cols: usize, rng: &mut Pcg32| {
        let data: Vec<f32> =
            (0..rows * cols).map(|_| rng.normal() * (rng.f32() * 6.0 - 3.0).exp2()).collect();
        if rows == 1 {
            HostTensor::f32(vec![cols], data)
        } else {
            HostTensor::f32(vec![rows, cols], data)
        }
    };
    let params = vec![
        mk(scale, scale, rng),
        mk(scale, scale + 5, rng), // minor axis not a box multiple
        mk(1, scale, rng),
    ];
    let zeros: Vec<HostTensor> = params.iter().map(HostTensor::zeros_like).collect();
    ModelState { params, m: zeros.clone(), v: zeros, step: 1 }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("DSQ_BENCH_SMOKE").is_ok_and(|v| v == "1");
    header(if smoke {
        "Stash store: resident vs spilled step (smoke profile)"
    } else {
        "Stash store: resident vs spilled step latency + traffic"
    });
    let b = if smoke {
        Bencher {
            warmup: std::time::Duration::from_millis(10),
            measure: std::time::Duration::from_millis(40),
            min_iters: 2,
            max_iters: 1_000,
        }
    } else {
        Bencher::default()
    };
    // Machine-readable trajectory (ROADMAP 3b): every run leaves
    // BENCH_stash.json at the repo root.
    let mut json = JsonReport::new("stash", if smoke { "smoke" } else { "full" });
    let scale = if smoke { 48 } else { 128 };
    let mut rng = Pcg32::new(7);

    let widths = [2u32, 4, 8, 16];
    let specs = registered_specs(&widths);
    for spec in specs {
        let dense = make_state(&mut rng, scale);
        let elems: usize = dense.params.iter().map(HostTensor::len).sum::<usize>() * 3;
        for (tier, budget) in
            [("resident", StashBudget::Unlimited), ("spilled", StashBudget::Bytes(0))]
        {
            // One instrumented cycle first: exact per-step traffic for
            // the report, and the smoke-mode correctness gates.
            let t = {
                let mut probe = StashStore::ephemeral(spec, budget).expect("store");
                let mut st = dense.clone();
                probe.stash_state(&mut st).expect("stash");
                probe.fetch_state(&mut st).expect("fetch");
                probe.note_dispatch_read(&st);
                probe.traffic_report()
            };
            if smoke {
                // Correctness gates (the reason CI runs this in smoke
                // mode): meter-vs-model agreement on every cell, and
                // real spill traffic on the budget-0 cells.
                assert!(
                    t.agrees(),
                    "{spec} {tier}: observed {} bits vs modeled {} bits (allowance {})",
                    t.meter.observed_stash_bits(),
                    t.meter.modeled_stash_bits,
                    t.allowance_bits
                );
                match budget {
                    StashBudget::Bytes(0) => {
                        assert!(
                            t.meter.spill_write_bytes > 0,
                            "{spec}: budget 0 must produce spill traffic"
                        );
                        assert_eq!(
                            t.meter.spill_read_bytes, t.meter.spill_write_bytes,
                            "{spec}: every spilled record reads back exactly once per step"
                        );
                    }
                    _ => assert!(
                        !t.meter.spilled(),
                        "{spec}: unlimited budget must never spill"
                    ),
                }
            }
            // Then the timed loop: the store's full per-step cycle from
            // the dense post-absorb form.
            let mut store = StashStore::ephemeral(spec, budget).expect("store");
            let mut state = dense.clone();
            let r = b.bench(&format!("{spec:<8} {tier} step ({elems} elems)"), || {
                state = dense.clone();
                store.stash_state(&mut state).expect("stash");
                store.fetch_state(&mut state).expect("fetch");
                store.note_dispatch_read(&state);
            });
            println!("{}", r.report());
            json.push(&r, Some(elems as f64));
            println!(
                "    traffic/step: stash W {:.1} KiB R {:.1} KiB, spill W {:.1} KiB R {:.1} KiB",
                t.meter.stash_write_bytes as f64 / 1024.0,
                t.meter.stash_read_bytes as f64 / 1024.0,
                t.meter.spill_write_bytes as f64 / 1024.0,
                t.meter.spill_read_bytes as f64 / 1024.0,
            );
        }
    }
    match json.write() {
        Ok(p) => println!("\nwrote {}", p.display()),
        Err(e) => eprintln!("could not write bench json: {e}"),
    }
}
