//! Bench: regenerate Table 1's IWSLT cost columns and time the cost
//! model itself (the harness that produces every table).
//!
//! The accuracy half of Table 1 comes from training runs
//! (`dsq experiment table1-iwslt`); this bench regenerates the
//! hardware-cost half and checks it against the paper's reference
//! values, row by row, while timing table generation.

use dsq::bench::{header, Bencher};
use dsq::costmodel::{self, tables, TransformerWorkload};
use dsq::schedule::{FormatSpec, PrecisionConfig};

fn main() {
    header("Table 1 (IWSLT17 DE-EN, 6-layer transformer) — cost columns");
    let w = TransformerWorkload::iwslt_6layer();

    println!(
        "{:<18} {:<16} {:>8} {:>8}   {:>8} {:>8}",
        "method", "precision", "arith", "dram", "paper-a", "paper-d"
    );
    for (m, p, score) in tables::standard_methods() {
        let row = costmodel::normalized_row(&w, m, &p, score);
        let paper = tables::PAPER_COST_ROWS
            .iter()
            .find(|(pm, pp, _, _)| *pm == m && *pp == p.notation());
        println!(
            "{:<18} {:<16} {:>8} {:>8}   {:>8} {:>8}",
            m,
            p.notation(),
            row.arith_rel.map_or("-".into(), |v| format!("{v:.3}x")),
            row.dram_rel.map_or("-".into(), |v| format!("{v:.3}x")),
            paper.map_or("-".into(), |(_, _, a, _)| format!("{a:.2}x")),
            paper.map_or("-".into(), |(_, _, _, d)| format!("{d:.2}x")),
        );
    }
    let lo = PrecisionConfig::of(FormatSpec::bfp(16), [2, 2, 2, 16]);
    let hi = PrecisionConfig::stashing(FormatSpec::bfp(16));
    let dsq = tables::dsq_trace_row(&w, &[(lo, 96), (hi, 4)]);
    println!(
        "{:<18} {:<16} {:>8} {:>8}   {:>8} {:>8}",
        "DSQ (BFP)",
        "-",
        format!("{:.3}x", dsq.arith_rel.unwrap()),
        format!("{:.3}x", dsq.dram_rel.unwrap()),
        "0.012x",
        "0.20x"
    );
    let f16 = costmodel::normalized_row(
        &w,
        "fixed16",
        &PrecisionConfig::uniform(FormatSpec::fixed(16)),
        true,
    );
    println!(
        "\nheadline: {:.1}x fewer arith ops, {:.2}x less DRAM vs fixed-16 (paper 20.95x / 2.55x)\n",
        f16.arith_rel.unwrap() / dsq.arith_rel.unwrap(),
        f16.dram_rel.unwrap() / dsq.dram_rel.unwrap()
    );

    // Timing: full-table generation is the repeated unit in sweeps.
    let b = Bencher::default();
    let r = b.bench("table1 cost-column generation (8 rows)", || {
        for (m, p, score) in tables::standard_methods() {
            std::hint::black_box(costmodel::normalized_row(&w, m, &p, score));
        }
        std::hint::black_box(tables::dsq_trace_row(&w, &[(lo, 96), (hi, 4)]));
    });
    println!("{}", r.report());
}
