//! Bench: end-to-end train-step latency through PJRT (the L3 request
//! path) at each precision config, plus the executable-dispatch
//! before/after comparison for the Session engine's memoized cache.
//!
//! This is the real-hardware half of §Perf: what one coordinator step
//! costs on this testbed, and how the runtime overhead (literal
//! marshalling, executable lookup) compares to the XLA compute.
//!
//! **Executable dispatch**: before the Session engine, both training
//! loops resolved the step executable on *every step* via
//! `manifest lookup -> PathBuf join -> global runtime mutex -> hash
//! probe` (`rt.load(man.model_path(...))`). The Session routes steps
//! through a per-run `ExeCache` that resolves each `(model, kind)` once
//! and then serves a local `HashMap` hit. Both paths are timed below so
//! the win is recorded, not assumed.
//!
//! Requires `make artifacts`. The artifact compile (~2 min) happens once
//! at startup and is excluded from the timings.

use std::path::PathBuf;

use dsq::bench::{fmt_ns, header, Bencher};
use dsq::coordinator::{ExeCache, LrSchedule, Trainer, TrainerConfig};
use dsq::data::Variant;
use dsq::runtime::Runtime;
use dsq::schedule::{FormatSpec, PrecisionConfig, Schedule, StaticSchedule};

fn main() {
    let artifacts = PathBuf::from("artifacts");
    if !artifacts.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    header("Train-step latency (PJRT CPU, small testbed model)");

    let configs = [
        ("fp32 [32,32,32,32]", PrecisionConfig::FP32),
        ("bfp [16,16,16,16]", PrecisionConfig::uniform(FormatSpec::bfp(16))),
        ("bfp stash [16,4,4,16]", PrecisionConfig::stashing(FormatSpec::bfp(16))),
        ("bfp dsq-lo [2,2,2,16]", PrecisionConfig::of(FormatSpec::bfp(16), [2, 2, 2, 16])),
        ("fixed [16,16,16,16]", PrecisionConfig::uniform(FormatSpec::fixed(16))),
        ("fixed-sr [16,4,4,16]", PrecisionConfig::stashing(FormatSpec::fixed_sr(16))),
    ];

    for (name, p) in configs {
        // One epoch of a few steps under a static schedule, timed from
        // the report (the Session engine itself is the measured path).
        let cfg = TrainerConfig {
            epochs: 1,
            batches_per_epoch: 20,
            lr: LrSchedule::Constant { lr: 1e-3 },
            variant: Variant::Iwslt,
            val_batches: 1,
            bleu_batches: 0,
            ..TrainerConfig::quick(artifacts.clone())
        };
        let mut schedule: Box<dyn Schedule> = Box::new(StaticSchedule(p));
        let mut trainer = Trainer::new(cfg).expect("trainer");
        // Warm the runtime's compile cache outside the timing.
        let report = trainer.run(schedule.as_mut()).expect("run");
        // First run includes compile; run a second trainer for steady state.
        let cfg2 = TrainerConfig {
            epochs: 1,
            batches_per_epoch: 30,
            ..trainer.cfg.clone()
        };
        let mut trainer2 = Trainer::new(cfg2).expect("trainer2");
        let report2 = trainer2.run(schedule.as_mut()).expect("run2");
        let per_step_ns = report2.wall_s / report2.steps as f64 * 1e9;
        println!(
            "{:<26} {:>12}/step  ({:.2} steps/s; first-epoch incl-compile {:.1}s)",
            name,
            fmt_ns(per_step_ns),
            report2.steps_per_s(),
            report.wall_s
        );
    }

    // Executable dispatch: the legacy per-step path vs the Session's
    // memoized cache (both hot — compile cost excluded by the warmup).
    let b = Bencher::default();
    let man = dsq::runtime::ArtifactManifest::load(&artifacts).unwrap();
    let rt = Runtime::global();
    let legacy = b.bench("dispatch: rt.load(model_path) per step (before)", || {
        std::hint::black_box(rt.load(&man.model_path("nmt", "train_bfp").unwrap()).unwrap());
    });
    let mut cache = ExeCache::new(&man, "nmt").unwrap();
    let cached = b.bench("dispatch: ExeCache::get per step (after)", || {
        std::hint::black_box(cache.get("train_bfp").unwrap());
    });
    println!("\n{}", legacy.report());
    println!("{}", cached.report());
    println!(
        "memoized dispatch saves {} per step ({:.1}x)",
        fmt_ns(legacy.mean_ns - cached.mean_ns),
        legacy.mean_ns / cached.mean_ns.max(1e-9)
    );

    // Literal marshalling overhead: build the input vec without executing.
    let state =
        dsq::model::ModelState::init(rt, &man, "nmt", 0).unwrap();
    let r = b.bench("host->literal conversion of full param set", || {
        for t in &state.params {
            std::hint::black_box(t.to_literal().unwrap());
        }
    });
    println!("\n{}", r.report());
}
