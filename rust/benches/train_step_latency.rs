//! Bench: end-to-end train-step latency through PJRT (the L3 request
//! path) at each precision config, the executable-dispatch before/after
//! comparison for the Session engine's memoized cache, and the span
//! recorder's overhead budget.
//!
//! **Recorder overhead** (artifact-free, also the `DSQ_BENCH_SMOKE=1`
//! CI mode): a synthetic ~100 µs step is timed three ways —
//! uninstrumented, with the session's span pattern against a *disabled*
//! recorder, and with tracing on (spans + per-step flush into a temp
//! dir). Passes alternate between the variants and each variant keeps
//! its best (min) median across repeats, so drift hits all three
//! equally. Smoke mode asserts the disabled recorder stays within 1% of
//! the uninstrumented median — the "tracing off costs nothing" contract
//! `--trace` rests on.
//!
//! **Executable dispatch**: before the Session engine, both training
//! loops resolved the step executable on *every step* via
//! `manifest lookup -> PathBuf join -> global runtime mutex -> hash
//! probe` (`rt.load(man.model_path(...))`). The Session routes steps
//! through a per-run `ExeCache` that resolves each `(model, kind)` once
//! and then serves a local `HashMap` hit. Both paths are timed below so
//! the win is recorded, not assumed.
//!
//! The PJRT sections require `make artifacts` (the compile happens once
//! at startup, excluded from timings) and are skipped — loudly — when
//! the artifacts are absent. Results land in `BENCH_train_step.json`.

use std::path::PathBuf;

use dsq::bench::{fmt_ns, header, BenchResult, Bencher, JsonReport};
use dsq::coordinator::{ExeCache, LrSchedule, Trainer, TrainerConfig};
use dsq::data::Variant;
use dsq::obs::{Phase, Recorder};
use dsq::runtime::Runtime;
use dsq::schedule::{FormatSpec, PrecisionConfig, Schedule, StaticSchedule};

/// The stand-in for one XLA step: ~100 µs of FMA over a small buffer,
/// big enough that per-span nanoseconds are measured against realistic
/// step granularity rather than an empty loop.
fn synthetic_step(xs: &mut [f32]) {
    for _ in 0..32 {
        for x in xs.iter_mut() {
            *x = x.mul_add(1.000_1, 3.0e-4);
        }
    }
    std::hint::black_box(xs.first().copied());
}

/// The session's per-step span pattern (see `Session::run`): four
/// top-level spans around the work plus one imported sub-phase.
fn instrumented_step(obs: &Recorder, step: u64, xs: &mut [f32]) {
    let b = obs.span_start(Phase::BatchWait);
    obs.span_close(b, step, 0);
    let r = obs.span_start(Phase::StashRead);
    obs.span_close(r, step, 0);
    let d = obs.span_start(Phase::Dispatch);
    synthetic_step(xs);
    obs.span_close(d, step, 0);
    let w = obs.span_start(Phase::StashWrite);
    obs.span_close(w, step, 4096);
    obs.span_import(Phase::Quantize, step, 1, 4096);
}

/// Alternating passes, min-of-medians: returns the three best medians
/// (baseline, disabled, traced) plus the last full result of each for
/// the JSON report.
fn recorder_overhead(b: &Bencher, reps: usize) -> ([f64; 3], [BenchResult; 3]) {
    let mut trace_dir = std::env::temp_dir();
    trace_dir.push(format!("dsq-bench-trace-{}", std::process::id()));
    std::fs::remove_dir_all(&trace_dir).ok();
    let disabled = Recorder::disabled();
    let traced = Recorder::to_dir(&trace_dir, 0).expect("bench trace dir");

    let mut xs = vec![1.0f32; 8192];
    let mut step = 0u64;
    let mut best = [f64::INFINITY; 3];
    let mut last: [Option<BenchResult>; 3] = [None, None, None];
    for _ in 0..reps {
        let r0 = b.bench("step: uninstrumented baseline", || synthetic_step(&mut xs));
        let r1 = b.bench("step: recorder disabled", || {
            step += 1;
            instrumented_step(&disabled, step, &mut xs);
        });
        let r2 = b.bench("step: tracing on (spans + flush)", || {
            step += 1;
            instrumented_step(&traced, step, &mut xs);
            traced.flush_events().expect("flush bench trace");
        });
        for (i, r) in [r0, r1, r2].into_iter().enumerate() {
            best[i] = best[i].min(r.median_ns);
            last[i] = Some(r);
        }
    }
    std::fs::remove_dir_all(&trace_dir).ok();
    (best, last.map(|r| r.expect("reps >= 1")))
}

fn main() {
    let smoke = std::env::var("DSQ_BENCH_SMOKE").is_ok_and(|v| v == "1");
    let profile = if smoke { "smoke" } else { "full" };
    let mut json = JsonReport::new("train_step", profile);

    // ---- Recorder overhead (artifact-free; the smoke-mode payload) --
    header("Recorder overhead (synthetic ~100 µs step)");
    let (b, reps) = if smoke {
        let quick = Bencher {
            warmup: std::time::Duration::from_millis(50),
            measure: std::time::Duration::from_millis(200),
            min_iters: 30,
            max_iters: 100_000,
        };
        (quick, 3)
    } else {
        (Bencher::default(), 5)
    };
    let (best, results) = recorder_overhead(&b, reps);
    for r in &results {
        println!("{}", r.report());
        json.push(r, None);
    }
    let [base, disabled, traced] = best;
    println!(
        "best medians: baseline {}, disabled {} ({:+.3}%), traced {} ({:+.3}%)",
        fmt_ns(base),
        fmt_ns(disabled),
        (disabled / base - 1.0) * 100.0,
        fmt_ns(traced),
        (traced / base - 1.0) * 100.0,
    );
    if smoke {
        assert!(
            disabled <= base * 1.01,
            "disabled recorder costs {:.3}% over the uninstrumented step (budget: 1%)",
            (disabled / base - 1.0) * 100.0
        );
    }

    // ---- PJRT sections (need compiled artifacts) --------------------
    let artifacts = PathBuf::from("artifacts");
    if smoke || !artifacts.join("manifest.json").exists() {
        if !smoke {
            dsq::warn!("skipping the PJRT sections: run `make artifacts` first");
        }
        match json.write() {
            Ok(path) => dsq::info!("bench report written to {}", path.display()),
            Err(e) => dsq::warn!("could not write bench json: {e}"),
        }
        return;
    }
    header("Train-step latency (PJRT CPU, small testbed model)");

    let configs = [
        ("fp32 [32,32,32,32]", PrecisionConfig::FP32),
        ("bfp [16,16,16,16]", PrecisionConfig::uniform(FormatSpec::bfp(16))),
        ("bfp stash [16,4,4,16]", PrecisionConfig::stashing(FormatSpec::bfp(16))),
        ("bfp dsq-lo [2,2,2,16]", PrecisionConfig::of(FormatSpec::bfp(16), [2, 2, 2, 16])),
        ("fixed [16,16,16,16]", PrecisionConfig::uniform(FormatSpec::fixed(16))),
        ("fixed-sr [16,4,4,16]", PrecisionConfig::stashing(FormatSpec::fixed_sr(16))),
    ];

    for (name, p) in configs {
        // One epoch of a few steps under a static schedule, timed from
        // the report (the Session engine itself is the measured path).
        let cfg = TrainerConfig {
            epochs: 1,
            batches_per_epoch: 20,
            lr: LrSchedule::Constant { lr: 1e-3 },
            variant: Variant::Iwslt,
            val_batches: 1,
            bleu_batches: 0,
            ..TrainerConfig::quick(artifacts.clone())
        };
        let mut schedule: Box<dyn Schedule> = Box::new(StaticSchedule(p));
        let mut trainer = Trainer::new(cfg).expect("trainer");
        // Warm the runtime's compile cache outside the timing.
        let report = trainer.run(schedule.as_mut()).expect("run");
        // First run includes compile; run a second trainer for steady state.
        let cfg2 = TrainerConfig {
            epochs: 1,
            batches_per_epoch: 30,
            ..trainer.cfg.clone()
        };
        let mut trainer2 = Trainer::new(cfg2).expect("trainer2");
        let report2 = trainer2.run(schedule.as_mut()).expect("run2");
        let per_step_ns = report2.wall_s / report2.steps as f64 * 1e9;
        println!(
            "{:<26} {:>12}/step  ({:.2} steps/s; first-epoch incl-compile {:.1}s)",
            name,
            fmt_ns(per_step_ns),
            report2.steps_per_s(),
            report.wall_s
        );
        json.push(
            &BenchResult {
                name: format!("train step: {name}"),
                iters: report2.steps,
                mean_ns: per_step_ns,
                median_ns: per_step_ns,
                stddev_ns: 0.0,
                min_ns: per_step_ns,
                max_ns: per_step_ns,
            },
            None,
        );
    }

    // Executable dispatch: the legacy per-step path vs the Session's
    // memoized cache (both hot — compile cost excluded by the warmup).
    let b = Bencher::default();
    let man = dsq::runtime::ArtifactManifest::load(&artifacts).unwrap();
    let rt = Runtime::global();
    let legacy = b.bench("dispatch: rt.load(model_path) per step (before)", || {
        std::hint::black_box(rt.load(&man.model_path("nmt", "train_bfp").unwrap()).unwrap());
    });
    let mut cache = ExeCache::new(&man, "nmt").unwrap();
    let cached = b.bench("dispatch: ExeCache::get per step (after)", || {
        std::hint::black_box(cache.get("train_bfp").unwrap());
    });
    println!("\n{}", legacy.report());
    println!("{}", cached.report());
    println!(
        "memoized dispatch saves {} per step ({:.1}x)",
        fmt_ns(legacy.mean_ns - cached.mean_ns),
        legacy.mean_ns / cached.mean_ns.max(1e-9)
    );
    json.push(&legacy, None);
    json.push(&cached, None);

    // Literal marshalling overhead: build the input vec without executing.
    let state =
        dsq::model::ModelState::init(rt, &man, "nmt", 0).unwrap();
    let r = b.bench("host->literal conversion of full param set", || {
        for t in &state.params {
            std::hint::black_box(t.to_literal().unwrap());
        }
    });
    println!("\n{}", r.report());
    json.push(&r, None);

    match json.write() {
        Ok(path) => dsq::info!("bench report written to {}", path.display()),
        Err(e) => dsq::warn!("could not write bench json: {e}"),
    }
}
