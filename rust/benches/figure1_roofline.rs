//! Bench: Figure 1 — roofline placements on A100-like and edge-like
//! machine profiles, plus timing of the placement computation.

use dsq::bench::{header, Bencher};
use dsq::costmodel::{Machine, TransformerWorkload};
use dsq::experiments::figure1;

fn main() {
    header("Figure 1 (roofline model)");
    let w = TransformerWorkload::iwslt_6layer();
    for m in [Machine::a100_like(), Machine::edge_like()] {
        figure1::print_roofline(&m, &w);
        println!();
    }
    let m = Machine::a100_like();
    let b = Bencher::default();
    let r = b.bench("figure1 point placement (5 configs)", || {
        std::hint::black_box(figure1::figure_points(&w, &m));
    });
    println!("{}", r.report());
}
