//! Bench: Table 4 (Appendix B) — the stash-precision sweep's cost side
//! plus quantization-error measurements that explain its BLEU shape.
//!
//! The paper's BLEU column needs training (`dsq experiment table4`);
//! here we regenerate, for every sweep point: the hardware cost columns
//! AND the measured stash quantization error (rust BFP mirror on a
//! transformer-like activation distribution) — the error curve is the
//! mechanism behind the BLEU cliff at [2,2,2,16].

use dsq::bench::{header, Bencher};
use dsq::costmodel::{self, TransformerWorkload};
use dsq::experiments::table4::SWEEP;
use dsq::quant;
use dsq::schedule::PrecisionConfig;
use dsq::util::rng::Pcg32;

fn main() {
    header("Table 4 (stash precision sweep)");
    let w = TransformerWorkload::iwslt_6layer();

    // Activation-like data (heavy-ish tails, like post-GELU/attention).
    let mut rng = Pcg32::new(4);
    let acts: Vec<f32> =
        (0..1 << 16).map(|_| rng.normal() * (rng.normal() * 1.5).exp()).collect();

    println!(
        "{:<14} {:>8} {:>8} {:>12} {:>12}   {:>8}",
        "precision", "arith", "dram", "q1 rel-err", "q0 rel-err", "paperΔ"
    );
    for (setup, paper_delta) in SWEEP {
        let p = PrecisionConfig::parse(&format!("bfp:{setup}")).unwrap();
        let row = costmodel::normalized_row(&w, "stash", &p, true);
        let err = |bits: f32| {
            let q = quant::bfp_quantize(&acts, 256, bits);
            let (mut num, mut den) = (0f64, 0f64);
            for (a, b) in acts.iter().zip(&q) {
                num += ((a - b) * (a - b)) as f64;
                den += (a * a) as f64;
            }
            (num / den).sqrt()
        };
        println!(
            "{:<14} {:>7.3}x {:>7.3}x {:>12.4} {:>12.4}   {:>+8.2}",
            setup,
            row.arith_rel.unwrap(),
            row.dram_rel.unwrap(),
            err(p.stash().bits() as f32),
            err(p.fwd().bits() as f32),
            paper_delta
        );
    }

    let b = Bencher::default();
    let r = b.bench("bfp stash quantize 64k elems @4b", || {
        std::hint::black_box(quant::bfp_quantize(&acts, 256, 4.0));
    });
    println!("\n{}  ({:.1} Melem/s)", r.report(), r.throughput(65536.0) / 1e6);
}
