//! Bench: Table 6 (Appendix D) — the WMT14 variant's cost columns.
//!
//! Same 6-layer architecture on the larger-vocab WMT workload; the cost
//! ratios carry over (they are per-step relative), the BLEU column needs
//! training on the harder bigram synthetic variant
//! (`dsq experiment table6`).

use dsq::bench::{header, Bencher};
use dsq::costmodel::{self, tables, TransformerWorkload};
use dsq::experiments::table6::PAPER_WMT_DELTAS;

fn main() {
    header("Table 6 (WMT14 EN-DE, 6-layer transformer) — cost columns");
    let w = TransformerWorkload::wmt_6layer();
    println!(
        "workload: {} ({:.0}M params, {:.1} GMAC/step fwd)",
        w.name,
        w.params / 1e6,
        w.total_macs() / 1e9
    );
    println!("{:<18} {:<16} {:>8} {:>8} {:>9}", "method", "precision", "arith", "dram", "paperΔ");
    for (m, p, score) in tables::standard_methods() {
        let row = costmodel::normalized_row(&w, m, &p, score);
        let paper = PAPER_WMT_DELTAS
            .iter()
            .find(|(pm, pp, _)| *pm == m && *pp == p.notation())
            .map(|(_, _, d)| *d);
        println!(
            "{:<18} {:<16} {:>8} {:>8} {:>9}",
            m,
            p.notation(),
            row.arith_rel.map_or("-".into(), |v| format!("{v:.3}x")),
            row.dram_rel.map_or("-".into(), |v| format!("{v:.3}x")),
            paper.map_or("-".into(), |d| format!("{d:+.2}")),
        );
    }

    let b = Bencher::default();
    let r = b.bench("wmt workload build + 7 rows", || {
        let w = TransformerWorkload::wmt_6layer();
        for (m, p, score) in tables::standard_methods() {
            std::hint::black_box(costmodel::normalized_row(&w, m, &p, score));
        }
    });
    println!("\n{}", r.report());
}
