//! Bench: Table 5 (Appendix C) — why q3 must stay >= 16.
//!
//! Regenerates the cost rows for [8,8,8,{32,16,8}] fixed-point and
//! measures the *gradient* quantization error of per-tensor fixed point
//! vs BFP at each q3 — the dynamic-range starvation that makes the
//! 8-bit row diverge (the training side is `dsq experiment table5`).

use dsq::bench::{header, Bencher};
use dsq::costmodel::{self, TransformerWorkload};
use dsq::experiments::table5::SWEEP;
use dsq::quant;
use dsq::schedule::PrecisionConfig;
use dsq::util::rng::Pcg32;

fn main() {
    header("Table 5 (gradient-output precision q3, fixed-point stashing)");
    let w = TransformerWorkload::iwslt_6layer();

    // Gradient-like data: near-sparse, heavy-tailed (a few dominant
    // directions + tiny everything else) — the worst case for a single
    // per-tensor exponent.
    let mut rng = Pcg32::new(5);
    let grads: Vec<f32> = (0..1 << 16)
        .map(|_| {
            if rng.chance(0.01) {
                rng.normal() * 10.0
            } else {
                rng.normal() * 0.01
            }
        })
        .collect();

    println!(
        "{:<14} {:>8} {:>8} {:>14} {:>14} {:>16}",
        "precision", "arith", "dram", "fixed rel-err", "bfp rel-err", "fixed zeroed %"
    );
    for (setup, _paper) in SWEEP {
        let p = PrecisionConfig::parse(&format!("fixed:{setup}")).unwrap();
        let row = costmodel::normalized_row(&w, "stash-fixed", &p, true);
        let qf = quant::fixed_quantize(&grads, p.grad().bits() as f32);
        let qb = quant::bfp_quantize(&grads, 256, p.grad().bits() as f32);
        let rel = |q: &[f32]| {
            let (mut num, mut den) = (0f64, 0f64);
            for (a, b) in grads.iter().zip(q) {
                num += ((a - b) * (a - b)) as f64;
                den += (a * a) as f64;
            }
            (num / den).sqrt()
        };
        let zeroed =
            qf.iter().zip(&grads).filter(|(q, g)| **q == 0.0 && **g != 0.0).count() as f64
                / grads.len() as f64;
        println!(
            "{:<14} {:>7.3}x {:>7.3}x {:>14.4} {:>14.4} {:>15.1}%",
            setup,
            row.arith_rel.unwrap(),
            row.dram_rel.unwrap(),
            rel(&qf),
            rel(&qb),
            zeroed * 100.0
        );
    }
    println!("\n(q3=8 fixed point zeroes nearly all small gradient mass -> divergence, paper 'Failed')");

    let b = Bencher::default();
    let r = b.bench("fixed quantize 64k grads @8b", || {
        std::hint::black_box(quant::fixed_quantize(&grads, 8.0));
    });
    println!("{}", r.report());
}
