//! Bench: the quantizer hot path (rust mirrors) across widths, shapes
//! and every registered format — the L3-side microbenchmark backing
//! §Perf.
//!
//! The production quantization happens inside the XLA artifact; these
//! mirrors run in tests/cost analysis and must not be a bottleneck for
//! large sweeps. The sweep enumerates `quant::FORMAT_REGISTRY`, so a
//! newly registered format (e.g. the stochastic-rounding fixed point
//! added with the registry) is tracked here automatically.

use dsq::bench::{header, Bencher};
use dsq::quant::registered_specs;
use dsq::util::rng::Pcg32;

fn main() {
    header("Quantizer hot path (rust mirrors, all registered formats)");
    let mut rng = Pcg32::new(1);
    let sizes = [(1usize << 12, 128usize), (1 << 16, 256), (1 << 20, 512)];
    let widths = [2u32, 4, 8, 16];
    let b = Bencher::default();
    for (n, inner) in sizes {
        let x: Vec<f32> = (0..n).map(|_| rng.normal() * (rng.f32() * 8.0 - 4.0).exp2()).collect();
        let mut buf = x.clone();
        // The width list stays below the >= 25-bit passthrough, so every
        // swept spec (fp32 never instantiates at these widths) does real work.
        for spec in registered_specs(&widths) {
            let label = format!("{:<10} n={n:>8} inner={inner:>4}", spec.spec_string());
            let r = b.bench(&label, || {
                buf.copy_from_slice(&x);
                // Step-indexed entry point: the stochastic formats pay
                // for their rounding stream here, which is exactly the
                // per-step cost the trainer-side mirror would pay.
                spec.quantize_into_step(std::hint::black_box(&mut buf), inner, 1);
            });
            println!("{}  ({:.0} Melem/s)", r.report(), r.throughput(n as f64) / 1e6);
        }
    }
}
