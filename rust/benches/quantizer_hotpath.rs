//! Bench: the quantizer hot path (rust mirrors) across widths, shapes
//! and formats — the L3-side microbenchmark backing §Perf.
//!
//! The production quantization happens inside the XLA artifact; these
//! mirrors run in tests/cost analysis and must not be a bottleneck for
//! large sweeps.

use dsq::bench::{header, Bencher};
use dsq::quant;
use dsq::util::rng::Pcg32;

fn main() {
    header("Quantizer hot path (rust mirrors)");
    let mut rng = Pcg32::new(1);
    let sizes = [(1usize << 12, 128usize), (1 << 16, 256), (1 << 20, 512)];
    let b = Bencher::default();
    for (n, inner) in sizes {
        let x: Vec<f32> = (0..n).map(|_| rng.normal() * (rng.f32() * 8.0 - 4.0).exp2()).collect();
        for bits in [2.0f32, 4.0, 8.0, 16.0] {
            let mut buf = x.clone();
            let r = b.bench(&format!("bfp  n={n:>8} inner={inner:>4} m={bits}"), || {
                buf.copy_from_slice(&x);
                quant::bfp_quantize_into(std::hint::black_box(&mut buf), inner, bits);
            });
            println!("{}  ({:.0} Melem/s)", r.report(), r.throughput(n as f64) / 1e6);
        }
        let mut buf = x.clone();
        let r = b.bench(&format!("fixed n={n:>8} b=8"), || {
            buf.copy_from_slice(&x);
            quant::fixed_quantize_into(std::hint::black_box(&mut buf), 8.0);
        });
        println!("{}  ({:.0} Melem/s)", r.report(), r.throughput(n as f64) / 1e6);
    }
}
