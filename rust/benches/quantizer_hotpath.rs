//! Bench: the quantizer hot path (rust mirrors) across widths, shapes
//! and every registered format — plus the packed codec's encode/decode
//! path — the L3-side microbenchmark backing §Perf.
//!
//! The production quantization happens inside the XLA artifact; these
//! mirrors run in tests/cost analysis, and the codec runs on every
//! stash/checkpoint round trip, so neither may be a bottleneck for
//! large sweeps. The sweep enumerates `quant::FORMAT_REGISTRY`, so a
//! newly registered format is tracked here automatically.
//!
//! `--smoke` (or `DSQ_BENCH_SMOKE=1`): a seconds-long CI profile that
//! still executes every (format, size) cell — including the FP8 pair
//! from the registry plus the generic-grammar float formats (SR fp8,
//! fp16, bf16) — and *asserts* the codec round-trip
//! (`decode(encode(x)) == quantize(x)`) on each cell, so a codec
//! regression fails the workflow rather than just skewing a number
//! nobody reads.

use dsq::bench::{header, Bencher, JsonReport};
use dsq::quant::{registered_specs, same_f32, Codec, FormatSpec};
use dsq::util::rng::Pcg32;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("DSQ_BENCH_SMOKE").is_ok_and(|v| v == "1");
    header(if smoke {
        "Quantizer + codec hot path (smoke profile)"
    } else {
        "Quantizer + codec hot path (rust mirrors, all registered formats)"
    });
    // Machine-readable trajectory (ROADMAP 3b): every run leaves
    // BENCH_quantizer.json at the repo root.
    let mut json = JsonReport::new("quantizer", if smoke { "smoke" } else { "full" });
    let mut rng = Pcg32::new(1);
    let sizes: &[(usize, usize)] = if smoke {
        &[(1 << 12, 128)]
    } else {
        &[(1 << 12, 128), (1 << 16, 256), (1 << 20, 512)]
    };
    let widths = [2u32, 4, 8, 16];
    let b = if smoke {
        Bencher {
            warmup: std::time::Duration::from_millis(10),
            measure: std::time::Duration::from_millis(40),
            min_iters: 3,
            max_iters: 10_000,
        }
    } else {
        Bencher::default()
    };
    for &(n, inner) in sizes {
        let x: Vec<f32> = (0..n).map(|_| rng.normal() * (rng.f32() * 8.0 - 4.0).exp2()).collect();
        let mut buf = x.clone();
        let shape = [n / inner, inner];
        // The width list stays below the >= 25-bit passthrough, so every
        // swept spec (fp32 never instantiates at these widths) does real
        // work. The registry contributes fp8e4m3/fp8e5m2 at width 8; the
        // generic-grammar float formats (SR fp8, fp16, bf16) are added
        // explicitly since they have no registry width row.
        let mut specs = registered_specs(&widths);
        for extra in ["e4m3sr", "e5m10", "e8m7"] {
            specs.push(FormatSpec::parse(extra).unwrap());
        }
        for spec in specs {
            let label = format!("{:<10} n={n:>8} inner={inner:>4}", spec.spec_string());
            let r = b.bench(&label, || {
                buf.copy_from_slice(&x);
                // Step-indexed entry point: the stochastic formats pay
                // for their rounding stream here, which is exactly the
                // per-step cost the trainer-side mirror would pay.
                spec.quantize_into_step(std::hint::black_box(&mut buf), inner, 1);
            });
            println!("{}  ({:.0} Melem/s)", r.report(), r.throughput(n as f64) / 1e6);
            json.push(&r, Some(n as f64));

            // The codec path: encode (quantize + pack) and decode.
            let packed = spec.encode_stream(&x, &shape, inner, 1, 0);
            let re = b.bench(&format!("encode:{label}"), || {
                std::hint::black_box(spec.encode_stream(
                    std::hint::black_box(&x),
                    &shape,
                    inner,
                    1,
                    0,
                ));
            });
            println!("{}  ({:.0} Melem/s)", re.report(), re.throughput(n as f64) / 1e6);
            json.push(&re, Some(n as f64));
            let rd = b.bench(&format!("decode:{label}"), || {
                std::hint::black_box(std::hint::black_box(&packed).decode());
            });
            println!("{}  ({:.0} Melem/s)", rd.report(), rd.throughput(n as f64) / 1e6);
            json.push(&rd, Some(n as f64));

            // Correctness gate (cheap next to the timing): the packed
            // bytes must round-trip to the quantized grid exactly.
            let got = packed.decode();
            buf.copy_from_slice(&x);
            spec.quantize_into_step(&mut buf, inner, 1);
            for (i, (&g, &w)) in got.iter().zip(buf.iter()).enumerate() {
                assert!(
                    same_f32(g, w),
                    "codec regression: {spec} elem {i}: decoded {g} != quantized {w}"
                );
            }
        }
    }
    match json.write() {
        Ok(p) => println!("\nwrote {}", p.display()),
        Err(e) => eprintln!("could not write bench json: {e}"),
    }
}
