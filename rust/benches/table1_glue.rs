//! Bench: Table 1's GLUE (RoBERTa-base) cost columns.
//!
//! Uniform rows carry the same relative costs as IWSLT (they scale all
//! components together); the stash/DSQ rows shift with RoBERTa's
//! activation/weight mix — which is why the paper reports DSQ MNLI/QNLI
//! at 0.043x (shorter fine-tuning spends proportionally more time at
//! the higher ladder rungs).

use dsq::bench::{header, Bencher};
use dsq::costmodel::{self, tables, TransformerWorkload};
use dsq::schedule::{FormatSpec, PrecisionConfig};

fn main() {
    header("Table 1 (GLUE MNLI/QNLI, RoBERTa-base) — cost columns");
    let w = TransformerWorkload::roberta_base();
    println!("workload: {} ({:.0}M params)", w.name, w.params / 1e6);
    println!("{:<18} {:<16} {:>8} {:>8}", "method", "precision", "arith", "dram");
    for (m, p, score) in tables::standard_methods() {
        let row = costmodel::normalized_row(&w, m, &p, score);
        println!("{}", row.fmt_paper_style());
    }
    // Fine-tuning trace (paper: DSQ = 0.043x / 0.26x): more time at the
    // higher rungs than the from-scratch run.
    let lo = PrecisionConfig::of(FormatSpec::bfp(16), [2, 2, 2, 16]);
    let mid = PrecisionConfig::of(FormatSpec::bfp(16), [8, 4, 4, 16]);
    let hi = PrecisionConfig::stashing(FormatSpec::bfp(16));
    let dsq = tables::dsq_trace_row(&w, &[(lo, 70), (mid, 20), (hi, 10)]);
    println!(
        "{:<18} {:<16} {:>7.3}x {:>7.3}x   (paper 0.043x / 0.26x)",
        "DSQ (BFP)",
        "-",
        dsq.arith_rel.unwrap(),
        dsq.dram_rel.unwrap()
    );

    let b = Bencher::default();
    let r = b.bench("roberta-base workload build + table", || {
        let w = TransformerWorkload::roberta_base();
        for (m, p, score) in tables::standard_methods() {
            std::hint::black_box(costmodel::normalized_row(&w, m, &p, score));
        }
    });
    println!("\n{}", r.report());
}
