//! The DSQ dynamic precision controller (the paper's §3 schedule).
//!
//! Policy, following the paper's Appendix B tuning and Hönig et al.'s
//! monotone-increase result:
//!
//! * training starts at the most aggressive ladder level
//!   (`bfp:2,2,2,16` by default);
//! * after each validation pass the controller checks for a plateau:
//!   "several epochs of unchanged or increasing validation loss" — here,
//!   `patience` consecutive validations with relative improvement below
//!   `min_rel_improvement`;
//! * on a plateau it advances one ladder level (never retreats — the
//!   monotone property the tests assert);
//! * the gradient slot stays ≥ 16 bits in every built-in ladder
//!   (Appendix C: 8-bit gradient outputs diverge under fixed point).
//!
//! Ladders are built from [`PrecisionConfig`] spec strings
//! ([`DsqControllerConfig::from_specs`]), so any registered format
//! family — including heterogeneous per-slot configs — can drive the
//! schedule: `DsqControllerConfig::paper_default("fixedsr")` instantiates
//! the paper's ladder over stochastic-rounding fixed point, and
//! [`DsqControllerConfig::fp8_default`] ships an FP8-LM-style float
//! ladder (E4M3 compute/stash slots, E5M2 gradients — `dsq-fp8` on the
//! CLI).

use super::{FormatSpec, PrecisionConfig, Schedule, ScheduleState};

/// The paper's Appendix-B ladder widths, shared by every family.
const PAPER_LADDER: &[[u32; 4]] = &[
    [2, 2, 2, 16],
    [4, 2, 2, 16],
    [8, 4, 4, 16],
    [16, 4, 4, 16],
    [16, 8, 8, 16],
    [16, 16, 16, 16],
];

/// The `dsq-fp8` ladder: start all-FP8 (E4M3 fwd/stash/bwd, E5M2 grad —
/// FP8-LM's slot assignment), widen the compute path through fp16
/// (`e5m10`) as validation stalls, and only at the top level widen the
/// gradient slot too (E5M2 → E5M10 keeps the 5-bit exponent, so range
/// never shrinks — the monotone-in-width ladder property in float form).
const FP8_LADDER: &[&str] = &[
    "fp8e4m3,fp8e4m3,fp8e4m3,fp8e5m2",
    "e5m10,fp8e4m3,fp8e4m3,fp8e5m2",
    "e5m10,e5m10,e5m10,fp8e5m2",
    "e5m10,e5m10,e5m10,e5m10",
];

/// Appendix-C floor for the gradient slot in built-in ladders.
const GRAD_MIN_BITS: u32 = 16;
/// The float-form of the Appendix-C rule: "grad stays wide" is about
/// *range*, and an FP8 gradient slot is legal iff it carries at least
/// E5M2's 5 exponent bits (Lang et al. 2024 / FP8-LM: E5M2 for grads,
/// E4M3 diverges).
const GRAD_MIN_FLOAT_EXP: u32 = 5;

/// Is `f` wide enough for the gradient-output slot of a built-in ladder?
/// Integer families need ≥ 16 total bits (Appendix C: 8-bit gradient
/// outputs diverge); float formats satisfy the range form instead — ≥
/// [`GRAD_MIN_FLOAT_EXP`] exponent bits. (A width-only escape hatch for
/// floats would be dead code: with mantissas capped at 10 bits, any
/// ≥ 16-bit float already has ≥ 5 exponent bits.)
fn grad_slot_ok(f: &FormatSpec) -> bool {
    match f {
        FormatSpec::Float { exp_bits, .. } => *exp_bits >= GRAD_MIN_FLOAT_EXP,
        _ => f.bits() >= GRAD_MIN_BITS,
    }
}

/// Controller hyper-parameters.
#[derive(Clone, Debug)]
pub struct DsqControllerConfig {
    /// Relative improvement below which a validation counts as "no better".
    pub min_rel_improvement: f64,
    /// Consecutive no-better validations that trigger a precision bump.
    pub patience: usize,
    /// The (monotone) precision ladder.
    pub ladder: Vec<PrecisionConfig>,
}

impl DsqControllerConfig {
    /// Build a controller config from one [`PrecisionConfig`] spec
    /// string per ladder level. Validates that the ladder is non-empty,
    /// component-wise monotone non-decreasing, and keeps the gradient
    /// slot at ≥ 16 bits (Appendix C); violations are
    /// [`crate::Error::Config`].
    pub fn from_specs(
        min_rel_improvement: f64,
        patience: usize,
        levels: &[&str],
    ) -> crate::Result<Self> {
        let ladder = levels
            .iter()
            .map(|s| PrecisionConfig::parse(s))
            .collect::<crate::Result<Vec<_>>>()?;
        if ladder.is_empty() {
            return Err(crate::Error::Config("ladder must be non-empty".into()));
        }
        for w in ladder.windows(2) {
            if !w[1].at_least(&w[0]) {
                return Err(crate::Error::Config(format!(
                    "ladder must be monotone: {} !>= {}",
                    w[1].notation(),
                    w[0].notation()
                )));
            }
        }
        for l in &ladder {
            if !grad_slot_ok(&l.grad()) {
                return Err(crate::Error::Config(format!(
                    "ladder level {} has a too-narrow gradient slot {} (Appendix C requires \
                     >= {GRAD_MIN_BITS} bits, or a float format with >= {GRAD_MIN_FLOAT_EXP} \
                     exponent bits)",
                    l.spec_string(),
                    l.grad().spec_string(),
                )));
            }
        }
        Ok(DsqControllerConfig { min_rel_improvement, patience, ladder })
    }

    /// The paper's setup for a format family (`"bfp"`, `"fixed"`,
    /// `"fixedsr"`, …): start `[2,2,2,16]`, jump toward `[16,4,4,16]`
    /// and beyond as validation stalls.
    pub fn paper_default(family: &str) -> crate::Result<Self> {
        let specs: Vec<String> = PAPER_LADDER
            .iter()
            .map(|[q0, q1, q2, q3]| format!("{family}:{q0},{q1},{q2},{q3}"))
            .collect();
        let refs: Vec<&str> = specs.iter().map(String::as_str).collect();
        Self::from_specs(0.002, 2, &refs)
    }

    /// The FP8-LM-style float ladder (`dsq-fp8`): [`FP8_LADDER`] under
    /// the paper's plateau hyper-parameters.
    pub fn fp8_default() -> crate::Result<Self> {
        Self::from_specs(0.002, 2, FP8_LADDER)
    }
}

/// Plateau-driven monotone precision controller.
#[derive(Clone, Debug)]
pub struct DsqController {
    cfg: DsqControllerConfig,
    level: usize,
    best_loss: f64,
    stale: usize,
    /// (validation index, level after observation) transition log.
    transitions: Vec<(usize, usize)>,
    observed: usize,
}

impl DsqController {
    pub fn new(cfg: DsqControllerConfig) -> Self {
        assert!(!cfg.ladder.is_empty(), "ladder must be non-empty");
        // The ladder must be monotone non-decreasing per component —
        // guaranteed for `from_specs` ladders, asserted for hand-built
        // ones.
        for w in cfg.ladder.windows(2) {
            assert!(
                w[1].at_least(&w[0]),
                "ladder must be monotone: {} !>= {}",
                w[1].notation(),
                w[0].notation()
            );
        }
        DsqController {
            cfg,
            level: 0,
            best_loss: f64::INFINITY,
            stale: 0,
            transitions: Vec::new(),
            observed: 0,
        }
    }

    /// The paper's controller over a format family; errors on an
    /// unregistered family name.
    pub fn paper_default(family: &str) -> crate::Result<Self> {
        Ok(DsqController::new(DsqControllerConfig::paper_default(family)?))
    }

    /// The FP8 float-format controller (`--schedule dsq-fp8`).
    pub fn fp8_default() -> crate::Result<Self> {
        Ok(DsqController::new(DsqControllerConfig::fp8_default()?))
    }

    pub fn level(&self) -> usize {
        self.level
    }

    pub fn at_top(&self) -> bool {
        self.level + 1 == self.cfg.ladder.len()
    }

    /// Transition log: (validation index, new level).
    pub fn transitions(&self) -> &[(usize, usize)] {
        &self.transitions
    }
}

impl Schedule for DsqController {
    fn current(&self) -> PrecisionConfig {
        self.cfg.ladder[self.level]
    }

    fn observe_validation(&mut self, val_loss: f64) {
        self.observed += 1;
        let improved = val_loss.is_finite()
            && val_loss < self.best_loss * (1.0 - self.cfg.min_rel_improvement);
        if improved {
            self.best_loss = val_loss;
            self.stale = 0;
            return;
        }
        self.stale += 1;
        if self.stale >= self.cfg.patience && !self.at_top() {
            self.level += 1;
            self.stale = 0;
            // A precision change resets the plateau reference: the model
            // should now be able to improve again.
            self.best_loss = val_loss.min(self.best_loss);
            self.transitions.push((self.observed, self.level));
            crate::info!(
                "DSQ controller: advancing to level {} {}",
                self.level,
                self.current().spec_string()
            );
        }
    }

    fn describe(&self) -> String {
        format!(
            "dsq level {}/{} {} (best val {:.4}, stale {})",
            self.level,
            self.cfg.ladder.len() - 1,
            self.current().spec_string(),
            self.best_loss,
            self.stale
        )
    }

    fn snapshot(&self) -> Option<ScheduleState> {
        Some(ScheduleState {
            level: self.level as u32,
            stale: self.stale as u32,
            observed: self.observed as u32,
            best_loss: self.best_loss,
        })
    }

    /// Resume the ladder: the level is clamped to this controller's
    /// ladder (a checkpoint from a longer ladder resumes at the top) and
    /// the plateau reference (best loss + stale count) carries over, so
    /// the monotone-increase property holds across the save/load
    /// boundary.
    fn restore(&mut self, s: &ScheduleState) {
        self.level = (s.level as usize).min(self.cfg.ladder.len() - 1);
        self.stale = s.stale as usize;
        self.observed = s.observed as usize;
        self.best_loss = s.best_loss;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::FormatSpec;
    use crate::util::prop::Prop;
    use crate::util::rng::Pcg32;

    fn ctl() -> DsqController {
        DsqController::paper_default("bfp").unwrap()
    }

    #[test]
    fn starts_most_aggressive() {
        let c = ctl();
        assert_eq!(c.current().notation(), "[2,2,2,16]");
        assert_eq!(c.current(), PrecisionConfig::parse("bfp:2,2,2,16").unwrap());
    }

    #[test]
    fn paper_default_instantiates_any_registered_family() {
        for fam in ["bfp", "fixed", "fixedsr"] {
            let c = DsqController::paper_default(fam)
                .unwrap_or_else(|e| panic!("{fam}: {e}"));
            assert_eq!(c.current().notation(), "[2,2,2,16]");
            assert_eq!(c.current().fwd().family_name(), fam);
        }
        assert!(DsqController::paper_default("int").is_err());
    }

    #[test]
    fn improving_loss_keeps_level() {
        let mut c = ctl();
        for i in 0..20 {
            c.observe_validation(10.0 - i as f64 * 0.2);
        }
        assert_eq!(c.level(), 0);
    }

    #[test]
    fn plateau_advances_one_level() {
        let mut c = ctl();
        c.observe_validation(5.0);
        c.observe_validation(5.0); // stale 1
        assert_eq!(c.level(), 0);
        c.observe_validation(5.01); // stale 2 -> advance
        assert_eq!(c.level(), 1);
        assert_eq!(c.transitions(), &[(3, 1)]);
    }

    #[test]
    fn grad_slot_always_at_least_16() {
        for fam in ["bfp", "fixed", "fixedsr"] {
            let c = DsqControllerConfig::paper_default(fam).unwrap();
            for l in &c.ladder {
                assert!(
                    l.grad().bits() >= 16,
                    "Appendix C: grad slot must stay >= 16 ({})",
                    l.spec_string()
                );
            }
        }
    }

    #[test]
    fn from_specs_rejects_low_grad_slot() {
        let r = DsqControllerConfig::from_specs(0.01, 1, &["fixed:8,8,8,8"]);
        assert!(matches!(r, Err(crate::Error::Config(_))), "got {r:?}");
    }

    #[test]
    fn fp8_ladder_starts_all_fp8_and_climbs_to_fp16() {
        let mut c = DsqController::fp8_default().unwrap();
        assert_eq!(c.current().notation(), "[8,8,8,8]");
        assert_eq!(c.current().fwd(), FormatSpec::fp8e4m3());
        assert_eq!(c.current().stash(), FormatSpec::fp8e4m3());
        assert_eq!(c.current().grad(), FormatSpec::fp8e5m2(), "grad slot is the E5M2 format");
        for _ in 0..100 {
            c.observe_validation(5.0);
        }
        assert!(c.at_top());
        assert_eq!(c.current(), PrecisionConfig::uniform(FormatSpec::float(5, 10)));
        // Monotone in width at every transition (checked by new(), but
        // pin the notation path here too).
        assert_eq!(c.current().notation(), "[16,16,16,16]");
    }

    #[test]
    fn float_grad_rule_is_about_range_not_width() {
        // E5M2 (8 bits, 5-bit exponent) is a legal grad slot...
        let ok = DsqControllerConfig::from_specs(
            0.01,
            1,
            &["fp8e4m3,fp8e4m3,fp8e4m3,fp8e5m2"],
        );
        assert!(ok.is_ok(), "{ok:?}");
        // ...but E4M3 (same width, 4-bit exponent) is not — the float
        // form of Appendix C's "8-bit gradient outputs diverge".
        let r = DsqControllerConfig::from_specs(0.01, 1, &["fp8e4m3,fp8e4m3,fp8e4m3,fp8e4m3"]);
        assert!(matches!(r, Err(crate::Error::Config(_))), "got {r:?}");
        // Wide floats pass through the same range rule (e8m7 = bf16 has
        // 8 exponent bits; no ≥16-bit float with < 5 exists, since
        // mantissas cap at 10).
        let ok = DsqControllerConfig::from_specs(0.01, 1, &["e8m7,e8m7,e8m7,e8m7"]);
        assert!(ok.is_ok(), "{ok:?}");
    }

    #[test]
    fn from_specs_rejects_non_monotone() {
        let r = DsqControllerConfig::from_specs(0.01, 1, &["bfp8", "bfp:4,4,4,16"]);
        assert!(matches!(r, Err(crate::Error::Config(_))), "got {r:?}");
    }

    #[test]
    fn from_specs_accepts_heterogeneous_ladder() {
        // A BFP compute path whose gradient outputs are stochastic-
        // rounding fixed point at every level — the registry makes this
        // a two-line ladder instead of a cross-cutting rewrite.
        let cfg = DsqControllerConfig::from_specs(
            0.002,
            2,
            &["bfp2,bfp2,bfp2,fixed16sr", "bfp16,bfp4,bfp4,fixed16sr"],
        )
        .unwrap();
        let c = DsqController::new(cfg);
        assert_eq!(c.current().grad(), FormatSpec::fixed_sr(16));
        assert_eq!(c.current().fwd(), FormatSpec::bfp(2));
    }

    #[test]
    fn saturates_at_top() {
        let mut c = ctl();
        for _ in 0..100 {
            c.observe_validation(5.0);
        }
        assert!(c.at_top());
        assert_eq!(c.current().notation(), "[16,16,16,16]");
    }

    #[test]
    fn nan_loss_counts_as_stale_not_improvement() {
        let mut c = ctl();
        c.observe_validation(f64::NAN);
        c.observe_validation(f64::NAN);
        assert_eq!(c.level(), 1, "NaN validations must push precision up");
    }

    #[test]
    #[should_panic(expected = "monotone")]
    fn non_monotone_ladder_rejected() {
        DsqController::new(DsqControllerConfig {
            min_rel_improvement: 0.01,
            patience: 1,
            ladder: vec![
                PrecisionConfig::uniform(FormatSpec::bfp(8)),
                PrecisionConfig::uniform(FormatSpec::bfp(4)),
            ],
        });
    }

    #[test]
    fn snapshot_restore_roundtrip() {
        let mut c = ctl();
        for _ in 0..5 {
            c.observe_validation(5.0); // improve once, then 2x2 stale -> level 2
        }
        assert_eq!(c.level(), 2);
        let snap = c.snapshot().unwrap();
        assert_eq!(snap.level, 2);
        assert_eq!(snap.best_loss, 5.0);

        let mut fresh = ctl();
        assert_eq!(fresh.level(), 0);
        fresh.restore(&snap);
        assert_eq!(fresh.level(), 2);
        assert_eq!(fresh.current(), c.current());
        assert_eq!(fresh.describe(), c.describe());
        // The plateau reference carried over: one more stale pair bumps
        // the restored controller exactly like the original.
        fresh.observe_validation(5.0);
        fresh.observe_validation(5.0);
        assert_eq!(fresh.level(), 3);
    }

    #[test]
    fn restore_clamps_level_to_ladder() {
        let cfg =
            DsqControllerConfig::from_specs(0.01, 1, &["bfp:2,2,2,16", "bfp:8,8,8,16"]).unwrap();
        let mut c = DsqController::new(cfg);
        c.restore(&ScheduleState { level: 99, stale: 0, observed: 7, best_loss: 1.0 });
        assert_eq!(c.level(), 1);
        assert!(c.at_top());
    }

    #[test]
    fn static_schedule_has_no_snapshot() {
        use crate::schedule::StaticSchedule;
        let mut s = StaticSchedule(PrecisionConfig::uniform(FormatSpec::bfp(8)));
        assert!(Schedule::snapshot(&s).is_none());
        // Restore is a no-op.
        let snap = ScheduleState { level: 3, stale: 1, observed: 2, best_loss: 0.5 };
        Schedule::restore(&mut s, &snap);
        assert_eq!(s.current(), PrecisionConfig::uniform(FormatSpec::bfp(8)));
    }

    #[test]
    fn monotone_under_arbitrary_losses_property() {
        Prop::new("controller level is monotone non-decreasing").cases(60).run(
            |rng: &mut Pcg32, size| {
                (0..size * 3).map(|_| (rng.f64() * 10.0) - 1.0).collect::<Vec<f64>>()
            },
            |losses| {
                let mut c = ctl();
                let mut prev = c.level();
                for &l in losses {
                    c.observe_validation(l);
                    if c.level() < prev {
                        return Err(format!("level decreased: {} -> {}", prev, c.level()));
                    }
                    prev = c.level();
                }
                Ok(())
            },
        );
    }

    #[test]
    fn precision_config_monotone_along_run_property() {
        Prop::new("emitted configs are component-wise monotone").cases(40).run(
            |rng: &mut Pcg32, size| {
                (0..size * 2).map(|_| rng.f64() * 5.0).collect::<Vec<f64>>()
            },
            |losses| {
                let mut c = ctl();
                let mut prev = c.current();
                for &l in losses {
                    c.observe_validation(l);
                    let cur = c.current();
                    if !cur.at_least(&prev) {
                        return Err(format!(
                            "config regressed: {} -> {}",
                            prev.notation(),
                            cur.notation()
                        ));
                    }
                    prev = cur;
                }
                Ok(())
            },
        );
    }
}
