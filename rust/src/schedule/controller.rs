//! The DSQ dynamic precision controller (the paper's §3 schedule).
//!
//! Policy, following the paper's Appendix B tuning and Hönig et al.'s
//! monotone-increase result:
//!
//! * training starts at the most aggressive ladder level
//!   (`[2,2,2,16]` BFP by default);
//! * after each validation pass the controller checks for a plateau:
//!   "several epochs of unchanged or increasing validation loss" — here,
//!   `patience` consecutive validations with relative improvement below
//!   `min_rel_improvement`;
//! * on a plateau it advances one ladder level (never retreats — the
//!   monotone property the tests assert);
//! * `q3` stays ≥ 16 in every built-in ladder (Appendix C: 8-bit
//!   gradient outputs diverge under fixed point).

use super::{PrecisionConfig, QuantMode, Schedule};

/// Controller hyper-parameters.
#[derive(Clone, Debug)]
pub struct DsqControllerConfig {
    /// Relative improvement below which a validation counts as "no better".
    pub min_rel_improvement: f64,
    /// Consecutive no-better validations that trigger a precision bump.
    pub patience: usize,
    /// The (monotone) precision ladder.
    pub ladder: Vec<PrecisionConfig>,
}

impl DsqControllerConfig {
    /// The paper's setup: start `[2,2,2,16]`, jump toward `[16,4,4,16]`
    /// and beyond as validation stalls.
    pub fn paper_default(mode: QuantMode) -> Self {
        let l = |q0, q1, q2, q3| PrecisionConfig::new(mode, q0, q1, q2, q3);
        DsqControllerConfig {
            min_rel_improvement: 0.002,
            patience: 2,
            ladder: vec![
                l(2.0, 2.0, 2.0, 16.0),
                l(4.0, 2.0, 2.0, 16.0),
                l(8.0, 4.0, 4.0, 16.0),
                l(16.0, 4.0, 4.0, 16.0),
                l(16.0, 8.0, 8.0, 16.0),
                l(16.0, 16.0, 16.0, 16.0),
            ],
        }
    }
}

/// Plateau-driven monotone precision controller.
#[derive(Clone, Debug)]
pub struct DsqController {
    cfg: DsqControllerConfig,
    level: usize,
    best_loss: f64,
    stale: usize,
    /// (validation index, level after observation) transition log.
    transitions: Vec<(usize, usize)>,
    observed: usize,
}

impl DsqController {
    pub fn new(cfg: DsqControllerConfig) -> Self {
        assert!(!cfg.ladder.is_empty(), "ladder must be non-empty");
        // The ladder must be monotone non-decreasing per component —
        // guaranteed for built-ins, asserted for user-supplied ladders.
        for w in cfg.ladder.windows(2) {
            assert!(
                w[1].at_least(&w[0]),
                "ladder must be monotone: {} !>= {}",
                w[1].notation(),
                w[0].notation()
            );
        }
        DsqController {
            cfg,
            level: 0,
            best_loss: f64::INFINITY,
            stale: 0,
            transitions: Vec::new(),
            observed: 0,
        }
    }

    pub fn paper_default(mode: QuantMode) -> Self {
        DsqController::new(DsqControllerConfig::paper_default(mode))
    }

    pub fn level(&self) -> usize {
        self.level
    }

    pub fn at_top(&self) -> bool {
        self.level + 1 == self.cfg.ladder.len()
    }

    /// Transition log: (validation index, new level).
    pub fn transitions(&self) -> &[(usize, usize)] {
        &self.transitions
    }
}

impl Schedule for DsqController {
    fn current(&self) -> PrecisionConfig {
        self.cfg.ladder[self.level]
    }

    fn observe_validation(&mut self, val_loss: f64) {
        self.observed += 1;
        let improved = val_loss.is_finite()
            && val_loss < self.best_loss * (1.0 - self.cfg.min_rel_improvement);
        if improved {
            self.best_loss = val_loss;
            self.stale = 0;
            return;
        }
        self.stale += 1;
        if self.stale >= self.cfg.patience && !self.at_top() {
            self.level += 1;
            self.stale = 0;
            // A precision change resets the plateau reference: the model
            // should now be able to improve again.
            self.best_loss = val_loss.min(self.best_loss);
            self.transitions.push((self.observed, self.level));
            crate::info!(
                "DSQ controller: advancing to level {} {}",
                self.level,
                self.current().notation()
            );
        }
    }

    fn describe(&self) -> String {
        format!(
            "dsq level {}/{} {} {} (best val {:.4}, stale {})",
            self.level,
            self.cfg.ladder.len() - 1,
            self.current().mode.name(),
            self.current().notation(),
            self.best_loss,
            self.stale
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Prop;
    use crate::util::rng::Pcg32;

    fn ctl() -> DsqController {
        DsqController::paper_default(QuantMode::Bfp)
    }

    #[test]
    fn starts_most_aggressive() {
        let c = ctl();
        assert_eq!(c.current().notation(), "[2,2,2,16]");
    }

    #[test]
    fn improving_loss_keeps_level() {
        let mut c = ctl();
        for i in 0..20 {
            c.observe_validation(10.0 - i as f64 * 0.2);
        }
        assert_eq!(c.level(), 0);
    }

    #[test]
    fn plateau_advances_one_level() {
        let mut c = ctl();
        c.observe_validation(5.0);
        c.observe_validation(5.0); // stale 1
        assert_eq!(c.level(), 0);
        c.observe_validation(5.01); // stale 2 -> advance
        assert_eq!(c.level(), 1);
        assert_eq!(c.transitions(), &[(3, 1)]);
    }

    #[test]
    fn q3_always_at_least_16() {
        let c = DsqControllerConfig::paper_default(QuantMode::Bfp);
        for l in &c.ladder {
            assert!(l.q3 >= 16.0, "Appendix C: q3 must stay >= 16 ({})", l.notation());
        }
    }

    #[test]
    fn saturates_at_top() {
        let mut c = ctl();
        for _ in 0..100 {
            c.observe_validation(5.0);
        }
        assert!(c.at_top());
        assert_eq!(c.current().notation(), "[16,16,16,16]");
    }

    #[test]
    fn nan_loss_counts_as_stale_not_improvement() {
        let mut c = ctl();
        c.observe_validation(f64::NAN);
        c.observe_validation(f64::NAN);
        assert_eq!(c.level(), 1, "NaN validations must push precision up");
    }

    #[test]
    #[should_panic(expected = "monotone")]
    fn non_monotone_ladder_rejected() {
        let mode = QuantMode::Bfp;
        DsqController::new(DsqControllerConfig {
            min_rel_improvement: 0.01,
            patience: 1,
            ladder: vec![
                PrecisionConfig::uniform(mode, 8.0),
                PrecisionConfig::uniform(mode, 4.0),
            ],
        });
    }

    #[test]
    fn monotone_under_arbitrary_losses_property() {
        Prop::new("controller level is monotone non-decreasing").cases(60).run(
            |rng: &mut Pcg32, size| {
                (0..size * 3).map(|_| (rng.f64() * 10.0) - 1.0).collect::<Vec<f64>>()
            },
            |losses| {
                let mut c = ctl();
                let mut prev = c.level();
                for &l in losses {
                    c.observe_validation(l);
                    if c.level() < prev {
                        return Err(format!("level decreased: {} -> {}", prev, c.level()));
                    }
                    prev = c.level();
                }
                Ok(())
            },
        );
    }

    #[test]
    fn precision_config_monotone_along_run_property() {
        Prop::new("emitted configs are component-wise monotone").cases(40).run(
            |rng: &mut Pcg32, size| {
                (0..size * 2).map(|_| rng.f64() * 5.0).collect::<Vec<f64>>()
            },
            |losses| {
                let mut c = ctl();
                let mut prev = c.current();
                for &l in losses {
                    c.observe_validation(l);
                    let cur = c.current();
                    if !cur.at_least(&prev) {
                        return Err(format!(
                            "config regressed: {} -> {}",
                            prev.notation(),
                            cur.notation()
                        ));
                    }
                    prev = cur;
                }
                Ok(())
            },
        );
    }
}
