//! Precision configurations and schedules — the paper's §3 "time-adaptive
//! principle".
//!
//! A [`PrecisionConfig`] is the `[q0, q1, q2, q3]` vector (plus quantizer
//! mode) that parameterizes a training step at runtime. Schedules produce
//! one config per step:
//!
//! * [`StaticSchedule`] — a fixed config for the whole run (the paper's
//!   baseline and "Stashing" rows);
//! * [`DsqController`] — the paper's contribution: start at the most
//!   aggressive level (`[2,2,2,16]` BFP) and **monotonically** climb the
//!   precision ladder whenever the validation loss plateaus (the paper
//!   follows Hönig et al. in showing monotone-increase beats fancier
//!   schedules). `q3 ≥ 16` is enforced by every built-in ladder level per
//!   Appendix C (8-bit gradient outputs diverge).

pub mod controller;

pub use controller::{DsqController, DsqControllerConfig};

/// Which quantizer the step uses (mirrors the artifact's runtime `mode`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QuantMode {
    /// No quantization (fp32 reference).
    Fp32,
    /// Dynamic per-tensor fixed point.
    Fixed,
    /// Block floating point (MSFP, box 16, 8-bit shared exponent).
    Bfp,
}

impl QuantMode {
    pub fn as_f32(self) -> f32 {
        match self {
            QuantMode::Fp32 => 0.0,
            QuantMode::Fixed => 1.0,
            QuantMode::Bfp => 2.0,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            QuantMode::Fp32 => "fp32",
            QuantMode::Fixed => "fixed",
            QuantMode::Bfp => "bfp",
        }
    }
}

/// A full precision configuration `[q0, q1, q2, q3]` + quantizer mode.
///
/// * `q0` — forward-GEMM operand width (arith density);
/// * `q1` — the **stash** width (fwd→bwd DRAM traffic);
/// * `q2` — first backward GEMM operand width;
/// * `q3` — gradient-output width (DRAM + second backward GEMM).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PrecisionConfig {
    pub mode: QuantMode,
    pub q0: f32,
    pub q1: f32,
    pub q2: f32,
    pub q3: f32,
}

impl PrecisionConfig {
    pub const fn new(mode: QuantMode, q0: f32, q1: f32, q2: f32, q3: f32) -> Self {
        PrecisionConfig { mode, q0, q1, q2, q3 }
    }

    /// The fp32 reference config `[32,32,32,32]`.
    pub const FP32: PrecisionConfig =
        PrecisionConfig::new(QuantMode::Fp32, 32.0, 32.0, 32.0, 32.0);

    /// Uniform width (the paper's `[b,b,b,b]` rows).
    pub fn uniform(mode: QuantMode, bits: f32) -> Self {
        PrecisionConfig::new(mode, bits, bits, bits, bits)
    }

    /// The paper's static stashing setup `[16, 4, 4, 16]`.
    pub fn stashing(mode: QuantMode) -> Self {
        PrecisionConfig::new(mode, 16.0, 4.0, 4.0, 16.0)
    }

    /// Runtime vector for the artifacts: `[mode, q0, q1, q2, q3]`.
    pub fn as_qcfg(&self) -> [f32; 5] {
        [self.mode.as_f32(), self.q0, self.q1, self.q2, self.q3]
    }

    /// `"[16,4,4,16]"` — the paper's notation.
    pub fn notation(&self) -> String {
        format!("[{},{},{},{}]", self.q0, self.q1, self.q2, self.q3)
    }

    /// Parse `"16,4,4,16"` or `"[16,4,4,16]"`.
    pub fn parse(mode: QuantMode, s: &str) -> crate::Result<Self> {
        let trimmed = s.trim().trim_start_matches('[').trim_end_matches(']');
        let parts: Vec<f32> = trimmed
            .split(',')
            .map(|p| p.trim().parse::<f32>())
            .collect::<std::result::Result<_, _>>()
            .map_err(|_| crate::Error::Config(format!("bad precision setup '{s}'")))?;
        if parts.len() != 4 {
            return Err(crate::Error::Config(format!("precision setup needs 4 entries: '{s}'")));
        }
        for &b in &parts {
            if !(2.0..=32.0).contains(&b) || b.fract() != 0.0 {
                return Err(crate::Error::Config(format!("bit width {b} out of range [2,32]")));
            }
        }
        Ok(PrecisionConfig::new(mode, parts[0], parts[1], parts[2], parts[3]))
    }

    /// Component-wise ≥ (used to assert monotone schedules).
    pub fn at_least(&self, other: &PrecisionConfig) -> bool {
        self.q0 >= other.q0 && self.q1 >= other.q1 && self.q2 >= other.q2 && self.q3 >= other.q3
    }
}

/// A precision schedule: one config per training step.
pub trait Schedule {
    /// Config to use for the upcoming step.
    fn current(&self) -> PrecisionConfig;
    /// Feed a validation result (loss); may advance the schedule.
    fn observe_validation(&mut self, val_loss: f64);
    /// Human-readable state for logs.
    fn describe(&self) -> String;
}

/// Fixed precision for the whole run.
#[derive(Clone, Debug)]
pub struct StaticSchedule(pub PrecisionConfig);

impl Schedule for StaticSchedule {
    fn current(&self) -> PrecisionConfig {
        self.0
    }
    fn observe_validation(&mut self, _val_loss: f64) {}
    fn describe(&self) -> String {
        format!("static {} {}", self.0.mode.name(), self.0.notation())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qcfg_vector_layout() {
        let c = PrecisionConfig::stashing(QuantMode::Bfp);
        assert_eq!(c.as_qcfg(), [2.0, 16.0, 4.0, 4.0, 16.0]);
        assert_eq!(PrecisionConfig::FP32.as_qcfg(), [0.0, 32.0, 32.0, 32.0, 32.0]);
    }

    #[test]
    fn parse_roundtrip() {
        let c = PrecisionConfig::parse(QuantMode::Bfp, "[16,4,4,16]").unwrap();
        assert_eq!(c, PrecisionConfig::stashing(QuantMode::Bfp));
        assert_eq!(c.notation(), "[16,4,4,16]");
        let c2 = PrecisionConfig::parse(QuantMode::Fixed, "8, 8, 8, 32").unwrap();
        assert_eq!(c2.q3, 32.0);
    }

    #[test]
    fn parse_rejects_bad_input() {
        assert!(PrecisionConfig::parse(QuantMode::Bfp, "16,4,4").is_err());
        assert!(PrecisionConfig::parse(QuantMode::Bfp, "16,4,4,1").is_err());
        assert!(PrecisionConfig::parse(QuantMode::Bfp, "16,4,x,16").is_err());
        assert!(PrecisionConfig::parse(QuantMode::Bfp, "64,4,4,16").is_err());
    }

    #[test]
    fn at_least_ordering() {
        let lo = PrecisionConfig::uniform(QuantMode::Bfp, 4.0);
        let hi = PrecisionConfig::uniform(QuantMode::Bfp, 16.0);
        assert!(hi.at_least(&lo));
        assert!(!lo.at_least(&hi));
    }

    #[test]
    fn static_schedule_never_changes() {
        let mut s = StaticSchedule(PrecisionConfig::stashing(QuantMode::Bfp));
        let before = s.current();
        for i in 0..10 {
            s.observe_validation(10.0 - i as f64);
        }
        assert_eq!(s.current(), before);
    }
}
