//! Precision configurations and schedules — the paper's §3 "time-adaptive
//! principle", built on the pluggable [`FormatSpec`] descriptor.
//!
//! A [`PrecisionConfig`] assigns one [`FormatSpec`] to each of the four
//! dataflow slots of a training step (paper Figure 2), so slots may use
//! *heterogeneous* formats (e.g. a BFP stash with fixed-point gradient
//! outputs). Schedules produce one config per step:
//!
//! * [`StaticSchedule`] — a fixed config for the whole run (the paper's
//!   baseline and "Stashing" rows);
//! * [`DsqController`] — the paper's contribution: start at the most
//!   aggressive level (`bfp:2,2,2,16`) and **monotonically** climb the
//!   precision ladder whenever the validation loss plateaus (the paper
//!   follows Hönig et al. in showing monotone-increase beats fancier
//!   schedules). The gradient slot stays ≥ 16 bits in every built-in
//!   ladder per Appendix C (8-bit gradient outputs diverge).
//!
//! Configs are spelled as spec strings and parsed through the format
//! registry ([`PrecisionConfig::parse`]):
//!
//! * `"bfp8"` — one format, all four slots;
//! * `"bfp:16,4,4,16"` — one family, per-slot widths (the paper's
//!   `[16,4,4,16]` notation);
//! * `"bfp16,bfp4,bfp4,fixed16sr"` — fully heterogeneous per-slot specs;
//! * `"fp8e4m3,fp8e4m3,fp8e4m3,fp8e5m2"` — the FP8-LM float slot
//!   assignment (float formats have no width knob, so they only appear
//!   in uniform or per-slot form — `dsq-fp8` ships the ladder).

pub mod controller;

pub use controller::{DsqController, DsqControllerConfig};

pub use crate::quant::format::{FormatSpec, Rounding};

/// A full precision configuration: one [`FormatSpec`] per dataflow slot.
///
/// Slot meaning (paper Figure 2):
/// * `slots[0]` (`q0`) — forward-GEMM operand format (arith density);
/// * `slots[1]` (`q1`) — the **stash** format (fwd→bwd DRAM traffic);
/// * `slots[2]` (`q2`) — first backward GEMM operand format;
/// * `slots[3]` (`q3`) — gradient-output format (DRAM + second backward
///   GEMM).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct PrecisionConfig {
    pub slots: [FormatSpec; 4],
}

impl PrecisionConfig {
    pub const fn new(slots: [FormatSpec; 4]) -> Self {
        PrecisionConfig { slots }
    }

    /// The fp32 reference config.
    pub const FP32: PrecisionConfig = PrecisionConfig { slots: [FormatSpec::Fp32; 4] };

    /// The same format in every slot (the paper's `[b,b,b,b]` rows).
    pub fn uniform(f: FormatSpec) -> Self {
        PrecisionConfig::new([f; 4])
    }

    /// The paper's static stashing pattern `[16,4,4,16]`, instantiated
    /// for `f`'s family.
    pub fn stashing(f: FormatSpec) -> Self {
        PrecisionConfig::new([f.with_bits(16), f.with_bits(4), f.with_bits(4), f.with_bits(16)])
    }

    /// `f`'s family at explicit per-slot widths (ladder levels etc.).
    pub fn of(f: FormatSpec, q: [u32; 4]) -> Self {
        PrecisionConfig::new([
            f.with_bits(q[0]),
            f.with_bits(q[1]),
            f.with_bits(q[2]),
            f.with_bits(q[3]),
        ])
    }

    /// Slot accessors by dataflow role.
    pub fn fwd(&self) -> FormatSpec {
        self.slots[0]
    }
    pub fn stash(&self) -> FormatSpec {
        self.slots[1]
    }
    pub fn bwd(&self) -> FormatSpec {
        self.slots[2]
    }
    pub fn grad(&self) -> FormatSpec {
        self.slots[3]
    }

    /// Per-slot widths `[q0, q1, q2, q3]`.
    pub fn bits(&self) -> [u32; 4] {
        [
            self.slots[0].bits(),
            self.slots[1].bits(),
            self.slots[2].bits(),
            self.slots[3].bits(),
        ]
    }

    /// True iff every slot is the fp32 identity (the paper leaves such
    /// configs unscored in its cost tables).
    pub fn is_fp32(&self) -> bool {
        self.slots.iter().all(|f| *f == FormatSpec::Fp32)
    }

    /// Runtime vector for the artifacts: four `[mode, bits]` slot pairs,
    /// `[m0,q0, m1,q1, m2,q2, m3,q3]` (see `python/compile/layers.py`).
    pub fn as_qcfg(&self) -> [f32; 8] {
        let mut out = [0f32; 8];
        for (i, f) in self.slots.iter().enumerate() {
            let [m, b] = f.slot_qcfg();
            out[2 * i] = m;
            out[2 * i + 1] = b;
        }
        out
    }

    /// `"[16,4,4,16]"` — the paper's width notation (format-blind).
    pub fn notation(&self) -> String {
        let [q0, q1, q2, q3] = self.bits();
        format!("[{q0},{q1},{q2},{q3}]")
    }

    /// Canonical spec string; round-trips through
    /// [`PrecisionConfig::parse`]. Uniform configs print as one format
    /// spec (`"bfp8"`), single-family configs in family form
    /// (`"bfp:16,4,4,16"`), heterogeneous configs slot-by-slot
    /// (`"bfp16,bfp4,bfp4,fixed16sr"`).
    pub fn spec_string(&self) -> String {
        let first = self.slots[0];
        if self.slots.iter().all(|f| *f == first) {
            return first.spec_string();
        }
        if self.slots.iter().all(|f| f.family_name() == first.family_name()) {
            let [q0, q1, q2, q3] = self.bits();
            return format!("{}:{q0},{q1},{q2},{q3}", first.family_name());
        }
        self.slots.iter().map(|f| f.spec_string()).collect::<Vec<_>>().join(",")
    }

    /// Parse a config spec string (see [`PrecisionConfig::spec_string`]
    /// for the three accepted shapes). Width lists may be bracketed
    /// (`"bfp:[16,4,4,16]"`). Every error is [`crate::Error::Config`].
    pub fn parse(s: &str) -> crate::Result<Self> {
        let t = s.trim();
        if let Some((fam_s, widths)) = t.split_once(':') {
            let fam = crate::quant::format::family(fam_s).ok_or_else(|| {
                crate::Error::Config(format!(
                    "unknown format family '{fam_s}' in '{s}' (registered: {})",
                    crate::quant::format::registered_summary()
                ))
            })?;
            let widths = widths.trim().trim_start_matches('[').trim_end_matches(']');
            let parts: Vec<&str> = widths.split(',').collect();
            if parts.len() != 4 {
                return Err(crate::Error::Config(format!(
                    "precision setup needs 4 slot widths: '{s}'"
                )));
            }
            let mut slots = [FormatSpec::Fp32; 4];
            for (slot, p) in slots.iter_mut().zip(&parts) {
                let bits: u32 = p.trim().parse().map_err(|_| {
                    crate::Error::Config(format!("bad slot width '{p}' in '{s}'"))
                })?;
                *slot = fam.instantiate(bits)?;
            }
            return Ok(PrecisionConfig::new(slots));
        }
        if t.contains(',') {
            let parts: Vec<&str> = t.split(',').collect();
            if parts.len() != 4 {
                return Err(crate::Error::Config(format!(
                    "precision setup needs 4 slot specs: '{s}'"
                )));
            }
            let mut slots = [FormatSpec::Fp32; 4];
            for (slot, p) in slots.iter_mut().zip(&parts) {
                *slot = FormatSpec::parse(p)?;
            }
            return Ok(PrecisionConfig::new(slots));
        }
        Ok(PrecisionConfig::uniform(FormatSpec::parse(t)?))
    }

    /// Component-wise width ≥ (used to assert monotone schedules).
    pub fn at_least(&self, other: &PrecisionConfig) -> bool {
        self.bits().iter().zip(other.bits()).all(|(a, b)| *a >= b)
    }
}

/// Resumable schedule state, persisted in checkpoint trailers so a
/// resumed run continues the precision ladder where it left off instead
/// of silently restarting at the most aggressive level.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ScheduleState {
    /// Current ladder level.
    pub level: u32,
    /// Consecutive no-better validations toward the next bump.
    pub stale: u32,
    /// Validations observed so far.
    pub observed: u32,
    /// Best validation loss seen (the plateau reference).
    pub best_loss: f64,
}

/// A precision schedule: one config per training step.
pub trait Schedule {
    /// Config to use for the upcoming step.
    fn current(&self) -> PrecisionConfig;
    /// Feed a validation result (loss); may advance the schedule.
    fn observe_validation(&mut self, val_loss: f64);
    /// Human-readable state for logs.
    fn describe(&self) -> String;
    /// Resumable state for checkpoints (`None` for stateless schedules).
    fn snapshot(&self) -> Option<ScheduleState> {
        None
    }
    /// Restore from a checkpoint snapshot (no-op for stateless schedules).
    fn restore(&mut self, _state: &ScheduleState) {}
}

/// Fixed precision for the whole run.
#[derive(Clone, Debug)]
pub struct StaticSchedule(pub PrecisionConfig);

impl Schedule for StaticSchedule {
    fn current(&self) -> PrecisionConfig {
        self.0
    }
    fn observe_validation(&mut self, _val_loss: f64) {}
    fn describe(&self) -> String {
        format!("static {} {}", self.0.spec_string(), self.0.notation())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qcfg_vector_layout() {
        let c = PrecisionConfig::stashing(FormatSpec::bfp(16));
        assert_eq!(c.as_qcfg(), [2.0, 16.0, 2.0, 4.0, 2.0, 4.0, 2.0, 16.0]);
        assert_eq!(
            PrecisionConfig::FP32.as_qcfg(),
            [0.0, 32.0, 0.0, 32.0, 0.0, 32.0, 0.0, 32.0]
        );
        // Heterogeneous slots carry their own mode scalars.
        let h = PrecisionConfig::new([
            FormatSpec::bfp(16),
            FormatSpec::bfp(4),
            FormatSpec::fixed(4),
            FormatSpec::fixed_sr(16),
        ]);
        assert_eq!(h.as_qcfg(), [2.0, 16.0, 2.0, 4.0, 1.0, 4.0, 3.0, 16.0]);
        // Float slots use mode 4/5 with the packed 100·E + M width field.
        let f = PrecisionConfig::parse("fp8e4m3,fp8e4m3,e4m3sr,fp8e5m2").unwrap();
        assert_eq!(f.as_qcfg(), [4.0, 403.0, 4.0, 403.0, 5.0, 403.0, 4.0, 502.0]);
        assert_eq!(f.notation(), "[8,8,8,8]", "notation stays the total width");
    }

    #[test]
    fn parse_family_form() {
        let c = PrecisionConfig::parse("bfp:[16,4,4,16]").unwrap();
        assert_eq!(c, PrecisionConfig::stashing(FormatSpec::bfp(16)));
        assert_eq!(c.notation(), "[16,4,4,16]");
        let c2 = PrecisionConfig::parse("fixed: 8, 8, 8, 32").unwrap();
        assert_eq!(c2.grad(), FormatSpec::fixed(32));
        let c3 = PrecisionConfig::parse("fixedsr:16,4,4,16").unwrap();
        assert_eq!(c3.stash(), FormatSpec::fixed_sr(4));
    }

    #[test]
    fn parse_uniform_and_per_slot_forms() {
        assert_eq!(PrecisionConfig::parse("fp32").unwrap(), PrecisionConfig::FP32);
        assert_eq!(
            PrecisionConfig::parse("bfp8").unwrap(),
            PrecisionConfig::uniform(FormatSpec::bfp(8))
        );
        let h = PrecisionConfig::parse("bfp16,bfp4,bfp4,fixed16sr").unwrap();
        assert_eq!(
            h.slots,
            [
                FormatSpec::bfp(16),
                FormatSpec::bfp(4),
                FormatSpec::bfp(4),
                FormatSpec::fixed_sr(16)
            ]
        );
    }

    #[test]
    fn parse_rejects_bad_input() {
        for bad in [
            "bfp:16,4,4",
            "bfp:16,4,4,1",
            "bfp:16,4,x,16",
            "bfp:64,4,4,16",
            "int8:8,8,8,16",
            "bfp16,bfp4,bfp4",
            "bfp16,bfp4,bfp4,nope16",
            "",
            "bfp",
            "fixed0",
        ] {
            let r = PrecisionConfig::parse(bad);
            assert!(
                matches!(r, Err(crate::Error::Config(_))),
                "'{bad}' should be Error::Config, got {r:?}"
            );
        }
    }

    #[test]
    fn spec_string_roundtrip() {
        let configs = [
            PrecisionConfig::FP32,
            PrecisionConfig::uniform(FormatSpec::bfp(8)),
            PrecisionConfig::uniform(FormatSpec::fixed_sr(8)),
            PrecisionConfig::stashing(FormatSpec::bfp(16)),
            PrecisionConfig::stashing(FormatSpec::fixed(16)),
            PrecisionConfig::new([
                FormatSpec::bfp(16),
                FormatSpec::bfp(4),
                FormatSpec::fixed(4),
                FormatSpec::fixed_sr(16),
            ]),
            // Float slots: uniform, heterogeneous-within-float, and
            // float mixed with the integer families.
            PrecisionConfig::uniform(FormatSpec::fp8e4m3()),
            PrecisionConfig::parse("fp8e4m3,fp8e4m3,fp8e4m3,fp8e5m2").unwrap(),
            PrecisionConfig::parse("e5m10,e4m3,e4m3sr,e5m2").unwrap(),
            PrecisionConfig::parse("bfp16,e4m3,bfp4,fixed16sr").unwrap(),
        ];
        for c in configs {
            let s = c.spec_string();
            assert_eq!(PrecisionConfig::parse(&s).unwrap(), c, "round-trip of '{s}'");
        }
    }

    #[test]
    fn roundtrip_property_over_registry() {
        use crate::util::prop::Prop;
        Prop::new("random per-slot configs round-trip through spec strings").cases(80).run(
            |rng, _| {
                let pick = |rng: &mut crate::util::rng::Pcg32| {
                    let fam = &crate::quant::format::FORMAT_REGISTRY
                        [rng.below(crate::quant::format::FORMAT_REGISTRY.len() as u32) as usize];
                    fam.instantiate(rng.range(fam.min_bits, fam.max_bits + 1)).unwrap()
                };
                PrecisionConfig::new([
                    pick(&mut *rng),
                    pick(&mut *rng),
                    pick(&mut *rng),
                    pick(&mut *rng),
                ])
            },
            |c| {
                let s = c.spec_string();
                match PrecisionConfig::parse(&s) {
                    Ok(back) if back == *c => Ok(()),
                    Ok(back) => Err(format!("'{s}' reparsed as {back:?}")),
                    Err(e) => Err(format!("'{s}' failed to parse: {e}")),
                }
            },
        );
    }

    #[test]
    fn at_least_ordering() {
        let lo = PrecisionConfig::uniform(FormatSpec::bfp(4));
        let hi = PrecisionConfig::uniform(FormatSpec::bfp(16));
        assert!(hi.at_least(&lo));
        assert!(!lo.at_least(&hi));
        // Width comparison is format-blind: a fixed16 grad slot still
        // dominates a bfp4 one.
        let het = PrecisionConfig::parse("bfp16,bfp4,bfp4,fixed16").unwrap();
        assert!(het.at_least(&PrecisionConfig::parse("bfp:4,4,4,16").unwrap()));
    }

    #[test]
    fn static_schedule_never_changes() {
        let mut s = StaticSchedule(PrecisionConfig::stashing(FormatSpec::bfp(16)));
        let before = s.current();
        for i in 0..10 {
            s.observe_validation(10.0 - i as f64);
        }
        assert_eq!(s.current(), before);
    }
}
