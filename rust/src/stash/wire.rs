//! Versioned wire-frame codec for the replica exchange.
//!
//! Every byte that crosses a replica boundary — whether through the
//! in-memory ring or a real socket — is one **frame**: a fixed 40-byte
//! self-describing header followed by a length-prefixed payload. The
//! payload of a data frame is exactly what [`crate::stash::exchange`]
//! has always shipped: the packed v2 records for every state tensor in
//! registry order, followed by one little-endian `f32` loss word. The
//! codec owns only the envelope; it never interprets the payload.
//!
//! # Frame layout (`DSQWIRE1`)
//!
//! | bytes  | field        | encoding                                  |
//! |--------|--------------|-------------------------------------------|
//! | 0..8   | magic        | `DSQWIRE1` (ASCII, version in the name)   |
//! | 8..12  | rank         | `u32` LE — sender replica rank            |
//! | 12..20 | step         | `u64` LE — optimizer step of this round   |
//! | 20..28 | seq          | `u64` LE — per-sender frame sequence no.  |
//! | 28..32 | tensors      | `u32` LE — tensor-record count in payload |
//! | 32..40 | payload len  | `u64` LE — payload byte count             |
//! | 40..   | payload      | packed v2 records + trailing loss word    |
//!
//! Two reserved ranks carry control traffic instead of tensor data:
//! [`RANK_ABORT`] frames ship a UTF-8 teardown message (the
//! `ABORT_PREFIX` propagation path), and [`RANK_CONTROL`] frames carry
//! transport-internal handshake payloads (HELLO / CONFIG). Real
//! replica ranks are always below both.
//!
//! # Torn-frame detection
//!
//! [`WireFrame::read_from`] refuses to return a partial frame: EOF in
//! the middle of the header or the payload is an error naming how many
//! bytes arrived versus how many the header promised, a wrong magic is
//! an error quoting the bytes found, and a payload length above
//! [`MAX_PAYLOAD`] is rejected before any allocation (a torn or
//! corrupt header cannot ask us to allocate the universe).
//! [`WireFrame::read_or_eof`] is the one sanctioned clean-shutdown
//! path: EOF *exactly at a frame boundary* (zero header bytes read)
//! returns `Ok(None)`; everything else behaves like `read_from`.
//!
//! The exact header bytes are pinned by a golden-byte test below —
//! bump the magic to `DSQWIRE2` if the layout ever changes.

use crate::{Error, Result};
use std::io::{Read, Write};

/// The one definition of the wire magic. Grep for `DSQWIRE1` finds
/// this constant, the golden-byte test pinning it, and prose only.
pub const WIRE_MAGIC: &[u8; 8] = b"DSQWIRE1";

/// Fixed header length in bytes: magic(8) + rank(4) + step(8) +
/// seq(8) + tensors(4) + payload-len(8).
pub const HEADER_LEN: usize = 40;

/// Sender rank of an abort (teardown) frame; payload is the UTF-8
/// error message.
pub const RANK_ABORT: u32 = u32::MAX;

/// Sender rank of a transport-internal control frame (handshake
/// HELLO / CONFIG payloads).
pub const RANK_CONTROL: u32 = u32::MAX - 1;

/// Upper bound on a single frame's payload, enforced before
/// allocation on the read path. Generous — the largest real frame is
/// a full model state in packed records — but finite, so a torn or
/// corrupt length field fails fast instead of aborting on OOM.
pub const MAX_PAYLOAD: u64 = 1 << 32;

/// The fixed-size portion of a frame: everything but the payload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FrameHeader {
    /// Sender replica rank, or [`RANK_ABORT`] / [`RANK_CONTROL`].
    pub rank: u32,
    /// Optimizer step the frame belongs to (0 for control traffic).
    pub step: u64,
    /// Per-sender monotonically increasing frame counter.
    pub seq: u64,
    /// Number of packed tensor records in the payload (0 for control).
    pub tensors: u32,
}

/// One complete wire frame: header + owned payload bytes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WireFrame {
    pub header: FrameHeader,
    pub payload: Vec<u8>,
}

fn wire_error(msg: String) -> Error {
    Error::Config(format!("wire frame: {msg}"))
}

fn u32_at(buf: &[u8], at: usize) -> u32 {
    let mut b = [0u8; 4];
    b.copy_from_slice(&buf[at..at + 4]);
    u32::from_le_bytes(b)
}

fn u64_at(buf: &[u8], at: usize) -> u64 {
    let mut b = [0u8; 8];
    b.copy_from_slice(&buf[at..at + 8]);
    u64::from_le_bytes(b)
}

impl WireFrame {
    /// A data frame from a real replica rank.
    pub fn data(rank: u32, step: u64, seq: u64, tensors: u32, payload: Vec<u8>) -> Self {
        WireFrame {
            header: FrameHeader { rank, step, seq, tensors },
            payload,
        }
    }

    /// A teardown frame carrying a UTF-8 error message; every peer
    /// that reads one surfaces the message as an `ABORT_PREFIX` error.
    pub fn abort(msg: &str) -> Self {
        WireFrame {
            header: FrameHeader { rank: RANK_ABORT, step: 0, seq: 0, tensors: 0 },
            payload: msg.as_bytes().to_vec(),
        }
    }

    /// A transport-internal control frame (handshake payloads).
    pub fn control(payload: Vec<u8>) -> Self {
        WireFrame {
            header: FrameHeader { rank: RANK_CONTROL, step: 0, seq: 0, tensors: 0 },
            payload,
        }
    }

    /// True for teardown frames written by [`WireFrame::abort`].
    pub fn is_abort(&self) -> bool {
        self.header.rank == RANK_ABORT
    }

    /// True for handshake frames written by [`WireFrame::control`].
    pub fn is_control(&self) -> bool {
        self.header.rank == RANK_CONTROL
    }

    /// The teardown message of an abort frame (lossy UTF-8).
    pub fn abort_message(&self) -> String {
        String::from_utf8_lossy(&self.payload).into_owned()
    }

    /// Total on-the-wire size of this frame in bytes.
    pub fn frame_len(&self) -> usize {
        HEADER_LEN + self.payload.len()
    }

    /// Serialize the 40-byte header into a stack buffer.
    fn header_bytes(&self) -> [u8; HEADER_LEN] {
        let mut h = [0u8; HEADER_LEN];
        h[0..8].copy_from_slice(WIRE_MAGIC);
        h[8..12].copy_from_slice(&self.header.rank.to_le_bytes());
        h[12..20].copy_from_slice(&self.header.step.to_le_bytes());
        h[20..28].copy_from_slice(&self.header.seq.to_le_bytes());
        h[28..32].copy_from_slice(&self.header.tensors.to_le_bytes());
        h[32..40].copy_from_slice(&(self.payload.len() as u64).to_le_bytes());
        h
    }

    /// Write the complete frame (header + payload) to `w`.
    pub fn write_into(&self, w: &mut impl Write) -> Result<()> {
        w.write_all(&self.header_bytes())
            .map_err(|e| wire_error(format!("writing header: {e}")))?;
        w.write_all(&self.payload)
            .map_err(|e| wire_error(format!("writing {} payload bytes: {e}", self.payload.len())))?;
        Ok(())
    }

    /// Read exactly one frame from `r`, rejecting torn frames: EOF
    /// anywhere inside the header or payload is an error naming the
    /// byte counts, as are a wrong magic and an implausible length.
    pub fn read_from(r: &mut impl Read) -> Result<WireFrame> {
        match read_frame(r, false)? {
            Some(f) => Ok(f),
            // read_frame(eof_ok = false) never returns None.
            None => Err(wire_error("empty stream".into())),
        }
    }

    /// Like [`WireFrame::read_from`], but EOF *before any header byte*
    /// is the sanctioned clean-shutdown signal and returns `Ok(None)`.
    pub fn read_or_eof(r: &mut impl Read) -> Result<Option<WireFrame>> {
        read_frame(r, true)
    }
}

/// Read one frame; `eof_ok` permits clean EOF at a frame boundary.
fn read_frame(r: &mut impl Read, eof_ok: bool) -> Result<Option<WireFrame>> {
    let mut head = [0u8; HEADER_LEN];
    let mut got = 0usize;
    while got < HEADER_LEN {
        let n = r
            .read(&mut head[got..])
            .map_err(|e| wire_error(format!("reading header: {e}")))?;
        if n == 0 {
            if got == 0 && eof_ok {
                return Ok(None);
            }
            return Err(wire_error(format!(
                "torn frame: EOF after {got} of {HEADER_LEN} header bytes"
            )));
        }
        got += n;
    }
    if &head[0..8] != WIRE_MAGIC {
        return Err(wire_error(format!(
            "bad magic {:?} (expected {:?})",
            &head[0..8],
            WIRE_MAGIC
        )));
    }
    let header = FrameHeader {
        rank: u32_at(&head, 8),
        step: u64_at(&head, 12),
        seq: u64_at(&head, 20),
        tensors: u32_at(&head, 28),
    };
    let plen = u64_at(&head, 32);
    if plen > MAX_PAYLOAD {
        return Err(wire_error(format!(
            "implausible payload length {plen} (cap {MAX_PAYLOAD}) — torn or corrupt header"
        )));
    }
    let mut payload = vec![0u8; plen as usize];
    let mut got = 0usize;
    while got < payload.len() {
        let n = r
            .read(&mut payload[got..])
            .map_err(|e| wire_error(format!("reading payload: {e}")))?;
        if n == 0 {
            return Err(wire_error(format!(
                "torn frame: EOF after {got} of {plen} payload bytes (rank {})",
                header.rank
            )));
        }
        got += n;
    }
    Ok(Some(WireFrame { header, payload }))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(f: &WireFrame) -> WireFrame {
        let mut buf = Vec::new();
        f.write_into(&mut buf).unwrap();
        assert_eq!(buf.len(), f.frame_len());
        let mut cur = &buf[..];
        let got = WireFrame::read_or_eof(&mut cur).unwrap().unwrap();
        assert!(cur.is_empty(), "reader consumed exactly one frame");
        got
    }

    #[test]
    fn golden_bytes_pin_the_frame_header() {
        // The wire contract: any edit that changes these bytes must
        // bump the magic. rank=3, step=0x0102030405060708,
        // seq=0x1122334455667788, tensors=7, payload = [0xAA, 0xBB].
        let f = WireFrame::data(3, 0x0102030405060708, 0x1122334455667788, 7, vec![0xAA, 0xBB]);
        let mut buf = Vec::new();
        f.write_into(&mut buf).unwrap();
        let expect: Vec<u8> = [
            b"DSQWIRE1" as &[u8],              // magic — the one raw-literal site
            &3u32.to_le_bytes(),               // rank
            &[0x08, 0x07, 0x06, 0x05, 0x04, 0x03, 0x02, 0x01], // step LE
            &[0x88, 0x77, 0x66, 0x55, 0x44, 0x33, 0x22, 0x11], // seq LE
            &7u32.to_le_bytes(),               // tensors
            &2u64.to_le_bytes(),               // payload len
            &[0xAA, 0xBB],                     // payload
        ]
        .concat();
        assert_eq!(buf, expect);
        assert_eq!(buf.len(), HEADER_LEN + 2);
    }

    #[test]
    fn data_frame_roundtrips() {
        let f = WireFrame::data(2, 41, 9, 6, (0u8..=255).collect());
        let got = roundtrip(&f);
        assert_eq!(got, f);
        assert!(!got.is_abort() && !got.is_control());
    }

    #[test]
    fn abort_and_control_frames_roundtrip() {
        let a = WireFrame::abort("replica 1 failed: disk gone");
        let got = roundtrip(&a);
        assert!(got.is_abort());
        assert_eq!(got.abort_message(), "replica 1 failed: disk gone");

        let c = WireFrame::control(b"HELLO 0".to_vec());
        let got = roundtrip(&c);
        assert!(got.is_control());
        assert_eq!(got.payload, b"HELLO 0");
    }

    #[test]
    fn torn_header_and_torn_payload_are_named_errors() {
        let f = WireFrame::data(0, 1, 2, 3, vec![1, 2, 3, 4]);
        let mut buf = Vec::new();
        f.write_into(&mut buf).unwrap();

        // Truncate mid-header.
        for cut in [1usize, HEADER_LEN - 1] {
            let mut cur = &buf[..cut];
            let err = WireFrame::read_or_eof(&mut cur).unwrap_err().to_string();
            assert!(err.contains("torn frame"), "{err}");
            assert!(err.contains(&format!("{cut} of {HEADER_LEN} header bytes")), "{err}");
        }

        // Truncate mid-payload.
        let mut cur = &buf[..HEADER_LEN + 2];
        let err = WireFrame::read_or_eof(&mut cur).unwrap_err().to_string();
        assert!(err.contains("torn frame"), "{err}");
        assert!(err.contains("2 of 4 payload bytes"), "{err}");
    }

    #[test]
    fn clean_eof_at_a_frame_boundary_is_none_but_read_from_errors() {
        let mut cur: &[u8] = &[];
        assert!(WireFrame::read_or_eof(&mut cur).unwrap().is_none());

        let mut cur: &[u8] = &[];
        let err = WireFrame::read_from(&mut cur).unwrap_err().to_string();
        assert!(err.contains("torn frame"), "{err}");
    }

    #[test]
    fn bad_magic_and_implausible_length_are_rejected() {
        let f = WireFrame::data(0, 0, 0, 0, vec![]);
        let mut buf = Vec::new();
        f.write_into(&mut buf).unwrap();

        let mut bad = buf.clone();
        bad[7] = b'9';
        let err = WireFrame::read_or_eof(&mut &bad[..]).unwrap_err().to_string();
        assert!(err.contains("bad magic"), "{err}");

        let mut huge = buf;
        huge[32..40].copy_from_slice(&(MAX_PAYLOAD + 1).to_le_bytes());
        let err = WireFrame::read_or_eof(&mut &huge[..]).unwrap_err().to_string();
        assert!(err.contains("implausible payload length"), "{err}");
    }
}
