//! Replica exchange: transport-agnostic collectives that turn N
//! `Session` replicas into one data-parallel run, speaking the stash
//! layer's v2 packed-record format over any [`Transport`].
//!
//! ## Layering
//!
//! Since the multi-process refactor the exchange is three modules with
//! hard seams:
//!
//! * [`super::wire`] — the versioned DSQWIRE1 frame codec (header +
//!   length-prefixed payload, torn-frame detection). Only socket-style
//!   transports put it on a real wire; the payload format is the same
//!   everywhere.
//! * [`super::transport`] — how payloads move: post-and-collect
//!   semantics behind the [`Transport`] trait. `MemTransport` is the
//!   original in-process ring (one post slot per rank under the `ring`
//!   mutex — `--transport mem`, the default, bit-identical to the
//!   pre-refactor exchange); `SocketTransport` runs N OS processes
//!   over Unix/TCP sockets (`--transport socket:<addr>`).
//! * this module — the *collective*: the dequant–reduce–requant
//!   all-reduce over whichever transport, plus the comms traffic
//!   meter. Nothing here knows how bytes travel.
//!
//! ## Protocol
//!
//! Each step every rank
//!
//! 1. **encodes** its post-step state (params, m, v — the same tensors
//!    the stash store owns) as one payload of v2 packed records in the
//!    comms [`FormatSpec`], plus a trailing fp32 loss word;
//! 2. **posts** the payload through [`Transport::post_collect`], which
//!    blocks until every rank's payload for the round is available and
//!    returns all N in rank order;
//! 3. **decodes** all N payloads in rank order, sums dense f32, divides
//!    by N, and **requantizes** the mean at salt 0 — every rank applies
//!    the identical dequant–reduce–requant, so replica states
//!    re-converge bit-identically each step.
//!
//! Under `fp32` comms the encode/decode legs are exact passthrough and
//! the mean of two identical states is bit-identical to either (the
//! mirrored two-replica transparency test pins this).
//!
//! ## Replica seeding contract
//!
//! Stochastic-rounding encodes are salted with the **replica rank**
//! ([`Codec::encode_stream_salted`]): seeding on `(step, stream)` alone
//! would give every replica the same rounding stream — perfectly
//! correlated noise that biases the reduction instead of averaging out.
//! Salt 0 reproduces the unsalted stream exactly, so rank 0 and every
//! single-replica path are bit-compatible with the non-replicated
//! system. The post-reduce requantize of the (identical) mean always
//! runs at salt 0 on every rank.
//!
//! ## Failure teardown
//!
//! A replica that dies — divergence abort, I/O error, panic — must not
//! strand peers on the collective. [`Exchange::fail`] (called by
//! [`run_replicas`] on any worker error, and by a drop-guard on panic)
//! tears the transport down; every waiter, and every later arrival,
//! returns a loud [`Error`] carrying the transport's `ABORT_PREFIX`
//! instead of hanging. The same contract holds across processes: a
//! dead socket peer aborts every survivor within the read timeout.
//!
//! ## Lock order
//!
//! One global order across the exchange stack: the mem transport's
//! `ring` mutex (barrier state, witness rank 10) strictly before this
//! module's `comms` mutex (traffic meter, witness rank 20), with the
//! socket transport's `failed` flag (rank 15) between them. No
//! function acquires `comms` before `ring`. The order is enforced
//! twice: statically by `dsq lint`'s interprocedural `lock_discipline`
//! rule (with `blocking_under_lock` refusing channel/join/sleep/File
//! and socket I/O parks while any lock is held), and dynamically by
//! the debug-build lock-order witness — all three are
//! [`WitnessedMutex`]es, so every test run asserts the declared order
//! per thread at runtime.
//!
//! [`WitnessedMutex`]: crate::util::ordwitness::WitnessedMutex

use std::io::Read;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::model::ModelState;
use crate::quant::{stash_stream, Codec, FormatSpec, PackedTensor};
use crate::runtime::HostTensor;
use crate::util::ordwitness::{self, WitnessedMutex};
use crate::{Error, Result};

use super::transport::{MemTransport, Transport, ABORT_PREFIX};
use super::TrafficMeter;

/// How a replica participates in the sharded batch stream.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ReplicaShard {
    /// This replica's rank in `[0, replicas)`.
    pub rank: usize,
    /// Total replica count.
    pub replicas: usize,
    /// When true every replica consumes the *same* stream (the
    /// transparency/bit-identity configuration); when false the epoch
    /// stream is dealt round-robin, so N replicas consume N× the data
    /// per step — the 2×-batch emulation.
    pub mirror: bool,
}

/// Comms traffic report: the exchange-side mirror of `StashTraffic` —
/// modeled `container_bits()` next to codec-observed wire bytes, with
/// the same box-metadata allowance.
#[derive(Clone, Copy, Debug)]
pub struct CommsTraffic {
    pub spec: FormatSpec,
    pub replicas: usize,
    /// Aggregate meter across all ranks (only the `comms_*` channels are
    /// populated by the exchange).
    pub meter: TrafficMeter,
    /// Legitimate modeled-vs-observed slack in bits, accumulated per
    /// encoded/decoded tensor exactly like the stash store does.
    pub allowance_bits: f64,
}

impl CommsTraffic {
    /// |observed − modeled| in bits.
    pub fn gap_bits(&self) -> f64 {
        (self.meter.observed_comms_bits() - self.meter.modeled_comms_bits).abs()
    }

    /// True when the codec-observed wire bits agree with the cost
    /// model's `container_bits()` within the box-metadata allowance.
    pub fn agrees(&self) -> bool {
        self.gap_bits() <= self.allowance_bits
    }

    /// One-line human summary for run reports.
    pub fn summary(&self) -> String {
        format!(
            "comms[{} x{}]: observed {:.0} bits (tx {} B, rx {} B, frames {} B), \
             modeled {:.0} bits, gap {:.0} <= allowance {:.0}",
            self.spec,
            self.replicas,
            self.meter.observed_comms_bits(),
            self.meter.comms_tx_bytes,
            self.meter.comms_rx_bytes,
            self.meter.comms_frame_bytes,
            self.meter.modeled_comms_bits,
            self.gap_bits(),
            self.allowance_bits,
        )
    }

    /// JSON fragment for `RunReport::to_json`.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("spec", Json::str(&self.spec.spec_string())),
            ("replicas", Json::num(self.replicas as f64)),
            ("observed_comms_bits", Json::num(self.meter.observed_comms_bits())),
            ("modeled_comms_bits", Json::num(self.meter.modeled_comms_bits)),
            ("comms_tx_bytes", Json::num(self.meter.comms_tx_bytes as f64)),
            ("comms_rx_bytes", Json::num(self.meter.comms_rx_bytes as f64)),
            ("comms_frame_bytes", Json::num(self.meter.comms_frame_bytes as f64)),
            ("allowance_bits", Json::num(self.allowance_bits)),
            ("agrees", Json::Bool(self.agrees())),
        ])
    }
}

/// Aggregate comms meter, shared by all ranks of this process.
#[derive(Default)]
struct Comms {
    meter: TrafficMeter,
    allowance_bits: f64,
}

struct Core {
    spec: FormatSpec,
    /// How payloads move between ranks. The mem transport's `ring`
    /// mutex sorts strictly before `comms` in the global lock order.
    transport: Arc<dyn Transport>,
    /// Traffic meter, rank [`ordwitness::RANK_EXCHANGE_COMMS`] — always
    /// acquired with no other exchange lock held.
    comms: WitnessedMutex<Comms>,
}

/// Minor-axis length convention for box-based formats — the stash
/// layer's rule (last dim, scalars count as 1).
fn tensor_inner(shape: &[usize]) -> usize {
    shape.last().copied().filter(|&d| d > 0).unwrap_or(1)
}

/// Shared exchange core: construct once, hand one [`ReplicaExchange`]
/// per rank. Cloning shares the core (used for failure injection from
/// the orchestrator).
#[derive(Clone)]
pub struct Exchange {
    core: Arc<Core>,
}

impl Exchange {
    /// The default in-process exchange over [`MemTransport`].
    pub fn new(spec: FormatSpec, replicas: usize) -> Result<Exchange> {
        Ok(Self::with_transport(spec, Arc::new(MemTransport::new(replicas)?)))
    }

    /// An exchange over any transport — the multi-process seam: hand in
    /// a connected `SocketTransport` and the same collective runs
    /// across OS processes.
    pub fn with_transport(spec: FormatSpec, transport: Arc<dyn Transport>) -> Exchange {
        Exchange {
            core: Arc::new(Core {
                spec,
                transport,
                comms: WitnessedMutex::new(
                    ordwitness::RANK_EXCHANGE_COMMS,
                    "exchange.comms",
                    Comms::default(),
                ),
            }),
        }
    }

    pub fn replicas(&self) -> usize {
        self.core.transport.replicas()
    }

    pub fn spec(&self) -> FormatSpec {
        self.core.spec
    }

    /// The per-rank participant handle.
    pub fn handle(&self, rank: usize) -> Result<ReplicaExchange> {
        let n = self.core.transport.replicas();
        if rank >= n {
            return Err(Error::Config(format!(
                "replica rank {rank} out of range (replicas = {n})"
            )));
        }
        Ok(ReplicaExchange {
            core: Arc::clone(&self.core),
            rank,
            seq: AtomicU64::new(0),
            stats: ExchangeStats::default(),
        })
    }

    /// Tear the exchange down: every blocked or future collective call
    /// on any rank returns an error naming `msg`. First failure wins;
    /// idempotent after that.
    pub fn fail(&self, msg: &str) {
        self.core.transport.fail(msg);
    }

    /// Aggregate comms traffic across all ranks so far.
    pub fn traffic_report(&self) -> CommsTraffic {
        let comms = self.core.comms.lock();
        CommsTraffic {
            spec: self.core.spec,
            replicas: self.core.transport.replicas(),
            meter: comms.meter,
            allowance_bits: comms.allowance_bits,
        }
    }

    /// Completed all-reduce rounds, as visible to this process's
    /// transport.
    pub fn rounds(&self) -> u64 {
        self.core.transport.rounds()
    }
}

/// Per-handle wire/clock counters, bumped lock-free after every
/// all-reduce round. Unlike the shared `comms` meter (aggregated across
/// ranks, behind a mutex), these are *this rank's* numbers — what the
/// session's span recorder diffs around each round to attribute
/// exchange time and bytes to the step that spent them.
#[derive(Default)]
struct ExchangeStats {
    tx_bytes: AtomicU64,
    rx_bytes: AtomicU64,
    frame_bytes: AtomicU64,
    encode_ns: AtomicU64,
    post_ns: AtomicU64,
    reduce_ns: AtomicU64,
}

/// A point-in-time copy of one rank's [`ReplicaExchange`] counters
/// ([`ReplicaExchange::counter_snapshot`]): cumulative wire bytes plus
/// the encode / post / reduce clocks, in nanoseconds since the handle
/// was created.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ExchangeCounters {
    /// Own encoded payload bytes shipped (pre-envelope).
    pub tx_bytes: u64,
    /// Peer payload bytes decoded.
    pub rx_bytes: u64,
    /// On-the-wire frame bytes (payload + transport envelope).
    pub frame_bytes: u64,
    /// Time spent encoding this rank's contribution.
    pub encode_ns: u64,
    /// Time blocked in post-and-collect (the barrier wait).
    pub post_ns: u64,
    /// Time spent decoding peers + mean + requantize.
    pub reduce_ns: u64,
}

/// One rank's handle onto the exchange.
pub struct ReplicaExchange {
    core: Arc<Core>,
    rank: usize,
    /// Per-handle frame counter — all ranks advance it in lockstep, so
    /// self-describing transports can detect desynchronized rounds.
    seq: AtomicU64,
    /// Per-rank telemetry counters (see [`ExchangeCounters`]).
    stats: ExchangeStats,
}

impl ReplicaExchange {
    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn replicas(&self) -> usize {
        self.core.transport.replicas()
    }

    pub fn spec(&self) -> FormatSpec {
        self.core.spec
    }

    /// The factory view of this handle's core (for reports / teardown).
    pub fn exchange(&self) -> Exchange {
        Exchange { core: Arc::clone(&self.core) }
    }

    /// One collective round through the transport.
    fn post_round(&self, step: u64, tensors: u32, payload: Vec<u8>) -> Result<Vec<Arc<Vec<u8>>>> {
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        self.core.transport.post_collect(self.rank, step, seq, tensors, payload)
    }

    /// Post one raw payload and block until every rank's payload for
    /// this round is in; returns all N in rank order. Errors (never
    /// hangs) if any rank tore the exchange down.
    pub fn all_reduce_bytes(&self, frame: Vec<u8>) -> Result<Vec<Arc<Vec<u8>>>> {
        self.post_round(0, 0, frame)
    }

    /// See [`Exchange::fail`].
    pub fn fail(&self, msg: &str) {
        self.core.transport.fail(msg);
    }

    /// The dequant–reduce–requant all-reduce over one post-step state:
    /// encode (rank-salted), post-and-collect, decode all ranks, mean in
    /// rank order, requantize the mean at salt 0, write back. Returns
    /// the mean loss. With 1 replica this is a strict no-op so the
    /// default path stays bit-for-bit.
    pub fn all_reduce_state(&self, state: &mut ModelState, loss: f32) -> Result<f32> {
        let n_replicas = self.core.transport.replicas();
        if n_replicas == 1 {
            return Ok(loss);
        }
        let spec = self.core.spec;
        let step = state.step;

        // Encode this rank's contribution as one payload of v2 records.
        let t_encode = Instant::now();
        let mut frame: Vec<u8> = Vec::new();
        let mut tx_payload = 0u64;
        let mut modeled_bits = 0f64;
        let mut allowance_bits = 0f64;
        for (g, group) in [&state.params, &state.m, &state.v].into_iter().enumerate() {
            for (i, t) in group.iter().enumerate() {
                let x = t.as_f32()?;
                let inner = tensor_inner(&t.shape);
                let p = spec.encode_stream_salted(
                    x,
                    &t.shape,
                    inner,
                    step,
                    stash_stream(g, i),
                    self.rank as u64,
                );
                tx_payload += p.packed_len() as u64;
                modeled_bits += spec.container_bits() * x.len() as f64;
                allowance_bits += spec.storage_allowance_bits(x.len(), inner);
                p.write_into(&mut frame)?;
            }
        }
        frame.extend_from_slice(&loss.to_le_bytes());
        // The transport knows its envelope: the mem ring ships bare
        // payloads, the socket path adds the wire header.
        let frame_bytes = self.core.transport.frame_bytes(frame.len());
        let encode_ns = t_encode.elapsed().as_nanos() as u64;

        let ntensors = (state.params.len() * 3) as u32;
        let t_post = Instant::now();
        let frames = self.post_round(step, ntensors, frame)?;
        let post_ns = t_post.elapsed().as_nanos() as u64;
        let t_reduce = Instant::now();

        // Decode every rank in rank order (own frame included: peers see
        // this rank through the wire, so this rank must too) and sum.
        let ntensors = state.params.len() * 3;
        let mut sums: Vec<Vec<f32>> = Vec::with_capacity(ntensors);
        let mut loss_sum = 0f32;
        let mut rx_payload = 0u64;
        for (r, buf) in frames.iter().enumerate() {
            let mut cur: &[u8] = buf;
            for (g, group) in [&state.params, &state.m, &state.v].into_iter().enumerate() {
                for (i, t) in group.iter().enumerate() {
                    let p = PackedTensor::read_from(&mut cur)?;
                    if p.spec() != spec || p.shape() != t.shape.as_slice() {
                        return Err(Error::Shape(format!(
                            "exchange frame from rank {r} mismatches tensor ({g},{i}): \
                             {} {:?} vs expected {} {:?}",
                            p.spec(),
                            p.shape(),
                            spec,
                            t.shape
                        )));
                    }
                    if r != self.rank {
                        rx_payload += p.packed_len() as u64;
                    }
                    let decoded = p.decode();
                    let k = g * state.params.len() + i;
                    if r == 0 {
                        sums.push(decoded);
                    } else {
                        for (s, d) in sums[k].iter_mut().zip(&decoded) {
                            *s += d;
                        }
                    }
                }
            }
            let mut lb = [0u8; 4];
            cur.read_exact(&mut lb)?;
            if !cur.is_empty() {
                return Err(Error::Shape(format!(
                    "exchange frame from rank {r} has {} trailing bytes",
                    cur.len()
                )));
            }
            loss_sum += f32::from_le_bytes(lb);
        }

        // Mean + requantize at salt 0 — identical on every rank, so the
        // replica states re-converge bit-for-bit each round.
        let n = n_replicas as f32;
        let nparams = state.params.len();
        for (g, group) in
            [&mut state.params, &mut state.m, &mut state.v].into_iter().enumerate()
        {
            for (i, t) in group.iter_mut().enumerate() {
                let mut mean = std::mem::take(&mut sums[g * nparams + i]);
                for v in mean.iter_mut() {
                    *v /= n;
                }
                let inner = tensor_inner(&t.shape);
                spec.quantize_into_stream(&mut mean, inner, step, stash_stream(g, i));
                *t = HostTensor::f32(t.shape.clone(), mean);
            }
        }

        // Per-rank telemetry first — lock-free, so it cannot perturb
        // the lock order the meter below is witnessed under.
        self.stats.tx_bytes.fetch_add(tx_payload, Ordering::Relaxed);
        self.stats.rx_bytes.fetch_add(rx_payload, Ordering::Relaxed);
        self.stats.frame_bytes.fetch_add(frame_bytes, Ordering::Relaxed);
        self.stats.encode_ns.fetch_add(encode_ns, Ordering::Relaxed);
        self.stats.post_ns.fetch_add(post_ns, Ordering::Relaxed);
        self.stats.reduce_ns.fetch_add(t_reduce.elapsed().as_nanos() as u64, Ordering::Relaxed);

        // Meter after the collective; the transport's ring mutex (if
        // any) is long released, so `ring` before `comms` holds.
        let rx_tensors = (n_replicas - 1) as f64;
        self.note_round(
            tx_payload,
            rx_payload,
            frame_bytes,
            modeled_bits * (1.0 + rx_tensors),
            allowance_bits * (1.0 + rx_tensors),
        );
        Ok(loss_sum / n)
    }

    fn note_round(
        &self,
        tx_payload: u64,
        rx_payload: u64,
        frame_bytes: u64,
        modeled_bits: f64,
        allowance_bits: f64,
    ) {
        let mut comms = self.core.comms.lock();
        comms.meter.comms_tx_bytes += tx_payload;
        comms.meter.comms_rx_bytes += rx_payload;
        comms.meter.comms_frame_bytes += frame_bytes;
        comms.meter.modeled_comms_bits += modeled_bits;
        comms.allowance_bits += allowance_bits;
    }

    /// This rank's view of the aggregate comms traffic.
    pub fn traffic_report(&self) -> CommsTraffic {
        self.exchange().traffic_report()
    }

    /// Point-in-time copy of this rank's wire/clock counters. Lock-free
    /// (plain relaxed atomic loads) — the session's span recorder diffs
    /// two snapshots around every round to attribute exchange time and
    /// bytes to the step that spent them.
    pub fn counter_snapshot(&self) -> ExchangeCounters {
        ExchangeCounters {
            tx_bytes: self.stats.tx_bytes.load(Ordering::Relaxed),
            rx_bytes: self.stats.rx_bytes.load(Ordering::Relaxed),
            frame_bytes: self.stats.frame_bytes.load(Ordering::Relaxed),
            encode_ns: self.stats.encode_ns.load(Ordering::Relaxed),
            post_ns: self.stats.post_ns.load(Ordering::Relaxed),
            reduce_ns: self.stats.reduce_ns.load(Ordering::Relaxed),
        }
    }
}

/// Tears the exchange down if a worker unwinds without reporting.
struct AbortGuard {
    ex: Exchange,
    rank: usize,
    armed: bool,
}

impl Drop for AbortGuard {
    fn drop(&mut self) {
        if self.armed {
            self.ex.fail(&format!("replica {} panicked mid-run", self.rank));
        }
    }
}

/// Run `run(rank, handle)` on `replicas` scoped threads sharing one
/// in-memory exchange. Any worker error (or panic) tears the exchange
/// down so peers blocked on the collective error out instead of
/// hanging; the originating failure is preferred over secondary
/// barrier aborts when reporting. On success, rank 0's result is
/// returned.
pub fn run_replicas<R: Send>(
    replicas: usize,
    spec: FormatSpec,
    run: impl Fn(usize, ReplicaExchange) -> Result<R> + Sync,
) -> Result<R> {
    let ex = Exchange::new(spec, replicas)?;
    let results: Vec<Result<R>> = std::thread::scope(|s| {
        let joins: Result<Vec<_>> = (0..replicas)
            .map(|rank| {
                let h = ex.handle(rank)?;
                let exf = ex.clone();
                let run = &run;
                Ok(s.spawn(move || {
                    let mut guard = AbortGuard { ex: exf.clone(), rank, armed: true };
                    let r = run(rank, h);
                    guard.armed = false;
                    if let Err(e) = &r {
                        exf.fail(&format!("replica {rank} failed: {e}"));
                    }
                    r
                }))
            })
            .collect();
        match joins {
            Ok(joins) => joins
                .into_iter()
                .enumerate()
                .map(|(rank, j)| {
                    ordwitness::assert_lock_free("joining a replica worker");
                    j.join().unwrap_or_else(|_| {
                        Err(Error::Config(format!("replica {rank} panicked")))
                    })
                })
                .collect(),
            Err(e) => vec![Err(e)],
        }
    });
    // Prefer the originating error: a barrier abort is a symptom.
    if let Some(idx) = results
        .iter()
        .position(|r| matches!(r, Err(e) if !e.to_string().contains(ABORT_PREFIX)))
    {
        let rank = idx;
        return results.into_iter().nth(rank).unwrap_or_else(|| {
            Err(Error::Config("replica result vanished".into()))
        });
    }
    if let Some(idx) = results.iter().position(Result::is_err) {
        return results.into_iter().nth(idx).unwrap_or_else(|| {
            Err(Error::Config("replica result vanished".into()))
        });
    }
    results.into_iter().next().unwrap_or_else(|| {
        Err(Error::Config("replica exchange ran zero replicas".into()))
    })
}

/// Run one two-replica all-reduce round of `state` in `spec` and return
/// the metered comms traffic — pure measurement on clones; the caller's
/// state and numerics are untouched. The measurement behind the
/// experiments' "measured comms" columns.
pub fn measure_comms_round(state: &ModelState, spec: FormatSpec) -> Result<CommsTraffic> {
    run_replicas(2, spec, |rank, ex| {
        let mut st = state.clone();
        ex.all_reduce_state(&mut st, 1.0 + rank as f32)?;
        Ok(ex.traffic_report())
    })
}

/// [`measure_comms_round`] over a synthetic state with the stash audit
/// shapes (a ragged matrix, a vector, a scalar) — the fixed workload
/// behind [`audit_observed_comms`] and the figure's comms column.
pub fn measure_state_comms(spec: FormatSpec) -> Result<CommsTraffic> {
    let shapes: [&[usize]; 3] = [&[3, 21], &[5], &[]];
    let params: Vec<HostTensor> = shapes
        .iter()
        .map(|s| {
            let len = s.iter().product::<usize>().max(1);
            HostTensor::f32(
                s.to_vec(),
                (0..len).map(|i| (i as f32 * 0.37 - 3.0) * 1.5f32.powi(i as i32 % 7)).collect(),
            )
        })
        .collect();
    let zeros: Vec<HostTensor> = params.iter().map(HostTensor::zeros_like).collect();
    let state = ModelState { params, m: zeros.clone(), v: zeros, step: 3 };
    measure_comms_round(&state, spec)
}

/// `audit_observed_traffic`-style sweep for the comms channel: run one
/// synthetic two-replica all-reduce round over the stash audit shapes
/// and check the meter's observed wire bits agree with the modeled
/// `container_bits()` within the box-metadata allowance.
pub fn audit_observed_comms(spec: &FormatSpec) -> std::result::Result<(), String> {
    let spec = *spec;
    let report =
        measure_state_comms(spec).map_err(|e| format!("{spec}: audit round failed: {e}"))?;
    if report.meter.comms_tx_bytes == 0 || report.meter.comms_rx_bytes == 0 {
        return Err(format!("{spec}: audit metered no comms traffic"));
    }
    if !report.agrees() {
        return Err(format!(
            "{spec}: observed {} bits vs modeled {} (gap {} > allowance {})",
            report.meter.observed_comms_bits(),
            report.meter.modeled_comms_bits,
            report.gap_bits(),
            report.allowance_bits
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::registered_specs;

    fn demo_state(offset: f32) -> ModelState {
        let params = vec![
            HostTensor::f32(vec![2, 21], (0..42).map(|i| i as f32 * 0.25 - 4.0 + offset).collect()),
            HostTensor::f32(vec![5], (0..5).map(|i| i as f32 - 2.0 + offset).collect()),
        ];
        let m: Vec<HostTensor> =
            params.iter().map(|t| HostTensor::f32(t.shape.clone(), vec![offset; t.len()])).collect();
        let v: Vec<HostTensor> = params.iter().map(HostTensor::zeros_like).collect();
        ModelState { params, m, v, step: 7 }
    }

    fn flat(state: &ModelState) -> Vec<f32> {
        [&state.params, &state.m, &state.v]
            .iter()
            .flat_map(|g| g.iter())
            .flat_map(|t| t.as_f32().unwrap().iter().copied())
            .collect()
    }

    #[test]
    fn mirrored_fp32_all_reduce_is_bit_transparent() {
        // Two replicas with identical state: mean of (x, x) at fp32 is x
        // exactly, so the exchange must be invisible bit-for-bit.
        let want = flat(&demo_state(0.0));
        let (losses, states) = run_replicas(2, FormatSpec::Fp32, |_rank, ex| {
            let mut st = demo_state(0.0);
            let loss = ex.all_reduce_state(&mut st, 0.625)?;
            Ok((loss, flat(&st)))
        })
        .unwrap();
        assert_eq!(losses, 0.625);
        assert_eq!(states, want, "mirrored fp32 exchange must be bit-transparent");
    }

    #[test]
    fn fp32_mean_is_exact_and_identical_on_every_rank() {
        // Ranks hold different states; both must converge to the same
        // exact (a + b) / 2.
        let a = demo_state(0.0);
        let b = demo_state(1.0);
        let want: Vec<f32> =
            flat(&a).iter().zip(flat(&b).iter()).map(|(x, y)| (x + y) / 2.0).collect();
        let ex = Exchange::new(FormatSpec::Fp32, 2).unwrap();
        let got: Vec<Vec<f32>> = std::thread::scope(|s| {
            let joins: Vec<_> = [a, b]
                .into_iter()
                .enumerate()
                .map(|(rank, mut st)| {
                    let h = ex.handle(rank).unwrap();
                    s.spawn(move || {
                        let loss = h.all_reduce_state(&mut st, rank as f32).unwrap();
                        assert_eq!(loss, 0.5, "losses average in fp32");
                        flat(&st)
                    })
                })
                .collect();
            joins.into_iter().map(|j| j.join().unwrap()).collect()
        });
        assert_eq!(got[0], want);
        assert_eq!(got[1], want, "all ranks must hold the identical reduced state");
        assert_eq!(ex.rounds(), 1);
    }

    #[test]
    fn quantized_comms_matches_the_dequant_reduce_requant_oracle() {
        // Replays the exact pipeline by hand for a stochastic format:
        // rank-salted encode, dense mean, salt-0 requantize.
        let spec = FormatSpec::fixed_sr(8);
        let states = [demo_state(0.0), demo_state(1.0)];
        let step = states[0].step;
        let mut want: Vec<Vec<f32>> = Vec::new();
        for (g, _) in ["p", "m", "v"].iter().enumerate() {
            let nparams = states[0].params.len();
            for i in 0..nparams {
                let pick = |st: &ModelState| match g {
                    0 => st.params[i].clone(),
                    1 => st.m[i].clone(),
                    _ => st.v[i].clone(),
                };
                let t0 = pick(&states[0]);
                let inner = tensor_inner(&t0.shape);
                let mut sum = vec![0f32; t0.len()];
                for (rank, st) in states.iter().enumerate() {
                    let t = pick(st);
                    let enc = spec.encode_stream_salted(
                        t.as_f32().unwrap(),
                        &t.shape,
                        inner,
                        step,
                        stash_stream(g, i),
                        rank as u64,
                    );
                    for (s, d) in sum.iter_mut().zip(enc.decode()) {
                        *s += d;
                    }
                }
                for v in sum.iter_mut() {
                    *v /= 2.0;
                }
                spec.quantize_into_stream(&mut sum, inner, step, stash_stream(g, i));
                want.push(sum);
            }
        }
        let want: Vec<f32> = want.into_iter().flatten().collect();
        let got = run_replicas(2, spec, |rank, ex| {
            let mut st = demo_state(rank as f32);
            ex.all_reduce_state(&mut st, 0.0)?;
            Ok(flat(&st))
        })
        .unwrap();
        assert_eq!(got, want, "all_reduce_state must equal the explicit pipeline");
    }

    #[test]
    fn injected_failure_unblocks_a_waiting_peer_with_an_error() {
        // Satellite bugfix: a dead replica must never strand peers on
        // the barrier. Rank 0 blocks (rank 1 never posts); the injected
        // failure must surface as an Error, not a hang.
        let ex = Exchange::new(FormatSpec::Fp32, 2).unwrap();
        let h0 = ex.handle(0).unwrap();
        let exf = ex.clone();
        let err = std::thread::scope(|s| {
            let j = s.spawn(move || h0.all_reduce_bytes(vec![1, 2, 3]).map(|_| ()));
            // Give rank 0 time to reach the wait, then kill the exchange
            // the way the orchestrator does when a worker errors.
            std::thread::sleep(std::time::Duration::from_millis(30));
            exf.fail("replica 1 failed: injected I/O error");
            j.join().unwrap().unwrap_err()
        });
        let msg = err.to_string();
        assert!(
            msg.contains("replica exchange aborted") && msg.contains("injected I/O error"),
            "barrier must report the teardown loudly: {msg}"
        );
        // Late arrivals see the same loud error immediately.
        let h1 = ex.handle(1).unwrap();
        assert!(h1.all_reduce_bytes(vec![9]).is_err(), "post-failure calls must error");
    }

    #[test]
    fn run_replicas_propagates_a_mid_run_worker_failure() {
        // Rank 1 dies before its first barrier; rank 0 is already
        // blocked in all_reduce_state. The run must end (no deadlock)
        // with the originating error, not the secondary barrier abort.
        let err = run_replicas(2, FormatSpec::Fp32, |rank, ex| {
            let mut st = demo_state(0.0);
            if rank == 1 {
                return Err(Error::Io(std::io::Error::new(
                    std::io::ErrorKind::Other,
                    "disk gone",
                )));
            }
            ex.all_reduce_state(&mut st, 0.0)?;
            Ok(())
        })
        .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("disk gone"), "originating failure must win: {msg}");
    }

    #[test]
    fn run_replicas_surfaces_a_panicking_worker() {
        let err = run_replicas(2, FormatSpec::Fp32, |rank, ex| {
            let mut st = demo_state(0.0);
            if rank == 1 {
                panic!("synthetic panic");
            }
            ex.all_reduce_state(&mut st, 0.0)?;
            Ok(())
        })
        .unwrap_err();
        assert!(err.to_string().contains("panicked"), "{err}");
    }

    #[test]
    fn single_replica_exchange_is_a_strict_noop() {
        let ex = Exchange::new(FormatSpec::fixed_sr(4), 1).unwrap();
        let h = ex.handle(0).unwrap();
        let mut st = demo_state(0.0);
        let before = flat(&st);
        let loss = h.all_reduce_state(&mut st, 2.5).unwrap();
        assert_eq!(loss, 2.5);
        assert_eq!(flat(&st), before, "n=1 must not touch the state");
        let t = ex.traffic_report();
        assert_eq!(t.meter.comms_tx_bytes, 0, "n=1 must meter no comms traffic");
    }

    #[test]
    fn comms_meter_agrees_with_the_model_across_the_registry() {
        // The audit_observed_traffic-style sweep, per registered format.
        for spec in registered_specs(&[2u32, 4, 8, 16]) {
            audit_observed_comms(&spec).unwrap();
        }
    }

    #[test]
    fn rank_salted_wire_frames_decorrelate_for_sr_formats() {
        // The replica-correlation bugfix, observed at the wire level:
        // two ranks encoding the *same* state with an SR comms spec must
        // post different payloads.
        let spec = FormatSpec::fixed_sr(6);
        let frames = run_replicas(2, spec, |_rank, ex| {
            let st = demo_state(0.0);
            let t = &st.params[0];
            let inner = tensor_inner(&t.shape);
            let p = spec.encode_stream_salted(
                t.as_f32().unwrap(),
                &t.shape,
                inner,
                st.step,
                stash_stream(0, 0),
                ex.rank() as u64,
            );
            let all = ex.all_reduce_bytes(p.payload().to_vec())?;
            Ok(all.iter().map(|b| b.as_ref().clone()).collect::<Vec<Vec<u8>>>())
        })
        .unwrap();
        assert_ne!(frames[0], frames[1], "rank salt must decorrelate the SR wire bytes");
    }

    #[test]
    fn exchange_rejects_bad_config() {
        assert!(Exchange::new(FormatSpec::Fp32, 0).is_err());
        let ex = Exchange::new(FormatSpec::Fp32, 2).unwrap();
        assert!(ex.handle(2).is_err(), "rank must be < replicas");
    }
}
