//! The tiered stash store: every packed tensor the coordinator holds
//! between the step that produces it and the step that consumes it is
//! *owned* here — budgeted, spillable, and byte-accurately metered.
//!
//! PR 2's codec made stash bytes physically real; this module makes
//! them *accountable*. A [`StashStore`] manages the model state's
//! packed tensors across two tiers:
//!
//! * **resident** — [`PackedTensor`] payloads in host memory (the
//!   DRAM-scale bytes the paper's 2.55× claim is about);
//! * **spill** — a per-run segment file under the store's directory,
//!   one seekable [`PackedTensor::write_into`] record per tensor (the
//!   v2 packed-record layout, so every record — and through BFP's
//!   per-box byte alignment, every box — stays independently
//!   addressable). Spilling moves bytes out of DRAM without touching
//!   their values: spill→readback is the identity on the payload.
//!
//! A byte budget ([`StashBudget`], CLI `--stash-budget`) caps the
//! resident tier: when packed bytes exceed it, the coldest slots (LRU
//! by the step of last touch, ties broken by slot order) spill to the
//! segment file. Before the next dispatch a readback prefetcher
//! ([`StashStore::start_prefetch`]) pulls spilled records back on a
//! background thread — overlapping disk reads with the batch-generator
//! wait, so the PJRT boundary never blocks on a cold read. The budget
//! is a *residency* policy, never a numerics policy: a budgeted run's
//! loss trajectory is bit-identical to the unbudgeted run's
//! (property-tested in `tests/stash_spill.rs`, e2e-tested in
//! `tests/coordinator_e2e.rs`).
//!
//! Every byte crossing a tier is counted by the [`TrafficMeter`]:
//! stash writes/reads (packed payload bytes entering/leaving the
//! resident tier around a step), spill writes/readbacks (full record
//! bytes to/from disk), and checkpoint I/O. Alongside the observed
//! bytes the meter accumulates the *modeled* bits
//! (`FormatSpec::container_bits() × elements`, the cost model's number
//! for the same events) plus the box-metadata allowance, so every run
//! can print — and the tests can assert — modeled-vs-observed DRAM
//! agreement the same way `audit_storage` pins `storage_bits()`
//! against `packed_len()`.
//!
//! The spill tier uses plain positioned file I/O rather than a literal
//! `mmap(2)` (a real mapping needs a platform crate this build
//! intentionally avoids); the segment layout is mmap-ready — fixed
//! offsets, self-describing records — so swapping the read path for a
//! mapping is a local change. Checkpoints stream spilled records
//! straight from the segment file ([`SpillHandle::read_record`])
//! without rehydrating them into DRAM.
//!
//! The store also writes a small JSON index (`stash.json`) into its
//! directory after every stash pass — per-slot tier/bytes/last-touch
//! plus the meter — which is what the `dsq stash <dir>` inspector
//! prints.
//!
//! Since PR 7 the v2 packed-record layout is also a *wire* format: the
//! [`exchange`] submodule runs an all-reduce between N replica
//! sessions, posting whole states as frames of packed records and
//! metering the exchanged bytes on the meter's `comms_*` channels
//! (tx = own encoded payloads, rx = peer payloads decoded) — the
//! interconnect-scale mirror of the DRAM-scale stash channels above,
//! judged against the same `container_bits()`-modeled number via
//! [`CommsTraffic`]. Since the multi-process refactor that exchange is
//! layered: [`wire`] owns the versioned `DSQWIRE1` frame envelope,
//! [`transport`] owns movement ([`MemTransport`]'s in-memory ring —
//! the default, bit-identical to PR 7 — and [`SocketTransport`]'s
//! multi-process Unix/TCP path behind `--transport socket:<addr>`),
//! and [`exchange`] keeps only the transport-agnostic collective. See
//! the `exchange` module docs for the round protocol, the replica
//! SR-seeding contract, and the failure-teardown semantics.

use std::collections::HashMap;
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use crate::model::ModelState;
use crate::quant::{stash_stream, FormatSpec, PackedTensor};
use crate::runtime::{HostTensor, TensorData};
use crate::util::json::Json;
use crate::{Error, Result};

pub mod exchange;
pub mod transport;
pub mod wire;

pub use exchange::{
    audit_observed_comms, measure_comms_round, measure_state_comms, run_replicas, CommsTraffic,
    Exchange, ExchangeCounters, ReplicaExchange, ReplicaShard,
};
pub use transport::{
    MemTransport, SocketHub, SocketTransport, Transport, TransportSpec, ABORT_PREFIX,
    TRANSPORT_GRAMMAR,
};
pub use wire::WireFrame;

/// Grammar of `--stash-budget` values, quoted by every parse error.
pub const BUDGET_GRAMMAR: &str = "<bytes> | <n>k[i]b | <n>m[i]b | <n>g[i]b | unlimited";

/// Resident-tier byte budget for a [`StashStore`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum StashBudget {
    /// No cap: everything stays resident (the spill tier never engages).
    #[default]
    Unlimited,
    /// Cap resident packed bytes; the overflow spills coldest-first.
    /// `Bytes(0)` spills every slot every step.
    Bytes(u64),
}

impl StashBudget {
    /// Parse a budget spec: a raw byte count (`"65536"`, `"0"`), a
    /// suffixed size (`"256k"`, `"4mb"`, `"1gib"` — 1024-based), or
    /// `"unlimited"`/`"none"`. Errors name the offending token and
    /// quote the [`BUDGET_GRAMMAR`].
    pub fn parse(s: &str) -> Result<StashBudget> {
        let t = s.trim().to_ascii_lowercase();
        if t.is_empty() {
            return Err(Error::Config(format!(
                "empty stash budget (expected: {BUDGET_GRAMMAR})"
            )));
        }
        if matches!(t.as_str(), "unlimited" | "none" | "inf") {
            return Ok(StashBudget::Unlimited);
        }
        let digits_end = t.find(|c: char| !c.is_ascii_digit()).unwrap_or(t.len());
        let (digits, suffix) = t.split_at(digits_end);
        if digits.is_empty() {
            return Err(Error::Config(format!(
                "bad stash budget '{s}': '{t}' does not start with a byte count \
                 (expected: {BUDGET_GRAMMAR})"
            )));
        }
        let n: u64 = digits.parse().map_err(|_| {
            Error::Config(format!(
                "bad stash budget '{s}': byte count '{digits}' does not fit u64 \
                 (expected: {BUDGET_GRAMMAR})"
            ))
        })?;
        let mult: u64 = match suffix {
            "" | "b" => 1,
            "k" | "kb" | "kib" => 1 << 10,
            "m" | "mb" | "mib" => 1 << 20,
            "g" | "gb" | "gib" => 1 << 30,
            other => {
                return Err(Error::Config(format!(
                    "bad stash budget '{s}': unknown size suffix '{other}' \
                     (expected: {BUDGET_GRAMMAR})"
                )))
            }
        };
        let bytes = n.checked_mul(mult).ok_or_else(|| {
            Error::Config(format!(
                "bad stash budget '{s}': {n}{suffix} overflows u64 bytes"
            ))
        })?;
        Ok(StashBudget::Bytes(bytes))
    }

    /// True when `bytes` fits under the budget.
    pub fn allows(&self, bytes: u64) -> bool {
        match *self {
            StashBudget::Unlimited => true,
            StashBudget::Bytes(b) => bytes <= b,
        }
    }
}

impl std::fmt::Display for StashBudget {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            StashBudget::Unlimited => f.write_str("unlimited"),
            StashBudget::Bytes(b) => f.write_str(&fmt_bytes(b)),
        }
    }
}

/// Humanized byte count (1024-based).
pub fn fmt_bytes(b: u64) -> String {
    const K: f64 = 1024.0;
    let bf = b as f64;
    if bf >= K * K * K {
        format!("{:.2} GiB", bf / (K * K * K))
    } else if bf >= K * K {
        format!("{:.2} MiB", bf / (K * K))
    } else if bf >= K {
        format!("{:.2} KiB", bf / K)
    } else {
        format!("{b} B")
    }
}

/// Byte-accurate traffic counters for one store (all monotone).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TrafficMeter {
    /// Packed payload bytes written into the resident tier (the stash
    /// write of each step: dense step outputs re-encoded to packed).
    pub stash_write_bytes: u64,
    /// Packed payload bytes read out of the resident tier for dispatch
    /// (the stash read: decode at the PJRT boundary).
    pub stash_read_bytes: u64,
    /// Record bytes appended to the spill segment file.
    pub spill_write_bytes: u64,
    /// Record bytes read back from the spill segment file.
    pub spill_read_bytes: u64,
    /// Checkpoint bytes written through/around the store.
    pub checkpoint_bytes: u64,
    /// The cost model's counterpart of the stash write+read events:
    /// `container_bits() × elements` summed over the same tensors the
    /// observed counters saw.
    pub modeled_stash_bits: f64,
    /// Packed payload bytes this replica encoded onto the exchange wire
    /// (its own all-reduce contribution each round).
    pub comms_tx_bytes: u64,
    /// Packed payload bytes decoded off the wire from *peer* replicas.
    pub comms_rx_bytes: u64,
    /// Whole frame bytes posted to the ring (records + loss word) —
    /// the wire-level counterpart of the spill tier's record bytes.
    pub comms_frame_bytes: u64,
    /// The cost model's counterpart of the comms tx+rx events.
    pub modeled_comms_bits: f64,
}

impl TrafficMeter {
    /// Observed DRAM-scale stash traffic in bits (write + read).
    pub fn observed_stash_bits(&self) -> f64 {
        (self.stash_write_bytes + self.stash_read_bytes) as f64 * 8.0
    }

    /// Observed interconnect-scale comms traffic in bits (tx + rx).
    pub fn observed_comms_bits(&self) -> f64 {
        (self.comms_tx_bytes + self.comms_rx_bytes) as f64 * 8.0
    }

    /// True when the spill tier carried any traffic.
    pub fn spilled(&self) -> bool {
        self.spill_write_bytes > 0 || self.spill_read_bytes > 0
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("stash_write_bytes", Json::num(self.stash_write_bytes as f64)),
            ("stash_read_bytes", Json::num(self.stash_read_bytes as f64)),
            ("spill_write_bytes", Json::num(self.spill_write_bytes as f64)),
            ("spill_read_bytes", Json::num(self.spill_read_bytes as f64)),
            ("checkpoint_bytes", Json::num(self.checkpoint_bytes as f64)),
            ("modeled_stash_bits", Json::num(self.modeled_stash_bits)),
            ("observed_stash_bits", Json::num(self.observed_stash_bits())),
            ("comms_tx_bytes", Json::num(self.comms_tx_bytes as f64)),
            ("comms_rx_bytes", Json::num(self.comms_rx_bytes as f64)),
            ("comms_frame_bytes", Json::num(self.comms_frame_bytes as f64)),
            ("modeled_comms_bits", Json::num(self.modeled_comms_bits)),
            ("observed_comms_bits", Json::num(self.observed_comms_bits())),
        ])
    }
}

/// A run's stash-traffic report: the meter plus everything needed to
/// judge modeled-vs-observed agreement. Carried on `RunReport::stash`.
#[derive(Clone, Debug, PartialEq)]
pub struct StashTraffic {
    pub spec: FormatSpec,
    pub budget: StashBudget,
    pub meter: TrafficMeter,
    /// Box-metadata slack accumulated over the metered events (the same
    /// per-tensor allowance `FormatSpec::audit_storage` grants).
    pub allowance_bits: f64,
}

impl StashTraffic {
    /// Modeled-vs-observed gap in bits.
    pub fn gap_bits(&self) -> f64 {
        (self.meter.observed_stash_bits() - self.meter.modeled_stash_bits).abs()
    }

    /// True when the observed stash bytes agree with the cost model
    /// within box-metadata slack — the run-level `audit_storage`.
    pub fn agrees(&self) -> bool {
        self.gap_bits() <= self.allowance_bits
    }

    /// The modeled-vs-observed line every stashed run prints.
    pub fn summary(&self) -> String {
        let m = &self.meter;
        let modeled = m.modeled_stash_bits;
        let observed = m.observed_stash_bits();
        let gap_pct = if modeled > 0.0 { self.gap_bits() / modeled * 100.0 } else { 0.0 };
        format!(
            "stash ({}, budget {}): DRAM modeled {:.3} Mbit observed {:.3} Mbit \
             (gap {:.2}%); spill wrote {} read {}; checkpoints {}",
            self.spec,
            self.budget,
            modeled / 1e6,
            observed / 1e6,
            gap_pct,
            fmt_bytes(m.spill_write_bytes),
            fmt_bytes(m.spill_read_bytes),
            fmt_bytes(m.checkpoint_bytes),
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("spec", Json::str(&self.spec.spec_string())),
            ("budget", Json::str(&self.budget.to_string())),
            ("traffic", self.meter.to_json()),
            ("allowance_bits", Json::num(self.allowance_bits)),
            ("agrees", Json::Bool(self.agrees())),
        ])
    }
}

/// Handle to a spilled tensor's record inside a segment file. Lives in
/// `TensorData::Spilled`, so a spilled slot keeps its shape/spec
/// identity (and validates against the manifest) while its payload is
/// on disk. Reading it back requires either the owning [`StashStore`]
/// (metered) or, for checkpoint streaming, [`SpillHandle::read_record`]
/// directly.
#[derive(Clone, Debug, PartialEq)]
pub struct SpillHandle {
    /// Segment file holding the record.
    pub path: Arc<PathBuf>,
    /// Byte offset of the record inside the segment.
    pub offset: u64,
    /// Full record length (header + payload).
    pub record_len: usize,
    /// Payload bytes (what the resident tier would occupy).
    pub payload_len: usize,
    /// Format the payload is packed in.
    pub spec: FormatSpec,
}

impl SpillHandle {
    /// Raw record bytes — exactly what [`PackedTensor::write_into`]
    /// produced, so checkpoints can stream a spilled tensor to disk
    /// byte-for-byte without rehydrating it.
    pub fn read_record(&self) -> Result<Vec<u8>> {
        crate::util::ordwitness::assert_lock_free("stash spill readback");
        let mut f = File::open(self.path.as_path())?;
        f.seek(SeekFrom::Start(self.offset))?;
        let mut buf = vec![0u8; self.record_len];
        f.read_exact(&mut buf)?;
        Ok(buf)
    }

    /// Read and decode the record back into a [`PackedTensor`]
    /// (validated by the record reader).
    pub fn read_tensor(&self) -> Result<PackedTensor> {
        PackedTensor::read_from(&mut self.read_record()?.as_slice())
    }
}

/// Append-only segment file of packed-tensor records.
struct SpillFile {
    path: Arc<PathBuf>,
    file: File,
    cursor: u64,
}

impl SpillFile {
    fn create(path: PathBuf) -> Result<SpillFile> {
        let file =
            OpenOptions::new().read(true).write(true).create(true).truncate(true).open(&path)?;
        Ok(SpillFile { path: Arc::new(path), file, cursor: 0 })
    }

    /// Append one record; returns the handle addressing it.
    fn append(&mut self, p: &PackedTensor) -> Result<SpillHandle> {
        crate::util::ordwitness::assert_lock_free("stash spill append");
        let mut buf = Vec::with_capacity(p.record_len());
        p.write_into(&mut buf)?;
        self.file.seek(SeekFrom::Start(self.cursor))?;
        self.file.write_all(&buf)?;
        let h = SpillHandle {
            path: self.path.clone(),
            offset: self.cursor,
            record_len: buf.len(),
            payload_len: p.packed_len(),
            spec: p.spec(),
        };
        self.cursor += buf.len() as u64;
        Ok(h)
    }

    /// Rewind the write cursor. Only legal when no live handle
    /// references the file (the store checks) — keeps an all-spill run's
    /// segment at one step's working set instead of growing per step.
    fn rewind(&mut self) {
        self.cursor = 0;
    }
}

/// Per-slot bookkeeping (tensors themselves live in the `ModelState`).
struct SlotMeta {
    label: String,
    /// Step of last touch (the LRU key).
    last_touch: u64,
}

/// Store configuration.
#[derive(Clone, Debug)]
pub struct StashStoreConfig {
    /// Format every stashed tensor is packed in.
    pub spec: FormatSpec,
    /// Resident-tier byte cap.
    pub budget: StashBudget,
    /// Run directory for the spill segment + `stash.json` index.
    pub dir: PathBuf,
}

/// Sequence counter for default (per-run temp) store directories.
static STORE_SEQ: AtomicU64 = AtomicU64::new(0);

/// What the readback prefetcher thread returns: (slot id, tensor)
/// pairs, or an error string (errors cross the thread as strings so
/// the handle type stays `Send` without constraining `Error`).
type PrefetchResult = std::result::Result<Vec<(usize, PackedTensor)>, String>;

/// Cumulative time the store has spent in each internal phase
/// (nanoseconds since construction). Read via
/// [`StashStore::phase_ns`] by the session's span recorder, which
/// turns the per-step deltas into `quantize` / `spill_write` /
/// `spill_read` sub-phase spans — the store stays ignorant of
/// [`crate::obs`], it only keeps the clocks.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StashPhaseNs {
    /// Packing state into the store's format ([`StashStore::stash_state`]'s
    /// re-encode loop).
    pub quantize_ns: u64,
    /// Spilling over-budget slots to the segment file.
    pub spill_write_ns: u64,
    /// Reading spilled slots back (prefetch join + synchronous reads).
    pub spill_read_ns: u64,
}

/// The tiered stash store (see the module docs).
pub struct StashStore {
    spec: FormatSpec,
    budget: StashBudget,
    dir: PathBuf,
    /// True when `dir` is a generated temp dir the store may delete.
    ephemeral: bool,
    spill: Option<SpillFile>,
    meter: TrafficMeter,
    allowance_bits: f64,
    slots: Vec<SlotMeta>,
    /// In-flight readback.
    prefetch: Option<JoinHandle<PrefetchResult>>,
    /// Per-phase wall-clock totals (see [`StashPhaseNs`]).
    phase: StashPhaseNs,
}

const INDEX_FILE: &str = "stash.json";
const SEGMENT_FILE: &str = "stash.seg";

fn slot_count(state: &ModelState) -> usize {
    3 * state.params.len()
}

fn group_of(state: &ModelState, g: usize) -> &[HostTensor] {
    match g {
        0 => &state.params,
        1 => &state.m,
        _ => &state.v,
    }
}

fn tensor_of(state: &ModelState, n: usize, id: usize) -> &HostTensor {
    let (g, i) = (id / n, id % n);
    &group_of(state, g)[i]
}

fn tensor_mut(state: &mut ModelState, n: usize, id: usize) -> &mut HostTensor {
    let (g, i) = (id / n, id % n);
    match g {
        0 => &mut state.params[i],
        1 => &mut state.m[i],
        _ => &mut state.v[i],
    }
}

impl StashStore {
    pub fn new(cfg: StashStoreConfig) -> Result<StashStore> {
        Self::with_ephemeral(cfg, false)
    }

    fn with_ephemeral(cfg: StashStoreConfig, ephemeral: bool) -> Result<StashStore> {
        std::fs::create_dir_all(&cfg.dir)?;
        Ok(StashStore {
            spec: cfg.spec,
            budget: cfg.budget,
            dir: cfg.dir,
            ephemeral,
            spill: None,
            meter: TrafficMeter::default(),
            allowance_bits: 0.0,
            slots: Vec::new(),
            prefetch: None,
            phase: StashPhaseNs::default(),
        })
    }

    /// A store in a fresh per-run temp directory (removed on drop).
    pub fn ephemeral(spec: FormatSpec, budget: StashBudget) -> Result<StashStore> {
        let dir = std::env::temp_dir().join(format!(
            "dsq-stash-{}-{}",
            std::process::id(),
            STORE_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        Self::with_ephemeral(StashStoreConfig { spec, budget, dir }, true)
    }

    pub fn spec(&self) -> FormatSpec {
        self.spec
    }

    pub fn budget(&self) -> StashBudget {
        self.budget
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Snapshot of the traffic counters.
    pub fn traffic(&self) -> TrafficMeter {
        self.meter
    }

    /// Snapshot of the cumulative per-phase clocks (see
    /// [`StashPhaseNs`]).
    pub fn phase_ns(&self) -> StashPhaseNs {
        self.phase
    }

    /// The run-level traffic report (for `RunReport::stash`).
    pub fn traffic_report(&self) -> StashTraffic {
        StashTraffic {
            spec: self.spec,
            budget: self.budget,
            meter: self.meter,
            allowance_bits: self.allowance_bits,
        }
    }

    /// Human labels for the slot table (`params/<name>` etc.); sized
    /// lazily from the first state otherwise.
    pub fn set_param_names(&mut self, names: &[&str]) {
        self.slots = ["params", "m", "v"]
            .iter()
            .flat_map(|g| {
                names.iter().map(move |n| SlotMeta { label: format!("{g}/{n}"), last_touch: 0 })
            })
            .collect();
    }

    fn ensure_slots(&mut self, state: &ModelState) {
        let want = slot_count(state);
        if self.slots.len() != want {
            self.slots = (0..want)
                .map(|id| {
                    let (g, i) = (id / state.params.len(), id % state.params.len());
                    SlotMeta {
                        label: format!("{}/{}", ["params", "m", "v"][g], i),
                        last_touch: 0,
                    }
                })
                .collect();
        }
    }

    /// Count one packed tensor crossing the resident tier, in both
    /// currencies: observed payload bytes and modeled container bits
    /// (plus the audit allowance for the gap between them).
    fn note_event(&mut self, p: &PackedTensor, write: bool) {
        let bytes = p.packed_len() as u64;
        if write {
            self.meter.stash_write_bytes += bytes;
        } else {
            self.meter.stash_read_bytes += bytes;
        }
        self.meter.modeled_stash_bits += self.spec.container_bits() * p.len() as f64;
        self.allowance_bits += self.spec.storage_allowance_bits(p.len(), p.inner());
    }

    /// Stash the state after a step: pack every dense tensor into the
    /// store's format (metering the writes), touch the LRU clock, then
    /// enforce the budget by spilling the coldest resident slots. The
    /// `(step, stream)` scheme matches `ModelState::pack_state`, so a
    /// store-managed state packs bit-identically to the pre-store path.
    pub fn stash_state(&mut self, state: &mut ModelState) -> Result<()> {
        self.ensure_slots(state);
        self.join_prefetch()?; // a stale prefetch must not race the spill file
        let step = state.step;
        let n = state.params.len();
        // If nothing currently lives in the segment file, every record
        // in it is garbage from overwritten steps — reuse the space.
        let any_spilled = (0..slot_count(state))
            .any(|id| matches!(tensor_of(state, n, id).data, TensorData::Spilled(_)));
        if !any_spilled {
            if let Some(f) = &mut self.spill {
                f.rewind();
            }
        }
        let t_pack = Instant::now();
        for g in 0..3 {
            for i in 0..n {
                let id = g * n + i;
                // Dense tensors (and tensors packed in a foreign format)
                // get re-encoded into the store's format — a stash
                // write. Slots already at rest in the store's format
                // (resident or spilled) cross no tier.
                let needs_pack = match &tensor_of(state, n, id).data {
                    TensorData::F32(_) => true,
                    TensorData::Packed(p) => p.spec() != self.spec,
                    // A spilled slot in the store's format is at rest; a
                    // foreign-format handle cannot be repacked from disk
                    // — fail loudly like every other un-fetched read.
                    TensorData::Spilled(h) if h.spec == self.spec => false,
                    TensorData::Spilled(h) => {
                        return Err(Error::Shape(format!(
                            "slot is spilled in {} but this store packs {}: fetch it \
                             before re-stashing",
                            h.spec, self.spec
                        )))
                    }
                    TensorData::I32(_) => {
                        return Err(Error::Shape(
                            "stash store cannot hold an i32 tensor".into(),
                        ))
                    }
                };
                if needs_pack {
                    let t = tensor_mut(state, n, id);
                    let packed = t.pack_stream(&self.spec, step, stash_stream(g, i))?;
                    if let TensorData::Packed(p) = &packed.data {
                        self.note_event(p, true);
                    }
                    *t = packed;
                }
                self.slots[id].last_touch = step;
            }
        }
        self.phase.quantize_ns += t_pack.elapsed().as_nanos() as u64;
        let t_spill = Instant::now();
        self.enforce_budget(state)?;
        self.phase.spill_write_ns += t_spill.elapsed().as_nanos() as u64;
        self.write_index(state)?;
        Ok(())
    }

    /// Resident packed payload bytes of the state.
    pub fn resident_bytes(state: &ModelState) -> u64 {
        (0..3)
            .flat_map(|g| group_of(state, g))
            .map(|t| match &t.data {
                TensorData::Packed(p) => p.packed_len() as u64,
                _ => 0,
            })
            .sum()
    }

    /// Spilled payload bytes (on disk) of the state.
    pub fn spilled_bytes(state: &ModelState) -> u64 {
        (0..3)
            .flat_map(|g| group_of(state, g))
            .map(|t| match &t.data {
                TensorData::Spilled(h) => h.payload_len as u64,
                _ => 0,
            })
            .sum()
    }

    /// Spill coldest-first until the resident tier fits the budget.
    fn enforce_budget(&mut self, state: &mut ModelState) -> Result<()> {
        let StashBudget::Bytes(budget) = self.budget else { return Ok(()) };
        let n = state.params.len();
        while Self::resident_bytes(state) > budget {
            // Coldest resident slot: min (last_touch, id).
            let victim = (0..slot_count(state))
                .filter(|&id| matches!(tensor_of(state, n, id).data, TensorData::Packed(_)))
                .min_by_key(|&id| (self.slots[id].last_touch, id));
            let Some(id) = victim else { break };
            if self.spill.is_none() {
                self.spill = Some(SpillFile::create(self.dir.join(SEGMENT_FILE))?);
            }
            let Some(file) = self.spill.as_mut() else {
                return Err(Error::Config(
                    "stash spill segment unavailable right after creation".into(),
                ));
            };
            let t = tensor_mut(state, n, id);
            let TensorData::Packed(p) = &t.data else {
                return Err(Error::Config(format!(
                    "stash budget victim slot {id} is not resident — \
                     store index and model state are out of sync"
                )));
            };
            let handle = file.append(p)?;
            self.meter.spill_write_bytes += handle.record_len as u64;
            let shape = t.shape.clone();
            *t = HostTensor::spilled(shape, handle);
        }
        Ok(())
    }

    /// Bring every spilled slot back to the resident tier (draining the
    /// prefetch thread first, falling back to synchronous reads), so the
    /// next dispatch sees a fully materialized state. Metered as spill
    /// readback; values are bit-identical to what was spilled.
    pub fn fetch_state(&mut self, state: &mut ModelState) -> Result<()> {
        let t0 = Instant::now();
        let mut did_work = false;
        let mut ready: HashMap<usize, PackedTensor> = HashMap::new();
        if let Some(h) = self.prefetch.take() {
            crate::util::ordwitness::assert_lock_free("joining the stash prefetcher");
            let got = h
                .join()
                .map_err(|_| Error::Config("stash prefetch thread panicked".into()))?
                .map_err(Error::Config)?;
            ready.extend(got);
            did_work = true;
        }
        let n = state.params.len();
        for id in 0..slot_count(state) {
            let t = tensor_mut(state, n, id);
            let TensorData::Spilled(h) = &t.data else { continue };
            let record_len = h.record_len as u64;
            let p = match ready.remove(&id) {
                Some(p) => p,
                None => h.read_tensor()?,
            };
            self.meter.spill_read_bytes += record_len;
            *t = HostTensor::packed(p);
            did_work = true;
        }
        // No-op calls (every step of an unbudgeted run) stay off the
        // clock, so `spill_read_ns` only accumulates real readback work.
        if did_work {
            self.phase.spill_read_ns += t0.elapsed().as_nanos() as u64;
        }
        Ok(())
    }

    /// Meter the packed bytes about to cross the PJRT boundary as step
    /// inputs (the stash *read* of the write/read cycle). Call after
    /// [`StashStore::fetch_state`], before dispatch.
    pub fn note_dispatch_read(&mut self, state: &ModelState) {
        for g in 0..3 {
            for t in group_of(state, g) {
                if let TensorData::Packed(p) = &t.data {
                    self.note_event(p, false);
                }
            }
        }
    }

    /// Account checkpoint bytes written for this run.
    pub fn note_checkpoint_bytes(&mut self, bytes: u64) {
        self.meter.checkpoint_bytes += bytes;
    }

    /// Kick off the readback prefetcher for the state's spilled slots
    /// on a background thread (no-op when nothing is spilled). The next
    /// [`StashStore::fetch_state`] drains it, so the disk reads overlap
    /// the batch-generator wait instead of stalling dispatch.
    pub fn start_prefetch(&mut self, state: &ModelState) {
        if self.prefetch.is_some() {
            return; // previous prefetch not yet drained
        }
        let n = state.params.len();
        let handles: Vec<(usize, SpillHandle)> = (0..slot_count(state))
            .filter_map(|id| {
                let (g, i) = (id / n, id % n);
                match &group_of(state, g)[i].data {
                    TensorData::Spilled(h) => Some((id, h.clone())),
                    _ => None,
                }
            })
            .collect();
        if handles.is_empty() {
            return;
        }
        self.prefetch = Some(std::thread::spawn(move || {
            handles
                .into_iter()
                .map(|(id, h)| h.read_tensor().map(|p| (id, p)).map_err(|e| e.to_string()))
                .collect()
        }));
    }

    fn join_prefetch(&mut self) -> Result<()> {
        if let Some(h) = self.prefetch.take() {
            crate::util::ordwitness::assert_lock_free("joining the stash prefetcher");
            h.join()
                .map_err(|_| Error::Config("stash prefetch thread panicked".into()))?
                .map_err(Error::Config)?;
        }
        Ok(())
    }

    /// Write the `stash.json` index: per-slot residency + the meter —
    /// what `dsq stash <dir>` prints.
    fn write_index(&self, state: &ModelState) -> Result<()> {
        crate::util::ordwitness::assert_lock_free("writing the stash index");
        let n = state.params.len();
        let slots = (0..slot_count(state)).map(|id| {
            let (g, i) = (id / n, id % n);
            let t = &group_of(state, g)[i];
            let (tier, bytes) = match &t.data {
                TensorData::Packed(p) => ("resident", p.packed_len()),
                TensorData::Spilled(h) => ("spilled", h.payload_len),
                TensorData::F32(v) => ("dense", v.len() * 4),
                TensorData::I32(v) => ("dense", v.len() * 4),
            };
            Json::obj(vec![
                ("slot", Json::str(&self.slots[id].label)),
                (
                    "shape",
                    Json::arr(t.shape.iter().map(|&d| Json::num(d as f64))),
                ),
                ("tier", Json::str(tier)),
                ("bytes", Json::num(bytes as f64)),
                ("last_touch", Json::num(self.slots[id].last_touch as f64)),
            ])
        });
        let idx = Json::obj(vec![
            ("spec", Json::str(&self.spec.spec_string())),
            ("budget", Json::str(&self.budget.to_string())),
            ("step", Json::num(state.step as f64)),
            ("resident_bytes", Json::num(Self::resident_bytes(state) as f64)),
            ("spilled_bytes", Json::num(Self::spilled_bytes(state) as f64)),
            ("slots", Json::arr(slots)),
            ("traffic", self.meter.to_json()),
        ]);
        std::fs::write(self.dir.join(INDEX_FILE), idx.to_string_pretty())?;
        Ok(())
    }
}

impl Drop for StashStore {
    fn drop(&mut self) {
        if let Some(h) = self.prefetch.take() {
            h.join().ok();
        }
        if self.ephemeral {
            std::fs::remove_dir_all(&self.dir).ok();
        }
    }
}

/// One synthetic stash round trip of `state` (a clone; the input is
/// untouched) through a fresh ephemeral store: pack + dispatch-read at
/// `spec`, returning the measured traffic. This is the "measured
/// column" the experiments report next to the modeled numbers.
pub fn measure_state_traffic(state: &ModelState, spec: &FormatSpec) -> Result<StashTraffic> {
    if state.is_spilled() {
        // unpack_state cannot materialize spilled payloads, so the
        // measurement would silently see no bytes — refuse instead.
        return Err(Error::Config(
            "cannot measure a spilled state: fetch it through its stash store first".into(),
        ));
    }
    let mut st = state.clone();
    let mut store = StashStore::ephemeral(*spec, StashBudget::Unlimited)?;
    // Force a real write even if the state is already packed in `spec`.
    st.unpack_state();
    store.stash_state(&mut st)?;
    store.note_dispatch_read(&st);
    Ok(store.traffic_report())
}

/// The `audit_storage` sibling for traffic: one synthetic step through
/// the store must report stash bytes equal to the codec's
/// `packed_len()` exactly, and agree with the cost model's
/// `container_bits()` within box-metadata slack. Shapes include a
/// ragged minor axis so the short-trailing-box paths are pinned too.
pub fn audit_observed_traffic(spec: &FormatSpec) -> std::result::Result<(), String> {
    let mk = |shape: &[usize], fill: f32| {
        let len: usize = shape.iter().product();
        HostTensor::f32(shape.to_vec(), (0..len).map(|i| (i as f32 - 7.0) * fill).collect())
    };
    // A ragged (21-wide) matrix, a vector, and a scalar.
    let params = vec![mk(&[3, 21], 0.37), mk(&[5], 1.25), HostTensor::f32(vec![], vec![2.5])];
    let zeros: Vec<HostTensor> = params.iter().map(HostTensor::zeros_like).collect();
    let mut state = ModelState { params, m: zeros.clone(), v: zeros, step: 1 };
    let expected: u64 = state
        .params
        .iter()
        .map(|t| {
            let inner = t.shape.last().copied().filter(|&d| d > 0).unwrap_or(1);
            3 * spec.observed_bytes(t.len(), inner) as u64 // params + m + v
        })
        .sum();
    let mut store =
        StashStore::ephemeral(*spec, StashBudget::Unlimited).map_err(|e| e.to_string())?;
    store.stash_state(&mut state).map_err(|e| e.to_string())?;
    store.note_dispatch_read(&state);
    let t = store.traffic_report();
    if t.meter.stash_write_bytes != expected {
        return Err(format!(
            "{spec}: store reported {} stash-write bytes, codec packs {expected}",
            t.meter.stash_write_bytes
        ));
    }
    if t.meter.stash_read_bytes != expected {
        return Err(format!(
            "{spec}: store reported {} stash-read bytes, codec packs {expected}",
            t.meter.stash_read_bytes
        ));
    }
    if !t.agrees() {
        return Err(format!(
            "{spec}: observed {} bits vs modeled {} bits; gap {} > allowance {}",
            t.meter.observed_stash_bits(),
            t.meter.modeled_stash_bits,
            t.gap_bits(),
            t.allowance_bits
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::registered_specs;

    fn state_of(tensors: Vec<HostTensor>) -> ModelState {
        let zeros: Vec<HostTensor> = tensors.iter().map(HostTensor::zeros_like).collect();
        ModelState { params: tensors, m: zeros.clone(), v: zeros, step: 1 }
    }

    fn demo_state() -> ModelState {
        state_of(vec![
            HostTensor::f32(vec![4, 16], (0..64).map(|x| x as f32 * 0.3 - 9.0).collect()),
            HostTensor::f32(vec![2, 21], (0..42).map(|x| (x as f32).sin() * 3.0).collect()),
        ])
    }

    #[test]
    fn budget_parse_accepts_the_grammar() {
        assert_eq!(StashBudget::parse("unlimited").unwrap(), StashBudget::Unlimited);
        assert_eq!(StashBudget::parse("none").unwrap(), StashBudget::Unlimited);
        assert_eq!(StashBudget::parse("0").unwrap(), StashBudget::Bytes(0));
        assert_eq!(StashBudget::parse("65536").unwrap(), StashBudget::Bytes(65536));
        assert_eq!(StashBudget::parse("64k").unwrap(), StashBudget::Bytes(64 << 10));
        assert_eq!(StashBudget::parse("64kb").unwrap(), StashBudget::Bytes(64 << 10));
        assert_eq!(StashBudget::parse("4MiB").unwrap(), StashBudget::Bytes(4 << 20));
        assert_eq!(StashBudget::parse(" 2g ").unwrap(), StashBudget::Bytes(2 << 30));
        assert_eq!(StashBudget::parse("100b").unwrap(), StashBudget::Bytes(100));
    }

    #[test]
    fn budget_parse_errors_name_the_token_and_the_grammar() {
        // The satellite contract: a bad spec must say *which token* broke
        // and list the valid forms, not fail bare.
        let err = |s: &str| match StashBudget::parse(s) {
            Err(Error::Config(m)) => m,
            other => panic!("'{s}' should be Error::Config, got {other:?}"),
        };
        let m = err("64x");
        assert!(m.contains("'x'"), "names the bad suffix: {m}");
        assert!(m.contains(BUDGET_GRAMMAR), "lists the grammar: {m}");
        let m = err("lots");
        assert!(m.contains("lots") && m.contains(BUDGET_GRAMMAR), "{m}");
        let m = err("");
        assert!(m.contains("empty") && m.contains(BUDGET_GRAMMAR), "{m}");
        let m = err("99999999999999999999999b");
        assert!(m.contains("u64"), "names the overflow: {m}");
        let m = err("k");
        assert!(m.contains("byte count"), "{m}");
        // Multiplied overflow is caught too.
        assert!(StashBudget::parse("99999999999g").is_err());
    }

    #[test]
    fn budget_display_and_allows() {
        assert_eq!(StashBudget::Unlimited.to_string(), "unlimited");
        assert_eq!(StashBudget::Bytes(512).to_string(), "512 B");
        assert_eq!(StashBudget::Bytes(4 << 20).to_string(), "4.00 MiB");
        assert!(StashBudget::Unlimited.allows(u64::MAX));
        assert!(StashBudget::Bytes(10).allows(10));
        assert!(!StashBudget::Bytes(10).allows(11));
    }

    #[test]
    fn unbudgeted_store_keeps_everything_resident() {
        let mut st = demo_state();
        let mut store = StashStore::ephemeral(FormatSpec::bfp(4), StashBudget::Unlimited).unwrap();
        store.stash_state(&mut st).unwrap();
        assert!(st.is_packed());
        assert_eq!(StashStore::spilled_bytes(&st), 0);
        assert!(!store.traffic().spilled());
        assert!(store.traffic().stash_write_bytes > 0);
        // And the index exists for the inspector.
        assert!(store.dir().join("stash.json").exists());
        // Unbudgeted runs agree with the cost model within box metadata.
        store.note_dispatch_read(&st);
        assert!(store.traffic_report().agrees(), "{:?}", store.traffic_report());
    }

    #[test]
    fn zero_budget_spills_every_slot_and_readback_is_bit_identical() {
        let mut st = demo_state();
        let spec = FormatSpec::bfp(4);
        // Reference: what the pre-store pack path produces.
        let mut want = demo_state();
        want.pack_state(&spec).unwrap();

        let mut store = StashStore::ephemeral(spec, StashBudget::Bytes(0)).unwrap();
        store.stash_state(&mut st).unwrap();
        assert_eq!(StashStore::resident_bytes(&st), 0, "budget 0 must spill everything");
        assert!(StashStore::spilled_bytes(&st) > 0);
        assert!(store.traffic().spill_write_bytes > 0);
        assert!(st.params.iter().all(|t| matches!(t.data, TensorData::Spilled(_))));

        store.fetch_state(&mut st).unwrap();
        assert!(store.traffic().spill_read_bytes > 0);
        assert_eq!(
            store.traffic().spill_read_bytes,
            store.traffic().spill_write_bytes,
            "every spilled record read back exactly once"
        );
        for (a, b) in st.params.iter().zip(&want.params) {
            assert_eq!(a, b, "spill -> readback must be bit-identical to pack_state");
        }
        for (a, b) in st.v.iter().zip(&want.v) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn partial_budget_spills_coldest_first_and_respects_the_cap() {
        let mut st = demo_state();
        let spec = FormatSpec::fixed(8);
        // Budget sized to hold some but not all of the six slots.
        let mut probe = demo_state();
        probe.pack_state(&spec).unwrap();
        let total = StashStore::resident_bytes(&probe);
        let budget = total / 2;
        let mut store = StashStore::ephemeral(spec, StashBudget::Bytes(budget)).unwrap();
        store.stash_state(&mut st).unwrap();
        assert!(StashStore::resident_bytes(&st) <= budget);
        assert!(StashStore::spilled_bytes(&st) > 0);
        // All slots share last_touch (whole-state stash), so the tie
        // break is slot order: params spill before v.
        assert!(
            matches!(st.params[0].data, TensorData::Spilled(_)),
            "lowest slot id spills first on an LRU tie"
        );
        assert!(
            matches!(st.v.last().unwrap().data, TensorData::Packed(_)),
            "highest slot id stays resident"
        );
    }

    #[test]
    fn lru_spills_the_coldest_slot() {
        let mut st = demo_state();
        let spec = FormatSpec::fixed(8);
        let mut store = StashStore::ephemeral(spec, StashBudget::Unlimited).unwrap();
        store.stash_state(&mut st).unwrap();
        // Warm every slot except params[0] at a later step.
        st.step = 5;
        for s in store.slots.iter_mut().skip(1) {
            s.last_touch = 5;
        }
        // Now force a one-victim budget pass.
        store.budget = StashBudget::Bytes(StashStore::resident_bytes(&st) - 1);
        store.enforce_budget(&mut st).unwrap();
        assert!(
            matches!(st.params[0].data, TensorData::Spilled(_)),
            "the stale slot is the victim"
        );
        assert_eq!(
            st.params.iter().chain(&st.m).chain(&st.v).filter(|t| matches!(
                t.data,
                TensorData::Spilled(_)
            ))
            .count(),
            1
        );
    }

    #[test]
    fn segment_file_does_not_grow_across_steps() {
        // An all-spill loop rewrites the whole working set each step;
        // the rewind keeps the segment at one step's size.
        let spec = FormatSpec::bfp(8);
        let mut store = StashStore::ephemeral(spec, StashBudget::Bytes(0)).unwrap();
        let mut sizes = Vec::new();
        for step in 1..=3u64 {
            let mut st = demo_state();
            st.step = step;
            store.stash_state(&mut st).unwrap();
            store.fetch_state(&mut st).unwrap();
            // Dense overwrite (as absorb_step_output would do).
            st.unpack_state();
            store.stash_state(&mut st).unwrap();
            sizes.push(std::fs::metadata(store.dir().join("stash.seg")).unwrap().len());
        }
        assert_eq!(sizes[0], sizes[1]);
        assert_eq!(sizes[1], sizes[2]);
    }

    #[test]
    fn prefetch_overlaps_and_matches_sync_readback() {
        let spec = FormatSpec::fixed_sr(6);
        let mut a = demo_state();
        let mut b = demo_state();
        let mut store_a = StashStore::ephemeral(spec, StashBudget::Bytes(0)).unwrap();
        let mut store_b = StashStore::ephemeral(spec, StashBudget::Bytes(0)).unwrap();
        store_a.stash_state(&mut a).unwrap();
        store_b.stash_state(&mut b).unwrap();
        store_a.start_prefetch(&a); // background readback
        store_a.fetch_state(&mut a).unwrap(); // drains the thread
        store_b.fetch_state(&mut b).unwrap(); // pure sync path
        assert_eq!(a.params, b.params, "prefetched and sync readback agree");
        assert_eq!(a.m, b.m);
        assert_eq!(
            store_a.traffic().spill_read_bytes,
            store_b.traffic().spill_read_bytes
        );
    }

    #[test]
    fn spilled_checkpoint_handle_streams_the_exact_record() {
        let spec = FormatSpec::bfp(4);
        let mut st = demo_state();
        let mut store = StashStore::ephemeral(spec, StashBudget::Bytes(0)).unwrap();
        // What the record must look like.
        let want = {
            let t = &demo_state().params[0];
            let p = t.pack_stream(&spec, 1, stash_stream(0, 0)).unwrap();
            let TensorData::Packed(p) = p.data else { unreachable!() };
            let mut buf = Vec::new();
            p.write_into(&mut buf).unwrap();
            buf
        };
        store.stash_state(&mut st).unwrap();
        let TensorData::Spilled(h) = &st.params[0].data else {
            panic!("params[0] should be spilled")
        };
        assert_eq!(h.read_record().unwrap(), want, "streamed record is byte-exact");
        assert_eq!(h.payload_len, h.record_len - (8 + 4 + 8 * 2 + 8));
    }

    #[test]
    fn empty_and_scalar_tensors_round_trip_through_the_spill_tier() {
        let spec = FormatSpec::fixed(4);
        let mut st = state_of(vec![
            HostTensor::f32(vec![0, 5], vec![]),
            HostTensor::f32(vec![], vec![2.75]),
        ]);
        let mut want = state_of(vec![
            HostTensor::f32(vec![0, 5], vec![]),
            HostTensor::f32(vec![], vec![2.75]),
        ]);
        want.pack_state(&spec).unwrap();
        let mut store = StashStore::ephemeral(spec, StashBudget::Bytes(0)).unwrap();
        store.stash_state(&mut st).unwrap();
        store.fetch_state(&mut st).unwrap();
        assert_eq!(st.params, want.params);
    }

    #[test]
    fn audit_observed_traffic_every_registered_format() {
        // Satellite: the meter is pinned against the codec the way
        // storage bits already are.
        for spec in registered_specs(&[2, 3, 4, 8, 16, 24, 32]) {
            audit_observed_traffic(&spec)
                .unwrap_or_else(|e| panic!("traffic meter disagrees with codec: {e}"));
        }
    }

    #[test]
    fn measure_state_traffic_reports_codec_bytes() {
        let st = demo_state();
        let t = measure_state_traffic(&st, &FormatSpec::bfp(4)).unwrap();
        // 3 groups x (64-elem exact-box tensor + ragged 2x21 tensor).
        let expect = 3 * (FormatSpec::bfp(4).observed_bytes(64, 16)
            + FormatSpec::bfp(4).observed_bytes(42, 21)) as u64;
        assert_eq!(t.meter.stash_write_bytes, expect);
        assert_eq!(t.meter.stash_read_bytes, expect);
        assert!(t.agrees());
        assert!(!t.meter.spilled());
    }

    #[test]
    fn traffic_report_json_and_summary() {
        let mut st = demo_state();
        let mut store = StashStore::ephemeral(FormatSpec::bfp(8), StashBudget::Bytes(0)).unwrap();
        store.stash_state(&mut st).unwrap();
        store.fetch_state(&mut st).unwrap();
        store.note_dispatch_read(&st);
        store.note_checkpoint_bytes(123);
        let r = store.traffic_report();
        let s = r.summary();
        assert!(s.contains("modeled") && s.contains("observed"), "{s}");
        assert!(s.contains("spill wrote"), "{s}");
        let j = r.to_json().to_string_pretty();
        assert!(j.contains("spill_write_bytes"), "{j}");
        assert!(j.contains("agrees"), "{j}");
        assert_eq!(r.meter.checkpoint_bytes, 123);
    }

    #[test]
    fn ephemeral_dir_is_removed_on_drop() {
        let dir;
        {
            let mut st = demo_state();
            let mut store =
                StashStore::ephemeral(FormatSpec::fixed(8), StashBudget::Bytes(0)).unwrap();
            store.stash_state(&mut st).unwrap();
            dir = store.dir().to_path_buf();
            assert!(dir.exists());
        }
        assert!(!dir.exists(), "ephemeral store must clean up {dir:?}");
    }

    #[test]
    fn named_dir_survives_for_the_inspector() {
        let dir = std::env::temp_dir().join(format!("dsq-stash-test-{}", std::process::id()));
        {
            let mut st = demo_state();
            let mut store = StashStore::new(StashStoreConfig {
                spec: FormatSpec::bfp(4),
                budget: StashBudget::Bytes(0),
                dir: dir.clone(),
            })
            .unwrap();
            store.set_param_names(&["w", "b"]);
            store.stash_state(&mut st).unwrap();
        }
        let idx = crate::util::json::parse_file(&dir.join("stash.json")).unwrap();
        assert_eq!(idx.path("spec").and_then(Json::as_str), Some("bfp4"));
        let slots = idx.path("slots").and_then(Json::as_arr).unwrap();
        assert_eq!(slots.len(), 6);
        assert_eq!(slots[0].path("slot").and_then(Json::as_str), Some("params/w"));
        assert_eq!(slots[0].path("tier").and_then(Json::as_str), Some("spilled"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
