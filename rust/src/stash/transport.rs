//! Replica transports: how exchange frames move between ranks.
//!
//! [`super::exchange`] owns the *collective* (dequant–reduce–requant
//! all-reduce); this module owns the *movement*. The seam is the
//! [`Transport`] trait — post-and-collect semantics, deliberately
//! `send`/`recv`/`barrier`-free: one call posts this rank's frame
//! payload and blocks until every rank's payload for the round is
//! available, returning all of them in rank order. Peer failure is
//! surfaced as an `Err` on **every** peer (the PR 7 no-deadlock
//! teardown contract): a transport may block, but it may never hang
//! past its timeouts once any rank has died.
//!
//! Two implementations:
//!
//! * [`MemTransport`] (`--transport mem`, the default) — the original
//!   in-process ring, moved here verbatim from `exchange.rs`: one
//!   post slot per rank, a round counter, and a condvar under the
//!   `ring` mutex (witness rank `ring` 10 < `comms` 20). Payload
//!   bytes are handed over as-is — no envelope — so the default path
//!   is bit- and meter-identical to the pre-refactor exchange.
//! * [`SocketTransport`] (`--transport socket:<addr>`) — N real OS
//!   processes over Unix-domain (`socket:/path.sock`) or TCP-loopback
//!   (`socket:host:port`) streams, speaking [`super::wire`]
//!   `DSQWIRE1` frames through a central [`SocketHub`] (bound by the
//!   orchestrating process, rank 0's parent). Handshake: each worker
//!   connects (with retry up to a timeout), sends a `HELLO rank
//!   replicas` control frame, and receives a CONFIG control frame
//!   carrying the orchestrator's opaque config payload. Each round
//!   the hub reads one data frame per rank (in rank order) and
//!   broadcasts all N back to every connection. A worker that dies
//!   mid-round — torn frame, EOF, read timeout, or an explicit abort
//!   frame from [`Transport::fail`] — makes the hub broadcast an
//!   abort frame to every survivor, so all peers error out with the
//!   exchange's `ABORT_PREFIX` within the read timeout instead of
//!   hanging. Clean shutdown is EOF at a frame boundary on every
//!   connection.
//!
//! ## Locking
//!
//! Socket I/O must never happen under a held lock (`dsq lint`'s
//! `blocking_under_lock` rule counts stream reads/writes, accepts,
//! and connects as blocking ops). [`SocketTransport`] therefore keeps
//! its only mutex — the `failed` flag, witness rank
//! [`ordwitness::RANK_TRANSPORT_SOCKET`] (15) — confined to the
//! `check_failed`/`set_failed` helpers; `post_collect` itself holds
//! nothing across the wire, and the hub is single-threaded and
//! lock-free by construction.

use std::fmt;
use std::io::{Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar};
use std::time::{Duration, Instant};

use crate::util::ordwitness::{self, WitnessedMutex};
use crate::{Error, Result};

use super::wire::{WireFrame, HEADER_LEN};

/// Every barrier abort on every rank carries this prefix, so
/// orchestrators can prefer the originating failure over the
/// secondary teardown errors it caused.
pub const ABORT_PREFIX: &str = "replica exchange aborted";

pub(crate) fn abort_error(msg: &str) -> Error {
    Error::Config(format!("{ABORT_PREFIX}: {msg}"))
}

/// The valid `--transport` grammar, quoted by parse errors.
pub const TRANSPORT_GRAMMAR: &str = "mem | socket:<path.sock> | socket:<host>:<port>";

/// Default wait for a worker to reach the hub (and the hub to see all
/// workers): covers process spawn + connect retry.
pub const CONNECT_TIMEOUT: Duration = Duration::from_secs(10);

/// Default cap on any single blocking read once connected — the bound
/// on how long a peer failure can take to surface.
pub const READ_TIMEOUT: Duration = Duration::from_secs(60);

/// Parsed `--transport` flag value.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TransportSpec {
    /// The in-process ring (default).
    Mem,
    /// Multi-process socket transport; the address is a Unix socket
    /// path (contains `/`) or a TCP `host:port`.
    Socket(String),
}

impl TransportSpec {
    /// Parse a `--transport` value, naming the offending token and the
    /// valid grammar on error (the CLI prepends the flag name).
    pub fn parse(s: &str) -> Result<TransportSpec> {
        let t = s.trim();
        if t == "mem" {
            return Ok(TransportSpec::Mem);
        }
        if let Some(addr) = t.strip_prefix("socket:") {
            if addr.is_empty() {
                return Err(Error::Config(format!(
                    "\"{s}\" names no address after \"socket:\" (valid: {TRANSPORT_GRAMMAR})"
                )));
            }
            return Ok(TransportSpec::Socket(addr.to_string()));
        }
        Err(Error::Config(format!(
            "unrecognized transport \"{s}\" (valid: {TRANSPORT_GRAMMAR})"
        )))
    }

    pub fn is_socket(&self) -> bool {
        matches!(self, TransportSpec::Socket(_))
    }
}

impl fmt::Display for TransportSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TransportSpec::Mem => write!(f, "mem"),
            TransportSpec::Socket(addr) => write!(f, "socket:{addr}"),
        }
    }
}

/// How exchange frames move between ranks. One call = one collective
/// round: post this rank's payload, block until every rank's payload
/// for the round is in, return all of them in rank order. Any peer
/// failure must surface as `Err` on every rank (never a hang).
pub trait Transport: Send + Sync {
    /// Total replica count this transport connects.
    fn replicas(&self) -> usize;

    /// Post `payload` as `rank`'s frame for this round and collect all
    /// ranks' payloads in rank order. `step`/`seq`/`tensors` describe
    /// the frame for self-describing wires (the in-memory ring ignores
    /// them); all ranks proceed in lockstep, so every rank passes the
    /// same values each round.
    fn post_collect(
        &self,
        rank: usize,
        step: u64,
        seq: u64,
        tensors: u32,
        payload: Vec<u8>,
    ) -> Result<Vec<Arc<Vec<u8>>>>;

    /// Tear the transport down: every blocked or future
    /// `post_collect` on any rank returns an error naming `msg`.
    /// First failure wins; idempotent after that.
    fn fail(&self, msg: &str);

    /// Completed collective rounds, as visible to this transport
    /// instance (global for the ring, per-process for sockets).
    fn rounds(&self) -> u64;

    /// Metered on-the-wire bytes for a frame with `payload_len`
    /// payload bytes. The ring ships bare payloads; the socket path
    /// adds the wire header.
    fn frame_bytes(&self, payload_len: usize) -> u64 {
        payload_len as u64
    }
}

/// Barrier state for the single in-flight round of the in-memory ring.
struct Ring {
    /// One posted frame per rank; a full vector completes the round.
    posts: Vec<Option<Arc<Vec<u8>>>>,
    /// Ranks that have collected the current round's frames.
    taken: usize,
    /// Completed rounds (diagnostics only).
    round: u64,
    /// Set once by [`Transport::fail`]; every wait exits with an error.
    failed: Option<String>,
}

/// The in-process ring: one slot per rank under a single mutex +
/// condvar. This is the pre-refactor exchange barrier verbatim —
/// payloads are reference-counted and never copied, so `--transport
/// mem` is bit- and meter-identical to the fused implementation.
pub struct MemTransport {
    n: usize,
    /// Post board, rank [`ordwitness::RANK_EXCHANGE_RING`] — the
    /// global order `ring` before `comms` is asserted statically by
    /// `lock_discipline` and dynamically by the debug-build witness.
    ring: WitnessedMutex<Ring>,
    ring_cv: Condvar,
}

impl MemTransport {
    pub fn new(replicas: usize) -> Result<MemTransport> {
        if replicas == 0 {
            return Err(Error::Config("replica exchange needs at least 1 replica".into()));
        }
        Ok(MemTransport {
            n: replicas,
            ring: WitnessedMutex::new(
                ordwitness::RANK_EXCHANGE_RING,
                "exchange.ring",
                Ring { posts: vec![None; replicas], taken: 0, round: 0, failed: None },
            ),
            ring_cv: Condvar::new(),
        })
    }
}

impl Transport for MemTransport {
    fn replicas(&self) -> usize {
        self.n
    }

    fn post_collect(
        &self,
        rank: usize,
        _step: u64,
        _seq: u64,
        _tensors: u32,
        payload: Vec<u8>,
    ) -> Result<Vec<Arc<Vec<u8>>>> {
        if rank >= self.n {
            return Err(Error::Config(format!(
                "replica rank {rank} out of range (replicas = {})",
                self.n
            )));
        }
        let mut ring = self.ring.lock();
        // Wait for this rank's slot from the previous round to drain —
        // rounds never overlap, so one slot vector is the whole ring.
        loop {
            if let Some(msg) = &ring.failed {
                return Err(abort_error(msg));
            }
            if ring.posts[rank].is_none() {
                break;
            }
            ring = ring.wait(&self.ring_cv);
        }
        ring.posts[rank] = Some(Arc::new(payload));
        self.ring_cv.notify_all();
        loop {
            if let Some(msg) = &ring.failed {
                return Err(abort_error(msg));
            }
            if ring.posts.iter().all(Option::is_some) {
                break;
            }
            ring = ring.wait(&self.ring_cv);
        }
        let all: Vec<Arc<Vec<u8>>> = ring.posts.iter().flatten().map(Arc::clone).collect();
        ring.taken += 1;
        if ring.taken == self.n {
            for p in ring.posts.iter_mut() {
                *p = None;
            }
            ring.taken = 0;
            ring.round += 1;
            self.ring_cv.notify_all();
        }
        Ok(all)
    }

    fn fail(&self, msg: &str) {
        let mut ring = self.ring.lock();
        if ring.failed.is_none() {
            ring.failed = Some(msg.to_string());
        }
        self.ring_cv.notify_all();
    }

    fn rounds(&self) -> u64 {
        self.ring.lock().round
    }
}

/// A connected stream of either flavor. `&Stream` implements
/// `Read`/`Write` (delegating to `&UnixStream`/`&TcpStream`), so the
/// transport can do I/O through a shared reference without a lock.
enum Stream {
    Unix(UnixStream),
    Tcp(TcpStream),
}

impl Stream {
    /// Connect to `addr` (Unix path if it contains `/`, else TCP),
    /// retrying until `timeout` — workers race the hub's bind.
    fn connect(addr: &str, timeout: Duration) -> Result<Stream> {
        let deadline = Instant::now() + timeout;
        loop {
            let attempt = if addr.contains('/') {
                UnixStream::connect(addr).map(Stream::Unix)
            } else {
                TcpStream::connect(addr).map(Stream::Tcp)
            };
            match attempt {
                Ok(s) => return Ok(s),
                Err(e) => {
                    if Instant::now() >= deadline {
                        return Err(Error::Config(format!(
                            "socket transport: connecting to {addr} timed out \
                             after {timeout:?}: {e}"
                        )));
                    }
                    ordwitness::assert_lock_free("retrying a socket connect");
                    std::thread::sleep(Duration::from_millis(25));
                }
            }
        }
    }

    fn set_read_timeout(&self, d: Duration) -> Result<()> {
        match self {
            Stream::Unix(s) => s.set_read_timeout(Some(d))?,
            Stream::Tcp(s) => s.set_read_timeout(Some(d))?,
        }
        Ok(())
    }

    fn set_blocking(&self) -> Result<()> {
        match self {
            Stream::Unix(s) => s.set_nonblocking(false)?,
            Stream::Tcp(s) => s.set_nonblocking(false)?,
        }
        Ok(())
    }

    fn shutdown(&self) {
        let _ = match self {
            Stream::Unix(s) => s.shutdown(Shutdown::Both),
            Stream::Tcp(s) => s.shutdown(Shutdown::Both),
        };
    }
}

impl Read for &Stream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match *self {
            Stream::Unix(ref s) => Read::read(&mut &*s, buf),
            Stream::Tcp(ref s) => Read::read(&mut &*s, buf),
        }
    }
}

impl Write for &Stream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match *self {
            Stream::Unix(ref s) => Write::write(&mut &*s, buf),
            Stream::Tcp(ref s) => Write::write(&mut &*s, buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match *self {
            Stream::Unix(ref s) => Write::flush(&mut &*s),
            Stream::Tcp(ref s) => Write::flush(&mut &*s),
        }
    }
}

enum Listener {
    Unix(UnixListener),
    Tcp(TcpListener),
}

/// The worker-side socket transport: one connected stream to the hub.
/// One process = one rank = one instance; `post_collect` validates
/// the caller's rank against the connected one.
pub struct SocketTransport {
    rank: usize,
    n: usize,
    stream: Stream,
    /// First failure message, witness rank
    /// [`ordwitness::RANK_TRANSPORT_SOCKET`]. The only lock in this
    /// type; confined to `check_failed`/`set_failed` so no socket I/O
    /// ever happens while it is held.
    failed: WitnessedMutex<Option<String>>,
    completed: AtomicU64,
}

impl SocketTransport {
    /// Connect to the hub at `addr` as `rank` of `replicas`, with the
    /// default timeouts. Returns the transport plus the orchestrator's
    /// opaque CONFIG payload from the handshake.
    pub fn connect(addr: &str, rank: usize, replicas: usize) -> Result<(SocketTransport, Vec<u8>)> {
        Self::connect_with_timeouts(addr, rank, replicas, CONNECT_TIMEOUT, READ_TIMEOUT)
    }

    pub fn connect_with_timeouts(
        addr: &str,
        rank: usize,
        replicas: usize,
        connect_timeout: Duration,
        read_timeout: Duration,
    ) -> Result<(SocketTransport, Vec<u8>)> {
        if replicas < 2 {
            return Err(Error::Config(format!(
                "socket transport needs at least 2 replicas (got {replicas})"
            )));
        }
        if rank >= replicas {
            return Err(Error::Config(format!(
                "replica rank {rank} out of range (replicas = {replicas})"
            )));
        }
        let stream = Stream::connect(addr, connect_timeout)?;
        stream.set_read_timeout(read_timeout)?;
        WireFrame::control(format!("HELLO {rank} {replicas}").into_bytes())
            .write_into(&mut &stream)?;
        // CONFIG arrives once every rank has joined; an abort frame here
        // means the hub rejected the handshake.
        let cfg = WireFrame::read_from(&mut &stream)?;
        if cfg.is_abort() {
            return Err(abort_error(&cfg.abort_message()));
        }
        if !cfg.is_control() {
            return Err(Error::Config(format!(
                "socket transport: expected a CONFIG frame, got sender rank {}",
                cfg.header.rank
            )));
        }
        Ok((
            SocketTransport {
                rank,
                n: replicas,
                stream,
                failed: WitnessedMutex::new(
                    ordwitness::RANK_TRANSPORT_SOCKET,
                    "transport.socket.failed",
                    None,
                ),
                completed: AtomicU64::new(0),
            },
            cfg.payload,
        ))
    }

    /// The only reader of the `failed` lock; never called with I/O in
    /// flight so the lock is never held across a blocking op.
    fn check_failed(&self) -> Result<()> {
        let failed = self.failed.lock();
        match &*failed {
            Some(msg) => Err(abort_error(msg)),
            None => Ok(()),
        }
    }

    /// The only writer of the `failed` lock; first failure wins.
    fn set_failed(&self, msg: &str) {
        let mut failed = self.failed.lock();
        if failed.is_none() {
            *failed = Some(msg.to_string());
        }
    }
}

impl Transport for SocketTransport {
    fn replicas(&self) -> usize {
        self.n
    }

    fn post_collect(
        &self,
        rank: usize,
        step: u64,
        seq: u64,
        tensors: u32,
        payload: Vec<u8>,
    ) -> Result<Vec<Arc<Vec<u8>>>> {
        if rank != self.rank {
            return Err(Error::Config(format!(
                "socket transport is connected as rank {} but was asked to post as rank {rank}",
                self.rank
            )));
        }
        self.check_failed()?;
        ordwitness::assert_lock_free("posting a frame on the socket transport");
        let frame = WireFrame::data(rank as u32, step, seq, tensors, payload);
        if let Err(e) = frame.write_into(&mut &self.stream) {
            let msg = format!("replica {rank} lost the hub mid-post: {e}");
            self.set_failed(&msg);
            return Err(abort_error(&msg));
        }
        // The hub echoes every rank's frame back in rank order; our own
        // comes through the wire too, so all ranks decode identical bytes.
        let mut all: Vec<Arc<Vec<u8>>> = Vec::with_capacity(self.n);
        for r in 0..self.n {
            let got = match WireFrame::read_from(&mut &self.stream) {
                Ok(f) => f,
                Err(e) => {
                    let msg = format!("replica {rank} lost the hub mid-collect: {e}");
                    self.set_failed(&msg);
                    return Err(abort_error(&msg));
                }
            };
            if got.is_abort() {
                let msg = got.abort_message();
                self.set_failed(&msg);
                return Err(abort_error(&msg));
            }
            if got.header.rank as usize != r || got.header.step != step || got.header.seq != seq {
                let msg = format!(
                    "out-of-order frame: got (rank {}, step {}, seq {}), \
                     expected (rank {r}, step {step}, seq {seq})",
                    got.header.rank, got.header.step, got.header.seq
                );
                self.set_failed(&msg);
                return Err(abort_error(&msg));
            }
            all.push(Arc::new(got.payload));
        }
        self.completed.fetch_add(1, Ordering::Relaxed);
        Ok(all)
    }

    fn fail(&self, msg: &str) {
        self.set_failed(msg);
        // Best effort: tell the hub why, then sever the stream so peers
        // unblock even if the abort frame never lands.
        let _ = WireFrame::abort(msg).write_into(&mut &self.stream);
        self.stream.shutdown();
    }

    fn rounds(&self) -> u64 {
        self.completed.load(Ordering::Relaxed)
    }

    fn frame_bytes(&self, payload_len: usize) -> u64 {
        (HEADER_LEN + payload_len) as u64
    }
}

/// The hub end of the socket transport: bound by the orchestrating
/// process, it accepts one connection per rank, broadcasts the CONFIG
/// payload, then relays rounds until every worker shuts down cleanly
/// (EOF at a frame boundary) or any worker fails (abort broadcast to
/// all survivors). Single-threaded and lock-free; run [`serve`] on a
/// dedicated thread.
///
/// [`serve`]: SocketHub::serve
pub struct SocketHub {
    listener: Listener,
    addr: String,
    n: usize,
    config: Vec<u8>,
    accept_timeout: Duration,
    read_timeout: Duration,
    unix_path: Option<String>,
}

impl SocketHub {
    /// Bind on `addr` (Unix path if it contains `/`, else TCP — use
    /// port 0 to let the OS pick). `config` is broadcast verbatim to
    /// every worker once all have joined.
    pub fn bind(addr: &str, replicas: usize, config: Vec<u8>) -> Result<SocketHub> {
        if replicas < 2 {
            return Err(Error::Config(format!(
                "socket transport needs at least 2 replicas (got {replicas})"
            )));
        }
        let (listener, addr, unix_path) = if addr.contains('/') {
            // A stale socket file from a killed run blocks bind; it is
            // ours by construction, so clear it.
            let _ = std::fs::remove_file(addr);
            let l = UnixListener::bind(addr)?;
            l.set_nonblocking(true)?;
            (Listener::Unix(l), addr.to_string(), Some(addr.to_string()))
        } else {
            let l = TcpListener::bind(addr)?;
            l.set_nonblocking(true)?;
            let resolved = l.local_addr()?.to_string();
            (Listener::Tcp(l), resolved, None)
        };
        Ok(SocketHub {
            listener,
            addr,
            n: replicas,
            config,
            accept_timeout: CONNECT_TIMEOUT,
            read_timeout: READ_TIMEOUT,
            unix_path,
        })
    }

    /// The bound address with any OS-assigned TCP port resolved —
    /// what workers should `--connect` to.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    pub fn set_timeouts(&mut self, accept: Duration, read: Duration) {
        self.accept_timeout = accept;
        self.read_timeout = read;
    }

    /// Accept one connection, polling the non-blocking listener until
    /// `deadline`.
    fn accept_one(&self, deadline: Instant) -> Result<Stream> {
        loop {
            let got = match &self.listener {
                Listener::Unix(l) => match l.accept() {
                    Ok((s, _)) => Some(Stream::Unix(s)),
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => None,
                    Err(e) => return Err(Error::Io(e)),
                },
                Listener::Tcp(l) => match l.accept() {
                    Ok((s, _)) => Some(Stream::Tcp(s)),
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => None,
                    Err(e) => return Err(Error::Io(e)),
                },
            };
            if let Some(s) = got {
                s.set_blocking()?;
                s.set_read_timeout(self.read_timeout)?;
                return Ok(s);
            }
            if Instant::now() >= deadline {
                return Err(abort_error(&format!(
                    "hub on {} timed out waiting for workers ({:?})",
                    self.addr, self.accept_timeout
                )));
            }
            ordwitness::assert_lock_free("waiting for a replica worker to connect");
            std::thread::sleep(Duration::from_millis(10));
        }
    }

    /// Broadcast an abort frame to every live connection and return the
    /// teardown error — the socket-path twin of poisoning the ring.
    fn abort_iter<'a>(
        &self,
        conns: impl Iterator<Item = &'a Stream>,
        msg: &str,
    ) -> Result<u64> {
        let frame = WireFrame::abort(msg);
        for c in conns {
            let _ = frame.write_into(&mut &*c);
            c.shutdown();
        }
        Err(abort_error(msg))
    }

    /// Validate one HELLO frame against the hub's config and the slots
    /// already claimed; returns the rank to seat or the abort message.
    fn claim_slot(
        &self,
        pending: &[Option<Stream>],
        hello: &WireFrame,
    ) -> std::result::Result<usize, String> {
        let (rank, replicas) = parse_hello(hello).map_err(|e| e.to_string())?;
        if replicas != self.n {
            return Err(format!(
                "rank {rank} was launched for {replicas} replicas but the hub serves {}",
                self.n
            ));
        }
        if rank >= self.n {
            return Err(format!("handshake rank {rank} out of range (replicas = {})", self.n));
        }
        if pending[rank].is_some() {
            return Err(format!("two workers claimed rank {rank}"));
        }
        Ok(rank)
    }

    /// Run the hub to completion: handshake, then relay rounds until
    /// clean EOF from every rank (returns the completed round count)
    /// or any failure (abort broadcast to all survivors, `Err`).
    pub fn serve(self) -> Result<u64> {
        // Handshake: one HELLO per rank, each claiming a unique slot.
        let deadline = Instant::now() + self.accept_timeout;
        let mut pending: Vec<Option<Stream>> = (0..self.n).map(|_| None).collect();
        let mut accepted = 0usize;
        while accepted < self.n {
            let s = match self.accept_one(deadline) {
                Ok(s) => s,
                Err(e) => return self.abort_iter(pending.iter().flatten(), &e.to_string()),
            };
            let hello = match WireFrame::read_from(&mut &s) {
                Ok(f) => f,
                Err(e) => {
                    let msg = format!("handshake read failed: {e}");
                    return self
                        .abort_iter(pending.iter().flatten().chain(std::iter::once(&s)), &msg);
                }
            };
            match self.claim_slot(&pending, &hello) {
                Ok(rank) => {
                    pending[rank] = Some(s);
                    accepted += 1;
                }
                Err(msg) => {
                    return self
                        .abort_iter(pending.iter().flatten().chain(std::iter::once(&s)), &msg);
                }
            }
        }
        let conns: Vec<Stream> = pending.into_iter().flatten().collect();

        // Everyone is in: release the workers with the CONFIG payload.
        let config = WireFrame::control(self.config.clone());
        for c in &conns {
            if let Err(e) = config.write_into(&mut &*c) {
                return self.abort_iter(conns.iter(), &format!("broadcasting CONFIG: {e}"));
            }
        }

        // Round loop: read one data frame per rank in rank order, then
        // broadcast all of them to every rank.
        let mut rounds = 0u64;
        loop {
            let mut frames: Vec<WireFrame> = Vec::with_capacity(self.n);
            for (r, c) in conns.iter().enumerate() {
                let got = match WireFrame::read_or_eof(&mut &*c) {
                    Ok(g) => g,
                    Err(e) => {
                        let msg = format!("reading rank {r} in round {rounds}: {e}");
                        return self.abort_iter(conns.iter(), &msg);
                    }
                };
                let f = match got {
                    Some(f) => f,
                    None if r == 0 => {
                        // Rank 0 closed at a frame boundary: a clean end
                        // of run iff every other rank is at EOF too.
                        for (r2, c2) in conns.iter().enumerate().skip(1) {
                            match WireFrame::read_or_eof(&mut &*c2) {
                                Ok(None) => {}
                                Ok(Some(_)) => {
                                    let msg = format!(
                                        "replica {r2} posted a frame after rank 0 shut down"
                                    );
                                    return self.abort_iter(conns.iter(), &msg);
                                }
                                Err(e) => {
                                    let msg = format!("draining rank {r2} at shutdown: {e}");
                                    return self.abort_iter(conns.iter(), &msg);
                                }
                            }
                        }
                        return Ok(rounds);
                    }
                    None => {
                        let msg = format!("replica {r} disconnected mid-round {rounds}");
                        return self.abort_iter(conns.iter(), &msg);
                    }
                };
                if f.is_abort() {
                    return self.abort_iter(conns.iter(), &f.abort_message());
                }
                if f.header.rank as usize != r {
                    let msg = format!(
                        "frame from rank {} arrived on replica {r}'s connection",
                        f.header.rank
                    );
                    return self.abort_iter(conns.iter(), &msg);
                }
                frames.push(f);
            }
            for c in &conns {
                for f in &frames {
                    if let Err(e) = f.write_into(&mut &*c) {
                        let msg = format!("broadcasting round {rounds}: {e}");
                        return self.abort_iter(conns.iter(), &msg);
                    }
                }
            }
            rounds += 1;
        }
    }
}

/// Parse a `HELLO <rank> <replicas>` handshake frame.
fn parse_hello(f: &WireFrame) -> Result<(usize, usize)> {
    let text = String::from_utf8_lossy(&f.payload).into_owned();
    let bad = || Error::Config(format!("socket transport: malformed handshake frame {text:?}"));
    if !f.is_control() {
        return Err(bad());
    }
    let mut it = text.split_whitespace();
    if it.next() != Some("HELLO") {
        return Err(bad());
    }
    let rank: usize = it.next().and_then(|t| t.parse().ok()).ok_or_else(bad)?;
    let replicas: usize = it.next().and_then(|t| t.parse().ok()).ok_or_else(bad)?;
    Ok((rank, replicas))
}

impl Drop for SocketHub {
    fn drop(&mut self) {
        if let Some(p) = &self.unix_path {
            let _ = std::fs::remove_file(p);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn uds_path(tag: &str) -> String {
        let mut p = std::env::temp_dir();
        p.push(format!("dsq-transport-{}-{tag}.sock", std::process::id()));
        p.to_string_lossy().into_owned()
    }

    fn fast(hub: &mut SocketHub) {
        hub.set_timeouts(Duration::from_secs(5), Duration::from_secs(5));
    }

    #[test]
    fn transport_spec_parse_names_the_token_and_grammar() {
        assert_eq!(TransportSpec::parse("mem").unwrap(), TransportSpec::Mem);
        assert_eq!(
            TransportSpec::parse("socket:/tmp/x.sock").unwrap(),
            TransportSpec::Socket("/tmp/x.sock".into())
        );
        assert!(TransportSpec::parse("socket:127.0.0.1:0").unwrap().is_socket());
        let e = TransportSpec::parse("carrier-pigeon").unwrap_err().to_string();
        assert!(e.contains("carrier-pigeon") && e.contains(TRANSPORT_GRAMMAR), "{e}");
        let e = TransportSpec::parse("socket:").unwrap_err().to_string();
        assert!(e.contains("socket:") && e.contains(TRANSPORT_GRAMMAR), "{e}");
        assert_eq!(TransportSpec::Socket("a:1".into()).to_string(), "socket:a:1");
        assert_eq!(TransportSpec::Mem.to_string(), "mem");
    }

    #[test]
    fn mem_transport_posts_and_collects_in_rank_order() {
        let t = Arc::new(MemTransport::new(2).unwrap());
        let results: Vec<Vec<Vec<u8>>> = std::thread::scope(|s| {
            let joins: Vec<_> = (0..2)
                .map(|rank| {
                    let t = Arc::clone(&t);
                    s.spawn(move || {
                        let all = t.post_collect(rank, 0, 0, 0, vec![rank as u8]).unwrap();
                        all.iter().map(|b| b.as_ref().clone()).collect::<Vec<_>>()
                    })
                })
                .collect();
            joins.into_iter().map(|j| j.join().unwrap()).collect()
        });
        assert_eq!(results[0], vec![vec![0u8], vec![1u8]]);
        assert_eq!(results[0], results[1], "every rank collects identical bytes");
        assert_eq!(t.rounds(), 1);
        assert!(t.post_collect(5, 0, 0, 0, vec![]).is_err(), "rank must be < replicas");
        assert_eq!(t.frame_bytes(10), 10, "the ring ships bare payloads");
        assert!(MemTransport::new(0).is_err());
    }

    fn socket_round_trip(addr: &str) {
        let mut hub = SocketHub::bind(addr, 2, b"cfg!".to_vec()).unwrap();
        fast(&mut hub);
        let addr = hub.addr().to_string();
        let hub_j = std::thread::spawn(move || hub.serve());
        let clients: Vec<_> = (0..2usize)
            .map(|rank| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let (t, cfg) = SocketTransport::connect(&addr, rank, 2).unwrap();
                    assert_eq!(cfg, b"cfg!", "CONFIG payload must arrive verbatim");
                    for round in 0..2u64 {
                        let all =
                            t.post_collect(rank, 7, round, 3, vec![rank as u8; 4]).unwrap();
                        assert_eq!(all.len(), 2);
                        assert_eq!(*all[0], vec![0u8; 4]);
                        assert_eq!(*all[1], vec![1u8; 4]);
                    }
                    assert_eq!(t.rounds(), 2);
                    assert_eq!(t.frame_bytes(4), (HEADER_LEN + 4) as u64);
                })
            })
            .collect();
        for c in clients {
            c.join().unwrap();
        }
        assert_eq!(hub_j.join().unwrap().unwrap(), 2, "hub must see both rounds then clean EOF");
    }

    #[test]
    fn socket_rounds_trip_over_tcp_loopback() {
        socket_round_trip("127.0.0.1:0");
    }

    #[test]
    fn socket_rounds_trip_over_a_unix_socket() {
        let path = uds_path("roundtrip");
        socket_round_trip(&path);
        assert!(!std::path::Path::new(&path).exists(), "hub drop must clear the socket file");
    }

    #[test]
    fn a_dead_socket_peer_aborts_the_survivor_instead_of_hanging() {
        // The satellite bugfix, socket edition: rank 1 joins the
        // handshake then dies without posting; rank 0's blocked collect
        // must error with the teardown prefix, not hang.
        let mut hub = SocketHub::bind("127.0.0.1:0", 2, Vec::new()).unwrap();
        fast(&mut hub);
        let addr = hub.addr().to_string();
        let hub_j = std::thread::spawn(move || hub.serve());
        let survivor_addr = addr.clone();
        let survivor = std::thread::spawn(move || {
            let (t, _) = SocketTransport::connect(&survivor_addr, 0, 2).unwrap();
            t.post_collect(0, 0, 0, 0, vec![1, 2, 3]).map(|_| ())
        });
        let (dead, _) = SocketTransport::connect(&addr, 1, 2).unwrap();
        drop(dead);
        let err = survivor.join().unwrap().unwrap_err().to_string();
        assert!(err.contains(ABORT_PREFIX), "survivor must see the teardown: {err}");
        assert!(hub_j.join().unwrap().is_err(), "the hub run itself must report the abort");
    }

    #[test]
    fn an_explicit_socket_failure_carries_its_message_to_peers() {
        // Transport::fail on one rank must surface the *original*
        // message on every peer (mirrors the in-memory injected-failure
        // test from PR 7).
        let mut hub = SocketHub::bind("127.0.0.1:0", 2, Vec::new()).unwrap();
        fast(&mut hub);
        let addr = hub.addr().to_string();
        let hub_j = std::thread::spawn(move || hub.serve());
        let survivor_addr = addr.clone();
        let survivor = std::thread::spawn(move || {
            let (t, _) = SocketTransport::connect(&survivor_addr, 0, 2).unwrap();
            t.post_collect(0, 0, 0, 0, vec![9]).map(|_| ())
        });
        let (t1, _) = SocketTransport::connect(&addr, 1, 2).unwrap();
        t1.fail("replica 1 failed: injected I/O error");
        let err = survivor.join().unwrap().unwrap_err().to_string();
        assert!(
            err.contains(ABORT_PREFIX) && err.contains("injected I/O error"),
            "peers must see the originating message: {err}"
        );
        // The failed transport itself refuses further rounds.
        let err = t1.post_collect(1, 0, 0, 0, vec![]).unwrap_err().to_string();
        assert!(err.contains(ABORT_PREFIX), "{err}");
        assert!(hub_j.join().unwrap().is_err());
    }

    #[test]
    fn hub_rejects_a_mismatched_handshake() {
        let mut hub = SocketHub::bind("127.0.0.1:0", 2, Vec::new()).unwrap();
        fast(&mut hub);
        let addr = hub.addr().to_string();
        let hub_j = std::thread::spawn(move || hub.serve());
        // Claims 3 replicas against a 2-replica hub: the handshake must
        // come back as a loud abort, not a hang or a silent seat.
        let err = SocketTransport::connect(&addr, 0, 3).unwrap_err().to_string();
        assert!(err.contains(ABORT_PREFIX), "{err}");
        assert!(err.contains("3 replicas"), "must name the mismatch: {err}");
        assert!(hub_j.join().unwrap().is_err());
    }

    #[test]
    fn socket_transport_rejects_bad_config() {
        assert!(SocketTransport::connect("127.0.0.1:1", 0, 1).is_err(), "needs >= 2 replicas");
        assert!(SocketTransport::connect("127.0.0.1:1", 5, 2).is_err(), "rank < replicas");
        assert!(SocketHub::bind("127.0.0.1:0", 1, Vec::new()).is_err());
    }
}
