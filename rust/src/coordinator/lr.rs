//! Learning-rate schedules (owned by L3; the AOT graph takes lr as a
//! runtime scalar).
//!
//! The paper (Appendix B): Inverse Square Root for training from
//! scratch, Polynomial Decay for fine-tuning.

/// A learning-rate schedule; `step` is 1-based.
#[derive(Clone, Debug, PartialEq)]
pub enum LrSchedule {
    Constant {
        lr: f64,
    },
    /// fairseq-style inverse-sqrt with linear warmup.
    InverseSqrt {
        peak_lr: f64,
        warmup_steps: u64,
    },
    /// Linear-to-zero polynomial decay (power 1.0) from `lr` over
    /// `total_steps`, with optional warmup.
    Polynomial {
        lr: f64,
        warmup_steps: u64,
        total_steps: u64,
    },
}

impl LrSchedule {
    pub fn at(&self, step: u64) -> f64 {
        let s = step.max(1) as f64;
        match *self {
            LrSchedule::Constant { lr } => lr,
            LrSchedule::InverseSqrt { peak_lr, warmup_steps } => {
                let w = warmup_steps.max(1) as f64;
                peak_lr * (s / w).min((w / s).sqrt())
            }
            LrSchedule::Polynomial { lr, warmup_steps, total_steps } => {
                let w = warmup_steps.max(1) as f64;
                let t = total_steps.max(1) as f64;
                if s <= w {
                    lr * s / w
                } else {
                    lr * ((t - s) / (t - w)).max(0.0)
                }
            }
        }
    }

    /// Parse `"const:0.001"`, `"isqrt:0.003:400"`,
    /// `"poly:0.0001:100:5000"`.
    pub fn parse(s: &str) -> crate::Result<LrSchedule> {
        let parts: Vec<&str> = s.split(':').collect();
        let bad = || crate::Error::Config(format!("bad lr schedule '{s}'"));
        let f = |x: &str| x.parse::<f64>().map_err(|_| bad());
        let u = |x: &str| x.parse::<u64>().map_err(|_| bad());
        match parts.as_slice() {
            ["const", lr] => Ok(LrSchedule::Constant { lr: f(lr)? }),
            ["isqrt", lr, w] => {
                Ok(LrSchedule::InverseSqrt { peak_lr: f(lr)?, warmup_steps: u(w)? })
            }
            ["poly", lr, w, t] => Ok(LrSchedule::Polynomial {
                lr: f(lr)?,
                warmup_steps: u(w)?,
                total_steps: u(t)?,
            }),
            _ => Err(bad()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant() {
        let s = LrSchedule::Constant { lr: 0.01 };
        assert_eq!(s.at(1), 0.01);
        assert_eq!(s.at(10_000), 0.01);
    }

    #[test]
    fn inverse_sqrt_warms_up_then_decays() {
        let s = LrSchedule::InverseSqrt { peak_lr: 1.0, warmup_steps: 100 };
        assert!((s.at(50) - 0.5).abs() < 1e-12);
        assert!((s.at(100) - 1.0).abs() < 1e-12);
        assert!((s.at(400) - 0.5).abs() < 1e-12); // sqrt(100/400) = 0.5
        assert!(s.at(401) < s.at(400));
    }

    #[test]
    fn polynomial_hits_zero_at_end() {
        let s = LrSchedule::Polynomial { lr: 1.0, warmup_steps: 10, total_steps: 100 };
        assert!((s.at(5) - 0.5).abs() < 1e-12);
        assert!((s.at(10) - 1.0).abs() < 1e-12);
        assert!((s.at(55) - 0.5).abs() < 1e-12);
        assert_eq!(s.at(100), 0.0);
        assert_eq!(s.at(200), 0.0);
    }

    #[test]
    fn parse_roundtrip() {
        assert_eq!(LrSchedule::parse("const:0.01").unwrap(), LrSchedule::Constant { lr: 0.01 });
        assert_eq!(
            LrSchedule::parse("isqrt:0.003:400").unwrap(),
            LrSchedule::InverseSqrt { peak_lr: 0.003, warmup_steps: 400 }
        );
        assert_eq!(
            LrSchedule::parse("poly:1e-4:100:5000").unwrap(),
            LrSchedule::Polynomial { lr: 1e-4, warmup_steps: 100, total_steps: 5000 }
        );
        assert!(LrSchedule::parse("bogus").is_err());
        assert!(LrSchedule::parse("isqrt:x:400").is_err());
    }

    #[test]
    fn never_negative() {
        for sched in [
            LrSchedule::Constant { lr: 0.1 },
            LrSchedule::InverseSqrt { peak_lr: 0.1, warmup_steps: 10 },
            LrSchedule::Polynomial { lr: 0.1, warmup_steps: 5, total_steps: 50 },
        ] {
            for step in 1..200 {
                assert!(sched.at(step) >= 0.0, "{sched:?} at {step}");
            }
        }
    }
}
