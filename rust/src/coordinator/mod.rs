//! L3 coordinator: training loops, the DSQ dynamic precision controller
//! glue, checkpoints, and the CLI surface.

pub mod cli;
pub mod finetune;
pub mod lr;
pub mod trainer;

pub use cli::dispatch;
pub use finetune::{FinetuneConfig, FinetuneReport, Finetuner};
pub use lr::LrSchedule;
pub use trainer::{TrainReport, Trainer, TrainerConfig};
