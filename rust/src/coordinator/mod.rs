//! L3 coordinator: the task-agnostic [`Session`] training engine, its
//! task adapters, the DSQ dynamic precision controller glue,
//! checkpoints, and the CLI surface.
//!
//! Architecture: one [`session::Session`] loop owns everything every
//! workload shares — bounded-prefetch batch production, per-step
//! artifact dispatch through a memoized executable cache
//! ([`session::ExeCache`]), precision-trace accumulation, divergence
//! abort, the stash-store hand-off (`--stash-state` packs the state
//! into a budgeted [`crate::stash::StashStore`]; `--stash-budget`
//! overflow spills to disk and prefetches back, with byte-accurate
//! traffic on the report), validation cadence (per-epoch or every N
//! steps), and mid-run/final checkpointing with resumable schedule
//! state. Per-workload behavior lives behind the [`session::Task`]
//! trait ([`session::NmtTask`] for translation, [`session::ClsTask`]
//! for classification); [`Trainer`] and [`Finetuner`] are thin
//! CLI-level adapters that build a `Session` from their configs. Both
//! produce one [`RunReport`] whose headline metric is tagged
//! ([`TaskMetric::Bleu`] / [`TaskMetric::Accuracy`]) and which scores
//! its schedule trace on any paper-scale workload via
//! [`RunReport::cost_on`].
//!
//! Adding a workload (SASQ-style calibrated activations, an FP8-LM
//! float recipe, …) is one new `Task` impl — batch supply, step/eval
//! input assembly, eval normalization, headline metric — not another
//! copy of the loop.
//!
//! Data-parallel replication (`--replicas N`) stays in this layer too,
//! hosted two ways behind one collective surface (`--transport`):
//! `--transport mem` (the default) has [`Trainer::run_replicated`] /
//! [`Finetuner::run_replicated`] spin up N sessions on threads wired to
//! one [`crate::stash::Exchange`] over the in-memory ring; `--transport
//! socket:<addr>` has [`worker::orchestrate`] bind a
//! [`crate::stash::SocketHub`], spawn N−1 `dsq worker` OS processes,
//! and host rank 0 in-parent, every rank exchanging versioned wire
//! frames over the socket. Either way each rank owns a
//! [`crate::stash::ReplicaShard`] of the batch stream and all-reduces
//! the post-step state in `--comms` packed records (dequant → mean →
//! requant at salt 0, so every rank lands on identical bytes). Metered
//! comms traffic rides the report as [`RunReport::comms`].

pub mod cli;
pub mod finetune;
pub mod lr;
pub mod session;
pub mod trainer;
pub mod worker;

pub use cli::dispatch;
pub use finetune::{FinetuneConfig, Finetuner};
pub use lr::LrSchedule;
pub use session::{
    next_global_batch, replica_consumes, ClsTask, ExeCache, NmtTask, RunReport, Session,
    SessionConfig, Task, TaskMetric,
};
pub use trainer::{Trainer, TrainerConfig};

use crate::schedule::PrecisionConfig;

/// Which train-artifact variant a precision config needs — delegated to
/// the artifact-side guard ([`crate::runtime::train_variant_for`]),
/// which owns the per-variant dispatch contract (single-family variants
/// apply their quantizer only on an exact mode match; cross-family
/// configs must run `train_both`).
pub fn train_artifact_kind(p: &PrecisionConfig) -> &'static str {
    crate::runtime::train_variant_for(p)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_kind_per_slot_families() {
        let kind = |s: &str| train_artifact_kind(&PrecisionConfig::parse(s).unwrap());
        assert_eq!(kind("fp32"), "train_bfp");
        assert_eq!(kind("bfp:16,4,4,16"), "train_bfp");
        assert_eq!(kind("fixed:8,8,8,16"), "train_fixed");
        assert_eq!(kind("fixedsr:8,8,8,16"), "train_fixed");
        assert_eq!(kind("bfp16,bfp4,bfp4,fixed16sr"), "train_both");
        assert_eq!(kind("fp32,bfp4,bfp4,bfp16"), "train_bfp");
        assert_eq!(kind("fp8e4m3,fp8e4m3,fp8e4m3,fp8e5m2"), "train_float");
        assert_eq!(kind("e4m3,bfp4,bfp4,fixed16sr"), "train_both");
    }
}
