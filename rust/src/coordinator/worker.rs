//! Multi-process replica orchestration: the `--transport socket` twin
//! of [`crate::stash::run_replicas`].
//!
//! One `dsq train`/`dsq finetune` invocation with `--transport
//! socket:<addr>` becomes N real OS processes sharing one collective:
//!
//! 1. [`orchestrate`] binds a [`SocketHub`] on the requested address
//!    (TCP port 0 lets the OS pick) and serves it on a thread;
//! 2. it spawns ranks `1..N` as `dsq worker --rank <r> --connect
//!    <addr> --replicas <n>` child processes of the same binary;
//! 3. rank 0 runs in-parent over its own connected
//!    [`SocketTransport`], so the orchestrator's report is rank 0's
//!    report exactly as on the thread path;
//! 4. each worker's handshake returns the CONFIG payload — the
//!    original subcommand argv as a JSON array — which the worker
//!    re-parses with the *same* CLI parser the orchestrator used, then
//!    builds its rank via `Trainer::replica` / `Finetuner::replica`.
//!    One parser, one config: the processes cannot drift.
//!
//! Teardown mirrors the in-memory contract: any rank's error calls
//! `Exchange::fail`, which puts an abort frame on the wire; the hub
//! broadcasts it, so every surviving process errors out with the
//! exchange's `ABORT_PREFIX` (carrying the originating message)
//! within the read timeout instead of hanging. A rank that dies
//! without a word (kill -9) closes its stream, which the hub treats
//! the same way.
//!
//! The `exchange-selftest` config runs the collective over a synthetic
//! deterministic state with no artifacts on disk — the process-level
//! e2e tests drive it to pin cross-transport bit-identity and
//! injected-failure teardown against real child processes.
//!
//! This module is deliberately lock-free: every blocking edge (socket
//! connects inside the transport, the hub join, child waits) runs with
//! no lock held, witnessed by [`ordwitness::assert_lock_free`].

use std::path::{Path, PathBuf};
use std::process::{Child, Command};
use std::sync::Arc;
use std::time::Instant;

use crate::model::ModelState;
use crate::obs::{Phase, Recorder, RunInfo};
use crate::quant::FormatSpec;
use crate::runtime::HostTensor;
use crate::stash::{Exchange, ReplicaExchange, SocketHub, SocketTransport, Transport};
use crate::util::cli::ArgSpec;
use crate::util::json::{self, Json};
use crate::util::ordwitness;
use crate::{Error, Result};

use super::finetune::Finetuner;
use super::trainer::Trainer;

/// The CONFIG payload: the orchestrator's subcommand argv as a JSON
/// array, broadcast verbatim to every worker at handshake.
fn config_payload(subcmd: &str, raw: &[String]) -> Vec<u8> {
    Json::arr(std::iter::once(subcmd).chain(raw.iter().map(String::as_str)).map(Json::str))
        .to_string()
        .into_bytes()
}

fn parse_config_argv(bytes: Vec<u8>) -> Result<Vec<String>> {
    let text = String::from_utf8(bytes)
        .map_err(|_| Error::Config("worker CONFIG payload is not UTF-8".into()))?;
    let doc = json::parse(&text)?;
    let arr = doc
        .as_arr()
        .ok_or_else(|| Error::Config(format!("worker CONFIG payload is not an argv array: {text}")))?;
    arr.iter()
        .map(|j| {
            j.as_str().map(str::to_string).ok_or_else(|| {
                Error::Config(format!("worker CONFIG argv holds a non-string entry: {text}"))
            })
        })
        .collect()
}

/// Run `rank`'s leg of the collective, tearing the exchange down on
/// error so no peer is left blocked — the per-process mirror of the
/// error handling inside [`crate::stash::run_replicas`].
fn run_rank<R>(
    ex: &Exchange,
    rank: usize,
    run: impl FnOnce(ReplicaExchange) -> Result<R>,
) -> Result<R> {
    let result = ex.handle(rank).and_then(run);
    if let Err(e) = &result {
        ex.fail(&format!("replica {rank} failed: {e}"));
    }
    result
}

/// Host a socket-transport replicated run: bind the hub on `addr`,
/// spawn ranks `1..replicas` as `exe worker …` child processes whose
/// CONFIG payload replays `subcmd` + `raw`, and run rank 0 in-parent
/// via `run0`. Returns rank 0's result once the hub and every child
/// have wound down; any rank's failure surfaces here with the
/// originating message (relayed through the hub's abort broadcast).
pub fn orchestrate<R>(
    exe: &Path,
    subcmd: &str,
    raw: &[String],
    addr: &str,
    replicas: usize,
    comms: FormatSpec,
    run0: impl FnOnce(ReplicaExchange) -> Result<R>,
) -> Result<R> {
    if replicas < 2 {
        return Err(Error::Config(format!(
            "socket orchestration needs at least 2 replicas (got {replicas})"
        )));
    }
    let hub = SocketHub::bind(addr, replicas, config_payload(subcmd, raw))?;
    let resolved = hub.addr().to_string();
    let hub_thread = std::thread::spawn(move || hub.serve());

    let mut children: Vec<(usize, Child)> = Vec::new();
    let mut spawn_failure: Option<Error> = None;
    for rank in 1..replicas {
        let spawned = Command::new(exe)
            .arg("worker")
            .arg("--rank")
            .arg(rank.to_string())
            .arg("--connect")
            .arg(&resolved)
            .arg("--replicas")
            .arg(replicas.to_string())
            .spawn();
        match spawned {
            Ok(c) => children.push((rank, c)),
            Err(e) => {
                spawn_failure = Some(Error::Config(format!(
                    "spawning worker rank {rank} ({}): {e}",
                    exe.display()
                )));
                break;
            }
        }
    }

    let rank0 = match spawn_failure {
        Some(e) => {
            // Rank 0 never connects; the already-spawned workers die
            // now and the hub's accept timeout tears the round down.
            for (_, c) in children.iter_mut() {
                let _ = c.kill();
            }
            Err(e)
        }
        None => match SocketTransport::connect(&resolved, 0, replicas) {
            Err(e) => Err(e),
            Ok((transport, _config)) => {
                let ex = Exchange::with_transport(comms, Arc::new(transport));
                run_rank(&ex, 0, run0)
                // `ex` (and with it rank 0's stream) drops here, so the
                // hub sees rank 0's clean EOF before we join it below.
            }
        },
    };

    ordwitness::assert_lock_free("joining the socket hub thread");
    let hub_result = hub_thread
        .join()
        .unwrap_or_else(|_| Err(Error::Config("socket hub panicked".into())));
    let mut child_failure: Option<Error> = None;
    for (rank, mut c) in children {
        ordwitness::assert_lock_free("waiting for a worker process to exit");
        match c.wait() {
            Ok(status) if status.success() => {}
            Ok(status) => {
                child_failure.get_or_insert(Error::Config(format!(
                    "worker rank {rank} exited with {status}"
                )));
            }
            Err(e) => {
                child_failure.get_or_insert(Error::Config(format!(
                    "waiting for worker rank {rank}: {e}"
                )));
            }
        }
    }

    // Rank 0's error already carries the originating failure (a worker
    // fault arrives as the relayed abort message); the hub and child
    // statuses only matter when rank 0 itself succeeded.
    let value = rank0?;
    hub_result?;
    if let Some(e) = child_failure {
        return Err(e);
    }
    Ok(value)
}

/// `dsq worker --rank <r> --connect <addr> --replicas <n>`: one spawned
/// replica of a `--transport socket` run. Not meant for hand-invocation
/// — the orchestrating `dsq train`/`dsq finetune` process spawns these
/// and supplies their config over the handshake.
pub fn cmd_worker(raw: &[String]) -> Result<()> {
    let spec = ArgSpec::new("worker", "socket-transport replica worker (spawned, not hand-run)")
        .req("rank", "this worker's replica rank (1..replicas; rank 0 runs in the orchestrator)")
        .req("connect", "hub address (unix socket path or host:port)")
        .req("replicas", "total replica count of the run");
    let a = spec.parse(raw)?;
    run_worker(a.get("connect"), a.get_usize("rank")?, a.get_usize("replicas")?)
}

fn run_worker(addr: &str, rank: usize, replicas: usize) -> Result<()> {
    let (transport, config) = SocketTransport::connect(addr, rank, replicas)?;
    let transport: Arc<dyn Transport> = Arc::new(transport);
    let argv = parse_config_argv(config)?;
    let (subcmd, rest) = argv
        .split_first()
        .ok_or_else(|| Error::Config("worker CONFIG argv is empty".into()))?;
    match subcmd.as_str() {
        "train" => {
            let (cfg, sched, _json) = super::cli::parse_train_cli(rest)?;
            check_replicas(cfg.replicas, replicas)?;
            let ex = Exchange::with_transport(cfg.comms, transport);
            run_rank(&ex, rank, |h| {
                let mut t = Trainer::replica(&cfg, rank)?;
                t.session().set_exchange(h)?;
                let mut schedule = super::cli::parse_schedule(&sched)?;
                t.run(schedule.as_mut())
            })?;
            Ok(())
        }
        "finetune" => {
            let (cfg, sched, _json) = super::cli::parse_finetune_cli(rest)?;
            check_replicas(cfg.replicas, replicas)?;
            let ex = Exchange::with_transport(cfg.comms, transport);
            run_rank(&ex, rank, |h| {
                let mut f = Finetuner::replica(&cfg, rank)?;
                f.session().set_exchange(h)?;
                let mut schedule = super::cli::parse_schedule(&sched)?;
                f.run(schedule.as_mut())
            })?;
            Ok(())
        }
        "exchange-selftest" => run_selftest_worker(rest, rank, transport),
        other => Err(Error::Config(format!(
            "worker CONFIG names unknown subcommand '{other}' (train | finetune | \
             exchange-selftest)"
        ))),
    }
}

/// The worker's config must describe the same world it was launched
/// into — a mismatch means the orchestrator and worker disagree.
fn check_replicas(cfg_replicas: usize, launched: usize) -> Result<()> {
    if cfg_replicas != launched {
        return Err(Error::Config(format!(
            "worker launched for {launched} replicas but its config says --replicas \
             {cfg_replicas}"
        )));
    }
    Ok(())
}

/// Flag schema for the `exchange-selftest` CONFIG — shared by the
/// worker side here and the process-level tests that drive it.
fn selftest_spec() -> ArgSpec {
    ArgSpec::new("exchange-selftest", "artifact-free collective check over a synthetic state")
        .opt("elems", "64", "elements in the synthetic parameter tensor")
        .opt("rounds", "3", "all-reduce rounds to run")
        .opt("comms", "fp32", "wire format for the exchange")
        .opt("die-rank", "", "rank that injects a failure (empty = nobody dies)")
        .opt("die-round", "0", "round before which --die-rank fails")
        .opt("trace", "", "telemetry directory — rank-tagged span trace + run manifest")
}

fn run_selftest_worker(rest: &[String], rank: usize, transport: Arc<dyn Transport>) -> Result<()> {
    let a = selftest_spec().parse(rest)?;
    let comms = FormatSpec::parse(a.get("comms"))?;
    let die_at = if a.get("die-rank") == rank.to_string().as_str() {
        Some(a.get_u64("die-round")?)
    } else {
        None
    };
    let elems = a.get_usize("elems")?;
    let rounds = a.get_u64("rounds")?;
    let trace_dir = Some(a.get("trace")).filter(|t| !t.is_empty()).map(PathBuf::from);
    let ex = Exchange::with_transport(comms, transport);
    let state = run_rank(&ex, rank, |h| {
        selftest_run_traced(h, elems, rounds, die_at, trace_dir.as_deref())
    })?;
    let digest = state
        .iter()
        .fold(0u64, |acc, v| acc.rotate_left(7) ^ u64::from(v.to_bits()));
    crate::info!("exchange-selftest rank {rank}: {rounds} rounds, state digest {digest:016x}");
    Ok(())
}

/// Deterministic synthetic state for the exchange selftest — identical
/// on every rank (the mirrored configuration), so fp32 comms must be
/// bit-transparent across any transport.
pub fn selftest_state(elems: usize) -> ModelState {
    let n = elems.max(1);
    let params = vec![
        HostTensor::f32(
            vec![n],
            (0..n).map(|i| (i as f32 * 0.37 - 3.0) * 1.5f32.powi(i as i32 % 7)).collect(),
        ),
        HostTensor::f32(vec![], vec![0.5]),
    ];
    let m: Vec<HostTensor> =
        params.iter().map(|t| HostTensor::f32(t.shape.clone(), vec![0.25; t.len()])).collect();
    let v: Vec<HostTensor> = params.iter().map(HostTensor::zeros_like).collect();
    ModelState { params, m, v, step: 7 }
}

/// Flattened `(params, m, v)` view — what the selftest's bit-identity
/// assertions compare across transports.
pub fn flat_state(state: &ModelState) -> Result<Vec<f32>> {
    let mut out = Vec::new();
    for group in [&state.params, &state.m, &state.v] {
        for t in group {
            out.extend_from_slice(t.as_f32()?);
        }
    }
    Ok(out)
}

/// One rank's selftest leg: `rounds` all-reduce rounds over
/// [`selftest_state`], returning the flattened final state. `die_at`
/// injects a failure before posting that round — the process-level
/// teardown tests' fault hook.
pub fn selftest_run(
    ex: ReplicaExchange,
    elems: usize,
    rounds: u64,
    die_at: Option<u64>,
) -> Result<Vec<f32>> {
    selftest_run_traced(ex, elems, rounds, die_at, None)
}

/// [`selftest_run`] with optional telemetry (`--trace`): one `exchange`
/// span per round, with the wire-byte deltas and the encode/post/reduce
/// sub-phases imported from the handle's counters; on success the rank
/// writes its `trace.rank<N>.jsonl` + `run.rank<N>.json` into
/// `trace_dir` (see [`crate::obs`]). The manifest's wall clock is the
/// round loop itself, so the exchange spans account for essentially all
/// of it — what the socket-transport e2e asserts.
pub fn selftest_run_traced(
    ex: ReplicaExchange,
    elems: usize,
    rounds: u64,
    die_at: Option<u64>,
    trace_dir: Option<&Path>,
) -> Result<Vec<f32>> {
    let obs = match trace_dir {
        Some(dir) => Recorder::to_dir(dir, ex.rank())?,
        None => Recorder::disabled(),
    };
    let mut state = selftest_state(elems);
    let start = Instant::now();
    for round in 0..rounds {
        if die_at == Some(round) {
            return Err(Error::Config(format!(
                "replica {} injected a selftest fault before round {round}",
                ex.rank()
            )));
        }
        let c0 = obs.is_active().then(|| ex.counter_snapshot());
        let span = obs.span_start(Phase::Exchange);
        ex.all_reduce_state(&mut state, 1.0)?;
        if let Some(c0) = c0 {
            let c1 = ex.counter_snapshot();
            obs.span_close(
                span,
                round + 1,
                (c1.tx_bytes - c0.tx_bytes) + (c1.rx_bytes - c0.rx_bytes),
            );
            obs.span_import(
                Phase::ExchEncode,
                round + 1,
                c1.encode_ns - c0.encode_ns,
                c1.tx_bytes - c0.tx_bytes,
            );
            obs.span_import(
                Phase::ExchPost,
                round + 1,
                c1.post_ns - c0.post_ns,
                c1.frame_bytes - c0.frame_bytes,
            );
            obs.span_import(
                Phase::ExchReduce,
                round + 1,
                c1.reduce_ns - c0.reduce_ns,
                c1.rx_bytes - c0.rx_bytes,
            );
        } else {
            obs.span_close(span, round + 1, 0);
        }
    }
    obs.finish_run(&RunInfo {
        argv: std::env::args().collect(),
        config: Json::obj(vec![
            ("elems", Json::num(elems as f64)),
            ("rounds", Json::num(rounds as f64)),
            ("replicas", Json::num(ex.replicas() as f64)),
            ("comms", Json::str(&ex.spec().spec_string())),
        ]),
        steps: rounds,
        wall_s: start.elapsed().as_secs_f64(),
        stash: None,
        comms: Some(ex.traffic_report().to_json()),
        ladder: Vec::new(),
    })?;
    flat_state(&state)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stash::{run_replicas, ABORT_PREFIX};

    #[test]
    fn config_payload_roundtrips_through_json() {
        let raw = vec!["--elems".to_string(), "8".to_string(), "--comms".to_string(), "fp32".to_string()];
        let argv = parse_config_argv(config_payload("exchange-selftest", &raw)).unwrap();
        assert_eq!(argv[0], "exchange-selftest");
        assert_eq!(&argv[1..], raw.as_slice());
        assert!(parse_config_argv(b"{\"not\": \"an array\"}".to_vec()).is_err());
        assert!(parse_config_argv(vec![0xFF, 0xFE]).is_err());
    }

    #[test]
    fn selftest_is_bit_transparent_over_the_mem_transport() {
        // Mirrored fp32 all-reduce must leave the selftest state
        // untouched — the same invariant the socket e2e pins against
        // real processes, here on the default transport.
        let want = flat_state(&selftest_state(16)).unwrap();
        let got = run_replicas(2, FormatSpec::Fp32, |_rank, ex| selftest_run(ex, 16, 3, None))
            .unwrap();
        assert_eq!(got, want, "mirrored fp32 selftest must be bit-transparent");
    }

    #[test]
    fn selftest_die_at_injects_a_teardown() {
        let err = run_replicas(2, FormatSpec::Fp32, |rank, ex| {
            selftest_run(ex, 8, 2, (rank == 1).then_some(1))
        })
        .unwrap_err()
        .to_string();
        assert!(err.contains("injected a selftest fault"), "originating fault must win: {err}");
        assert!(!err.contains(ABORT_PREFIX), "not the secondary barrier abort: {err}");
    }

    #[test]
    fn orchestrate_rejects_a_single_replica() {
        let err = orchestrate(
            Path::new("/nonexistent-dsq"),
            "exchange-selftest",
            &[],
            "127.0.0.1:0",
            1,
            FormatSpec::Fp32,
            |_h| Ok(()),
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("at least 2 replicas"), "{err}");
    }

    #[test]
    fn selftest_flags_parse_with_defaults() {
        let a = selftest_spec().parse(&[]).unwrap();
        assert_eq!(a.get_usize("elems").unwrap(), 64);
        assert_eq!(a.get_u64("rounds").unwrap(), 3);
        assert_eq!(a.get("comms"), "fp32");
        assert_eq!(a.get("die-rank"), "");
    }
}
