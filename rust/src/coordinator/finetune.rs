//! Fine-tuning adapter: [`Finetuner`] maps the CLI-level
//! [`FinetuneConfig`] onto the generic [`Session`] engine with a
//! [`ClsTask`] (synthetic entailment corpus, accuracy headline metric).
//!
//! The "pre-train then fine-tune" paradigm is reproduced by
//! initializing from a checkpoint of a *previous* run on a different
//! task instance (`--init-checkpoint`), exactly how the paper
//! fine-tunes RoBERTa-base with DSQ precision schedules. Everything
//! else — including the prefetch generator thread the fine-tuner
//! historically lacked — comes from [`super::session`].

use std::path::PathBuf;

use crate::data::classify::{ClassifyConfig, ClassifyTask};
use crate::model::ModelState;
use crate::runtime::ArtifactManifest;
use crate::schedule::{FormatSpec, Schedule};
use crate::stash::{run_replicas, ReplicaShard, StashBudget, TransportSpec};
use crate::{Error, Result};

use super::lr::LrSchedule;
use super::session::{ClsTask, RunReport, Session, SessionConfig};

/// Fine-tune configuration.
#[derive(Clone, Debug)]
pub struct FinetuneConfig {
    pub artifacts: PathBuf,
    pub seed: u64,
    pub epochs: usize,
    pub batches_per_epoch: usize,
    pub lr: LrSchedule,
    /// 2 = QNLI-style, 3 = MNLI-style. Must be <= the artifact's
    /// `nclasses` (labels above the artifact head size are impossible).
    pub nclasses: usize,
    pub val_batches: usize,
    /// Also validate every N steps (0 = per-epoch only).
    pub val_every_steps: usize,
    pub checkpoint: Option<PathBuf>,
    /// Save `checkpoint` every N steps mid-run (0 = final save only;
    /// crash-salvage semantics — see
    /// [`SessionConfig::checkpoint_every_steps`]).
    pub checkpoint_every_steps: usize,
    pub init_checkpoint: Option<PathBuf>,
    /// Bounded prefetch depth for the batch generator thread (≥ 1).
    pub prefetch: usize,
    /// Hold the tuner state packed in this format between steps (see
    /// [`SessionConfig::stash_format`]); `None` = dense f32.
    pub stash_format: Option<FormatSpec>,
    /// Resident byte budget for the packed stash (see
    /// [`SessionConfig::stash_budget`]).
    pub stash_budget: StashBudget,
    /// Spill-segment / index directory (see
    /// [`SessionConfig::stash_dir`]); `None` = per-run temp dir.
    pub stash_dir: Option<PathBuf>,
    /// In-process data-parallel replica count (`--replicas`; 1 = the
    /// single-replica path, bit-for-bit today's behavior). Replicated
    /// runs go through [`Finetuner::run_replicated`].
    pub replicas: usize,
    /// Packed format the replicas exchange state in (`--comms`); only
    /// meaningful when `replicas > 1`.
    pub comms: FormatSpec,
    /// Mirror the batch stream across replicas instead of round-robin
    /// sharding it (see [`crate::stash::ReplicaShard::mirror`]).
    pub mirror_replicas: bool,
    /// How replicas exchange state (`--transport`): `mem` (default)
    /// runs them as threads over the in-memory ring via
    /// [`Finetuner::run_replicated`]; `socket:<addr>` runs them as OS
    /// processes — the CLI's `worker` orchestration owns that path
    /// and builds each rank with [`Finetuner::replica`].
    pub transport: TransportSpec,
    /// Telemetry directory (`--trace`): each rank writes
    /// `trace.rank<N>.jsonl` + `run.rank<N>.json` here (see
    /// [`crate::obs`]). `None` = tracing disabled.
    pub trace_dir: Option<PathBuf>,
}

impl FinetuneConfig {
    pub fn quick(artifacts: PathBuf) -> Self {
        FinetuneConfig {
            artifacts,
            seed: 0,
            epochs: 2,
            batches_per_epoch: 20,
            lr: LrSchedule::Polynomial { lr: 1e-3, warmup_steps: 10, total_steps: 2000 },
            nclasses: 3,
            val_batches: 4,
            val_every_steps: 0,
            checkpoint: None,
            checkpoint_every_steps: 0,
            init_checkpoint: None,
            prefetch: 4,
            stash_format: None,
            stash_budget: StashBudget::Unlimited,
            stash_dir: None,
            replicas: 1,
            comms: FormatSpec::Fp32,
            mirror_replicas: false,
            transport: TransportSpec::Mem,
            trace_dir: None,
        }
    }

    fn session_config(&self) -> SessionConfig {
        SessionConfig {
            artifacts: self.artifacts.clone(),
            seed: self.seed,
            epochs: self.epochs,
            batches_per_epoch: self.batches_per_epoch,
            lr: self.lr.clone(),
            val_batches: self.val_batches,
            val_every_steps: self.val_every_steps,
            checkpoint: self.checkpoint.clone(),
            init_checkpoint: self.init_checkpoint.clone(),
            checkpoint_every_steps: self.checkpoint_every_steps,
            prefetch: self.prefetch,
            stash_format: self.stash_format,
            stash_budget: self.stash_budget,
            stash_dir: self.stash_dir.clone(),
            shard: None,
            trace_dir: self.trace_dir.clone(),
        }
    }

    /// Per-rank view of a replicated config: rank 0 keeps checkpointing;
    /// peers only train. Spill directories get a per-rank suffix so
    /// replicas never share index files (the trace dir is shared — obs
    /// files are rank-tagged).
    fn for_rank(&self, rank: usize) -> Self {
        let mut cfg = self.clone();
        if self.replicas > 1 {
            if rank != 0 {
                cfg.checkpoint = None;
                cfg.checkpoint_every_steps = 0;
            }
            cfg.stash_dir = self.stash_dir.as_ref().map(|d| d.join(format!("rank{rank}")));
        }
        cfg
    }

    fn shard_for(&self, rank: usize) -> Option<ReplicaShard> {
        (self.replicas > 1).then_some(ReplicaShard {
            rank,
            replicas: self.replicas,
            mirror: self.mirror_replicas,
        })
    }
}

/// The classifier fine-tuner: a [`Session`] over [`ClsTask`].
pub struct Finetuner {
    pub cfg: FinetuneConfig,
    session: Session<ClsTask>,
}

impl Finetuner {
    pub fn new(cfg: FinetuneConfig) -> Result<Self> {
        Self::with_shard(cfg, None)
    }

    /// Build rank `rank`'s view of a replicated run — the per-rank
    /// config plus its batch shard — without deciding how the ranks
    /// are hosted. The thread path ([`Finetuner::run_replicated`]) and
    /// the multi-process `worker` orchestration both build replicas
    /// through here, so the two transports train identical sessions.
    pub fn replica(cfg: &FinetuneConfig, rank: usize) -> Result<Self> {
        Self::with_shard(cfg.for_rank(rank), cfg.shard_for(rank))
    }

    fn with_shard(cfg: FinetuneConfig, shard: Option<ReplicaShard>) -> Result<Self> {
        let man = ArtifactManifest::load(&cfg.artifacts)?;
        let (b, l, v, ncls) = (
            man.cls.cfg("batch")?,
            man.cls.cfg("seq_len")?,
            man.cls.cfg("vocab")?,
            man.cls.cfg("nclasses")?,
        );
        if cfg.nclasses > ncls {
            return Err(Error::Config(format!(
                "--nclasses {} exceeds artifact head size {ncls}",
                cfg.nclasses
            )));
        }
        let task = ClsTask {
            task: ClassifyTask::new(ClassifyConfig {
                vocab: v as i32,
                seq_len: l,
                nclasses: cfg.nclasses,
                seed: cfg.seed,
            }),
            batch: b,
            seq_len: l,
            seed: cfg.seed,
        };
        let mut scfg = cfg.session_config();
        scfg.shard = shard;
        let session = Session::new(scfg, task, man)?;
        Ok(Finetuner { cfg, session })
    }

    /// Run `cfg.replicas` in-process data-parallel replicas, exchanging
    /// state in `cfg.comms` packed records after every step (see
    /// [`crate::stash::exchange`]). `replicas <= 1` is exactly
    /// [`Finetuner::new`] + [`Finetuner::run`] — today's path,
    /// bit-for-bit. Rank 0's report is returned, with
    /// [`RunReport::comms`] carrying the metered exchange traffic.
    pub fn run_replicated(
        cfg: FinetuneConfig,
        make_schedule: impl Fn() -> Result<Box<dyn Schedule>> + Sync,
    ) -> Result<RunReport> {
        if cfg.replicas <= 1 {
            let mut f = Finetuner::new(cfg)?;
            let mut schedule = make_schedule()?;
            return f.run(schedule.as_mut());
        }
        if cfg.transport.is_socket() {
            // Process orchestration (hub + spawned `dsq worker`s) is
            // the CLI's job — reaching here means a caller skipped it.
            return Err(Error::Config(format!(
                "transport {} needs the multi-process worker orchestration \
                 (run through the dsq CLI); run_replicated only hosts --transport mem",
                cfg.transport
            )));
        }
        run_replicas(cfg.replicas, cfg.comms, |rank, ex| {
            let mut f = Finetuner::replica(&cfg, rank)?;
            f.session().set_exchange(ex)?;
            let mut schedule = make_schedule()?;
            f.run(schedule.as_mut())
        })
    }

    pub fn manifest(&self) -> &ArtifactManifest {
        self.session.manifest()
    }

    pub fn state(&self) -> &ModelState {
        self.session.state()
    }

    /// The underlying engine (e.g. for [`Session::evaluate`]).
    pub fn session(&mut self) -> &mut Session<ClsTask> {
        &mut self.session
    }

    /// Run fine-tuning under `schedule`.
    pub fn run(&mut self, schedule: &mut dyn Schedule) -> Result<RunReport> {
        self.session.run(schedule)
    }
}
