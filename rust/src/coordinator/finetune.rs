//! Fine-tuning coordinator for the classifier (the paper's GLUE setup).
//!
//! Mirrors [`super::trainer`] for the encoder-classifier artifacts. The
//! "pre-train then fine-tune" paradigm is reproduced by initializing
//! from a checkpoint of a *previous* run on a different task instance
//! (`--init-checkpoint`), exactly how the paper fine-tunes RoBERTa-base
//! with DSQ precision schedules.

use std::path::PathBuf;
use std::time::Instant;

use crate::data::classify::{ClassifyConfig, ClassifyTask};
use crate::data::batcher::{assemble_cls, ClsBatch};
use crate::metrics::LossTracker;
use crate::model::{checkpoint, ModelState};
use crate::runtime::{ArtifactManifest, HostTensor, Runtime};
use crate::schedule::{FormatSpec, PrecisionConfig, Schedule};
use crate::util::json::Json;
use crate::util::rng::Pcg32;
use crate::{Error, Result};

use super::lr::LrSchedule;

/// Fine-tune configuration.
#[derive(Clone, Debug)]
pub struct FinetuneConfig {
    pub artifacts: PathBuf,
    pub seed: u64,
    pub epochs: usize,
    pub batches_per_epoch: usize,
    pub lr: LrSchedule,
    /// 2 = QNLI-style, 3 = MNLI-style. Must be <= the artifact's
    /// `nclasses` (labels above the artifact head size are impossible).
    pub nclasses: usize,
    pub val_batches: usize,
    pub checkpoint: Option<PathBuf>,
    pub init_checkpoint: Option<PathBuf>,
    /// Hold the tuner state physically packed in this format between
    /// steps (see `TrainerConfig::stash_format`); `None` = dense f32.
    pub stash_format: Option<FormatSpec>,
}

impl FinetuneConfig {
    pub fn quick(artifacts: PathBuf) -> Self {
        FinetuneConfig {
            artifacts,
            seed: 0,
            epochs: 2,
            batches_per_epoch: 20,
            lr: LrSchedule::Polynomial { lr: 1e-3, warmup_steps: 10, total_steps: 2000 },
            nclasses: 3,
            val_batches: 4,
            checkpoint: None,
            init_checkpoint: None,
            stash_format: None,
        }
    }
}

/// Result of a fine-tuning run.
#[derive(Clone, Debug)]
pub struct FinetuneReport {
    pub steps: u64,
    pub final_val_loss: f64,
    pub final_accuracy: f64,
    pub diverged: bool,
    pub trace: Vec<(PrecisionConfig, usize)>,
    pub val_curve: Vec<(u64, f64)>,
    pub schedule_desc: String,
    pub wall_s: f64,
}

impl FinetuneReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("steps", Json::num(self.steps as f64)),
            ("final_val_loss", Json::num(self.final_val_loss)),
            ("final_accuracy", Json::num(self.final_accuracy)),
            ("diverged", Json::Bool(self.diverged)),
            ("schedule", Json::str(&self.schedule_desc)),
            ("wall_s", Json::num(self.wall_s)),
            (
                "trace",
                Json::arr(self.trace.iter().map(|(p, n)| {
                    Json::obj(vec![
                        ("precision", Json::str(&p.notation())),
                        ("formats", Json::str(&p.spec_string())),
                        ("steps", Json::num(*n as f64)),
                    ])
                })),
            ),
        ])
    }
}

/// The classifier fine-tuner.
pub struct Finetuner {
    pub cfg: FinetuneConfig,
    man: ArtifactManifest,
    task: ClassifyTask,
    state: ModelState,
    batch: usize,
    seq_len: usize,
}

impl Finetuner {
    pub fn new(cfg: FinetuneConfig) -> Result<Self> {
        let man = ArtifactManifest::load(&cfg.artifacts)?;
        let (b, l, v, ncls) = (
            man.cls.cfg("batch")?,
            man.cls.cfg("seq_len")?,
            man.cls.cfg("vocab")?,
            man.cls.cfg("nclasses")?,
        );
        if cfg.nclasses > ncls {
            return Err(Error::Config(format!(
                "--nclasses {} exceeds artifact head size {ncls}",
                cfg.nclasses
            )));
        }
        let task = ClassifyTask::new(ClassifyConfig {
            vocab: v as i32,
            seq_len: l,
            nclasses: cfg.nclasses,
            seed: cfg.seed,
        });
        let rt = Runtime::global();
        let mut state = match &cfg.init_checkpoint {
            Some(path) => checkpoint::load_checkpoint(path, &man.cls)?,
            None => ModelState::init(rt, &man, "cls", cfg.seed as i32)?,
        };
        if let Some(spec) = &cfg.stash_format {
            state.pack_state(spec)?;
        }
        Ok(Finetuner { batch: b, seq_len: l, cfg, man, task, state })
    }

    pub fn state(&self) -> &ModelState {
        &self.state
    }

    pub fn manifest(&self) -> &ArtifactManifest {
        &self.man
    }

    fn make_batch(&self, rng: &mut Pcg32) -> ClsBatch {
        let exs: Vec<_> = (0..self.batch).map(|_| self.task.sample(rng)).collect();
        assemble_cls(&exs, self.seq_len)
    }

    /// Mean loss + accuracy over batches.
    pub fn evaluate(&self, batches: &[ClsBatch]) -> Result<(f64, f64)> {
        let exe = Runtime::global().load(&self.man.model_path("cls", "eval")?)?;
        let (mut loss_sum, mut ncorrect, mut total) = (0f64, 0f64, 0f64);
        for batch in batches {
            let mut inputs = self.state.params.clone();
            inputs.push(HostTensor::i32(vec![self.batch, self.seq_len], batch.tokens.clone()));
            inputs.push(HostTensor::i32(vec![self.batch], batch.labels.clone()));
            let outs = exe.run(&inputs)?;
            loss_sum += outs[0].item_f32()? as f64;
            ncorrect += outs[1].item_f32()? as f64;
            total += outs[2].item_f32()? as f64;
        }
        Ok((loss_sum / batches.len().max(1) as f64, ncorrect / total.max(1.0)))
    }

    /// Run fine-tuning under `schedule`.
    pub fn run(&mut self, schedule: &mut dyn Schedule) -> Result<FinetuneReport> {
        let rt = Runtime::global();
        let start = Instant::now();
        let mut tracker = LossTracker::new();
        let mut trace: Vec<(PrecisionConfig, usize)> = Vec::new();
        let mut val_curve = Vec::new();
        let mut diverged = false;

        let mut vrng = self.task.split_rng("valid");
        let val_set: Vec<ClsBatch> =
            (0..self.cfg.val_batches).map(|_| self.make_batch(&mut vrng)).collect();

        'epochs: for epoch in 0..self.cfg.epochs {
            let mut rng =
                Pcg32::new(self.cfg.seed ^ ((epoch as u64 + 1) << 32) ^ 0xF17E);
            for _ in 0..self.cfg.batches_per_epoch {
                let batch = self.make_batch(&mut rng);
                let pc = schedule.current();
                let exe =
                    rt.load(&self.man.model_path("cls", super::train_artifact_kind(&pc))?)?;
                let lr = self.cfg.lr.at(self.state.step + 1) as f32;
                let mut inputs = Vec::with_capacity(3 * self.state.params.len() + 5);
                inputs.extend(self.state.params.iter().cloned());
                inputs.extend(self.state.m.iter().cloned());
                inputs.extend(self.state.v.iter().cloned());
                inputs.push(HostTensor::scalar_f32((self.state.step + 1) as f32));
                inputs.push(HostTensor::i32(
                    vec![self.batch, self.seq_len],
                    batch.tokens.clone(),
                ));
                inputs.push(HostTensor::i32(vec![self.batch], batch.labels.clone()));
                inputs.push(HostTensor::f32(vec![8], pc.as_qcfg().to_vec()));
                inputs.push(HostTensor::scalar_f32(lr));
                let outs = exe.run(&inputs)?;
                let loss = self.state.absorb_step_output(outs)? as f64;
                // Re-stash the resident state into packed storage.
                if let Some(spec) = &self.cfg.stash_format {
                    self.state.pack_state(spec)?;
                }
                tracker.record(self.state.step, loss);
                match trace.last_mut() {
                    Some((last, n)) if *last == pc => *n += 1,
                    _ => trace.push((pc, 1)),
                }
                if tracker.diverged() {
                    diverged = true;
                    crate::warn!("fine-tuning diverged at step {}", self.state.step);
                    break 'epochs;
                }
            }
            let (val_loss, val_acc) = self.evaluate(&val_set)?;
            val_curve.push((self.state.step, val_loss));
            schedule.observe_validation(val_loss);
            crate::info!(
                "epoch {epoch}: val {val_loss:.4} acc {:.1}% | {}",
                val_acc * 100.0,
                schedule.describe()
            );
        }

        let (final_val_loss, final_accuracy) = self.evaluate(&val_set)?;
        if let Some(path) = &self.cfg.checkpoint {
            checkpoint::save_checkpoint(path, &self.state, &self.man.cls)?;
            crate::info!("checkpoint saved to {path:?}");
        }
        Ok(FinetuneReport {
            steps: self.state.step,
            final_val_loss,
            final_accuracy,
            diverged,
            trace,
            val_curve,
            schedule_desc: schedule.describe(),
            wall_s: start.elapsed().as_secs_f64(),
        })
    }
}
