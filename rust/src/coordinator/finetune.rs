//! Fine-tuning adapter: [`Finetuner`] maps the CLI-level
//! [`FinetuneConfig`] onto the generic [`Session`] engine with a
//! [`ClsTask`] (synthetic entailment corpus, accuracy headline metric).
//!
//! The "pre-train then fine-tune" paradigm is reproduced by
//! initializing from a checkpoint of a *previous* run on a different
//! task instance (`--init-checkpoint`), exactly how the paper
//! fine-tunes RoBERTa-base with DSQ precision schedules. Everything
//! else — including the prefetch generator thread the fine-tuner
//! historically lacked — comes from [`super::session`].

use std::path::PathBuf;

use crate::data::classify::{ClassifyConfig, ClassifyTask};
use crate::model::ModelState;
use crate::runtime::ArtifactManifest;
use crate::schedule::{FormatSpec, Schedule};
use crate::stash::StashBudget;
use crate::{Error, Result};

use super::lr::LrSchedule;
use super::session::{ClsTask, RunReport, Session, SessionConfig};

/// Fine-tune configuration.
#[derive(Clone, Debug)]
pub struct FinetuneConfig {
    pub artifacts: PathBuf,
    pub seed: u64,
    pub epochs: usize,
    pub batches_per_epoch: usize,
    pub lr: LrSchedule,
    /// 2 = QNLI-style, 3 = MNLI-style. Must be <= the artifact's
    /// `nclasses` (labels above the artifact head size are impossible).
    pub nclasses: usize,
    pub val_batches: usize,
    /// Also validate every N steps (0 = per-epoch only).
    pub val_every_steps: usize,
    pub checkpoint: Option<PathBuf>,
    /// Save `checkpoint` every N steps mid-run (0 = final save only;
    /// crash-salvage semantics — see
    /// [`SessionConfig::checkpoint_every_steps`]).
    pub checkpoint_every_steps: usize,
    pub init_checkpoint: Option<PathBuf>,
    /// Bounded prefetch depth for the batch generator thread (≥ 1).
    pub prefetch: usize,
    /// Hold the tuner state packed in this format between steps (see
    /// [`SessionConfig::stash_format`]); `None` = dense f32.
    pub stash_format: Option<FormatSpec>,
    /// Resident byte budget for the packed stash (see
    /// [`SessionConfig::stash_budget`]).
    pub stash_budget: StashBudget,
    /// Spill-segment / index directory (see
    /// [`SessionConfig::stash_dir`]); `None` = per-run temp dir.
    pub stash_dir: Option<PathBuf>,
}

impl FinetuneConfig {
    pub fn quick(artifacts: PathBuf) -> Self {
        FinetuneConfig {
            artifacts,
            seed: 0,
            epochs: 2,
            batches_per_epoch: 20,
            lr: LrSchedule::Polynomial { lr: 1e-3, warmup_steps: 10, total_steps: 2000 },
            nclasses: 3,
            val_batches: 4,
            val_every_steps: 0,
            checkpoint: None,
            checkpoint_every_steps: 0,
            init_checkpoint: None,
            prefetch: 4,
            stash_format: None,
            stash_budget: StashBudget::Unlimited,
            stash_dir: None,
        }
    }

    fn session_config(&self) -> SessionConfig {
        SessionConfig {
            artifacts: self.artifacts.clone(),
            seed: self.seed,
            epochs: self.epochs,
            batches_per_epoch: self.batches_per_epoch,
            lr: self.lr.clone(),
            val_batches: self.val_batches,
            val_every_steps: self.val_every_steps,
            checkpoint: self.checkpoint.clone(),
            init_checkpoint: self.init_checkpoint.clone(),
            checkpoint_every_steps: self.checkpoint_every_steps,
            prefetch: self.prefetch,
            stash_format: self.stash_format,
            stash_budget: self.stash_budget,
            stash_dir: self.stash_dir.clone(),
        }
    }
}

/// The classifier fine-tuner: a [`Session`] over [`ClsTask`].
pub struct Finetuner {
    pub cfg: FinetuneConfig,
    session: Session<ClsTask>,
}

impl Finetuner {
    pub fn new(cfg: FinetuneConfig) -> Result<Self> {
        let man = ArtifactManifest::load(&cfg.artifacts)?;
        let (b, l, v, ncls) = (
            man.cls.cfg("batch")?,
            man.cls.cfg("seq_len")?,
            man.cls.cfg("vocab")?,
            man.cls.cfg("nclasses")?,
        );
        if cfg.nclasses > ncls {
            return Err(Error::Config(format!(
                "--nclasses {} exceeds artifact head size {ncls}",
                cfg.nclasses
            )));
        }
        let task = ClsTask {
            task: ClassifyTask::new(ClassifyConfig {
                vocab: v as i32,
                seq_len: l,
                nclasses: cfg.nclasses,
                seed: cfg.seed,
            }),
            batch: b,
            seq_len: l,
            seed: cfg.seed,
        };
        let session = Session::new(cfg.session_config(), task, man)?;
        Ok(Finetuner { cfg, session })
    }

    pub fn manifest(&self) -> &ArtifactManifest {
        self.session.manifest()
    }

    pub fn state(&self) -> &ModelState {
        self.session.state()
    }

    /// The underlying engine (e.g. for [`Session::evaluate`]).
    pub fn session(&mut self) -> &mut Session<ClsTask> {
        &mut self.session
    }

    /// Run fine-tuning under `schedule`.
    pub fn run(&mut self, schedule: &mut dyn Schedule) -> Result<RunReport> {
        self.session.run(schedule)
    }
}
