//! CLI dispatch for the `dsq` binary.
//!
//! ```text
//! dsq train       --schedule dsq|dsq-<family>|<config-spec> ...
//! dsq finetune    --nclasses 2|3 --init-checkpoint ...
//! dsq cost-table  --workload iwslt|wmt|roberta|testbed
//! dsq roofline    --machine a100|edge
//! dsq experiment  table1-iwslt|table1-glue|table4|table5|table6|figure1|all
//! dsq formats     (registered number formats + spec grammar)
//! dsq info        (artifact manifest summary)
//! dsq version
//! ```
//!
//! Config specs go through the format registry: `fp32`, `bfp8`,
//! `bfp:16,4,4,16`, `bfp16,bfp4,bfp4,fixed16sr`, … (see `dsq formats`).

use std::path::PathBuf;

use crate::costmodel::{self, TransformerWorkload, WorkloadKind};
use crate::data::Variant;
use crate::schedule::{DsqController, FormatSpec, PrecisionConfig, Schedule, StaticSchedule};
use crate::stash::{self, StashBudget, TransportSpec};
use crate::util::cli::{ArgSpec, Args};
use crate::util::json::Json;
use crate::{Error, Result};

use super::finetune::{FinetuneConfig, Finetuner};
use super::lr::LrSchedule;
use super::trainer::{Trainer, TrainerConfig};

/// Dispatch a raw argument list; returns the process exit code.
pub fn dispatch(args: &[String]) -> i32 {
    let (cmd, rest) = match args.split_first() {
        Some((c, r)) => (c.as_str(), r),
        None => ("help", &[][..]),
    };
    let result = match cmd {
        "train" => cmd_train(rest),
        "finetune" => cmd_finetune(rest),
        "cost-table" => cmd_cost_table(rest),
        "roofline" => cmd_roofline(rest),
        "experiment" => cmd_experiment(rest),
        "formats" => cmd_formats(),
        "lint" => cmd_lint(rest),
        "bench" => cmd_bench(rest),
        "stash" => cmd_stash(rest),
        "trace" => cmd_trace(rest),
        "worker" => super::worker::cmd_worker(rest),
        "info" => cmd_info(rest),
        "version" => {
            println!("dsq {} — Dynamic Stashing Quantization trainer", env!("CARGO_PKG_VERSION"));
            Ok(())
        }
        "help" | "--help" | "-h" => {
            print!("{}", HELP);
            Ok(())
        }
        other => Err(Error::Config(format!("unknown subcommand '{other}'\n{HELP}"))),
    };
    match result {
        Ok(()) => 0,
        Err(Error::Config(msg)) => {
            crate::error!("{msg}");
            2
        }
        Err(e) => {
            crate::error!("error: {e}");
            1
        }
    }
}

const HELP: &str = "dsq — Dynamic Stashing Quantization for Efficient Transformer Training

subcommands:
  train        train the seq2seq model on the synthetic translation task
  finetune     fine-tune the classifier (GLUE-style)
  cost-table   print the paper's Arith/DRAM cost columns for a workload
  roofline     print Figure 1 (roofline placements)
  experiment   regenerate a paper table/figure (table1-iwslt, table1-glue,
               table4, table5, table6, figure1, all)
  formats      list the registered number formats (the --schedule grammar)
  lint         check the cross-layer invariants (registry coverage,
               rust/python qcfg sync, magic constants, panic hygiene,
               call-graph lock discipline + blocking-under-lock, lint
               self-consistency); dsq lint [--root <repo-dir>] [--json]
               [--github] — --json prints a machine-readable report,
               --github prints ::error annotations for PR diffs
  bench        gate BENCH_*.json smoke reports against committed baselines
               (dsq bench gate [--ratio r] | dsq bench publish)
  stash        inspect a stash-store run dir (per-slot residency + traffic)
  trace        analyze a --trace telemetry dir: per-phase step-time breakdown,
               share of step, cross-rank skew, modeled-vs-observed traffic
  worker       socket-transport replica worker: dsq worker --rank <r>
               --connect <addr> --replicas <n>; spawned automatically by a
               --transport socket:<addr> run, not meant for hand-invocation
  info         artifact manifest summary
  version      print version

train and finetune share one task-agnostic Session engine: bounded
batch prefetch (--prefetch), validation per epoch or every N steps
(--val-every), mid-run checkpoints (--checkpoint-every), and resumable
schedule state — a checkpoint saved mid-DSQ-ladder resumes at the saved
controller level via --init-checkpoint. Both print the time-weighted
hardware cost of the run's schedule (IWSLT / RoBERTa-base scale).

--stash-state <spec> holds the run's state physically packed in a tiered
stash store between steps; --stash-budget <bytes|64k|4m|1g|unlimited>
caps its resident bytes (the overflow spills to an on-disk segment and
is prefetched back before dispatch — numerics are unchanged, only
residency). Stashed runs print measured stash/spill traffic with a
modeled-vs-observed DRAM comparison; --stash-dir keeps the store's
segment + index on disk for `dsq stash <dir>`.

--replicas <n> trains n data-parallel replicas over a sharded batch
stream, all-reducing the post-step state in packed DSQ records after
every step; --comms <spec> picks the wire format (fp32 =
bit-transparent full-precision reduce; SR formats draw rank-salted
rounding streams so replicas never correlate). --mirror-replicas feeds
every replica the identical stream instead of round-robin shards — with
--comms fp32 that run is bit-identical to single-replica. Replicated
runs print measured comms traffic with a modeled-vs-observed
comparison, next to the stash DRAM line.

--trace <dir> records span-based telemetry at near-zero cost: every rank
writes trace.rank<N>.jsonl (one DSQTRCE1-schema JSON event per span:
batch wait, dispatch, stash read/write, quantize, spill, exchange,
checkpoint, validate) plus run.rank<N>.json — a structured manifest with
per-phase count/total/p50/p95/bytes, the controller's precision ladder
with the step each rung started at, and the stash/comms traffic meters.
`dsq trace <dir>` renders the breakdown. Works across transports; the
dir is shared, files are rank-tagged.

--transport picks how those replicas are hosted: mem (the default)
runs them as threads over an in-memory ring, bit-identical to the
pre-transport behavior; socket:<path.sock> or socket:<host>:<port>
runs them as real OS processes — the parent binds a hub socket, spawns
one `dsq worker` per extra rank (port 0 picks a free TCP port), and
hosts rank 0 itself, every rank exchanging versioned DSQWIRE1 frames
over the socket. socket:* requires --replicas > 1.

--schedule accepts dsq (the paper's BFP ladder), dsq-<family>
(dsq-fixed, dsq-fixedsr), dsq-fp8 (FP8-LM-style floats: E4M3
fwd/stash/bwd, E5M2 gradients), or any static config spec — see `dsq
formats` for the registered formats, including the FP8 pair and the
generic e<E>m<M>[sr] float spelling (e8m7 = bf16, e5m10 = fp16).
";

/// Parse `--schedule`. Every static form goes through the format
/// registry ([`PrecisionConfig::parse`]), so a new registered format is
/// immediately spellable here with no CLI change:
///
/// * `dsq` — the paper's dynamic controller over BFP;
/// * `dsq-fp8` — the FP8-LM-style float ladder (E4M3 compute/stash,
///   E5M2 gradients, widening through fp16 on plateaus);
/// * `dsq-<family>` — the paper's ladder over any registered
///   width-parameterized family (`dsq-fixed`, `dsq-fixedsr`, …);
/// * a static config spec: `fp32`, one format for all slots (`bfp8`,
///   `fp8e4m3`), one family with per-slot widths (`bfp:16,4,4,16`), or
///   per-slot specs (`bfp16,bfp4,bfp4,fixed16sr`,
///   `fp8e4m3,fp8e4m3,fp8e4m3,fp8e5m2`).
pub fn parse_schedule(spec: &str) -> Result<Box<dyn Schedule>> {
    match spec {
        "dsq" => Ok(Box::new(DsqController::paper_default("bfp")?)),
        "dsq-fp8" => Ok(Box::new(DsqController::fp8_default()?)),
        other => {
            if let Some(family) = other.strip_prefix("dsq-") {
                return Ok(Box::new(DsqController::paper_default(family)?));
            }
            Ok(Box::new(StaticSchedule(PrecisionConfig::parse(other)?)))
        }
    }
}

fn common_train_flags(spec: ArgSpec) -> ArgSpec {
    spec.opt("artifacts", "artifacts", "artifact directory (make artifacts)")
        .opt("seed", "0", "RNG seed for init + corpus")
        .opt("epochs", "4", "training epochs")
        .opt("batches-per-epoch", "50", "train batches per epoch")
        .opt(
            "schedule",
            "dsq",
            "dsq | dsq-<family> | dsq-fp8 | fp32 | <family>:q0,q1,q2,q3 | s0,s1,s2,s3",
        )
        .opt("prefetch", "4", "bounded prefetch depth for the batch generator thread (>= 1)")
        .opt("val-every", "0", "also validate every N steps (0 = per-epoch only)")
        .opt(
            "checkpoint",
            "",
            "save checkpoint here (with resumable schedule state; a resumed \
             run continues the DSQ ladder at the saved level)",
        )
        .opt(
            "checkpoint-every",
            "0",
            "save --checkpoint every N steps mid-run (0 = final only); mid-run \
             saves are crash-salvage — resuming starts a fresh run from the \
             saved state and ladder level",
        )
        .opt("init-checkpoint", "", "initialize (and resume schedule state) from this checkpoint")
        .opt(
            "stash-state",
            "",
            "hold trainer state packed in this format between steps (e.g. bfp8); \
             checkpoints then use the packed v2 layout",
        )
        .opt(
            "stash-budget",
            "",
            "resident byte budget for the packed stash (e.g. 64k, 4m, 0 = spill \
             everything); overflow spills to disk and prefetches back — requires \
             --stash-state",
        )
        .opt(
            "stash-dir",
            "",
            "directory for the stash store's spill segment + stash.json index \
             (inspect with `dsq stash <dir>`; default: a per-run temp dir)",
        )
        .opt(
            "replicas",
            "1",
            "in-process data-parallel replicas (threads); 1 = today's \
             single-replica path, bit-for-bit",
        )
        .opt(
            "comms",
            "",
            "packed format replicas exchange state in (e.g. fp32, fixed8sr); \
             requires --replicas > 1; default fp32 (bit-transparent reduce)",
        )
        .opt(
            "transport",
            "mem",
            "how replicas are hosted: mem (threads over an in-memory ring) or \
             socket:<path.sock> | socket:<host>:<port> (one OS process per \
             rank via `dsq worker`); socket:* requires --replicas > 1",
        )
        .opt(
            "trace",
            "",
            "telemetry directory: write trace.rank<N>.jsonl span events + a \
             run.rank<N>.json manifest per rank (inspect with `dsq trace <dir>`)",
        )
        .bool(
            "mirror-replicas",
            "mirror the batch stream across replicas instead of round-robin \
             sharding it (the fp32 bit-identity configuration)",
        )
        .bool("json", "print the full report as JSON")
}

/// Parse `--prefetch`, rejecting 0 (the generator channel needs a slot).
fn parse_prefetch(a: &Args) -> Result<usize> {
    let p = a.get_usize("prefetch")?;
    if p == 0 {
        return Err(Error::Config("--prefetch must be >= 1".into()));
    }
    Ok(p)
}

/// Parse the replication quad `--replicas` / `--comms` /
/// `--mirror-replicas` / `--transport`. `--comms` goes through the
/// format registry (any registered spec is a wire format) and is
/// rejected without `--replicas > 1` — a comms format with nobody to
/// talk to is a config mistake, not a no-op. `--transport` goes through
/// [`TransportSpec::parse`] (a bad value names the offending token and
/// quotes the valid grammar; this wrapper prepends the flag name), and
/// `socket:*` is likewise rejected without `--replicas > 1` — a
/// multi-process transport with one process is a config mistake.
fn parse_replicas(a: &Args) -> Result<(usize, FormatSpec, bool, TransportSpec)> {
    let replicas = a.get_usize("replicas")?;
    if replicas == 0 {
        return Err(Error::Config("--replicas must be >= 1".into()));
    }
    let comms = opt_format(a, "comms")?;
    if replicas == 1 && comms.is_some() {
        return Err(Error::Config(
            "--comms requires --replicas > 1 (single-replica runs exchange nothing)".into(),
        ));
    }
    let transport = TransportSpec::parse(a.get("transport")).map_err(|e| match e {
        Error::Config(msg) => Error::Config(format!("--transport: {msg}")),
        other => other,
    })?;
    if replicas == 1 && transport.is_socket() {
        return Err(Error::Config(format!(
            "--transport {transport} requires --replicas > 1 (a multi-process \
             transport with a single process exchanges nothing)"
        )));
    }
    Ok((
        replicas,
        comms.unwrap_or(FormatSpec::Fp32),
        a.get_bool("mirror-replicas"),
        transport,
    ))
}

/// The comms-traffic line after a replicated run: modeled vs observed
/// exchange bytes (absent for single-replica runs, which exchange
/// nothing).
fn print_comms_line(report: &crate::coordinator::RunReport) {
    if let Some(c) = &report.comms {
        println!("{}", c.summary());
    }
}

/// Parse an optional `--stash-state` spec ("" = dense f32 state). A bad
/// spec names the flag and the offending token, and the underlying
/// parser lists every registered format — no bare parse failures.
fn opt_format(a: &Args, key: &str) -> Result<Option<FormatSpec>> {
    let v = a.get(key);
    if v.is_empty() {
        Ok(None)
    } else {
        FormatSpec::parse(v).map(Some).map_err(|e| match e {
            Error::Config(msg) => Error::Config(format!("--{key}: {msg}")),
            other => other,
        })
    }
}

/// Parse `--stash-budget` ("" = unlimited). Errors name the flag, the
/// offending token, and the accepted grammar.
fn opt_budget(a: &Args, key: &str) -> Result<StashBudget> {
    let v = a.get(key);
    if v.is_empty() {
        Ok(StashBudget::Unlimited)
    } else {
        StashBudget::parse(v).map_err(|e| match e {
            Error::Config(msg) => Error::Config(format!("--{key}: {msg}")),
            other => other,
        })
    }
}

/// Parse the full `dsq train` argv into its config, `--schedule` spec,
/// and `--json` flag. Split from [`cmd_train`] so the multi-process
/// path can replay the *same bytes* through the *same parser*: the
/// orchestrator ships its argv to every `dsq worker` as the handshake
/// CONFIG payload, and each worker re-parses it here — one parser, one
/// config, no drift between the processes of a socket-transport run.
pub(crate) fn parse_train_cli(raw: &[String]) -> Result<(TrainerConfig, String, bool)> {
    let spec = common_train_flags(ArgSpec::new("train", "train seq2seq with DSQ"))
        .opt("lr", "isqrt:3e-3:100", "lr schedule: const:x | isqrt:x:warmup | poly:x:w:total")
        .opt("variant", "iwslt", "task variant: iwslt | wmt")
        .opt("val-batches", "4", "validation batches")
        .opt("bleu-batches", "4", "test batches for BLEU (0 = skip)");
    let a = spec.parse(raw)?;
    let (replicas, comms, mirror_replicas, transport) = parse_replicas(&a)?;
    let cfg = TrainerConfig {
        artifacts: PathBuf::from(a.get("artifacts")),
        seed: a.get_u64("seed")?,
        epochs: a.get_usize("epochs")?,
        batches_per_epoch: a.get_usize("batches-per-epoch")?,
        lr: LrSchedule::parse(a.get("lr"))?,
        variant: parse_variant(a.get("variant"))?,
        val_batches: a.get_usize("val-batches")?,
        val_every_steps: a.get_usize("val-every")?,
        bleu_batches: a.get_usize("bleu-batches")?,
        checkpoint: opt_path(&a, "checkpoint"),
        checkpoint_every_steps: a.get_usize("checkpoint-every")?,
        init_checkpoint: opt_path(&a, "init-checkpoint"),
        prefetch: parse_prefetch(&a)?,
        stash_format: opt_format(&a, "stash-state")?,
        stash_budget: opt_budget(&a, "stash-budget")?,
        stash_dir: opt_path(&a, "stash-dir"),
        replicas,
        comms,
        mirror_replicas,
        transport,
        trace_dir: opt_path(&a, "trace"),
    };
    Ok((cfg, a.get("schedule").to_string(), a.get_bool("json")))
}

fn cmd_train(raw: &[String]) -> Result<()> {
    let (cfg, sched_spec, json) = parse_train_cli(raw)?;
    let report = match cfg.transport.clone() {
        TransportSpec::Socket(addr) => {
            let exe = std::env::current_exe()?;
            super::worker::orchestrate(
                &exe,
                "train",
                raw,
                &addr,
                cfg.replicas,
                cfg.comms,
                |ex| {
                    let mut t = Trainer::replica(&cfg, 0)?;
                    t.session().set_exchange(ex)?;
                    let mut schedule = parse_schedule(&sched_spec)?;
                    t.run(schedule.as_mut())
                },
            )?
        }
        TransportSpec::Mem => Trainer::run_replicated(cfg, || parse_schedule(&sched_spec))?,
    };
    println!(
        "steps={} val_loss={:.4} token_acc={:.1}% bleu={} diverged={} ({:.2} steps/s)",
        report.steps,
        report.final_val_loss,
        report.final_eval_acc * 100.0,
        report.bleu().map_or("-".into(), |b| format!("{b:.2}")),
        report.diverged,
        report.steps_per_s()
    );
    print_cost_line(&report, &TransformerWorkload::iwslt_6layer(), "IWSLT");
    print_stash_line(&report);
    print_comms_line(&report);
    if json {
        println!("{}", report.to_json().to_string_pretty());
    }
    Ok(())
}

/// The hardware-cost line after a run: the time-weighted relative cost
/// of the schedule trace on a paper-scale workload; fp32 reference runs
/// stay unscored, exactly like the paper's "-" rows.
fn print_cost_line(report: &crate::coordinator::RunReport, w: &TransformerWorkload, name: &str) {
    match report.cost_on(w) {
        Some((arith, dram)) => println!(
            "hardware cost of this schedule on paper-scale {name}: arith {arith:.3}x dram {dram:.3}x (vs fixed32)"
        ),
        None => println!("hardware cost: - (fp32 reference is unscored)"),
    }
}

/// The measured-traffic line after a stashed run: modeled vs observed
/// stash DRAM plus spill/checkpoint byte counts (absent for dense-state
/// runs, which have no stash store to meter).
fn print_stash_line(report: &crate::coordinator::RunReport) {
    if let Some(st) = &report.stash {
        println!("{}", st.summary());
    }
}

/// The `dsq finetune` twin of [`parse_train_cli`] — same split, same
/// reason: the socket-transport workers replay the orchestrator's argv
/// through this exact parser.
pub(crate) fn parse_finetune_cli(raw: &[String]) -> Result<(FinetuneConfig, String, bool)> {
    let spec = common_train_flags(ArgSpec::new("finetune", "fine-tune the classifier"))
        .opt("lr", "poly:1e-3:20:2000", "lr schedule")
        .opt("nclasses", "3", "2 = QNLI-style, 3 = MNLI-style")
        .opt("val-batches", "4", "validation batches");
    let a = spec.parse(raw)?;
    let (replicas, comms, mirror_replicas, transport) = parse_replicas(&a)?;
    let cfg = FinetuneConfig {
        artifacts: PathBuf::from(a.get("artifacts")),
        seed: a.get_u64("seed")?,
        epochs: a.get_usize("epochs")?,
        batches_per_epoch: a.get_usize("batches-per-epoch")?,
        lr: LrSchedule::parse(a.get("lr"))?,
        nclasses: a.get_usize("nclasses")?,
        val_batches: a.get_usize("val-batches")?,
        val_every_steps: a.get_usize("val-every")?,
        checkpoint: opt_path(&a, "checkpoint"),
        checkpoint_every_steps: a.get_usize("checkpoint-every")?,
        init_checkpoint: opt_path(&a, "init-checkpoint"),
        prefetch: parse_prefetch(&a)?,
        stash_format: opt_format(&a, "stash-state")?,
        stash_budget: opt_budget(&a, "stash-budget")?,
        stash_dir: opt_path(&a, "stash-dir"),
        replicas,
        comms,
        mirror_replicas,
        transport,
        trace_dir: opt_path(&a, "trace"),
    };
    Ok((cfg, a.get("schedule").to_string(), a.get_bool("json")))
}

fn cmd_finetune(raw: &[String]) -> Result<()> {
    let (cfg, sched_spec, json) = parse_finetune_cli(raw)?;
    let report = match cfg.transport.clone() {
        TransportSpec::Socket(addr) => {
            let exe = std::env::current_exe()?;
            super::worker::orchestrate(
                &exe,
                "finetune",
                raw,
                &addr,
                cfg.replicas,
                cfg.comms,
                |ex| {
                    let mut f = Finetuner::replica(&cfg, 0)?;
                    f.session().set_exchange(ex)?;
                    let mut schedule = parse_schedule(&sched_spec)?;
                    f.run(schedule.as_mut())
                },
            )?
        }
        TransportSpec::Mem => Finetuner::run_replicated(cfg, || parse_schedule(&sched_spec))?,
    };
    println!(
        "steps={} val_loss={:.4} accuracy={:.1}% diverged={} ({:.2} steps/s)",
        report.steps,
        report.final_val_loss,
        report.accuracy().unwrap_or(f64::NAN) * 100.0,
        report.diverged,
        report.steps_per_s()
    );
    // The paper scores GLUE fine-tuning on RoBERTa-base (Table 1's
    // MNLI/QNLI columns) — same line `dsq train` prints for IWSLT.
    print_cost_line(&report, &TransformerWorkload::roberta_base(), "RoBERTa-base");
    print_stash_line(&report);
    print_comms_line(&report);
    if json {
        println!("{}", report.to_json().to_string_pretty());
    }
    Ok(())
}

pub fn parse_variant(s: &str) -> Result<Variant> {
    match s {
        "iwslt" => Ok(Variant::Iwslt),
        "wmt" => Ok(Variant::Wmt),
        other => Err(Error::Config(format!("unknown variant '{other}'"))),
    }
}

pub fn parse_workload(s: &str) -> Result<TransformerWorkload> {
    Ok(match s {
        "iwslt" => TransformerWorkload::for_kind(WorkloadKind::Iwslt6Layer),
        "wmt" => TransformerWorkload::for_kind(WorkloadKind::Wmt6Layer),
        "roberta" => TransformerWorkload::for_kind(WorkloadKind::RobertaBase),
        "testbed" => TransformerWorkload::for_kind(WorkloadKind::Testbed),
        other => return Err(Error::Config(format!("unknown workload '{other}'"))),
    })
}

fn opt_path(a: &Args, key: &str) -> Option<PathBuf> {
    let v = a.get(key);
    if v.is_empty() {
        None
    } else {
        Some(PathBuf::from(v))
    }
}

fn cmd_cost_table(raw: &[String]) -> Result<()> {
    let spec = ArgSpec::new("cost-table", "paper cost columns for a workload")
        .opt("workload", "iwslt", "iwslt | wmt | roberta | testbed");
    let a = spec.parse(raw)?;
    let w = parse_workload(a.get("workload"))?;
    println!(
        "{:<18} {:<16} {:>8} {:>8}   (workload: {}, fixed32 = 1.00x)",
        "method", "precision", "arith", "dram", w.name
    );
    for (m, p, score) in costmodel::tables::standard_methods() {
        println!("{}", costmodel::normalized_row(&w, m, &p, score).fmt_paper_style());
    }
    // The canonical DSQ trace (mostly level-0 steps).
    let lo = PrecisionConfig::of(FormatSpec::bfp(16), [2, 2, 2, 16]);
    let hi = PrecisionConfig::stashing(FormatSpec::bfp(16));
    println!("{}", costmodel::tables::dsq_trace_row(&w, &[(lo, 96), (hi, 4)]).fmt_paper_style());
    Ok(())
}

fn cmd_roofline(raw: &[String]) -> Result<()> {
    let spec = ArgSpec::new("roofline", "Figure 1 placements")
        .opt("machine", "a100", "a100 | edge")
        .opt("workload", "iwslt", "iwslt | wmt | roberta | testbed");
    let a = spec.parse(raw)?;
    let machine = match a.get("machine") {
        "a100" => costmodel::Machine::a100_like(),
        "edge" => costmodel::Machine::edge_like(),
        other => return Err(Error::Config(format!("unknown machine '{other}'"))),
    };
    let w = parse_workload(a.get("workload"))?;
    crate::experiments::figure1::print_roofline(&machine, &w);
    crate::experiments::figure1::print_stash_traffic(&w);
    Ok(())
}

fn cmd_experiment(raw: &[String]) -> Result<()> {
    let spec = ArgSpec::new("experiment", "regenerate a paper table/figure")
        .opt("artifacts", "artifacts", "artifact directory")
        .opt("out", "results", "output directory for reports")
        .opt("train-epochs", "3", "training epochs per table row")
        .opt("batches-per-epoch", "40", "train batches per epoch")
        .bool("no-train", "cost columns only (skip accuracy training runs)");
    let a = spec.parse(raw)?;
    let which = a
        .positional
        .first()
        .ok_or_else(|| Error::Config("experiment name required (e.g. table1-iwslt)".into()))?;
    let opts = crate::experiments::ExperimentOpts {
        artifacts: PathBuf::from(a.get("artifacts")),
        out: PathBuf::from(a.get("out")),
        train_epochs: a.get_usize("train-epochs")?,
        batches_per_epoch: a.get_usize("batches-per-epoch")?,
        train: !a.get_bool("no-train"),
    };
    crate::experiments::run(which, &opts)
}

fn cmd_formats() -> Result<()> {
    println!("registered number formats ({}):", crate::quant::format::registered_summary());
    println!("  {:<16} {:>13}  {:<9}  {}", "format", "packed B/elem", "at", "description");
    for fam in crate::quant::format::FORMAT_REGISTRY {
        // Physical storage of the packed codec at a representative width
        // (16 clamped into the family's range), on a 4096-elem tensor.
        let spec = fam.instantiate(16.clamp(fam.min_bits, fam.max_bits))?;
        let n = 4096;
        let bytes_per_elem = spec.observed_bytes(n, n) as f64 / n as f64;
        println!(
            "  {:<16} {:>13.3}  {:<9}  {}",
            fam.spelling(),
            bytes_per_elem,
            spec.spec_string(),
            fam.help
        );
    }
    println!(
        "\ngeneric float spelling: e<E>m<M>[sr] (e4m3, e5m2, e8m7 = bf16, e5m10 = fp16)\n\
         config spec forms: <spec> | <family>:q0,q1,q2,q3 | <spec>,<spec>,<spec>,<spec>\n\
         schedules: dsq | dsq-<family> | dsq-fp8 | any config spec (static)\n\
         --stash-state <spec>: keep trainer state packed (sub-byte) between steps\n\
         --stash-budget <{}>: cap resident stash bytes (overflow spills to disk)",
        stash::BUDGET_GRAMMAR
    );
    Ok(())
}

/// `dsq lint [--root <dir>] [--json] [--github]`: run the cross-layer
/// invariant checker ([`crate::analysis`]). Default output is one
/// clickable `lint[rule] file:line: message` per finding; `--json`
/// prints a machine-readable report instead (the CI artifact), and
/// `--github` prints `::error file=…,line=…::` workflow annotations so
/// findings land on the PR diff. Exit 0 when clean, 1 on findings (via
/// [`Error::Lint`]), 2 on usage errors. Without `--root` the repo root
/// is found by walking up from the current directory, so the
/// subcommand works from the repo root, `rust/`, or any subdir.
fn cmd_lint(args: &[String]) -> Result<()> {
    let mut root: Option<std::path::PathBuf> = None;
    let mut json = false;
    let mut github = false;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--root" => {
                let v = it
                    .next()
                    .ok_or_else(|| Error::Config("--root needs a directory".into()))?;
                root = Some(std::path::PathBuf::from(v));
            }
            "--json" => json = true,
            "--github" => github = true,
            other => {
                return Err(Error::Config(format!("unknown lint flag '{other}'")));
            }
        }
    }
    let root = match root {
        Some(r) => r,
        None => {
            let cwd = std::env::current_dir()?;
            crate::analysis::find_root(&cwd).ok_or_else(|| {
                Error::Config(format!(
                    "cannot locate the repo root from {} (no rust/src/quant/format.rs \
                     above it); pass --root <dir>",
                    cwd.display()
                ))
            })?
        }
    };
    let report = crate::analysis::run_lint(&root)?;
    if github {
        for f in &report.findings {
            println!("{}", github_annotation(f));
        }
    }
    if json {
        use crate::util::json::Json;
        let doc = Json::obj(vec![
            ("root", Json::str(&root.display().to_string())),
            ("rules", Json::arr(crate::analysis::RULES.iter().map(|r| Json::str(r)))),
            ("rules_run", Json::Num(report.rules_run as f64)),
            ("clean", Json::Bool(report.findings.is_empty())),
            ("findings", Json::arr(report.findings.iter().map(|f| f.to_json()))),
        ]);
        println!("{}", doc.to_string_pretty());
    } else if !github {
        for f in &report.findings {
            println!("{f}");
        }
    }
    if report.findings.is_empty() {
        if !json && !github {
            println!(
                "dsq lint: {} rules over {}: clean",
                report.rules_run,
                root.display()
            );
        }
        Ok(())
    } else {
        Err(Error::Lint(format!(
            "{} finding(s) — cross-layer invariants violated",
            report.findings.len()
        )))
    }
}

/// One finding as a GitHub Actions workflow command, so CI failures are
/// clickable on the PR diff. Properties escape `%`, newlines, `:` and
/// `,` per the workflow-command grammar; the free-text message escapes
/// only `%` and newlines.
fn github_annotation(f: &crate::analysis::Finding) -> String {
    let prop = |s: &str| {
        s.replace('%', "%25")
            .replace('\r', "%0D")
            .replace('\n', "%0A")
            .replace(':', "%3A")
            .replace(',', "%2C")
    };
    let msg = f.message.replace('%', "%25").replace('\r', "%0D").replace('\n', "%0A");
    format!(
        "::error file={},line={},title={}::{msg}",
        prop(&f.file),
        f.line,
        prop(&format!("lint[{}]", f.rule)),
    )
}

/// `dsq bench gate [--root <dir>] [--ratio <r>]` / `dsq bench publish
/// [--root <dir>]`: the bench regression gate ([`crate::bench::gate`]).
/// `gate` compares every gated `BENCH_<name>.json` at the repo root
/// against its committed baseline in `rust/benches/baselines/` and
/// exits 1 (via [`Error::Lint`]) on stale or regressed reports;
/// `publish` copies the current reports over the baselines (the
/// deliberate-perf-change workflow).
fn cmd_bench(args: &[String]) -> Result<()> {
    use crate::bench::gate;
    let (action, rest) = args
        .split_first()
        .ok_or_else(|| Error::Config("bench action required: gate | publish".into()))?;
    let mut root: Option<PathBuf> = None;
    let mut ratio = gate::DEFAULT_RATIO;
    let mut it = rest.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--root" => {
                let v =
                    it.next().ok_or_else(|| Error::Config("--root needs a directory".into()))?;
                root = Some(PathBuf::from(v));
            }
            "--ratio" => {
                let v = it.next().ok_or_else(|| Error::Config("--ratio needs a number".into()))?;
                ratio = v.parse().map_err(|_| {
                    Error::Config(format!("--ratio: '{v}' is not a number"))
                })?;
                if ratio.is_nan() || ratio < 1.0 {
                    return Err(Error::Config("--ratio must be >= 1.0".into()));
                }
            }
            other => return Err(Error::Config(format!("unknown bench flag '{other}'"))),
        }
    }
    let root = match root {
        Some(r) => r,
        None => {
            let cwd = std::env::current_dir()?;
            crate::analysis::find_root(&cwd).ok_or_else(|| {
                Error::Config(format!(
                    "cannot locate the repo root from {}; pass --root <dir>",
                    cwd.display()
                ))
            })?
        }
    };
    match action.as_str() {
        "gate" => {
            let notes = gate::run_gate(&root, ratio)?;
            for n in &notes {
                println!("note: {n}");
            }
            println!(
                "dsq bench gate: {} report(s) within {ratio}x of baseline",
                gate::GATED.len()
            );
            Ok(())
        }
        "publish" => {
            for p in gate::publish(&root)? {
                println!("published {}", p.display());
            }
            Ok(())
        }
        other => Err(Error::Config(format!("unknown bench action '{other}' (gate | publish)"))),
    }
}

/// `dsq stash <run-dir>`: print the stash store's index — per-slot
/// resident/spilled bytes, last touch, and the traffic meter — for a
/// run that kept its store on disk (`--stash-dir`).
fn cmd_stash(raw: &[String]) -> Result<()> {
    let spec = ArgSpec::new("stash", "inspect a stash-store run directory");
    let a = spec.parse(raw)?;
    let dir = a.positional.first().ok_or_else(|| {
        Error::Config("stash run directory required (the --stash-dir of a run)".into())
    })?;
    let idx_path = PathBuf::from(dir).join("stash.json");
    let idx = crate::util::json::parse_file(&idx_path).map_err(|e| {
        Error::Config(format!("{idx_path:?}: not a stash index ({e})"))
    })?;
    let get_str = |k: &str| idx.path(k).and_then(Json::as_str).unwrap_or("?").to_string();
    let get_num = |k: &str| idx.path(k).and_then(Json::as_f64).unwrap_or(0.0);
    println!(
        "stash store at {dir}: format {}, budget {}, step {}",
        get_str("spec"),
        get_str("budget"),
        get_num("step"),
    );
    println!(
        "resident {} | spilled {}",
        stash::fmt_bytes(get_num("resident_bytes") as u64),
        stash::fmt_bytes(get_num("spilled_bytes") as u64),
    );
    println!("{:<28} {:>10} {:>12} {:>12}", "slot", "tier", "bytes", "last touch");
    for slot in idx.path("slots").and_then(Json::as_arr).unwrap_or(&[]) {
        println!(
            "{:<28} {:>10} {:>12} {:>12}",
            slot.path("slot").and_then(Json::as_str).unwrap_or("?"),
            slot.path("tier").and_then(Json::as_str).unwrap_or("?"),
            stash::fmt_bytes(slot.path("bytes").and_then(Json::as_f64).unwrap_or(0.0) as u64),
            slot.path("last_touch").and_then(Json::as_f64).unwrap_or(0.0),
        );
    }
    if let Some(t) = idx.path("traffic") {
        let tb = |k: &str| t.path(k).and_then(Json::as_f64).unwrap_or(0.0);
        println!(
            "traffic: stash wrote {} read {} | spill wrote {} read {} | checkpoints {}",
            stash::fmt_bytes(tb("stash_write_bytes") as u64),
            stash::fmt_bytes(tb("stash_read_bytes") as u64),
            stash::fmt_bytes(tb("spill_write_bytes") as u64),
            stash::fmt_bytes(tb("spill_read_bytes") as u64),
            stash::fmt_bytes(tb("checkpoint_bytes") as u64),
        );
        println!(
            "DRAM stash bits: modeled {:.3} Mbit observed {:.3} Mbit",
            tb("modeled_stash_bits") / 1e6,
            tb("observed_stash_bits") / 1e6,
        );
    }
    Ok(())
}

/// `dsq trace <dir>`: analyze the telemetry a `--trace <dir>` run
/// wrote — per-phase step-time breakdown (count, total, share of step,
/// p50/p95, bytes) for every rank's `run.rank<N>.json` manifest,
/// modeled-vs-observed traffic next to the timings, and cross-rank
/// phase skew for replicated runs. See [`crate::obs::analyze`].
fn cmd_trace(raw: &[String]) -> Result<()> {
    let spec = ArgSpec::new("trace", "analyze a --trace telemetry directory");
    let a = spec.parse(raw)?;
    let dir = a.positional.first().ok_or_else(|| {
        Error::Config("trace directory required (the --trace <dir> of a run)".into())
    })?;
    let runs = crate::obs::analyze::load_runs(&PathBuf::from(dir))?;
    print!("{}", crate::obs::analyze::render(&runs));
    Ok(())
}

fn cmd_info(raw: &[String]) -> Result<()> {
    let spec = ArgSpec::new("info", "artifact manifest summary")
        .opt("artifacts", "artifacts", "artifact directory");
    let a = spec.parse(raw)?;
    let man = crate::runtime::ArtifactManifest::load(&PathBuf::from(a.get("artifacts")))?;
    println!("artifacts: {:?}", man.dir);
    for (name, m) in [("nmt", &man.nmt), ("cls", &man.cls)] {
        println!(
            "  {name}: {} param tensors, {} total params, artifacts: {}",
            m.params.len(),
            m.total_params(),
            m.artifacts.keys().cloned().collect::<Vec<_>>().join(", ")
        );
        for (k, v) in &m.config {
            println!("    {k} = {v}");
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_schedule_variants() {
        assert!(parse_schedule("dsq").is_ok());
        assert!(parse_schedule("fp32").is_ok());
        let s = parse_schedule("bfp:16,4,4,16").unwrap();
        assert_eq!(s.current().notation(), "[16,4,4,16]");
        assert_eq!(s.current().fwd(), FormatSpec::bfp(16));
        let s = parse_schedule("fixed:8,8,8,32").unwrap();
        assert_eq!(s.current().grad(), FormatSpec::fixed(32));
        assert!(parse_schedule("nope").is_err());
        assert!(parse_schedule("bfp:1,2").is_err());
    }

    #[test]
    fn parse_schedule_registry_formats() {
        // Registered families are spellable with no CLI change: the SR
        // format, per-slot heterogeneous configs, and dsq-<family>.
        let s = parse_schedule("fixedsr:16,4,4,16").unwrap();
        assert_eq!(s.current().stash(), FormatSpec::fixed_sr(4));
        let s = parse_schedule("bfp16,bfp4,bfp4,fixed16sr").unwrap();
        assert_eq!(s.current().grad(), FormatSpec::fixed_sr(16));
        let s = parse_schedule("dsq-fixedsr").unwrap();
        assert_eq!(s.current().notation(), "[2,2,2,16]");
        assert_eq!(s.current().fwd(), FormatSpec::fixed_sr(2));
        assert!(parse_schedule("dsq-fixed").is_ok());
        assert!(parse_schedule("dsq-int8").is_err());
    }

    #[test]
    fn parse_schedule_fp8_forms() {
        // The dynamic FP8 ladder.
        let s = parse_schedule("dsq-fp8").unwrap();
        assert_eq!(s.current().notation(), "[8,8,8,8]");
        assert_eq!(s.current().fwd(), FormatSpec::fp8e4m3());
        assert_eq!(s.current().grad(), FormatSpec::fp8e5m2());
        // Static float configs through the registry + generic grammar.
        let s = parse_schedule("fp8e4m3,fp8e4m3,fp8e4m3,fp8e5m2").unwrap();
        assert_eq!(s.current().grad(), FormatSpec::fp8e5m2());
        let s = parse_schedule("e8m7").unwrap();
        assert_eq!(s.current().fwd(), FormatSpec::float(8, 7));
        // "dsq-e4m3" is not a width-parameterized family ladder.
        assert!(parse_schedule("dsq-e4m3").is_err());
    }

    #[test]
    fn stash_state_flag_parses_through_the_registry() {
        let spec = common_train_flags(ArgSpec::new("t", "test"));
        let a = spec.parse(&["--stash-state".to_string(), "bfp8".to_string()]).unwrap();
        assert_eq!(opt_format(&a, "stash-state").unwrap(), Some(FormatSpec::bfp(8)));
        let spec = common_train_flags(ArgSpec::new("t", "test"));
        let a = spec.parse(&[]).unwrap();
        assert_eq!(opt_format(&a, "stash-state").unwrap(), None);
        let spec = common_train_flags(ArgSpec::new("t", "test"));
        let a = spec.parse(&["--stash-state".to_string(), "int8".to_string()]).unwrap();
        assert!(opt_format(&a, "stash-state").is_err());
    }

    #[test]
    fn stash_flag_errors_name_the_flag_token_and_valid_formats() {
        // The satellite contract: --stash-state / --stash-budget parse
        // failures must name the offending token and list what is
        // valid, not fail bare.
        let parse_with = |flag: &str, val: &str| {
            let spec = common_train_flags(ArgSpec::new("t", "test"));
            spec.parse(&[format!("--{flag}"), val.to_string()]).unwrap()
        };
        let a = parse_with("stash-state", "int8");
        match opt_format(&a, "stash-state").err() {
            Some(Error::Config(msg)) => {
                assert!(msg.contains("--stash-state"), "names the flag: {msg}");
                assert!(msg.contains("'int8'"), "names the token: {msg}");
                assert!(msg.contains("registered:"), "lists valid formats: {msg}");
            }
            other => panic!("expected Config error, got {other:?}"),
        }
        let a = parse_with("stash-state", "bfp64");
        match opt_format(&a, "stash-state").err() {
            Some(Error::Config(msg)) => {
                assert!(msg.contains("--stash-state") && msg.contains("64"), "{msg}");
            }
            other => panic!("expected Config error, got {other:?}"),
        }
        let a = parse_with("stash-budget", "64x");
        match opt_budget(&a, "stash-budget").err() {
            Some(Error::Config(msg)) => {
                assert!(msg.contains("--stash-budget"), "names the flag: {msg}");
                assert!(msg.contains("'x'"), "names the bad suffix: {msg}");
                assert!(msg.contains(stash::BUDGET_GRAMMAR), "lists the grammar: {msg}");
            }
            other => panic!("expected Config error, got {other:?}"),
        }
    }

    #[test]
    fn stash_budget_and_dir_flags_parse() {
        let spec = common_train_flags(ArgSpec::new("t", "test"));
        let a = spec.parse(&[]).unwrap();
        assert_eq!(opt_budget(&a, "stash-budget").unwrap(), StashBudget::Unlimited);
        assert_eq!(opt_path(&a, "stash-dir"), None);
        let spec = common_train_flags(ArgSpec::new("t", "test"));
        let a = spec
            .parse(&[
                "--stash-budget".to_string(),
                "64k".to_string(),
                "--stash-dir".to_string(),
                "/tmp/run1".to_string(),
            ])
            .unwrap();
        assert_eq!(opt_budget(&a, "stash-budget").unwrap(), StashBudget::Bytes(64 << 10));
        assert_eq!(opt_path(&a, "stash-dir"), Some(PathBuf::from("/tmp/run1")));
    }

    #[test]
    fn stash_subcommand_dispatches_and_requires_a_dir() {
        // Missing dir and bogus dir both exit 2 (config error), like
        // every other CLI misuse.
        assert_eq!(dispatch(&["stash".to_string()]), 2);
        assert_eq!(
            dispatch(&["stash".to_string(), "/nonexistent-run-dir".to_string()]),
            2
        );
    }

    #[test]
    fn prefetch_flag_defaults_and_validates() {
        let spec = common_train_flags(ArgSpec::new("t", "test"));
        let a = spec.parse(&[]).unwrap();
        assert_eq!(parse_prefetch(&a).unwrap(), 4);
        let spec = common_train_flags(ArgSpec::new("t", "test"));
        let a = spec.parse(&["--prefetch".to_string(), "9".to_string()]).unwrap();
        assert_eq!(parse_prefetch(&a).unwrap(), 9);
        let spec = common_train_flags(ArgSpec::new("t", "test"));
        let a = spec.parse(&["--prefetch".to_string(), "0".to_string()]).unwrap();
        assert!(matches!(parse_prefetch(&a), Err(Error::Config(_))));
    }

    #[test]
    fn replica_flags_default_validate_and_parse() {
        // Default: single replica, fp32 comms, round-robin moot.
        let spec = common_train_flags(ArgSpec::new("t", "test"));
        let a = spec.parse(&[]).unwrap();
        assert_eq!(parse_replicas(&a).unwrap(), (1, FormatSpec::Fp32, false, TransportSpec::Mem));
        // A replicated run with an SR comms format through the registry.
        let spec = common_train_flags(ArgSpec::new("t", "test"));
        let a = spec
            .parse(&[
                "--replicas".to_string(),
                "2".to_string(),
                "--comms".to_string(),
                "fixed8sr".to_string(),
                "--mirror-replicas".to_string(),
            ])
            .unwrap();
        assert_eq!(
            parse_replicas(&a).unwrap(),
            (2, FormatSpec::fixed_sr(8), true, TransportSpec::Mem)
        );
        // 0 replicas and comms-without-replicas are config mistakes.
        let spec = common_train_flags(ArgSpec::new("t", "test"));
        let a = spec.parse(&["--replicas".to_string(), "0".to_string()]).unwrap();
        assert!(matches!(parse_replicas(&a), Err(Error::Config(_))));
        let spec = common_train_flags(ArgSpec::new("t", "test"));
        let a = spec.parse(&["--comms".to_string(), "fp32".to_string()]).unwrap();
        match parse_replicas(&a) {
            Err(Error::Config(msg)) => assert!(msg.contains("--replicas"), "{msg}"),
            other => panic!("expected Config error, got {other:?}"),
        }
        // A bad comms spec names the flag and lists the registry.
        let spec = common_train_flags(ArgSpec::new("t", "test"));
        let a = spec
            .parse(&[
                "--replicas".to_string(),
                "2".to_string(),
                "--comms".to_string(),
                "int8".to_string(),
            ])
            .unwrap();
        match parse_replicas(&a) {
            Err(Error::Config(msg)) => {
                assert!(msg.contains("--comms") && msg.contains("'int8'"), "{msg}");
            }
            other => panic!("expected Config error, got {other:?}"),
        }
    }

    #[test]
    fn transport_flag_parses_and_errors_name_flag_token_and_grammar() {
        let parse_with = |argv: &[&str]| {
            let spec = common_train_flags(ArgSpec::new("t", "test"));
            spec.parse(&argv.iter().map(|s| s.to_string()).collect::<Vec<_>>()).unwrap()
        };
        // Both socket spellings parse when replicated.
        let a = parse_with(&["--replicas", "2", "--transport", "socket:/tmp/x.sock"]);
        let (_, _, _, t) = parse_replicas(&a).unwrap();
        assert_eq!(t, TransportSpec::Socket("/tmp/x.sock".into()));
        let a = parse_with(&["--replicas", "2", "--transport", "socket:127.0.0.1:0"]);
        let (_, _, _, t) = parse_replicas(&a).unwrap();
        assert_eq!(t, TransportSpec::Socket("127.0.0.1:0".into()));
        // The satellite contract: a bad value names the flag, the
        // offending token, and the valid grammar — no bare failures.
        let a = parse_with(&["--replicas", "2", "--transport", "carrier-pigeon"]);
        match parse_replicas(&a) {
            Err(Error::Config(msg)) => {
                assert!(msg.contains("--transport"), "names the flag: {msg}");
                assert!(msg.contains("carrier-pigeon"), "names the token: {msg}");
                assert!(msg.contains(stash::TRANSPORT_GRAMMAR), "lists the grammar: {msg}");
            }
            other => panic!("expected Config error, got {other:?}"),
        }
        // socket: with no address is named too.
        let a = parse_with(&["--replicas", "2", "--transport", "socket:"]);
        match parse_replicas(&a) {
            Err(Error::Config(msg)) => {
                assert!(msg.contains("--transport") && msg.contains("socket:"), "{msg}");
                assert!(msg.contains(stash::TRANSPORT_GRAMMAR), "{msg}");
            }
            other => panic!("expected Config error, got {other:?}"),
        }
        // A multi-process transport with one process is rejected loudly,
        // pointing at --replicas.
        let a = parse_with(&["--transport", "socket:/tmp/x.sock"]);
        match parse_replicas(&a) {
            Err(Error::Config(msg)) => {
                assert!(msg.contains("--replicas > 1"), "points at --replicas: {msg}");
                assert!(msg.contains("socket:/tmp/x.sock"), "names the transport: {msg}");
            }
            other => panic!("expected Config error, got {other:?}"),
        }
    }

    #[test]
    fn worker_subcommand_requires_its_flags() {
        // `dsq worker` without --rank/--connect/--replicas is a usage
        // error (exit 2), like every other CLI misuse.
        assert_eq!(dispatch(&["worker".to_string()]), 2);
        assert_eq!(
            dispatch(&["worker".to_string(), "--rank".to_string(), "1".to_string()]),
            2
        );
    }

    #[test]
    fn bench_subcommand_validates_usage() {
        // Missing action, bogus action, and bad flags all exit 2.
        assert_eq!(dispatch(&["bench".to_string()]), 2);
        assert_eq!(dispatch(&["bench".to_string(), "bogus".to_string()]), 2);
        assert_eq!(
            dispatch(&["bench".to_string(), "gate".to_string(), "--ratio".to_string()]),
            2
        );
        assert_eq!(
            dispatch(&[
                "bench".to_string(),
                "gate".to_string(),
                "--ratio".to_string(),
                "0.5".to_string(),
            ]),
            2
        );
    }

    #[test]
    fn cadence_flags_default_to_zero() {
        let spec = common_train_flags(ArgSpec::new("t", "test"));
        let a = spec.parse(&[]).unwrap();
        assert_eq!(a.get_usize("val-every").unwrap(), 0);
        assert_eq!(a.get_usize("checkpoint-every").unwrap(), 0);
    }

    #[test]
    fn parse_workloads() {
        for w in ["iwslt", "wmt", "roberta", "testbed"] {
            assert!(parse_workload(w).is_ok());
        }
        assert!(parse_workload("nope").is_err());
    }

    #[test]
    fn unknown_subcommand_exit_code() {
        assert_eq!(dispatch(&["bogus".to_string()]), 2);
        assert_eq!(dispatch(&["version".to_string()]), 0);
        assert_eq!(dispatch(&["formats".to_string()]), 0);
        assert_eq!(dispatch(&[]), 0); // help
    }
}
