//! The task-agnostic training engine: one `Session` loop for every
//! workload, with per-task behavior factored into the [`Task`] trait.
//!
//! The paper evaluates a single algorithm (the DSQ precision schedule)
//! across trained-from-scratch translation and fine-tuned
//! classification; this module is the one implementation of that loop.
//! A [`Session`] owns everything the tasks share:
//!
//! * bounded-prefetch batch production (a generator thread per epoch
//!   feeding a `sync_channel`, so corpus synthesis never blocks steps);
//! * per-step artifact dispatch through a memoized [`ExeCache`] — each
//!   `(model, artifact-kind)` executable is resolved once per run
//!   instead of once per step;
//! * the precision-trace accumulator that feeds the cost model;
//! * divergence detection and abort (Table 5's "Failed" rows);
//! * the stash-store hand-off (`--stash-state`): step outputs arrive
//!   dense and go back to the [`StashStore`]'s packed resident tier
//!   every step, the `--stash-budget` overflow spills to its segment
//!   file, the prefetcher pulls it back before the next dispatch, and
//!   every byte lands on the run's [`StashTraffic`] report;
//! * validation cadence — per-epoch always, plus every
//!   `val_every_steps` when set — feeding the schedule's plateau
//!   detector;
//! * checkpointing, mid-run (`checkpoint_every_steps`) and final, with
//!   the schedule's resumable [`ScheduleState`] in the trailer so a
//!   resumed run continues the DSQ ladder at the saved level. Mid-run
//!   (crash-salvage) checkpoints additionally carry the batch-stream
//!   [`checkpoint::ResumePosition`], so resuming one continues the
//!   interrupted epoch at the next unconsumed batch instead of
//!   re-drawing the epoch stream and silently replaying seen data;
//! * replica participation (`--replicas`): a [`ReplicaShard`] in the
//!   config picks this session's slice of the *global* batch stream
//!   (round-robin by batch index, or mirrored for the bit-identity
//!   configuration), and a [`ReplicaExchange`] handle installed via
//!   [`Session::set_exchange`] all-reduces the post-step state between
//!   replicas in the `--comms` packed format — the dequant–reduce–
//!   requant protocol documented in `stash::exchange`, with its
//!   metered comms bytes landing on [`RunReport::comms`].
//!
//! **Replica seeding contract:** every stochastic-rounding encode onto
//! the exchange wire is salted with the replica rank (salt 0 ≡ the
//! unsalted single-replica stream), so replicas never share rounding
//! noise; the post-reduce requantize runs at salt 0 on every rank,
//! keeping replica states bit-identical after each exchange.
//!
//! A [`Task`] supplies what differs: batch synthesis, step/eval input
//! assembly, eval-output normalization, and the headline metric
//! ([`TaskMetric::Bleu`] via greedy decode, [`TaskMetric::Accuracy`]
//! from the final eval). [`NmtTask`] and [`ClsTask`] adapt the
//! synthetic translation and classification corpora; a new workload
//! (calibrated SASQ-style activations, FP8 float formats, …) is one
//! more `Task` impl — not a third copy of the loop.

use std::collections::{BTreeMap, HashMap};
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use crate::costmodel::{self, TransformerWorkload};
use crate::data::batcher::{assemble_cls, Batcher, ClsBatch};
use crate::data::{Batch, ClassifyTask, TranslationTask};
use crate::metrics::{bleu, LossTracker};
use crate::model::{checkpoint, ModelState};
use crate::obs::{Phase, Recorder, RunInfo};
use crate::runtime::{ArtifactManifest, Executable, HostTensor, Runtime};
use crate::schedule::{FormatSpec, PrecisionConfig, Schedule, ScheduleState};
use crate::model::checkpoint::ResumePosition;
use crate::stash::{
    CommsTraffic, ReplicaExchange, ReplicaShard, StashBudget, StashStore, StashStoreConfig,
    StashTraffic,
};
use crate::util::json::Json;
use crate::util::rng::Pcg32;
use crate::{Error, Result};

use super::lr::LrSchedule;

/// Task-agnostic session knobs (each task adapter maps its CLI-level
/// config onto this).
#[derive(Clone, Debug)]
pub struct SessionConfig {
    pub artifacts: PathBuf,
    pub seed: u64,
    pub epochs: usize,
    pub batches_per_epoch: usize,
    pub lr: LrSchedule,
    /// Validation batches (fixed set, disjoint stream).
    pub val_batches: usize,
    /// Also validate (and feed the controller) every N steps
    /// (0 = per-epoch only).
    pub val_every_steps: usize,
    pub checkpoint: Option<PathBuf>,
    pub init_checkpoint: Option<PathBuf>,
    /// Save `checkpoint` every N steps mid-run (0 = final save only).
    /// Mid-run checkpoints are crash-salvage: they carry the
    /// batch-stream [`ResumePosition`] trailer, so resuming one
    /// continues the interrupted epoch at the next unconsumed batch
    /// (same seed, no batch seen twice) instead of re-drawing the epoch
    /// stream from the top. Final (end-of-run) checkpoints carry no
    /// position — resuming them starts a fresh set of epochs.
    pub checkpoint_every_steps: usize,
    /// Bounded prefetch depth for the batch generator thread (≥ 1).
    pub prefetch: usize,
    /// Hold the resident state (params + Adam moments) physically packed
    /// in this format between steps, decoding only at the PJRT boundary
    /// — the coordinator-side stash, owned by a [`StashStore`].
    /// Quantizes the resident state every step
    /// (Direct-Quantized-Training style), so it changes numerics;
    /// `None` (the default) keeps dense f32 state. Checkpoints written
    /// from a packed state use the packed v2 format and shrink
    /// accordingly.
    pub stash_format: Option<FormatSpec>,
    /// Resident byte budget for the stash store (`--stash-budget`):
    /// packed state beyond it spills coldest-first to the store's
    /// segment file and is prefetched back before the next dispatch.
    /// Purely a residency policy — a budgeted run's numerics are
    /// bit-identical to the unbudgeted run's. Requires `stash_format`.
    pub stash_budget: StashBudget,
    /// Directory for the stash store's spill segment + `stash.json`
    /// index (`--stash-dir`; what `dsq stash <dir>` inspects). `None`
    /// uses a per-run temp directory that is removed when the run ends.
    pub stash_dir: Option<PathBuf>,
    /// This session's slice of the data-parallel batch stream
    /// (`--replicas`). `None` ≡ `{rank 0 of 1}`: the single-replica
    /// path, bit-for-bit today's behavior. Round-robin shards consume a
    /// `replicas`-times larger global epoch stream (every batch exactly
    /// once across replicas); mirrored shards all consume the identical
    /// stream. Stepping in lockstep with peers additionally needs a
    /// [`ReplicaExchange`] installed via [`Session::set_exchange`].
    pub shard: Option<ReplicaShard>,
    /// Telemetry directory (`--trace`): the session writes
    /// `trace.rank<N>.jsonl` (span events) and `run.rank<N>.json` (the
    /// structured run manifest) here — see [`crate::obs`]. Shared
    /// across ranks in replicated runs (files are rank-tagged). `None`
    /// = tracing disabled, at near-zero per-step cost.
    pub trace_dir: Option<PathBuf>,
}

/// Whether this shard consumes global batch `idx` of an epoch stream,
/// given that the first `skip` global batches were already consumed by
/// the pre-crash run (mid-epoch resume; 0 otherwise). Round-robin deals
/// by index; mirrored shards consume everything. The partition
/// invariant — every global batch consumed by exactly one replica
/// (round-robin) and never twice across a resume — is unit-tested
/// below and is what makes N replicas a true 2×/N×-batch emulation.
pub fn replica_consumes(shard: &ReplicaShard, skip: usize, idx: usize) -> bool {
    idx >= skip && (shard.mirror || idx % shard.replicas == shard.rank)
}

/// The first globally-unconsumed batch index once *every* replica has
/// finished the step that consumed `idx` on this shard — what a
/// mid-run checkpoint persists as [`ResumePosition::batch`].
pub fn next_global_batch(shard: &ReplicaShard, idx: usize) -> usize {
    if shard.mirror {
        idx + 1
    } else {
        idx - shard.rank + shard.replicas
    }
}

/// One workload plugged into the [`Session`] engine.
pub trait Task {
    /// Batch type handed from the generator thread to the step loop.
    type Batch: Send + 'static;

    /// Manifest model key ("nmt" / "cls").
    fn model(&self) -> &'static str;

    /// Short run label for logs.
    fn describe(&self) -> &'static str;

    /// Build this epoch's batch producer. The closure runs on the
    /// generator thread (corpus synthesis happens off the step loop);
    /// it yields the epoch's batches in order, then `None`.
    fn batch_producer(
        &self,
        epoch: usize,
        nbatches: usize,
    ) -> Box<dyn FnMut() -> Option<Self::Batch> + Send>;

    /// The fixed validation set (identical every validation pass).
    fn val_batches(&self, n: usize) -> Vec<Self::Batch>;

    /// Append the batch tensors of a train step (called after the state
    /// tensors and the Adam-step scalar, before qcfg + lr).
    fn push_step_inputs(&self, batch: &Self::Batch, inputs: &mut Vec<HostTensor>);

    /// Append the batch tensors of an eval call (after the params).
    fn push_eval_inputs(&self, batch: &Self::Batch, inputs: &mut Vec<HostTensor>);

    /// Normalize one eval output tuple to `(loss_sum, ncorrect, n)`,
    /// where `n` counts the task's evaluation units (non-pad target
    /// tokens for translation, examples for classification) and
    /// `loss_sum` is the loss summed over those units — so
    /// `Σ loss_sum / Σ n` is the per-unit mean regardless of how loss
    /// mass is distributed across batches.
    fn eval_terms(&self, outs: &[HostTensor]) -> Result<(f64, f64, f64)>;

    /// The task's headline metric for the report.
    fn final_metric(
        &self,
        state: &ModelState,
        exes: &mut ExeCache,
        final_eval_acc: f64,
        diverged: bool,
    ) -> Result<Option<TaskMetric>>;
}

/// Per-run memoized executable cache for one model's artifacts.
///
/// The global [`Runtime`] already caches *compilation* by path, but the
/// per-step path (`manifest lookup -> PathBuf join -> global mutex ->
/// hash probe -> Arc clone`) used to run on every single step in both
/// training loops. This cache resolves each artifact kind exactly once
/// per run and afterwards serves a plain `HashMap` hit with no path
/// materialization or global locking (`benches/train_step_latency.rs`
/// records the per-step win).
pub struct ExeCache {
    dir: PathBuf,
    artifacts: BTreeMap<String, String>,
    cache: HashMap<String, Arc<Executable>>,
}

impl ExeCache {
    /// Build over one model family's manifest entries.
    pub fn new(man: &ArtifactManifest, model: &str) -> Result<Self> {
        let mm = man.model(model)?;
        Ok(ExeCache {
            dir: man.dir.clone(),
            artifacts: mm.artifacts.clone(),
            cache: HashMap::new(),
        })
    }

    /// The executable for an artifact kind ("train_bfp", "eval", …),
    /// loaded at most once per run.
    pub fn get(&mut self, kind: &str) -> Result<Arc<Executable>> {
        if let Some(e) = self.cache.get(kind) {
            return Ok(e.clone());
        }
        let file = self
            .artifacts
            .get(kind)
            .ok_or_else(|| Error::Manifest(format!("no '{kind}' artifact")))?;
        let exe = Runtime::global().load(&self.dir.join(file))?;
        self.cache.insert(kind.to_string(), exe.clone());
        Ok(exe)
    }

    /// Resolve the train executable for a precision config through the
    /// artifact-side dispatch guard ([`crate::runtime::train_kind_for`]):
    /// the preferred single-family variant when this model's manifest
    /// carries it, else a `train_both` that genuinely covers the config
    /// — so a cross-family config can never run through a variant that
    /// would skip (or, historically, wrong-kernel) its foreign slots,
    /// and a float config against pre-float artifacts fails loudly
    /// instead of silently training unquantized.
    pub fn get_train(&mut self, p: &PrecisionConfig) -> Result<Arc<Executable>> {
        let kind = crate::runtime::train_kind_for(&self.artifacts, p)?;
        self.get(kind)
    }

    /// Distinct artifact kinds resolved so far.
    pub fn loaded(&self) -> usize {
        self.cache.len()
    }
}

/// The task's headline quality metric, tagged by kind.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TaskMetric {
    /// Corpus BLEU from greedy decode (translation).
    Bleu(f64),
    /// Fraction correct on the validation set (classification).
    Accuracy(f64),
}

impl TaskMetric {
    pub fn kind(&self) -> &'static str {
        match self {
            TaskMetric::Bleu(_) => "bleu",
            TaskMetric::Accuracy(_) => "accuracy",
        }
    }

    pub fn value(&self) -> f64 {
        match *self {
            TaskMetric::Bleu(v) | TaskMetric::Accuracy(v) => v,
        }
    }
}

/// Result of one session run (both tasks).
///
/// **Loss convention:** `final_val_loss` (and every `val_curve` entry)
/// is the mean loss *per evaluation unit*, where a unit is a non-pad
/// target token for translation and an example for classification.
/// Batch contributions are weighted by the eval artifact's returned
/// count (`outs[2]`), so the number is comparable across partial
/// batches and between the two tasks' conventions.
#[derive(Clone, Debug)]
pub struct RunReport {
    pub steps: u64,
    pub final_val_loss: f64,
    pub best_val_loss: f64,
    /// Fraction correct in the final validation pass (token-level for
    /// translation, example-level for classification).
    pub final_eval_acc: f64,
    /// Headline task metric (`None` e.g. for a diverged or
    /// decode-skipped translation run).
    pub metric: Option<TaskMetric>,
    pub diverged: bool,
    pub trace: Vec<(PrecisionConfig, usize)>,
    pub loss_curve: Vec<(u64, f64)>,
    pub val_curve: Vec<(u64, f64)>,
    pub schedule_desc: String,
    pub wall_s: f64,
    /// Measured stash traffic (`--stash-state` runs): byte-accurate
    /// stash/spill/checkpoint counters plus the modeled-vs-observed
    /// DRAM comparison. `None` for dense-state runs.
    pub stash: Option<StashTraffic>,
    /// Measured replica-exchange traffic (`--replicas > 1` runs): the
    /// comms-bytes column next to the DRAM one — codec-observed wire
    /// bytes vs the modeled `container_bits()` number, aggregated over
    /// all ranks. `None` for single-replica runs.
    pub comms: Option<CommsTraffic>,
}

impl RunReport {
    pub fn steps_per_s(&self) -> f64 {
        self.steps as f64 / self.wall_s.max(1e-9)
    }

    /// BLEU, when this was a translation run that decoded.
    pub fn bleu(&self) -> Option<f64> {
        match self.metric {
            Some(TaskMetric::Bleu(b)) => Some(b),
            _ => None,
        }
    }

    /// Accuracy, when this was a classification run.
    pub fn accuracy(&self) -> Option<f64> {
        match self.metric {
            Some(TaskMetric::Accuracy(a)) => Some(a),
            _ => None,
        }
    }

    /// Relative hardware cost of this run's schedule trace on a
    /// paper-scale workload (the DSQ table columns). `None` when the
    /// trace is unscored — an fp32-only run (the paper leaves fp32 rows
    /// as "-") or a run that took zero steps.
    pub fn cost_on(&self, w: &TransformerWorkload) -> Option<(f64, f64)> {
        let row = costmodel::tables::dsq_trace_row(w, &self.trace);
        row.arith_rel.zip(row.dram_rel)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("steps", Json::num(self.steps as f64)),
            ("final_val_loss", Json::num(self.final_val_loss)),
            ("best_val_loss", Json::num(self.best_val_loss)),
            ("final_eval_acc", Json::num(self.final_eval_acc)),
            (
                "metric",
                self.metric.map_or(Json::Null, |m| {
                    Json::obj(vec![
                        ("kind", Json::str(m.kind())),
                        ("value", Json::num(m.value())),
                    ])
                }),
            ),
            ("diverged", Json::Bool(self.diverged)),
            ("schedule", Json::str(&self.schedule_desc)),
            ("wall_s", Json::num(self.wall_s)),
            (
                "trace",
                Json::arr(self.trace.iter().map(|(p, n)| {
                    Json::obj(vec![
                        ("precision", Json::str(&p.notation())),
                        ("formats", Json::str(&p.spec_string())),
                        ("steps", Json::num(*n as f64)),
                    ])
                })),
            ),
            (
                "loss_curve",
                Json::arr(
                    self.loss_curve
                        .iter()
                        .map(|&(s, l)| Json::arr([Json::num(s as f64), Json::num(l)])),
                ),
            ),
            (
                "val_curve",
                Json::arr(
                    self.val_curve
                        .iter()
                        .map(|&(s, l)| Json::arr([Json::num(s as f64), Json::num(l)])),
                ),
            ),
            ("stash", self.stash.as_ref().map_or(Json::Null, StashTraffic::to_json)),
            ("comms", self.comms.as_ref().map_or(Json::Null, CommsTraffic::to_json)),
        ])
    }
}

/// The generic training/fine-tuning engine. `Trainer` and `Finetuner`
/// are thin task adapters over this.
pub struct Session<T: Task> {
    cfg: SessionConfig,
    task: T,
    man: ArtifactManifest,
    state: ModelState,
    exes: ExeCache,
    model: &'static str,
    /// The tiered stash store owning the packed state between steps
    /// (`--stash-state`); `None` for dense-state runs.
    stash: Option<StashStore>,
    /// Schedule state recovered from `init_checkpoint`, applied to the
    /// schedule at the start of [`Session::run`].
    restored_schedule: Option<ScheduleState>,
    /// Batch-stream position recovered from a crash-salvage
    /// `init_checkpoint`: the epoch/offset the run resumes at (consumed
    /// at the start of [`Session::run`]).
    resume_pos: Option<ResumePosition>,
    /// All-reduce handle for data-parallel runs (installed by the
    /// replica orchestrator via [`Session::set_exchange`]).
    exchange: Option<ReplicaExchange>,
    /// Span recorder for `--trace` runs (the disabled no-op otherwise).
    obs: Recorder,
}

impl<T: Task> Session<T> {
    /// Initialize model state (from the init artifact or a checkpoint —
    /// the latter also recovering any resumable schedule state) and the
    /// per-run executable cache.
    pub fn new(cfg: SessionConfig, task: T, man: ArtifactManifest) -> Result<Self> {
        if cfg.prefetch == 0 {
            return Err(Error::Config("prefetch depth must be >= 1".into()));
        }
        if cfg.checkpoint_every_steps > 0 && cfg.checkpoint.is_none() {
            return Err(Error::Config(
                "checkpoint-every requires a checkpoint path (mid-run saves \
                 would silently go nowhere)"
                    .into(),
            ));
        }
        if cfg.stash_format.is_none() && cfg.stash_budget != StashBudget::Unlimited {
            return Err(Error::Config(
                "--stash-budget requires --stash-state <spec> (there is no packed \
                 stash to budget)"
                    .into(),
            ));
        }
        if cfg.stash_format.is_none() && cfg.stash_dir.is_some() {
            return Err(Error::Config(
                "--stash-dir requires --stash-state <spec> (there is no stash store \
                 to put there)"
                    .into(),
            ));
        }
        if let Some(sh) = &cfg.shard {
            if sh.replicas == 0 || sh.rank >= sh.replicas {
                return Err(Error::Config(format!(
                    "bad replica shard: rank {} of {} replicas",
                    sh.rank, sh.replicas
                )));
            }
        }
        let model = task.model();
        let mm = man.model(model)?;
        let (mut state, restored_schedule, resume_pos) = match &cfg.init_checkpoint {
            Some(path) => checkpoint::load_checkpoint_positioned(path, mm)?,
            None => {
                (ModelState::init(Runtime::global(), &man, model, cfg.seed as i32)?, None, None)
            }
        };
        let mut stash = match &cfg.stash_format {
            Some(spec) => {
                let mut store = match &cfg.stash_dir {
                    Some(dir) => StashStore::new(StashStoreConfig {
                        spec: *spec,
                        budget: cfg.stash_budget,
                        dir: dir.clone(),
                    })?,
                    None => StashStore::ephemeral(*spec, cfg.stash_budget)?,
                };
                let names: Vec<&str> = mm.params.iter().map(|p| p.name.as_str()).collect();
                store.set_param_names(&names);
                Some(store)
            }
            None => None,
        };
        if let Some(store) = &mut stash {
            store.stash_state(&mut state)?;
            // If the budget spilled any of the initial state, start
            // reading it back now so the first dispatch doesn't block
            // on a cold read.
            store.start_prefetch(&state);
        }
        let exes = ExeCache::new(&man, model)?;
        let obs = match &cfg.trace_dir {
            Some(dir) => Recorder::to_dir(dir, cfg.shard.as_ref().map_or(0, |s| s.rank))?,
            None => Recorder::disabled(),
        };
        Ok(Session {
            cfg,
            task,
            man,
            state,
            exes,
            model,
            stash,
            restored_schedule,
            resume_pos,
            exchange: None,
            obs,
        })
    }

    /// Install the per-rank all-reduce handle for a data-parallel run.
    /// Requires a matching [`SessionConfig::shard`] — the shard decides
    /// which batches this session consumes, the exchange reduces its
    /// state with the peers', and the two must agree on rank/replicas.
    pub fn set_exchange(&mut self, ex: ReplicaExchange) -> Result<()> {
        let Some(sh) = self.cfg.shard else {
            return Err(Error::Config(
                "a replica exchange needs a shard config (which slice of the batch \
                 stream is this replica's?)"
                    .into(),
            ));
        };
        if sh.rank != ex.rank() || sh.replicas != ex.replicas() {
            return Err(Error::Config(format!(
                "replica exchange is rank {} of {}, but this session shards as rank {} of {}",
                ex.rank(),
                ex.replicas(),
                sh.rank,
                sh.replicas
            )));
        }
        self.exchange = Some(ex);
        Ok(())
    }

    pub fn cfg(&self) -> &SessionConfig {
        &self.cfg
    }

    pub fn task(&self) -> &T {
        &self.task
    }

    pub fn state(&self) -> &ModelState {
        &self.state
    }

    pub fn manifest(&self) -> &ArtifactManifest {
        &self.man
    }

    /// Distinct executables resolved so far this run.
    pub fn executables_loaded(&self) -> usize {
        self.exes.loaded()
    }

    /// The stash store's traffic report, when this run stashes state.
    pub fn stash_traffic(&self) -> Option<StashTraffic> {
        self.stash.as_ref().map(StashStore::traffic_report)
    }

    /// The replica exchange's comms-traffic report, when this run is
    /// data-parallel (aggregated across all ranks sharing the core).
    pub fn comms_traffic(&self) -> Option<CommsTraffic> {
        self.exchange.as_ref().map(ReplicaExchange::traffic_report)
    }

    /// Mean per-unit loss + accuracy over batches (see [`RunReport`]
    /// for the unit convention).
    pub fn evaluate(&mut self, batches: &[T::Batch]) -> Result<(f64, f64)> {
        // Eval reads the params: spilled slots must come back first
        // (budgeted runs may have spilled them after the last step).
        if let Some(store) = &mut self.stash {
            store.fetch_state(&mut self.state)?;
        }
        let exe = self.exes.get("eval")?;
        let (mut loss_sum, mut ncorrect, mut total) = (0f64, 0f64, 0f64);
        for batch in batches {
            let mut inputs = self.state.params.clone();
            self.task.push_eval_inputs(batch, &mut inputs);
            let outs = exe.run(&inputs)?;
            let (l, c, n) = self.task.eval_terms(&outs)?;
            loss_sum += l;
            ncorrect += c;
            total += n;
        }
        Ok((loss_sum / total.max(1.0), ncorrect / total.max(1.0)))
    }

    fn validate(
        &mut self,
        schedule: &mut dyn Schedule,
        val_set: &[T::Batch],
        val_curve: &mut Vec<(u64, f64)>,
    ) -> Result<(f64, f64)> {
        let span = self.obs.span_start(Phase::Validate);
        let (val_loss, val_acc) = self.evaluate(val_set)?;
        self.obs.span_close(span, self.state.step, 0);
        val_curve.push((self.state.step, val_loss));
        schedule.observe_validation(val_loss);
        Ok((val_loss, val_acc))
    }

    /// Save `cfg.checkpoint` (no-op when unset) with the schedule's
    /// resumable state in the trailer — plus, for mid-run saves, the
    /// batch-stream position the resumed run continues at. Spilled
    /// slots stream their records from the spill segment without
    /// rehydrating; the bytes written land on the traffic meter.
    fn save_checkpoint(
        &mut self,
        schedule: &dyn Schedule,
        position: Option<&ResumePosition>,
    ) -> Result<()> {
        let Some(path) = self.cfg.checkpoint.clone() else { return Ok(()) };
        let span = self.obs.span_start(Phase::Checkpoint);
        let mm = self.man.model(self.model)?;
        checkpoint::save_checkpoint_positioned(
            &path,
            &self.state,
            mm,
            schedule.snapshot().as_ref(),
            position,
        )?;
        let bytes = std::fs::metadata(&path)?.len();
        if let Some(store) = &mut self.stash {
            store.note_checkpoint_bytes(bytes);
        }
        self.obs.span_close(span, self.state.step, bytes);
        crate::info!("checkpoint saved to {path:?}");
        Ok(())
    }

    /// Run the full loop under `schedule`.
    pub fn run(&mut self, schedule: &mut dyn Schedule) -> Result<RunReport> {
        if let Some(s) = self.restored_schedule.take() {
            schedule.restore(&s);
            crate::info!("schedule state resumed from checkpoint: {}", schedule.describe());
        }
        let start = Instant::now();
        let mut tracker = LossTracker::new();
        let mut trace: Vec<(PrecisionConfig, usize)> = Vec::new();
        let mut val_curve: Vec<(u64, f64)> = Vec::new();
        let val_set = self.task.val_batches(self.cfg.val_batches);
        let mut diverged = false;
        // Most recent validation as (step, loss, acc): dedupes the
        // epoch-boundary pass when `val_every_steps` lands on it (double-
        // observing one loss would spuriously advance the ladder) and
        // lets the final report reuse it instead of re-running eval.
        let mut last_val: Option<(u64, f64, f64)> = None;

        crate::info!(
            "{}: {} params, {} epochs x {} batches, schedule {}",
            self.task.describe(),
            self.state.numel(),
            self.cfg.epochs,
            self.cfg.batches_per_epoch,
            schedule.describe()
        );

        let shard =
            self.cfg.shard.unwrap_or(ReplicaShard { rank: 0, replicas: 1, mirror: true });
        // Global epoch stream size: round-robin shards deal a
        // `replicas`-times larger pool so every replica still takes
        // `batches_per_epoch` owned steps per epoch (the N×-batch
        // emulation); mirrored — and single-replica — streams are the
        // plain per-epoch pool.
        let epoch_total = if shard.mirror {
            self.cfg.batches_per_epoch
        } else {
            self.cfg.batches_per_epoch * shard.replicas
        };
        // Crash-salvage resume: continue the interrupted epoch at the
        // first unconsumed global batch instead of re-drawing streams
        // and replaying seen data.
        let resume = self.resume_pos.take();
        let start_epoch = resume.map_or(0, |p| p.epoch as usize);
        let mut resume_skip = resume.map_or(0, |p| (p.batch as usize).min(epoch_total));
        if let Some(p) = resume {
            crate::info!("resuming the batch stream at epoch {} offset {}", p.epoch, p.batch);
        }

        'epochs: for epoch in start_epoch..self.cfg.epochs {
            // Batch generator thread (bounded prefetch). Every replica
            // synthesizes the identical global stream (seeded by epoch
            // alone) and consumes only its shard of it.
            let mut produce = self.task.batch_producer(epoch, epoch_total);
            let (tx, rx) = mpsc::sync_channel::<T::Batch>(self.cfg.prefetch);
            let producer = std::thread::spawn(move || {
                while let Some(batch) = produce() {
                    if tx.send(batch).is_err() {
                        return; // consumer gone (divergence abort)
                    }
                }
            });
            let skip = std::mem::take(&mut resume_skip);

            // The consume loop parks on the channel between batches; a
            // witnessed lock held here would stall the whole replica
            // group (the blocking_under_lock class, asserted at runtime).
            crate::util::ordwitness::assert_lock_free("consuming the batch channel");
            let mut gidx = 0usize;
            loop {
                let bspan = self.obs.span_start(Phase::BatchWait);
                let Ok(batch) = rx.recv() else { break };
                self.obs.span_close(bspan, self.state.step + 1, 0);
                let idx = gidx;
                gidx += 1;
                if !replica_consumes(&shard, skip, idx) {
                    continue;
                }
                let pc = schedule.current();
                let exe = self.exes.get_train(&pc)?;
                // Materialize the stash before dispatch: the readback
                // prefetcher started after the previous step has been
                // pulling spilled slots back while we waited on the
                // batch channel, so this drains it rather than reading
                // cold. The StashRead span covers the whole input
                // staging region (fetch + clone + dispatch-read note);
                // the SpillRead sub-phase is imported from the store's
                // own clock.
                let read0 = self
                    .obs
                    .is_active()
                    .then(|| self.stash.as_ref().map(|s| (s.traffic(), s.phase_ns())))
                    .flatten();
                let rspan = self.obs.span_start(Phase::StashRead);
                if let Some(store) = &mut self.stash {
                    store.fetch_state(&mut self.state)?;
                }
                let lr = self.cfg.lr.at(self.state.step + 1) as f32;
                let mut inputs = Vec::with_capacity(3 * self.state.params.len() + 6);
                inputs.extend(self.state.params.iter().cloned());
                inputs.extend(self.state.m.iter().cloned());
                inputs.extend(self.state.v.iter().cloned());
                inputs.push(HostTensor::scalar_f32((self.state.step + 1) as f32));
                self.task.push_step_inputs(&batch, &mut inputs);
                inputs.push(HostTensor::f32(vec![8], pc.as_qcfg().to_vec()));
                inputs.push(HostTensor::scalar_f32(lr));
                if let Some(store) = &mut self.stash {
                    // The packed state is about to decode into PJRT —
                    // the stash *read* of the write/read cycle.
                    store.note_dispatch_read(&self.state);
                }
                if let (Some((m0, p0)), Some(store)) = (read0, self.stash.as_ref()) {
                    let (m1, p1) = (store.traffic(), store.phase_ns());
                    let step = self.state.step + 1;
                    self.obs.span_close(
                        rspan,
                        step,
                        (m1.stash_read_bytes - m0.stash_read_bytes)
                            + (m1.spill_read_bytes - m0.spill_read_bytes),
                    );
                    self.obs.span_import(
                        Phase::SpillRead,
                        step,
                        p1.spill_read_ns - p0.spill_read_ns,
                        m1.spill_read_bytes - m0.spill_read_bytes,
                    );
                } else {
                    self.obs.span_close(rspan, self.state.step + 1, 0);
                }
                let dspan = self.obs.span_start(Phase::Dispatch);
                let outs = exe.run(&inputs)?;
                let mut loss = self.state.absorb_step_output(outs)? as f64;
                self.obs.span_close(dspan, self.state.step, 0);
                // Lockstep all-reduce with the peer replicas: dequant,
                // mean in rank order, requant at salt 0 — every replica
                // leaves this call with bit-identical state and loss, so
                // divergence detection and the schedule stay in lockstep
                // too (no rank can abort while peers block on the
                // barrier; an *error* here tears the exchange down via
                // the orchestrator instead).
                if let Some(ex) = &self.exchange {
                    let c0 = self.obs.is_active().then(|| ex.counter_snapshot());
                    let espan = self.obs.span_start(Phase::Exchange);
                    loss = ex.all_reduce_state(&mut self.state, loss as f32)? as f64;
                    if let Some(c0) = c0 {
                        // The exchange's own clocks split the round into
                        // encode / post / reduce sub-phases; bytes are
                        // the wire deltas this round moved.
                        let c1 = ex.counter_snapshot();
                        let step = self.state.step;
                        self.obs.span_close(
                            espan,
                            step,
                            (c1.tx_bytes - c0.tx_bytes) + (c1.rx_bytes - c0.rx_bytes),
                        );
                        self.obs.span_import(
                            Phase::ExchEncode,
                            step,
                            c1.encode_ns - c0.encode_ns,
                            c1.tx_bytes - c0.tx_bytes,
                        );
                        self.obs.span_import(
                            Phase::ExchPost,
                            step,
                            c1.post_ns - c0.post_ns,
                            c1.frame_bytes - c0.frame_bytes,
                        );
                        self.obs.span_import(
                            Phase::ExchReduce,
                            step,
                            c1.reduce_ns - c0.reduce_ns,
                            c1.rx_bytes - c0.rx_bytes,
                        );
                    } else {
                        self.obs.span_close(espan, self.state.step, 0);
                    }
                }
                // Re-stash: step outputs arrive dense from the artifact;
                // the resident copy goes back to packed storage (the
                // stash *write*), the budget spills the overflow, and
                // the prefetcher starts reading it back in the
                // background.
                if let Some(store) = &mut self.stash {
                    let write0 =
                        self.obs.is_active().then(|| (store.traffic(), store.phase_ns()));
                    let wspan = self.obs.span_start(Phase::StashWrite);
                    store.stash_state(&mut self.state)?;
                    store.start_prefetch(&self.state);
                    let step = self.state.step;
                    if let Some((m0, p0)) = write0 {
                        let (m1, p1) = (store.traffic(), store.phase_ns());
                        self.obs.span_close(
                            wspan,
                            step,
                            (m1.stash_write_bytes - m0.stash_write_bytes)
                                + (m1.spill_write_bytes - m0.spill_write_bytes),
                        );
                        self.obs.span_import(
                            Phase::Quantize,
                            step,
                            p1.quantize_ns - p0.quantize_ns,
                            m1.stash_write_bytes - m0.stash_write_bytes,
                        );
                        self.obs.span_import(
                            Phase::SpillWrite,
                            step,
                            p1.spill_write_ns - p0.spill_write_ns,
                            m1.spill_write_bytes - m0.spill_write_bytes,
                        );
                    } else {
                        self.obs.span_close(wspan, step, 0);
                    }
                }
                tracker.record(self.state.step, loss);
                match trace.last_mut() {
                    Some((last, n)) if *last == pc => *n += 1,
                    _ => trace.push((pc, 1)),
                }
                if tracker.diverged() {
                    diverged = true;
                    crate::warn!("{} diverged at step {}", self.task.describe(), self.state.step);
                    drop(rx);
                    break 'epochs;
                }
                if self.cfg.val_every_steps > 0
                    && self.state.step % self.cfg.val_every_steps as u64 == 0
                {
                    let (val_loss, val_acc) =
                        self.validate(schedule, &val_set, &mut val_curve)?;
                    last_val = Some((self.state.step, val_loss, val_acc));
                    crate::info!(
                        "step {}: val {val_loss:.4} acc {:.1}% | {}",
                        self.state.step,
                        val_acc * 100.0,
                        schedule.describe()
                    );
                }
                if self.cfg.checkpoint_every_steps > 0
                    && self.state.step % self.cfg.checkpoint_every_steps as u64 == 0
                {
                    // The position a resumed run continues at: the first
                    // global batch no replica has consumed once everyone
                    // finishes this step (normalized to the next epoch's
                    // origin when this step closed the epoch out).
                    let done = next_global_batch(&shard, idx);
                    let pos = if done >= epoch_total {
                        ResumePosition { epoch: epoch as u64 + 1, batch: 0 }
                    } else {
                        ResumePosition { epoch: epoch as u64, batch: done as u64 }
                    };
                    self.save_checkpoint(schedule, Some(&pos))?;
                }
                // Drain the bounded event buffer while the producer
                // refills the channel — the trace file is appended here,
                // off every lock, not from inside the recorder's mutex.
                self.obs.flush_events()?;
            }
            crate::util::ordwitness::assert_lock_free("joining the batch producer");
            producer.join().map_err(|_| Error::Config("batch producer panicked".into()))?;

            // Per-epoch validation — unless the step cadence already
            // validated at exactly this step.
            if !last_val.is_some_and(|(s, _, _)| s == self.state.step) {
                let (val_loss, val_acc) = self.validate(schedule, &val_set, &mut val_curve)?;
                last_val = Some((self.state.step, val_loss, val_acc));
                crate::info!(
                    "epoch {epoch}: train {:.4} | val {val_loss:.4} acc {:.1}% | {}",
                    tracker.window_mean(self.cfg.batches_per_epoch).unwrap_or(f64::NAN),
                    val_acc * 100.0,
                    schedule.describe()
                );
            }
        }

        // Eval is deterministic and the state hasn't changed since the
        // last validation pass, so reuse it; re-run only when the run
        // broke off mid-epoch (divergence) or never validated.
        let (final_val_loss, final_eval_acc) = match last_val {
            Some((s, l, a)) if s == self.state.step => (l, a),
            _ => {
                let span = self.obs.span_start(Phase::Validate);
                let r = self.evaluate(&val_set)?;
                self.obs.span_close(span, self.state.step, 0);
                r
            }
        };
        // The headline metric (BLEU decode) reads the params directly;
        // bring any slots the budget spilled after the last step back.
        if let Some(store) = &mut self.stash {
            store.fetch_state(&mut self.state)?;
        }
        let metric =
            self.task.final_metric(&self.state, &mut self.exes, final_eval_acc, diverged)?;
        // Never overwrite the checkpoint with diverged (NaN/blown-up)
        // state — a crash-salvage file from `checkpoint_every_steps`
        // holding the last good params is worth keeping.
        if diverged {
            if self.cfg.checkpoint.is_some() {
                crate::warn!("skipping final checkpoint: state diverged");
            }
        } else {
            // End-of-run saves carry no position: resuming a *finished*
            // run starts a fresh set of epochs (the mid-ladder resume
            // semantics every pre-position checkpoint had).
            self.save_checkpoint(schedule, None)?;
        }
        let report = RunReport {
            steps: self.state.step,
            final_val_loss,
            best_val_loss: val_curve
                .iter()
                .map(|&(_, l)| l)
                .fold(final_val_loss, f64::min),
            final_eval_acc,
            metric,
            diverged,
            trace,
            loss_curve: tracker.history().to_vec(),
            val_curve,
            schedule_desc: schedule.describe(),
            wall_s: start.elapsed().as_secs_f64(),
            stash: self.stash_traffic(),
            comms: self.comms_traffic(),
        };
        // Finalize the run manifest (`--trace`): the precision ladder
        // with the step each rung started at, the run config, and the
        // traffic reports `dsq trace` cross-checks span bytes against.
        // This tail also covers the diverged early-exit path.
        let mut ladder = Vec::new();
        let mut at = 0u64;
        for (pc, n) in &report.trace {
            ladder.push((at + 1, pc.spec_string()));
            at += *n as u64;
        }
        let config = Json::obj(vec![
            ("artifacts", Json::str(&self.cfg.artifacts.display().to_string())),
            ("seed", Json::num(self.cfg.seed as f64)),
            ("epochs", Json::num(self.cfg.epochs as f64)),
            ("batches_per_epoch", Json::num(self.cfg.batches_per_epoch as f64)),
            (
                "stash_format",
                self.cfg.stash_format.map_or(Json::Null, |f| Json::str(&f.to_string())),
            ),
            ("stash_budget", Json::str(&self.cfg.stash_budget.to_string())),
            ("replicas", Json::num(shard.replicas as f64)),
            ("schedule", Json::str(&report.schedule_desc)),
        ]);
        self.obs.finish_run(&RunInfo {
            argv: std::env::args().collect(),
            config,
            steps: report.steps,
            wall_s: report.wall_s,
            stash: report.stash.as_ref().map(StashTraffic::to_json),
            comms: report.comms.as_ref().map(CommsTraffic::to_json),
            ladder,
        })?;
        Ok(report)
    }
}

/// Translation task adapter ([`TranslationTask`] + fixed-shape
/// [`Batcher`]): the trained-from-scratch seq2seq workload, with greedy
/// BLEU as the headline metric.
pub struct NmtTask {
    pub task: TranslationTask,
    pub batcher: Batcher,
    pub seed: u64,
    /// Test batches for the BLEU decode (0 = skip).
    pub bleu_batches: usize,
}

impl Task for NmtTask {
    type Batch = Batch;

    fn model(&self) -> &'static str {
        "nmt"
    }

    fn describe(&self) -> &'static str {
        "translation training"
    }

    fn batch_producer(
        &self,
        epoch: usize,
        nbatches: usize,
    ) -> Box<dyn FnMut() -> Option<Batch> + Send> {
        let task = self.task.clone();
        let batcher = self.batcher.clone();
        let epoch_seed = self.seed ^ ((epoch as u64 + 1) << 32);
        // The pool is synthesized lazily on the generator thread, then
        // drained batch by batch through the bounded channel.
        let mut queue: Option<std::vec::IntoIter<Batch>> = None;
        Box::new(move || {
            queue
                .get_or_insert_with(|| {
                    let mut rng = Pcg32::new(epoch_seed);
                    let mut pool: Vec<_> = (0..nbatches * batcher.batch)
                        .map(|_| task.sample_pair(&mut rng))
                        .collect();
                    batcher.epoch(&mut pool, &mut rng).into_iter()
                })
                .next()
        })
    }

    fn val_batches(&self, n: usize) -> Vec<Batch> {
        let mut rng = self.task.split_rng("valid");
        (0..n)
            .map(|_| {
                let pairs: Vec<_> =
                    (0..self.batcher.batch).map(|_| self.task.sample_pair(&mut rng)).collect();
                self.batcher.assemble(&pairs)
            })
            .collect()
    }

    fn push_step_inputs(&self, batch: &Batch, inputs: &mut Vec<HostTensor>) {
        let (b, s, t) = (self.batcher.batch, self.batcher.src_len, self.batcher.tgt_len);
        inputs.push(HostTensor::i32(vec![b, s], batch.src.clone()));
        inputs.push(HostTensor::i32(vec![b, t], batch.tgt_in.clone()));
        inputs.push(HostTensor::i32(vec![b, t], batch.tgt_out.clone()));
    }

    fn push_eval_inputs(&self, batch: &Batch, inputs: &mut Vec<HostTensor>) {
        self.push_step_inputs(batch, inputs);
    }

    /// The nmt eval artifact returns `(loss_sum, ncorrect, ntok)` — the
    /// loss is already summed over non-pad target tokens.
    fn eval_terms(&self, outs: &[HostTensor]) -> Result<(f64, f64, f64)> {
        Ok((
            outs[0].item_f32()? as f64,
            outs[1].item_f32()? as f64,
            outs[2].item_f32()? as f64,
        ))
    }

    /// Greedy-decode BLEU on the test stream (skipped for diverged runs
    /// — there is nothing meaningful to decode).
    fn final_metric(
        &self,
        state: &ModelState,
        exes: &mut ExeCache,
        _final_eval_acc: f64,
        diverged: bool,
    ) -> Result<Option<TaskMetric>> {
        if self.bleu_batches == 0 || diverged {
            return Ok(None);
        }
        let exe = exes.get("decode")?;
        let (b, s, t) = (self.batcher.batch, self.batcher.src_len, self.batcher.tgt_len);
        let mut rng = self.task.split_rng("test");
        let mut pairs = Vec::new();
        for _ in 0..self.bleu_batches {
            let batch_pairs: Vec<_> = (0..b).map(|_| self.task.sample_pair(&mut rng)).collect();
            let batch = self.batcher.assemble(&batch_pairs);
            let mut inputs = state.params.clone();
            inputs.push(HostTensor::i32(vec![b, s], batch.src.clone()));
            let outs = exe.run(&inputs)?;
            let toks = outs[0].as_i32()?;
            for (i, p) in batch_pairs.iter().enumerate() {
                let hyp = bleu::sentence_tokens(&toks[i * t..(i + 1) * t]);
                let reference = bleu::sentence_tokens(&p.tgt);
                pairs.push((hyp, reference));
            }
        }
        Ok(Some(TaskMetric::Bleu(bleu::corpus_bleu(&pairs).bleu)))
    }
}

/// Classification task adapter ([`ClassifyTask`]): the fine-tuned
/// GLUE-style workload, with validation accuracy as the headline
/// metric.
pub struct ClsTask {
    pub task: ClassifyTask,
    pub batch: usize,
    pub seq_len: usize,
    pub seed: u64,
}

impl ClsTask {
    fn make_batch(&self, rng: &mut Pcg32) -> ClsBatch {
        make_cls_batch(&self.task, self.batch, self.seq_len, rng)
    }
}

fn make_cls_batch(
    task: &ClassifyTask,
    batch: usize,
    seq_len: usize,
    rng: &mut Pcg32,
) -> ClsBatch {
    let exs: Vec<_> = (0..batch).map(|_| task.sample(rng)).collect();
    assemble_cls(&exs, seq_len)
}

impl Task for ClsTask {
    type Batch = ClsBatch;

    fn model(&self) -> &'static str {
        "cls"
    }

    fn describe(&self) -> &'static str {
        "classification fine-tuning"
    }

    fn batch_producer(
        &self,
        epoch: usize,
        nbatches: usize,
    ) -> Box<dyn FnMut() -> Option<ClsBatch> + Send> {
        let task = self.task.clone();
        let (b, l) = (self.batch, self.seq_len);
        let mut rng = Pcg32::new(self.seed ^ ((epoch as u64 + 1) << 32) ^ 0xF17E);
        let mut left = nbatches;
        Box::new(move || {
            if left == 0 {
                return None;
            }
            left -= 1;
            Some(make_cls_batch(&task, b, l, &mut rng))
        })
    }

    fn val_batches(&self, n: usize) -> Vec<ClsBatch> {
        let mut rng = self.task.split_rng("valid");
        (0..n).map(|_| self.make_batch(&mut rng)).collect()
    }

    fn push_step_inputs(&self, batch: &ClsBatch, inputs: &mut Vec<HostTensor>) {
        inputs.push(HostTensor::i32(vec![self.batch, self.seq_len], batch.tokens.clone()));
        inputs.push(HostTensor::i32(vec![self.batch], batch.labels.clone()));
    }

    fn push_eval_inputs(&self, batch: &ClsBatch, inputs: &mut Vec<HostTensor>) {
        self.push_step_inputs(batch, inputs);
    }

    /// The cls eval artifact returns `(mean_loss, ncorrect, n)` — the
    /// loss is the *batch mean*, so it is re-weighted by the returned
    /// example count to make `Σ loss_sum / Σ n` a per-example mean
    /// (comparable with the trainer's per-token convention).
    fn eval_terms(&self, outs: &[HostTensor]) -> Result<(f64, f64, f64)> {
        let n = outs[2].item_f32()? as f64;
        Ok((outs[0].item_f32()? as f64 * n, outs[1].item_f32()? as f64, n))
    }

    fn final_metric(
        &self,
        _state: &ModelState,
        _exes: &mut ExeCache,
        final_eval_acc: f64,
        _diverged: bool,
    ) -> Result<Option<TaskMetric>> {
        Ok(Some(TaskMetric::Accuracy(final_eval_acc)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{ClassifyConfig, TranslationConfig, Variant};

    fn nmt_task() -> NmtTask {
        NmtTask {
            task: TranslationTask::new(TranslationConfig {
                vocab: 256,
                src_len: 24,
                tgt_len: 24,
                variant: Variant::Iwslt,
                seed: 7,
            }),
            batcher: Batcher::new(16, 24, 24),
            seed: 7,
            bleu_batches: 0,
        }
    }

    fn cls_task() -> ClsTask {
        ClsTask {
            task: ClassifyTask::new(ClassifyConfig {
                vocab: 256,
                seq_len: 48,
                nclasses: 3,
                seed: 7,
            }),
            batch: 16,
            seq_len: 48,
            seed: 7,
        }
    }

    #[test]
    fn nmt_producer_yields_exactly_nbatches_then_none() {
        let t = nmt_task();
        let mut produce = t.batch_producer(0, 5);
        let mut got = 0;
        while let Some(b) = produce() {
            assert_eq!(b.src.len(), 16 * 24);
            got += 1;
        }
        assert_eq!(got, 5);
        assert!(produce().is_none(), "stays exhausted");
    }

    #[test]
    fn nmt_producer_is_deterministic_per_epoch_and_differs_across_epochs() {
        let t = nmt_task();
        let (mut a, mut b, mut c) =
            (t.batch_producer(0, 2), t.batch_producer(0, 2), t.batch_producer(1, 2));
        let (x, y, z) = (a().unwrap(), b().unwrap(), c().unwrap());
        assert_eq!(x, y, "same epoch seed, same stream");
        assert_ne!(x, z, "different epoch, different stream");
    }

    #[test]
    fn cls_producer_yields_exactly_nbatches_then_none() {
        let t = cls_task();
        let mut produce = t.batch_producer(3, 4);
        let mut got = 0;
        while let Some(b) = produce() {
            assert_eq!(b.tokens.len(), 16 * 48);
            got += 1;
        }
        assert_eq!(got, 4);
        assert!(produce().is_none());
    }

    #[test]
    fn val_batches_are_fixed_across_calls() {
        let t = cls_task();
        assert_eq!(t.val_batches(3), t.val_batches(3));
        let n = nmt_task();
        assert_eq!(n.val_batches(2), n.val_batches(2));
    }

    #[test]
    fn step_inputs_have_expected_arity_and_shapes() {
        let t = nmt_task();
        let mut produce = t.batch_producer(0, 1);
        let batch = produce().unwrap();
        let mut inputs = Vec::new();
        t.push_step_inputs(&batch, &mut inputs);
        assert_eq!(inputs.len(), 3);
        assert_eq!(inputs[0].shape, vec![16, 24]);

        let c = cls_task();
        let mut produce = c.batch_producer(0, 1);
        let batch = produce().unwrap();
        let mut inputs = Vec::new();
        c.push_step_inputs(&batch, &mut inputs);
        assert_eq!(inputs.len(), 2);
        assert_eq!(inputs[0].shape, vec![16, 48]);
        assert_eq!(inputs[1].shape, vec![16]);
    }

    #[test]
    fn eval_terms_normalize_per_unit() {
        // nmt: already a sum over ntok.
        let t = nmt_task();
        let outs = vec![
            HostTensor::scalar_f32(12.0),
            HostTensor::scalar_f32(30.0),
            HostTensor::scalar_f32(40.0),
        ];
        assert_eq!(t.eval_terms(&outs).unwrap(), (12.0, 30.0, 40.0));
        // cls: batch-mean loss is re-weighted by the example count, so
        // two batches of different sizes average per example.
        let c = cls_task();
        let outs = vec![
            HostTensor::scalar_f32(0.5),
            HostTensor::scalar_f32(10.0),
            HostTensor::scalar_f32(16.0),
        ];
        assert_eq!(c.eval_terms(&outs).unwrap(), (8.0, 10.0, 16.0));
    }

    #[test]
    fn new_rejects_bad_config_before_touching_the_runtime() {
        let empty = crate::runtime::ModelManifest {
            config: Default::default(),
            params: vec![],
            artifacts: Default::default(),
        };
        let man = ArtifactManifest {
            dir: "/nonexistent".into(),
            nmt: empty.clone(),
            cls: empty,
            quant_artifacts: Default::default(),
            quant_shape: vec![],
        };
        let cfg = SessionConfig {
            artifacts: "/nonexistent".into(),
            seed: 0,
            epochs: 1,
            batches_per_epoch: 1,
            lr: LrSchedule::Constant { lr: 1e-3 },
            val_batches: 1,
            val_every_steps: 0,
            checkpoint: None,
            init_checkpoint: None,
            checkpoint_every_steps: 0,
            prefetch: 0,
            stash_format: None,
            stash_budget: StashBudget::Unlimited,
            stash_dir: None,
            shard: None,
            trace_dir: None,
        };
        // prefetch 0 is rejected up front (no PJRT involved).
        let r = Session::new(cfg.clone(), nmt_task(), man.clone());
        assert!(matches!(r, Err(Error::Config(_))));
        // checkpoint-every without a checkpoint path would silently
        // save nothing mid-run — rejected up front too.
        let cfg2 = SessionConfig { prefetch: 4, checkpoint_every_steps: 5, ..cfg.clone() };
        let r = Session::new(cfg2, nmt_task(), man.clone());
        assert!(matches!(r, Err(Error::Config(_))));
        // A budget without a stash format has nothing to budget.
        let cfg3 = SessionConfig {
            prefetch: 4,
            stash_budget: StashBudget::Bytes(1024),
            ..cfg.clone()
        };
        match Session::new(cfg3, nmt_task(), man.clone()).err() {
            Some(Error::Config(msg)) => {
                assert!(msg.contains("--stash-state"), "{msg}");
            }
            other => panic!("expected Config error, got {other:?}"),
        }
        // Likewise a stash dir without a stash store to put there.
        let cfg4 =
            SessionConfig { prefetch: 4, stash_dir: Some("/tmp/x".into()), ..cfg.clone() };
        match Session::new(cfg4, nmt_task(), man.clone()).err() {
            Some(Error::Config(msg)) => {
                assert!(msg.contains("--stash-state"), "{msg}");
            }
            other => panic!("expected Config error, got {other:?}"),
        }
        // An out-of-range replica shard is caught before any PJRT work.
        let cfg5 = SessionConfig {
            prefetch: 4,
            shard: Some(ReplicaShard { rank: 2, replicas: 2, mirror: false }),
            ..cfg
        };
        match Session::new(cfg5, nmt_task(), man).err() {
            Some(Error::Config(msg)) => assert!(msg.contains("rank 2"), "{msg}"),
            other => panic!("expected Config error, got {other:?}"),
        }
    }

    #[test]
    fn round_robin_shard_partitions_every_batch_exactly_once() {
        // The data-parallel contract: across ranks, each global batch of
        // an epoch is consumed by exactly one replica — no batch dropped,
        // none seen twice. Mirrored shards consume everything.
        for replicas in [1usize, 2, 3, 5] {
            let total = 4 * replicas;
            for idx in 0..total {
                let owners: Vec<usize> = (0..replicas)
                    .filter(|&rank| {
                        replica_consumes(
                            &ReplicaShard { rank, replicas, mirror: false },
                            0,
                            idx,
                        )
                    })
                    .collect();
                assert_eq!(owners.len(), 1, "batch {idx} with {replicas} replicas: {owners:?}");
            }
            // Every rank owns exactly batches_per_epoch = total/replicas.
            for rank in 0..replicas {
                let sh = ReplicaShard { rank, replicas, mirror: false };
                let owned = (0..total).filter(|&i| replica_consumes(&sh, 0, i)).count();
                assert_eq!(owned, total / replicas);
            }
        }
        let mirror = ReplicaShard { rank: 1, replicas: 2, mirror: true };
        assert!((0..8).all(|i| replica_consumes(&mirror, 0, i)));
    }

    #[test]
    fn resume_skip_never_replays_a_consumed_batch() {
        // Crash-salvage invariant: batches consumed before the crash
        // (0..skip) and after the resume (the skip-filtered stream) are
        // disjoint and together cover the epoch exactly once — per rank.
        for replicas in [1usize, 2, 3] {
            let total = 6 * replicas;
            for rank in 0..replicas {
                let sh = ReplicaShard { rank, replicas, mirror: false };
                // Simulate a crash right after the step that consumed
                // global batch `cut`; the checkpoint records the
                // next-unconsumed position.
                for cut in (0..total).filter(|&i| replica_consumes(&sh, 0, i)) {
                    let skip = next_global_batch(&sh, cut);
                    let before: Vec<usize> =
                        (0..skip).filter(|&i| replica_consumes(&sh, 0, i)).collect();
                    let after: Vec<usize> =
                        (0..total).filter(|&i| replica_consumes(&sh, skip, i)).collect();
                    assert!(before.iter().all(|i| !after.contains(i)), "replayed a batch");
                    let mut union = before;
                    union.extend(&after);
                    let want: Vec<usize> =
                        (0..total).filter(|&i| replica_consumes(&sh, 0, i)).collect();
                    assert_eq!(union, want, "resume must cover the rest exactly once");
                }
            }
        }
        // And the mirrored/single-replica position is just idx + 1.
        let single = ReplicaShard { rank: 0, replicas: 1, mirror: true };
        assert_eq!(next_global_batch(&single, 3), 4);
    }

    #[test]
    fn task_metric_accessors() {
        let b = TaskMetric::Bleu(31.5);
        assert_eq!(b.kind(), "bleu");
        assert_eq!(b.value(), 31.5);
        let a = TaskMetric::Accuracy(0.75);
        assert_eq!(a.kind(), "accuracy");
        assert_eq!(a.value(), 0.75);
    }

    #[test]
    fn run_report_metric_helpers_and_json() {
        let mk = |metric| RunReport {
            steps: 4,
            final_val_loss: 1.0,
            best_val_loss: 0.9,
            final_eval_acc: 0.5,
            metric,
            diverged: false,
            trace: vec![(PrecisionConfig::FP32, 4)],
            loss_curve: vec![(1, 2.0)],
            val_curve: vec![(4, 1.0)],
            schedule_desc: "static fp32".into(),
            wall_s: 2.0,
            stash: None,
            comms: None,
        };
        let r = mk(Some(TaskMetric::Bleu(20.0)));
        assert_eq!(r.bleu(), Some(20.0));
        assert_eq!(r.accuracy(), None);
        assert_eq!(r.steps_per_s(), 2.0);
        let s = r.to_json().to_string_pretty();
        assert!(s.contains("\"kind\""), "{s}");
        assert!(s.contains("bleu"), "{s}");
        let r = mk(Some(TaskMetric::Accuracy(0.8)));
        assert_eq!(r.accuracy(), Some(0.8));
        assert_eq!(r.bleu(), None);
        let r = mk(None);
        assert!(r.to_json().to_string_pretty().contains("null"));
        // fp32-only traces stay unscored, like the paper's "-" rows.
        assert!(r.cost_on(&TransformerWorkload::iwslt_6layer()).is_none());
    }
}
