//! Seq2seq training adapter: [`Trainer`] maps the CLI-level
//! [`TrainerConfig`] onto the generic [`Session`] engine with an
//! [`NmtTask`] (synthetic translation corpus, BLEU headline metric).
//! The loop itself — prefetch, step dispatch, trace, divergence,
//! validation, checkpointing — lives in [`super::session`].

use std::path::PathBuf;

use crate::data::{Batcher, TranslationConfig, TranslationTask, Variant};
use crate::model::ModelState;
use crate::runtime::ArtifactManifest;
use crate::schedule::{FormatSpec, Schedule};
use crate::stash::StashBudget;
use crate::Result;

use super::lr::LrSchedule;
use super::session::{NmtTask, RunReport, Session, SessionConfig};

/// Trainer configuration (CLI-level knobs).
#[derive(Clone, Debug)]
pub struct TrainerConfig {
    pub artifacts: PathBuf,
    pub seed: u64,
    pub epochs: usize,
    pub batches_per_epoch: usize,
    pub lr: LrSchedule,
    pub variant: Variant,
    /// Validation batches per pass (fixed set, disjoint stream).
    pub val_batches: usize,
    /// Also validate every N steps (0 = per-epoch only).
    pub val_every_steps: usize,
    /// Test batches for BLEU after training (0 = skip decode).
    pub bleu_batches: usize,
    pub checkpoint: Option<PathBuf>,
    /// Save `checkpoint` every N steps mid-run (0 = final save only;
    /// crash-salvage semantics — see
    /// [`SessionConfig::checkpoint_every_steps`]).
    pub checkpoint_every_steps: usize,
    pub init_checkpoint: Option<PathBuf>,
    /// Bounded prefetch depth for the batch generator thread (≥ 1).
    pub prefetch: usize,
    /// Hold the trainer state packed in this format between steps (see
    /// [`SessionConfig::stash_format`]); `None` = dense f32.
    pub stash_format: Option<FormatSpec>,
    /// Resident byte budget for the packed stash (see
    /// [`SessionConfig::stash_budget`]).
    pub stash_budget: StashBudget,
    /// Spill-segment / index directory (see
    /// [`SessionConfig::stash_dir`]); `None` = per-run temp dir.
    pub stash_dir: Option<PathBuf>,
}

impl TrainerConfig {
    pub fn quick(artifacts: PathBuf) -> Self {
        TrainerConfig {
            artifacts,
            seed: 0,
            epochs: 2,
            batches_per_epoch: 20,
            lr: LrSchedule::InverseSqrt { peak_lr: 3e-3, warmup_steps: 40 },
            variant: Variant::Iwslt,
            val_batches: 4,
            val_every_steps: 0,
            bleu_batches: 4,
            checkpoint: None,
            checkpoint_every_steps: 0,
            init_checkpoint: None,
            prefetch: 4,
            stash_format: None,
            stash_budget: StashBudget::Unlimited,
            stash_dir: None,
        }
    }

    fn session_config(&self) -> SessionConfig {
        SessionConfig {
            artifacts: self.artifacts.clone(),
            seed: self.seed,
            epochs: self.epochs,
            batches_per_epoch: self.batches_per_epoch,
            lr: self.lr.clone(),
            val_batches: self.val_batches,
            val_every_steps: self.val_every_steps,
            checkpoint: self.checkpoint.clone(),
            init_checkpoint: self.init_checkpoint.clone(),
            checkpoint_every_steps: self.checkpoint_every_steps,
            prefetch: self.prefetch,
            stash_format: self.stash_format,
            stash_budget: self.stash_budget,
            stash_dir: self.stash_dir.clone(),
        }
    }
}

/// The seq2seq trainer: a [`Session`] over [`NmtTask`].
pub struct Trainer {
    pub cfg: TrainerConfig,
    session: Session<NmtTask>,
}

impl Trainer {
    pub fn new(cfg: TrainerConfig) -> Result<Self> {
        let man = ArtifactManifest::load(&cfg.artifacts)?;
        let (b, s, t, v) = (
            man.nmt.cfg("batch")?,
            man.nmt.cfg("src_len")?,
            man.nmt.cfg("tgt_len")?,
            man.nmt.cfg("vocab")?,
        );
        let task = NmtTask {
            task: TranslationTask::new(TranslationConfig {
                vocab: v as i32,
                src_len: s,
                tgt_len: t,
                variant: cfg.variant,
                seed: cfg.seed,
            }),
            batcher: Batcher::new(b, s, t),
            seed: cfg.seed,
            bleu_batches: cfg.bleu_batches,
        };
        let session = Session::new(cfg.session_config(), task, man)?;
        Ok(Trainer { cfg, session })
    }

    pub fn manifest(&self) -> &ArtifactManifest {
        self.session.manifest()
    }

    pub fn state(&self) -> &ModelState {
        self.session.state()
    }

    /// The underlying engine (e.g. for [`Session::evaluate`]).
    pub fn session(&mut self) -> &mut Session<NmtTask> {
        &mut self.session
    }

    /// Run the full training loop under `schedule`.
    pub fn run(&mut self, schedule: &mut dyn Schedule) -> Result<RunReport> {
        self.session.run(schedule)
    }
}
