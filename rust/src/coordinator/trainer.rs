//! The seq2seq training coordinator: the L3 loop that drives the AOT
//! train/eval/decode artifacts with a precision schedule.
//!
//! Responsibilities per run:
//! * corpus synthesis + prefetch (generator thread + bounded channel);
//! * step execution through PJRT, tracking the training loss;
//! * per-epoch validation (fixed batches from the disjoint `valid`
//!   stream) feeding the schedule's plateau detector;
//! * cost accounting: a `(PrecisionConfig, steps)` trace that the cost
//!   model turns into the paper's time-weighted DSQ rows;
//! * divergence detection (Table 5's "Failed" entries);
//! * BLEU via greedy decode against the synthetic references;
//! * checkpointing.

use std::path::PathBuf;
use std::sync::mpsc;
use std::time::Instant;

use crate::costmodel::{self, TransformerWorkload};
use crate::data::{Batch, Batcher, TranslationConfig, TranslationTask, Variant};
use crate::metrics::{bleu, LossTracker};
use crate::model::{checkpoint, ModelState};
use crate::runtime::{ArtifactManifest, HostTensor, Runtime};
use crate::schedule::{FormatSpec, PrecisionConfig, Schedule};
use crate::util::json::Json;
use crate::{Error, Result};

use super::lr::LrSchedule;

/// Trainer configuration (CLI-level knobs).
#[derive(Clone, Debug)]
pub struct TrainerConfig {
    pub artifacts: PathBuf,
    pub seed: u64,
    pub epochs: usize,
    pub batches_per_epoch: usize,
    pub lr: LrSchedule,
    pub variant: Variant,
    /// Validation batches per epoch (fixed set, disjoint stream).
    pub val_batches: usize,
    /// Test batches for BLEU after training (0 = skip decode).
    pub bleu_batches: usize,
    pub checkpoint: Option<PathBuf>,
    pub init_checkpoint: Option<PathBuf>,
    /// Bounded prefetch depth for the batch generator thread.
    pub prefetch: usize,
    /// Hold the trainer state (params + Adam moments) physically packed
    /// in this format between steps, decoding only at the PJRT boundary
    /// — the coordinator-side stash. Quantizes the resident state every
    /// step (Direct-Quantized-Training style), so it changes numerics;
    /// `None` (the default) keeps dense f32 state. Checkpoints written
    /// from a packed state use the packed v2 format and shrink
    /// accordingly.
    pub stash_format: Option<FormatSpec>,
}

impl TrainerConfig {
    pub fn quick(artifacts: PathBuf) -> Self {
        TrainerConfig {
            artifacts,
            seed: 0,
            epochs: 2,
            batches_per_epoch: 20,
            lr: LrSchedule::InverseSqrt { peak_lr: 3e-3, warmup_steps: 40 },
            variant: Variant::Iwslt,
            val_batches: 4,
            bleu_batches: 4,
            checkpoint: None,
            init_checkpoint: None,
            prefetch: 4,
            stash_format: None,
        }
    }
}

/// Result of a training run.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub steps: u64,
    pub final_val_loss: f64,
    pub best_val_loss: f64,
    pub final_token_acc: f64,
    pub bleu: Option<f64>,
    pub diverged: bool,
    pub trace: Vec<(PrecisionConfig, usize)>,
    pub loss_curve: Vec<(u64, f64)>,
    pub val_curve: Vec<(u64, f64)>,
    pub schedule_desc: String,
    pub wall_s: f64,
}

impl TrainReport {
    pub fn steps_per_s(&self) -> f64 {
        self.steps as f64 / self.wall_s.max(1e-9)
    }

    /// Relative hardware cost of this run's schedule trace on a
    /// paper-scale workload (the DSQ table columns). `None` when the
    /// trace is unscored — an fp32-only run (the paper leaves fp32 rows
    /// as "-") or a run that took zero steps.
    pub fn cost_on(&self, w: &TransformerWorkload) -> Option<(f64, f64)> {
        let row = costmodel::tables::dsq_trace_row(w, &self.trace);
        row.arith_rel.zip(row.dram_rel)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("steps", Json::num(self.steps as f64)),
            ("final_val_loss", Json::num(self.final_val_loss)),
            ("best_val_loss", Json::num(self.best_val_loss)),
            ("final_token_acc", Json::num(self.final_token_acc)),
            (
                "bleu",
                self.bleu.map_or(Json::Null, Json::num),
            ),
            ("diverged", Json::Bool(self.diverged)),
            ("schedule", Json::str(&self.schedule_desc)),
            ("wall_s", Json::num(self.wall_s)),
            (
                "trace",
                Json::arr(self.trace.iter().map(|(p, n)| {
                    Json::obj(vec![
                        ("precision", Json::str(&p.notation())),
                        ("formats", Json::str(&p.spec_string())),
                        ("steps", Json::num(*n as f64)),
                    ])
                })),
            ),
            (
                "loss_curve",
                Json::arr(
                    self.loss_curve
                        .iter()
                        .map(|&(s, l)| Json::arr([Json::num(s as f64), Json::num(l)])),
                ),
            ),
            (
                "val_curve",
                Json::arr(
                    self.val_curve
                        .iter()
                        .map(|&(s, l)| Json::arr([Json::num(s as f64), Json::num(l)])),
                ),
            ),
        ])
    }
}

/// The seq2seq trainer.
pub struct Trainer {
    pub cfg: TrainerConfig,
    man: ArtifactManifest,
    task: TranslationTask,
    batcher: Batcher,
    state: ModelState,
}

impl Trainer {
    pub fn new(cfg: TrainerConfig) -> Result<Self> {
        let man = ArtifactManifest::load(&cfg.artifacts)?;
        let (b, s, t, v) = (
            man.nmt.cfg("batch")?,
            man.nmt.cfg("src_len")?,
            man.nmt.cfg("tgt_len")?,
            man.nmt.cfg("vocab")?,
        );
        let task = TranslationTask::new(TranslationConfig {
            vocab: v as i32,
            src_len: s,
            tgt_len: t,
            variant: cfg.variant,
            seed: cfg.seed,
        });
        let rt = Runtime::global();
        let mut state = match &cfg.init_checkpoint {
            Some(path) => checkpoint::load_checkpoint(path, &man.nmt)?,
            None => ModelState::init(rt, &man, "nmt", cfg.seed as i32)?,
        };
        if let Some(spec) = &cfg.stash_format {
            state.pack_state(spec)?;
        }
        Ok(Trainer { batcher: Batcher::new(b, s, t), cfg, man, task, state })
    }

    pub fn manifest(&self) -> &ArtifactManifest {
        &self.man
    }

    pub fn state(&self) -> &ModelState {
        &self.state
    }

    fn step_inputs(&self, batch: &Batch, qcfg: [f32; 8], lr: f32) -> Vec<HostTensor> {
        let (b, s, t) = (self.batcher.batch, self.batcher.src_len, self.batcher.tgt_len);
        let mut inputs =
            Vec::with_capacity(3 * self.state.params.len() + 6);
        inputs.extend(self.state.params.iter().cloned());
        inputs.extend(self.state.m.iter().cloned());
        inputs.extend(self.state.v.iter().cloned());
        inputs.push(HostTensor::scalar_f32((self.state.step + 1) as f32));
        inputs.push(HostTensor::i32(vec![b, s], batch.src.clone()));
        inputs.push(HostTensor::i32(vec![b, t], batch.tgt_in.clone()));
        inputs.push(HostTensor::i32(vec![b, t], batch.tgt_out.clone()));
        inputs.push(HostTensor::f32(vec![8], qcfg.to_vec()));
        inputs.push(HostTensor::scalar_f32(lr));
        inputs
    }

    /// Fixed validation batches (same every epoch).
    fn val_batches(&self) -> Vec<Batch> {
        let mut rng = self.task.split_rng("valid");
        (0..self.cfg.val_batches)
            .map(|_| {
                let pairs: Vec<_> =
                    (0..self.batcher.batch).map(|_| self.task.sample_pair(&mut rng)).collect();
                self.batcher.assemble(&pairs)
            })
            .collect()
    }

    /// Evaluate mean per-token loss + token accuracy on batches.
    pub fn evaluate(&self, batches: &[Batch]) -> Result<(f64, f64)> {
        let rt = Runtime::global();
        let exe = rt.load(&self.man.model_path("nmt", "eval")?)?;
        let (b, s, t) = (self.batcher.batch, self.batcher.src_len, self.batcher.tgt_len);
        let (mut loss_sum, mut ncorrect, mut ntok) = (0f64, 0f64, 0f64);
        for batch in batches {
            let mut inputs = self.state.params.clone();
            inputs.push(HostTensor::i32(vec![b, s], batch.src.clone()));
            inputs.push(HostTensor::i32(vec![b, t], batch.tgt_in.clone()));
            inputs.push(HostTensor::i32(vec![b, t], batch.tgt_out.clone()));
            let outs = exe.run(&inputs)?;
            loss_sum += outs[0].item_f32()? as f64;
            ncorrect += outs[1].item_f32()? as f64;
            ntok += outs[2].item_f32()? as f64;
        }
        Ok((loss_sum / ntok.max(1.0), ncorrect / ntok.max(1.0)))
    }

    /// Greedy-decode BLEU on the test stream.
    pub fn bleu(&self, nbatches: usize) -> Result<bleu::BleuScore> {
        let rt = Runtime::global();
        let exe = rt.load(&self.man.model_path("nmt", "decode")?)?;
        let (b, s, t) = (self.batcher.batch, self.batcher.src_len, self.batcher.tgt_len);
        let mut rng = self.task.split_rng("test");
        let mut pairs = Vec::new();
        for _ in 0..nbatches {
            let batch_pairs: Vec<_> =
                (0..b).map(|_| self.task.sample_pair(&mut rng)).collect();
            let batch = self.batcher.assemble(&batch_pairs);
            let mut inputs = self.state.params.clone();
            inputs.push(HostTensor::i32(vec![b, s], batch.src.clone()));
            let outs = exe.run(&inputs)?;
            let toks = outs[0].as_i32()?;
            for (i, p) in batch_pairs.iter().enumerate() {
                let hyp = bleu::sentence_tokens(&toks[i * t..(i + 1) * t]);
                let reference = bleu::sentence_tokens(&p.tgt);
                pairs.push((hyp, reference));
            }
        }
        Ok(bleu::corpus_bleu(&pairs))
    }

    /// Run the full training loop under `schedule`.
    pub fn run(&mut self, schedule: &mut dyn Schedule) -> Result<TrainReport> {
        let rt = Runtime::global();
        let start = Instant::now();
        let mut tracker = LossTracker::new();
        let mut trace: Vec<(PrecisionConfig, usize)> = Vec::new();
        let mut val_curve = Vec::new();
        let val_set = self.val_batches();
        let mut diverged = false;

        crate::info!(
            "training: {} params, {} epochs x {} batches, schedule {}",
            self.state.numel(),
            self.cfg.epochs,
            self.cfg.batches_per_epoch,
            schedule.describe()
        );

        'epochs: for epoch in 0..self.cfg.epochs {
            // Batch generator thread (bounded prefetch).
            let task = self.task.clone();
            let batcher = self.batcher.clone();
            let nbatches = self.cfg.batches_per_epoch;
            let epoch_seed = self.cfg.seed ^ ((epoch as u64 + 1) << 32);
            let (tx, rx) = mpsc::sync_channel::<Batch>(self.cfg.prefetch);
            let producer = std::thread::spawn(move || {
                let mut rng = crate::util::rng::Pcg32::new(epoch_seed);
                let mut pool: Vec<_> =
                    (0..nbatches * batcher.batch).map(|_| task.sample_pair(&mut rng)).collect();
                for batch in batcher.epoch(&mut pool, &mut rng) {
                    if tx.send(batch).is_err() {
                        return; // consumer gone (divergence abort)
                    }
                }
            });

            for batch in rx.iter() {
                let pc = schedule.current();
                let exe =
                    rt.load(&self.man.model_path("nmt", super::train_artifact_kind(&pc))?)?;
                let lr = self.cfg.lr.at(self.state.step + 1) as f32;
                let inputs = self.step_inputs(&batch, pc.as_qcfg(), lr);
                let outs = exe.run(&inputs)?;
                let loss = self.state.absorb_step_output(outs)? as f64;
                // Re-stash: step outputs arrive dense from the artifact;
                // the resident copy goes back to packed storage.
                if let Some(spec) = &self.cfg.stash_format {
                    self.state.pack_state(spec)?;
                }
                tracker.record(self.state.step, loss);
                match trace.last_mut() {
                    Some((last, n)) if *last == pc => *n += 1,
                    _ => trace.push((pc, 1)),
                }
                if tracker.diverged() {
                    diverged = true;
                    crate::warn!("training diverged at step {}", self.state.step);
                    drop(rx);
                    break 'epochs;
                }
            }
            producer.join().map_err(|_| Error::Config("batch producer panicked".into()))?;

            let (val_loss, val_acc) = self.evaluate(&val_set)?;
            val_curve.push((self.state.step, val_loss));
            schedule.observe_validation(val_loss);
            crate::info!(
                "epoch {epoch}: train {:.4} | val {val_loss:.4} acc {:.1}% | {}",
                tracker.window_mean(self.cfg.batches_per_epoch).unwrap_or(f64::NAN),
                val_acc * 100.0,
                schedule.describe()
            );
        }

        let (final_val_loss, final_token_acc) = self.evaluate(&val_set)?;
        let bleu_score = if self.cfg.bleu_batches > 0 && !diverged {
            Some(self.bleu(self.cfg.bleu_batches)?.bleu)
        } else {
            None
        };
        if let Some(path) = &self.cfg.checkpoint {
            checkpoint::save_checkpoint(path, &self.state, &self.man.nmt)?;
            crate::info!("checkpoint saved to {path:?}");
        }
        Ok(TrainReport {
            steps: self.state.step,
            final_val_loss,
            best_val_loss: val_curve
                .iter()
                .map(|&(_, l)| l)
                .fold(final_val_loss, f64::min),
            final_token_acc,
            bleu: bleu_score,
            diverged,
            trace,
            loss_curve: tracker.history().to_vec(),
            val_curve,
            schedule_desc: schedule.describe(),
            wall_s: start.elapsed().as_secs_f64(),
        })
    }
}
