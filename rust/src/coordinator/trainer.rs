//! Seq2seq training adapter: [`Trainer`] maps the CLI-level
//! [`TrainerConfig`] onto the generic [`Session`] engine with an
//! [`NmtTask`] (synthetic translation corpus, BLEU headline metric).
//! The loop itself — prefetch, step dispatch, trace, divergence,
//! validation, checkpointing — lives in [`super::session`].

use std::path::PathBuf;

use crate::data::{Batcher, TranslationConfig, TranslationTask, Variant};
use crate::model::ModelState;
use crate::runtime::ArtifactManifest;
use crate::schedule::{FormatSpec, Schedule};
use crate::stash::{run_replicas, ReplicaShard, StashBudget, TransportSpec};
use crate::{Error, Result};

use super::lr::LrSchedule;
use super::session::{NmtTask, RunReport, Session, SessionConfig};

/// Trainer configuration (CLI-level knobs).
#[derive(Clone, Debug)]
pub struct TrainerConfig {
    pub artifacts: PathBuf,
    pub seed: u64,
    pub epochs: usize,
    pub batches_per_epoch: usize,
    pub lr: LrSchedule,
    pub variant: Variant,
    /// Validation batches per pass (fixed set, disjoint stream).
    pub val_batches: usize,
    /// Also validate every N steps (0 = per-epoch only).
    pub val_every_steps: usize,
    /// Test batches for BLEU after training (0 = skip decode).
    pub bleu_batches: usize,
    pub checkpoint: Option<PathBuf>,
    /// Save `checkpoint` every N steps mid-run (0 = final save only;
    /// crash-salvage semantics — see
    /// [`SessionConfig::checkpoint_every_steps`]).
    pub checkpoint_every_steps: usize,
    pub init_checkpoint: Option<PathBuf>,
    /// Bounded prefetch depth for the batch generator thread (≥ 1).
    pub prefetch: usize,
    /// Hold the trainer state packed in this format between steps (see
    /// [`SessionConfig::stash_format`]); `None` = dense f32.
    pub stash_format: Option<FormatSpec>,
    /// Resident byte budget for the packed stash (see
    /// [`SessionConfig::stash_budget`]).
    pub stash_budget: StashBudget,
    /// Spill-segment / index directory (see
    /// [`SessionConfig::stash_dir`]); `None` = per-run temp dir.
    pub stash_dir: Option<PathBuf>,
    /// In-process data-parallel replica count (`--replicas`; 1 = the
    /// single-replica path, bit-for-bit today's behavior). Replicated
    /// runs go through [`Trainer::run_replicated`].
    pub replicas: usize,
    /// Packed format the replicas exchange state in (`--comms`); only
    /// meaningful when `replicas > 1`. `fp32` reduces in full precision
    /// (bit-transparent); SR formats draw rank-salted rounding streams.
    pub comms: FormatSpec,
    /// Mirror the batch stream across replicas instead of round-robin
    /// sharding it — the transparency configuration (N replicas consume
    /// identical data, so under `fp32` comms the run is bit-identical
    /// to single-replica). Round-robin (the default) is the N×-batch
    /// data-parallel emulation.
    pub mirror_replicas: bool,
    /// How replicas exchange state (`--transport`): `mem` (default)
    /// runs them as threads over the in-memory ring via
    /// [`Trainer::run_replicated`]; `socket:<addr>` runs them as OS
    /// processes — the CLI's `worker` orchestration owns that path
    /// and builds each rank with [`Trainer::replica`].
    pub transport: TransportSpec,
    /// Telemetry directory (`--trace`): each rank writes
    /// `trace.rank<N>.jsonl` + `run.rank<N>.json` here (see
    /// [`crate::obs`]). `None` = tracing disabled.
    pub trace_dir: Option<PathBuf>,
}

impl TrainerConfig {
    pub fn quick(artifacts: PathBuf) -> Self {
        TrainerConfig {
            artifacts,
            seed: 0,
            epochs: 2,
            batches_per_epoch: 20,
            lr: LrSchedule::InverseSqrt { peak_lr: 3e-3, warmup_steps: 40 },
            variant: Variant::Iwslt,
            val_batches: 4,
            val_every_steps: 0,
            bleu_batches: 4,
            checkpoint: None,
            checkpoint_every_steps: 0,
            init_checkpoint: None,
            prefetch: 4,
            stash_format: None,
            stash_budget: StashBudget::Unlimited,
            stash_dir: None,
            replicas: 1,
            comms: FormatSpec::Fp32,
            mirror_replicas: false,
            transport: TransportSpec::Mem,
            trace_dir: None,
        }
    }

    fn session_config(&self) -> SessionConfig {
        SessionConfig {
            artifacts: self.artifacts.clone(),
            seed: self.seed,
            epochs: self.epochs,
            batches_per_epoch: self.batches_per_epoch,
            lr: self.lr.clone(),
            val_batches: self.val_batches,
            val_every_steps: self.val_every_steps,
            checkpoint: self.checkpoint.clone(),
            init_checkpoint: self.init_checkpoint.clone(),
            checkpoint_every_steps: self.checkpoint_every_steps,
            prefetch: self.prefetch,
            stash_format: self.stash_format,
            stash_budget: self.stash_budget,
            stash_dir: self.stash_dir.clone(),
            shard: None,
            trace_dir: self.trace_dir.clone(),
        }
    }

    /// Per-rank view of a replicated config: rank 0 keeps the headline
    /// duties (checkpointing, BLEU decode); peers only train. Spill
    /// directories get a per-rank suffix so replicas never share index
    /// files (the trace dir is shared — obs files are rank-tagged).
    fn for_rank(&self, rank: usize) -> Self {
        let mut cfg = self.clone();
        if self.replicas > 1 {
            if rank != 0 {
                cfg.checkpoint = None;
                cfg.checkpoint_every_steps = 0;
                cfg.bleu_batches = 0;
            }
            cfg.stash_dir = self.stash_dir.as_ref().map(|d| d.join(format!("rank{rank}")));
        }
        cfg
    }

    fn shard_for(&self, rank: usize) -> Option<ReplicaShard> {
        (self.replicas > 1).then_some(ReplicaShard {
            rank,
            replicas: self.replicas,
            mirror: self.mirror_replicas,
        })
    }
}

/// The seq2seq trainer: a [`Session`] over [`NmtTask`].
pub struct Trainer {
    pub cfg: TrainerConfig,
    session: Session<NmtTask>,
}

impl Trainer {
    pub fn new(cfg: TrainerConfig) -> Result<Self> {
        Self::with_shard(cfg, None)
    }

    /// Build rank `rank`'s view of a replicated run — the per-rank
    /// config plus its batch shard — without deciding how the ranks
    /// are hosted. The thread path ([`Trainer::run_replicated`]) and
    /// the multi-process `worker` orchestration both build replicas
    /// through here, so the two transports train identical sessions.
    pub fn replica(cfg: &TrainerConfig, rank: usize) -> Result<Self> {
        Self::with_shard(cfg.for_rank(rank), cfg.shard_for(rank))
    }

    fn with_shard(cfg: TrainerConfig, shard: Option<ReplicaShard>) -> Result<Self> {
        let man = ArtifactManifest::load(&cfg.artifacts)?;
        let (b, s, t, v) = (
            man.nmt.cfg("batch")?,
            man.nmt.cfg("src_len")?,
            man.nmt.cfg("tgt_len")?,
            man.nmt.cfg("vocab")?,
        );
        let task = NmtTask {
            task: TranslationTask::new(TranslationConfig {
                vocab: v as i32,
                src_len: s,
                tgt_len: t,
                variant: cfg.variant,
                seed: cfg.seed,
            }),
            batcher: Batcher::new(b, s, t),
            seed: cfg.seed,
            bleu_batches: cfg.bleu_batches,
        };
        let mut scfg = cfg.session_config();
        scfg.shard = shard;
        let session = Session::new(scfg, task, man)?;
        Ok(Trainer { cfg, session })
    }

    /// Run `cfg.replicas` in-process data-parallel replicas, exchanging
    /// state in `cfg.comms` packed records after every step (see
    /// [`crate::stash::exchange`]). `replicas <= 1` is exactly
    /// [`Trainer::new`] + [`Trainer::run`] — today's path, bit-for-bit.
    /// Each replica gets its own schedule from `make_schedule`; rank 0's
    /// report (post-reduce state is identical on every rank) is
    /// returned, with [`RunReport::comms`] carrying the metered
    /// exchange traffic.
    pub fn run_replicated(
        cfg: TrainerConfig,
        make_schedule: impl Fn() -> Result<Box<dyn Schedule>> + Sync,
    ) -> Result<RunReport> {
        if cfg.replicas <= 1 {
            let mut t = Trainer::new(cfg)?;
            let mut schedule = make_schedule()?;
            return t.run(schedule.as_mut());
        }
        if cfg.transport.is_socket() {
            // Process orchestration (hub + spawned `dsq worker`s) is
            // the CLI's job — reaching here means a caller skipped it.
            return Err(Error::Config(format!(
                "transport {} needs the multi-process worker orchestration \
                 (run through the dsq CLI); run_replicated only hosts --transport mem",
                cfg.transport
            )));
        }
        run_replicas(cfg.replicas, cfg.comms, |rank, ex| {
            let mut t = Trainer::replica(&cfg, rank)?;
            t.session().set_exchange(ex)?;
            let mut schedule = make_schedule()?;
            t.run(schedule.as_mut())
        })
    }

    pub fn manifest(&self) -> &ArtifactManifest {
        self.session.manifest()
    }

    pub fn state(&self) -> &ModelState {
        self.session.state()
    }

    /// The underlying engine (e.g. for [`Session::evaluate`]).
    pub fn session(&mut self) -> &mut Session<NmtTask> {
        &mut self.session
    }

    /// Run the full training loop under `schedule`.
    pub fn run(&mut self, schedule: &mut dyn Schedule) -> Result<RunReport> {
        self.session.run(schedule)
    }
}
