//! Table 4 (Appendix B): stash-precision sweep — how aggressive can
//! `[q0,q1,q2,16]` get before BLEU collapses?
//!
//! Paper reference (IWSLT14 DE-EN, Stashing BFP, fp32 = 35.22):
//!
//! | precision      | BLEU (Δ)        |
//! |----------------|-----------------|
//! | [2,2,2,16]     | 17.45 (−17.77)  |
//! | [4,2,2,16]     | 33.51 (−1.71)   |
//! | [4,4,4,16]     | 34.47 (−0.75)   |
//! | [8,4,4,16]     | 34.47 (−0.75)   |
//! | [8,8,8,16]     | 34.65 (−0.57)   |
//! | [16,4,4,16]    | 34.78 (−0.44)   |
//! | [16,8,8,16]    | 34.47 (−0.75)   |
//!
//! The reproduction target is the *shape*: [2,2,2,16] clearly behind,
//! everything from [4,4,4,16] up clustered near fp32 — which is exactly
//! the observation that justifies DSQ's ladder.

use crate::coordinator::{Trainer, TrainerConfig};
use crate::data::Variant;
use crate::schedule::{PrecisionConfig, Schedule, StaticSchedule};
use crate::util::json::Json;
use crate::Result;

use super::ExperimentOpts;

pub const SWEEP: &[(&str, f64)] = &[
    ("[2,2,2,16]", -17.77),
    ("[4,2,2,16]", -1.71),
    ("[4,4,4,16]", -0.75),
    ("[8,4,4,16]", -0.75),
    ("[8,8,8,16]", -0.57),
    ("[16,4,4,16]", -0.44),
    ("[16,8,8,16]", -0.75),
];

pub fn run(opts: &ExperimentOpts) -> Result<()> {
    let mut md = String::from(
        "# Table 4: stash precision sweep (Stashing BFP, synthetic IWSLT-style task)\n\n\
         The measured columns are codec-observed bytes, not modeled\n\
         numbers: one stash round trip of the final model state at the\n\
         row's q1 format (one synthetic step through the stash store),\n\
         and the wire bytes one rank sends + receives in a two-replica\n\
         exchange round of that state at the same format.\n\n\
         | precision | BLEU | Δ vs fp32 | paper Δ | stash state (measured) | comms/round (measured) |\n\
         |---|---|---|---|---|---|\n",
    );
    let mut json_rows = Vec::new();

    // fp32 baseline first.
    let (fp32_bleu, fp32_measured, fp32_comms) = if opts.train {
        train_one(opts, PrecisionConfig::FP32)?
    } else {
        (None, None, None)
    };
    md.push_str(&format!(
        "| fp32 [32,32,32,32] | {} | - | - | {} | {} |\n",
        fp32_bleu.map_or("-".into(), |b| format!("{b:.2}")),
        fp32_measured.map_or("-".into(), crate::stash::fmt_bytes),
        fp32_comms.map_or("-".into(), crate::stash::fmt_bytes),
    ));

    for (setup, paper_delta) in SWEEP {
        let p = PrecisionConfig::parse(&format!("bfp:{setup}"))?;
        let (bleu, delta, measured, comms) = if opts.train {
            let (bleu, measured, comms) = train_one(opts, p)?;
            let delta = match (bleu, fp32_bleu) {
                (Some(b), Some(f)) => Some(b - f),
                _ => None,
            };
            (bleu, delta, measured, comms)
        } else {
            (None, None, None, None)
        };
        md.push_str(&format!(
            "| {} | {} | {} | {paper_delta:+.2} | {} | {} |\n",
            setup,
            bleu.map_or("-".into(), |b| format!("{b:.2}")),
            delta.map_or("-".into(), |d| format!("{d:+.2}")),
            measured.map_or("-".into(), crate::stash::fmt_bytes),
            comms.map_or("-".into(), crate::stash::fmt_bytes),
        ));
        json_rows.push(Json::obj(vec![
            ("precision", Json::str(setup)),
            ("bleu", bleu.map_or(Json::Null, Json::num)),
            ("delta", delta.map_or(Json::Null, Json::num)),
            ("paper_delta", Json::num(*paper_delta)),
            (
                "measured_stash_bytes",
                measured.map_or(Json::Null, |b| Json::num(b as f64)),
            ),
            (
                "measured_comms_bytes",
                comms.map_or(Json::Null, |b| Json::num(b as f64)),
            ),
        ]));
    }
    println!("{md}");
    super::write_report(&opts.out, "table4", &md, &Json::arr(json_rows))
}

/// One sweep row: BLEU from the run, plus two pure measurements on the
/// final state (the run's numerics are untouched) — the stash bytes of
/// one round trip through the stash store at the row's q1 format, and
/// the wire bytes one rank moves (tx + rx) in a two-replica exchange
/// round at that same format.
fn train_one(
    opts: &ExperimentOpts,
    p: PrecisionConfig,
) -> Result<(Option<f64>, Option<u64>, Option<u64>)> {
    let cfg = TrainerConfig {
        artifacts: opts.artifacts.clone(),
        seed: 0,
        epochs: opts.train_epochs,
        batches_per_epoch: opts.batches_per_epoch,
        variant: Variant::Iwslt,
        ..TrainerConfig::quick(opts.artifacts.clone())
    };
    let mut schedule: Box<dyn Schedule> = Box::new(StaticSchedule(p));
    let mut trainer = Trainer::new(cfg)?;
    let report = trainer.run(schedule.as_mut())?;
    let traffic = crate::stash::measure_state_traffic(trainer.state(), &p.stash())?;
    let comms = crate::stash::measure_comms_round(trainer.state(), p.stash())?;
    Ok((
        report.bleu(),
        Some(traffic.meter.stash_write_bytes),
        Some(comms.meter.comms_tx_bytes + comms.meter.comms_rx_bytes),
    ))
}
