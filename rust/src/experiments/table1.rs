//! Table 1: the paper's main result — accuracy/BLEU + hardware cost for
//! every method, on IWSLT-style translation (train-from-scratch) and
//! GLUE-style classification (fine-tuning).
//!
//! Paper reference (IWSLT17 DE-EN, 6-layer transformer):
//!
//! | method            | precision       | BLEU(Δ)        | arith | dram |
//! |-------------------|-----------------|----------------|-------|------|
//! | Floating-point    | [32,32,32,32]   | 35.22          |  –    |  –   |
//! | Fixed-point       | [32,32,32,32]   | (anchor)       | 1.00  | 1.00 |
//! | Fixed-point       | [16,16,16,16]   | 32.59 (−2.63)  | 0.25  | 0.50 |
//! | Block FP          | [32,32,32,32]   | 34.56 (−0.66)  | 0.56  | 1.13 |
//! | Block FP          | [16,16,16,16]   | 34.30 (−0.92)  | 0.18  | 0.63 |
//! | Stashing (Fixed)  | [16,4,4,16]     | 25.50 (−9.72)  | 0.13  | 0.31 |
//! | Stashing (BFP)    | [16,4,4,16]     | 34.78 (−0.44)  | 0.10  | 0.45 |
//! | DSQ (BFP)         | –               | 34.81 (−0.41)  | 0.012 | 0.20 |
//!
//! Here BLEU comes from real training runs on the synthetic translation
//! task (absolute values differ from IWSLT — it's a different corpus —
//! but the *deltas vs the fp32 run* are the reproduction target: BFP
//! tracks fp32, fixed-point stashing collapses, DSQ matches stashing at
//! a fraction of the cost).

use crate::coordinator::{Finetuner, FinetuneConfig, Trainer, TrainerConfig};
use crate::costmodel::{self, TransformerWorkload};
use crate::data::Variant;
use crate::schedule::{DsqController, FormatSpec, PrecisionConfig, Schedule, StaticSchedule};
use crate::util::json::Json;
use crate::Result;

use super::ExperimentOpts;

/// Method list with paper BLEU deltas for IWSLT (None = anchor rows).
pub const PAPER_IWSLT_DELTAS: &[(&str, &str, f64)] = &[
    ("Fixed-point", "[16,16,16,16]", -2.63),
    ("Block FP", "[32,32,32,32]", -0.66),
    ("Block FP", "[16,16,16,16]", -0.92),
    ("Stashing (Fixed)", "[16,4,4,16]", -9.72),
    ("Stashing (BFP)", "[16,4,4,16]", -0.44),
    ("DSQ (BFP)", "-", -0.41),
];

fn method_rows() -> Vec<(&'static str, Option<PrecisionConfig>)> {
    let mut rows: Vec<(&'static str, Option<PrecisionConfig>)> = vec![
        ("Floating-point", Some(PrecisionConfig::FP32)),
        ("Fixed-point", Some(PrecisionConfig::uniform(FormatSpec::fixed(32)))),
        ("Fixed-point", Some(PrecisionConfig::uniform(FormatSpec::fixed(16)))),
        ("Block FP", Some(PrecisionConfig::uniform(FormatSpec::bfp(32)))),
        ("Block FP", Some(PrecisionConfig::uniform(FormatSpec::bfp(16)))),
        ("Stashing (Fixed)", Some(PrecisionConfig::stashing(FormatSpec::fixed(16)))),
        ("Stashing (BFP)", Some(PrecisionConfig::stashing(FormatSpec::bfp(16)))),
    ];
    rows.push(("DSQ (BFP)", None)); // dynamic controller
    rows
}

fn schedule_for(p: Option<PrecisionConfig>) -> Box<dyn Schedule> {
    match p {
        Some(cfg) => Box::new(StaticSchedule(cfg)),
        None => Box::new(DsqController::paper_default("bfp").expect("built-in ladder")),
    }
}

struct Row {
    method: String,
    precision: String,
    metric: Option<f64>,
    delta: Option<f64>,
    arith: Option<f64>,
    dram: Option<f64>,
    diverged: bool,
}

fn fmt_rows(title: &str, metric_name: &str, rows: &[Row]) -> String {
    let mut s = format!(
        "# {title}\n\n| method | precision | {metric_name} (Δ vs fp32) | arith (↓) | dram (↓) |\n|---|---|---|---|---|\n"
    );
    for r in rows {
        let metric = match (r.metric, r.diverged) {
            (_, true) => "Failed".to_string(),
            (Some(m), _) => format!(
                "{m:.2}{}",
                r.delta.map_or(String::new(), |d| format!(" ({d:+.2})"))
            ),
            (None, _) => "-".to_string(),
        };
        let f = |v: Option<f64>| v.map_or("-".to_string(), |x| format!("{x:.3}x"));
        s.push_str(&format!(
            "| {} | {} | {} | {} | {} |\n",
            r.method,
            r.precision,
            metric,
            f(r.arith),
            f(r.dram)
        ));
    }
    s
}

fn rows_to_json(rows: &[Row]) -> Json {
    Json::arr(rows.iter().map(|r| {
        Json::obj(vec![
            ("method", Json::str(&r.method)),
            ("precision", Json::str(&r.precision)),
            ("metric", r.metric.map_or(Json::Null, Json::num)),
            ("delta", r.delta.map_or(Json::Null, Json::num)),
            ("arith_rel", r.arith.map_or(Json::Null, Json::num)),
            ("dram_rel", r.dram.map_or(Json::Null, Json::num)),
            ("diverged", Json::Bool(r.diverged)),
        ])
    }))
}

/// Table 1, translation half.
pub fn run_iwslt(opts: &ExperimentOpts) -> Result<()> {
    let workload = TransformerWorkload::iwslt_6layer();
    let mut rows = Vec::new();
    let mut fp32_bleu: Option<f64> = None;

    for (method, pcfg) in method_rows() {
        // Cost columns.
        let (arith, dram, precision) = match pcfg {
            Some(p) => {
                let row = costmodel::normalized_row(&workload, method, &p, !p.is_fp32());
                (row.arith_rel, row.dram_rel, p.notation())
            }
            None => (None, None, "-".to_string()), // filled from the trace below
        };

        let is_fp32_row = pcfg.is_some_and(|p| p.is_fp32());
        let (metric, delta, diverged, trace_cost) = if opts.train {
            let cfg = TrainerConfig {
                artifacts: opts.artifacts.clone(),
                seed: 0,
                epochs: opts.train_epochs,
                batches_per_epoch: opts.batches_per_epoch,
                variant: Variant::Iwslt,
                ..TrainerConfig::quick(opts.artifacts.clone())
            };
            let mut schedule = schedule_for(pcfg);
            let mut trainer = Trainer::new(cfg)?;
            let report = trainer.run(schedule.as_mut())?;
            let bleu = report.bleu();
            if is_fp32_row {
                fp32_bleu = bleu;
            }
            let delta = match (bleu, fp32_bleu) {
                (Some(b), Some(f)) if !is_fp32_row => Some(b - f),
                _ => None,
            };
            // cost_on is None for unscored (fp32-only) traces; the DSQ
            // row always quantizes, so this passes its Some through.
            let tc = if pcfg.is_none() { report.cost_on(&workload) } else { None };
            (bleu, delta, report.diverged, tc)
        } else {
            (None, None, false, None)
        };

        let (arith, dram) = match trace_cost {
            Some((a, d)) => (Some(a), Some(d)),
            None if pcfg.is_none() => {
                // --no-train: report the canonical mostly-level-0 trace.
                let lo = PrecisionConfig::of(FormatSpec::bfp(16), [2, 2, 2, 16]);
                let hi = PrecisionConfig::stashing(FormatSpec::bfp(16));
                let r = costmodel::tables::dsq_trace_row(&workload, &[(lo, 96), (hi, 4)]);
                (r.arith_rel, r.dram_rel)
            }
            None => (arith, dram),
        };

        rows.push(Row {
            method: method.to_string(),
            precision,
            metric,
            delta,
            arith,
            dram,
            diverged,
        });
    }

    let md = fmt_rows(
        "Table 1 (IWSLT-style translation, synthetic corpus — see DESIGN.md §4)",
        "BLEU",
        &rows,
    );
    println!("{md}");
    print_headline(&rows);
    super::write_report(&opts.out, "table1-iwslt", &md, &rows_to_json(&rows))
}

fn print_headline(rows: &[Row]) {
    let find = |m: &str, p: &str| {
        rows.iter().find(|r| r.method == m && r.precision == p).and_then(|r| r.arith.zip(r.dram))
    };
    if let (Some((fa, fd)), Some((da, dd))) =
        (find("Fixed-point", "[16,16,16,16]"), find("DSQ (BFP)", "-"))
    {
        println!(
            "headline vs fixed-16: {:.1}x fewer arith ops, {:.2}x less DRAM (paper: 20.95x / 2.55x)\n",
            fa / da,
            fd / dd
        );
    }
}

/// Table 1, GLUE half (MNLI-style 3-way + QNLI-style 2-way fine-tunes).
pub fn run_glue(opts: &ExperimentOpts) -> Result<()> {
    let workload = TransformerWorkload::roberta_base();
    let mut all_md = String::new();
    let mut all_json = Vec::new();

    for (task_name, nclasses) in [("MNLI-style (3-way)", 3usize), ("QNLI-style (2-way)", 2)] {
        let mut rows = Vec::new();
        let mut fp32_acc: Option<f64> = None;
        for (method, pcfg) in method_rows() {
            let (arith, dram, precision) = match pcfg {
                Some(p) => {
                    let row = costmodel::normalized_row(&workload, method, &p, !p.is_fp32());
                    (row.arith_rel, row.dram_rel, p.notation())
                }
                None => {
                    // Fine-tuning is shorter: the controller reaches the
                    // higher rungs sooner (paper MNLI/QNLI DSQ = 0.043x).
                    let lo = PrecisionConfig::of(FormatSpec::bfp(16), [2, 2, 2, 16]);
                    let mid = PrecisionConfig::of(FormatSpec::bfp(16), [8, 4, 4, 16]);
                    let hi = PrecisionConfig::stashing(FormatSpec::bfp(16));
                    let r = costmodel::tables::dsq_trace_row(
                        &workload,
                        &[(lo, 70), (mid, 20), (hi, 10)],
                    );
                    (r.arith_rel, r.dram_rel, "-".to_string())
                }
            };

            let is_fp32_row = pcfg.is_some_and(|p| p.is_fp32());
            let (metric, delta, diverged, trace_cost) = if opts.train {
                let cfg = FinetuneConfig {
                    artifacts: opts.artifacts.clone(),
                    seed: 1,
                    epochs: opts.train_epochs,
                    batches_per_epoch: opts.batches_per_epoch,
                    nclasses,
                    ..FinetuneConfig::quick(opts.artifacts.clone())
                };
                let mut schedule = schedule_for(pcfg);
                let mut tuner = Finetuner::new(cfg)?;
                let report = tuner.run(schedule.as_mut())?;
                let acc = report.accuracy().map(|a| a * 100.0);
                if is_fp32_row {
                    fp32_acc = acc;
                }
                let delta = match (acc, fp32_acc) {
                    (Some(a), Some(f)) if !is_fp32_row => Some(a - f),
                    _ => None,
                };
                let tc = if pcfg.is_none() {
                    let row = costmodel::tables::dsq_trace_row(&workload, &report.trace);
                    row.arith_rel.zip(row.dram_rel)
                } else {
                    None
                };
                (acc, delta, report.diverged, tc)
            } else {
                (None, None, false, None)
            };

            let (arith, dram) = match trace_cost {
                Some((a, d)) => (Some(a), Some(d)),
                None => (arith, dram),
            };
            rows.push(Row {
                method: method.to_string(),
                precision,
                metric,
                delta,
                arith,
                dram,
                diverged,
            });
        }
        let md = fmt_rows(
            &format!("Table 1 ({task_name} fine-tune, synthetic entailment)"),
            "Acc %",
            &rows,
        );
        println!("{md}");
        all_md.push_str(&md);
        all_md.push('\n');
        all_json.push(Json::obj(vec![
            ("task", Json::str(task_name)),
            ("rows", rows_to_json(&rows)),
        ]));
    }
    super::write_report(&opts.out, "table1-glue", &all_md, &Json::arr(all_json))
}
