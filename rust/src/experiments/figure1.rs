//! Figure 1: the Roofline picture — (1) non-quantized, (2) static
//! quantization, (3) DSQ, against the machine balance point.

use crate::costmodel::{self, roofline, Machine, TransformerWorkload};
use crate::schedule::{FormatSpec, PrecisionConfig};
use crate::util::json::Json;
use crate::Result;

use super::ExperimentOpts;

/// The figure's config set (label, precision config).
fn figure_configs() -> Vec<(&'static str, PrecisionConfig)> {
    vec![
        ("(1) fp32 (non-quantized)", PrecisionConfig::FP32),
        ("fixed-point 32", PrecisionConfig::uniform(FormatSpec::fixed(32))),
        ("(2) static quant: BFP16", PrecisionConfig::uniform(FormatSpec::bfp(16))),
        ("static stashing [16,4,4,16]", PrecisionConfig::stashing(FormatSpec::bfp(16))),
        ("(3) DSQ @ [2,2,2,16]", PrecisionConfig::of(FormatSpec::bfp(16), [2, 2, 2, 16])),
    ]
}

/// The three points of the paper's Figure 1 + extras.
pub fn figure_points(w: &TransformerWorkload, m: &Machine) -> Vec<roofline::RooflinePoint> {
    figure_configs()
        .into_iter()
        .map(|(label, p)| roofline::place(m, label, &costmodel::step_cost(w, &p)))
        .collect()
}

/// The measured column: per-config stash traffic of one step — the
/// modeled `stash_bits` (storage_bits) next to the codec-observed bits
/// (`observed_stash_bytes`, the same layout function the stash store
/// meters) — so the figure's DRAM story is a measured quantity, not
/// only a spreadsheet one.
pub fn stash_traffic_rows(w: &TransformerWorkload) -> Vec<(&'static str, f64, f64)> {
    figure_configs()
        .into_iter()
        .map(|(label, p)| {
            let modeled = costmodel::step_cost(w, &p).stash_bits;
            let observed = 8.0 * costmodel::training::observed_stash_bytes(w, &p);
            (label, modeled, observed)
        })
        .collect()
}

/// The comms measured column (PR 7): per wire format, the modeled
/// `container_bits()` of one two-replica exchange round next to the
/// meter-observed wire bits ([`crate::stash::measure_state_comms`]) —
/// the comms-bytes story gets the same modeled-vs-observed treatment
/// the DRAM column has. Returns `(spec string, modeled, observed)`.
pub fn comms_traffic_rows() -> Vec<(String, f64, f64)> {
    let widths = [8u32];
    let mut specs = vec![FormatSpec::Fp32];
    specs.extend(
        crate::quant::registered_specs(&widths).into_iter().filter(|s| *s != FormatSpec::Fp32),
    );
    specs
        .into_iter()
        .filter_map(|spec| {
            let t = crate::stash::measure_state_comms(spec).ok()?;
            Some((spec.to_string(), t.meter.modeled_comms_bits, t.meter.observed_comms_bits()))
        })
        .collect()
}

pub fn print_roofline(m: &Machine, w: &TransformerWorkload) {
    println!(
        "roofline on {} (peak {:.0} TMAC/s, bw {:.0} GB/s, balance I_opt = {:.1} MAC/byte), workload {}",
        m.name,
        m.peak_macs_per_s / 1e12,
        m.dram_bytes_per_s / 1e9,
        m.balance(),
        w.name
    );
    println!(
        "{:<32} {:>14} {:>16} {:>10} {:>8}",
        "config", "I (MAC/byte)", "attainable", "% peak", "bound"
    );
    for p in figure_points(w, m) {
        println!(
            "{:<32} {:>14.2} {:>12.2e}/s {:>9.1}% {:>8}",
            p.label,
            p.intensity,
            p.attainable,
            p.peak_fraction * 100.0,
            if p.memory_bound { "memory" } else { "compute" }
        );
    }
}

/// Print the measured column (machine-independent — it depends only on
/// the workload).
pub fn print_stash_traffic(w: &TransformerWorkload) {
    println!("\nstash traffic per step (modeled storage_bits vs codec-observed):");
    println!("{:<32} {:>16} {:>16}", "config", "modeled (Mbit)", "observed (Mbit)");
    for (label, modeled, observed) in stash_traffic_rows(w) {
        println!("{label:<32} {:>16.2} {:>16.2}", modeled / 1e6, observed / 1e6);
    }
    println!("\ncomms traffic per 2-replica exchange round (modeled vs wire-observed):");
    println!("{:<32} {:>16} {:>16}", "wire format", "modeled (Kbit)", "observed (Kbit)");
    for (spec, modeled, observed) in comms_traffic_rows() {
        println!("{spec:<32} {:>16.2} {:>16.2}", modeled / 1e3, observed / 1e3);
    }
}

pub fn run(opts: &ExperimentOpts) -> Result<()> {
    let w = TransformerWorkload::iwslt_6layer();
    let mut md = String::from("# Figure 1: Roofline placements\n\n");
    let mut json_machines = Vec::new();
    for m in [Machine::a100_like(), Machine::edge_like()] {
        print_roofline(&m, &w);
        println!();
        md.push_str(&format!(
            "## {} (balance I_opt = {:.1} MAC/byte)\n\n| config | intensity | attainable (MAC/s) | % of peak | bound |\n|---|---|---|---|---|\n",
            m.name,
            m.balance()
        ));
        let pts = figure_points(&w, &m);
        for p in &pts {
            md.push_str(&format!(
                "| {} | {:.2} | {:.3e} | {:.1}% | {} |\n",
                p.label,
                p.intensity,
                p.attainable,
                p.peak_fraction * 100.0,
                if p.memory_bound { "memory" } else { "compute" }
            ));
        }
        md.push('\n');
        json_machines.push(Json::obj(vec![
            ("machine", Json::str(m.name)),
            ("balance", Json::num(m.balance())),
            (
                "points",
                Json::arr(pts.iter().map(|p| {
                    Json::obj(vec![
                        ("label", Json::str(&p.label)),
                        ("intensity", Json::num(p.intensity)),
                        ("attainable", Json::num(p.attainable)),
                        ("peak_fraction", Json::num(p.peak_fraction)),
                        ("memory_bound", Json::Bool(p.memory_bound)),
                    ])
                })),
            ),
            (
                "curve",
                Json::arr(
                    roofline::roofline_curve(&m, 32)
                        .into_iter()
                        .map(|(x, y)| Json::arr([Json::num(x), Json::num(y)])),
                ),
            ),
        ]));
    }
    // The measured column once, machine-independent.
    print_stash_traffic(&w);
    md.push_str(
        "## Stash traffic per step (measured)\n\n\
         | config | modeled Mbit | observed Mbit |\n|---|---|---|\n",
    );
    for (label, modeled, observed) in stash_traffic_rows(&w) {
        md.push_str(&format!("| {label} | {:.2} | {:.2} |\n", modeled / 1e6, observed / 1e6));
    }
    md.push_str(
        "\n## Comms traffic per 2-replica exchange round (measured)\n\n\
         | wire format | modeled Kbit | observed Kbit |\n|---|---|---|\n",
    );
    for (spec, modeled, observed) in comms_traffic_rows() {
        md.push_str(&format!("| {spec} | {:.2} | {:.2} |\n", modeled / 1e3, observed / 1e3));
    }
    let json = Json::obj(vec![
        ("machines", Json::arr(json_machines)),
        (
            "stash_traffic",
            Json::arr(stash_traffic_rows(&w).into_iter().map(|(label, modeled, observed)| {
                Json::obj(vec![
                    ("config", Json::str(label)),
                    ("modeled_bits", Json::num(modeled)),
                    ("observed_bits", Json::num(observed)),
                ])
            })),
        ),
        (
            "comms_traffic",
            Json::arr(comms_traffic_rows().into_iter().map(|(spec, modeled, observed)| {
                Json::obj(vec![
                    ("spec", Json::str(&spec)),
                    ("modeled_comms_bits", Json::num(modeled)),
                    ("observed_comms_bits", Json::num(observed)),
                ])
            })),
        ),
    ]);
    super::write_report(&opts.out, "figure1", &md, &json)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figure_points_ordering_matches_paper() {
        let w = TransformerWorkload::iwslt_6layer();
        let m = Machine::a100_like();
        let pts = figure_points(&w, &m);
        // Intensity must increase monotonically from (1) to (3).
        let i: Vec<f64> = pts.iter().map(|p| p.intensity).collect();
        assert!(i[0] < i[2] && i[2] < i[4], "{i:?}");
    }

    #[test]
    fn measured_stash_column_agrees_with_the_model_within_box_metadata() {
        let w = TransformerWorkload::iwslt_6layer();
        let rows = stash_traffic_rows(&w);
        assert_eq!(rows.len(), 5);
        for (label, modeled, observed) in &rows {
            let p = figure_configs()
                .into_iter()
                .find(|(l, _)| l == label)
                .map(|(_, p)| p)
                .unwrap();
            let allowance =
                crate::costmodel::training::observed_stash_allowance_bits(&w, &p);
            assert!(
                (observed - modeled).abs() <= allowance,
                "{label}: observed {observed} vs modeled {modeled} (allowance {allowance})"
            );
            assert!(*observed > 0.0, "{label} must measure real bytes");
        }
        // The DSQ point stashes at bfp2 — its measured traffic must be
        // far below the fp32 point's.
        assert!(rows[4].2 < rows[0].2 / 8.0, "{rows:?}");
    }

    #[test]
    fn measured_comms_column_covers_fp32_and_the_8bit_registry() {
        let rows = comms_traffic_rows();
        assert!(rows.len() >= 2, "{rows:?}");
        assert_eq!(rows[0].0, "fp32");
        for (spec, modeled, observed) in &rows {
            assert!(*modeled > 0.0 && *observed > 0.0, "{spec}: empty measurement");
        }
        // An 8-bit wire format must move clearly fewer observed bits
        // than the fp32 wire per round (record framing is shared, so
        // the gap is smaller than the raw 4x payload ratio).
        let fp32 = rows[0].2;
        let sub = rows.iter().find(|(s, _, _)| s.contains('8')).expect("an 8-bit row");
        assert!(sub.2 < fp32 * 0.7, "{rows:?}");
    }
}
