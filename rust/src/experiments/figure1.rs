//! Figure 1: the Roofline picture — (1) non-quantized, (2) static
//! quantization, (3) DSQ, against the machine balance point.

use crate::costmodel::{self, roofline, Machine, TransformerWorkload};
use crate::schedule::{FormatSpec, PrecisionConfig};
use crate::util::json::Json;
use crate::Result;

use super::ExperimentOpts;

/// The three points of the paper's Figure 1 + extras.
pub fn figure_points(w: &TransformerWorkload, m: &Machine) -> Vec<roofline::RooflinePoint> {
    let configs: Vec<(&str, PrecisionConfig)> = vec![
        ("(1) fp32 (non-quantized)", PrecisionConfig::FP32),
        ("fixed-point 32", PrecisionConfig::uniform(FormatSpec::fixed(32))),
        ("(2) static quant: BFP16", PrecisionConfig::uniform(FormatSpec::bfp(16))),
        ("static stashing [16,4,4,16]", PrecisionConfig::stashing(FormatSpec::bfp(16))),
        ("(3) DSQ @ [2,2,2,16]", PrecisionConfig::of(FormatSpec::bfp(16), [2, 2, 2, 16])),
    ];
    configs
        .into_iter()
        .map(|(label, p)| roofline::place(m, label, &costmodel::step_cost(w, &p)))
        .collect()
}

pub fn print_roofline(m: &Machine, w: &TransformerWorkload) {
    println!(
        "roofline on {} (peak {:.0} TMAC/s, bw {:.0} GB/s, balance I_opt = {:.1} MAC/byte), workload {}",
        m.name,
        m.peak_macs_per_s / 1e12,
        m.dram_bytes_per_s / 1e9,
        m.balance(),
        w.name
    );
    println!(
        "{:<32} {:>14} {:>16} {:>10} {:>8}",
        "config", "I (MAC/byte)", "attainable", "% peak", "bound"
    );
    for p in figure_points(w, m) {
        println!(
            "{:<32} {:>14.2} {:>12.2e}/s {:>9.1}% {:>8}",
            p.label,
            p.intensity,
            p.attainable,
            p.peak_fraction * 100.0,
            if p.memory_bound { "memory" } else { "compute" }
        );
    }
}

pub fn run(opts: &ExperimentOpts) -> Result<()> {
    let w = TransformerWorkload::iwslt_6layer();
    let mut md = String::from("# Figure 1: Roofline placements\n\n");
    let mut json_machines = Vec::new();
    for m in [Machine::a100_like(), Machine::edge_like()] {
        print_roofline(&m, &w);
        println!();
        md.push_str(&format!(
            "## {} (balance I_opt = {:.1} MAC/byte)\n\n| config | intensity | attainable (MAC/s) | % of peak | bound |\n|---|---|---|---|---|\n",
            m.name,
            m.balance()
        ));
        let pts = figure_points(&w, &m);
        for p in &pts {
            md.push_str(&format!(
                "| {} | {:.2} | {:.3e} | {:.1}% | {} |\n",
                p.label,
                p.intensity,
                p.attainable,
                p.peak_fraction * 100.0,
                if p.memory_bound { "memory" } else { "compute" }
            ));
        }
        md.push('\n');
        json_machines.push(Json::obj(vec![
            ("machine", Json::str(m.name)),
            ("balance", Json::num(m.balance())),
            (
                "points",
                Json::arr(pts.iter().map(|p| {
                    Json::obj(vec![
                        ("label", Json::str(&p.label)),
                        ("intensity", Json::num(p.intensity)),
                        ("attainable", Json::num(p.attainable)),
                        ("peak_fraction", Json::num(p.peak_fraction)),
                        ("memory_bound", Json::Bool(p.memory_bound)),
                    ])
                })),
            ),
            (
                "curve",
                Json::arr(
                    roofline::roofline_curve(&m, 32)
                        .into_iter()
                        .map(|(x, y)| Json::arr([Json::num(x), Json::num(y)])),
                ),
            ),
        ]));
    }
    super::write_report(&opts.out, "figure1", &md, &Json::arr(json_machines))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figure_points_ordering_matches_paper() {
        let w = TransformerWorkload::iwslt_6layer();
        let m = Machine::a100_like();
        let pts = figure_points(&w, &m);
        // Intensity must increase monotonically from (1) to (3).
        let i: Vec<f64> = pts.iter().map(|p| p.intensity).collect();
        assert!(i[0] < i[2] && i[2] < i[4], "{i:?}");
    }
}
