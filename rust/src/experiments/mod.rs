//! Experiment drivers: one per paper table/figure (DESIGN.md §8).
//!
//! Each experiment combines the analytic cost columns (always) with real
//! training runs on the synthetic stand-in tasks (unless `--no-train`),
//! prints the paper-style table with paper reference values alongside,
//! and writes `results/<id>.{md,json}`.

pub mod figure1;
pub mod table1;
pub mod table4;
pub mod table5;
pub mod table6;

use std::path::{Path, PathBuf};

use crate::util::json::Json;
use crate::{Error, Result};

/// Shared experiment options (from `dsq experiment` flags).
#[derive(Clone, Debug)]
pub struct ExperimentOpts {
    pub artifacts: PathBuf,
    pub out: PathBuf,
    pub train_epochs: usize,
    pub batches_per_epoch: usize,
    /// false = cost columns only (fast, no PJRT).
    pub train: bool,
}

impl ExperimentOpts {
    pub fn quick(artifacts: PathBuf) -> Self {
        ExperimentOpts {
            artifacts,
            out: PathBuf::from("results"),
            train_epochs: 2,
            batches_per_epoch: 20,
            train: true,
        }
    }
}

/// Run one experiment by id.
pub fn run(which: &str, opts: &ExperimentOpts) -> Result<()> {
    match which {
        "table1-iwslt" => table1::run_iwslt(opts),
        "table1-glue" => table1::run_glue(opts),
        "table4" => table4::run(opts),
        "table5" => table5::run(opts),
        "table6" => table6::run(opts),
        "figure1" => figure1::run(opts),
        "all" => {
            for id in ["figure1", "table1-iwslt", "table1-glue", "table4", "table5", "table6"] {
                crate::info!("=== experiment {id} ===");
                run(id, opts)?;
            }
            Ok(())
        }
        other => Err(Error::Config(format!(
            "unknown experiment '{other}' (table1-iwslt, table1-glue, table4, table5, table6, figure1, all)"
        ))),
    }
}

/// Write an experiment report to `<out>/<id>.md` and `.json`.
pub fn write_report(out: &Path, id: &str, markdown: &str, json: &Json) -> Result<()> {
    std::fs::create_dir_all(out)?;
    std::fs::write(out.join(format!("{id}.md")), markdown)?;
    std::fs::write(out.join(format!("{id}.json")), json.to_string_pretty())?;
    crate::info!("report written to {}/{id}.{{md,json}}", out.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_experiment_is_error() {
        let opts = ExperimentOpts::quick(PathBuf::from("/nonexistent"));
        assert!(run("bogus", &opts).is_err());
    }

    #[test]
    fn write_report_creates_files() {
        let dir = std::env::temp_dir().join(format!("dsq-exp-{}", std::process::id()));
        write_report(&dir, "test", "# hi\n", &Json::obj(vec![("a", Json::num(1))])).unwrap();
        assert!(dir.join("test.md").exists());
        assert!(dir.join("test.json").exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
