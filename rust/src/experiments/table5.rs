//! Table 5 (Appendix C): the effect of the gradient-output width `q3`
//! under fixed-point stashing.
//!
//! Paper reference (IWSLT14, Stashing Fixed):
//!
//! | precision     | BLEU   |
//! |---------------|--------|
//! | [8,8,8,32]    | 34.08  |
//! | [8,8,8,16]    | 31.94  |
//! | [8,8,8,8]     | Failed |
//!
//! This is why every DSQ ladder keeps `q3 ≥ 16`: 8-bit per-tensor
//! fixed-point gradients lose the dynamic range the backward pass needs
//! and training diverges. The divergence detector (metrics::tracker) is
//! what flags the "Failed" row here.

use crate::coordinator::{Trainer, TrainerConfig};
use crate::data::Variant;
use crate::schedule::{PrecisionConfig, Schedule, StaticSchedule};
use crate::util::json::Json;
use crate::Result;

use super::ExperimentOpts;

pub const SWEEP: &[(&str, Option<f64>)] =
    &[("[8,8,8,32]", Some(34.08)), ("[8,8,8,16]", Some(31.94)), ("[8,8,8,8]", None)];

pub fn run(opts: &ExperimentOpts) -> Result<()> {
    let mut md = String::from(
        "# Table 5: gradient-output precision q3 (Stashing Fixed, synthetic IWSLT-style task)\n\n\
         | precision | BLEU | val loss | diverged | paper BLEU |\n|---|---|---|---|---|\n",
    );
    let mut json_rows = Vec::new();
    for (setup, paper) in SWEEP {
        let p = PrecisionConfig::parse(&format!("fixed:{setup}"))?;
        let (bleu, val, diverged) = if opts.train {
            let cfg = TrainerConfig {
                artifacts: opts.artifacts.clone(),
                seed: 0,
                epochs: opts.train_epochs,
                batches_per_epoch: opts.batches_per_epoch,
                variant: Variant::Iwslt,
                ..TrainerConfig::quick(opts.artifacts.clone())
            };
            let mut schedule: Box<dyn Schedule> = Box::new(StaticSchedule(p));
            let report = Trainer::new(cfg)?.run(schedule.as_mut())?;
            (report.bleu(), Some(report.final_val_loss), report.diverged)
        } else {
            (None, None, false)
        };
        md.push_str(&format!(
            "| {} | {} | {} | {} | {} |\n",
            setup,
            if diverged { "Failed".into() } else { bleu.map_or("-".into(), |b| format!("{b:.2}")) },
            val.map_or("-".into(), |v| format!("{v:.3}")),
            diverged,
            paper.map_or("Failed".into(), |b| format!("{b:.2}")),
        ));
        json_rows.push(Json::obj(vec![
            ("precision", Json::str(setup)),
            ("bleu", bleu.map_or(Json::Null, Json::num)),
            ("val_loss", val.map_or(Json::Null, Json::num)),
            ("diverged", Json::Bool(diverged)),
            ("paper_bleu", paper.map_or(Json::str("Failed"), Json::num)),
        ]));
    }
    println!("{md}");
    super::write_report(&opts.out, "table5", &md, &Json::arr(json_rows))
}
