//! Table 6 (Appendix D): the WMT14 EN-DE variant of Table 1 — same
//! method list on the harder task (paper trains 15 epochs only, BLEU
//! 25.79 fp32; the bigram synthetic variant is likewise harder than the
//! unigram one at equal budget).

use crate::coordinator::{Trainer, TrainerConfig};
use crate::costmodel::{self, TransformerWorkload};
use crate::data::Variant;
use crate::schedule::{FormatSpec, PrecisionConfig, Schedule, StaticSchedule};
use crate::util::json::Json;
use crate::Result;

use super::ExperimentOpts;

/// Paper Table 6 BLEU deltas vs fp32 (25.79).
pub const PAPER_WMT_DELTAS: &[(&str, &str, f64)] = &[
    ("Fixed-point", "[32,32,32,32]", -0.38),
    ("Fixed-point", "[16,16,16,16]", -2.39),
    ("Block FP", "[32,32,32,32]", -0.03),
    ("Block FP", "[16,16,16,16]", -0.18),
    ("Stashing (Fixed)", "[16,4,4,16]", -3.93),
    ("Stashing (BFP)", "[16,4,4,16]", -0.55),
];

pub fn run(opts: &ExperimentOpts) -> Result<()> {
    let workload = TransformerWorkload::wmt_6layer();
    let methods: Vec<(&str, PrecisionConfig)> = vec![
        ("Floating-point", PrecisionConfig::FP32),
        ("Fixed-point", PrecisionConfig::uniform(FormatSpec::fixed(32))),
        ("Fixed-point", PrecisionConfig::uniform(FormatSpec::fixed(16))),
        ("Block FP", PrecisionConfig::uniform(FormatSpec::bfp(32))),
        ("Block FP", PrecisionConfig::uniform(FormatSpec::bfp(16))),
        ("Stashing (Fixed)", PrecisionConfig::stashing(FormatSpec::fixed(16))),
        ("Stashing (BFP)", PrecisionConfig::stashing(FormatSpec::bfp(16))),
    ];

    let mut md = String::from(
        "# Table 6: WMT14-style translation (bigram synthetic variant)\n\n\
         | method | precision | BLEU (Δ) | arith | dram | paper Δ |\n|---|---|---|---|---|---|\n",
    );
    let mut json_rows = Vec::new();
    let mut fp32_bleu: Option<f64> = None;

    for (method, p) in methods {
        let cost = costmodel::normalized_row(&workload, method, &p, !p.is_fp32());
        let (bleu, delta, diverged) = if opts.train {
            let cfg = TrainerConfig {
                artifacts: opts.artifacts.clone(),
                seed: 0,
                epochs: opts.train_epochs,
                batches_per_epoch: opts.batches_per_epoch,
                variant: Variant::Wmt,
                ..TrainerConfig::quick(opts.artifacts.clone())
            };
            let mut schedule: Box<dyn Schedule> = Box::new(StaticSchedule(p));
            let report = Trainer::new(cfg)?.run(schedule.as_mut())?;
            if p.is_fp32() {
                fp32_bleu = report.bleu();
            }
            let delta = match (report.bleu(), fp32_bleu) {
                (Some(b), Some(f)) if !p.is_fp32() => Some(b - f),
                _ => None,
            };
            (report.bleu(), delta, report.diverged)
        } else {
            (None, None, false)
        };

        let paper_delta = PAPER_WMT_DELTAS
            .iter()
            .find(|(m, pr, _)| *m == method && *pr == p.notation())
            .map(|(_, _, d)| *d);
        let f = |v: Option<f64>| v.map_or("-".to_string(), |x| format!("{x:.3}x"));
        md.push_str(&format!(
            "| {} | {} | {} | {} | {} | {} |\n",
            method,
            p.notation(),
            if diverged {
                "Failed".into()
            } else {
                bleu.map_or("-".into(), |b| format!(
                    "{b:.2}{}",
                    delta.map_or(String::new(), |d| format!(" ({d:+.2})"))
                ))
            },
            f(cost.arith_rel),
            f(cost.dram_rel),
            paper_delta.map_or("-".into(), |d| format!("{d:+.2}")),
        ));
        json_rows.push(Json::obj(vec![
            ("method", Json::str(method)),
            ("precision", Json::str(&p.notation())),
            ("bleu", bleu.map_or(Json::Null, Json::num)),
            ("delta", delta.map_or(Json::Null, Json::num)),
            ("arith_rel", cost.arith_rel.map_or(Json::Null, Json::num)),
            ("dram_rel", cost.dram_rel.map_or(Json::Null, Json::num)),
            ("paper_delta", paper_delta.map_or(Json::Null, Json::num)),
            ("diverged", Json::Bool(diverged)),
        ]));
    }
    println!("{md}");
    super::write_report(&opts.out, "table6", &md, &Json::arr(json_rows))
}
