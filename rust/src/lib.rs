//! # DSQ — Dynamic Stashing Quantization for Efficient Transformer Training
//!
//! Rust reproduction of Yang, Mullins, Lo & Zhao (EMNLP 2023 Findings):
//! a quantized-training system in which **all GEMM operands are quantized**
//! and the intermediate tensors *stashed* between the forward and backward
//! passes are quantized far more aggressively (`q1`, the stash) than the
//! compute path, with a **time-adaptive schedule** that starts at 2-bit
//! block-floating-point and monotonically raises precision when the
//! validation loss plateaus.
//!
//! Architecture (see `DESIGN.md`):
//!
//! * **L1/L2 (build time, python)** — Pallas quantizer kernels + a JAX
//!   transformer whose autodiff implements the paper's Figure-2 dataflow;
//!   AOT-lowered once to `artifacts/*.hlo.txt`.
//! * **L3 (this crate)** — the training coordinator: loads the artifacts
//!   through PJRT ([`runtime`]), synthesizes corpora ([`data`]), drives
//!   training with the dynamic precision controller ([`schedule`],
//!   [`coordinator`]), accounts hardware cost per step ([`costmodel`]),
//!   scores BLEU/accuracy ([`metrics`]) and regenerates every table and
//!   figure of the paper ([`experiments`]).
//!
//! Python never runs at request time: once `make artifacts` has produced
//! the HLO text, the `dsq` binary is self-contained.

pub mod analysis;
pub mod bench;
pub mod coordinator;
pub mod costmodel;
pub mod data;
pub mod experiments;
pub mod metrics;
pub mod model;
pub mod obs;
pub mod quant;
pub mod runtime;
pub mod schedule;
pub mod stash;
pub mod util;

/// Crate-wide error type.
#[derive(thiserror::Error, Debug)]
pub enum Error {
    #[error("xla error: {0}")]
    Xla(#[from] xla::Error),
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
    #[error("json error: {0}")]
    Json(String),
    #[error("manifest error: {0}")]
    Manifest(String),
    #[error("shape error: {0}")]
    Shape(String),
    #[error("config error: {0}")]
    Config(String),
    #[error("training diverged: {0}")]
    Diverged(String),
    #[error("lint: {0}")]
    Lint(String),
}

pub type Result<T> = std::result::Result<T, Error>;
