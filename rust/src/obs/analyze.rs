//! `dsq trace <dir>`: load and render the run manifests written by
//! [`Recorder`](super::Recorder).
//!
//! Everything here is data-driven from the manifest JSON (schema
//! [`TRACE_MAGIC`](super::TRACE_MAGIC)): per-phase step-time breakdown
//! with share-of-step, nested phases indented under their parents,
//! cross-rank skew when several ranks wrote into the same directory,
//! and the modeled-vs-observed traffic columns next to the timings —
//! the wall-clock counterpart of the byte tables.

use std::fmt::Write as _;
use std::path::Path;

use crate::bench::fmt_ns;
use crate::stash::fmt_bytes;
use crate::util::json::{self, Json};
use crate::{Error, Result};

/// Load every `run.*.json` manifest under `dir`, sorted by file name
/// (rank order for rank-tagged files). Errors when the directory holds
/// no manifests or one carries an unsupported schema.
pub fn load_runs(dir: &Path) -> Result<Vec<(String, Json)>> {
    let mut names: Vec<String> = Vec::new();
    for entry in std::fs::read_dir(dir)?.flatten() {
        let name = entry.file_name().to_string_lossy().into_owned();
        if name.starts_with("run.") && name.ends_with(".json") {
            names.push(name);
        }
    }
    names.sort();
    if names.is_empty() {
        return Err(Error::Config(format!(
            "no run.*.json manifests under {} — run with --trace <dir> first",
            dir.display()
        )));
    }
    let want = super::schema_str();
    let mut runs = Vec::new();
    for name in names {
        let doc = json::parse_file(&dir.join(&name))?;
        let got = doc.get("schema").and_then(Json::as_str).unwrap_or("<missing>").to_string();
        if got != want {
            return Err(Error::Config(format!(
                "{name}: schema '{got}' is not the supported '{want}'"
            )));
        }
        runs.push((name, doc));
    }
    Ok(runs)
}

/// Render loaded manifests as the analyzer report (pure string; the
/// CLI prints it).
pub fn render(runs: &[(String, Json)]) -> String {
    let mut out = String::new();
    for (name, doc) in runs {
        render_run(&mut out, name, doc);
    }
    if runs.len() > 1 {
        render_skew(&mut out, runs);
    }
    out
}

fn num(doc: &Json, key: &str) -> f64 {
    doc.get(key).and_then(Json::as_f64).unwrap_or(0.0)
}

fn phase_entries(doc: &Json) -> Vec<&Json> {
    doc.get("phases").and_then(Json::as_arr).map(|v| v.iter().collect()).unwrap_or_default()
}

fn is_top_level(entry: &Json) -> bool {
    matches!(entry.get("parent"), Some(Json::Null) | None)
}

fn render_run(out: &mut String, name: &str, doc: &Json) {
    let rank = num(doc, "rank") as u64;
    let steps = num(doc, "steps") as u64;
    let wall_s = num(doc, "wall_s");
    let _ = writeln!(out, "== {name} · rank {rank} · steps {steps} · wall {wall_s:.3} s");
    let entries = phase_entries(doc);
    let step_total_ns: f64 =
        entries.iter().filter(|e| is_top_level(e)).map(|e| num(e, "total_ns")).sum();
    let _ = writeln!(
        out,
        "{:<22} {:>8} {:>12} {:>7} {:>12} {:>12} {:>12}",
        "phase", "count", "total", "share", "p50", "p95", "bytes"
    );
    for top in entries.iter().filter(|e| is_top_level(e)) {
        render_phase_row(out, top, step_total_ns, 0);
        let pname = top.get("phase").and_then(Json::as_str).unwrap_or("");
        for nested in entries
            .iter()
            .filter(|e| e.get("parent").and_then(Json::as_str) == Some(pname))
        {
            render_phase_row(out, nested, step_total_ns, 2);
        }
    }
    if wall_s > 0.0 {
        let covered = step_total_ns / 1e9 / wall_s * 100.0;
        let _ = writeln!(
            out,
            "step phases total {} of {wall_s:.3} s wall ({covered:.1}%)",
            fmt_ns(step_total_ns)
        );
    }
    let dropped = num(doc, "events_dropped") as u64;
    if dropped > 0 {
        let _ = writeln!(out, "events dropped: {dropped}");
    }
    render_ladder(out, doc);
    render_traffic(out, doc, &entries);
    out.push('\n');
}

fn render_phase_row(out: &mut String, entry: &Json, step_total_ns: f64, indent: usize) {
    let pname = entry.get("phase").and_then(Json::as_str).unwrap_or("?");
    let total_ns = num(entry, "total_ns");
    let share = if is_top_level(entry) && step_total_ns > 0.0 {
        format!("{:.1}%", total_ns / step_total_ns * 100.0)
    } else {
        "·".to_string()
    };
    let bytes = num(entry, "bytes") as u64;
    let bytes_col = if bytes > 0 { fmt_bytes(bytes) } else { "-".to_string() };
    let _ = writeln!(
        out,
        "{:<22} {:>8} {:>12} {:>7} {:>12} {:>12} {:>12}",
        format!("{}{pname}", " ".repeat(indent)),
        num(entry, "count") as u64,
        fmt_ns(total_ns),
        share,
        fmt_ns(num(entry, "p50_ns")),
        fmt_ns(num(entry, "p95_ns")),
        bytes_col
    );
}

fn render_ladder(out: &mut String, doc: &Json) {
    let Some(rungs) = doc.get("ladder").and_then(Json::as_arr) else { return };
    if rungs.is_empty() {
        return;
    }
    let desc: Vec<String> = rungs
        .iter()
        .map(|r| {
            let step = num(r, "step") as u64;
            let spec = r.get("spec").and_then(Json::as_str).unwrap_or("?");
            format!("step {step} → {spec}")
        })
        .collect();
    let _ = writeln!(out, "ladder: {}", desc.join(", "));
}

fn render_traffic(out: &mut String, doc: &Json, entries: &[&Json]) {
    if let Some(stash) = doc.get("stash").filter(|s| !matches!(s, Json::Null)) {
        // StashTraffic::to_json nests the meter under "traffic".
        let m = stash.get("traffic").unwrap_or(stash);
        let _ = writeln!(
            out,
            "traffic (stash): write {}, read {}, spill write {}, spill read {}, checkpoint {}; \
             modeled {:.3e} bits vs observed {:.3e} bits ({})",
            fmt_bytes(num(m, "stash_write_bytes") as u64),
            fmt_bytes(num(m, "stash_read_bytes") as u64),
            fmt_bytes(num(m, "spill_write_bytes") as u64),
            fmt_bytes(num(m, "spill_read_bytes") as u64),
            fmt_bytes(num(m, "checkpoint_bytes") as u64),
            num(m, "modeled_stash_bits"),
            num(m, "observed_stash_bits"),
            agree_str(stash)
        );
    }
    if let Some(comms) = doc.get("comms").filter(|c| !matches!(c, Json::Null)) {
        let tx = num(comms, "comms_tx_bytes") as u64;
        let rx = num(comms, "comms_rx_bytes") as u64;
        let _ = writeln!(
            out,
            "traffic (comms): tx {}, rx {}, frames {}; \
             modeled {:.3e} bits vs observed {:.3e} bits ({})",
            fmt_bytes(tx),
            fmt_bytes(rx),
            fmt_bytes(num(comms, "comms_frame_bytes") as u64),
            num(comms, "modeled_comms_bits"),
            num(comms, "observed_comms_bits"),
            agree_str(comms)
        );
        // The wall-clock-vs-bytes cross-check: bytes the exchange spans
        // attributed against what the comms meter counted.
        let span_bytes: f64 = entries
            .iter()
            .filter(|e| e.get("phase").and_then(Json::as_str) == Some("exchange"))
            .map(|e| num(e, "bytes"))
            .sum();
        if span_bytes > 0.0 && tx + rx > 0 {
            let meter = (tx + rx) as f64;
            let delta = (span_bytes - meter).abs() / meter * 100.0;
            let _ = writeln!(
                out,
                "exchange span bytes {} vs comms meter tx+rx {} (Δ {delta:.1}%)",
                fmt_bytes(span_bytes as u64),
                fmt_bytes(tx + rx)
            );
        }
    }
}

fn agree_str(traffic: &Json) -> &'static str {
    match traffic.get("agrees").and_then(Json::as_bool) {
        Some(true) => "agrees",
        Some(false) => "DISAGREES",
        None => "unchecked",
    }
}

fn render_skew(out: &mut String, runs: &[(String, Json)]) {
    let _ = writeln!(out, "== cross-rank skew ({} ranks)", runs.len());
    let _ = writeln!(
        out,
        "{:<22} {:>12} {:>12} {:>12}",
        "phase", "min total", "max total", "skew"
    );
    // Phase order from the first run; every rank runs the same step.
    let order: Vec<String> = phase_entries(&runs[0].1)
        .iter()
        .filter(|e| is_top_level(e))
        .filter_map(|e| e.get("phase").and_then(Json::as_str).map(str::to_string))
        .collect();
    for pname in order {
        let totals: Vec<f64> = runs
            .iter()
            .filter_map(|(_, doc)| {
                phase_entries(doc)
                    .iter()
                    .find(|e| e.get("phase").and_then(Json::as_str) == Some(pname.as_str()))
                    .map(|e| num(e, "total_ns"))
            })
            .collect();
        if totals.len() < 2 {
            continue;
        }
        let mut lo = f64::INFINITY;
        let mut hi = 0.0f64;
        for &t in &totals {
            lo = lo.min(t);
            hi = hi.max(t);
        }
        let _ = writeln!(
            out,
            "{pname:<22} {:>12} {:>12} {:>12}",
            fmt_ns(lo),
            fmt_ns(hi),
            fmt_ns(hi - lo)
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{Phase, Recorder, RunInfo};
    use std::path::PathBuf;

    fn tmpdir(tag: &str) -> PathBuf {
        let mut d = std::env::temp_dir();
        d.push(format!("dsq-obs-analyze-{tag}-{}", std::process::id()));
        d
    }

    fn write_run(dir: &Path, rank: usize) {
        let r = Recorder::to_dir(dir, rank).unwrap();
        for step in 0..2u64 {
            let s = r.span_start(Phase::Dispatch);
            r.span_close(s, step, 100);
            let e = r.span_start(Phase::Exchange);
            r.span_close(e, step, 64);
            r.span_import(Phase::ExchEncode, step, 500, 0);
        }
        let info = RunInfo { steps: 2, wall_s: 0.01, ..RunInfo::empty() };
        r.finish_run(&info).unwrap();
    }

    #[test]
    fn load_renders_single_and_multi_rank() {
        let dir = tmpdir("render");
        write_run(&dir, 0);
        write_run(&dir, 1);
        let runs = load_runs(&dir).unwrap();
        assert_eq!(runs.len(), 2);
        assert_eq!(runs[0].0, "run.rank0.json");
        let report = render(&runs);
        assert!(report.contains("dispatch"), "{report}");
        assert!(report.contains("exchange"), "{report}");
        assert!(report.contains("  exch_encode"), "nested phase indented: {report}");
        assert!(report.contains("cross-rank skew (2 ranks)"), "{report}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn empty_dir_is_a_config_error() {
        let dir = tmpdir("empty");
        std::fs::create_dir_all(&dir).unwrap();
        let err = load_runs(&dir).unwrap_err().to_string();
        assert!(err.contains("no run.*.json manifests"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bad_schema_is_rejected_by_name() {
        let dir = tmpdir("schema");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("run.rank0.json"), "{\"schema\": \"BOGUS\"}").unwrap();
        let err = load_runs(&dir).unwrap_err().to_string();
        assert!(err.contains("BOGUS"), "{err}");
        assert!(err.contains("run.rank0.json"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
