//! Run telemetry: span-based step tracing, per-phase aggregates, and
//! structured run manifests (ROADMAP "Observability").
//!
//! The paper's thesis is that DSQ training is *memory-bound*. The
//! [`TrafficMeter`](crate::stash::TrafficMeter) already counts every
//! stash and comms byte; this module adds the wall-clock counterpart —
//! where a training step's time actually goes (batch wait vs dispatch
//! vs quantize vs spill vs exchange vs checkpoint) — so ROADMAP track 3
//! can pick parallelization targets from measurements instead of
//! guesses.
//!
//! # Design
//!
//! * [`Recorder`] is a cheap cloneable handle threaded into every
//!   instrumented component. Disabled (the default) a span is a single
//!   `Option` check; the `train_step_latency` bench asserts the
//!   disabled overhead stays under 1% of the median step.
//! * [`ObsSpan`]s carry a monotonic [`Instant`]; closing one folds the
//!   duration and attributed bytes into a per-phase aggregate and
//!   appends one JSONL event to a bounded in-memory buffer. Events past
//!   the buffer cap are counted in `events_dropped`, never silently
//!   lost. Sub-phase timings measured elsewhere (stash store clocks,
//!   exchange counters) enter through [`Recorder::span_import`].
//! * All file I/O stays *off-lock*: [`Recorder::flush_events`] first
//!   drains the buffer under the witnessed mutex (rank
//!   [`RANK_OBS_BUFFER`](crate::util::ordwitness::RANK_OBS_BUFFER)),
//!   then appends to the trace file with no lock held —
//!   `ordwitness::assert_lock_free` is the runtime proof, the
//!   `blocking_under_lock` lint the static one.
//! * [`Recorder::finish_run`] writes the `run.rank<N>.json` manifest:
//!   argv/config, per-phase aggregates (count/total/min/max/p50/p95 and
//!   attributed bytes), the stash + comms traffic columns, and the
//!   controller ladder transitions. The schema is versioned by
//!   [`TRACE_MAGIC`] and pinned by `rust/tests/trace_schema.rs`.
//!
//! Replicated runs write one trace + manifest pair per rank into the
//! same `--trace <dir>` (worker processes tag files with their own
//! rank); `dsq trace <dir>` ([`analyze`]) renders the per-phase
//! breakdown, share-of-step, cross-rank skew, and modeled-vs-observed
//! traffic next to the timings.

pub mod analyze;

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

use crate::util::json::Json;
use crate::util::ordwitness::{WitnessedMutex, RANK_OBS_BUFFER};
use crate::Result;

/// Trace/manifest schema version: the `schema` field of every
/// `run.rank<N>.json` manifest and trace JSONL header line. Bump on any
/// breaking schema change; `rust/tests/trace_schema.rs` pins the bytes.
pub const TRACE_MAGIC: &[u8; 8] = b"DSQTRCE1";

/// [`TRACE_MAGIC`] as the string carried in the JSON `schema` field.
pub fn schema_str() -> String {
    String::from_utf8_lossy(TRACE_MAGIC).into_owned()
}

/// Per-phase sample reservoir cap: aggregates keep the most recent
/// `SAMPLE_CAP` durations (ring-replaced) for p50/p95 without unbounded
/// memory on long runs.
const SAMPLE_CAP: usize = 4096;

/// Pending-event cap: JSONL events buffered between flushes beyond this
/// are dropped (and counted) rather than growing without bound.
const MAX_PENDING: usize = 8192;

/// A traced phase of the training step.
///
/// Top-level phases partition the step wall-clock — their totals sum to
/// (approximately) the measured step time. Nested phases attribute time
/// *inside* a parent (see [`Phase::parent`]) and are excluded from
/// step-time sums by the analyzer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Blocking on the batch-producer channel.
    BatchWait,
    /// Executable dispatch + step-output absorb.
    Dispatch,
    /// Materializing state for dispatch (spill readback + fetch).
    StashRead,
    /// Packing state back into the stash after the step.
    StashWrite,
    /// The replica-exchange all-reduce round.
    Exchange,
    /// Checkpoint serialization + write.
    Checkpoint,
    /// Validation passes.
    Validate,
    /// Nested in [`Phase::StashWrite`]: quantize/pack kernels.
    Quantize,
    /// Nested in [`Phase::StashWrite`]: spill segment writes.
    SpillWrite,
    /// Nested in [`Phase::StashRead`]: spill readback.
    SpillRead,
    /// Nested in [`Phase::Exchange`]: wire-format encode.
    ExchEncode,
    /// Nested in [`Phase::Exchange`]: posting/collecting frames.
    ExchPost,
    /// Nested in [`Phase::Exchange`]: decode + mean + requantize.
    ExchReduce,
}

impl Phase {
    /// Every phase, top-level first, in manifest order.
    pub const ALL: [Phase; 13] = [
        Phase::BatchWait,
        Phase::Dispatch,
        Phase::StashRead,
        Phase::StashWrite,
        Phase::Exchange,
        Phase::Checkpoint,
        Phase::Validate,
        Phase::Quantize,
        Phase::SpillWrite,
        Phase::SpillRead,
        Phase::ExchEncode,
        Phase::ExchPost,
        Phase::ExchReduce,
    ];

    /// The snake_case name used in events and manifests.
    pub fn name(self) -> &'static str {
        match self {
            Phase::BatchWait => "batch_wait",
            Phase::Dispatch => "dispatch",
            Phase::StashRead => "stash_read",
            Phase::StashWrite => "stash_write",
            Phase::Exchange => "exchange",
            Phase::Checkpoint => "checkpoint",
            Phase::Validate => "validate",
            Phase::Quantize => "quantize",
            Phase::SpillWrite => "spill_write",
            Phase::SpillRead => "spill_read",
            Phase::ExchEncode => "exch_encode",
            Phase::ExchPost => "exch_post",
            Phase::ExchReduce => "exch_reduce",
        }
    }

    /// `Some(parent)` for nested phases, `None` for the top-level
    /// step-partition phases.
    pub fn parent(self) -> Option<Phase> {
        match self {
            Phase::Quantize | Phase::SpillWrite => Some(Phase::StashWrite),
            Phase::SpillRead => Some(Phase::StashRead),
            Phase::ExchEncode | Phase::ExchPost | Phase::ExchReduce => Some(Phase::Exchange),
            _ => None,
        }
    }
}

/// Aggregate over every closed span of one phase.
#[derive(Clone, Debug)]
struct PhaseAgg {
    count: u64,
    total_ns: u64,
    min_ns: u64,
    max_ns: u64,
    bytes: u64,
    samples: Vec<u64>,
}

impl Default for PhaseAgg {
    fn default() -> Self {
        PhaseAgg {
            count: 0,
            total_ns: 0,
            min_ns: u64::MAX,
            max_ns: 0,
            bytes: 0,
            samples: Vec::new(),
        }
    }
}

impl PhaseAgg {
    fn fold(&mut self, dur_ns: u64, bytes: u64) {
        self.count += 1;
        self.total_ns += dur_ns;
        self.min_ns = self.min_ns.min(dur_ns);
        self.max_ns = self.max_ns.max(dur_ns);
        self.bytes += bytes;
        if self.samples.len() < SAMPLE_CAP {
            self.samples.push(dur_ns);
        } else {
            self.samples[((self.count - 1) % SAMPLE_CAP as u64) as usize] = dur_ns;
        }
    }

    fn pct_ns(&self, p: f64) -> u64 {
        let xs: Vec<f64> = self.samples.iter().map(|&v| v as f64).collect();
        crate::util::stats::percentile(&xs, p).round() as u64
    }
}

/// The mutex-protected recorder state: per-phase aggregates plus the
/// bounded pending-event buffer. Everything done under this lock is
/// memory-only; file I/O happens after the guard is dropped.
struct ObsBuf {
    phases: Vec<PhaseAgg>,
    pending: Vec<String>,
    dropped: u64,
}

impl Default for ObsBuf {
    fn default() -> Self {
        ObsBuf {
            phases: Phase::ALL.iter().map(|_| PhaseAgg::default()).collect(),
            pending: Vec::new(),
            dropped: 0,
        }
    }
}

struct RecorderInner {
    origin: Instant,
    rank: usize,
    trace_path: PathBuf,
    run_path: PathBuf,
    obsbuf: WitnessedMutex<ObsBuf>,
}

/// An open span: created by [`Recorder::span_start`], consumed by
/// [`Recorder::span_close`]. When the recorder is disabled the span
/// carries no timestamp and closing it is a no-op.
#[must_use = "close the span via Recorder::span_close or the phase is never recorded"]
pub struct ObsSpan {
    phase: Phase,
    start: Option<Instant>,
}

/// A cheap handle to the run's telemetry sink.
///
/// Cloning shares the underlying buffer; the default/[`disabled`]
/// recorder does nothing and costs one branch per span.
///
/// [`disabled`]: Recorder::disabled
#[derive(Clone, Default)]
pub struct Recorder {
    inner: Option<Arc<RecorderInner>>,
}

impl Recorder {
    /// The no-op recorder used when `--trace` is not given.
    pub fn disabled() -> Recorder {
        Recorder::default()
    }

    /// A recorder writing `trace.rank<rank>.jsonl` (truncated, header
    /// line first) and, at [`Recorder::finish_run`],
    /// `run.rank<rank>.json` under `dir`.
    pub fn to_dir(dir: &Path, rank: usize) -> Result<Recorder> {
        crate::util::ordwitness::assert_lock_free("creating the obs trace dir");
        std::fs::create_dir_all(dir)?;
        let trace_path = dir.join(format!("trace.rank{rank}.jsonl"));
        let run_path = dir.join(format!("run.rank{rank}.json"));
        let header = Json::obj(vec![
            ("schema", Json::str(&schema_str())),
            ("kind", Json::str("header")),
            ("rank", Json::num(rank as f64)),
        ]);
        let mut line = header.to_string();
        line.push('\n');
        std::fs::write(&trace_path, line)?;
        Ok(Recorder {
            inner: Some(Arc::new(RecorderInner {
                origin: Instant::now(),
                rank,
                trace_path,
                run_path,
                obsbuf: WitnessedMutex::new(RANK_OBS_BUFFER, "obs.buffer", ObsBuf::default()),
            })),
        })
    }

    /// Whether spans are actually recorded.
    pub fn is_active(&self) -> bool {
        self.inner.is_some()
    }

    /// Open a span for `phase`. Costs one branch when disabled.
    pub fn span_start(&self, phase: Phase) -> ObsSpan {
        ObsSpan { phase, start: self.inner.as_ref().map(|_| Instant::now()) }
    }

    /// Close `span`, folding its duration and `bytes` into the phase
    /// aggregate and buffering one JSONL event (memory-only; the file
    /// write happens in [`Recorder::flush_events`]).
    pub fn span_close(&self, span: ObsSpan, step: u64, bytes: u64) {
        let (Some(inner), Some(start)) = (self.inner.as_deref(), span.start) else {
            return;
        };
        let dur_ns = start.elapsed().as_nanos() as u64;
        let t_ns = inner.origin.elapsed().as_nanos() as u64;
        Self::obs_record(inner, span.phase, step, t_ns, dur_ns, bytes);
    }

    /// Record a duration measured elsewhere (stash-store clocks,
    /// exchange counters) as a nested-phase event. Zero duration and
    /// zero bytes is skipped so inactive sub-phases stay out of the
    /// manifest.
    pub fn span_import(&self, phase: Phase, step: u64, dur_ns: u64, bytes: u64) {
        let Some(inner) = self.inner.as_deref() else { return };
        if dur_ns == 0 && bytes == 0 {
            return;
        }
        let t_ns = inner.origin.elapsed().as_nanos() as u64;
        Self::obs_record(inner, phase, step, t_ns, dur_ns, bytes);
    }

    /// Memory-only: formats the event line *before* taking the lock and
    /// does nothing but aggregate folds and a bounded push under it.
    fn obs_record(
        inner: &RecorderInner,
        phase: Phase,
        step: u64,
        t_ns: u64,
        dur_ns: u64,
        bytes: u64,
    ) {
        let name = phase.name();
        let line = format!(
            "{{\"phase\":\"{name}\",\"step\":{step},\"t_ns\":{t_ns},\
             \"dur_ns\":{dur_ns},\"bytes\":{bytes}}}"
        );
        let mut buf = inner.obsbuf.lock();
        buf.phases[phase as usize].fold(dur_ns, bytes);
        if buf.pending.len() < MAX_PENDING {
            buf.pending.push(line);
        } else {
            buf.dropped += 1;
        }
    }

    /// Drain the pending buffer under the lock; memory-only.
    fn obs_take_lines(inner: &RecorderInner) -> Vec<String> {
        std::mem::take(&mut inner.obsbuf.lock().pending)
    }

    /// Snapshot the aggregates under the lock; memory-only.
    fn obs_snapshot(inner: &RecorderInner) -> (Vec<PhaseAgg>, u64) {
        let buf = inner.obsbuf.lock();
        (buf.phases.to_vec(), buf.dropped)
    }

    /// Append buffered events to the trace file. The buffer is drained
    /// under the lock first; the file write runs with no lock held.
    pub fn flush_events(&self) -> Result<()> {
        let Some(inner) = self.inner.as_deref() else { return Ok(()) };
        let lines = Self::obs_take_lines(inner);
        if lines.is_empty() {
            return Ok(());
        }
        crate::util::ordwitness::assert_lock_free("flushing obs trace events");
        append_jsonl(&inner.trace_path, &lines)
    }

    /// Flush remaining events and write the `run.rank<N>.json`
    /// manifest. Returns the manifest path, or `None` when disabled.
    pub fn finish_run(&self, info: &RunInfo) -> Result<Option<PathBuf>> {
        let Some(inner) = self.inner.as_deref() else { return Ok(None) };
        self.flush_events()?;
        let (phases, dropped) = Self::obs_snapshot(inner);
        let manifest = build_manifest(info, inner.rank, &phases, dropped);
        crate::util::ordwitness::assert_lock_free("writing the obs run manifest");
        std::fs::write(&inner.run_path, manifest.to_string_pretty())?;
        Ok(Some(inner.run_path.clone()))
    }
}

/// Everything [`Recorder::finish_run`] needs that the recorder does not
/// observe itself: run identity, traffic columns, and the controller
/// ladder transitions.
pub struct RunInfo {
    pub argv: Vec<String>,
    pub config: Json,
    pub steps: u64,
    pub wall_s: f64,
    pub stash: Option<Json>,
    pub comms: Option<Json>,
    /// `(step, spec)` pairs: the quantization ladder rung entered at
    /// each step (the first entry is the opening rung).
    pub ladder: Vec<(u64, String)>,
}

impl RunInfo {
    /// An empty shell; callers fill in what they have.
    pub fn empty() -> RunInfo {
        RunInfo {
            argv: Vec::new(),
            config: Json::Null,
            steps: 0,
            wall_s: 0.0,
            stash: None,
            comms: None,
            ladder: Vec::new(),
        }
    }
}

/// One `write_all` of all pending lines; called with no lock held.
fn append_jsonl(path: &Path, lines: &[String]) -> Result<()> {
    use std::io::Write;
    let mut buf = String::new();
    for l in lines {
        buf.push_str(l);
        buf.push('\n');
    }
    let mut f = std::fs::OpenOptions::new().append(true).create(true).open(path)?;
    f.write_all(buf.as_bytes())?;
    Ok(())
}

fn build_manifest(info: &RunInfo, rank: usize, phases: &[PhaseAgg], dropped: u64) -> Json {
    let entries = Phase::ALL.iter().filter_map(|&p| {
        let a = &phases[p as usize];
        if a.count == 0 {
            return None;
        }
        let parent = match p.parent() {
            Some(pp) => Json::str(pp.name()),
            None => Json::Null,
        };
        Some(Json::obj(vec![
            ("phase", Json::str(p.name())),
            ("parent", parent),
            ("count", Json::num(a.count as f64)),
            ("total_ns", Json::num(a.total_ns as f64)),
            ("min_ns", Json::num(a.min_ns as f64)),
            ("max_ns", Json::num(a.max_ns as f64)),
            ("p50_ns", Json::num(a.pct_ns(50.0) as f64)),
            ("p95_ns", Json::num(a.pct_ns(95.0) as f64)),
            ("bytes", Json::num(a.bytes as f64)),
        ]))
    });
    let ladder = info.ladder.iter().map(|(step, spec)| {
        Json::obj(vec![("step", Json::num(*step as f64)), ("spec", Json::str(spec))])
    });
    Json::obj(vec![
        ("schema", Json::str(&schema_str())),
        ("rank", Json::num(rank as f64)),
        ("argv", Json::arr(info.argv.iter().map(|a| Json::str(a)))),
        ("config", info.config.clone()),
        ("steps", Json::num(info.steps as f64)),
        ("wall_s", Json::num(info.wall_s)),
        ("phases", Json::arr(entries)),
        ("ladder", Json::arr(ladder)),
        ("stash", info.stash.clone().unwrap_or(Json::Null)),
        ("comms", info.comms.clone().unwrap_or(Json::Null)),
        ("events_dropped", Json::num(dropped as f64)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn tmpdir(tag: &str) -> PathBuf {
        let mut d = std::env::temp_dir();
        d.push(format!("dsq-obs-{tag}-{}", std::process::id()));
        d
    }

    #[test]
    fn disabled_recorder_is_a_no_op() {
        let r = Recorder::disabled();
        assert!(!r.is_active());
        let s = r.span_start(Phase::Dispatch);
        r.span_close(s, 0, 123);
        r.span_import(Phase::Quantize, 0, 5, 5);
        r.flush_events().unwrap();
        assert_eq!(r.finish_run(&RunInfo::empty()).unwrap(), None);
    }

    #[test]
    fn spans_aggregate_and_flush_to_jsonl() {
        let dir = tmpdir("spans");
        let r = Recorder::to_dir(&dir, 0).unwrap();
        assert!(r.is_active());
        for step in 0..3u64 {
            let s = r.span_start(Phase::Dispatch);
            std::thread::sleep(std::time::Duration::from_micros(200));
            r.span_close(s, step, 10);
        }
        r.span_import(Phase::Quantize, 2, 1_000, 7);
        r.flush_events().unwrap();
        let trace = std::fs::read_to_string(dir.join("trace.rank0.jsonl")).unwrap();
        let lines: Vec<&str> = trace.lines().collect();
        assert_eq!(lines.len(), 5, "header + 3 dispatch + 1 quantize: {trace}");
        let header = json::parse(lines[0]).unwrap();
        assert_eq!(header.get("schema").and_then(Json::as_str), Some("DSQTRCE1"));
        let ev = json::parse(lines[1]).unwrap();
        assert_eq!(ev.get("phase").and_then(Json::as_str), Some("dispatch"));
        assert!(ev.get("dur_ns").and_then(Json::as_i64).unwrap() > 0);
        let info = RunInfo { steps: 3, wall_s: 0.5, ..RunInfo::empty() };
        let path = r.finish_run(&info).unwrap().unwrap();
        let man = json::parse_file(&path).unwrap();
        assert_eq!(man.get("schema").and_then(Json::as_str), Some("DSQTRCE1"));
        let phases = man.get("phases").and_then(Json::as_arr).unwrap();
        assert_eq!(phases.len(), 2);
        assert_eq!(phases[0].get("phase").and_then(Json::as_str), Some("dispatch"));
        assert_eq!(phases[0].get("count").and_then(Json::as_i64), Some(3));
        assert_eq!(phases[0].get("bytes").and_then(Json::as_i64), Some(30));
        assert_eq!(phases[1].get("parent").and_then(Json::as_str), Some("stash_write"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn pending_buffer_is_bounded_and_drops_are_counted() {
        let dir = tmpdir("bounded");
        let r = Recorder::to_dir(&dir, 1).unwrap();
        for i in 0..(MAX_PENDING as u64 + 10) {
            r.span_import(Phase::Validate, i, 1, 0);
        }
        let info = RunInfo::empty();
        let path = r.finish_run(&info).unwrap().unwrap();
        let man = json::parse_file(&path).unwrap();
        assert_eq!(man.get("events_dropped").and_then(Json::as_i64), Some(10));
        let agg = man.path("phases/0");
        assert_eq!(
            agg.and_then(|a| a.get("count")).and_then(Json::as_i64),
            Some(MAX_PENDING as i64 + 10),
            "aggregates must see every event even past the pending cap"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn phase_parents_are_top_level() {
        for p in Phase::ALL {
            if let Some(parent) = p.parent() {
                assert_eq!(parent.parent(), None, "{} nests under a nested phase", p.name());
            }
        }
    }

    #[test]
    fn agg_percentiles_track_samples() {
        let mut a = PhaseAgg::default();
        for v in 1..=100u64 {
            a.fold(v, 0);
        }
        assert_eq!(a.count, 100);
        assert_eq!(a.min_ns, 1);
        assert_eq!(a.max_ns, 100);
        let p50 = a.pct_ns(50.0);
        assert!((45..=55).contains(&p50), "p50 {p50}");
        let p95 = a.pct_ns(95.0);
        assert!((90..=100).contains(&p95), "p95 {p95}");
    }
}
