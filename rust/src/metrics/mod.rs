//! Evaluation metrics: BLEU for the translation tables, accuracies for
//! the GLUE-style tables, and the loss tracker feeding the DSQ
//! controller's plateau detection.

pub mod bleu;
pub mod tracker;

pub use bleu::{corpus_bleu, sentence_tokens, BleuScore};
pub use tracker::LossTracker;

/// Classification accuracy in percent.
pub fn accuracy_pct(ncorrect: f64, total: f64) -> f64 {
    if total <= 0.0 {
        0.0
    } else {
        100.0 * ncorrect / total
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn accuracy_pct_basic() {
        assert_eq!(super::accuracy_pct(3.0, 4.0), 75.0);
        assert_eq!(super::accuracy_pct(0.0, 0.0), 0.0);
    }
}
