//! Training-loss tracking: history, EMA smoothing, divergence detection.
//!
//! The DSQ controller consumes *validation* losses directly; this tracker
//! watches the *training* loss stream for logging and for the failure
//! mode Table 5 reproduces (fixed-point q3=8 diverges — detected here as
//! NaN or sustained blow-up past `divergence_factor ×` the initial loss).

use crate::util::stats::Ema;

#[derive(Clone, Debug)]
pub struct LossTracker {
    history: Vec<(u64, f64)>,
    ema: Ema,
    initial: Option<f64>,
    best: f64,
    nan_seen: bool,
    /// Loss above `divergence_factor * initial` (smoothed) = diverged.
    pub divergence_factor: f64,
}

impl Default for LossTracker {
    fn default() -> Self {
        Self::new()
    }
}

impl LossTracker {
    pub fn new() -> Self {
        LossTracker {
            history: Vec::new(),
            ema: Ema::new(0.05),
            initial: None,
            best: f64::INFINITY,
            nan_seen: false,
            divergence_factor: 3.0,
        }
    }

    pub fn record(&mut self, step: u64, loss: f64) {
        if !loss.is_finite() {
            self.nan_seen = true;
        }
        if self.initial.is_none() && loss.is_finite() {
            self.initial = Some(loss);
        }
        if loss.is_finite() {
            self.ema.update(loss);
            self.best = self.best.min(loss);
        }
        self.history.push((step, loss));
    }

    pub fn smoothed(&self) -> Option<f64> {
        self.ema.get()
    }

    pub fn best(&self) -> f64 {
        self.best
    }

    pub fn last(&self) -> Option<f64> {
        self.history.last().map(|&(_, l)| l)
    }

    pub fn len(&self) -> usize {
        self.history.len()
    }

    pub fn is_empty(&self) -> bool {
        self.history.is_empty()
    }

    pub fn history(&self) -> &[(u64, f64)] {
        &self.history
    }

    /// Training failure: NaN/Inf seen, or smoothed loss blown past the
    /// divergence threshold (Table 5's "Failed").
    pub fn diverged(&self) -> bool {
        if self.nan_seen {
            return true;
        }
        match (self.initial, self.smoothed()) {
            (Some(init), Some(cur)) => cur > init * self.divergence_factor,
            _ => false,
        }
    }

    /// Mean loss over the last `n` records (for epoch summaries).
    pub fn window_mean(&self, n: usize) -> Option<f64> {
        if self.history.is_empty() {
            return None;
        }
        let tail: Vec<f64> = self
            .history
            .iter()
            .rev()
            .take(n)
            .map(|&(_, l)| l)
            .filter(|l| l.is_finite())
            .collect();
        if tail.is_empty() {
            None
        } else {
            Some(tail.iter().sum::<f64>() / tail.len() as f64)
        }
    }

    /// Dump the loss curve as `step\tloss` lines (EXPERIMENTS.md logs).
    pub fn curve_tsv(&self) -> String {
        let mut s = String::from("step\tloss\n");
        for &(step, loss) in &self.history {
            s.push_str(&format!("{step}\t{loss:.6}\n"));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summaries() {
        let mut t = LossTracker::new();
        assert!(t.is_empty());
        for i in 0..10 {
            t.record(i, 10.0 - i as f64);
        }
        assert_eq!(t.len(), 10);
        assert_eq!(t.best(), 1.0);
        assert_eq!(t.last(), Some(1.0));
        assert!(t.smoothed().unwrap() < 10.0);
        assert_eq!(t.window_mean(2), Some(1.5));
        assert!(!t.diverged());
    }

    #[test]
    fn nan_marks_divergence() {
        let mut t = LossTracker::new();
        t.record(0, 5.0);
        t.record(1, f64::NAN);
        assert!(t.diverged());
    }

    #[test]
    fn blowup_marks_divergence() {
        let mut t = LossTracker::new();
        t.record(0, 2.0);
        for i in 1..200 {
            t.record(i, 50.0);
        }
        assert!(t.diverged());
    }

    #[test]
    fn healthy_run_not_diverged() {
        let mut t = LossTracker::new();
        for i in 0..100 {
            t.record(i, 4.0 - (i as f64) * 0.01);
        }
        assert!(!t.diverged());
    }

    #[test]
    fn curve_tsv_format() {
        let mut t = LossTracker::new();
        t.record(1, 2.5);
        let tsv = t.curve_tsv();
        assert!(tsv.starts_with("step\tloss\n"));
        assert!(tsv.contains("1\t2.5"));
    }
}
