//! Corpus BLEU (Papineni et al. 2002) over token-id sequences.
//!
//! Standard BLEU-4: geometric mean of clipped n-gram precisions (n ≤ 4)
//! × brevity penalty, accumulated at corpus level. Precision smoothing
//! follows the common "+1 on higher orders when a count is zero"
//! (Lin & Och smoothing-1-like) so short synthetic sentences don't
//! zero the score. Token sequences stop at the first EOS/PAD, matching
//! how the decode artifact emits hypotheses.

use std::collections::HashMap;

use crate::data::{EOS, PAD};

/// Corpus BLEU result.
#[derive(Clone, Debug)]
pub struct BleuScore {
    /// BLEU-4 in percent (0..100).
    pub bleu: f64,
    /// Per-order clipped precisions.
    pub precisions: [f64; 4],
    pub brevity_penalty: f64,
    pub hyp_len: usize,
    pub ref_len: usize,
}

/// Cut a raw decode row at BOS prefix / first EOS or PAD.
pub fn sentence_tokens(row: &[i32]) -> Vec<i32> {
    let start = usize::from(row.first() == Some(&crate::data::BOS));
    row[start..]
        .iter()
        .take_while(|&&t| t != EOS && t != PAD)
        .copied()
        .collect()
}

fn ngram_counts(tokens: &[i32], n: usize) -> HashMap<&[i32], usize> {
    let mut m: HashMap<&[i32], usize> = HashMap::new();
    if tokens.len() >= n {
        for w in tokens.windows(n) {
            *m.entry(w).or_insert(0) += 1;
        }
    }
    m
}

/// Corpus BLEU over (hypothesis, reference) pairs.
pub fn corpus_bleu(pairs: &[(Vec<i32>, Vec<i32>)]) -> BleuScore {
    let mut matches = [0usize; 4];
    let mut totals = [0usize; 4];
    let mut hyp_len = 0usize;
    let mut ref_len = 0usize;
    for (hyp, reference) in pairs {
        hyp_len += hyp.len();
        ref_len += reference.len();
        for n in 1..=4 {
            let h = ngram_counts(hyp, n);
            let r = ngram_counts(reference, n);
            for (gram, &hc) in &h {
                let rc = r.get(gram).copied().unwrap_or(0);
                matches[n - 1] += hc.min(rc);
            }
            totals[n - 1] += hyp.len().saturating_sub(n - 1);
        }
    }

    let mut precisions = [0f64; 4];
    let mut log_sum = 0f64;
    for n in 0..4 {
        // Smoothing: +1 on HIGHER orders (n >= 2) with no matches; a
        // zero unigram precision legitimately zeroes the score.
        let (num, den) = if totals[n] == 0 {
            (0.0, 1.0)
        } else if matches[n] == 0 && n > 0 {
            (1.0, totals[n] as f64 + 1.0)
        } else {
            (matches[n] as f64, totals[n] as f64)
        };
        precisions[n] = num / den;
        log_sum += if precisions[n] > 0.0 { precisions[n].ln() } else { f64::NEG_INFINITY };
    }

    let bp = if hyp_len == 0 {
        0.0
    } else if hyp_len >= ref_len {
        1.0
    } else {
        (1.0 - ref_len as f64 / hyp_len as f64).exp()
    };
    let bleu = if log_sum.is_finite() { 100.0 * bp * (log_sum / 4.0).exp() } else { 0.0 };
    BleuScore { bleu, precisions, brevity_penalty: bp, hyp_len, ref_len }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_match_is_100() {
        let r = vec![4, 5, 6, 7, 8, 9];
        let s = corpus_bleu(&[(r.clone(), r)]);
        assert!((s.bleu - 100.0).abs() < 1e-9, "{}", s.bleu);
        assert_eq!(s.brevity_penalty, 1.0);
    }

    #[test]
    fn disjoint_is_zero_ish() {
        let s = corpus_bleu(&[(vec![4, 5, 6, 7], vec![8, 9, 10, 11])]);
        assert!(s.bleu < 5.0, "{}", s.bleu);
    }

    #[test]
    fn known_value_half_overlap() {
        // hyp: "a b c d", ref: "a b e f" -> p1 = 2/4, p2 = 1/3 (only
        // "a b" matches), p3 = 0/2 (smoothed 1/3), p4 = 0/1 (smoothed 1/2).
        let s = corpus_bleu(&[(vec![1, 2, 3, 4], vec![1, 2, 5, 6])]);
        assert!((s.precisions[0] - 0.5).abs() < 1e-12);
        assert!((s.precisions[1] - 1.0 / 3.0).abs() < 1e-12);
        let expected = 100.0 * (0.5f64.ln() / 4.0 + (1.0 / 3.0f64).ln() / 4.0
            + (1.0 / 3.0f64).ln() / 4.0 + 0.5f64.ln() / 4.0)
            .exp();
        assert!((s.bleu - expected).abs() < 1e-9, "{} vs {expected}", s.bleu);
    }

    #[test]
    fn brevity_penalty_punishes_short_hyps() {
        let reference: Vec<i32> = (4..24).collect();
        let short: Vec<i32> = (4..14).collect(); // 10 vs 20 tokens
        let s = corpus_bleu(&[(short, reference.clone())]);
        assert!((s.brevity_penalty - (1.0f64 - 2.0).exp()).abs() < 1e-12);
        let full = corpus_bleu(&[(reference.clone(), reference)]);
        assert!(s.bleu < full.bleu);
    }

    #[test]
    fn clipping_prevents_repeated_unigram_gaming() {
        // "the the the the" vs "the cat": clipped p1 = 1/4.
        let s = corpus_bleu(&[(vec![7, 7, 7, 7], vec![7, 8])]);
        assert!((s.precisions[0] - 0.25).abs() < 1e-12);
    }

    #[test]
    fn corpus_level_accumulation() {
        // Two sentences, one perfect, one disjoint: corpus BLEU must be
        // far below 50 (geometric-mean behavior, not averaging).
        let a = (vec![4, 5, 6, 7], vec![4, 5, 6, 7]);
        let b = (vec![8, 9, 10, 11], vec![12, 13, 14, 15]);
        let s = corpus_bleu(&[a, b]);
        assert!(s.bleu > 10.0 && s.bleu < 80.0, "{}", s.bleu);
    }

    #[test]
    fn sentence_tokens_strips_bos_eos_pad() {
        assert_eq!(sentence_tokens(&[1, 5, 6, 2, 0, 0]), vec![5, 6]);
        assert_eq!(sentence_tokens(&[5, 6, 0, 7]), vec![5, 6]);
        assert_eq!(sentence_tokens(&[2, 5]), Vec::<i32>::new());
        assert_eq!(sentence_tokens(&[1]), Vec::<i32>::new());
    }

    #[test]
    fn empty_corpus_is_zero() {
        let s = corpus_bleu(&[]);
        assert_eq!(s.bleu, 0.0);
    }

    #[test]
    fn range_property() {
        use crate::util::prop::Prop;
        Prop::new("BLEU in [0, 100]").cases(60).run(
            |rng, size| {
                let len = 1 + rng.below(size.max(2)) as usize;
                let hyp: Vec<i32> = (0..len).map(|_| rng.range(4, 20) as i32).collect();
                let rlen = 1 + rng.below(size.max(2)) as usize;
                let reference: Vec<i32> = (0..rlen).map(|_| rng.range(4, 20) as i32).collect();
                (hyp, reference)
            },
            |(h, r)| {
                let s = corpus_bleu(&[(h.clone(), r.clone())]);
                if (0.0..=100.0 + 1e-9).contains(&s.bleu) {
                    Ok(())
                } else {
                    Err(format!("bleu {}", s.bleu))
                }
            },
        );
    }
}
