//! Binary checkpoint formats (no serde available; simple, versioned,
//! length-prefixed layouts):
//!
//! **v1 — dense f32** (`DSQCKPT1`, written when every tensor is dense):
//!
//! ```text
//! magic   b"DSQCKPT1"
//! u64     adam step
//! u32     tensor-group count (always 3: params, m, v)
//! per group:
//!   u32   tensor count
//!   per tensor:
//!     u32       name length, then name bytes (UTF-8)
//!     u32       ndims, then u64 dims...
//!     f32[...]  row-major data (little-endian)
//! ```
//!
//! **v2 — packed** (`DSQCKPT2`, written when any tensor is packed): the
//! same framing, but each tensor is a self-describing
//! [`PackedTensor`] record (versioned header + sub-byte payload; layout
//! pinned in `quant/packed.rs`). Dense f32 tensors in a mixed state are
//! written as fp32 packed records (same bytes as v1 data). A bfp4
//! checkpoint is ~4.5 bits/element — ~0.14x its fp32 equivalent on disk.
//!
//! `load_checkpoint` sniffs the magic and reads either version; v2
//! tensors stay packed in memory (decoded lazily at the PJRT boundary),
//! so load-then-save reproduces the file bit-for-bit.
//!
//! **Trailers** (optional, both versions): after the tensor groups a
//! checkpoint may carry self-describing trailer records, each led by an
//! 8-byte magic, in any order (at most one of each):
//!
//! * `DSQSCHD1` — `u32 level, u32 stale, u32 observed, f64 best_loss` —
//!   the resumable [`ScheduleState`] of the precision controller. A
//!   resumed run restores it so the DSQ ladder continues where it
//!   stopped instead of silently restarting at `[2,2,2,16]`.
//! * `DSQPOSN1` — `u64 epoch, u64 batch` — the batch-stream
//!   [`ResumePosition`]: the 0-based epoch index and the offset of the
//!   *next unconsumed batch* within that epoch at save time. Crash
//!   salvage resumes mid-epoch from here instead of re-drawing the
//!   epoch stream and silently replaying already-seen batches.
//!
//! Files without a given trailer (all pre-trailer checkpoints, runs
//! under stateless schedules, end-of-run saves) load that slot as
//! `None`.
//!
//! Checkpoints are validated against the artifact manifest on load, so a
//! checkpoint from a different model config fails loudly instead of
//! producing garbage.

use std::io::{Read, Write};
use std::path::Path;

use crate::model::ModelState;
use crate::quant::{stash_stream, FormatSpec, PackedTensor};
use crate::runtime::{HostTensor, ModelManifest, TensorData};
use crate::schedule::ScheduleState;
use crate::{Error, Result};

const MAGIC: &[u8; 8] = b"DSQCKPT1";
const MAGIC_V2: &[u8; 8] = b"DSQCKPT2";
/// Optional schedule-state trailer magic (after the tensor groups).
const SCHED_MAGIC: &[u8; 8] = b"DSQSCHD1";
/// Optional batch-stream position trailer magic.
const POSN_MAGIC: &[u8; 8] = b"DSQPOSN1";

/// Where in the sharded batch stream a mid-run checkpoint was taken:
/// the first batch a resumed run should consume. `epoch` is 0-based;
/// `batch` is the offset within that epoch's stream (in *global* batch
/// indices, before any replica sharding).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ResumePosition {
    pub epoch: u64,
    pub batch: u64,
}

/// A loaded checkpoint (pre-validation).
#[derive(Debug)]
pub struct Checkpoint {
    pub state: ModelState,
    pub names: Vec<String>,
}

fn write_u32(w: &mut impl Write, v: u32) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn write_u64(w: &mut impl Write, v: u64) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn write_name(w: &mut impl Write, name: &str) -> Result<()> {
    write_u32(w, name.len() as u32)?;
    w.write_all(name.as_bytes())?;
    Ok(())
}

fn read_name(r: &mut impl Read) -> Result<String> {
    let name_len = read_u32(r)? as usize;
    if name_len > 4096 {
        return Err(Error::Manifest(format!("checkpoint name length {name_len} implausible")));
    }
    let mut name_bytes = vec![0u8; name_len];
    r.read_exact(&mut name_bytes)?;
    String::from_utf8(name_bytes).map_err(|_| Error::Manifest("checkpoint name not UTF-8".into()))
}

fn write_tensor(w: &mut impl Write, name: &str, t: &HostTensor) -> Result<()> {
    write_name(w, name)?;
    write_u32(w, t.shape.len() as u32)?;
    for &d in &t.shape {
        write_u64(w, d as u64)?;
    }
    let data = t.as_f32()?;
    // Bulk little-endian write.
    let mut bytes = Vec::with_capacity(data.len() * 4);
    for &x in data {
        bytes.extend_from_slice(&x.to_le_bytes());
    }
    w.write_all(&bytes)?;
    Ok(())
}

fn read_tensor(r: &mut impl Read) -> Result<(String, HostTensor)> {
    let name = read_name(r)?;
    let ndims = read_u32(r)? as usize;
    if ndims > 16 {
        return Err(Error::Manifest(format!("checkpoint rank {ndims} implausible")));
    }
    let mut shape = Vec::with_capacity(ndims);
    for _ in 0..ndims {
        shape.push(read_u64(r)? as usize);
    }
    let numel: usize = shape.iter().product();
    let mut bytes = vec![0u8; numel * 4];
    r.read_exact(&mut bytes)?;
    let data: Vec<f32> =
        bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect();
    Ok((name, HostTensor::f32(shape, data)))
}

/// How tensors are framed on disk.
#[derive(Clone, Copy)]
enum TensorFraming<'a> {
    /// v1 dense f32 records.
    Dense,
    /// v2 packed records. `Some(spec)` additionally packs dense tensors
    /// into `spec` on the fly — one tensor at a time, so a packed save
    /// of a dense state never holds a second copy of the whole state.
    Packed(Option<&'a FormatSpec>),
}

/// v2 tensor record: name + self-describing packed record.
/// Already-packed tensors (in the target format, when one is given)
/// write their payload untouched — bit-identity across save/load/save;
/// dense tensors pack into `spec` (or ride as raw fp32 records).
/// *Spilled* tensors (stash-store disk tier) stream their record bytes
/// straight from the spill segment — the segment stores the exact
/// [`crate::quant::PackedTensor::write_into`] record, so a checkpoint
/// of a spilled state is byte-identical to one of the resident state
/// without rehydrating any payload into DRAM.
fn write_tensor_v2(
    w: &mut impl Write,
    name: &str,
    t: &HostTensor,
    spec: Option<&FormatSpec>,
    step: u64,
    stream: u64,
) -> Result<()> {
    write_name(w, name)?;
    match (&t.data, spec) {
        (TensorData::Packed(p), None) => p.write_into(w),
        (TensorData::Packed(p), Some(s)) if p.spec() == *s => p.write_into(w),
        (TensorData::Spilled(h), None) => {
            w.write_all(&h.read_record()?)?;
            Ok(())
        }
        (TensorData::Spilled(h), Some(s)) if h.spec == *s => {
            w.write_all(&h.read_record()?)?;
            Ok(())
        }
        (TensorData::Spilled(_), Some(_)) => Err(Error::Config(
            "cannot repack a spilled tensor into another format: fetch it first".into(),
        )),
        _ => {
            let s = spec.unwrap_or(&FormatSpec::Fp32);
            match t.pack_stream(s, step, stream)?.data {
                TensorData::Packed(p) => p.write_into(w),
                _ => unreachable!("pack_stream() always yields packed data"),
            }
        }
    }
}

fn read_tensor_v2(r: &mut impl Read) -> Result<(String, HostTensor)> {
    let name = read_name(r)?;
    let packed = PackedTensor::read_from(r)?;
    Ok((name, HostTensor::packed(packed)))
}

fn write_schedule_trailer(w: &mut impl Write, s: &ScheduleState) -> Result<()> {
    w.write_all(SCHED_MAGIC)?;
    write_u32(w, s.level)?;
    write_u32(w, s.stale)?;
    write_u32(w, s.observed)?;
    write_u64(w, s.best_loss.to_bits())?;
    Ok(())
}

fn write_position_trailer(w: &mut impl Write, p: &ResumePosition) -> Result<()> {
    w.write_all(POSN_MAGIC)?;
    write_u64(w, p.epoch)?;
    write_u64(w, p.batch)?;
    Ok(())
}

/// Read one trailer magic, or `None` on clean EOF right after the
/// tensor groups / previous trailer. A *truncated* magic is corruption
/// and fails loudly.
fn read_trailer_magic(r: &mut impl Read) -> Result<Option<[u8; 8]>> {
    let mut magic = [0u8; 8];
    let mut got = 0;
    while got < magic.len() {
        match r.read(&mut magic[got..]) {
            Ok(0) => break,
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e.into()),
        }
    }
    if got == 0 {
        return Ok(None);
    }
    if got < magic.len() {
        return Err(Error::Manifest("truncated checkpoint trailer".into()));
    }
    Ok(Some(magic))
}

/// Read the optional trailer records (any order, at most one of each)
/// until clean EOF. Unknown magics — including any pre-trailer garbage
/// — fail loudly instead of silently resuming with fresh state.
fn read_trailers(
    r: &mut impl Read,
) -> Result<(Option<ScheduleState>, Option<ResumePosition>)> {
    let mut schedule = None;
    let mut position = None;
    while let Some(magic) = read_trailer_magic(r)? {
        match &magic {
            m if m == SCHED_MAGIC => {
                if schedule.is_some() {
                    return Err(Error::Manifest("duplicate schedule trailer".into()));
                }
                let level = read_u32(r)?;
                let stale = read_u32(r)?;
                let observed = read_u32(r)?;
                let best_loss = f64::from_bits(read_u64(r)?);
                schedule = Some(ScheduleState { level, stale, observed, best_loss });
            }
            m if m == POSN_MAGIC => {
                if position.is_some() {
                    return Err(Error::Manifest("duplicate position trailer".into()));
                }
                position = Some(ResumePosition { epoch: read_u64(r)?, batch: read_u64(r)? });
            }
            _ => return Err(Error::Manifest("unrecognized checkpoint trailer".into())),
        }
    }
    Ok((schedule, position))
}

fn save_with(
    path: &Path,
    state: &ModelState,
    mm: &ModelManifest,
    framing: TensorFraming<'_>,
    schedule: Option<&ScheduleState>,
    position: Option<&ResumePosition>,
) -> Result<()> {
    ModelState::validate_against(&state.params, mm)?;
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    // Torn-write protection: the full file is staged next to the target
    // (same filesystem, so the rename is atomic), fsync'd, then
    // published. A crash mid-save — or mid-spill while a stash store is
    // streaming records into the save — leaves at worst a stale `.tmp`
    // beside an intact previous checkpoint, never a truncated
    // `DSQCKPT2`. The suffix is appended (not substituted) so two
    // checkpoints differing only in extension cannot share a stage file.
    let tmp = match path.file_name() {
        Some(name) => path.with_file_name(format!("{}.tmp", name.to_string_lossy())),
        None => path.with_extension("tmp"),
    };
    {
        let mut w = std::io::BufWriter::new(std::fs::File::create(&tmp)?);
        w.write_all(match framing {
            TensorFraming::Dense => MAGIC,
            TensorFraming::Packed(_) => MAGIC_V2,
        })?;
        write_u64(&mut w, state.step)?;
        write_u32(&mut w, 3)?;
        for (g, group) in [&state.params, &state.m, &state.v].into_iter().enumerate() {
            write_u32(&mut w, group.len() as u32)?;
            for (i, (t, spec)) in group.iter().zip(&mm.params).enumerate() {
                match framing {
                    TensorFraming::Dense => write_tensor(&mut w, &spec.name, t)?,
                    // Same (step, stream) scheme as ModelState::pack_state,
                    // so on-the-fly packing writes the identical file.
                    TensorFraming::Packed(ps) => write_tensor_v2(
                        &mut w,
                        &spec.name,
                        t,
                        ps,
                        state.step,
                        stash_stream(g, i),
                    )?,
                }
            }
        }
        if let Some(s) = schedule {
            write_schedule_trailer(&mut w, s)?;
        }
        if let Some(p) = position {
            write_position_trailer(&mut w, p)?;
        }
        w.flush()?;
        // Durability before visibility: the bytes must be on disk
        // before the rename makes them the checkpoint.
        w.get_ref().sync_all()?;
    }
    std::fs::rename(&tmp, path)?; // atomic publish
    Ok(())
}

/// Save a model state (names come from the manifest order). Dense states
/// write the v1 format; states holding packed tensors write v2, keeping
/// each tensor's exact payload (so save(load(p)) == p byte-for-byte).
pub fn save_checkpoint(path: &Path, state: &ModelState, mm: &ModelManifest) -> Result<()> {
    save_checkpoint_full(path, state, mm, None)
}

/// [`save_checkpoint`] plus an optional resumable [`ScheduleState`]
/// trailer (the Session engine passes the schedule's snapshot here so a
/// mid-ladder checkpoint resumes at the saved controller level).
pub fn save_checkpoint_full(
    path: &Path,
    state: &ModelState,
    mm: &ModelManifest,
    schedule: Option<&ScheduleState>,
) -> Result<()> {
    save_checkpoint_positioned(path, state, mm, schedule, None)
}

/// [`save_checkpoint_full`] plus an optional batch-stream
/// [`ResumePosition`] trailer. Mid-run (crash-salvage) saves pass the
/// next-unconsumed-batch position so a resumed run continues mid-epoch
/// instead of replaying the epoch from the top; end-of-run saves pass
/// `None` (there is nothing left to resume into).
pub fn save_checkpoint_positioned(
    path: &Path,
    state: &ModelState,
    mm: &ModelManifest,
    schedule: Option<&ScheduleState>,
    position: Option<&ResumePosition>,
) -> Result<()> {
    let framing =
        if state.is_packed() { TensorFraming::Packed(None) } else { TensorFraming::Dense };
    save_with(path, state, mm, framing, schedule, position)
}

/// Save with every tensor packed into `spec` (quantizing dense tensors
/// on the fly, one at a time; tensors already packed in `spec` keep
/// their payload). This is how a low-bit checkpoint shrinks on disk
/// without the trainer itself holding packed state — and without ever
/// materializing a second copy of it.
pub fn save_checkpoint_packed(
    path: &Path,
    state: &ModelState,
    mm: &ModelManifest,
    spec: &FormatSpec,
) -> Result<()> {
    save_with(path, state, mm, TensorFraming::Packed(Some(spec)), None, None)
}

/// Load and validate a checkpoint against the manifest, dropping any
/// schedule trailer. v2 tensors stay packed in memory; call
/// [`ModelState::unpack_state`] to force dense.
pub fn load_checkpoint(path: &Path, mm: &ModelManifest) -> Result<ModelState> {
    load_checkpoint_full(path, mm).map(|(state, _)| state)
}

/// Load a checkpoint plus its resumable [`ScheduleState`] (if the file
/// carries the trailer; pre-trailer files and stateless-schedule runs
/// yield `None`).
pub fn load_checkpoint_full(
    path: &Path,
    mm: &ModelManifest,
) -> Result<(ModelState, Option<ScheduleState>)> {
    load_checkpoint_positioned(path, mm).map(|(state, sched, _)| (state, sched))
}

/// Load a checkpoint plus both optional trailers: the resumable
/// [`ScheduleState`] and the batch-stream [`ResumePosition`] (each
/// `None` when the file does not carry it).
pub fn load_checkpoint_positioned(
    path: &Path,
    mm: &ModelManifest,
) -> Result<(ModelState, Option<ScheduleState>, Option<ResumePosition>)> {
    let mut r = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    let packed = match &magic {
        m if m == MAGIC => false,
        m if m == MAGIC_V2 => true,
        _ => return Err(Error::Manifest(format!("{path:?}: not a DSQ checkpoint"))),
    };
    let step = read_u64(&mut r)?;
    let groups = read_u32(&mut r)?;
    if groups != 3 {
        return Err(Error::Manifest(format!("checkpoint has {groups} groups, expected 3")));
    }
    let mut all: Vec<Vec<HostTensor>> = Vec::with_capacity(3);
    for _ in 0..3 {
        let count = read_u32(&mut r)? as usize;
        if count != mm.params.len() {
            return Err(Error::Manifest(format!(
                "checkpoint group has {count} tensors, manifest has {}",
                mm.params.len()
            )));
        }
        let mut group = Vec::with_capacity(count);
        for spec in &mm.params {
            let (name, t) =
                if packed { read_tensor_v2(&mut r)? } else { read_tensor(&mut r)? };
            if name != spec.name {
                return Err(Error::Manifest(format!(
                    "checkpoint tensor '{name}' where manifest expects '{}' \
                     (different model config?)",
                    spec.name
                )));
            }
            if t.shape != spec.shape {
                return Err(Error::Manifest(format!(
                    "checkpoint '{name}': shape {:?} != manifest {:?}",
                    t.shape, spec.shape
                )));
            }
            group.push(t);
        }
        all.push(group);
    }
    let (schedule, position) = read_trailers(&mut r)?;
    let v = all.pop().unwrap();
    let m = all.pop().unwrap();
    let params = all.pop().unwrap();
    Ok((ModelState { params, m, v, step }, schedule, position))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ParamSpec;

    fn mm() -> ModelManifest {
        ModelManifest {
            config: Default::default(),
            params: vec![
                ParamSpec { name: "a.w".into(), shape: vec![2, 3] },
                ParamSpec { name: "b.b".into(), shape: vec![4] },
            ],
            artifacts: Default::default(),
        }
    }

    fn state() -> ModelState {
        let p = vec![
            HostTensor::f32(vec![2, 3], (0..6).map(|x| x as f32).collect()),
            HostTensor::f32(vec![4], vec![-1.0, 0.5, 2.0, 3.5]),
        ];
        let m = vec![HostTensor::zeros(&[2, 3]), HostTensor::zeros(&[4])];
        ModelState { params: p, m: m.clone(), v: m, step: 42 }
    }

    fn tmpfile(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("dsq-ckpt-{}-{name}", std::process::id()))
    }

    #[test]
    fn roundtrip() {
        let path = tmpfile("roundtrip.bin");
        let st = state();
        save_checkpoint(&path, &st, &mm()).unwrap();
        let back = load_checkpoint(&path, &mm()).unwrap();
        assert_eq!(back.step, 42);
        assert_eq!(back.params[0], st.params[0]);
        assert_eq!(back.params[1], st.params[1]);
        assert_eq!(back.v[1], st.v[1]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn dense_state_still_writes_v1_magic() {
        // Bit-compat: a dense save must remain readable by (and byte-
        // compatible with) the pre-packed format.
        let path = tmpfile("v1magic.bin");
        save_checkpoint(&path, &state(), &mm()).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(&bytes[..8], b"DSQCKPT1");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn packed_roundtrip_stays_packed() {
        let path = tmpfile("packed-roundtrip.bin");
        let spec = FormatSpec::bfp(4);
        let mut st = state();
        st.pack_state(&spec).unwrap();
        save_checkpoint(&path, &st, &mm()).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(&bytes[..8], b"DSQCKPT2");
        let back = load_checkpoint(&path, &mm()).unwrap();
        assert!(back.is_packed());
        assert_eq!(back.step, 42);
        assert_eq!(back.params[0], st.params[0]);
        assert_eq!(back.m[1], st.m[1]);
        // Saving the loaded state reproduces the file byte-for-byte.
        let path2 = tmpfile("packed-roundtrip2.bin");
        save_checkpoint(&path2, &back, &mm()).unwrap();
        assert_eq!(bytes, std::fs::read(&path2).unwrap());
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&path2).ok();
    }

    #[test]
    fn save_checkpoint_packed_quantizes_dense_state() {
        let path = tmpfile("packed-fromdense.bin");
        let spec = FormatSpec::fixed(8);
        let st = state();
        save_checkpoint_packed(&path, &st, &mm(), &spec).unwrap();
        let back = load_checkpoint(&path, &mm()).unwrap();
        let dense = {
            let mut b = back.clone();
            b.unpack_state();
            b
        };
        let want = crate::quant::fixed_quantize(st.params[1].as_f32().unwrap(), 8.0);
        assert_eq!(dense.params[1].as_f32().unwrap(), want.as_slice());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn schedule_trailer_roundtrips() {
        let path = tmpfile("sched-trailer.bin");
        let sched = ScheduleState { level: 3, stale: 1, observed: 9, best_loss: 4.625 };
        save_checkpoint_full(&path, &state(), &mm(), Some(&sched)).unwrap();
        let (back, got) = load_checkpoint_full(&path, &mm()).unwrap();
        assert_eq!(back.step, 42);
        assert_eq!(got, Some(sched));
        // The compat loader still reads the tensors and drops the trailer.
        assert_eq!(load_checkpoint(&path, &mm()).unwrap().params[0], state().params[0]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn schedule_trailer_preserves_infinite_best_loss() {
        // A controller that never saw a finite validation snapshots
        // best_loss = +inf; the bit-exact f64 framing keeps it.
        let path = tmpfile("sched-inf.bin");
        let sched =
            ScheduleState { level: 0, stale: 0, observed: 0, best_loss: f64::INFINITY };
        save_checkpoint_full(&path, &state(), &mm(), Some(&sched)).unwrap();
        let (_, got) = load_checkpoint_full(&path, &mm()).unwrap();
        assert_eq!(got, Some(sched));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn no_trailer_loads_as_none() {
        let path = tmpfile("sched-none.bin");
        save_checkpoint(&path, &state(), &mm()).unwrap();
        let (_, got) = load_checkpoint_full(&path, &mm()).unwrap();
        assert_eq!(got, None);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn schedule_trailer_on_packed_checkpoint() {
        let path = tmpfile("sched-packed.bin");
        let mut st = state();
        st.pack_state(&FormatSpec::bfp(4)).unwrap();
        let sched = ScheduleState { level: 2, stale: 0, observed: 4, best_loss: 1.5 };
        save_checkpoint_full(&path, &st, &mm(), Some(&sched)).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(&bytes[..8], b"DSQCKPT2");
        let (back, got) = load_checkpoint_full(&path, &mm()).unwrap();
        assert!(back.is_packed());
        assert_eq!(got, Some(sched));
        // Resaving with the restored trailer reproduces the file exactly.
        let path2 = tmpfile("sched-packed2.bin");
        save_checkpoint_full(&path2, &back, &mm(), got.as_ref()).unwrap();
        assert_eq!(bytes, std::fs::read(&path2).unwrap());
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&path2).ok();
    }

    #[test]
    fn position_trailer_roundtrips_alongside_the_schedule() {
        let path = tmpfile("posn-trailer.bin");
        let sched = ScheduleState { level: 2, stale: 0, observed: 5, best_loss: 3.25 };
        let pos = ResumePosition { epoch: 1, batch: 5 };
        save_checkpoint_positioned(&path, &state(), &mm(), Some(&sched), Some(&pos)).unwrap();
        let (back, got_sched, got_pos) = load_checkpoint_positioned(&path, &mm()).unwrap();
        assert_eq!(back.step, 42);
        assert_eq!(got_sched, Some(sched));
        assert_eq!(got_pos, Some(pos));
        // The compat loaders still read the tensors and drop trailers.
        let (_, got_sched) = load_checkpoint_full(&path, &mm()).unwrap();
        assert_eq!(got_sched, Some(sched));
        assert_eq!(load_checkpoint(&path, &mm()).unwrap().step, 42);
        // Resaving the loaded trailers reproduces the file exactly.
        let path2 = tmpfile("posn-trailer2.bin");
        save_checkpoint_positioned(&path2, &back, &mm(), got_sched.as_ref(), got_pos.as_ref())
            .unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), std::fs::read(&path2).unwrap());
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&path2).ok();
    }

    #[test]
    fn position_trailer_golden_bytes() {
        // Pin the on-disk framing: the file ends with the DSQPOSN1 magic
        // followed by little-endian u64 epoch and batch.
        let path = tmpfile("posn-golden.bin");
        let pos = ResumePosition { epoch: 3, batch: 0x0102_0304 };
        save_checkpoint_positioned(&path, &state(), &mm(), None, Some(&pos)).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let tail = &bytes[bytes.len() - 24..];
        assert_eq!(&tail[..8], b"DSQPOSN1");
        assert_eq!(&tail[8..16], &3u64.to_le_bytes());
        assert_eq!(&tail[16..24], &0x0102_0304u64.to_le_bytes());
        // Everything before the trailer is exactly the positionless file.
        let plain = tmpfile("posn-golden-plain.bin");
        save_checkpoint(&plain, &state(), &mm()).unwrap();
        assert_eq!(&bytes[..bytes.len() - 24], std::fs::read(&plain).unwrap().as_slice());
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&plain).ok();
    }

    #[test]
    fn duplicate_or_truncated_position_trailer_is_rejected() {
        let path = tmpfile("posn-dup.bin");
        let pos = ResumePosition { epoch: 0, batch: 7 };
        save_checkpoint_positioned(&path, &state(), &mm(), None, Some(&pos)).unwrap();
        let good = std::fs::read(&path).unwrap();
        // A second DSQPOSN1 record is corruption, not a silent override.
        let mut bytes = good.clone();
        bytes.extend_from_slice(&good[good.len() - 24..]);
        std::fs::write(&path, &bytes).unwrap();
        assert!(load_checkpoint_positioned(&path, &mm()).is_err());
        // A truncated position payload fails loudly too.
        std::fs::write(&path, &good[..good.len() - 9]).unwrap();
        assert!(load_checkpoint_positioned(&path, &mm()).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn garbage_trailer_is_rejected() {
        let path = tmpfile("sched-garbage.bin");
        save_checkpoint(&path, &state(), &mm()).unwrap();
        let clean = std::fs::read(&path).unwrap();
        // Wrong magic.
        let mut bytes = clean.clone();
        bytes.extend_from_slice(b"NOTSCHEDxxxxxxxxxxxx");
        std::fs::write(&path, &bytes).unwrap();
        assert!(load_checkpoint_full(&path, &mm()).is_err());
        // Truncated magic (1-7 trailing bytes) must also fail loudly,
        // not silently resume with a fresh schedule.
        let mut bytes = clean;
        bytes.extend_from_slice(&b"DSQSCHD1"[..3]);
        std::fs::write(&path, &bytes).unwrap();
        assert!(load_checkpoint_full(&path, &mm()).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn torn_write_cannot_corrupt_the_published_checkpoint() {
        // Regression for the crash-mid-save story: the stage file is
        // `<full name>.tmp` (appended, not substituted), garbage left by
        // an interrupted save never shadows the real file, and a
        // truncated checkpoint fails loudly instead of loading partial
        // state.
        let path = tmpfile("torn.bin");
        let mut st = state();
        st.pack_state(&FormatSpec::bfp(4)).unwrap();
        save_checkpoint(&path, &st, &mm()).unwrap();
        let good = std::fs::read(&path).unwrap();

        // The stage path appends ".tmp" to the whole file name.
        let stage = path.with_file_name(format!(
            "{}.tmp",
            path.file_name().unwrap().to_string_lossy()
        ));
        assert!(!stage.exists(), "a completed save leaves no stage file");

        // Simulate a crash mid-save: a half-written stage file appears.
        std::fs::write(&stage, &good[..good.len() / 2]).unwrap();
        // The published checkpoint is untouched and still loads.
        let back = load_checkpoint(&path, &mm()).unwrap();
        assert_eq!(back.step, 42);
        // The next save overwrites the stale stage and republishes.
        save_checkpoint(&path, &st, &mm()).unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), good, "resave is bit-identical");
        assert!(!stage.exists());

        // A genuinely torn file (truncated DSQCKPT2) must fail loudly,
        // at every truncation point — header, mid-record, mid-trailer.
        for cut in [4, 9, good.len() / 3, good.len() - 1] {
            std::fs::write(&path, &good[..cut]).unwrap();
            assert!(
                load_checkpoint(&path, &mm()).is_err(),
                "truncation at {cut}/{} bytes must not load",
                good.len()
            );
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn spilled_state_checkpoint_streams_records_bit_identically() {
        use crate::stash::{StashBudget, StashStore};
        // A fully spilled state must write the same checkpoint bytes as
        // the resident packed state — records stream from the segment
        // file without rehydration.
        let spec = FormatSpec::bfp(4);
        let mut resident = state();
        resident.pack_state(&spec).unwrap();
        let p1 = tmpfile("spill-resident.bin");
        save_checkpoint(&p1, &resident, &mm()).unwrap();

        let mut spilled = state();
        let mut store = StashStore::ephemeral(spec, StashBudget::Bytes(0)).unwrap();
        store.stash_state(&mut spilled).unwrap();
        assert!(spilled.is_spilled() && spilled.is_packed());
        assert_eq!(
            spilled.storage_bytes(),
            0,
            "a fully spilled state occupies no DRAM"
        );
        let p2 = tmpfile("spill-streamed.bin");
        save_checkpoint(&p2, &spilled, &mm()).unwrap();
        assert_eq!(
            std::fs::read(&p1).unwrap(),
            std::fs::read(&p2).unwrap(),
            "streamed and resident checkpoints must be byte-identical"
        );
        // And the streamed checkpoint loads back to the resident form.
        let back = load_checkpoint(&p2, &mm()).unwrap();
        assert_eq!(back.params, resident.params);
        std::fs::remove_file(&p1).ok();
        std::fs::remove_file(&p2).ok();
    }

    #[test]
    fn rejects_wrong_manifest() {
        let path = tmpfile("wrongman.bin");
        save_checkpoint(&path, &state(), &mm()).unwrap();
        let mut other = mm();
        other.params[0].shape = vec![3, 2];
        assert!(load_checkpoint(&path, &other).is_err());
        other.params[0] = ParamSpec { name: "z.w".into(), shape: vec![2, 3] };
        assert!(load_checkpoint(&path, &other).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_wrong_manifest_packed() {
        let path = tmpfile("wrongman2.bin");
        save_checkpoint_packed(&path, &state(), &mm(), &FormatSpec::bfp(4)).unwrap();
        let mut other = mm();
        other.params[0].shape = vec![3, 2];
        assert!(load_checkpoint(&path, &other).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_garbage_file() {
        let path = tmpfile("garbage.bin");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(load_checkpoint(&path, &mm()).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn missing_file_errors() {
        assert!(load_checkpoint(std::path::Path::new("/nonexistent/x.bin"), &mm()).is_err());
    }
}
