//! Binary checkpoint format (no serde available; a simple, versioned,
//! length-prefixed layout):
//!
//! ```text
//! magic   b"DSQCKPT1"
//! u64     adam step
//! u32     tensor-group count (always 3: params, m, v)
//! per group:
//!   u32   tensor count
//!   per tensor:
//!     u32       name length, then name bytes (UTF-8)
//!     u32       ndims, then u64 dims...
//!     f32[...]  row-major data (little-endian)
//! ```
//!
//! Checkpoints are validated against the artifact manifest on load, so a
//! checkpoint from a different model config fails loudly instead of
//! producing garbage.

use std::io::{Read, Write};
use std::path::Path;

use crate::model::ModelState;
use crate::runtime::{HostTensor, ModelManifest};
use crate::{Error, Result};

const MAGIC: &[u8; 8] = b"DSQCKPT1";

/// A loaded checkpoint (pre-validation).
#[derive(Debug)]
pub struct Checkpoint {
    pub state: ModelState,
    pub names: Vec<String>,
}

fn write_u32(w: &mut impl Write, v: u32) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn write_u64(w: &mut impl Write, v: u64) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn write_tensor(w: &mut impl Write, name: &str, t: &HostTensor) -> Result<()> {
    write_u32(w, name.len() as u32)?;
    w.write_all(name.as_bytes())?;
    write_u32(w, t.shape.len() as u32)?;
    for &d in &t.shape {
        write_u64(w, d as u64)?;
    }
    let data = t.as_f32()?;
    // Bulk little-endian write.
    let mut bytes = Vec::with_capacity(data.len() * 4);
    for &x in data {
        bytes.extend_from_slice(&x.to_le_bytes());
    }
    w.write_all(&bytes)?;
    Ok(())
}

fn read_tensor(r: &mut impl Read) -> Result<(String, HostTensor)> {
    let name_len = read_u32(r)? as usize;
    if name_len > 4096 {
        return Err(Error::Manifest(format!("checkpoint name length {name_len} implausible")));
    }
    let mut name_bytes = vec![0u8; name_len];
    r.read_exact(&mut name_bytes)?;
    let name = String::from_utf8(name_bytes)
        .map_err(|_| Error::Manifest("checkpoint name not UTF-8".into()))?;
    let ndims = read_u32(r)? as usize;
    if ndims > 16 {
        return Err(Error::Manifest(format!("checkpoint rank {ndims} implausible")));
    }
    let mut shape = Vec::with_capacity(ndims);
    for _ in 0..ndims {
        shape.push(read_u64(r)? as usize);
    }
    let numel: usize = shape.iter().product();
    let mut bytes = vec![0u8; numel * 4];
    r.read_exact(&mut bytes)?;
    let data: Vec<f32> =
        bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect();
    Ok((name, HostTensor::f32(shape, data)))
}

/// Save a model state (names come from the manifest order).
pub fn save_checkpoint(path: &Path, state: &ModelState, mm: &ModelManifest) -> Result<()> {
    ModelState::validate_against(&state.params, mm)?;
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let tmp = path.with_extension("tmp");
    {
        let mut w = std::io::BufWriter::new(std::fs::File::create(&tmp)?);
        w.write_all(MAGIC)?;
        write_u64(&mut w, state.step)?;
        write_u32(&mut w, 3)?;
        for group in [&state.params, &state.m, &state.v] {
            write_u32(&mut w, group.len() as u32)?;
            for (t, spec) in group.iter().zip(&mm.params) {
                write_tensor(&mut w, &spec.name, t)?;
            }
        }
        w.flush()?;
    }
    std::fs::rename(&tmp, path)?; // atomic-ish publish
    Ok(())
}

/// Load and validate a checkpoint against the manifest.
pub fn load_checkpoint(path: &Path, mm: &ModelManifest) -> Result<ModelState> {
    let mut r = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(Error::Manifest(format!("{path:?}: not a DSQ checkpoint")));
    }
    let step = read_u64(&mut r)?;
    let groups = read_u32(&mut r)?;
    if groups != 3 {
        return Err(Error::Manifest(format!("checkpoint has {groups} groups, expected 3")));
    }
    let mut all: Vec<Vec<HostTensor>> = Vec::with_capacity(3);
    for _ in 0..3 {
        let count = read_u32(&mut r)? as usize;
        if count != mm.params.len() {
            return Err(Error::Manifest(format!(
                "checkpoint group has {count} tensors, manifest has {}",
                mm.params.len()
            )));
        }
        let mut group = Vec::with_capacity(count);
        for spec in &mm.params {
            let (name, t) = read_tensor(&mut r)?;
            if name != spec.name {
                return Err(Error::Manifest(format!(
                    "checkpoint tensor '{name}' where manifest expects '{}' \
                     (different model config?)",
                    spec.name
                )));
            }
            if t.shape != spec.shape {
                return Err(Error::Manifest(format!(
                    "checkpoint '{name}': shape {:?} != manifest {:?}",
                    t.shape, spec.shape
                )));
            }
            group.push(t);
        }
        all.push(group);
    }
    let v = all.pop().unwrap();
    let m = all.pop().unwrap();
    let params = all.pop().unwrap();
    Ok(ModelState { params, m, v, step })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ParamSpec;

    fn mm() -> ModelManifest {
        ModelManifest {
            config: Default::default(),
            params: vec![
                ParamSpec { name: "a.w".into(), shape: vec![2, 3] },
                ParamSpec { name: "b.b".into(), shape: vec![4] },
            ],
            artifacts: Default::default(),
        }
    }

    fn state() -> ModelState {
        let p = vec![
            HostTensor::f32(vec![2, 3], (0..6).map(|x| x as f32).collect()),
            HostTensor::f32(vec![4], vec![-1.0, 0.5, 2.0, 3.5]),
        ];
        let m = vec![HostTensor::zeros(&[2, 3]), HostTensor::zeros(&[4])];
        ModelState { params: p, m: m.clone(), v: m, step: 42 }
    }

    fn tmpfile(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("dsq-ckpt-{}-{name}", std::process::id()))
    }

    #[test]
    fn roundtrip() {
        let path = tmpfile("roundtrip.bin");
        let st = state();
        save_checkpoint(&path, &st, &mm()).unwrap();
        let back = load_checkpoint(&path, &mm()).unwrap();
        assert_eq!(back.step, 42);
        assert_eq!(back.params[0], st.params[0]);
        assert_eq!(back.params[1], st.params[1]);
        assert_eq!(back.v[1], st.v[1]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_wrong_manifest() {
        let path = tmpfile("wrongman.bin");
        save_checkpoint(&path, &state(), &mm()).unwrap();
        let mut other = mm();
        other.params[0].shape = vec![3, 2];
        assert!(load_checkpoint(&path, &other).is_err());
        other.params[0] = ParamSpec { name: "z.w".into(), shape: vec![2, 3] };
        assert!(load_checkpoint(&path, &other).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_garbage_file() {
        let path = tmpfile("garbage.bin");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(load_checkpoint(&path, &mm()).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn missing_file_errors() {
        assert!(load_checkpoint(std::path::Path::new("/nonexistent/x.bin"), &mm()).is_err());
    }
}
