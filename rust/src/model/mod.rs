//! Host-side model state: parameters + Adam moments as flat tensor
//! lists (the artifact calling convention), plus a binary checkpoint
//! format.
//!
//! State may be held *packed* ([`ModelState::pack_state`]): every tensor
//! stashed in its format's physical bit layout between steps, decoded
//! only at the PJRT boundary — the coordinator-side mirror of the
//! paper's stashing dataflow (and of Direct Quantized Training's
//! low-bit-resident weights). Packed state round-trips through v2
//! checkpoints bit-identically.

pub mod checkpoint;

pub use checkpoint::{
    load_checkpoint, load_checkpoint_full, load_checkpoint_positioned, save_checkpoint,
    save_checkpoint_full, save_checkpoint_packed, save_checkpoint_positioned, Checkpoint,
    ResumePosition,
};

use crate::quant::{stash_stream, FormatSpec};
use crate::runtime::{ArtifactManifest, HostTensor, ModelManifest, Runtime};
use crate::{Error, Result};

/// Parameters + optimizer state for one model, in manifest order.
#[derive(Clone, Debug)]
pub struct ModelState {
    pub params: Vec<HostTensor>,
    pub m: Vec<HostTensor>,
    pub v: Vec<HostTensor>,
    /// 1-based Adam step count already applied.
    pub step: u64,
}

impl ModelState {
    /// Initialize from the model's `init` artifact (seeded, on-device).
    pub fn init(rt: &Runtime, man: &ArtifactManifest, model: &str, seed: i32) -> Result<Self> {
        let exe = rt.load(&man.model_path(model, "init")?)?;
        let params = exe.run(&[HostTensor::scalar_i32(seed)])?;
        let mm = match model {
            "nmt" => &man.nmt,
            "cls" => &man.cls,
            other => return Err(Error::Config(format!("unknown model '{other}'"))),
        };
        Self::validate_against(&params, mm)?;
        // Moments inherit each parameter's dtype: for a packed state the
        // zeros are built directly in the bit layout, no encode pass.
        let zeros: Vec<HostTensor> = params.iter().map(HostTensor::zeros_like).collect();
        Ok(ModelState { params, m: zeros.clone(), v: zeros, step: 0 })
    }

    /// Check a tensor list against the manifest's shapes.
    pub fn validate_against(tensors: &[HostTensor], mm: &ModelManifest) -> Result<()> {
        if tensors.len() != mm.params.len() {
            return Err(Error::Shape(format!(
                "expected {} tensors, got {}",
                mm.params.len(),
                tensors.len()
            )));
        }
        for (t, spec) in tensors.iter().zip(&mm.params) {
            if t.shape != spec.shape {
                return Err(Error::Shape(format!(
                    "param '{}': shape {:?} != manifest {:?}",
                    spec.name, t.shape, spec.shape
                )));
            }
        }
        Ok(())
    }

    /// Consume a train-step output tuple (p', m', v', loss) and return
    /// the loss.
    pub fn absorb_step_output(&mut self, outs: Vec<HostTensor>) -> Result<f32> {
        let n = self.params.len();
        if outs.len() != 3 * n + 1 {
            return Err(Error::Shape(format!(
                "train step returned {} tensors, expected {}",
                outs.len(),
                3 * n + 1
            )));
        }
        let mut it = outs.into_iter();
        self.params = it.by_ref().take(n).collect();
        self.m = it.by_ref().take(n).collect();
        self.v = it.by_ref().take(n).collect();
        let loss = it.next().unwrap().item_f32()?;
        self.step += 1;
        Ok(loss)
    }

    /// Total parameter count.
    pub fn numel(&self) -> usize {
        self.params.iter().map(HostTensor::len).sum()
    }

    /// Stash the whole state in `spec`'s packed bit layout. Stochastic
    /// formats draw their rounding stream from the current step and a
    /// per-tensor [`stash_stream`] id, so a given (state, step) packs
    /// bit-identically. Tensors already packed in `spec` are left
    /// untouched (bit-identity across checkpoint reload).
    pub fn pack_state(&mut self, spec: &FormatSpec) -> Result<()> {
        let step = self.step;
        for (g, group) in [&mut self.params, &mut self.m, &mut self.v].into_iter().enumerate() {
            for (i, t) in group.iter_mut().enumerate() {
                *t = t.pack_stream(spec, step, stash_stream(g, i))?;
            }
        }
        Ok(())
    }

    /// Decode every packed tensor back to dense f32 (no-op when dense).
    pub fn unpack_state(&mut self) {
        for group in [&mut self.params, &mut self.m, &mut self.v] {
            for t in group.iter_mut() {
                *t = t.unpack();
            }
        }
    }

    /// True if any tensor is held in packed storage — resident or
    /// spilled (both write the v2 checkpoint framing).
    pub fn is_packed(&self) -> bool {
        use crate::runtime::TensorData;
        [&self.params, &self.m, &self.v].iter().any(|g| {
            g.iter()
                .any(|t| matches!(t.data, TensorData::Packed(_) | TensorData::Spilled(_)))
        })
    }

    /// True if any tensor's payload is currently in a spill segment.
    pub fn is_spilled(&self) -> bool {
        [&self.params, &self.m, &self.v].iter().any(|g| {
            g.iter().any(|t| matches!(t.data, crate::runtime::TensorData::Spilled(_)))
        })
    }

    /// Bytes the state occupies at rest (packed tensors count their
    /// payload — the number the DRAM-traffic claims are about).
    pub fn storage_bytes(&self) -> usize {
        [&self.params, &self.m, &self.v]
            .iter()
            .flat_map(|g| g.iter())
            .map(HostTensor::storage_bytes)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ParamSpec;

    fn fake_manifest_model() -> ModelManifest {
        ModelManifest {
            config: Default::default(),
            params: vec![
                ParamSpec { name: "a".into(), shape: vec![2, 2] },
                ParamSpec { name: "b".into(), shape: vec![3] },
            ],
            artifacts: Default::default(),
        }
    }

    fn fake_state() -> ModelState {
        let p = vec![
            HostTensor::f32(vec![2, 2], vec![1.0; 4]),
            HostTensor::f32(vec![3], vec![2.0; 3]),
        ];
        ModelState { params: p.clone(), m: p.clone(), v: p, step: 0 }
    }

    #[test]
    fn validate_against_catches_mismatches() {
        let mm = fake_manifest_model();
        let good = fake_state();
        assert!(ModelState::validate_against(&good.params, &mm).is_ok());
        let bad = vec![HostTensor::f32(vec![2, 2], vec![0.0; 4])];
        assert!(ModelState::validate_against(&bad, &mm).is_err());
        let wrong_shape = vec![
            HostTensor::f32(vec![4], vec![0.0; 4]),
            HostTensor::f32(vec![3], vec![0.0; 3]),
        ];
        assert!(ModelState::validate_against(&wrong_shape, &mm).is_err());
    }

    #[test]
    fn absorb_step_output_rotates_state() {
        let mut st = fake_state();
        let mut outs = Vec::new();
        for v in [10.0f32, 20.0, 30.0] {
            outs.push(HostTensor::f32(vec![2, 2], vec![v; 4]));
            outs.push(HostTensor::f32(vec![3], vec![v; 3]));
        }
        outs.push(HostTensor::scalar_f32(1.25));
        let loss = st.absorb_step_output(outs).unwrap();
        assert_eq!(loss, 1.25);
        assert_eq!(st.step, 1);
        assert_eq!(st.params[0].as_f32().unwrap()[0], 10.0);
        assert_eq!(st.m[1].as_f32().unwrap()[0], 20.0);
        assert_eq!(st.v[0].as_f32().unwrap()[0], 30.0);
    }

    #[test]
    fn absorb_rejects_wrong_arity() {
        let mut st = fake_state();
        let outs = vec![HostTensor::scalar_f32(1.0)];
        assert!(st.absorb_step_output(outs).is_err());
    }

    #[test]
    fn numel() {
        assert_eq!(fake_state().numel(), 7);
    }

    #[test]
    fn pack_state_roundtrips_and_shrinks() {
        let spec = FormatSpec::bfp(4);
        let mut st = ModelState {
            params: vec![HostTensor::f32(vec![4, 16], (0..64).map(|x| x as f32 * 0.3).collect())],
            m: vec![HostTensor::zeros(&[4, 16])],
            v: vec![HostTensor::zeros(&[4, 16])],
            step: 5,
        };
        let dense_bytes = st.storage_bytes();
        assert!(!st.is_packed());
        st.pack_state(&spec).unwrap();
        assert!(st.is_packed());
        assert!(
            st.storage_bytes() * 4 < dense_bytes,
            "bfp4 state must be sub-byte: {} vs {dense_bytes}",
            st.storage_bytes()
        );
        // Packing a packed state is a no-op (bit-identity across reload).
        let before = st.params[0].clone();
        st.pack_state(&spec).unwrap();
        assert_eq!(st.params[0], before);
        // Decoding gives the quantized grid values.
        st.unpack_state();
        assert!(!st.is_packed());
        let got = st.params[0].as_f32().unwrap().to_vec();
        let want =
            crate::quant::bfp_quantize(&(0..64).map(|x| x as f32 * 0.3).collect::<Vec<_>>(), 16, 4.0);
        assert_eq!(got, want);
    }

    #[test]
    fn absorb_then_repack_keeps_shapes_valid() {
        let mm = fake_manifest_model();
        let mut st = fake_state();
        st.pack_state(&FormatSpec::fixed(8)).unwrap();
        ModelState::validate_against(&st.params, &mm).unwrap();
        // Step outputs arrive dense from the artifact and repack cleanly.
        let mut outs = Vec::new();
        for v in [1.0f32, 2.0, 3.0] {
            outs.push(HostTensor::f32(vec![2, 2], vec![v; 4]));
            outs.push(HostTensor::f32(vec![3], vec![v; 3]));
        }
        outs.push(HostTensor::scalar_f32(0.5));
        st.absorb_step_output(outs).unwrap();
        st.pack_state(&FormatSpec::fixed(8)).unwrap();
        assert!(st.is_packed());
        ModelState::validate_against(&st.params, &mm).unwrap();
    }
}
