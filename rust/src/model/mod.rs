//! Host-side model state: parameters + Adam moments as flat tensor
//! lists (the artifact calling convention), plus a binary checkpoint
//! format.

pub mod checkpoint;

pub use checkpoint::{load_checkpoint, save_checkpoint, Checkpoint};

use crate::runtime::{ArtifactManifest, HostTensor, ModelManifest, Runtime};
use crate::{Error, Result};

/// Parameters + optimizer state for one model, in manifest order.
#[derive(Clone, Debug)]
pub struct ModelState {
    pub params: Vec<HostTensor>,
    pub m: Vec<HostTensor>,
    pub v: Vec<HostTensor>,
    /// 1-based Adam step count already applied.
    pub step: u64,
}

impl ModelState {
    /// Initialize from the model's `init` artifact (seeded, on-device).
    pub fn init(rt: &Runtime, man: &ArtifactManifest, model: &str, seed: i32) -> Result<Self> {
        let exe = rt.load(&man.model_path(model, "init")?)?;
        let params = exe.run(&[HostTensor::scalar_i32(seed)])?;
        let mm = match model {
            "nmt" => &man.nmt,
            "cls" => &man.cls,
            other => return Err(Error::Config(format!("unknown model '{other}'"))),
        };
        Self::validate_against(&params, mm)?;
        let zeros: Vec<HostTensor> =
            mm.params.iter().map(|s| HostTensor::zeros(&s.shape)).collect();
        Ok(ModelState { params, m: zeros.clone(), v: zeros, step: 0 })
    }

    /// Check a tensor list against the manifest's shapes.
    pub fn validate_against(tensors: &[HostTensor], mm: &ModelManifest) -> Result<()> {
        if tensors.len() != mm.params.len() {
            return Err(Error::Shape(format!(
                "expected {} tensors, got {}",
                mm.params.len(),
                tensors.len()
            )));
        }
        for (t, spec) in tensors.iter().zip(&mm.params) {
            if t.shape != spec.shape {
                return Err(Error::Shape(format!(
                    "param '{}': shape {:?} != manifest {:?}",
                    spec.name, t.shape, spec.shape
                )));
            }
        }
        Ok(())
    }

    /// Consume a train-step output tuple (p', m', v', loss) and return
    /// the loss.
    pub fn absorb_step_output(&mut self, outs: Vec<HostTensor>) -> Result<f32> {
        let n = self.params.len();
        if outs.len() != 3 * n + 1 {
            return Err(Error::Shape(format!(
                "train step returned {} tensors, expected {}",
                outs.len(),
                3 * n + 1
            )));
        }
        let mut it = outs.into_iter();
        self.params = it.by_ref().take(n).collect();
        self.m = it.by_ref().take(n).collect();
        self.v = it.by_ref().take(n).collect();
        let loss = it.next().unwrap().item_f32()?;
        self.step += 1;
        Ok(loss)
    }

    /// Total parameter count.
    pub fn numel(&self) -> usize {
        self.params.iter().map(HostTensor::len).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ParamSpec;

    fn fake_manifest_model() -> ModelManifest {
        ModelManifest {
            config: Default::default(),
            params: vec![
                ParamSpec { name: "a".into(), shape: vec![2, 2] },
                ParamSpec { name: "b".into(), shape: vec![3] },
            ],
            artifacts: Default::default(),
        }
    }

    fn fake_state() -> ModelState {
        let p = vec![
            HostTensor::f32(vec![2, 2], vec![1.0; 4]),
            HostTensor::f32(vec![3], vec![2.0; 3]),
        ];
        ModelState { params: p.clone(), m: p.clone(), v: p, step: 0 }
    }

    #[test]
    fn validate_against_catches_mismatches() {
        let mm = fake_manifest_model();
        let good = fake_state();
        assert!(ModelState::validate_against(&good.params, &mm).is_ok());
        let bad = vec![HostTensor::f32(vec![2, 2], vec![0.0; 4])];
        assert!(ModelState::validate_against(&bad, &mm).is_err());
        let wrong_shape = vec![
            HostTensor::f32(vec![4], vec![0.0; 4]),
            HostTensor::f32(vec![3], vec![0.0; 3]),
        ];
        assert!(ModelState::validate_against(&wrong_shape, &mm).is_err());
    }

    #[test]
    fn absorb_step_output_rotates_state() {
        let mut st = fake_state();
        let mut outs = Vec::new();
        for v in [10.0f32, 20.0, 30.0] {
            outs.push(HostTensor::f32(vec![2, 2], vec![v; 4]));
            outs.push(HostTensor::f32(vec![3], vec![v; 3]));
        }
        outs.push(HostTensor::scalar_f32(1.25));
        let loss = st.absorb_step_output(outs).unwrap();
        assert_eq!(loss, 1.25);
        assert_eq!(st.step, 1);
        assert_eq!(st.params[0].as_f32().unwrap()[0], 10.0);
        assert_eq!(st.m[1].as_f32().unwrap()[0], 20.0);
        assert_eq!(st.v[0].as_f32().unwrap()[0], 30.0);
    }

    #[test]
    fn absorb_rejects_wrong_arity() {
        let mut st = fake_state();
        let outs = vec![HostTensor::scalar_f32(1.0)];
        assert!(st.absorb_step_output(outs).is_err());
    }

    #[test]
    fn numel() {
        assert_eq!(fake_state().numel(), 7);
    }
}
