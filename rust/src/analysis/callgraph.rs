//! Lexical call graph over the concurrency-scoped modules — the
//! substrate the interprocedural rules (`lock_discipline`,
//! `blocking_under_lock`) run on.
//!
//! Built from the same annotated line stream as every other rule
//! ([`super::source`] — no AST, no new deps): function definitions are
//! delimited by `fn ` headers with the enclosing `impl` type tracked by
//! brace depth, and each function body yields an ordered event stream:
//!
//! * `Acquire` — a `.lock()` call, named by the receiver field (the
//!   dotted chain before it, minus `self.`, so `self.core.ring.lock()`
//!   and `core.ring.lock()` name the same lock);
//! * `Block` — a token from [`BLOCKING`]: channel `send`/`recv`,
//!   no-arg `.join()` (args would match `Path::join`), `thread::sleep`,
//!   `File`/`fs` I/O, and — since the socket transport — stream
//!   `read_exact`/`write_all`, no-arg `.accept()`, and
//!   `TcpStream`/`UnixStream` connects, so socket I/O under a held
//!   lock is a finding like any other blocking edge. Condvar `.wait(…)`
//!   is deliberately *not* a blocking token: it releases the mutex
//!   while parked, which is the exchange barrier's whole design;
//! * `Call` — an identifier followed by `(`, classified as a method
//!   call (`x.f(`), a qualified call (`T::f(`, with `Self::` resolved
//!   to the enclosing impl type), or a free call (`f(`).
//!
//! Calls resolve only to functions *defined in the scoped files*:
//! method calls match same-named impl methods (type-blind — the
//! receiver's type is unknowable lexically, so over-approximate),
//! qualified calls match by `(type, name)` or module suffix, and free
//! calls match free functions (same module preferred). Ubiquitous std
//! names ([`AMBIENT`]) never resolve, so `v.len()` cannot edge into a
//! project method that happens to share the name.
//!
//! Per-function summaries (locks transitively acquired, blocking ops
//! transitively reached — each with a representative [`Frame`] chain)
//! are propagated along call edges to a bounded monotone fixpoint:
//! every `(function, lock)` key keeps its first-discovered chain, so
//! recursion converges and chains stay finite. The event walk then
//! replays each function with a held-lock set (direct acquisitions
//! only): lock-order pairs and blocked-while-held sites fall out with
//! full call paths attached. Known conservative limits, documented not
//! hidden: guard drops are not tracked (a released lock still orders
//! later acquisitions), and helpers that *return* a guard to their
//! caller do not extend the caller's held set.

use std::collections::BTreeMap;

use super::source::SourceFile;

/// One step of a call path: the function `func` (module-qualified
/// display name) acting at `file:line` — either calling the next frame
/// or, on the last frame, performing the acquisition/blocking op.
#[derive(Clone, Debug)]
pub struct Frame {
    pub func: String,
    pub file: String,
    pub line: usize,
}

/// A lock reachable from some function, with the call path to its
/// `.lock()` site (one frame when acquired directly).
#[derive(Clone, Debug)]
pub struct Acquired {
    pub lock: String,
    pub chain: Vec<Frame>,
}

/// Observed "lock `first` held when `second` is acquired" ordering.
#[derive(Clone, Debug)]
pub struct OrderPair {
    pub first_lock: String,
    pub first_file: String,
    pub first_line: usize,
    pub first_func: String,
    pub second: Acquired,
}

/// A blocking operation reached while `lock` (acquired at
/// `lock_line` in `chain[0].func`) is held.
#[derive(Clone, Debug)]
pub struct BlockedOp {
    pub lock: String,
    pub lock_line: usize,
    pub op: String,
    pub chain: Vec<Frame>,
}

/// Blocking tokens and their display labels. `.join()` is matched
/// exactly with no argument so `Path::join(part)` stays out, and
/// `.accept()` likewise so non-socket `accept(arg)` helpers stay out.
pub const BLOCKING: &[(&str, &str)] = &[
    (".recv()", "channel recv"),
    (".recv_timeout(", "channel recv"),
    (".send(", "channel send"),
    (".join()", "thread join"),
    ("thread::sleep(", "sleep"),
    ("File::open(", "file I/O"),
    ("File::create(", "file I/O"),
    ("OpenOptions::new(", "file I/O"),
    ("fs::write(", "file I/O"),
    ("fs::read", "file I/O"),
    (".read_exact(", "stream read"),
    (".write_all(", "stream write"),
    (".accept()", "socket accept"),
    ("TcpStream::connect(", "socket connect"),
    ("UnixStream::connect(", "socket connect"),
];

/// Ubiquitous std method/function names that never resolve to project
/// definitions — without this deny-list, `v.len()` anywhere would edge
/// into any scoped `fn len` and drown the graph in false paths.
const AMBIENT: &[&str] = &[
    "all", "and_then", "any", "as_bytes", "as_mut", "as_ref", "as_slice", "as_str", "chain",
    "clear", "clone", "cloned", "collect", "contains", "contains_key", "copied", "count",
    "default", "drain", "drop", "entry", "enumerate", "eq", "expect", "extend", "filter",
    "filter_map", "find", "first", "flat_map", "flatten", "flush", "fmt", "fold", "from", "get",
    "get_mut", "hash", "insert", "into", "into_iter", "is_empty", "is_none", "is_some", "iter",
    "iter_mut", "join", "last", "len", "lock", "map", "map_err", "max", "min", "new", "next",
    "notify_all", "notify_one", "nth", "ok", "or_else", "parse", "pop", "position", "push",
    "read", "read_exact", "recv", "remove", "replace", "resize", "retain", "rev", "seek", "send",
    "skip", "sort", "sort_by", "sort_by_key", "spawn", "split", "sum", "take", "to_owned",
    "to_string", "to_vec", "trim", "unwrap", "unwrap_or", "unwrap_or_default", "unwrap_or_else",
    "wait", "windows", "write", "write_all", "zip",
];

const KEYWORDS: &[&str] = &[
    "as", "dyn", "else", "fn", "for", "if", "impl", "in", "let", "loop", "match", "move", "pub",
    "return", "unsafe", "use", "where", "while",
];

/// Fixpoint iteration cap. Summaries are monotone (first chain per
/// `(function, lock)` key wins, never replaced) so the loop converges
/// on its own; the cap bounds pathological trees and, with it, chain
/// length (≤ cap + 1 frames).
const MAX_FIXPOINT_ITERS: usize = 12;

enum Callee {
    Method(String),
    Qualified(String, String),
    Free(String),
}

enum Event {
    Acquire { lock: String, line: usize },
    Block { op: String, line: usize },
    Call { callee: Callee, line: usize },
}

struct Func {
    name: String,
    impl_type: Option<String>,
    module: String,
    file: String,
    events: Vec<Event>,
}

impl Func {
    fn display(&self) -> String {
        match &self.impl_type {
            Some(t) => format!("{}::{}::{}", self.module, t, self.name),
            None => format!("{}::{}", self.module, self.name),
        }
    }
}

/// The built graph plus the derived concurrency facts the rules read.
pub struct Graph {
    funcs: Vec<Func>,
    /// Locks each function may acquire, transitively, with a chain.
    acquires: Vec<BTreeMap<String, Vec<Frame>>>,
    order_pairs: Vec<OrderPair>,
    blocked_ops: Vec<BlockedOp>,
}

/// `rust/src/stash/exchange.rs` → `stash::exchange`;
/// `rust/src/stash/mod.rs` → `stash`.
fn module_of(rel: &str) -> String {
    let p = rel.strip_prefix("rust/src/").unwrap_or(rel);
    let p = p.strip_suffix(".rs").unwrap_or(p);
    let p = p.strip_suffix("/mod").unwrap_or(p);
    p.replace('/', "::")
}

/// Receiver of a `.lock()` call at byte offset `at`: the dotted ident
/// chain before it minus `self`, named by its last field.
pub fn receiver(code: &str, at: usize) -> Option<String> {
    let head = &code[..at];
    let start = head
        .rfind(|c: char| !(c.is_ascii_alphanumeric() || c == '_' || c == '.'))
        .map(|i| i + 1)
        .unwrap_or(0);
    let chain = head[start..].trim_matches('.');
    if chain.is_empty() {
        return None;
    }
    let tail: Vec<&str> = chain.split('.').filter(|s| *s != "self").collect();
    tail.last().map(|s| s.to_string())
}

fn is_ident(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

/// The `impl` type named by a header line (`impl Foo {`,
/// `impl<T> Bar<T> {`, `impl Trait for Baz {`), if the line is one.
fn impl_type(code: &str) -> Option<String> {
    let trimmed = code.trim_start();
    let rest = trimmed.strip_prefix("impl")?;
    // `impl` must be the keyword, not a prefix of an identifier.
    let rest = match rest.as_bytes().first() {
        Some(b'<') => &rest[rest.find('>')? + 1..],
        Some(c) if !is_ident(*c) => rest,
        _ => return None,
    };
    let rest = match rest.find(" for ") {
        Some(at) => &rest[at + " for ".len()..],
        None => rest,
    };
    let ty: String = rest
        .trim_start()
        .chars()
        .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
        .collect();
    if ty.is_empty() {
        None
    } else {
        Some(ty)
    }
}

/// Call sites on one line: each identifier directly followed by `(`,
/// classified by what precedes it. Macros (`name!(`) and definition
/// sites (`fn name(`) never register.
fn calls_on(code: &str) -> Vec<(usize, Callee)> {
    let bytes = code.as_bytes();
    let mut out = Vec::new();
    for at in 0..bytes.len() {
        if bytes[at] != b'(' {
            continue;
        }
        let mut s = at;
        while s > 0 && is_ident(bytes[s - 1]) {
            s -= 1;
        }
        if s == at || bytes[s].is_ascii_digit() {
            continue;
        }
        let name = &code[s..at];
        let before = &code[..s];
        if before.ends_with('.') {
            if !AMBIENT.contains(&name) {
                out.push((s, Callee::Method(name.to_string())));
            }
        } else if before.ends_with("::") {
            let qhead = &bytes[..s - 2];
            let mut qs = qhead.len();
            while qs > 0 && is_ident(qhead[qs - 1]) {
                qs -= 1;
            }
            let qual = &code[qs..s - 2];
            if !qual.is_empty() {
                out.push((s, Callee::Qualified(qual.to_string(), name.to_string())));
            }
        } else {
            let def_site = before.trim_end().ends_with("fn");
            let upper = name.starts_with(|c: char| c.is_ascii_uppercase());
            if !def_site && !upper && !KEYWORDS.contains(&name) && !AMBIENT.contains(&name) {
                out.push((s, Callee::Free(name.to_string())));
            }
        }
    }
    out
}

impl Graph {
    /// Build the graph over every file whose path starts with one of
    /// `scopes`, and derive the order pairs and blocked-while-held ops.
    pub fn build<'a>(files: impl Iterator<Item = &'a SourceFile>, scopes: &[&str]) -> Graph {
        let mut funcs: Vec<Func> = Vec::new();
        for f in files {
            if !scopes.iter().any(|p| f.rel.starts_with(p)) {
                continue;
            }
            extract(f, &mut funcs);
        }
        let resolved = resolve(&funcs);
        let (acquires, blocks) = summaries(&funcs, &resolved);
        let (order_pairs, blocked_ops) = walk(&funcs, &resolved, &acquires, &blocks);
        Graph { funcs, acquires, order_pairs, blocked_ops }
    }

    /// Every observed "first held, second acquired" ordering.
    pub fn order_pairs(&self) -> &[OrderPair] {
        &self.order_pairs
    }

    /// Every blocking op reached while a lock is held.
    pub fn blocked_ops(&self) -> &[BlockedOp] {
        &self.blocked_ops
    }

    /// Lock names the function whose display name ends with `func`
    /// may acquire, transitively (test/diagnostic accessor).
    pub fn acquires_of(&self, func: &str) -> Vec<String> {
        self.funcs
            .iter()
            .enumerate()
            .filter(|(_, f)| {
                let d = f.display();
                d == func || d.ends_with(&format!("::{func}"))
            })
            .flat_map(|(i, _)| self.acquires[i].keys().cloned())
            .collect()
    }

    /// `a (f.rs:1) -> b (g.rs:2)` rendering of a call path.
    pub fn chain_display(chain: &[Frame]) -> String {
        chain
            .iter()
            .map(|fr| format!("{} ({}:{})", fr.func, fr.file, fr.line))
            .collect::<Vec<_>>()
            .join(" -> ")
    }
}

/// Split one file into functions with ordered event streams.
fn extract(f: &SourceFile, funcs: &mut Vec<Func>) {
    let module = module_of(&f.rel);
    let mut depth: i64 = 0;
    let mut impls: Vec<(String, i64)> = Vec::new();
    let mut pending_impl: Option<String> = None;
    let mut cur: Option<usize> = None;
    for l in f.code_lines() {
        let code = l.code.as_str();
        if let Some(ty) = impl_type(code) {
            pending_impl = Some(ty);
        }
        if let Some(at) = code.find("fn ") {
            let name: String = code[at + 3..]
                .chars()
                .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
                .collect();
            if !name.is_empty() && code.contains('(') {
                funcs.push(Func {
                    name,
                    impl_type: impls.last().map(|(t, _)| t.clone()),
                    module: module.clone(),
                    file: f.rel.clone(),
                    events: Vec::new(),
                });
                cur = Some(funcs.len() - 1);
            }
        }
        if let Some(fi) = cur {
            let mut events: Vec<(usize, Event)> = Vec::new();
            let mut from = 0;
            while let Some(at) = code[from..].find(".lock()") {
                let col = from + at;
                if let Some(lock) = receiver(code, col) {
                    events.push((col, Event::Acquire { lock, line: l.number }));
                }
                from = col + ".lock()".len();
            }
            for (tok, label) in BLOCKING {
                let mut from = 0;
                while let Some(at) = code[from..].find(tok) {
                    let col = from + at;
                    events.push((col, Event::Block { op: label.to_string(), line: l.number }));
                    from = col + tok.len();
                }
            }
            for (col, callee) in calls_on(code) {
                events.push((col, Event::Call { callee, line: l.number }));
            }
            events.sort_by_key(|(col, _)| *col);
            funcs[fi].events.extend(events.into_iter().map(|(_, e)| e));
        }
        let before = depth;
        depth += code.matches('{').count() as i64 - code.matches('}').count() as i64;
        if pending_impl.is_some() && code.contains('{') {
            if let Some(ty) = pending_impl.take() {
                impls.push((ty, before));
            }
        }
        while impls.last().is_some_and(|(_, d)| depth <= *d) {
            impls.pop();
        }
    }
}

/// Resolve every `Call` event to the scoped functions it may reach.
/// `resolved[func][event_index]` is empty for non-calls and unresolved
/// calls (std, out-of-scope, ambient).
fn resolve(funcs: &[Func]) -> Vec<Vec<Vec<usize>>> {
    funcs
        .iter()
        .map(|f| {
            f.events
                .iter()
                .map(|ev| {
                    let Event::Call { callee, .. } = ev else { return Vec::new() };
                    match callee {
                        Callee::Method(name) => funcs
                            .iter()
                            .enumerate()
                            .filter(|(_, g)| g.impl_type.is_some() && g.name == *name)
                            .map(|(i, _)| i)
                            .collect(),
                        Callee::Qualified(qual, name) => {
                            let qual: &str = if qual == "Self" {
                                f.impl_type.as_deref().unwrap_or(qual.as_str())
                            } else {
                                qual.as_str()
                            };
                            let by_type: Vec<usize> = funcs
                                .iter()
                                .enumerate()
                                .filter(|(_, g)| {
                                    g.name == *name && g.impl_type.as_deref() == Some(qual)
                                })
                                .map(|(i, _)| i)
                                .collect();
                            if !by_type.is_empty() {
                                return by_type;
                            }
                            // Lowercase qualifier: a module path segment.
                            funcs
                                .iter()
                                .enumerate()
                                .filter(|(_, g)| {
                                    g.name == *name
                                        && g.impl_type.is_none()
                                        && (g.module == qual
                                            || g.module.ends_with(&format!("::{qual}")))
                                })
                                .map(|(i, _)| i)
                                .collect()
                        }
                        Callee::Free(name) => {
                            let frees: Vec<usize> = funcs
                                .iter()
                                .enumerate()
                                .filter(|(_, g)| g.impl_type.is_none() && g.name == *name)
                                .map(|(i, _)| i)
                                .collect();
                            let local: Vec<usize> = frees
                                .iter()
                                .copied()
                                .filter(|&i| funcs[i].module == f.module)
                                .collect();
                            if local.is_empty() {
                                frees
                            } else {
                                local
                            }
                        }
                    }
                })
                .collect()
        })
        .collect()
}

type Summary = Vec<BTreeMap<String, Vec<Frame>>>;

/// Bounded fixpoint over call edges: locks acquired and blocking ops
/// reached by each function, transitively, with representative chains.
fn summaries(funcs: &[Func], resolved: &[Vec<Vec<usize>>]) -> (Summary, Summary) {
    let mut acq: Summary = vec![BTreeMap::new(); funcs.len()];
    let mut blk: Summary = vec![BTreeMap::new(); funcs.len()];
    for (fi, f) in funcs.iter().enumerate() {
        for ev in &f.events {
            match ev {
                Event::Acquire { lock, line } => {
                    acq[fi].entry(lock.clone()).or_insert_with(|| {
                        vec![Frame { func: f.display(), file: f.file.clone(), line: *line }]
                    });
                }
                Event::Block { op, line } => {
                    blk[fi].entry(op.clone()).or_insert_with(|| {
                        vec![Frame { func: f.display(), file: f.file.clone(), line: *line }]
                    });
                }
                Event::Call { .. } => {}
            }
        }
    }
    for _ in 0..MAX_FIXPOINT_ITERS {
        let mut changed = false;
        let (acq_prev, blk_prev) = (acq.clone(), blk.clone());
        for (fi, f) in funcs.iter().enumerate() {
            for (ei, ev) in f.events.iter().enumerate() {
                let Event::Call { line, .. } = ev else { continue };
                for &ti in &resolved[fi][ei] {
                    let hop = Frame { func: f.display(), file: f.file.clone(), line: *line };
                    for (lock, chain) in &acq_prev[ti] {
                        if !acq[fi].contains_key(lock) {
                            let mut c = vec![hop.clone()];
                            c.extend(chain.iter().cloned());
                            acq[fi].insert(lock.clone(), c);
                            changed = true;
                        }
                    }
                    for (op, chain) in &blk_prev[ti] {
                        if !blk[fi].contains_key(op) {
                            let mut c = vec![hop.clone()];
                            c.extend(chain.iter().cloned());
                            blk[fi].insert(op.clone(), c);
                            changed = true;
                        }
                    }
                }
            }
        }
        if !changed {
            break;
        }
    }
    (acq, blk)
}

/// Replay each function with a held-lock set (direct acquisitions
/// only — guard drops are not tracked, calls do not extend the set).
fn walk(
    funcs: &[Func],
    resolved: &[Vec<Vec<usize>>],
    acq: &Summary,
    blk: &Summary,
) -> (Vec<OrderPair>, Vec<BlockedOp>) {
    let mut pairs = Vec::new();
    let mut blocked = Vec::new();
    for (fi, f) in funcs.iter().enumerate() {
        let mut held: Vec<(String, usize)> = Vec::new();
        for (ei, ev) in f.events.iter().enumerate() {
            match ev {
                Event::Acquire { lock, line } => {
                    for (h, hline) in &held {
                        if h != lock {
                            pairs.push(OrderPair {
                                first_lock: h.clone(),
                                first_file: f.file.clone(),
                                first_line: *hline,
                                first_func: f.display(),
                                second: Acquired {
                                    lock: lock.clone(),
                                    chain: vec![Frame {
                                        func: f.display(),
                                        file: f.file.clone(),
                                        line: *line,
                                    }],
                                },
                            });
                        }
                    }
                    if !held.iter().any(|(h, _)| h == lock) {
                        held.push((lock.clone(), *line));
                    }
                }
                Event::Block { op, line } => {
                    if let Some((h, hline)) = held.last() {
                        blocked.push(BlockedOp {
                            lock: h.clone(),
                            lock_line: *hline,
                            op: op.clone(),
                            chain: vec![Frame {
                                func: f.display(),
                                file: f.file.clone(),
                                line: *line,
                            }],
                        });
                    }
                }
                Event::Call { line, .. } => {
                    for &ti in &resolved[fi][ei] {
                        let hop = Frame { func: f.display(), file: f.file.clone(), line: *line };
                        for (lock, chain) in &acq[ti] {
                            for (h, hline) in &held {
                                if h != lock {
                                    let mut c = vec![hop.clone()];
                                    c.extend(chain.iter().cloned());
                                    pairs.push(OrderPair {
                                        first_lock: h.clone(),
                                        first_file: f.file.clone(),
                                        first_line: *hline,
                                        first_func: f.display(),
                                        second: Acquired { lock: lock.clone(), chain: c },
                                    });
                                }
                            }
                        }
                        if let Some((h, hline)) = held.last() {
                            for (op, chain) in &blk[ti] {
                                let mut c = vec![hop.clone()];
                                c.extend(chain.iter().cloned());
                                blocked.push(BlockedOp {
                                    lock: h.clone(),
                                    lock_line: *hline,
                                    op: op.clone(),
                                    chain: c,
                                });
                            }
                        }
                    }
                }
            }
        }
    }
    (pairs, blocked)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn graph(src: &str) -> Graph {
        let f = SourceFile::parse("rust/src/stash/fixture.rs", src);
        Graph::build(std::iter::once(&f), &["rust/src/stash/"])
    }

    #[test]
    fn method_and_free_calls_resolve_separately() {
        let g = graph(
            "struct S;\n\
             impl S {\n\
                 fn lockit(&self) { self.a.lock(); }\n\
             }\n\
             fn lockit() { b.lock(); }\n\
             fn via_method(s: &S) { s.lockit(); }\n\
             fn via_free() { lockit(); }\n",
        );
        assert_eq!(g.acquires_of("via_method"), vec!["a"]);
        assert_eq!(g.acquires_of("via_free"), vec!["b"]);
    }

    #[test]
    fn self_qualified_calls_resolve_to_the_impl_type() {
        let g = graph(
            "struct S;\n\
             impl S {\n\
                 fn inner() { c.lock(); }\n\
                 fn outer() { Self::inner(); }\n\
             }\n",
        );
        assert_eq!(g.acquires_of("outer"), vec!["c"]);
    }

    #[test]
    fn recursion_terminates_and_merges_summaries() {
        let g = graph(
            "fn r1() { r2(); a.lock(); }\n\
             fn r2() { r1(); b.lock(); }\n",
        );
        assert_eq!(g.acquires_of("r1"), vec!["a", "b"]);
        assert_eq!(g.acquires_of("r2"), vec!["a", "b"]);
    }

    #[test]
    fn ambient_method_names_never_edge_into_project_functions() {
        let g = graph(
            "struct S;\n\
             impl S {\n\
                 fn len(&self) { a.lock(); }\n\
             }\n\
             fn caller(v: &[u8]) { v.len(); }\n",
        );
        assert!(g.acquires_of("caller").is_empty(), "len() is ambient, no edge");
    }

    #[test]
    fn cross_function_order_pair_carries_the_call_path() {
        let g = graph(
            "fn helper(p: &P) { p.budget.lock(); }\n\
             fn outer(p: &P) {\n\
                 let _a = p.lru.lock();\n\
                 helper(p);\n\
             }\n",
        );
        let pair = g
            .order_pairs()
            .iter()
            .find(|p| p.first_lock == "lru" && p.second.lock == "budget")
            .expect("interprocedural lru→budget pair");
        let path = Graph::chain_display(&pair.second.chain);
        assert!(path.contains("outer") && path.contains("helper"), "{path}");
    }

    #[test]
    fn blocking_reached_through_a_call_is_attributed() {
        let g = graph(
            "fn helper(rx: &R) { rx.recv(); }\n\
             fn outer(p: &P, rx: &R) {\n\
                 let _g = p.ring.lock();\n\
                 helper(rx);\n\
             }\n",
        );
        assert!(
            g.blocked_ops().iter().any(|b| b.lock == "ring"
                && b.op == "channel recv"
                && Graph::chain_display(&b.chain).contains("helper")),
            "recv via helper while holding ring must surface"
        );
    }

    #[test]
    fn condvar_wait_is_not_a_blocking_token() {
        let g = graph(
            "fn barrier(core: &C) {\n\
                 let mut ring = core.ring.lock();\n\
                 ring = ring.wait(&core.ring_cv);\n\
                 let _ = ring;\n\
             }\n",
        );
        assert!(g.blocked_ops().is_empty(), "wait releases the lock while parked");
    }

    #[test]
    fn path_join_with_args_is_not_thread_join() {
        let g = graph(
            "fn write_side(p: &P, dir: &Path) {\n\
                 let _g = p.ring.lock();\n\
                 let _ = dir.join(name);\n\
             }\n",
        );
        assert!(g.blocked_ops().is_empty(), ".join(arg) is Path::join, not a thread join");
    }
}
