//! Rule `blocking_under_lock`: no blocking operation — channel
//! `send`/`recv`, no-arg thread `join`, `thread::sleep`, `File`/`fs`
//! I/O, and socket I/O (stream `read_exact`/`write_all`, no-arg
//! `accept`, `TcpStream`/`UnixStream` connects) — may be reached while
//! a mutex is held, directly or through any call chain.
//!
//! This is the PR-7 barrier-deadlock class made a build failure: a
//! replica thread that parks at a channel or joins a worker while
//! holding the exchange `ring` (or any stash/coordinator mutex) stalls
//! every peer spinning on that lock, and under a failed peer the park
//! never returns. The socket transport raises the stakes — a stream
//! read can block for the full read timeout, so the transport keeps
//! its `failed` mutex confined to flag helpers and the rule proves no
//! wire I/O ever runs under it. The rule shares the call graph and
//! held-set walk with `lock_discipline` ([`super::callgraph`]);
//! condvar `.wait(…)` is deliberately not a blocking token, because it
//! releases the mutex while parked — the exchange barrier is the legal
//! pattern.
//!
//! Findings anchor at the outermost frame (the blocking call, or the
//! call that leads to it), so a provably-safe site is escaped where the
//! decision is made: `// dsq-lint: allow(blocking_under_lock, <reason>)`.
//!
//! The runtime twin of this rule is
//! [`crate::util::ordwitness::assert_lock_free`], which panics in debug
//! builds if a blocking edge is crossed with a witnessed lock held.

use std::collections::BTreeSet;

use super::callgraph::Graph;
use super::{locks, Finding, Tree, RULE_BLOCKING};

pub fn check(tree: &Tree, findings: &mut Vec<Finding>) {
    let graph = Graph::build(tree.rust_files(), locks::SCOPES);
    // A call resolving to several candidates reports once per site.
    let mut seen: BTreeSet<(String, usize, String, String)> = BTreeSet::new();
    for b in graph.blocked_ops() {
        let Some(head) = b.chain.first() else { continue };
        if !seen.insert((head.file.clone(), head.line, b.op.clone(), b.lock.clone())) {
            continue;
        }
        findings.push(Finding::new(
            RULE_BLOCKING,
            &head.file,
            head.line,
            format!(
                "{} reached while holding lock '{}' (acquired {}:{}) via {} — \
                 release the lock before blocking",
                b.op,
                b.lock,
                head.file,
                b.lock_line,
                Graph::chain_display(&b.chain),
            ),
        ));
    }
}
