//! `dsq lint` — a repo-specific static analysis pass that turns
//! cross-layer drift into a build failure.
//!
//! The DSQ system keeps one contract in several places at once: the
//! format registry (`quant/format.rs`) must agree with the packed codec
//! (`quant/packed.rs`), the cost model (`costmodel/formats.rs`), the
//! benches, the CLI, the python mode-dispatch tables
//! (`python/compile/layers.py`) and the artifact variant lists
//! (`aot.py`, `runtime/artifact.rs`); the binary formats hang off magic
//! constants; and the per-step hot path must not panic. No unit test in
//! any single layer can see two layers drift apart — PR 4's
//! wrong-kernel dispatch bug was exactly that. This module parses the
//! source tree (lightweight line/token scanning, no syn/AST) and checks
//! the invariants directly:
//!
//! | rule               | invariant                                           |
//! |--------------------|-----------------------------------------------------|
//! | `registry_coverage`| every registry row has quantizer/codec/cost/bench/CLI arms ([`coverage`]) |
//! | `qcfg_sync`        | rust↔python mode tables, 100·E+M packing, variant lists agree ([`qcfg`]) |
//! | `magic_constants`  | on-disk magics defined once + pinned by golden tests ([`magic`]) |
//! | `panic_hygiene`    | no `unwrap`/`expect`/`panic!` on the hot path ([`panics`]) |
//! | `lock_discipline`  | one global mutex order, interprocedurally along the call graph ([`locks`]) |
//! | `blocking_under_lock` | no send/recv/join/sleep/file or socket I/O reached while a lock is held ([`blocking`]) |
//! | `lint_meta`        | RULES const ↔ this table ↔ ROADMAP "Static analysis" table agree ([`meta`]) |
//!
//! Escapes: `// dsq-lint: allow(<rule>, <reason>)` on the finding's
//! line or the line above suppresses it; the reason is mandatory and
//! the rule name must be real, so a typo'd escape is itself a finding.
//!
//! Run as `dsq lint [--root <dir>] [--json] [--github]` (exit 0 clean,
//! 1 on findings; `--json` emits a machine-readable report, `--github`
//! prints `::error file=…,line=…::` annotations so findings are
//! clickable in a PR diff) — wired into CI next to build/test/clippy —
//! or in-process via [`run_lint`], which is how the drift-injection
//! fixture tests prove each rule actually fires
//! (`rust/tests/lint_drift.rs`). The concurrency rules share a lexical
//! call graph ([`callgraph`]) and have a runtime twin: the debug-build
//! lock-order witness ([`crate::util::ordwitness`]) asserts the same
//! global order and lock-free blocking edges on every test run.

use std::path::{Path, PathBuf};

use crate::{Error, Result};

pub mod blocking;
pub mod callgraph;
pub mod coverage;
pub mod locks;
pub mod magic;
pub mod meta;
pub mod panics;
pub mod qcfg;
pub mod source;

use source::SourceFile;

pub const RULE_COVERAGE: &str = "registry_coverage";
pub const RULE_QCFG: &str = "qcfg_sync";
pub const RULE_MAGIC: &str = "magic_constants";
pub const RULE_PANIC: &str = "panic_hygiene";
pub const RULE_LOCKS: &str = "lock_discipline";
pub const RULE_BLOCKING: &str = "blocking_under_lock";
pub const RULE_META: &str = "lint_meta";
pub const RULE_ESCAPE: &str = "lint_escape";

pub const RULES: &[&str] = &[
    RULE_COVERAGE,
    RULE_QCFG,
    RULE_MAGIC,
    RULE_PANIC,
    RULE_LOCKS,
    RULE_BLOCKING,
    RULE_META,
    RULE_ESCAPE,
];

/// One lint violation, locatable as `file:line`.
#[derive(Clone, Debug)]
pub struct Finding {
    pub rule: &'static str,
    pub file: String,
    pub line: usize,
    pub message: String,
}

impl Finding {
    pub fn new(
        rule: &'static str,
        file: impl Into<String>,
        line: usize,
        message: impl Into<String>,
    ) -> Finding {
        Finding { rule, file: file.into(), line, message: message.into() }
    }

    /// The machine-readable form emitted by `dsq lint --json`.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("rule", Json::str(self.rule)),
            ("file", Json::str(&self.file)),
            ("line", Json::Num(self.line as f64)),
            ("message", Json::str(&self.message)),
        ])
    }
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "lint[{}] {}:{}: {}", self.rule, self.file, self.line, self.message)
    }
}

/// The lint's view of the repo: the cross-layer contract files plus
/// every `.rs` file under `rust/` (for the magic scan and the scoped
/// hot-path rules).
pub struct Tree {
    files: Vec<SourceFile>,
}

/// Files the rules parse structurally; `run_lint` fails loudly if one
/// is missing rather than skipping the invariants it carries.
const REQUIRED: &[&str] = &[
    "rust/src/quant/format.rs",
    "rust/src/quant/packed.rs",
    "rust/src/costmodel/formats.rs",
    "rust/src/model/checkpoint.rs",
    "rust/src/coordinator/cli.rs",
    "rust/src/coordinator/session.rs",
    "rust/src/runtime/artifact.rs",
    "rust/benches/quantizer_hotpath.rs",
    "rust/benches/stash_store.rs",
    "python/compile/layers.py",
    "python/compile/aot.py",
    "python/compile/kernels/ref.py",
    // lint_meta parses its own module doc and the ROADMAP rule table.
    "rust/src/analysis/mod.rs",
    "ROADMAP.md",
];

impl Tree {
    /// Load the tree rooted at `root` (the directory holding `rust/`
    /// and `python/`).
    pub fn load(root: &Path) -> Result<Tree> {
        let mut files = Vec::new();
        let mut seen = std::collections::BTreeSet::new();
        for rel in REQUIRED {
            let path = root.join(rel);
            let content = std::fs::read_to_string(&path).map_err(|e| {
                Error::Config(format!("dsq lint: cannot read required input {rel}: {e}"))
            })?;
            files.push(SourceFile::parse(rel, &content));
            seen.insert(rel.to_string());
        }
        // Everything else under rust/: the magic scan is tree-wide, and
        // the scoped rules (stash/, hot paths) pick by path prefix.
        for dir in ["rust/src", "rust/tests", "rust/benches"] {
            for (rel, content) in read_rs_tree(&root.join(dir), dir)? {
                if seen.insert(rel.clone()) {
                    files.push(SourceFile::parse(&rel, &content));
                }
            }
        }
        Ok(Tree { files })
    }

    /// The file at repo-relative path `rel` (must be in [`REQUIRED`]).
    pub fn file(&self, rel: &str) -> &SourceFile {
        self.files
            .iter()
            .find(|f| f.rel == rel)
            .unwrap_or_else(|| panic!("lint input {rel} not loaded"))
    }

    /// Every loaded rust file.
    pub fn rust_files(&self) -> impl Iterator<Item = &SourceFile> {
        self.files.iter().filter(|f| f.rel.ends_with(".rs"))
    }
}

/// Recursively collect `.rs` files under `dir` as (repo-relative path,
/// content), deterministic order.
fn read_rs_tree(dir: &Path, rel: &str) -> Result<Vec<(String, String)>> {
    let mut out = Vec::new();
    let Ok(entries) = std::fs::read_dir(dir) else {
        return Ok(out); // a fixture tree may omit whole directories
    };
    let mut entries: Vec<_> = entries.filter_map(|e| e.ok()).collect();
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let name = e.file_name().to_string_lossy().into_owned();
        let sub = format!("{rel}/{name}");
        let path = e.path();
        if path.is_dir() {
            out.extend(read_rs_tree(&path, &sub)?);
        } else if name.ends_with(".rs") {
            let content = std::fs::read_to_string(&path)
                .map_err(|e| Error::Config(format!("dsq lint: cannot read {sub}: {e}")))?;
            out.push((sub, content));
        }
    }
    Ok(out)
}

/// Lint report: surviving findings plus the rule count that ran.
pub struct Report {
    pub findings: Vec<Finding>,
    pub rules_run: usize,
}

/// Run every rule over the tree at `root`, apply `dsq-lint: allow`
/// escapes, and return the surviving findings sorted by location.
pub fn run_lint(root: &Path) -> Result<Report> {
    let tree = Tree::load(root)?;
    let mut findings = Vec::new();
    coverage::check(&tree, &mut findings);
    qcfg::check(&tree, &mut findings);
    magic::check(&tree, &mut findings);
    panics::check(&tree, &mut findings);
    locks::check(&tree, &mut findings);
    blocking::check(&tree, &mut findings);
    meta::check(&tree, &mut findings);

    // Apply escapes: an allow(rule, reason) on the finding's line or
    // the line above suppresses it.
    findings.retain(|fd| {
        let Some(file) = tree.files.iter().find(|f| f.rel == fd.file) else { return true };
        !allowed_at(file, fd.rule, fd.line)
    });

    // Malformed escapes are findings of their own: a typo'd rule name
    // or an empty reason silently suppresses nothing forever.
    for f in &tree.files {
        for l in &f.lines {
            if let Some((rule, reason)) = &l.allow {
                if !RULES.contains(&rule.as_str()) {
                    findings.push(Finding::new(
                        RULE_ESCAPE,
                        &f.rel,
                        l.number,
                        format!("allow({rule}, …) names an unknown rule (known: {RULES:?})"),
                    ));
                } else if reason.is_empty() {
                    findings.push(Finding::new(
                        RULE_ESCAPE,
                        &f.rel,
                        l.number,
                        format!("allow({rule}) without a reason — say why the site is safe"),
                    ));
                }
            }
        }
    }

    findings.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    Ok(Report { findings, rules_run: RULES.len() - 1 })
}

fn allowed_at(file: &SourceFile, rule: &str, line: usize) -> bool {
    let has = |n: usize| {
        n >= 1
            && file
                .lines
                .get(n - 1)
                .and_then(|l| l.allow.as_ref())
                .is_some_and(|(r, why)| r == rule && !why.is_empty())
    };
    has(line) || has(line.saturating_sub(1))
}

/// Locate the repo root (the directory holding `rust/src/quant/format.rs`)
/// by walking up from `start`. This is how `dsq lint` finds its inputs
/// when invoked from the repo root, from `rust/`, or from a subdir.
pub fn find_root(start: &Path) -> Option<PathBuf> {
    let mut dir = Some(start.to_path_buf());
    while let Some(d) = dir {
        if d.join("rust/src/quant/format.rs").is_file() {
            return Some(d);
        }
        dir = d.parent().map(Path::to_path_buf);
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn find_root_walks_up() {
        let here = std::env::current_dir().unwrap();
        if let Some(root) = find_root(&here) {
            assert!(root.join("rust/src/quant/format.rs").is_file());
            assert_eq!(find_root(&root.join("rust/src")), Some(root));
        }
    }

    #[test]
    fn finding_display_is_clickable() {
        let f = Finding::new(RULE_QCFG, "a/b.rs", 7, "drift");
        assert_eq!(f.to_string(), "lint[qcfg_sync] a/b.rs:7: drift");
    }
}
