//! Rule `registry_coverage`: every `FORMAT_REGISTRY` row has all of its
//! arms.
//!
//! ROADMAP promises "adding a format is one registry row + one
//! quantizer arm + a cost calibration" — this rule is what makes that
//! promise checkable. For each registered family the following must
//! exist, or the build fails:
//!
//! 1. a quantizer arm in `FormatSpec::quantize_into_stream`
//!    (`quant/format.rs`);
//! 2. a `codec_tag` arm in `quant/packed.rs`, and the inverse
//!    `spec_from_tag` arm for that tag number;
//! 3. cost-model arms in `costmodel/formats.rs` (`storage_bits` and
//!    `mac_cost`);
//! 4. a registry-driven bench sweep: the hot-path benches enumerate
//!    `registered_specs(…)` so new rows are benchmarked automatically;
//! 5. a registry-driven `dsq formats` CLI table (`cmd_formats` iterates
//!    `FORMAT_REGISTRY`).
//!
//! The checks are deliberately *structural* (token scans over match
//! bodies), so deleting an arm — the drift the rule exists for — is a
//! lint failure naming the exact function it vanished from.

use super::source::SourceFile;
use super::{Finding, Tree, RULE_COVERAGE};

/// One parsed `FormatFamily { … }` registry row.
pub struct RegistryRow {
    pub keyword: String,
    pub suffix: String,
    /// Line of the row's `FormatFamily {` opener in `quant/format.rs`.
    pub line: usize,
}

impl RegistryRow {
    pub fn name(&self) -> String {
        format!("{}{}", self.keyword, self.suffix)
    }

    /// Which `FormatSpec` enum variant (and rounding, when the arm
    /// matches on it) this row instantiates. `None` for a spelling the
    /// linter does not know — itself a finding: a new family must be
    /// taught to the coverage map when it is registered.
    pub fn variant(&self) -> Option<(&'static str, Option<&'static str>)> {
        match (self.keyword.as_str(), self.suffix.as_str()) {
            ("fp", "") => Some(("Fp32", None)),
            ("fixed", "") => Some(("Fixed", Some("Nearest"))),
            ("fixed", "sr") => Some(("Fixed", Some("Stochastic"))),
            ("bfp", "") => Some(("Bfp", None)),
            ("fp", s) if s.starts_with('e') && s.ends_with("sr") => {
                Some(("Float", Some("Stochastic")))
            }
            ("fp", s) if s.starts_with('e') => Some(("Float", Some("Nearest"))),
            _ => None,
        }
    }
}

/// Parse the `FORMAT_REGISTRY` table out of `quant/format.rs`.
///
/// The registry is a *bracket*-delimited array (`&[FormatFamily { … },
/// …];`), so brace-matched [`SourceFile::item_body`] would stop at the
/// first row's closing `}` — the table is instead scanned from its
/// header line to the `];` terminator.
pub fn parse_registry(format_rs: &SourceFile) -> Vec<RegistryRow> {
    let Some(start) =
        format_rs.lines.iter().position(|l| l.code.contains("pub const FORMAT_REGISTRY"))
    else {
        return Vec::new();
    };
    let end = format_rs.lines[start..]
        .iter()
        .position(|l| l.code.trim_end().ends_with("];"))
        .map_or(format_rs.lines.len() - 1, |off| start + off);
    let body = &format_rs.lines[start..=end];
    let mut rows = Vec::new();
    let mut cur: Option<RegistryRow> = None;
    let field = |code: &str, name: &str| -> Option<String> {
        let rest = code.trim_start().strip_prefix(name)?.trim_start().strip_prefix(':')?;
        let a = rest.find('"')? + 1;
        let b = a + rest[a..].find('"')?;
        Some(rest[a..b].to_string())
    };
    for l in body {
        if l.code.contains("FormatFamily {") {
            if let Some(row) = cur.take() {
                rows.push(row);
            }
            cur = Some(RegistryRow { keyword: String::new(), suffix: String::new(), line: l.number });
        }
        if let Some(row) = cur.as_mut() {
            // Field values live in string literals, so read the raw text.
            if let Some(v) = field(&l.text, "keyword") {
                row.keyword = v;
            }
            if let Some(v) = field(&l.text, "suffix") {
                row.suffix = v;
            }
        }
    }
    rows.extend(cur);
    rows
}

/// Does `body` mention `variant` at all? Looser than [`has_arm`]: the
/// cost model's `mac_cost` imports `FormatSpec::*` and matches on tuple
/// patterns (`(Fp32, _)`, `(Fixed { bits: b1, .. }, …)`), so the check
/// accepts the bare variant name in pattern position.
fn has_mention(body: &[super::source::Line], variant: &str) -> bool {
    let pats = [
        format!("FormatSpec::{variant}"),
        format!("{variant} {{"),
        format!("({variant},"),
        format!(" {variant})"),
        format!("({variant})"),
    ];
    body.iter().any(|l| pats.iter().any(|p| l.code.contains(p.as_str())))
}

/// Does `body` contain a match arm for `variant` (+ `rounding`)?
fn has_arm(body: &[super::source::Line], variant: &str, rounding: Option<&str>) -> bool {
    let vpat = format!("FormatSpec::{variant}");
    body.iter().any(|l| {
        l.code.contains(&vpat)
            && l.code.contains("=>")
            && match rounding {
                // `Fixed { bits, .. }` arms cover both roundings; an arm
                // naming the other rounding explicitly does not.
                Some(r) => {
                    l.code.contains(&format!("Rounding::{r}"))
                        || !l.code.contains("Rounding::")
                }
                None => true,
            }
    })
}

pub fn check(tree: &Tree, findings: &mut Vec<Finding>) {
    let format_rs = tree.file("rust/src/quant/format.rs");
    let packed_rs = tree.file("rust/src/quant/packed.rs");
    let cost_rs = tree.file("rust/src/costmodel/formats.rs");
    let cli_rs = tree.file("rust/src/coordinator/cli.rs");

    let rows = parse_registry(format_rs);
    if rows.is_empty() {
        findings.push(Finding::new(
            RULE_COVERAGE,
            &format_rs.rel,
            format_rs.item_line("FORMAT_REGISTRY"),
            "FORMAT_REGISTRY not found (or empty) — the registry is the linter's ground truth",
        ));
        return;
    }

    // Duplicate rows: two families with the same spelling shadow each
    // other in the parser's lookup.
    for (i, a) in rows.iter().enumerate() {
        if rows[..i].iter().any(|b| b.name() == a.name()) {
            findings.push(Finding::new(
                RULE_COVERAGE,
                &format_rs.rel,
                a.line,
                format!("registry family '{}' is registered twice", a.name()),
            ));
        }
    }

    let quantizer = format_rs.item_body("pub fn quantize_into_stream");
    let codec_tag = packed_rs.item_body("fn codec_tag");
    let spec_from_tag = packed_rs.item_body("fn spec_from_tag");
    let storage = cost_rs.item_body("pub fn storage_bits");
    let mac = cost_rs.item_body("pub fn mac_cost");

    for row in &rows {
        let Some((variant, rounding)) = row.variant() else {
            findings.push(Finding::new(
                RULE_COVERAGE,
                &format_rs.rel,
                row.line,
                format!(
                    "registry family '{}' is unknown to the coverage map — teach \
                     analysis/coverage.rs::RegistryRow::variant about it",
                    row.name()
                ),
            ));
            continue;
        };
        let mut need = |ok: bool, file: &SourceFile, what: &str, header: &str| {
            if !ok {
                findings.push(Finding::new(
                    RULE_COVERAGE,
                    &file.rel,
                    file.item_line(header),
                    format!(
                        "registry format '{}' ({}:{}) has no {what} arm for FormatSpec::{variant}",
                        row.name(),
                        format_rs.rel,
                        row.line,
                    ),
                ));
            }
        };
        need(
            quantizer.is_some_and(|b| has_arm(b, variant, rounding)),
            format_rs,
            "quantizer",
            "pub fn quantize_into_stream",
        );
        need(
            codec_tag.is_some_and(|b| has_arm(b, variant, rounding)),
            packed_rs,
            "codec_tag",
            "fn codec_tag",
        );
        // The cost model matches on the variant shape only (`Fixed {
        // bits, .. }` prices both roundings, `mac_cost` imports
        // FormatSpec::*) — mention-level, rounding-agnostic checks.
        need(
            storage.is_some_and(|b| has_mention(b, variant)),
            cost_rs,
            "storage_bits",
            "pub fn storage_bits",
        );
        need(mac.is_some_and(|b| has_mention(b, variant)), cost_rs, "mac_cost", "pub fn mac_cost");
    }

    // spec_from_tag must invert every tag codec_tag can emit.
    if let Some(body) = codec_tag {
        let tags: Vec<(usize, String)> = body
            .iter()
            .filter(|l| l.code.contains("=>"))
            .filter_map(|l| {
                let rhs = l.code.split("=>").nth(1)?.trim().trim_end_matches(',').trim();
                rhs.parse::<u8>().ok().map(|t| (l.number, t.to_string()))
            })
            .collect();
        match spec_from_tag {
            Some(inv) => {
                for (line, tag) in &tags {
                    let covered = inv.iter().any(|l| {
                        l.code.contains("=>")
                            && l.code
                                .split("=>")
                                .next()
                                .is_some_and(|lhs| lhs.split('|').any(|p| {
                                    p.trim().split_whitespace().next() == Some(tag.as_str())
                                }))
                    });
                    if !covered {
                        findings.push(Finding::new(
                            RULE_COVERAGE,
                            &packed_rs.rel,
                            packed_rs.item_line("fn spec_from_tag"),
                            format!(
                                "codec tag {tag} (emitted at {}:{line}) has no spec_from_tag \
                                 arm — records in that format cannot be read back",
                                packed_rs.rel
                            ),
                        ));
                    }
                }
            }
            None => findings.push(Finding::new(
                RULE_COVERAGE,
                &packed_rs.rel,
                1,
                "fn spec_from_tag not found in quant/packed.rs",
            )),
        }
    }

    // Registry-driven sweeps: the benches and the CLI table must
    // enumerate the registry, not a hand-kept list.
    for bench in ["rust/benches/quantizer_hotpath.rs", "rust/benches/stash_store.rs"] {
        let f = tree.file(bench);
        if !f.code_lines().any(|l| l.code.contains("registered_specs(")) {
            findings.push(Finding::new(
                RULE_COVERAGE,
                &f.rel,
                1,
                "bench does not sweep registered_specs(…) — newly registered formats \
                 would silently go unbenchmarked",
            ));
        }
    }
    let formats_body = cli_rs.item_body("fn cmd_formats");
    if !formats_body.is_some_and(|b| b.iter().any(|l| l.code.contains("FORMAT_REGISTRY"))) {
        findings.push(Finding::new(
            RULE_COVERAGE,
            &cli_rs.rel,
            cli_rs.item_line("fn cmd_formats"),
            "`dsq formats` does not iterate FORMAT_REGISTRY — the CLI table would \
             miss newly registered formats",
        ));
    }
}
