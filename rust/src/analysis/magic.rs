//! Rule `magic_constants`: on-disk magic bytes defined once, pinned by
//! tests.
//!
//! The binary formats are guarded by 8-byte magics (`DSQCKPT1`,
//! `DSQCKPT2`, `DSQSCHD1`, the exchange wire-frame `DSQWIRE1`, and the
//! telemetry trace/manifest schema `DSQTRCE1`) plus the packed-record
//! `PACKED_VERSION` byte. Each must be:
//!
//! * **defined exactly once** (a second `const` binding — or two
//!   different consts bound to the same literal, e.g. a trailer magic
//!   accidentally reusing a checkpoint magic — makes the reader/writer
//!   pair ambiguous);
//! * **pinned by a golden-byte test**: some `#[cfg(test)]` line (or a
//!   `rust/tests/` file) must reference the literal, so changing the
//!   on-disk format without updating the compatibility tests is a lint
//!   failure, not a silent format break.

use super::{Finding, Tree, RULE_MAGIC};

/// Extract every `b"DSQ…"` 8-byte magic literal on a line.
fn magics_on(text: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut rest = text;
    while let Some(at) = rest.find("b\"DSQ") {
        let lit = &rest[at + 2..];
        if let Some(end) = lit.find('"') {
            let m = &lit[..end];
            if m.len() == 8 && m.bytes().all(|b| b.is_ascii_uppercase() || b.is_ascii_digit()) {
                out.push(m.to_string());
            }
            rest = &rest[at + 2 + end..];
        } else {
            break;
        }
    }
    out
}

struct Site {
    file: String,
    line: usize,
    is_def: bool,
    is_test: bool,
}

pub fn check(tree: &Tree, findings: &mut Vec<Finding>) {
    let mut sites: std::collections::BTreeMap<String, Vec<Site>> = Default::default();
    for f in tree.rust_files() {
        let file_is_test = f.rel.starts_with("rust/tests/");
        for l in &f.lines {
            for m in magics_on(&l.text) {
                sites.entry(m).or_default().push(Site {
                    file: f.rel.clone(),
                    line: l.number,
                    is_def: l.code.contains("const") && l.code.contains('='),
                    is_test: file_is_test || l.in_test,
                });
            }
        }
    }

    for (magic, sites) in &sites {
        let defs: Vec<&Site> = sites.iter().filter(|s| s.is_def).collect();
        match defs.len() {
            0 => {
                // Referenced but never bound to a const: the literal is
                // floating free of a single source of truth.
                let s = &sites[0];
                findings.push(Finding::new(
                    RULE_MAGIC,
                    &s.file,
                    s.line,
                    format!("magic b\"{magic}\" is used but never defined as a const"),
                ));
            }
            1 => {}
            _ => {
                for dup in &defs[1..] {
                    findings.push(Finding::new(
                        RULE_MAGIC,
                        &dup.file,
                        dup.line,
                        format!(
                            "magic b\"{magic}\" defined more than once (first at {}:{}) — \
                             two formats would share an on-disk signature",
                            defs[0].file, defs[0].line
                        ),
                    ));
                }
            }
        }
        if !defs.is_empty() && !sites.iter().any(|s| s.is_test) {
            let d = defs[0];
            findings.push(Finding::new(
                RULE_MAGIC,
                &d.file,
                d.line,
                format!(
                    "magic b\"{magic}\" has no golden-byte test reference — the on-disk \
                     format could change without any compatibility test noticing"
                ),
            ));
        }
    }

    // PACKED_VERSION: the packed-record header's version byte.
    let mut version_defs: Vec<(String, usize)> = Vec::new();
    let mut version_tested = false;
    for f in tree.rust_files() {
        let file_is_test = f.rel.starts_with("rust/tests/");
        for l in &f.lines {
            if !l.code.contains("PACKED_VERSION") {
                continue;
            }
            if l.code.contains("const PACKED_VERSION") {
                version_defs.push((f.rel.clone(), l.number));
            }
            if file_is_test || l.in_test {
                version_tested = true;
            }
        }
    }
    match version_defs.as_slice() {
        [] => findings.push(Finding::new(
            RULE_MAGIC,
            "rust/src/quant/packed.rs",
            1,
            "const PACKED_VERSION not found — the packed-record header has no version \
             source of truth",
        )),
        [_] => {}
        [first, rest @ ..] => {
            for (file, line) in rest {
                findings.push(Finding::new(
                    RULE_MAGIC,
                    file,
                    *line,
                    format!(
                        "PACKED_VERSION defined more than once (first at {}:{})",
                        first.0, first.1
                    ),
                ));
            }
        }
    }
    if !version_defs.is_empty() && !version_tested {
        let (file, line) = &version_defs[0];
        findings.push(Finding::new(
            RULE_MAGIC,
            file,
            *line,
            "PACKED_VERSION has no golden-byte test reference",
        ));
    }
}
