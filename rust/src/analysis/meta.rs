//! Rule `lint_meta`: the linter's own docs must not drift. The
//! [`super::RULES`] const, the rule table in `analysis/mod.rs`'s module
//! doc, and ROADMAP.md's "Static analysis" table must list the same
//! rule set — a linter whose documentation disagrees with its code
//! fails its own build.
//!
//! `lint_escape` is the one deliberate exception: it is the escape
//! mechanism's self-check, documented in prose next to the escape
//! syntax rather than as a table row, on both sides.
//!
//! Parsing is raw-text (`Line::text`): both tables live in comments /
//! markdown, which the `code` view blanks. A doc row is a line whose
//! trimmed text starts with `//! | \`` (mod.rs) or `| \`` (ROADMAP,
//! scoped between the `## Static analysis` header and the next `## `),
//! and the rule is the first backtick-quoted identifier.

use std::collections::BTreeSet;

use super::source::SourceFile;
use super::{Finding, Tree, RULE_ESCAPE, RULE_META, RULES};

const MOD_RS: &str = "rust/src/analysis/mod.rs";
const ROADMAP: &str = "ROADMAP.md";
const ROADMAP_HEADER: &str = "## Static analysis";

/// First backtick-quoted token of a table row, if the trimmed line
/// starts with `prefix`.
fn row_rule(text: &str, prefix: &str) -> Option<String> {
    let t = text.trim_start();
    let rest = t.strip_prefix(prefix)?;
    let rest = rest.trim_start().strip_prefix('`')?;
    let end = rest.find('`')?;
    let name = &rest[..end];
    if name.is_empty() || !name.chars().all(|c| c.is_ascii_lowercase() || c == '_') {
        return None;
    }
    Some(name.to_string())
}

/// (rules listed, line of the first row or the table vicinity).
fn mod_doc_rules(f: &SourceFile) -> (BTreeSet<String>, usize) {
    let mut rules = BTreeSet::new();
    let mut line = 1;
    for l in &f.lines {
        if let Some(r) = row_rule(&l.text, "//! |") {
            if rules.is_empty() {
                line = l.number;
            }
            rules.insert(r);
        }
    }
    (rules, line)
}

fn roadmap_rules(f: &SourceFile) -> (BTreeSet<String>, usize) {
    let mut rules = BTreeSet::new();
    let mut line = 1;
    let mut in_section = false;
    for l in &f.lines {
        let t = l.text.trim_start();
        if t.starts_with(ROADMAP_HEADER) {
            in_section = true;
            line = l.number;
            continue;
        }
        if in_section && t.starts_with("## ") {
            break;
        }
        if in_section {
            if let Some(r) = row_rule(&l.text, "|") {
                rules.insert(r);
            }
        }
    }
    (rules, line)
}

pub fn check(tree: &Tree, findings: &mut Vec<Finding>) {
    let expected: BTreeSet<String> =
        RULES.iter().filter(|r| **r != RULE_ESCAPE).map(|r| r.to_string()).collect();
    let tables: [(&str, fn(&SourceFile) -> (BTreeSet<String>, usize), &str); 2] = [
        (MOD_RS, mod_doc_rules, "analysis/mod.rs module-doc rule table"),
        (ROADMAP, roadmap_rules, "ROADMAP \"Static analysis\" table"),
    ];
    for (rel, parse, what) in tables {
        let (rows, line) = parse(tree.file(rel));
        for missing in expected.difference(&rows) {
            findings.push(Finding::new(
                RULE_META,
                rel,
                line,
                format!(
                    "{what} is missing a row for rule '{missing}' — the RULES const, \
                     the module-doc table, and the ROADMAP table must list the same rules"
                ),
            ));
        }
        for extra in rows.difference(&expected) {
            findings.push(Finding::new(
                RULE_META,
                rel,
                line,
                format!(
                    "{what} lists '{extra}', which is not in the RULES const — \
                     delete the row or implement the rule"
                ),
            ));
        }
    }
}
