//! Rule `panic_hygiene`: no silent aborts on the training hot path.
//!
//! The stash store, the Session engine, and the packed codec run on
//! every training step; a panic there tears down a run (and any future
//! daemon serving many runs) instead of surfacing a contextual
//! [`crate::Error`]. This rule denies `unwrap()` / `expect(…)` /
//! `panic!` / `unreachable!` / `todo!` / `unimplemented!` in the
//! hot-path modules outside `#[cfg(test)]`.
//!
//! Provably-infallible sites carry an escape:
//!
//! ```text
//! // dsq-lint: allow(panic_hygiene, <why this cannot fire>)
//! ```
//!
//! on the same or the preceding line. The reason is mandatory — an
//! empty one is itself a finding — so every surviving panic documents
//! its impossibility argument at the site.

use super::{Finding, Tree, RULE_PANIC};

/// Modules on the per-step hot path. The `stash/` prefix covers the
/// whole tiered store *including* the replica exchange
/// (`stash/exchange.rs`); the trainer/finetune adapters drive the
/// Session loop on every run, so they are held to the same bar. The
/// obs recorder rides inside every instrumented step, so a panic there
/// would kill exactly the runs it is meant to observe.
pub const HOT_PATHS: &[&str] = &[
    "rust/src/stash/",
    "rust/src/coordinator/session.rs",
    "rust/src/coordinator/trainer.rs",
    "rust/src/coordinator/finetune.rs",
    "rust/src/obs/",
    "rust/src/quant/packed.rs",
];

/// Panic-class tokens (searched in comment/string-stripped code).
const DENIED: &[&str] =
    &[".unwrap()", ".expect(", "panic!(", "unreachable!(", "todo!(", "unimplemented!("];

pub fn check(tree: &Tree, findings: &mut Vec<Finding>) {
    for f in tree.rust_files() {
        if !HOT_PATHS.iter().any(|p| f.rel.starts_with(p)) {
            continue;
        }
        for l in f.code_lines() {
            for tok in DENIED {
                if l.code.contains(tok) {
                    findings.push(Finding::new(
                        RULE_PANIC,
                        &f.rel,
                        l.number,
                        format!(
                            "`{}` on the hot path — return a contextual crate::Error, or \
                             annotate with `// dsq-lint: allow(panic_hygiene, <reason>)` \
                             if provably infallible",
                            tok.trim_start_matches('.')
                        ),
                    ));
                }
            }
        }
    }
}
