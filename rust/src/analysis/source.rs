//! Lexical substrate of `dsq lint`: files as annotated line streams.
//!
//! The linter never builds an AST — every rule works on lines that have
//! been pre-annotated with the three facts the rules need:
//!
//! * `code`: the line with string literals blanked and `//` comments
//!   stripped, so token scans (`.unwrap()`, `=>`, `.lock()`) cannot
//!   match inside strings or prose;
//! * `in_test`: whether the line sits inside a `#[cfg(test)]` item
//!   (tracked by brace depth), so hot-path rules skip test code;
//! * `allow`: a parsed `// dsq-lint: allow(<rule>, <reason>)` escape,
//!   which suppresses findings of `<rule>` on the same and the next
//!   line.
//!
//! Known lexical limits (documented, not bugs): block comments
//! (`/* */`) are not tracked — the tree is rustfmt'd and uses line
//! comments throughout — and raw strings are treated as plain strings.

/// One annotated source line.
pub struct Line {
    /// 1-based line number.
    pub number: usize,
    /// Raw text (used by the magic-byte scan, which must see literals).
    pub text: String,
    /// Text with string/char literals blanked and `//` comments cut.
    pub code: String,
    /// Inside a `#[cfg(test)]` item.
    pub in_test: bool,
    /// `dsq-lint: allow(<rule>, <reason>)` directive on this line.
    pub allow: Option<(String, String)>,
}

/// One loaded file: repo-relative path + annotated lines.
pub struct SourceFile {
    /// Repo-relative path, forward slashes.
    pub rel: String,
    pub lines: Vec<Line>,
}

/// Blank string/char literal contents and strip `//` comments so token
/// scans see only code. Handles `"…"` (with escapes), `b"…"`, and
/// character literals (`'x'`, `'\n'`) without tripping on lifetimes.
fn strip_to_code(text: &str) -> String {
    let bytes = text.as_bytes();
    let mut out = String::with_capacity(text.len());
    let mut i = 0;
    while i < bytes.len() {
        let c = bytes[i] as char;
        match c {
            '/' if i + 1 < bytes.len() && bytes[i + 1] == b'/' => break,
            '"' => {
                out.push('"');
                i += 1;
                while i < bytes.len() {
                    match bytes[i] {
                        b'\\' => {
                            out.push(' ');
                            if i + 1 < bytes.len() {
                                out.push(' ');
                            }
                            i += 2;
                        }
                        b'"' => {
                            out.push('"');
                            i += 1;
                            break;
                        }
                        _ => {
                            out.push(' ');
                            i += 1;
                        }
                    }
                }
            }
            '\'' => {
                // Char literal iff it closes within a few bytes
                // (`'x'`, `'\n'`, `'\u{7f}'`); otherwise a lifetime.
                let close = (i + 1..bytes.len().min(i + 12)).find(|&j| {
                    bytes[j] == b'\'' && !(j == i + 1) && bytes[j - 1] != b'\\'
                });
                match close {
                    Some(j) if bytes[i + 1] == b'\\' || j == i + 2 => {
                        for _ in i..=j {
                            out.push(' ');
                        }
                        i = j + 1;
                    }
                    _ => {
                        out.push('\'');
                        i += 1;
                    }
                }
            }
            _ => {
                out.push(c);
                i += 1;
            }
        }
    }
    out
}

/// Parse a `dsq-lint: allow(<rule>, <reason>)` directive from raw
/// text. The rule must be a bare `snake_case` identifier — so prose
/// *describing* the directive syntax with `<rule>`-style placeholders
/// (this module's docs, for one) never registers as an escape.
fn parse_allow(text: &str) -> Option<(String, String)> {
    let at = text.find("dsq-lint: allow(")?;
    let inner = &text[at + "dsq-lint: allow(".len()..];
    let close = inner.rfind(')')?;
    let inner = &inner[..close];
    let (rule, reason) = match inner.split_once(',') {
        Some((r, why)) => (r.trim().to_string(), why.trim().to_string()),
        None => (inner.trim().to_string(), String::new()),
    };
    if rule.is_empty() || !rule.chars().all(|c| c.is_ascii_lowercase() || c == '_') {
        return None;
    }
    Some((rule, reason))
}

impl SourceFile {
    /// Annotate `content` as the file at `rel` (repo-relative path).
    pub fn parse(rel: &str, content: &str) -> SourceFile {
        let mut lines = Vec::new();
        let mut depth: i64 = 0;
        // `Some(d)` while inside a #[cfg(test)] item that opened at
        // brace depth `d`; `Pending` between the attribute and its item
        // body.
        let mut test_at: Option<i64> = None;
        let mut test_pending = false;
        let mut test_pending_since: i64 = 0;
        for (idx, raw) in content.lines().enumerate() {
            let code = strip_to_code(raw);
            let opens = code.matches('{').count() as i64;
            let closes = code.matches('}').count() as i64;

            let mut in_test = test_at.is_some() || test_pending;
            if !in_test && code.contains("#[cfg(test)]") {
                test_pending = true;
                test_pending_since = depth;
                in_test = true;
            }

            depth += opens - closes;

            if test_pending {
                if opens > 0 {
                    // The item body opened; the region lives until depth
                    // returns to the attribute's level.
                    test_at = Some(test_pending_since);
                    test_pending = false;
                } else if code.trim_end().ends_with(';') {
                    // Braceless item (`#[cfg(test)] use …;`).
                    test_pending = false;
                }
            }
            if let Some(d) = test_at {
                if depth <= d {
                    test_at = None; // closing line still counts as test
                }
            }

            lines.push(Line {
                number: idx + 1,
                text: raw.to_string(),
                code,
                in_test,
                allow: parse_allow(raw),
            });
        }
        SourceFile { rel: rel.to_string(), lines }
    }

    /// Non-test lines (the hot-path rules' view).
    pub fn code_lines(&self) -> impl Iterator<Item = &Line> {
        self.lines.iter().filter(|l| !l.in_test)
    }

    /// The body of the item whose header line contains `header_pat`
    /// (e.g. `"fn codec_tag"`): the lines from the header through the
    /// matching closing brace. `None` if the header is absent.
    pub fn item_body(&self, header_pat: &str) -> Option<&[Line]> {
        let start = self.lines.iter().position(|l| l.code.contains(header_pat))?;
        let mut depth = 0i64;
        let mut opened = false;
        for (off, l) in self.lines[start..].iter().enumerate() {
            depth += l.code.matches('{').count() as i64;
            depth -= l.code.matches('}').count() as i64;
            if l.code.contains('{') {
                opened = true;
            }
            if opened && depth <= 0 {
                return Some(&self.lines[start..=start + off]);
            }
        }
        Some(&self.lines[start..])
    }

    /// Python sibling of [`Self::item_body`]: the `def` whose header
    /// line contains `header_pat`, delimited by indentation (blank
    /// lines inside the body are kept).
    pub fn item_py_body(&self, header_pat: &str) -> Option<&[Line]> {
        let start = self.lines.iter().position(|l| l.text.contains(header_pat))?;
        let indent_of = |s: &str| s.len() - s.trim_start().len();
        let indent = indent_of(&self.lines[start].text);
        let mut end = start;
        for (off, l) in self.lines[start + 1..].iter().enumerate() {
            if l.text.trim().is_empty() {
                continue;
            }
            if indent_of(&l.text) <= indent {
                break;
            }
            end = start + 1 + off;
        }
        Some(&self.lines[start..=end])
    }

    /// Line number of the item header containing `header_pat` (1 when
    /// absent, so findings always carry a clickable location).
    pub fn item_line(&self, header_pat: &str) -> usize {
        self.lines
            .iter()
            .find(|l| l.code.contains(header_pat))
            .map(|l| l.number)
            .unwrap_or(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strings_and_comments_are_blanked() {
        let c = strip_to_code(r#"let x = "a.unwrap()"; // .expect(boom)"#);
        assert!(!c.contains("unwrap"));
        assert!(!c.contains("expect"));
        assert!(c.contains("let x ="));
    }

    #[test]
    fn char_literals_do_not_eat_the_line() {
        let c = strip_to_code("if c == '\"' { x.unwrap() }");
        assert!(c.contains(".unwrap()"), "{c}");
        let c = strip_to_code("fn f<'a>(x: &'a str) { x.unwrap() }");
        assert!(c.contains(".unwrap()"), "{c}");
    }

    #[test]
    fn cfg_test_regions_are_marked() {
        let src = "fn hot() {\n    x.unwrap();\n}\n#[cfg(test)]\nmod tests {\n    fn t() { y.unwrap(); }\n}\nfn hot2() { z.unwrap(); }\n";
        let f = SourceFile::parse("x.rs", src);
        let tests: Vec<usize> =
            f.lines.iter().filter(|l| l.in_test).map(|l| l.number).collect();
        assert_eq!(tests, vec![4, 5, 6, 7]);
        assert!(!f.lines[7].in_test, "code after the test mod is hot again");
    }

    #[test]
    fn braceless_cfg_test_item_closes() {
        let src = "#[cfg(test)]\nuse foo::bar;\nfn hot() { x.unwrap(); }\n";
        let f = SourceFile::parse("x.rs", src);
        assert!(f.lines[1].in_test);
        assert!(!f.lines[2].in_test);
    }

    #[test]
    fn allow_directives_parse() {
        let f = SourceFile::parse(
            "x.rs",
            "// dsq-lint: allow(panic_hygiene, guarded by is_passthrough above)\nx.unwrap();\n",
        );
        let (rule, reason) = f.lines[0].allow.clone().unwrap();
        assert_eq!(rule, "panic_hygiene");
        assert!(reason.contains("is_passthrough"));
    }

    #[test]
    fn allow_placeholders_in_prose_do_not_register() {
        let f = SourceFile::parse(
            "x.rs",
            "//! Escapes: `// dsq-lint: allow(<rule>, <reason>)` suppress findings.\n",
        );
        assert!(f.lines[0].allow.is_none(), "angle-bracket placeholders are prose, not escapes");
    }

    #[test]
    fn item_body_spans_the_braces() {
        let src = "fn a() {\n  1\n}\nfn b() {\n  2\n}\n";
        let f = SourceFile::parse("x.rs", src);
        let body = f.item_body("fn a").unwrap();
        assert_eq!(body.len(), 3);
        assert_eq!(f.item_line("fn b"), 4);
    }
}
