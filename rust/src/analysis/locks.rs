//! Rule `lock_discipline`: consistent mutex acquisition order in the
//! stash store.
//!
//! The stash store pairs an LRU/budget path with a background readback
//! prefetcher; the moment those two share mutexes, an inconsistent
//! acquisition order is a deadlock waiting for load. This rule scans
//! the stash (and Session) modules for `.lock()` acquisitions, records
//! the order in which each function takes distinct locks, and flags any
//! pair of locks acquired in *both* orders somewhere in the scanned
//! modules.
//!
//! The analysis is lexical and conservative: within one function, lock
//! A "precedes" lock B if A's `.lock()` call appears on an earlier (or
//! the same) line — guard drops are not tracked, so a function that
//! releases A before taking B still contributes an A→B edge. Since
//! PR 7 the rule is live: the replica exchange
//! (`rust/src/stash/exchange.rs`) holds two mutexes (the `ring` post
//! board and the `comms` traffic meter) shared by every replica thread,
//! with the global order *ring before comms*. A deliberate, commented
//! opposite-order pair can be escaped with
//! `// dsq-lint: allow(lock_discipline, <reason>)`.

use std::collections::BTreeMap;

use super::{Finding, Tree, RULE_LOCKS};

/// Modules the order graph is built over.
const SCOPES: &[&str] = &["rust/src/stash/", "rust/src/coordinator/session.rs"];

/// One lock-acquisition site.
#[derive(Clone)]
struct Acq {
    lock: String,
    file: String,
    func: String,
    line: usize,
}

/// Receiver of a `.lock()` call: the dotted ident chain before it,
/// without a leading `self.` (so `self.index.lock()` and
/// `store.index.lock()` name the same lock field).
fn receiver(code: &str, at: usize) -> Option<String> {
    let head = &code[..at];
    let start = head
        .rfind(|c: char| !(c.is_ascii_alphanumeric() || c == '_' || c == '.'))
        .map(|i| i + 1)
        .unwrap_or(0);
    let chain = head[start..].trim_matches('.');
    if chain.is_empty() {
        return None;
    }
    let tail: Vec<&str> = chain.split('.').filter(|s| *s != "self").collect();
    // The lock is named by the field, not the path to it.
    tail.last().map(|s| s.to_string())
}

pub fn check(tree: &Tree, findings: &mut Vec<Finding>) {
    // Per-function ordered acquisitions.
    let mut funcs: Vec<Vec<Acq>> = Vec::new();
    for f in tree.rust_files() {
        if !SCOPES.iter().any(|p| f.rel.starts_with(p)) {
            continue;
        }
        let mut cur: Option<(String, Vec<Acq>)> = None;
        for l in f.code_lines() {
            if let Some(at) = l.code.find("fn ") {
                let name: String = l.code[at + 3..]
                    .chars()
                    .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
                    .collect();
                if !name.is_empty() && l.code.contains('(') {
                    if let Some((_, acqs)) = cur.take() {
                        funcs.push(acqs);
                    }
                    cur = Some((name, Vec::new()));
                }
            }
            let mut rest = l.code.as_str();
            let mut off = 0;
            while let Some(at) = rest.find(".lock()") {
                if let (Some((func, acqs)), Some(lock)) =
                    (cur.as_mut(), receiver(&l.code, off + at))
                {
                    acqs.push(Acq {
                        lock,
                        file: f.rel.clone(),
                        func: func.clone(),
                        line: l.number,
                    });
                }
                off += at + ".lock()".len();
                rest = &rest[at + ".lock()".len()..];
            }
        }
        if let Some((_, acqs)) = cur.take() {
            funcs.push(acqs);
        }
    }

    // Order edges: (a, b) -> first site where a was taken before b.
    let mut edges: BTreeMap<(String, String), (Acq, Acq)> = BTreeMap::new();
    for acqs in &funcs {
        for (i, a) in acqs.iter().enumerate() {
            for b in &acqs[i + 1..] {
                if a.lock != b.lock {
                    edges
                        .entry((a.lock.clone(), b.lock.clone()))
                        .or_insert_with(|| (a.clone(), b.clone()));
                }
            }
        }
    }
    for ((a, b), (sa, sb)) in &edges {
        if a < b {
            if let Some((ra, rb)) = edges.get(&(b.clone(), a.clone())) {
                findings.push(Finding::new(
                    RULE_LOCKS,
                    &sa.file,
                    sa.line,
                    format!(
                        "locks '{a}' and '{b}' are acquired in both orders: \
                         {}::{} takes {a} then {b} ({}:{} → {}:{}), but {}::{} takes \
                         {b} then {a} ({}:{} → {}:{}) — pick one global order",
                        sa.file, sa.func, sa.file, sa.line, sb.file, sb.line, //
                        ra.file, ra.func, ra.file, ra.line, rb.file, rb.line,
                    ),
                ));
            }
        }
    }
}
