//! Rule `lock_discipline`: one global mutex acquisition order across
//! the stash/coordinator modules — now interprocedural.
//!
//! The stash store pairs an LRU/budget path with a background readback
//! prefetcher, and since PR 7 the replica exchange
//! (`rust/src/stash/exchange.rs`) holds two mutexes (the `ring` post
//! board and the `comms` traffic meter) shared by every replica thread,
//! with the global order *ring before comms*. The moment two code paths
//! acquire a shared pair in opposite orders, a deadlock is waiting for
//! load — and the inversion is invisible to any per-function scan when
//! lock A is taken in `f`, which then calls `g`, which takes lock B.
//!
//! This rule builds the lexical call graph ([`super::callgraph`]) over
//! [`SCOPES`], propagates held-lock sets along call edges to a bounded
//! fixpoint, and flags any lock pair observed in both orders — naming
//! the full call path (`f -> g -> .lock()`) on each side, so a
//! cross-function AB/BA split reads as the single ordering bug it is.
//!
//! The analysis is lexical and conservative: guard drops are not
//! tracked (a function that releases A before taking B still
//! contributes an A→B edge), and a helper that *returns* a guard does
//! not extend its caller's held set. A deliberate, commented
//! opposite-order pair can be escaped with
//! `// dsq-lint: allow(lock_discipline, <reason>)`.
//!
//! [`check_per_function`] keeps the superseded PR-6 per-function scan
//! alive as a baseline: the drift fixtures prove the interprocedural
//! upgrade is load-bearing by exhibiting an inversion the old logic
//! provably misses.

use std::collections::BTreeMap;

use super::callgraph::{Graph, OrderPair};
use super::{Finding, Tree, RULE_LOCKS};

/// Modules the order graph is built over: the whole stash layer, the
/// whole coordinator (the session loop plus the trainer/finetune
/// adapters that drive it), and the obs recorder (whose `obsbuf` mutex
/// must stay memory-only — its file I/O runs off-lock).
pub const SCOPES: &[&str] = &["rust/src/stash/", "rust/src/coordinator/", "rust/src/obs/"];

pub fn check(tree: &Tree, findings: &mut Vec<Finding>) {
    let graph = Graph::build(tree.rust_files(), SCOPES);
    // Representative pair per ordered lock pair (first observation wins
    // — the walk is deterministic, so findings are stable).
    let mut edges: BTreeMap<(String, String), &OrderPair> = BTreeMap::new();
    for p in graph.order_pairs() {
        edges.entry((p.first_lock.clone(), p.second.lock.clone())).or_insert(p);
    }
    for ((a, b), ab) in &edges {
        if a >= b {
            continue;
        }
        let Some(ba) = edges.get(&(b.clone(), a.clone())) else { continue };
        findings.push(Finding::new(
            RULE_LOCKS,
            &ab.first_file,
            ab.first_line,
            format!(
                "locks '{a}' and '{b}' are acquired in both orders: {} holds '{a}' \
                 ({}:{}) and then acquires '{b}' via {} -> .lock(), but {} holds '{b}' \
                 ({}:{}) and then acquires '{a}' via {} -> .lock() — pick one global order",
                ab.first_func,
                ab.first_file,
                ab.first_line,
                Graph::chain_display(&ab.second.chain),
                ba.first_func,
                ba.first_file,
                ba.first_line,
                Graph::chain_display(&ba.second.chain),
            ),
        ));
    }
}

/// One lock-acquisition site (per-function baseline).
#[derive(Clone)]
struct Acq {
    lock: String,
    file: String,
    func: String,
    line: usize,
}

/// The superseded per-function order scan (PR 6): within one function,
/// lock A "precedes" lock B if A's `.lock()` call appears on an earlier
/// (or the same) line. Kept so the drift fixtures can demonstrate the
/// inversion classes it cannot see; [`check`] is the live rule.
pub fn check_per_function(tree: &Tree, findings: &mut Vec<Finding>) {
    let mut funcs: Vec<Vec<Acq>> = Vec::new();
    for f in tree.rust_files() {
        if !SCOPES.iter().any(|p| f.rel.starts_with(p)) {
            continue;
        }
        let mut cur: Option<(String, Vec<Acq>)> = None;
        for l in f.code_lines() {
            if let Some(at) = l.code.find("fn ") {
                let name: String = l.code[at + 3..]
                    .chars()
                    .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
                    .collect();
                if !name.is_empty() && l.code.contains('(') {
                    if let Some((_, acqs)) = cur.take() {
                        funcs.push(acqs);
                    }
                    cur = Some((name, Vec::new()));
                }
            }
            let mut from = 0;
            while let Some(at) = l.code[from..].find(".lock()") {
                let col = from + at;
                if let (Some((func, acqs)), Some(lock)) =
                    (cur.as_mut(), super::callgraph::receiver(&l.code, col))
                {
                    acqs.push(Acq {
                        lock,
                        file: f.rel.clone(),
                        func: func.clone(),
                        line: l.number,
                    });
                }
                from = col + ".lock()".len();
            }
        }
        if let Some((_, acqs)) = cur.take() {
            funcs.push(acqs);
        }
    }

    let mut edges: BTreeMap<(String, String), (Acq, Acq)> = BTreeMap::new();
    for acqs in &funcs {
        for (i, a) in acqs.iter().enumerate() {
            for b in &acqs[i + 1..] {
                if a.lock != b.lock {
                    edges
                        .entry((a.lock.clone(), b.lock.clone()))
                        .or_insert_with(|| (a.clone(), b.clone()));
                }
            }
        }
    }
    for ((a, b), (sa, sb)) in &edges {
        if a < b {
            if let Some((ra, rb)) = edges.get(&(b.clone(), a.clone())) {
                findings.push(Finding::new(
                    RULE_LOCKS,
                    &sa.file,
                    sa.line,
                    format!(
                        "locks '{a}' and '{b}' are acquired in both orders: \
                         {}::{} takes {a} then {b} ({}:{} → {}:{}), but {}::{} takes \
                         {b} then {a} ({}:{} → {}:{}) — pick one global order",
                        sa.file, sa.func, sa.file, sa.line, sb.file, sb.line, //
                        ra.file, ra.func, ra.file, ra.line, rb.file, rb.line,
                    ),
                ));
            }
        }
    }
}
