//! Rule `qcfg_sync`: the cross-language `(mode, bits)` contract.
//!
//! The qcfg vector is the one value that crosses the rust/python
//! boundary at runtime: `FormatSpec::mode_scalar` (rust) encodes a
//! format family as a float mode, and `layers.py::quantize` (python,
//! baked into the AOT artifact) dispatches on that same float. PR 4's
//! costliest bug was exactly these two tables drifting apart — no unit
//! test on either side could see it. This rule diffs them on every
//! build:
//!
//! * the arms of `FormatSpec::mode_scalar` vs the `MODE_*` constants in
//!   `python/compile/layers.py` (the greppable python mode table);
//! * the python dispatch helpers must *use* the `MODE_*` constants —
//!   a raw `mode == 2.0` literal would let the table rot silently;
//! * the float-width packing (`100·E + M`) spelled identically in
//!   `FormatSpec::qcfg_bits` and `kernels/ref.py::float_code`;
//! * the artifact variant lists: `layers.py::_VARIANTS`, the
//!   `train_<v>`/`quant_select_<v>` export keys and `endswith("_<v>")`
//!   dispatch in `aot.py`, and the `"train_<v>"` routing literals in
//!   `runtime/artifact.rs::train_variant_for`;
//! * every registry family must map to a python variant family that is
//!   actually in `_VARIANTS`.

use std::collections::BTreeMap;

use super::coverage::parse_registry;
use super::source::SourceFile;
use super::{Finding, Tree, RULE_QCFG};

/// Family keys shared by both language's mode tables.
const FAMILIES: &[&str] = &["fp32", "fixed", "bfp", "fixedsr", "float", "floatsr"];

/// Parse `fn mode_scalar`'s arms into family → (mode, line).
fn rust_modes(format_rs: &SourceFile) -> BTreeMap<String, (f64, usize)> {
    let mut out = BTreeMap::new();
    let Some(body) = format_rs.item_body("pub fn mode_scalar") else {
        return out;
    };
    for l in body {
        let Some((lhs, rhs)) = l.code.split_once("=>") else { continue };
        let Ok(mode) = rhs.trim().trim_end_matches(',').parse::<f64>() else { continue };
        let family = if lhs.contains("Fp32") {
            "fp32"
        } else if lhs.contains("Fixed") && lhs.contains("Stochastic") {
            "fixedsr"
        } else if lhs.contains("Fixed") {
            "fixed"
        } else if lhs.contains("Bfp") {
            "bfp"
        } else if lhs.contains("Float") && lhs.contains("Stochastic") {
            "floatsr"
        } else if lhs.contains("Float") {
            "float"
        } else {
            continue;
        };
        out.insert(family.to_string(), (mode, l.number));
    }
    out
}

/// Parse the `MODE_<FAMILY> = <float>` constants out of `layers.py`.
fn python_modes(layers_py: &SourceFile) -> BTreeMap<String, (f64, usize)> {
    let mut out = BTreeMap::new();
    for l in &layers_py.lines {
        let t = l.text.trim();
        let Some(rest) = t.strip_prefix("MODE_") else { continue };
        let Some((name, value)) = rest.split_once('=') else { continue };
        let Ok(mode) = value.trim().parse::<f64>() else { continue };
        let family = name.trim().to_ascii_lowercase().replace('_', "");
        out.insert(family, (mode, l.number));
    }
    out
}

/// Parse `_VARIANTS = ("both", "bfp", …)` from `layers.py`.
fn python_variants(layers_py: &SourceFile) -> (Vec<String>, usize) {
    for l in &layers_py.lines {
        if let Some(rest) = l.text.trim().strip_prefix("_VARIANTS") {
            let names = rest
                .split('"')
                .skip(1)
                .step_by(2)
                .map(str::to_string)
                .collect();
            return (names, l.number);
        }
    }
    (Vec::new(), 1)
}

pub fn check(tree: &Tree, findings: &mut Vec<Finding>) {
    let format_rs = tree.file("rust/src/quant/format.rs");
    let layers_py = tree.file("python/compile/layers.py");
    let aot_py = tree.file("python/compile/aot.py");
    let ref_py = tree.file("python/compile/kernels/ref.py");
    let artifact_rs = tree.file("rust/src/runtime/artifact.rs");

    // ----- mode table diff ------------------------------------------------
    let rust = rust_modes(format_rs);
    let python = python_modes(layers_py);
    for &family in FAMILIES {
        match (rust.get(family), python.get(family)) {
            (Some(&(rm, rl)), Some(&(pm, pl))) => {
                if rm != pm {
                    findings.push(Finding::new(
                        RULE_QCFG,
                        &layers_py.rel,
                        pl,
                        format!(
                            "mode constant drift for family '{family}': python MODE table \
                             says {pm} but FormatSpec::mode_scalar ({}:{rl}) says {rm} — \
                             the artifact would dispatch this family to the wrong kernel",
                            format_rs.rel
                        ),
                    ));
                }
            }
            (Some(&(_, rl)), None) => findings.push(Finding::new(
                RULE_QCFG,
                &layers_py.rel,
                1,
                format!(
                    "family '{family}' has a rust mode ({}:{rl}) but no MODE_* constant \
                     in layers.py's mode table",
                    format_rs.rel
                ),
            )),
            (None, Some(&(_, pl))) => findings.push(Finding::new(
                RULE_QCFG,
                &layers_py.rel,
                pl,
                format!("python MODE constant for '{family}' has no FormatSpec::mode_scalar arm"),
            )),
            (None, None) => findings.push(Finding::new(
                RULE_QCFG,
                &format_rs.rel,
                format_rs.item_line("pub fn mode_scalar"),
                format!("family '{family}' missing from both mode tables"),
            )),
        }
    }
    // Modes must be distinct on each side (two families sharing a mode
    // scalar would alias in the artifact).
    for (side, table) in [("rust", &rust), ("python", &python)] {
        let mut seen: BTreeMap<u64, &str> = BTreeMap::new();
        for (family, &(mode, line)) in table {
            if let Some(prev) = seen.insert(mode.to_bits(), family) {
                let (file, line) = if side == "rust" {
                    (&format_rs.rel, line)
                } else {
                    (&layers_py.rel, line)
                };
                findings.push(Finding::new(
                    RULE_QCFG,
                    file,
                    line,
                    format!("{side} mode table: families '{prev}' and '{family}' share mode {mode}"),
                ));
            }
        }
    }

    // The python dispatch helpers must consume the table, not literals.
    for helper in ["def _fixed_like", "def _float_like", "def quantize("] {
        if let Some(body) = layers_py.item_py_body(helper) {
            for l in body {
                let code = l.text.split('#').next().unwrap_or("");
                if let Some(at) = code.find("mode ==") {
                    let rhs = code[at + "mode ==".len()..].trim_start();
                    if rhs.starts_with(|c: char| c.is_ascii_digit()) {
                        findings.push(Finding::new(
                            RULE_QCFG,
                            &layers_py.rel,
                            l.number,
                            "mode dispatch compares against a raw literal — use the MODE_* \
                             table so `dsq lint` can diff it against FormatSpec::mode_scalar",
                        ));
                    }
                }
            }
        } else {
            findings.push(Finding::new(
                RULE_QCFG,
                &layers_py.rel,
                1,
                format!("dispatch helper `{helper}` not found in layers.py"),
            ));
        }
    }

    // ----- float width packing (100·E + M) --------------------------------
    const PACKING: &str = "100 * exp_bits + man_bits";
    for (f, ctx) in [(format_rs, "FormatSpec::qcfg_bits"), (ref_py, "float_code")] {
        if !f.lines.iter().any(|l| l.text.contains(PACKING)) {
            findings.push(Finding::new(
                RULE_QCFG,
                &f.rel,
                1,
                format!(
                    "float qcfg width packing `{PACKING}` not spelled in {ctx} — the two \
                     sides of the 100·E+M convention must stay literally greppable"
                ),
            ));
        }
    }

    // ----- artifact variant lists -----------------------------------------
    let (variants, vline) = python_variants(layers_py);
    if variants.is_empty() {
        findings.push(Finding::new(
            RULE_QCFG,
            &layers_py.rel,
            vline,
            "_VARIANTS tuple not found in layers.py",
        ));
        return;
    }
    let aot_text = |pat: &str| aot_py.lines.iter().any(|l| l.text.contains(pat));
    for v in &variants {
        for key in [format!("\"train_{v}\""), format!("\"quant_select_{v}\"")] {
            if !aot_text(&key) {
                findings.push(Finding::new(
                    RULE_QCFG,
                    &aot_py.rel,
                    1,
                    format!(
                        "variant '{v}' ({}:{vline}) has no {key} export in aot.py",
                        layers_py.rel
                    ),
                ));
            }
        }
        // "both" is the suffix-dispatch fallback; the single-family
        // variants each need an endswith arm.
        if v != "both" && !aot_text(&format!("endswith(\"_{v}\")")) {
            findings.push(Finding::new(
                RULE_QCFG,
                &aot_py.rel,
                1,
                format!("aot.py main() has no endswith(\"_{v}\") dispatch for variant '{v}'"),
            ));
        }
        // The rust router must be able to pick the variant.
        if !artifact_rs
            .code_lines()
            .any(|l| l.text.contains(&format!("\"train_{v}\"")))
        {
            findings.push(Finding::new(
                RULE_QCFG,
                &artifact_rs.rel,
                artifact_rs.item_line("pub fn train_variant_for"),
                format!("runtime/artifact.rs never routes to \"train_{v}\" (variant '{v}')"),
            ));
        }
    }
    // Reverse direction: every set_quantizers("X") literal in aot.py
    // must name a registered variant.
    for l in &aot_py.lines {
        if let Some(at) = l.text.find("set_quantizers(\"") {
            let rest = &l.text[at + "set_quantizers(\"".len()..];
            if let Some(end) = rest.find('"') {
                let v = &rest[..end];
                if !variants.iter().any(|x| x == v) {
                    findings.push(Finding::new(
                        RULE_QCFG,
                        &aot_py.rel,
                        l.number,
                        format!("set_quantizers(\"{v}\") names a variant not in _VARIANTS"),
                    ));
                }
            }
        }
    }
    // Every registry family must land in a compiled variant.
    for row in parse_registry(format_rs) {
        let needed = match (row.keyword.as_str(), row.suffix.as_str()) {
            ("fp", "") => None, // identity in every variant
            ("fixed", _) => Some("fixed"),
            ("bfp", _) => Some("bfp"),
            ("fp", s) if s.starts_with('e') => Some("float"),
            _ => None, // unknown families are registry_coverage findings
        };
        if let Some(v) = needed {
            if !variants.iter().any(|x| x == v) {
                findings.push(Finding::new(
                    RULE_QCFG,
                    &layers_py.rel,
                    vline,
                    format!(
                        "registry family '{}' ({}:{}) needs python variant '{v}', which is \
                         not in _VARIANTS",
                        row.name(),
                        format_rs.rel,
                        row.line
                    ),
                ));
            }
        }
    }
}
