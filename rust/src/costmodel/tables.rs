//! Normalized table rows (the paper's "Arith Ops (↓)" and "DRAM R/W (↓)"
//! columns, fixed-point-32 ≡ 1.00×) and the standard method lists.

use super::training::{fixed32_reference, step_cost, StepCost};
use super::workload::TransformerWorkload;
use crate::schedule::{FormatSpec, PrecisionConfig};

/// One table row: a method + its relative hardware costs.
#[derive(Clone, Debug)]
pub struct CostRow {
    pub method: String,
    pub precision: String,
    /// Relative arithmetic cost (fixed32 = 1.0); None for unscored rows
    /// (the paper leaves fp32 rows as "-").
    pub arith_rel: Option<f64>,
    pub dram_rel: Option<f64>,
    /// Absolute per-step cost (for roofline / cumulative accounting).
    pub step: StepCost,
}

impl CostRow {
    pub fn fmt_paper_style(&self) -> String {
        let fmt = |v: Option<f64>| match v {
            None => "      -".to_string(),
            Some(x) if x < 0.1 => format!("{x:7.3}x"),
            Some(x) => format!("{x:7.2}x"),
        };
        format!(
            "{:<18} {:<16} {} {}",
            self.method,
            self.precision,
            fmt(self.arith_rel),
            fmt(self.dram_rel)
        )
    }
}

/// Relative costs for a static config on a workload.
pub fn normalized_row(
    w: &TransformerWorkload,
    method: &str,
    p: &PrecisionConfig,
    score: bool,
) -> CostRow {
    let base = fixed32_reference(w);
    let c = step_cost(w, p);
    CostRow {
        method: method.to_string(),
        precision: p.notation(),
        arith_rel: score.then_some(c.arith_macs / base.arith_macs),
        dram_rel: score.then_some(c.dram_bits / base.dram_bits),
        step: c,
    }
}

/// Relative cost of a *schedule trace*: per-level step counts from a DSQ
/// run, time-weighted (this is how the paper's DSQ rows are produced).
///
/// An empty trace, or one that only ever ran the fp32 reference config,
/// is unscored (`arith_rel`/`dram_rel` = `None`) — the paper deliberately
/// leaves fp32 out of the relative columns, and callers must not divide
/// by a zero-step average.
pub fn dsq_trace_row(
    w: &TransformerWorkload,
    trace: &[(PrecisionConfig, usize)],
) -> CostRow {
    let base = fixed32_reference(w);
    let total_steps: usize = trace.iter().map(|(_, n)| n).sum();
    let scored = total_steps > 0 && trace.iter().any(|(p, n)| *n > 0 && !p.is_fp32());
    let mut acc = StepCost::default();
    for (p, n) in trace {
        acc.add(&step_cost(w, p).scale(*n as f64));
    }
    let avg = acc.scale(1.0 / total_steps.max(1) as f64);
    CostRow {
        method: "DSQ (dynamic)".to_string(),
        precision: "-".to_string(),
        arith_rel: scored.then_some(avg.arith_macs / base.arith_macs),
        dram_rel: scored.then_some(avg.dram_bits / base.dram_bits),
        step: avg,
    }
}

/// The standard method list of Tables 1 and 6 (without the DSQ row,
/// which needs a schedule trace).
pub fn standard_methods() -> Vec<(&'static str, PrecisionConfig, bool)> {
    vec![
        ("Floating-point", PrecisionConfig::FP32, false),
        ("Fixed-point", PrecisionConfig::uniform(FormatSpec::fixed(32)), true),
        ("Fixed-point", PrecisionConfig::uniform(FormatSpec::fixed(16)), true),
        ("Block FP", PrecisionConfig::uniform(FormatSpec::bfp(32)), true),
        ("Block FP", PrecisionConfig::uniform(FormatSpec::bfp(16)), true),
        ("Stashing (Fixed)", PrecisionConfig::stashing(FormatSpec::fixed(16)), true),
        ("Stashing (BFP)", PrecisionConfig::stashing(FormatSpec::bfp(16)), true),
    ]
}

/// Paper Table 1/6 reference values for the cost columns, used by tests
/// and EXPERIMENTS.md reporting: (method, precision, arith, dram).
pub const PAPER_COST_ROWS: &[(&str, &str, f64, f64)] = &[
    ("Fixed-point", "[32,32,32,32]", 1.00, 1.00),
    ("Fixed-point", "[16,16,16,16]", 0.25, 0.50),
    ("Block FP", "[32,32,32,32]", 0.56, 1.13),
    ("Block FP", "[16,16,16,16]", 0.18, 0.63),
    ("Stashing (Fixed)", "[16,4,4,16]", 0.13, 0.31),
    ("Stashing (BFP)", "[16,4,4,16]", 0.10, 0.45),
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standard_rows_against_paper() {
        let w = TransformerWorkload::iwslt_6layer();
        let rows: Vec<CostRow> = standard_methods()
            .iter()
            .map(|(m, p, s)| normalized_row(&w, m, p, *s))
            .collect();
        // Align by (method, precision) with the paper's reference values.
        for (method, precision, pa, pd) in PAPER_COST_ROWS {
            let row = rows
                .iter()
                .find(|r| r.method == *method && r.precision == *precision)
                .unwrap_or_else(|| panic!("missing row {method} {precision}"));
            let a = row.arith_rel.unwrap();
            let d = row.dram_rel.unwrap();
            assert!(
                (a - pa).abs() <= 0.03,
                "{method} {precision}: arith {a:.3} vs paper {pa}"
            );
            assert!(
                (d - pd).abs() <= 0.08,
                "{method} {precision}: dram {d:.3} vs paper {pd}"
            );
        }
    }

    #[test]
    fn fp32_row_unscored() {
        let w = TransformerWorkload::iwslt_6layer();
        let row = normalized_row(&w, "Floating-point", &PrecisionConfig::FP32, false);
        assert!(row.arith_rel.is_none());
        assert!(row.fmt_paper_style().contains('-'));
    }

    #[test]
    fn fp32_trace_unscored() {
        // A run that never left the fp32 reference config has no
        // meaningful relative cost — the row must come back unscored
        // instead of panicking downstream (RunReport::cost_on).
        let w = TransformerWorkload::iwslt_6layer();
        let row = dsq_trace_row(&w, &[(PrecisionConfig::FP32, 100)]);
        assert!(row.arith_rel.is_none());
        assert!(row.dram_rel.is_none());
        let empty = dsq_trace_row(&w, &[]);
        assert!(empty.arith_rel.is_none());
        // But a trace with any quantized steps is scored, even if it
        // also contains fp32 steps.
        let mixed = dsq_trace_row(
            &w,
            &[
                (PrecisionConfig::FP32, 50),
                (PrecisionConfig::stashing(FormatSpec::bfp(16)), 50),
            ],
        );
        assert!(mixed.arith_rel.is_some());
    }

    #[test]
    fn dsq_trace_blends_levels() {
        let w = TransformerWorkload::iwslt_6layer();
        let lo = PrecisionConfig::of(FormatSpec::bfp(16), [2, 2, 2, 16]);
        let hi = PrecisionConfig::stashing(FormatSpec::bfp(16));
        let all_lo = dsq_trace_row(&w, &[(lo, 100)]);
        let all_hi = dsq_trace_row(&w, &[(hi, 100)]);
        let mix = dsq_trace_row(&w, &[(lo, 96), (hi, 4)]);
        let (alo, ahi, amix) =
            (all_lo.arith_rel.unwrap(), all_hi.arith_rel.unwrap(), mix.arith_rel.unwrap());
        assert!(alo < amix && amix < ahi, "{alo} {amix} {ahi}");
        // The headline: mostly-2-bit training lands near the paper's 0.012x.
        assert!((amix - 0.012).abs() < 0.01, "dsq arith {amix}");
    }

    #[test]
    fn headline_ratios_vs_fixed16() {
        // Paper abstract: DSQ reduces arith by 20.95x and DRAM by 2.55x
        // vs 16-bit fixed point. Using the paper's own DSQ IWSLT row
        // (0.012 / 0.196): 0.25/0.012 = 20.8, 0.50/0.196 = 2.55.
        let w = TransformerWorkload::iwslt_6layer();
        let lo = PrecisionConfig::of(FormatSpec::bfp(16), [2, 2, 2, 16]);
        let hi = PrecisionConfig::stashing(FormatSpec::bfp(16));
        let dsq = dsq_trace_row(&w, &[(lo, 96), (hi, 4)]);
        let f16 = normalized_row(
            &w,
            "Fixed-point",
            &PrecisionConfig::uniform(FormatSpec::fixed(16)),
            true,
        );
        let arith_ratio = f16.arith_rel.unwrap() / dsq.arith_rel.unwrap();
        let dram_ratio = f16.dram_rel.unwrap() / dsq.dram_rel.unwrap();
        assert!(arith_ratio > 10.0, "arith reduction {arith_ratio:.1}x (paper 20.95x)");
        assert!(dram_ratio > 1.3, "dram reduction {dram_ratio:.2}x (paper 2.55x)");
    }

    #[test]
    fn rows_consistent_across_workloads() {
        // Relative *uniform* rows are nearly workload-independent (all
        // components scale together); stash rows shift with the
        // activation/weight mix. Check uniform stability.
        for w in
            [TransformerWorkload::iwslt_6layer(), TransformerWorkload::roberta_base()]
        {
            let r = normalized_row(
                &w,
                "Fixed-point",
                &PrecisionConfig::uniform(FormatSpec::fixed(16)),
                true,
            );
            assert!((r.arith_rel.unwrap() - 0.25).abs() < 1e-9);
            assert!((r.dram_rel.unwrap() - 0.50).abs() < 1e-9);
        }
    }
}
