//! Figure 1: the Roofline model.
//!
//! Operational intensity `I = ops / DRAM-bytes`; attainable performance
//! `P = min(peak, I × bandwidth)`. The paper's Figure 1 places (1)
//! non-quantized, (2) statically quantized and (3) DSQ training on the
//! intensity axis and argues DSQ moves the workload toward the machine
//! balance point `I_opt = peak / bandwidth` because it cuts DRAM traffic
//! far more than it cuts (effective) arithmetic *throughput need*.
//!
//! "Operations" here are raw MACs (the work that must happen regardless
//! of format) and "bytes" are the format-dependent DRAM traffic from the
//! cost model — matching the paper's definition (quantization does not
//! change how many mathematical operations the training step performs,
//! it changes how many bytes move and how cheap each MAC is).

use super::training::StepCost;

/// A machine for the roofline: peak compute and DRAM bandwidth.
#[derive(Clone, Copy, Debug)]
pub struct Machine {
    pub name: &'static str,
    /// Peak throughput in MAC/s (int32-MAC-equivalents).
    pub peak_macs_per_s: f64,
    /// DRAM bandwidth in bytes/s.
    pub dram_bytes_per_s: f64,
}

impl Machine {
    /// An A100-SXM-80GB-like balance point (the paper's testbed):
    /// ~312 TFLOPS tensor / 2 ~= 156 TMAC/s, 2.0 TB/s HBM.
    pub fn a100_like() -> Machine {
        Machine { name: "A100-like", peak_macs_per_s: 156e12, dram_bytes_per_s: 2.0e12 }
    }

    /// An edge/on-device accelerator profile (the paper's motivation):
    /// 4 TMAC/s, 25 GB/s LPDDR.
    pub fn edge_like() -> Machine {
        Machine { name: "edge-like", peak_macs_per_s: 4e12, dram_bytes_per_s: 25e9 }
    }

    /// Machine balance point `I_opt` in MAC/byte.
    pub fn balance(&self) -> f64 {
        self.peak_macs_per_s / self.dram_bytes_per_s
    }

    /// Attainable performance at intensity `i` (MAC/s).
    pub fn attainable(&self, i: f64) -> f64 {
        (i * self.dram_bytes_per_s).min(self.peak_macs_per_s)
    }
}

/// One point on the roofline plot.
#[derive(Clone, Debug)]
pub struct RooflinePoint {
    pub label: String,
    /// Operational intensity (MAC/byte).
    pub intensity: f64,
    /// Attainable performance on the machine (MAC/s).
    pub attainable: f64,
    /// Fraction of peak.
    pub peak_fraction: f64,
    pub memory_bound: bool,
}

/// Place a per-step cost on a machine's roofline.
pub fn place(machine: &Machine, label: &str, cost: &StepCost) -> RooflinePoint {
    let intensity = cost.raw_macs / cost.dram_bytes();
    let attainable = machine.attainable(intensity);
    RooflinePoint {
        label: label.to_string(),
        intensity,
        attainable,
        peak_fraction: attainable / machine.peak_macs_per_s,
        memory_bound: intensity < machine.balance(),
    }
}

/// The series for the roofline curve itself (log-spaced intensities).
pub fn roofline_curve(machine: &Machine, points: usize) -> Vec<(f64, f64)> {
    (0..points)
        .map(|i| {
            let x = 0.1 * (10_000.0f64).powf(i as f64 / (points - 1) as f64);
            (x, machine.attainable(x))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::training::step_cost;
    use crate::costmodel::workload::TransformerWorkload;
    use crate::schedule::{FormatSpec, PrecisionConfig};

    #[test]
    fn balance_points() {
        let a100 = Machine::a100_like();
        assert!((a100.balance() - 78.0).abs() < 1.0);
        assert!(Machine::edge_like().balance() > 100.0);
    }

    #[test]
    fn attainable_clips_at_peak() {
        let m = Machine::a100_like();
        assert_eq!(m.attainable(1e9), m.peak_macs_per_s);
        assert!(m.attainable(1.0) < m.peak_macs_per_s);
    }

    #[test]
    fn paper_figure1_ordering() {
        // Figure 1's claim: I(fp32/fixed32) < I(static quant) < I(DSQ),
        // i.e. DSQ moves training toward (or past) the balance point.
        let w = TransformerWorkload::iwslt_6layer();
        let m = Machine::a100_like();
        let p1 =
            place(&m, "fixed32", &step_cost(&w, &PrecisionConfig::uniform(FormatSpec::fixed(32))));
        let p2 =
            place(&m, "bfp16", &step_cost(&w, &PrecisionConfig::uniform(FormatSpec::bfp(16))));
        let p3 = place(
            &m,
            "dsq[2,2,2,16]",
            &step_cost(&w, &PrecisionConfig::of(FormatSpec::bfp(16), [2, 2, 2, 16])),
        );
        assert!(p1.intensity < p2.intensity, "{} < {}", p1.intensity, p2.intensity);
        assert!(p2.intensity < p3.intensity, "{} < {}", p2.intensity, p3.intensity);
        // Transformer training is memory-bound at fp32/fixed32 (Ivanov
        // et al.) on the A100 profile.
        assert!(p1.memory_bound);
        // ...and DSQ raises attainable performance.
        assert!(p3.attainable > p1.attainable);
    }

    #[test]
    fn curve_is_monotone_then_flat() {
        let m = Machine::a100_like();
        let curve = roofline_curve(&m, 64);
        assert_eq!(curve.len(), 64);
        for w in curve.windows(2) {
            assert!(w[1].0 > w[0].0);
            assert!(w[1].1 >= w[0].1);
        }
        assert_eq!(curve.last().unwrap().1, m.peak_macs_per_s);
    }
}
