//! Per-step cost of a transformer workload under a precision config.
//!
//! ## Arithmetic
//!
//! Each GEMM of the forward pass induces three GEMMs per training step
//! (paper Figure 2):
//!
//! 1. forward `y = x@w` at `q0 × q0`;
//! 2. backward-input `dx = dy@wᵀ` at `q2 × q2`;
//! 3. backward-weight `dw = x_stashᵀ@dy` at `q1 × q0`: the stash meets
//!    the gradient *consumed at the working precision* (truncated-
//!    mantissa read of the q3 DRAM copy). Note the paper's §3 prose says
//!    q3 also affects GEMM 3's compute, but its reported numbers are
//!    only consistent with GEMM 3 charged at `q1 × q0` — the DSQ row
//!    (0.012×) sits *below* the `f(2,16)/3 ≈ 0.031` floor any q3=16
//!    multiplicand would imply, while `f(2,2) = 0.0116 ≈ 0.012` matches
//!    exactly (and `f(4,16) = 0.105` reproduces the 0.10× stash row). We
//!    follow the numbers and document the ambiguity (DESIGN.md §6).
//!
//! Non-GEMM arithmetic (LayerNorm, softmax, optimizer) is excluded from
//! the relative column, exactly as in the paper (its fixed-16 row is
//! 0.25 = (16/32)² to the digit, which only holds if GEMMs dominate).
//!
//! ## DRAM traffic
//!
//! Per forward GEMM, per step (element counts × storage bits):
//!
//! | tensor                  | dir   | format | note |
//! |-------------------------|-------|--------|------|
//! | weights (fwd read)      | R     | q0     | truncated-mantissa reads |
//! | weights (bwd read)      | R     | q2     | re-read for GEMM 2 |
//! | stash x (write + read)  | W + R | q1     | THE stashing traffic |
//! | gradient dy write       | W     | q3     | always flushed (paper §3) |
//! | gradient dy read GEMM2  | R     | q2     | truncated read |
//! | gradient dy read GEMM3  | R     | q0     | truncated read (working width) |
//! | weight gradient write   | W     | q3     | |
//! | optimizer (Adam)        | R+W   | q0     | 6 × params at the working width |
//!
//! Activation×activation GEMMs (attention) stash **both** operands at
//! `q1` and have no weight/optimizer terms. Forward activations between
//! layers are not charged (they flow on-chip; the paper's Figure 2 shows
//! only `x_l`, `dx_{l+1}`, `dx_l` as DRAM-resident, which is what makes
//! `q1`/`q3` the memory knobs).

use super::workload::{Gemm, GemmKind, TransformerWorkload};
use crate::schedule::{FormatSpec, PrecisionConfig};

/// Cost of one training step, in absolute units.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StepCost {
    /// Arithmetic cost in int32-MAC-equivalents.
    pub arith_macs: f64,
    /// DRAM traffic in bits.
    pub dram_bits: f64,
    /// Raw MAC count (format-independent; roofline's "operations").
    pub raw_macs: f64,
    /// Component split (bits): the stash (q1) share of the traffic.
    pub stash_bits: f64,
    /// Component split (bits): gradient (q3/q2) traffic.
    pub grad_bits: f64,
    /// Component split (bits): weight + optimizer traffic.
    pub weight_bits: f64,
}

impl StepCost {
    pub fn add(&mut self, other: &StepCost) {
        self.arith_macs += other.arith_macs;
        self.dram_bits += other.dram_bits;
        self.raw_macs += other.raw_macs;
        self.stash_bits += other.stash_bits;
        self.grad_bits += other.grad_bits;
        self.weight_bits += other.weight_bits;
    }

    pub fn scale(&self, s: f64) -> StepCost {
        StepCost {
            arith_macs: self.arith_macs * s,
            dram_bits: self.dram_bits * s,
            raw_macs: self.raw_macs * s,
            stash_bits: self.stash_bits * s,
            grad_bits: self.grad_bits * s,
            weight_bits: self.weight_bits * s,
        }
    }

    /// DRAM traffic in bytes (roofline).
    pub fn dram_bytes(&self) -> f64 {
        self.dram_bits / 8.0
    }
}

fn gemm_cost(g: &Gemm, p: &PrecisionConfig) -> StepCost {
    // Per-slot formats straight off the config — the same FormatSpec
    // objects the quantizers execute.
    let [f0, f1, f2, f3] = p.slots;

    let macs = g.macs();
    // Three GEMMs per training step (fwd, bwd-input, bwd-weight); see the
    // module docs for why GEMM 3 is q1 × q0 (not q1 × q3).
    let arith =
        macs * (f0.mac_cost(&f0) + f2.mac_cost(&f2) + f1.mac_cost(&f0));

    let (b0, b1, b2, b3) =
        (f0.storage_bits(), f1.storage_bits(), f2.storage_bits(), f3.storage_bits());

    let stash_bits;
    let grad_bits;
    let mut weight_bits = 0.0;
    match g.kind {
        GemmKind::Weight => {
            // Stash: x (lhs) written + read at q1.
            stash_bits = 2.0 * g.lhs_elems() * b1;
            // Gradients: dy flushed at q3, read back truncated at q2
            // (GEMM 2) and q0 (GEMM 3); dw written at q3.
            grad_bits = g.out_elems() * (b3 + b2 + b0) + g.rhs_elems() * b3;
            // Weights: fwd read at q0, bwd read at q2; Adam state R+W
            // (w, m, v each way) at the working width q0.
            weight_bits = g.rhs_elems() * (b0 + b2) + 6.0 * g.rhs_elems() * b0;
        }
        GemmKind::Activation => {
            // Both operands are activations: both stashed at q1.
            stash_bits = 2.0 * (g.lhs_elems() + g.rhs_elems()) * b1;
            // dy flushed + re-read; both operand gradients flushed at q3.
            grad_bits = g.out_elems() * (b3 + b2 + b0)
                + (g.lhs_elems() + g.rhs_elems()) * b3;
        }
    }
    StepCost {
        arith_macs: arith,
        dram_bits: stash_bits + grad_bits + weight_bits,
        raw_macs: 3.0 * macs,
        stash_bits,
        grad_bits,
        weight_bits,
    }
}

/// Cost of one full training step of `w` under precision `p`.
pub fn step_cost(w: &TransformerWorkload, p: &PrecisionConfig) -> StepCost {
    let mut total = StepCost::default();
    for g in &w.gemms {
        total.add(&gemm_cost(g, p));
    }
    total
}

/// Reference cost: 32-bit fixed point (the paper's 1.00× anchor).
pub fn fixed32_reference(w: &TransformerWorkload) -> StepCost {
    step_cost(w, &PrecisionConfig::uniform(FormatSpec::fixed(32)))
}

/// The *measured* counterpart of [`StepCost::stash_bits`]: the bytes
/// the packed codec actually stores for one step's stashed operands
/// (write + read), priced by `FormatSpec::observed_bytes` — the same
/// layout function the stash store meters — instead of the modeled
/// `storage_bits()`. Each stashed operand is a `(rows, k)` matrix with
/// the GEMM's contraction axis as its minor dimension, which is what
/// the box-based formats grid against.
pub fn observed_stash_bytes(w: &TransformerWorkload, p: &PrecisionConfig) -> f64 {
    let q1 = p.stash();
    let mut bytes = 0.0f64;
    for g in &w.gemms {
        let n = g.count as f64;
        // Write + read of the q1 stash copy.
        let lhs = 2.0 * q1.observed_bytes(g.m * g.k, g.k) as f64;
        bytes += n * lhs;
        if g.kind == GemmKind::Activation {
            let rhs = 2.0 * q1.observed_bytes(g.k * g.n, g.n) as f64;
            bytes += n * rhs;
        }
    }
    bytes
}

/// Box-metadata slack for [`observed_stash_bytes`] vs
/// [`StepCost::stash_bits`]: the per-tensor allowance
/// `FormatSpec::storage_allowance_bits` grants, summed over the same
/// stashed operands.
pub fn observed_stash_allowance_bits(w: &TransformerWorkload, p: &PrecisionConfig) -> f64 {
    let q1 = p.stash();
    let mut bits = 0.0f64;
    for g in &w.gemms {
        let n = g.count as f64;
        bits += n * 2.0 * q1.storage_allowance_bits(g.m * g.k, g.k);
        if g.kind == GemmKind::Activation {
            bits += n * 2.0 * q1.storage_allowance_bits(g.k * g.n, g.n);
        }
    }
    bits
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::{FormatSpec, PrecisionConfig};

    fn iwslt() -> TransformerWorkload {
        TransformerWorkload::iwslt_6layer()
    }

    fn bfp_of(q: [u32; 4]) -> PrecisionConfig {
        PrecisionConfig::of(FormatSpec::bfp(16), q)
    }

    fn rel(p: PrecisionConfig) -> (f64, f64) {
        let w = iwslt();
        let base = fixed32_reference(&w);
        let c = step_cost(&w, &p);
        (c.arith_macs / base.arith_macs, c.dram_bits / base.dram_bits)
    }

    #[test]
    fn fixed16_matches_paper() {
        // Paper Table 1: fixed [16,16,16,16] = 0.25x arith, 0.50x DRAM.
        let (a, d) = rel(PrecisionConfig::uniform(FormatSpec::fixed(16)));
        assert!((a - 0.25).abs() < 1e-9, "arith {a}");
        assert!((d - 0.50).abs() < 1e-9, "dram {d}");
    }

    #[test]
    fn bfp32_matches_paper() {
        // Paper: BFP [32,32,32,32] = 0.56x arith, 1.13x DRAM.
        let (a, d) = rel(PrecisionConfig::uniform(FormatSpec::bfp(32)));
        assert!((a - 0.56).abs() < 0.01, "arith {a}");
        assert!((d - 1.13).abs() < 0.01, "dram {d}");
    }

    #[test]
    fn bfp16_matches_paper() {
        // Paper: BFP [16,16,16,16] = 0.18x arith, 0.63x DRAM.
        let (a, d) = rel(PrecisionConfig::uniform(FormatSpec::bfp(16)));
        assert!((a - 0.18).abs() < 0.01, "arith {a}");
        assert!((d - 0.63).abs() < 0.01, "dram {d}");
    }

    #[test]
    fn stashing_rows_near_paper() {
        // Predictions (constants were fitted only on the uniform rows):
        // Stashing(BFP) [16,4,4,16]: paper 0.10x / 0.45x.
        let (a, d) = rel(PrecisionConfig::stashing(FormatSpec::bfp(16)));
        assert!((a - 0.10).abs() < 0.02, "bfp stash arith {a}");
        assert!((d - 0.45).abs() < 0.08, "bfp stash dram {d}");
        // Stashing(Fixed): paper 0.13x / 0.31x.
        let (a, d) = rel(PrecisionConfig::stashing(FormatSpec::fixed(16)));
        assert!((a - 0.13).abs() < 0.03, "fixed stash arith {a}");
        assert!((d - 0.31).abs() < 0.06, "fixed stash dram {d}");
    }

    #[test]
    fn sr_fixed_costs_identical_to_nearest_fixed() {
        // The SR format must slot into the cost model at exactly the
        // fixed-point price (rounding is not a MAC-array property).
        let a = rel(PrecisionConfig::stashing(FormatSpec::fixed(16)));
        let b = rel(PrecisionConfig::stashing(FormatSpec::fixed_sr(16)));
        assert_eq!(a, b);
    }

    #[test]
    fn heterogeneous_slots_price_per_slot() {
        // BFP compute path + fixed gradient outputs: the gradient DRAM
        // term must drop by exactly the BFP container overhead (4 bits
        // per element on both the dy flush and the dw/db writes).
        let w = iwslt();
        let all_bfp = step_cost(&w, &PrecisionConfig::parse("bfp:16,4,4,16").unwrap());
        let het = step_cost(&w, &PrecisionConfig::parse("bfp16,bfp4,bfp4,fixed16").unwrap());
        assert!(het.grad_bits < all_bfp.grad_bits, "fixed16 grad slot must be cheaper");
        assert_eq!(het.stash_bits, all_bfp.stash_bits, "stash slot untouched");
        assert_eq!(het.weight_bits, all_bfp.weight_bits, "weight slot untouched");
        // And the arith side is unchanged: GEMM 3 runs at q1 x q0.
        assert_eq!(het.arith_macs, all_bfp.arith_macs);
    }

    #[test]
    fn dsq_time_weighted_cost_near_paper() {
        // DSQ spends most steps at [2,2,2,16]: paper IWSLT row is
        // 0.012x arith / 0.20x DRAM. With ~96% of steps at level 0 and
        // the rest at the stash level:
        let w = iwslt();
        let base = fixed32_reference(&w);
        let lo = step_cost(&w, &bfp_of([2, 2, 2, 16]));
        let hi = step_cost(&w, &PrecisionConfig::stashing(FormatSpec::bfp(16)));
        let blend_arith = (0.96 * lo.arith_macs + 0.04 * hi.arith_macs) / base.arith_macs;
        assert!((blend_arith - 0.012).abs() < 0.006, "dsq arith {blend_arith}");
        let blend_dram = (0.96 * lo.dram_bits + 0.04 * hi.dram_bits) / base.dram_bits;
        // DRAM is dominated by q3=16 gradient flushes; paper reports 0.20.
        assert!((0.1..0.4).contains(&blend_dram), "dsq dram {blend_dram}");
    }

    #[test]
    fn stash_component_scales_with_q1_only() {
        let w = iwslt();
        let a = step_cost(&w, &bfp_of([16, 2, 4, 16]));
        let b = step_cost(&w, &bfp_of([16, 16, 4, 16]));
        assert!(a.stash_bits < b.stash_bits);
        assert_eq!(a.grad_bits, b.grad_bits);
        assert_eq!(a.weight_bits, b.weight_bits);
    }

    #[test]
    fn cost_monotone_in_every_knob() {
        let w = iwslt();
        let c0 = step_cost(&w, &bfp_of([8, 8, 8, 16]));
        for (i, bumped) in [
            bfp_of([16, 8, 8, 16]),
            bfp_of([8, 16, 8, 16]),
            bfp_of([8, 8, 16, 16]),
            bfp_of([8, 8, 8, 32]),
        ]
        .iter()
        .enumerate()
        {
            let c = step_cost(&w, bumped);
            assert!(c.dram_bits > c0.dram_bits, "knob {i} dram");
            assert!(c.arith_macs >= c0.arith_macs, "knob {i} arith");
        }
    }

    #[test]
    fn components_sum_to_total() {
        let w = iwslt();
        let c = step_cost(&w, &PrecisionConfig::stashing(FormatSpec::bfp(16)));
        assert!((c.stash_bits + c.grad_bits + c.weight_bits - c.dram_bits).abs() < 1.0);
    }

    #[test]
    fn observed_stash_bytes_agrees_with_the_modeled_stash_component() {
        // The measured column: the codec-observed stash traffic of a
        // paper-scale step must agree with the model's stash_bits within
        // box-metadata slack, for every stash format the tables use.
        let w = iwslt();
        for p in [
            PrecisionConfig::stashing(FormatSpec::bfp(16)),      // q1 = bfp4
            bfp_of([2, 2, 2, 16]),                               // q1 = bfp2
            PrecisionConfig::uniform(FormatSpec::bfp(16)),       // q1 = bfp16
            PrecisionConfig::uniform(FormatSpec::bfp(32)),       // q1 = bfp32 (container)
            PrecisionConfig::stashing(FormatSpec::fixed(16)),    // q1 = fixed4
            PrecisionConfig::uniform(FormatSpec::fixed(32)),     // q1 = fixed32
            PrecisionConfig::FP32,                               // q1 = fp32 (exact)
            PrecisionConfig::uniform(FormatSpec::fp8e4m3()),     // q1 = e4m3
        ] {
            let modeled = step_cost(&w, &p).stash_bits;
            let observed = 8.0 * observed_stash_bytes(&w, &p);
            let allowance = observed_stash_allowance_bits(&w, &p);
            let gap = (observed - modeled).abs();
            assert!(
                gap <= allowance,
                "{}: observed {observed} bits vs modeled {modeled} bits; \
                 gap {gap} > allowance {allowance}",
                p.spec_string()
            );
            assert!(observed > 0.0 || p.stash() == FormatSpec::Fp32 || modeled == 0.0);
        }
        // fp32 stash is byte-exact: no grid metadata at all.
        let p = PrecisionConfig::FP32;
        assert_eq!(8.0 * observed_stash_bytes(&w, &p), step_cost(&w, &p).stash_bits);
    }

    #[test]
    fn raw_macs_independent_of_precision() {
        let w = iwslt();
        let a = step_cost(&w, &PrecisionConfig::uniform(FormatSpec::bfp(2)));
        let b = step_cost(&w, &PrecisionConfig::FP32);
        assert_eq!(a.raw_macs, b.raw_macs);
        assert_eq!(a.raw_macs, 3.0 * w.total_macs());
    }
}
