//! Hardware cost model: arithmetic operations and DRAM traffic for
//! quantized transformer training (the framework behind the paper's
//! "Arith Ops" and "DRAM R/W" columns and Figure 1).
//!
//! The paper derives these columns from a performance-modeling framework
//! calibrated on a production MSFP system (Darvish Rouhani et al.); the
//! hardware itself is unavailable, so this module rebuilds the model from
//! first principles with constants calibrated once against the paper's
//! *static* rows — every other number (stashing rows, DSQ rows, WMT
//! table, roofline) is then a prediction. Calibration derivation:
//! DESIGN.md §6; per-cell fit: EXPERIMENTS.md.
//!
//! Layout:
//! * [`formats`] — the calibrated cost constants + the
//!   `storage_bits`/`mac_cost` impls on [`crate::quant::FormatSpec`]
//!   (one descriptor serves quantizers and cost model alike);
//! * [`workload`] — transformer training workloads as GEMM lists
//!   (paper-scale IWSLT/WMT 6-layer and RoBERTa-base, plus the local
//!   testbed dims);
//! * [`training`] — per-step cost of a workload under a
//!   [`crate::schedule::PrecisionConfig`], split into the paper's
//!   components (fwd GEMM, stash, backward GEMMs, optimizer);
//! * [`tables`] — normalized table rows (fixed-point-32 ≡ 1.00×);
//! * [`roofline`] — Figure 1: operational intensity vs attainable
//!   performance.

pub mod formats;
pub mod roofline;
pub mod tables;
pub mod training;
pub mod workload;

pub use roofline::{Machine, RooflinePoint};
pub use tables::{normalized_row, CostRow};
pub use training::{observed_stash_bytes, step_cost, StepCost};
pub use workload::{Gemm, GemmKind, TransformerWorkload, WorkloadKind};
