//! Transformer training workloads as GEMM lists.
//!
//! A workload is every GEMM executed in one training step, with its
//! dimensions and operand kinds (weight vs activation) — that
//! distinction drives the traffic model: weight GEMMs have an optimizer
//! and a weight-gradient, activation×activation GEMMs (attention) stash
//! both operands.
//!
//! Paper-scale builders reproduce the evaluation section's models:
//! * IWSLT/WMT 6-layer base transformer (Vaswani et al.): d=512,
//!   ff=2048, h=8, 6+6 layers, ~4096 tokens/batch (Appendix B);
//! * RoBERTa-base (GLUE fine-tuning): d=768, ff=3072, h=12, 12 layers,
//!   batch 32 × 128 tokens.

/// Operand/role classification of one GEMM.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GemmKind {
    /// `activations (tokens×k) @ weights (k×n)` — linear layers, logits.
    Weight,
    /// `activations @ activations` — attention score and context GEMMs.
    Activation,
}

/// One GEMM: `(m × k) @ (k × n)`, executed `count` times per step.
#[derive(Clone, Copy, Debug)]
pub struct Gemm {
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub count: usize,
    pub kind: GemmKind,
}

impl Gemm {
    pub fn macs(&self) -> f64 {
        self.m as f64 * self.k as f64 * self.n as f64 * self.count as f64
    }

    /// Elements of the left (activation) operand.
    pub fn lhs_elems(&self) -> f64 {
        (self.m * self.k * self.count) as f64
    }

    /// Elements of the right operand (weights or activations).
    pub fn rhs_elems(&self) -> f64 {
        (self.k * self.n * self.count) as f64
    }

    /// Elements of the output.
    pub fn out_elems(&self) -> f64 {
        (self.m * self.n * self.count) as f64
    }
}

/// Which paper workload a table row refers to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkloadKind {
    /// 6-layer base transformer on IWSLT'17-style batches.
    Iwslt6Layer,
    /// 6-layer base transformer on WMT'14-style batches (same model,
    /// same max-tokens → same per-step shape; kept distinct for
    /// reporting).
    Wmt6Layer,
    /// RoBERTa-base fine-tuning (MNLI/QNLI).
    RobertaBase,
    /// The local small testbed model (matches artifacts/manifest.json).
    Testbed,
}

/// A full training-step workload.
#[derive(Clone, Debug)]
pub struct TransformerWorkload {
    pub name: &'static str,
    pub gemms: Vec<Gemm>,
    /// Total trainable parameters (optimizer traffic).
    pub params: f64,
}

fn encoder_layer(gemms: &mut Vec<Gemm>, tokens: usize, d: usize, ff: usize, seq: usize) {
    let w = GemmKind::Weight;
    let a = GemmKind::Activation;
    // q, k, v, o projections.
    gemms.push(Gemm { m: tokens, k: d, n: d, count: 4, kind: w });
    // Attention: scores QK^T and context AV. Per batch row of length
    // `seq`: (seq × d) @ (d × seq) and (seq × seq) @ (seq × d) across all
    // heads together (head split doesn't change MACs or element counts).
    let rows = tokens / seq;
    gemms.push(Gemm { m: seq, k: d, n: seq, count: rows, kind: a });
    gemms.push(Gemm { m: seq, k: seq, n: d, count: rows, kind: a });
    // FFN.
    gemms.push(Gemm { m: tokens, k: d, n: ff, count: 1, kind: w });
    gemms.push(Gemm { m: tokens, k: ff, n: d, count: 1, kind: w });
}

fn decoder_layer(
    gemms: &mut Vec<Gemm>,
    tgt_tokens: usize,
    src_tokens: usize,
    d: usize,
    ff: usize,
    tgt_seq: usize,
    src_seq: usize,
) {
    let w = GemmKind::Weight;
    let a = GemmKind::Activation;
    // Self-attention.
    gemms.push(Gemm { m: tgt_tokens, k: d, n: d, count: 4, kind: w });
    let rows = tgt_tokens / tgt_seq;
    gemms.push(Gemm { m: tgt_seq, k: d, n: tgt_seq, count: rows, kind: a });
    gemms.push(Gemm { m: tgt_seq, k: tgt_seq, n: d, count: rows, kind: a });
    // Cross-attention: q from target, k/v from source.
    gemms.push(Gemm { m: tgt_tokens, k: d, n: d, count: 2, kind: w }); // q, o
    gemms.push(Gemm { m: src_tokens, k: d, n: d, count: 2, kind: w }); // k, v
    let _ = src_seq;
    gemms.push(Gemm { m: tgt_seq, k: d, n: src_seq, count: rows, kind: a });
    gemms.push(Gemm { m: tgt_seq, k: src_seq, n: d, count: rows, kind: a });
    // FFN.
    gemms.push(Gemm { m: tgt_tokens, k: d, n: ff, count: 1, kind: w });
    gemms.push(Gemm { m: tgt_tokens, k: ff, n: d, count: 1, kind: w });
}

/// Parameter count for a (pre-LN) encoder-decoder transformer.
fn seq2seq_params(
    d: usize,
    ff: usize,
    enc_layers: usize,
    dec_layers: usize,
    vocab: usize,
    seq: usize,
) -> f64 {
    let attn = 4 * d * d + 4 * d;
    let ffn = d * ff + ff + ff * d + d;
    let ln = 2 * d;
    let enc = enc_layers * (attn + ffn + 2 * ln);
    let dec = dec_layers * (2 * attn + ffn + 3 * ln);
    let emb = 2 * vocab * d + 2 * seq * d;
    (enc + dec + emb + 2 * ln) as f64
}

impl TransformerWorkload {
    /// 6-layer base transformer, IWSLT-style max-tokens batch (4096).
    pub fn iwslt_6layer() -> Self {
        Self::seq2seq("iwslt17-transformer6", 512, 2048, 6, 6, 32_000, 64, 4096)
    }

    /// Same architecture on WMT14 batches (Appendix D).
    pub fn wmt_6layer() -> Self {
        Self::seq2seq("wmt14-transformer6", 512, 2048, 6, 6, 37_000, 64, 4096)
    }

    /// A generic seq2seq builder.
    pub fn seq2seq(
        name: &'static str,
        d: usize,
        ff: usize,
        enc_layers: usize,
        dec_layers: usize,
        vocab: usize,
        seq: usize,
        max_tokens: usize,
    ) -> Self {
        let tokens = (max_tokens / seq) * seq; // whole sentences
        let mut gemms = Vec::new();
        for _ in 0..enc_layers {
            encoder_layer(&mut gemms, tokens, d, ff, seq);
        }
        for _ in 0..dec_layers {
            decoder_layer(&mut gemms, tokens, tokens, d, ff, seq, seq);
        }
        // Output projection (tied embedding still does the GEMM).
        gemms.push(Gemm { m: tokens, k: d, n: vocab, count: 1, kind: GemmKind::Weight });
        TransformerWorkload {
            name,
            gemms,
            params: seq2seq_params(d, ff, enc_layers, dec_layers, vocab, seq),
        }
    }

    /// RoBERTa-base fine-tuning on GLUE (batch 32 × 128 tokens).
    pub fn roberta_base() -> Self {
        Self::encoder_classifier("roberta-base", 768, 3072, 12, 50_265, 128, 32, 3)
    }

    /// A generic encoder-classifier builder.
    pub fn encoder_classifier(
        name: &'static str,
        d: usize,
        ff: usize,
        layers: usize,
        vocab: usize,
        seq: usize,
        batch: usize,
        nclasses: usize,
    ) -> Self {
        let tokens = batch * seq;
        let mut gemms = Vec::new();
        for _ in 0..layers {
            encoder_layer(&mut gemms, tokens, d, ff, seq);
        }
        // Pooled classification head.
        gemms.push(Gemm { m: batch, k: d, n: d, count: 1, kind: GemmKind::Weight });
        gemms.push(Gemm { m: batch, k: d, n: nclasses, count: 1, kind: GemmKind::Weight });
        let attn = 4 * d * d + 4 * d;
        let ffn = d * ff + ff + ff * d + d;
        let params =
            (layers * (attn + ffn + 4 * d) + vocab * d + seq * d + d * d + d * nclasses) as f64;
        TransformerWorkload { name, gemms, params }
    }

    /// The local testbed model (dims from the artifact manifest).
    pub fn testbed(
        d: usize,
        ff: usize,
        enc_layers: usize,
        dec_layers: usize,
        vocab: usize,
        seq: usize,
        batch: usize,
    ) -> Self {
        Self::seq2seq("testbed", d, ff, enc_layers, dec_layers, vocab, seq, batch * seq)
    }

    pub fn for_kind(kind: WorkloadKind) -> Self {
        match kind {
            WorkloadKind::Iwslt6Layer => Self::iwslt_6layer(),
            WorkloadKind::Wmt6Layer => Self::wmt_6layer(),
            WorkloadKind::RobertaBase => Self::roberta_base(),
            WorkloadKind::Testbed => Self::testbed(128, 256, 2, 2, 256, 24, 16),
        }
    }

    pub fn total_macs(&self) -> f64 {
        self.gemms.iter().map(Gemm::macs).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iwslt_workload_sane() {
        let w = TransformerWorkload::iwslt_6layer();
        // Base transformer ~= 60-75M params (we carry two embeddings +
        // learned positions).
        assert!(w.params > 40e6 && w.params < 110e6, "params {}", w.params);
        // Fwd MACs per 4096-token batch: O(100 GMAC).
        assert!(w.total_macs() > 1e10 && w.total_macs() < 1e12, "macs {}", w.total_macs());
        assert!(w.gemms.iter().any(|g| g.kind == GemmKind::Activation));
    }

    #[test]
    fn roberta_workload_sane() {
        let w = TransformerWorkload::roberta_base();
        // RoBERTa-base ~ 125M params.
        assert!(w.params > 100e6 && w.params < 150e6, "params {}", w.params);
    }

    #[test]
    fn gemm_helpers() {
        let g = Gemm { m: 4, k: 8, n: 2, count: 3, kind: GemmKind::Weight };
        assert_eq!(g.macs(), 4.0 * 8.0 * 2.0 * 3.0);
        assert_eq!(g.lhs_elems(), 96.0);
        assert_eq!(g.rhs_elems(), 48.0);
        assert_eq!(g.out_elems(), 24.0);
    }

    #[test]
    fn attention_macs_scale_quadratically_with_seq() {
        let short = TransformerWorkload::seq2seq("s", 256, 512, 2, 2, 1000, 32, 2048);
        let long = TransformerWorkload::seq2seq("l", 256, 512, 2, 2, 1000, 128, 2048);
        let attn = |w: &TransformerWorkload| -> f64 {
            w.gemms.iter().filter(|g| g.kind == GemmKind::Activation).map(Gemm::macs).sum()
        };
        // Same token count, 4x sequence length -> ~4x attention MACs.
        let ratio = attn(&long) / attn(&short);
        assert!((3.0..5.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn testbed_matches_manifest_dims() {
        let w = TransformerWorkload::for_kind(WorkloadKind::Testbed);
        assert!(w.total_macs() > 1e6);
        assert!(w.params > 50_000.0);
    }
}
