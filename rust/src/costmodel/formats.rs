//! Number formats and their hardware costs.
//!
//! Cost conventions (normalization target: one int32 MAC ≡ 1.0, one
//! 32-bit DRAM element ≡ 32 bits):
//!
//! * **fixed-point b-bit MAC**: `(b₁·b₂)/32²` — multiplier area/energy is
//!   proportional to the product of operand widths (standard array
//!   multiplier scaling; also what makes the paper's fixed-16 row exactly
//!   0.25×).
//! * **BFP m-bit MAC**: `A·(m₁·m₂)/32² + B·max(m₁,m₂)/32` — a mantissa
//!   multiply plus the per-element alignment/normalization shifter that
//!   scales linearly with width. Fitting the paper's BFP-32 (0.56×) and
//!   BFP-16 (0.18×) rows gives **A = 0.40, B = 0.16**; the stashing rows
//!   then come out at 0.104 (paper 0.10) as a *prediction*.
//! * **fp32 MAC**: 1.2 (aligner + normalizer over int32; the paper
//!   normalizes to fixed-32 and leaves fp32 rows unscored — we do the
//!   same in tables, this constant only feeds the roofline).
//! * **storage**: fixed-b = `b` bits/element; BFP-b = `b + 4`
//!   bits/element (sign+mantissa `b`, amortized shared exponent 8/16 =
//!   0.5, container padding — fitted: BFP-32 → 36/32 = 1.13×, BFP-16 →
//!   20/32 = 0.63×, both matching the paper exactly).

use crate::schedule::QuantMode;

/// Fitted BFP MAC constants (DESIGN.md §6).
pub const BFP_MAC_MUL: f64 = 0.40;
pub const BFP_MAC_SHIFT: f64 = 0.16;
/// fp32 MAC cost relative to int32 (roofline only).
pub const FP32_MAC: f64 = 1.2;
/// BFP per-element storage overhead in bits (exponent share + padding).
pub const BFP_STORAGE_OVERHEAD_BITS: f64 = 4.0;

/// A concrete number format for one tensor/operand.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum NumFormat {
    /// IEEE-754 binary32.
    Fp32,
    /// Fixed point with `b` total bits (sign + magnitude/fraction).
    Fixed(f64),
    /// Block floating point with `m` mantissa bits (box 16, 8-bit
    /// shared exponent).
    Bfp(f64),
}

impl NumFormat {
    /// Map a schedule (mode, bits) pair onto a format. Bits ≥ 25 mean
    /// "effectively full precision" numerically, but the *hardware* cost
    /// still reflects the container (32-bit fixed / BFP-32): the paper's
    /// `[32,32,32,32]` rows are real 32-bit hardware paths.
    pub fn from_qbits(mode: QuantMode, bits: f32) -> NumFormat {
        match mode {
            QuantMode::Fp32 => NumFormat::Fp32,
            QuantMode::Fixed => NumFormat::Fixed(bits as f64),
            QuantMode::Bfp => NumFormat::Bfp(bits as f64),
        }
    }

    /// Storage bits per element in DRAM.
    pub fn storage_bits(&self) -> f64 {
        match *self {
            NumFormat::Fp32 => 32.0,
            NumFormat::Fixed(b) => b,
            NumFormat::Bfp(m) => m + BFP_STORAGE_OVERHEAD_BITS,
        }
    }

    pub fn is_bfp(&self) -> bool {
        matches!(self, NumFormat::Bfp(_))
    }
}

/// Relative cost of one MAC with operand formats `a` and `b`
/// (int32 MAC ≡ 1.0).
pub fn mac_cost(a: NumFormat, b: NumFormat) -> f64 {
    use NumFormat::*;
    match (a, b) {
        (Fp32, _) | (_, Fp32) => FP32_MAC,
        (Fixed(b1), Fixed(b2)) => (b1 * b2) / 1024.0,
        (Bfp(m1), Bfp(m2)) => {
            BFP_MAC_MUL * (m1 * m2) / 1024.0 + BFP_MAC_SHIFT * m1.max(m2) / 32.0
        }
        // Mixed fixed/BFP operands: treat the fixed side as a degenerate
        // one-box BFP (same multiplier, shared alignment path).
        (Fixed(b1), Bfp(m2)) | (Bfp(m2), Fixed(b1)) => {
            BFP_MAC_MUL * (b1 * m2) / 1024.0 + BFP_MAC_SHIFT * b1.max(m2) / 32.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_mac_matches_paper_static_rows() {
        // fixed32 = 1.00x (the normalization anchor), fixed16 = 0.25x.
        assert!((mac_cost(NumFormat::Fixed(32.0), NumFormat::Fixed(32.0)) - 1.0).abs() < 1e-12);
        assert!((mac_cost(NumFormat::Fixed(16.0), NumFormat::Fixed(16.0)) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn bfp_mac_matches_paper_static_rows() {
        // BFP32 = 0.56x, BFP16 = 0.18x (the two fitted anchors).
        let c32 = mac_cost(NumFormat::Bfp(32.0), NumFormat::Bfp(32.0));
        let c16 = mac_cost(NumFormat::Bfp(16.0), NumFormat::Bfp(16.0));
        assert!((c32 - 0.56).abs() < 0.005, "bfp32 {c32}");
        assert!((c16 - 0.18).abs() < 0.005, "bfp16 {c16}");
    }

    #[test]
    fn bfp_stash_prediction_near_paper() {
        // Prediction check (not fitted): mean of the three GEMMs of a
        // [16,4,4,16] BFP stashing step = 0.104 vs paper 0.10.
        let f = |a, b| mac_cost(NumFormat::Bfp(a), NumFormat::Bfp(b));
        let mean = (f(16.0, 16.0) + f(4.0, 4.0) + f(4.0, 16.0)) / 3.0;
        assert!((mean - 0.10).abs() < 0.01, "stash-bfp arith {mean}");
    }

    #[test]
    fn storage_matches_paper_dram_anchors() {
        // BFP32 -> 36/32 = 1.125 (paper 1.13), BFP16 -> 20/32 = 0.625 (0.63).
        assert_eq!(NumFormat::Bfp(32.0).storage_bits() / 32.0, 1.125);
        assert_eq!(NumFormat::Bfp(16.0).storage_bits() / 32.0, 0.625);
        assert_eq!(NumFormat::Fixed(16.0).storage_bits() / 32.0, 0.5);
        assert_eq!(NumFormat::Fp32.storage_bits(), 32.0);
    }

    #[test]
    fn mac_cost_monotone_in_bits() {
        for b in [2.0, 4.0, 8.0, 16.0, 24.0] {
            let big = b * 2.0;
            assert!(
                mac_cost(NumFormat::Bfp(b), NumFormat::Bfp(b))
                    < mac_cost(NumFormat::Bfp(big), NumFormat::Bfp(big))
            );
            assert!(
                mac_cost(NumFormat::Fixed(b), NumFormat::Fixed(b))
                    < mac_cost(NumFormat::Fixed(big), NumFormat::Fixed(big))
            );
        }
    }

    #[test]
    fn mixed_operand_cost_symmetric() {
        let a = mac_cost(NumFormat::Bfp(4.0), NumFormat::Bfp(16.0));
        let b = mac_cost(NumFormat::Bfp(16.0), NumFormat::Bfp(4.0));
        assert_eq!(a, b);
        let c = mac_cost(NumFormat::Fixed(4.0), NumFormat::Bfp(16.0));
        let d = mac_cost(NumFormat::Bfp(16.0), NumFormat::Fixed(4.0));
        assert_eq!(c, d);
    }
}
