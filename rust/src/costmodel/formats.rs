//! Hardware costs of the number formats — the cost-model half of
//! [`FormatSpec`].
//!
//! The descriptor itself lives in [`crate::quant::format`]; this module
//! holds the calibrated constants and implements
//! [`FormatSpec::storage_bits`] / [`FormatSpec::mac_cost`] on it, so the
//! tables, roofline and training cost paths read costs from the *same
//! object the quantizers execute* — there is no parallel cost-only
//! format enum to keep in sync.
//!
//! Cost conventions (normalization target: one int32 MAC ≡ 1.0, one
//! 32-bit DRAM element ≡ 32 bits):
//!
//! * **fixed-point b-bit MAC**: `(b₁·b₂)/32²` — multiplier area/energy is
//!   proportional to the product of operand widths (standard array
//!   multiplier scaling; also what makes the paper's fixed-16 row exactly
//!   0.25×). Stochastic-rounding fixed point shares the fixed-point MAC
//!   and storage costs: the rounding happens once at quantization time,
//!   not in the multiply-accumulate array.
//! * **BFP m-bit MAC**: `A·(m₁·m₂)/32² + B·max(m₁,m₂)/32` — a mantissa
//!   multiply plus the per-element alignment/normalization shifter that
//!   scales linearly with width. Fitting the paper's BFP-32 (0.56×) and
//!   BFP-16 (0.18×) rows gives **A = 0.40, B = 0.16**; the stashing rows
//!   then come out at 0.104 (paper 0.10) as a *prediction*.
//! * **fp32 MAC**: 1.2 (aligner + normalizer over int32; the paper
//!   normalizes to fixed-32 and leaves fp32 rows unscored — we do the
//!   same in tables, this constant only feeds the roofline).
//! * **float `e<E>m<M>` MAC**: a significand multiply + per-element
//!   exponent add/align — `A·(p₁·p₂)/32² + B·max(p₁,p₂)/32 +
//!   C·max(E₁,E₂)/8` with `p = M + 1` (the implicit-bit significand;
//!   the sign is an XOR, excluded like everywhere else). `A`/`B` are
//!   the BFP multiplier/shifter constants (same datapath elements); `C`
//!   prices the per-MAC exponent adder against the 8-bit reference.
//!   e4m3×e4m3 comes out at 0.051×, e5m2×e5m2 at 0.050× — the ~1/20 of
//!   int32 that FP8 hardware surveys report.
//! * **storage**: fixed-b = `b` bits/element; BFP-b = `b + 4`
//!   bits/element (sign+mantissa `b`, amortized shared exponent 8/16 =
//!   0.5, container padding — fitted: BFP-32 → 36/32 = 1.13×, BFP-16 →
//!   20/32 = 0.63×, both matching the paper exactly); float = the
//!   container `1 + E + M` (every element carries its own exponent, so
//!   there is no amortized-metadata term — the codec stores exactly
//!   this, byte-per-element at the FP8 widths).
//!
//! Widths ≥ 25 are numerically an identity, but the *hardware* cost
//! still reflects the container (32-bit fixed / BFP-32): the paper's
//! `[32,32,32,32]` rows are real 32-bit hardware paths.

use crate::quant::format::{FormatSpec, Rounding};
use crate::quant::{Codec, BOX, EXP_BITS, PASSTHROUGH_BITS};

/// Fitted BFP MAC constants (DESIGN.md §6).
pub const BFP_MAC_MUL: f64 = 0.40;
pub const BFP_MAC_SHIFT: f64 = 0.16;
/// fp32 MAC cost relative to int32 (roofline only).
pub const FP32_MAC: f64 = 1.2;
/// BFP per-element storage overhead in bits (exponent share + padding).
pub const BFP_STORAGE_OVERHEAD_BITS: f64 = 4.0;
/// Float-family MAC constants: significand multiply reuses the BFP
/// multiplier scaling, alignment reuses the BFP shifter, and the
/// per-element exponent adder is priced against the 8-bit reference.
pub const FLOAT_MAC_MUL: f64 = BFP_MAC_MUL;
pub const FLOAT_MAC_ALIGN: f64 = BFP_MAC_SHIFT;
pub const FLOAT_MAC_EXP: f64 = 0.05;

impl FormatSpec {
    /// Storage bits per element in DRAM.
    pub fn storage_bits(&self) -> f64 {
        match *self {
            FormatSpec::Fp32 => 32.0,
            FormatSpec::Fixed { bits, .. } => bits as f64,
            FormatSpec::Bfp { bits } => bits as f64 + BFP_STORAGE_OVERHEAD_BITS,
            // The container is the whole story: the per-element exponent
            // lives inside the lane, no amortized metadata.
            FormatSpec::Float { .. } => self.bits() as f64,
        }
    }

    /// Relative cost of one MAC with `self` and `other` as operand
    /// formats (int32 MAC ≡ 1.0). Symmetric in its arguments.
    pub fn mac_cost(&self, other: &FormatSpec) -> f64 {
        use FormatSpec::*;
        // Float significand width: mantissa + implicit bit.
        fn p(man_bits: u32) -> f64 {
            (man_bits + 1) as f64
        }
        match (*self, *other) {
            (Fp32, _) | (_, Fp32) => FP32_MAC,
            (Fixed { bits: b1, .. }, Fixed { bits: b2, .. }) => {
                (b1 as f64 * b2 as f64) / 1024.0
            }
            (Bfp { bits: m1 }, Bfp { bits: m2 }) => {
                let (m1, m2) = (m1 as f64, m2 as f64);
                BFP_MAC_MUL * (m1 * m2) / 1024.0 + BFP_MAC_SHIFT * m1.max(m2) / 32.0
            }
            // Mixed fixed/BFP operands: treat the fixed side as a
            // degenerate one-box BFP (same multiplier, shared alignment
            // path).
            (Fixed { bits: b1, .. }, Bfp { bits: m2 })
            | (Bfp { bits: m2 }, Fixed { bits: b1, .. }) => {
                let (b1, m2) = (b1 as f64, m2 as f64);
                BFP_MAC_MUL * (b1 * m2) / 1024.0 + BFP_MAC_SHIFT * b1.max(m2) / 32.0
            }
            // Float × float: significand multiply + align + exponent add.
            (
                Float { exp_bits: e1, man_bits: m1, .. },
                Float { exp_bits: e2, man_bits: m2, .. },
            ) => {
                let (p1, p2) = (p(m1), p(m2));
                FLOAT_MAC_MUL * (p1 * p2) / 1024.0
                    + FLOAT_MAC_ALIGN * p1.max(p2) / 32.0
                    + FLOAT_MAC_EXP * e1.max(e2) as f64 / 8.0
            }
            // Float × fixed/BFP: the integer side feeds its full lane
            // width into the shared multiplier/aligner; the exponent
            // path runs at the float side's width (the integer operand's
            // shared exponent rides the same adder, as in the BFP unit).
            (Float { exp_bits, man_bits, .. }, o) | (o, Float { exp_bits, man_bits, .. }) => {
                let (p1, p2) = (p(man_bits), o.bits() as f64);
                FLOAT_MAC_MUL * (p1 * p2) / 1024.0
                    + FLOAT_MAC_ALIGN * p1.max(p2) / 32.0
                    + FLOAT_MAC_EXP * exp_bits as f64 / 8.0
            }
        }
    }

    /// Bytes the packed codec *actually* stores for `len` elements with
    /// minor axis `inner` — the physical counterpart of
    /// [`FormatSpec::storage_bits`], read straight from the codec's
    /// layout function so the two cannot be computed from different
    /// sources.
    pub fn observed_bytes(&self, len: usize, inner: usize) -> usize {
        self.packed_len(len, inner)
    }

    /// Audit the cost model against the codec: assert
    /// `observed_bytes() ≈ storage_bits() * len / 8` within box-metadata
    /// rounding. The legitimate gaps, and nothing else:
    ///
    /// * widths ≥ 25 quantize as identity, so the codec stores the raw
    ///   32-bit container (the model's documented convention — "the
    ///   hardware cost still reflects the container");
    /// * fixed formats carry one grid byte + bitstream byte-alignment;
    /// * BFP's modeled `+4` bits/elem is the *fitted* container overhead
    ///   (amortized exponent + padding), while the codec stores the raw
    ///   8-bit exponent byte + alignment per box — up to
    ///   [`BFP_STORAGE_OVERHEAD_BITS`] per element plus 15 bits per box
    ///   of divergence, counted over the **boxes the codec actually
    ///   packs** (ragged tensors pack `len % inner` trailing elements as
    ///   a short row with its own boxes);
    /// * float formats carry only the trailing byte-alignment of the
    ///   lane stream.
    ///
    /// Anything beyond the allowance is a drifted cost model (or a
    /// broken codec) and returns `Err` with the numbers.
    pub fn audit_storage(&self, len: usize, inner: usize) -> std::result::Result<(), String> {
        let observed_bits = self.observed_bytes(len, inner) as f64 * 8.0;
        let modeled_bits = self.container_bits() * len as f64;
        let allowance = self.storage_allowance_bits(len, inner);
        let gap = (observed_bits - modeled_bits).abs();
        if gap <= allowance {
            Ok(())
        } else {
            Err(format!(
                "{self}: observed {observed_bits} bits vs modeled {modeled_bits} bits \
                 for {len} elems (inner {inner}); gap {gap} > allowance {allowance}"
            ))
        }
    }

    /// Storage bits per element the *container* occupies — what a
    /// modeled-vs-observed comparison should charge. Equal to
    /// [`FormatSpec::storage_bits`] except at the identity widths
    /// (≥ 25, non-float), where the codec stores the raw 32-bit
    /// container even though narrower bits are priced.
    pub fn container_bits(&self) -> f64 {
        if !matches!(self, FormatSpec::Float { .. }) && self.bits() as f32 >= PASSTHROUGH_BITS {
            32.0f64.max(self.storage_bits())
        } else {
            self.storage_bits()
        }
    }

    /// The legitimate modeled-vs-observed slack (in bits) for a tensor
    /// of `len` elements with minor axis `inner` — grid bytes,
    /// bitstream byte-alignment, and BFP's fitted-vs-raw exponent
    /// metadata, counted over the boxes the codec actually packs
    /// (ragged tensors pack `len % inner` trailing elements as a short
    /// row with its own boxes). [`FormatSpec::audit_storage`] and the
    /// stash store's [`crate::stash::TrafficMeter`] both grant exactly
    /// this.
    pub fn storage_allowance_bits(&self, len: usize, inner: usize) -> f64 {
        match *self {
            FormatSpec::Fp32 => 0.0,
            FormatSpec::Fixed { .. } => 8.0 + 7.0,
            FormatSpec::Float { .. } => 7.0,
            FormatSpec::Bfp { .. } => {
                let full_rows = len / inner;
                let tail = len % inner;
                let nboxes = (full_rows * inner.div_ceil(BOX) + tail.div_ceil(BOX)) as f64;
                len as f64 * BFP_STORAGE_OVERHEAD_BITS + nboxes * (EXP_BITS as f64 + 7.0)
            }
        }
    }

    /// The traffic-side sibling of [`FormatSpec::audit_storage`]: one
    /// synthetic step through a [`crate::stash::StashStore`] must
    /// report stash bytes equal to the codec's `packed_len()` exactly,
    /// and agree with the modeled `container_bits()` within box
    /// metadata — pinning the meter against the codec the way storage
    /// bits already are.
    pub fn observed_traffic(&self) -> std::result::Result<(), String> {
        crate::stash::audit_observed_traffic(self)
    }

    pub fn is_bfp(&self) -> bool {
        matches!(self, FormatSpec::Bfp { .. })
    }

    pub fn is_float(&self) -> bool {
        matches!(self, FormatSpec::Float { .. })
    }

    /// True for formats whose quantizer applies stochastic rounding.
    pub fn is_stochastic(&self) -> bool {
        matches!(
            self,
            FormatSpec::Fixed { rounding: Rounding::Stochastic, .. }
                | FormatSpec::Float { rounding: Rounding::Stochastic, .. }
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_mac_matches_paper_static_rows() {
        // fixed32 = 1.00x (the normalization anchor), fixed16 = 0.25x.
        let f = |b| FormatSpec::fixed(b);
        assert!((f(32).mac_cost(&f(32)) - 1.0).abs() < 1e-12);
        assert!((f(16).mac_cost(&f(16)) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn bfp_mac_matches_paper_static_rows() {
        // BFP32 = 0.56x, BFP16 = 0.18x (the two fitted anchors).
        let c32 = FormatSpec::bfp(32).mac_cost(&FormatSpec::bfp(32));
        let c16 = FormatSpec::bfp(16).mac_cost(&FormatSpec::bfp(16));
        assert!((c32 - 0.56).abs() < 0.005, "bfp32 {c32}");
        assert!((c16 - 0.18).abs() < 0.005, "bfp16 {c16}");
    }

    #[test]
    fn bfp_stash_prediction_near_paper() {
        // Prediction check (not fitted): mean of the three GEMMs of a
        // [16,4,4,16] BFP stashing step = 0.104 vs paper 0.10.
        let f = |a: u32, b: u32| FormatSpec::bfp(a).mac_cost(&FormatSpec::bfp(b));
        let mean = (f(16, 16) + f(4, 4) + f(4, 16)) / 3.0;
        assert!((mean - 0.10).abs() < 0.01, "stash-bfp arith {mean}");
    }

    #[test]
    fn storage_matches_paper_dram_anchors() {
        // BFP32 -> 36/32 = 1.125 (paper 1.13), BFP16 -> 20/32 = 0.625 (0.63).
        assert_eq!(FormatSpec::bfp(32).storage_bits() / 32.0, 1.125);
        assert_eq!(FormatSpec::bfp(16).storage_bits() / 32.0, 0.625);
        assert_eq!(FormatSpec::fixed(16).storage_bits() / 32.0, 0.5);
        assert_eq!(FormatSpec::Fp32.storage_bits(), 32.0);
    }

    #[test]
    fn float_mac_and_storage_anchors() {
        let e4m3 = FormatSpec::fp8e4m3();
        let e5m2 = FormatSpec::fp8e5m2();
        // FP8 MACs land at ~1/20 of int32 (0.051 / 0.050).
        assert!((e4m3.mac_cost(&e4m3) - 0.051).abs() < 5e-4, "{}", e4m3.mac_cost(&e4m3));
        assert!((e5m2.mac_cost(&e5m2) - 0.050).abs() < 5e-4, "{}", e5m2.mac_cost(&e5m2));
        // Storage is the raw container: 8 bits for fp8, 16 for fp16/bf16.
        assert_eq!(e4m3.storage_bits(), 8.0);
        assert_eq!(e5m2.storage_bits(), 8.0);
        assert_eq!(FormatSpec::float(5, 10).storage_bits(), 16.0);
        assert_eq!(FormatSpec::float(8, 7).storage_bits(), 16.0);
        // The packed codec stores exactly one byte per fp8 element.
        assert_eq!(e4m3.observed_bytes(1000, 1000), 1000);
        assert_eq!(FormatSpec::float(5, 10).observed_bytes(1000, 1000), 2000);
        // Monotone in mantissa bits at fixed exponent width.
        let c = |m| {
            let f = FormatSpec::float(5, m);
            f.mac_cost(&f)
        };
        assert!(c(2) < c(5) && c(5) < c(10));
        // Mixed float x bfp / float x fixed is symmetric and finite.
        let m1 = e4m3.mac_cost(&FormatSpec::bfp(16));
        let m2 = FormatSpec::bfp(16).mac_cost(&e4m3);
        assert_eq!(m1, m2);
        assert!(m1 > 0.0 && m1 < 1.0);
        assert_eq!(
            e5m2.mac_cost(&FormatSpec::fixed(16)),
            FormatSpec::fixed(16).mac_cost(&e5m2)
        );
        // fp32 operands dominate as usual.
        assert_eq!(e4m3.mac_cost(&FormatSpec::Fp32), FP32_MAC);
    }

    #[test]
    fn float_sr_costs_like_nearest() {
        let (n, s) = (FormatSpec::fp8e4m3(), FormatSpec::float_sr(4, 3));
        assert_eq!(n.storage_bits(), s.storage_bits());
        assert_eq!(n.mac_cost(&n), s.mac_cost(&s));
        assert!(s.is_stochastic() && !n.is_stochastic());
        assert!(s.is_float() && n.is_float() && !FormatSpec::bfp(4).is_float());
    }

    #[test]
    fn stochastic_rounding_costs_like_nearest() {
        // SR changes the quantizer, not the MAC array or the container.
        for b in [4u32, 8, 16] {
            assert_eq!(
                FormatSpec::fixed_sr(b).storage_bits(),
                FormatSpec::fixed(b).storage_bits()
            );
            assert_eq!(
                FormatSpec::fixed_sr(b).mac_cost(&FormatSpec::fixed_sr(b)),
                FormatSpec::fixed(b).mac_cost(&FormatSpec::fixed(b))
            );
            assert_eq!(
                FormatSpec::fixed_sr(b).mac_cost(&FormatSpec::bfp(16)),
                FormatSpec::fixed(b).mac_cost(&FormatSpec::bfp(16))
            );
        }
    }

    #[test]
    fn mac_cost_monotone_in_bits() {
        for b in [2u32, 4, 8, 16] {
            let big = b * 2;
            assert!(
                FormatSpec::bfp(b).mac_cost(&FormatSpec::bfp(b))
                    < FormatSpec::bfp(big).mac_cost(&FormatSpec::bfp(big))
            );
            assert!(
                FormatSpec::fixed(b).mac_cost(&FormatSpec::fixed(b))
                    < FormatSpec::fixed(big).mac_cost(&FormatSpec::fixed(big))
            );
        }
    }

    #[test]
    fn storage_model_agrees_with_codec_for_every_registry_format() {
        // The satellite contract: the cost model can no longer disagree
        // with the bytes the codec actually stores, beyond box metadata
        // — including on ragged tensors (len % inner != 0).
        for spec in crate::quant::registered_specs(&[2, 3, 4, 5, 6, 8, 12, 16, 20, 24, 32]) {
            for (len, inner) in [
                (4096usize, 4096usize),
                (4096, 128),
                (3 * 100, 100),
                (2 * 21, 21),
                (40, 1),
                (0, 1),
                // Ragged: short trailing rows of every flavor.
                (4096 + 57, 128),
                (5, 24),
                (2 * 21 + 1, 21),
                (100, 48),
            ] {
                spec.audit_storage(len, inner).unwrap_or_else(|e| {
                    panic!("cost model disagrees with codec: {e}");
                });
            }
        }
    }

    #[test]
    fn storage_audit_property_over_random_widths() {
        use crate::util::prop::Prop;
        Prop::new("storage_bits matches packed_len within box metadata").cases(80).run(
            |rng, size| {
                let fam = &crate::quant::FORMAT_REGISTRY
                    [rng.below(crate::quant::FORMAT_REGISTRY.len() as u32) as usize];
                let bits = rng.range(fam.min_bits, fam.max_bits + 1);
                let inner = 1 + rng.below(4 * size + 16) as usize;
                let rows = rng.below(8) as usize;
                // Ragged shapes included: a trailing partial row of any
                // length the codec can pack.
                let tail = rng.below(inner as u32) as usize;
                (fam.instantiate(bits).unwrap(), rows * inner + tail, inner)
            },
            |(spec, len, inner)| spec.audit_storage(*len, *inner),
        );
    }

    #[test]
    fn observed_traffic_pins_the_meter_for_every_registry_format() {
        // The satellite contract: a synthetic step through the stash
        // store reports exactly the bytes the codec packs, and the
        // modeled bits agree within the same allowance audit_storage
        // grants. (The stash module runs the same audit; this placement
        // keeps the two sibling assertions next to each other.)
        for spec in crate::quant::registered_specs(&[2, 4, 8, 16, 32]) {
            spec.observed_traffic()
                .unwrap_or_else(|e| panic!("traffic meter disagrees with codec: {e}"));
        }
    }

    #[test]
    fn container_bits_matches_the_audit_convention() {
        assert_eq!(FormatSpec::Fp32.container_bits(), 32.0);
        assert_eq!(FormatSpec::fixed(8).container_bits(), 8.0);
        // Identity widths store the raw 32-bit container.
        assert_eq!(FormatSpec::fixed(25).container_bits(), 32.0);
        assert_eq!(FormatSpec::bfp(32).container_bits(), 36.0);
        assert_eq!(FormatSpec::fp8e4m3().container_bits(), 8.0);
    }

    #[test]
    fn audit_storage_accepts_ragged_bfp_regression() {
        // The exact shape class the truncating `len / inner` undercounted:
        // a ragged tensor whose tail adds boxes beyond rows * boxes_per_row.
        let spec = FormatSpec::bfp(2);
        // 3 full rows of 33 (3 boxes each) + a 31-elem tail (2 boxes).
        spec.audit_storage(3 * 33 + 31, 33).unwrap();
        // Tail-only tensor (the old count said zero boxes).
        spec.audit_storage(31, 33).unwrap();
        assert_eq!(spec.observed_bytes(31, 33), 2 + 4 + 4);
    }

    #[test]
    fn observed_bytes_exact_anchors() {
        // fp32 is byte-exact against the model.
        assert_eq!(FormatSpec::Fp32.observed_bytes(1000, 1000), 4000);
        // fixed-b: one grid byte + packed lanes.
        assert_eq!(FormatSpec::fixed(4).observed_bytes(1000, 1000), 1 + 500);
        assert_eq!(FormatSpec::fixed_sr(3).observed_bytes(8, 8), 1 + 3);
        // bfp4 full boxes: 9 bytes per 16 elems = 4.5 bits/elem — the
        // stash DRAM claim, physically.
        assert_eq!(FormatSpec::bfp(4).observed_bytes(1600, 1600), 100 * 9);
        let bits_per_elem = FormatSpec::bfp(4).observed_bytes(1600, 1600) as f64 * 8.0 / 1600.0;
        assert!(bits_per_elem <= FormatSpec::bfp(4).storage_bits());
        assert!(bits_per_elem < 4.6);
    }

    #[test]
    fn storage_audit_catches_a_drifted_model() {
        // Sanity for the audit itself: a format whose codec stored the
        // dense container at a sub-byte width would be caught.
        let gap = (FormatSpec::bfp(2).observed_bytes(4096, 4096) as f64 * 8.0
            - 32.0 * 4096.0)
            .abs();
        assert!(gap > 4096.0 * 8.0, "a dense-container bfp2 must trip the allowance");
    }

    #[test]
    fn mixed_operand_cost_symmetric() {
        let a = FormatSpec::bfp(4).mac_cost(&FormatSpec::bfp(16));
        let b = FormatSpec::bfp(16).mac_cost(&FormatSpec::bfp(4));
        assert_eq!(a, b);
        let c = FormatSpec::fixed(4).mac_cost(&FormatSpec::bfp(16));
        let d = FormatSpec::bfp(16).mac_cost(&FormatSpec::fixed(4));
        assert_eq!(c, d);
    }
}
