//! Hardware costs of the number formats — the cost-model half of
//! [`FormatSpec`].
//!
//! The descriptor itself lives in [`crate::quant::format`]; this module
//! holds the calibrated constants and implements
//! [`FormatSpec::storage_bits`] / [`FormatSpec::mac_cost`] on it, so the
//! tables, roofline and training cost paths read costs from the *same
//! object the quantizers execute* — there is no parallel cost-only
//! format enum to keep in sync.
//!
//! Cost conventions (normalization target: one int32 MAC ≡ 1.0, one
//! 32-bit DRAM element ≡ 32 bits):
//!
//! * **fixed-point b-bit MAC**: `(b₁·b₂)/32²` — multiplier area/energy is
//!   proportional to the product of operand widths (standard array
//!   multiplier scaling; also what makes the paper's fixed-16 row exactly
//!   0.25×). Stochastic-rounding fixed point shares the fixed-point MAC
//!   and storage costs: the rounding happens once at quantization time,
//!   not in the multiply-accumulate array.
//! * **BFP m-bit MAC**: `A·(m₁·m₂)/32² + B·max(m₁,m₂)/32` — a mantissa
//!   multiply plus the per-element alignment/normalization shifter that
//!   scales linearly with width. Fitting the paper's BFP-32 (0.56×) and
//!   BFP-16 (0.18×) rows gives **A = 0.40, B = 0.16**; the stashing rows
//!   then come out at 0.104 (paper 0.10) as a *prediction*.
//! * **fp32 MAC**: 1.2 (aligner + normalizer over int32; the paper
//!   normalizes to fixed-32 and leaves fp32 rows unscored — we do the
//!   same in tables, this constant only feeds the roofline).
//! * **storage**: fixed-b = `b` bits/element; BFP-b = `b + 4`
//!   bits/element (sign+mantissa `b`, amortized shared exponent 8/16 =
//!   0.5, container padding — fitted: BFP-32 → 36/32 = 1.13×, BFP-16 →
//!   20/32 = 0.63×, both matching the paper exactly).
//!
//! Widths ≥ 25 are numerically an identity, but the *hardware* cost
//! still reflects the container (32-bit fixed / BFP-32): the paper's
//! `[32,32,32,32]` rows are real 32-bit hardware paths.

use crate::quant::format::{FormatSpec, Rounding};

/// Fitted BFP MAC constants (DESIGN.md §6).
pub const BFP_MAC_MUL: f64 = 0.40;
pub const BFP_MAC_SHIFT: f64 = 0.16;
/// fp32 MAC cost relative to int32 (roofline only).
pub const FP32_MAC: f64 = 1.2;
/// BFP per-element storage overhead in bits (exponent share + padding).
pub const BFP_STORAGE_OVERHEAD_BITS: f64 = 4.0;

impl FormatSpec {
    /// Storage bits per element in DRAM.
    pub fn storage_bits(&self) -> f64 {
        match *self {
            FormatSpec::Fp32 => 32.0,
            FormatSpec::Fixed { bits, .. } => bits as f64,
            FormatSpec::Bfp { bits } => bits as f64 + BFP_STORAGE_OVERHEAD_BITS,
        }
    }

    /// Relative cost of one MAC with `self` and `other` as operand
    /// formats (int32 MAC ≡ 1.0). Symmetric in its arguments.
    pub fn mac_cost(&self, other: &FormatSpec) -> f64 {
        use FormatSpec::*;
        match (*self, *other) {
            (Fp32, _) | (_, Fp32) => FP32_MAC,
            (Fixed { bits: b1, .. }, Fixed { bits: b2, .. }) => {
                (b1 as f64 * b2 as f64) / 1024.0
            }
            (Bfp { bits: m1 }, Bfp { bits: m2 }) => {
                let (m1, m2) = (m1 as f64, m2 as f64);
                BFP_MAC_MUL * (m1 * m2) / 1024.0 + BFP_MAC_SHIFT * m1.max(m2) / 32.0
            }
            // Mixed fixed/BFP operands: treat the fixed side as a
            // degenerate one-box BFP (same multiplier, shared alignment
            // path).
            (Fixed { bits: b1, .. }, Bfp { bits: m2 })
            | (Bfp { bits: m2 }, Fixed { bits: b1, .. }) => {
                let (b1, m2) = (b1 as f64, m2 as f64);
                BFP_MAC_MUL * (b1 * m2) / 1024.0 + BFP_MAC_SHIFT * b1.max(m2) / 32.0
            }
        }
    }

    pub fn is_bfp(&self) -> bool {
        matches!(self, FormatSpec::Bfp { .. })
    }

    /// True for formats whose quantizer applies stochastic rounding.
    pub fn is_stochastic(&self) -> bool {
        matches!(self, FormatSpec::Fixed { rounding: Rounding::Stochastic, .. })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_mac_matches_paper_static_rows() {
        // fixed32 = 1.00x (the normalization anchor), fixed16 = 0.25x.
        let f = |b| FormatSpec::fixed(b);
        assert!((f(32).mac_cost(&f(32)) - 1.0).abs() < 1e-12);
        assert!((f(16).mac_cost(&f(16)) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn bfp_mac_matches_paper_static_rows() {
        // BFP32 = 0.56x, BFP16 = 0.18x (the two fitted anchors).
        let c32 = FormatSpec::bfp(32).mac_cost(&FormatSpec::bfp(32));
        let c16 = FormatSpec::bfp(16).mac_cost(&FormatSpec::bfp(16));
        assert!((c32 - 0.56).abs() < 0.005, "bfp32 {c32}");
        assert!((c16 - 0.18).abs() < 0.005, "bfp16 {c16}");
    }

    #[test]
    fn bfp_stash_prediction_near_paper() {
        // Prediction check (not fitted): mean of the three GEMMs of a
        // [16,4,4,16] BFP stashing step = 0.104 vs paper 0.10.
        let f = |a: u32, b: u32| FormatSpec::bfp(a).mac_cost(&FormatSpec::bfp(b));
        let mean = (f(16, 16) + f(4, 4) + f(4, 16)) / 3.0;
        assert!((mean - 0.10).abs() < 0.01, "stash-bfp arith {mean}");
    }

    #[test]
    fn storage_matches_paper_dram_anchors() {
        // BFP32 -> 36/32 = 1.125 (paper 1.13), BFP16 -> 20/32 = 0.625 (0.63).
        assert_eq!(FormatSpec::bfp(32).storage_bits() / 32.0, 1.125);
        assert_eq!(FormatSpec::bfp(16).storage_bits() / 32.0, 0.625);
        assert_eq!(FormatSpec::fixed(16).storage_bits() / 32.0, 0.5);
        assert_eq!(FormatSpec::Fp32.storage_bits(), 32.0);
    }

    #[test]
    fn stochastic_rounding_costs_like_nearest() {
        // SR changes the quantizer, not the MAC array or the container.
        for b in [4u32, 8, 16] {
            assert_eq!(
                FormatSpec::fixed_sr(b).storage_bits(),
                FormatSpec::fixed(b).storage_bits()
            );
            assert_eq!(
                FormatSpec::fixed_sr(b).mac_cost(&FormatSpec::fixed_sr(b)),
                FormatSpec::fixed(b).mac_cost(&FormatSpec::fixed(b))
            );
            assert_eq!(
                FormatSpec::fixed_sr(b).mac_cost(&FormatSpec::bfp(16)),
                FormatSpec::fixed(b).mac_cost(&FormatSpec::bfp(16))
            );
        }
    }

    #[test]
    fn mac_cost_monotone_in_bits() {
        for b in [2u32, 4, 8, 16] {
            let big = b * 2;
            assert!(
                FormatSpec::bfp(b).mac_cost(&FormatSpec::bfp(b))
                    < FormatSpec::bfp(big).mac_cost(&FormatSpec::bfp(big))
            );
            assert!(
                FormatSpec::fixed(b).mac_cost(&FormatSpec::fixed(b))
                    < FormatSpec::fixed(big).mac_cost(&FormatSpec::fixed(big))
            );
        }
    }

    #[test]
    fn mixed_operand_cost_symmetric() {
        let a = FormatSpec::bfp(4).mac_cost(&FormatSpec::bfp(16));
        let b = FormatSpec::bfp(16).mac_cost(&FormatSpec::bfp(4));
        assert_eq!(a, b);
        let c = FormatSpec::fixed(4).mac_cost(&FormatSpec::bfp(16));
        let d = FormatSpec::bfp(16).mac_cost(&FormatSpec::fixed(4));
        assert_eq!(c, d);
    }
}
