//! `dsq` — CLI entrypoint for the DSQ training coordinator.
//!
//! Subcommand dispatch lives here; each subcommand's implementation is in
//! the library ([`dsq::coordinator`], [`dsq::experiments`], ...).

fn main() {
    dsq::util::logging::level_from_env();
    let args: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(dsq::coordinator::cli::dispatch(&args));
}
