//! Minimal JSON parser/serializer (serde is unavailable offline).
//!
//! Covers the full JSON grammar; used for the artifact manifest, run
//! configs and experiment reports. Numbers are kept as f64 (the manifest
//! only contains small integers and the reports only finite floats).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::{Error, Result};

/// A JSON value. Objects use BTreeMap so serialization is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ----------------------------------------------------------- access

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"][2]`-style path access, `/`-separated.
    pub fn path(&self, path: &str) -> Option<&Json> {
        let mut cur = self;
        for part in path.split('/') {
            cur = match cur {
                Json::Obj(m) => m.get(part)?,
                Json::Arr(v) => v.get(part.parse::<usize>().ok()?)?,
                _ => return None,
            };
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| if f >= 0.0 { Some(f as usize) } else { None })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Required-field access with a descriptive error.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| Error::Json(format!("missing key '{key}'")))
    }

    // ------------------------------------------------------ construction

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num<N: Into<f64>>(n: N) -> Json {
        Json::Num(n.into())
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    // -------------------------------------------------------- serialize

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------------------------------------------------------- parse

pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(Error::Json(format!("trailing characters at byte {}", p.pos)));
    }
    Ok(v)
}

/// Parse the JSON file at `path`.
pub fn parse_file(path: &std::path::Path) -> Result<Json> {
    parse(&std::fs::read_to_string(path)?)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Json(format!("{msg} at byte {}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(self.err(&format!("invalid literal (expected {word})")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected character '{}'", c as char))),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => {
                    self.pos += 1;
                    return Ok(s);
                }
                b'\\' => {
                    self.pos += 1;
                    let esc = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .ok_or_else(|| self.err("bad \\u"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| self.err("bad \\u"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u"))?;
                            self.pos += 4;
                            // Surrogate pairs.
                            let ch = if (0xD800..0xDC00).contains(&code) {
                                if self.bytes.get(self.pos) == Some(&b'\\')
                                    && self.bytes.get(self.pos + 1) == Some(&b'u')
                                {
                                    let hex2 = self
                                        .bytes
                                        .get(self.pos + 2..self.pos + 6)
                                        .ok_or_else(|| self.err("bad surrogate"))?;
                                    let low = u32::from_str_radix(
                                        std::str::from_utf8(hex2)
                                            .map_err(|_| self.err("bad surrogate"))?,
                                        16,
                                    )
                                    .map_err(|_| self.err("bad surrogate"))?;
                                    self.pos += 6;
                                    0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00)
                                } else {
                                    return Err(self.err("lone high surrogate"));
                                }
                            } else {
                                code
                            };
                            s.push(char::from_u32(ch).ok_or_else(|| self.err("bad codepoint"))?);
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                _ => {
                    // Consume one UTF-8 scalar.
                    let rest = &self.bytes[self.pos..];
                    let text = std::str::from_utf8(rest).map_err(|_| self.err("invalid utf8"))?;
                    let ch = text.chars().next().unwrap();
                    s.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.path("a/2/b"), Some(&Json::Null));
        assert_eq!(v.path("c").and_then(Json::as_str), Some("x"));
        assert_eq!(v.path("a/0").and_then(Json::as_i64), Some(1));
    }

    #[test]
    fn parse_escapes() {
        let v = parse(r#""a\nb\t\"q\"A😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"q\"A😀");
    }

    #[test]
    fn parse_whitespace_and_empty() {
        assert_eq!(parse(" { } ").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn parse_errors() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,{"b":null,"c":true}],"d":"x\ny"}"#;
        let v = parse(src).unwrap();
        let re = parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
        let re2 = parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, re2);
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::num(5).to_string(), "5");
        assert_eq!(Json::num(5.25).to_string(), "5.25");
    }

    #[test]
    fn real_manifest_shape() {
        let man = r#"{"version":1,"models":{"nmt":{"params":[{"name":"w","shape":[2,3]}]}}}"#;
        let v = parse(man).unwrap();
        assert_eq!(v.path("models/nmt/params/0/name").and_then(Json::as_str), Some("w"));
        let shape: Vec<usize> = v
            .path("models/nmt/params/0/shape")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|j| j.as_usize().unwrap())
            .collect();
        assert_eq!(shape, vec![2, 3]);
    }
}
