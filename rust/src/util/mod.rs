//! Substrate utilities built in-tree (the deployment environment is
//! offline, so the usual crates — serde, clap, rand, criterion, proptest —
//! are replaced by small, tested, dependency-free implementations; see
//! DESIGN.md §5).

pub mod cli;
pub mod json;
pub mod logging;
pub mod ordwitness;
pub mod prop;
pub mod rng;
pub mod stats;
