//! Hand-rolled property-testing harness (proptest is unavailable offline).
//!
//! A property is checked against `cases` generated inputs from a seeded
//! [`Pcg32`]. On failure the harness retries the failing case with
//! smaller "size" hints (simple input shrinking by regeneration) and
//! panics with the seed + case index so the exact failure replays:
//!
//! ```text
//! property 'batcher never exceeds max tokens' failed
//!   seed=42 case=17 size=3   (re-run: Prop::replay(42, 17, 3, gen, check))
//! ```

use crate::util::rng::Pcg32;

/// Property harness configuration.
pub struct Prop {
    pub name: &'static str,
    pub cases: u32,
    pub seed: u64,
}

impl Prop {
    pub fn new(name: &'static str) -> Self {
        Prop { name, cases: 100, seed: 0xD5A } // default seed is arbitrary, fixed
    }

    pub fn cases(mut self, n: u32) -> Self {
        self.cases = n;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    /// Run `check` on `cases` inputs from `gen`.
    ///
    /// `gen(rng, size)` should scale its output with `size` (1 ..= 100):
    /// the harness sweeps sizes upward so small counterexamples surface
    /// first, then — on failure — retries the same seed at smaller sizes
    /// to report the smallest reproduction it can find.
    pub fn run<T, G, C>(self, mut gen: G, mut check: C)
    where
        T: std::fmt::Debug,
        G: FnMut(&mut Pcg32, u32) -> T,
        C: FnMut(&T) -> Result<(), String>,
    {
        for case in 0..self.cases {
            let size = 1 + (case * 100 / self.cases.max(1)).min(99);
            let mut rng = Pcg32::new(self.seed ^ (case as u64) << 17);
            let input = gen(&mut rng, size);
            if let Err(msg) = check(&input) {
                // Try to find a smaller failing size for the same case seed.
                let mut smallest: Option<(u32, T, String)> = None;
                for s in 1..size {
                    let mut r2 = Pcg32::new(self.seed ^ (case as u64) << 17);
                    let small = gen(&mut r2, s);
                    if let Err(m2) = check(&small) {
                        smallest = Some((s, small, m2));
                        break;
                    }
                }
                match smallest {
                    Some((s, small, m2)) => panic!(
                        "property '{}' failed: {m2}\n  seed={} case={case} size={s}\n  shrunk input: {small:?}",
                        self.name, self.seed
                    ),
                    None => panic!(
                        "property '{}' failed: {msg}\n  seed={} case={case} size={size}\n  input: {input:?}",
                        self.name, self.seed
                    ),
                }
            }
        }
    }
}

/// Generate a vec of `len` f32s with magnitudes spanning `2^±span`.
pub fn gen_f32s(rng: &mut Pcg32, len: usize, span: f32) -> Vec<f32> {
    (0..len)
        .map(|_| {
            let mag = (rng.f32() * 2.0 - 1.0) * span;
            rng.normal() * mag.exp2()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        Prop::new("sum of two non-negatives is >= each").cases(50).run(
            |rng, size| (rng.below(size) as u64, rng.below(size) as u64),
            |&(a, b)| {
                count += 1;
                if a + b >= a && a + b >= b {
                    Ok(())
                } else {
                    Err("overflow".into())
                }
            },
        );
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'always fails' failed")]
    fn failing_property_panics_with_seed() {
        Prop::new("always fails").cases(5).run(
            |rng, _| rng.below(10),
            |_| Err("nope".into()),
        );
    }

    #[test]
    fn gen_f32s_spans_magnitudes() {
        let mut rng = Pcg32::new(1);
        let xs = gen_f32s(&mut rng, 1000, 10.0);
        assert_eq!(xs.len(), 1000);
        let max = xs.iter().fold(0f32, |a, &x| a.max(x.abs()));
        let minpos = xs.iter().filter(|x| **x != 0.0).fold(f32::MAX, |a, &x| a.min(x.abs()));
        assert!(max / minpos > 100.0, "magnitude span too small: {max} / {minpos}");
    }
}
