//! Leveled stderr logger with monotonic timestamps.
//!
//! Zero-dependency substitute for `log`/`env_logger`. Level is set once
//! at startup (`--verbose`/`--quiet` or `DSQ_LOG=debug|info|warn|error`).

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn level_from_env() {
    if let Ok(v) = std::env::var("DSQ_LOG") {
        let lvl = match v.to_ascii_lowercase().as_str() {
            "debug" => Level::Debug,
            "info" => Level::Info,
            "warn" => Level::Warn,
            "error" => Level::Error,
            _ => Level::Info,
        };
        set_level(lvl);
    }
}

pub fn enabled(level: Level) -> bool {
    level as u8 >= LEVEL.load(Ordering::Relaxed)
}

/// Seconds since first log call, for compact relative timestamps.
pub fn elapsed() -> f64 {
    START.get_or_init(Instant::now).elapsed().as_secs_f64()
}

pub fn log(level: Level, args: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let tag = match level {
        Level::Debug => "DEBUG",
        Level::Info => "INFO ",
        Level::Warn => "WARN ",
        Level::Error => "ERROR",
    };
    eprintln!("[{:>9.3}s {tag}] {args}", elapsed());
}

#[macro_export]
macro_rules! debug {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($t)*)) };
}
#[macro_export]
macro_rules! info {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($t)*)) };
}
#[macro_export]
macro_rules! warn {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, format_args!($($t)*)) };
}
#[macro_export]
macro_rules! error {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, format_args!($($t)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Debug < Level::Info);
        assert!(Level::Info < Level::Warn);
        assert!(Level::Warn < Level::Error);
    }

    #[test]
    fn enabled_respects_level() {
        set_level(Level::Warn);
        assert!(!enabled(Level::Debug));
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Warn));
        assert!(enabled(Level::Error));
        set_level(Level::Info); // restore default for other tests
    }

    #[test]
    fn elapsed_monotonic() {
        let a = elapsed();
        let b = elapsed();
        assert!(b >= a);
    }
}
