//! Leveled stderr logger with monotonic timestamps.
//!
//! Zero-dependency substitute for `log`/`env_logger`. Level is set once
//! at startup (`--verbose`/`--quiet` or `DSQ_LOG=debug|info|warn|error`).

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Parse a level name (case-insensitive). `None` for anything outside
/// the valid set — the caller decides how loudly to complain.
pub fn parse_level(s: &str) -> Option<Level> {
    match s.to_ascii_lowercase().as_str() {
        "debug" => Some(Level::Debug),
        "info" => Some(Level::Info),
        "warn" => Some(Level::Warn),
        "error" => Some(Level::Error),
        _ => None,
    }
}

/// Apply `DSQ_LOG` if set. An unrecognized value used to be silently
/// coerced to `Info` — a typo like `DSQ_LOG=trace` just ate every debug
/// line with no hint why. Now it warns loudly, naming the bad value and
/// the valid set, and keeps the default.
pub fn level_from_env() {
    if let Ok(v) = std::env::var("DSQ_LOG") {
        match parse_level(&v) {
            Some(lvl) => set_level(lvl),
            None => log(
                Level::Warn,
                format_args!(
                    "DSQ_LOG={v:?} is not a log level (valid: debug|info|warn|error); \
                     keeping the default"
                ),
            ),
        }
    }
}

pub fn enabled(level: Level) -> bool {
    level as u8 >= LEVEL.load(Ordering::Relaxed)
}

/// Seconds since first log call, for compact relative timestamps.
pub fn elapsed() -> f64 {
    START.get_or_init(Instant::now).elapsed().as_secs_f64()
}

pub fn log(level: Level, args: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let tag = match level {
        Level::Debug => "DEBUG",
        Level::Info => "INFO ",
        Level::Warn => "WARN ",
        Level::Error => "ERROR",
    };
    eprintln!("[{:>9.3}s {tag}] {args}", elapsed());
}

#[macro_export]
macro_rules! debug {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($t)*)) };
}
#[macro_export]
macro_rules! info {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($t)*)) };
}
#[macro_export]
macro_rules! warn {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, format_args!($($t)*)) };
}
#[macro_export]
macro_rules! error {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, format_args!($($t)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Debug < Level::Info);
        assert!(Level::Info < Level::Warn);
        assert!(Level::Warn < Level::Error);
    }

    #[test]
    fn enabled_respects_level() {
        set_level(Level::Warn);
        assert!(!enabled(Level::Debug));
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Warn));
        assert!(enabled(Level::Error));
        set_level(Level::Info); // restore default for other tests
    }

    #[test]
    fn parse_level_accepts_the_valid_set_case_insensitively() {
        assert_eq!(parse_level("debug"), Some(Level::Debug));
        assert_eq!(parse_level("INFO"), Some(Level::Info));
        assert_eq!(parse_level("Warn"), Some(Level::Warn));
        assert_eq!(parse_level("error"), Some(Level::Error));
    }

    #[test]
    fn parse_level_rejects_everything_else() {
        // The values the old code silently coerced to Info.
        for bad in ["trace", "verbose", "2", "", " info"] {
            assert_eq!(parse_level(bad), None, "{bad:?} must not parse");
        }
    }

    #[test]
    fn elapsed_monotonic() {
        let a = elapsed();
        let b = elapsed();
        assert!(b >= a);
    }
}
