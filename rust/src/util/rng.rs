//! Deterministic PRNG (PCG-XSH-RR 64/32 + SplitMix64 seeding).
//!
//! Every stochastic component in the coordinator (corpus synthesis,
//! batching order, property tests) takes an explicit [`Pcg32`] so runs
//! are reproducible from a single `--seed` flag.

/// PCG-XSH-RR 64/32 (O'Neill 2014). Small, fast, statistically solid —
/// more than enough for workload synthesis.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

/// SplitMix64 — used to expand a user seed into stream/state.
fn splitmix64(x: &mut u64) -> u64 {
    *x = x.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Pcg32 {
    /// Create from a seed; distinct seeds give independent streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let init_state = splitmix64(&mut sm);
        let init_inc = splitmix64(&mut sm) | 1;
        let mut rng = Pcg32 { state: 0, inc: init_inc };
        rng.state = init_state.wrapping_add(rng.inc);
        rng.next_u32();
        rng
    }

    /// Derive an independent child stream (for per-worker RNGs).
    pub fn fork(&mut self, tag: u64) -> Pcg32 {
        Pcg32::new((self.next_u64()).wrapping_add(tag.wrapping_mul(0x9E3779B97F4A7C15)))
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, n)` without modulo bias (Lemire's method).
    pub fn below(&mut self, n: u32) -> u32 {
        assert!(n > 0);
        loop {
            let x = self.next_u32() as u64;
            let m = x * n as u64;
            let low = m as u32;
            if low >= n {
                return (m >> 32) as u32;
            }
            // Rejection zone: low < n. Accept iff low >= (2^32 - n) % n.
            let threshold = n.wrapping_neg() % n;
            if low >= threshold {
                return (m >> 32) as u32;
            }
        }
    }

    /// Uniform integer in `[lo, hi)`.
    pub fn range(&mut self, lo: u32, hi: u32) -> u32 {
        assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = (self.f64()).max(1e-300);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()) as f32
    }

    /// Bernoulli(p).
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Uniformly pick a reference from a non-empty slice.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u32) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg32::new(7);
        let mut b = Pcg32::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::new(1);
        let mut b = Pcg32::new(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Pcg32::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = rng.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut rng = Pcg32::new(4);
        for _ in 0..1000 {
            let x = rng.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::new(5);
        let n = 20_000;
        let xs: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::new(6);
        let mut xs: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Pcg32::new(9);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }
}
