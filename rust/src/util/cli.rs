//! Declarative command-line parsing (clap is unavailable offline).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, defaults,
//! required flags, and auto-generated `--help` text. Subcommand dispatch
//! lives in `main.rs`; each subcommand builds one [`ArgSpec`].

use std::collections::BTreeMap;

use crate::{Error, Result};

#[derive(Clone, Debug)]
struct Flag {
    name: &'static str,
    help: &'static str,
    default: Option<String>,
    required: bool,
    boolean: bool,
}

/// Flag schema + parser for one subcommand.
#[derive(Clone, Debug, Default)]
pub struct ArgSpec {
    command: &'static str,
    about: &'static str,
    flags: Vec<Flag>,
}

/// Parsed arguments.
#[derive(Clone, Debug)]
pub struct Args {
    values: BTreeMap<String, String>,
    bools: BTreeMap<String, bool>,
    /// Positional (non-flag) arguments, in order.
    pub positional: Vec<String>,
}

impl ArgSpec {
    pub fn new(command: &'static str, about: &'static str) -> Self {
        ArgSpec { command, about, flags: Vec::new() }
    }

    /// Optional flag with a default value.
    pub fn opt(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.flags.push(Flag {
            name,
            help,
            default: Some(default.to_string()),
            required: false,
            boolean: false,
        });
        self
    }

    /// Required flag.
    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(Flag { name, help, default: None, required: true, boolean: false });
        self
    }

    /// Boolean flag (no value; present = true).
    pub fn bool(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(Flag { name, help, default: None, required: false, boolean: true });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("dsq {} — {}\n\nflags:\n", self.command, self.about);
        for f in &self.flags {
            let kind = if f.boolean {
                String::new()
            } else if let Some(d) = &f.default {
                format!(" <value, default {d}>")
            } else {
                " <value, required>".to_string()
            };
            s.push_str(&format!("  --{}{}\n      {}\n", f.name, kind, f.help));
        }
        s
    }

    /// Parse a raw argument list (not including argv[0]/subcommand).
    pub fn parse(&self, raw: &[String]) -> Result<Args> {
        let mut values = BTreeMap::new();
        let mut bools: BTreeMap<String, bool> =
            self.flags.iter().filter(|f| f.boolean).map(|f| (f.name.to_string(), false)).collect();
        let mut positional = Vec::new();
        let find = |name: &str| self.flags.iter().find(|f| f.name == name);

        let mut i = 0;
        while i < raw.len() {
            let arg = &raw[i];
            if arg == "--help" || arg == "-h" {
                return Err(Error::Config(self.usage()));
            }
            if let Some(stripped) = arg.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (stripped, None),
                };
                let flag = find(name)
                    .ok_or_else(|| Error::Config(format!("unknown flag --{name}\n{}", self.usage())))?;
                if flag.boolean {
                    if inline.is_some() {
                        return Err(Error::Config(format!("--{name} takes no value")));
                    }
                    bools.insert(name.to_string(), true);
                } else {
                    let val = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            raw.get(i)
                                .cloned()
                                .ok_or_else(|| Error::Config(format!("--{name} needs a value")))?
                        }
                    };
                    values.insert(name.to_string(), val);
                }
            } else {
                positional.push(arg.clone());
            }
            i += 1;
        }

        for f in &self.flags {
            if f.boolean {
                continue;
            }
            if !values.contains_key(f.name) {
                match (&f.default, f.required) {
                    (Some(d), _) => {
                        values.insert(f.name.to_string(), d.clone());
                    }
                    (None, true) => {
                        return Err(Error::Config(format!(
                            "missing required flag --{}\n{}",
                            f.name,
                            self.usage()
                        )))
                    }
                    (None, false) => {}
                }
            }
        }
        Ok(Args { values, bools, positional })
    }
}

impl Args {
    pub fn get(&self, name: &str) -> &str {
        self.values.get(name).map(|s| s.as_str()).unwrap_or("")
    }

    pub fn get_bool(&self, name: &str) -> bool {
        self.bools.get(name).copied().unwrap_or(false)
    }

    pub fn get_usize(&self, name: &str) -> Result<usize> {
        self.get(name)
            .parse()
            .map_err(|_| Error::Config(format!("--{name} must be an integer, got '{}'", self.get(name))))
    }

    pub fn get_u64(&self, name: &str) -> Result<u64> {
        self.get(name)
            .parse()
            .map_err(|_| Error::Config(format!("--{name} must be an integer, got '{}'", self.get(name))))
    }

    pub fn get_f64(&self, name: &str) -> Result<f64> {
        self.get(name)
            .parse()
            .map_err(|_| Error::Config(format!("--{name} must be a number, got '{}'", self.get(name))))
    }

    pub fn get_f32(&self, name: &str) -> Result<f32> {
        Ok(self.get_f64(name)? as f32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ArgSpec {
        ArgSpec::new("train", "test")
            .opt("steps", "100", "number of steps")
            .opt("lr", "0.001", "learning rate")
            .req("out", "output dir")
            .bool("verbose", "chatty")
    }

    fn parse(args: &[&str]) -> Result<Args> {
        spec().parse(&args.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    }

    #[test]
    fn defaults_and_required() {
        let a = parse(&["--out", "/tmp/x"]).unwrap();
        assert_eq!(a.get_usize("steps").unwrap(), 100);
        assert_eq!(a.get_f64("lr").unwrap(), 0.001);
        assert_eq!(a.get("out"), "/tmp/x");
        assert!(!a.get_bool("verbose"));
    }

    #[test]
    fn explicit_values_and_equals_form() {
        let a = parse(&["--steps=7", "--out", "o", "--verbose", "--lr", "0.1"]).unwrap();
        assert_eq!(a.get_usize("steps").unwrap(), 7);
        assert_eq!(a.get_f64("lr").unwrap(), 0.1);
        assert!(a.get_bool("verbose"));
    }

    #[test]
    fn missing_required_is_error() {
        assert!(parse(&["--steps", "5"]).is_err());
    }

    #[test]
    fn unknown_flag_is_error() {
        assert!(parse(&["--out", "o", "--nope", "1"]).is_err());
    }

    #[test]
    fn positional_args_collected() {
        let a = parse(&["pos1", "--out", "o", "pos2"]).unwrap();
        assert_eq!(a.positional, vec!["pos1", "pos2"]);
    }

    #[test]
    fn bad_int_is_error() {
        let a = parse(&["--steps", "abc", "--out", "o"]).unwrap();
        assert!(a.get_usize("steps").is_err());
    }

    #[test]
    fn help_is_error_with_usage() {
        let err = parse(&["--help"]).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("--steps"));
        assert!(msg.contains("learning rate"));
    }
}
