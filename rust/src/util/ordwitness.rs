//! Debug-build lock-order witness: the runtime twin of the static
//! `lock_discipline` / `blocking_under_lock` rules.
//!
//! The static rules (`crate::analysis::{locks, blocking}`) prove lock
//! ordering over the *lexical* call graph; this module asserts the same
//! declared order *dynamically*, on every test run, per thread:
//!
//! * every shared mutex is a [`WitnessedMutex`] carrying a numeric rank
//!   and a name; acquisition pushes onto a thread-local stack and
//!   panics if the rank does not strictly exceed the rank currently on
//!   top — an AB/BA inversion dies at the first inverted acquisition,
//!   deterministically, instead of deadlocking one run in a thousand;
//! * [`assert_lock_free`] is the runtime counterpart of
//!   `blocking_under_lock`: call it at blocking edges (thread joins,
//!   channel parks, spill-file I/O) and it panics if any witnessed lock
//!   is held on this thread.
//!
//! Zero cost in release: the stack, the rank/name fields and every
//! check compile away under `#[cfg(debug_assertions)]`; what remains is
//! a plain poison-recovering `Mutex` (matching the repo's
//! `unwrap_or_else(PoisonError::into_inner)` convention — meters and
//! post boards stay usable after a peer panics, and the exchange has
//! its own teardown protocol).
//!
//! Declared global order (gaps left for future subsystems — ranks must
//! strictly increase along any acquisition chain, so same-rank
//! reacquisition is also refused):
//!
//! | rank | lock |
//! |------|------|
//! | [`RANK_EXCHANGE_RING`]  (10) | `stash::transport` mem `ring` post board |
//! | [`RANK_TRANSPORT_SOCKET`] (15) | `stash::transport` socket `failed` flag |
//! | [`RANK_EXCHANGE_COMMS`] (20) | `stash::exchange` `comms` traffic meter |
//! | [`RANK_OBS_BUFFER`] (30) | `obs` recorder `obsbuf` event buffer |
//!
//! The stash store and its readback prefetcher are deliberately
//! lock-free (the prefetcher is a `JoinHandle`, not a shared mutex);
//! their blocking edges carry [`assert_lock_free`] so that design
//! stays enforced, not assumed.
//!
//! Guards survive a condvar wait by going through
//! [`WitnessedGuard::wait`]: the mutex is released while parked (which
//! is why condvar waits are legal under `blocking_under_lock`) but the
//! witness entry stays, because the lock is re-held the moment the wait
//! returns.

use std::sync::{Condvar, Mutex, MutexGuard, PoisonError};

/// The exchange `ring` post board — first in the global order.
pub const RANK_EXCHANGE_RING: u32 = 10;
/// The socket transport's `failed` flag — never held across I/O, and
/// slotted between `ring` and `comms` so either may nest around it.
pub const RANK_TRANSPORT_SOCKET: u32 = 15;
/// The exchange `comms` traffic meter — always after `ring`.
pub const RANK_EXCHANGE_COMMS: u32 = 20;
/// The obs recorder's event buffer — last in the order, so telemetry
/// may be recorded while any other subsystem lock is held (it never
/// holds anything itself while file I/O runs).
pub const RANK_OBS_BUFFER: u32 = 30;

#[cfg(debug_assertions)]
thread_local! {
    /// Per-thread stack of held (rank, name) pairs, acquisition order.
    static HELD: std::cell::RefCell<Vec<(u32, &'static str)>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

#[cfg(debug_assertions)]
fn note_acquire(rank: u32, name: &'static str) {
    HELD.with(|h| {
        let mut h = h.borrow_mut();
        if let Some(&(top, top_name)) = h.last() {
            assert!(
                top < rank,
                "lock-order witness: acquiring '{name}' (rank {rank}) while holding \
                 '{top_name}' (rank {top}) — declared global order violated"
            );
        }
        h.push((rank, name));
    });
}

#[cfg(debug_assertions)]
fn note_release(rank: u32, name: &'static str) {
    HELD.with(|h| {
        let mut h = h.borrow_mut();
        // Guards may drop out of acquisition order; remove the matching
        // entry wherever it sits.
        if let Some(i) = h.iter().rposition(|&(r, n)| r == rank && n == name) {
            h.remove(i);
        }
    });
}

/// Ranks currently held by this thread, acquisition order (debug-only
/// diagnostic; the witness tests pin `wait` semantics through it).
#[cfg(debug_assertions)]
pub fn held_ranks() -> Vec<u32> {
    HELD.with(|h| h.borrow().iter().map(|&(r, _)| r).collect())
}

/// Runtime counterpart of the `blocking_under_lock` lint rule: panics
/// (debug builds only) if this thread holds any witnessed lock while
/// crossing a blocking edge named `op`.
pub fn assert_lock_free(op: &str) {
    #[cfg(debug_assertions)]
    HELD.with(|h| {
        if let Some(&(rank, name)) = h.borrow().last() {
            panic!(
                "lock-order witness: {op} while holding '{name}' (rank {rank}) — \
                 blocking operations must run lock-free"
            );
        }
    });
    #[cfg(not(debug_assertions))]
    let _ = op;
}

/// A `Mutex` that asserts the declared global acquisition order in
/// debug builds and is a plain poison-recovering mutex in release.
pub struct WitnessedMutex<T> {
    #[cfg(debug_assertions)]
    rank: u32,
    #[cfg(debug_assertions)]
    name: &'static str,
    inner: Mutex<T>,
}

impl<T> WitnessedMutex<T> {
    pub fn new(rank: u32, name: &'static str, value: T) -> WitnessedMutex<T> {
        #[cfg(not(debug_assertions))]
        let _ = (rank, name);
        WitnessedMutex {
            #[cfg(debug_assertions)]
            rank,
            #[cfg(debug_assertions)]
            name,
            inner: Mutex::new(value),
        }
    }

    /// Acquire, recovering from poisoning. The rank check runs *before*
    /// parking on the mutex, so an ordering violation panics loudly
    /// instead of deadlocking against the thread holding the peer lock.
    pub fn lock(&self) -> WitnessedGuard<'_, T> {
        #[cfg(debug_assertions)]
        note_acquire(self.rank, self.name);
        WitnessedGuard {
            inner: Some(self.inner.lock().unwrap_or_else(PoisonError::into_inner)),
            #[cfg(debug_assertions)]
            rank: self.rank,
            #[cfg(debug_assertions)]
            name: self.name,
        }
    }
}

/// Guard returned by [`WitnessedMutex::lock`]; releases the witness
/// entry on drop. `inner` is `Some` for the guard's whole life — the
/// `Option` only exists so [`Self::wait`] can thread the std guard
/// through a condvar without dropping the witness entry.
pub struct WitnessedGuard<'a, T> {
    inner: Option<MutexGuard<'a, T>>,
    #[cfg(debug_assertions)]
    rank: u32,
    #[cfg(debug_assertions)]
    name: &'static str,
}

impl<'a, T> WitnessedGuard<'a, T> {
    /// Park on `cv`, releasing the mutex while parked (condvar
    /// semantics) but keeping the witness entry: the lock is re-held
    /// the instant the wait returns, so to every *other* acquisition
    /// on this thread it never stopped being held.
    pub fn wait(mut self, cv: &Condvar) -> WitnessedGuard<'a, T> {
        let g = self.inner.take().expect("witnessed guard holds its mutex guard");
        self.inner = Some(cv.wait(g).unwrap_or_else(PoisonError::into_inner));
        self
    }
}

impl<T> std::ops::Deref for WitnessedGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.inner.as_deref().expect("witnessed guard holds its mutex guard")
    }
}

impl<T> std::ops::DerefMut for WitnessedGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner.as_deref_mut().expect("witnessed guard holds its mutex guard")
    }
}

impl<T> Drop for WitnessedGuard<'_, T> {
    fn drop(&mut self) {
        #[cfg(debug_assertions)]
        if self.inner.is_some() {
            note_release(self.rank, self.name);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn in_order_acquisition_is_clean() {
        let ring = WitnessedMutex::new(RANK_EXCHANGE_RING, "t.ring", 1u32);
        let comms = WitnessedMutex::new(RANK_EXCHANGE_COMMS, "t.comms", 2u32);
        let a = ring.lock();
        let b = comms.lock();
        assert_eq!(*a + *b, 3);
    }

    #[test]
    fn out_of_order_release_is_legal() {
        let ring = WitnessedMutex::new(RANK_EXCHANGE_RING, "t2.ring", 0u32);
        let comms = WitnessedMutex::new(RANK_EXCHANGE_COMMS, "t2.comms", 0u32);
        let a = ring.lock();
        let b = comms.lock();
        drop(a); // release the *outer* lock first
        drop(b);
        let _again = ring.lock(); // stack is clean, reacquire is fine
    }

    // The inversion/blocking panics only fire in debug builds (the
    // release CI lane runs these tests too, where the witness is
    // compiled out), so the `should_panic` expectations are debug-only.

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "declared global order violated")]
    fn rank_inversion_panics_in_debug() {
        let ring = WitnessedMutex::new(RANK_EXCHANGE_RING, "t3.ring", ());
        let comms = WitnessedMutex::new(RANK_EXCHANGE_COMMS, "t3.comms", ());
        let _b = comms.lock();
        let _a = ring.lock(); // comms (20) held, ring (10) requested
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "blocking operations must run lock-free")]
    fn blocking_while_holding_a_lock_panics_in_debug() {
        let ring = WitnessedMutex::new(RANK_EXCHANGE_RING, "t4.ring", ());
        let _g = ring.lock();
        assert_lock_free("test blocking edge");
    }

    #[test]
    fn assert_lock_free_is_silent_when_nothing_is_held() {
        assert_lock_free("no locks held");
    }

    #[test]
    fn wait_preserves_the_witness_entry() {
        let m = Arc::new(WitnessedMutex::new(RANK_EXCHANGE_RING, "t5.m", false));
        let cv = Arc::new(Condvar::new());
        let (m2, cv2) = (Arc::clone(&m), Arc::clone(&cv));
        let t = std::thread::spawn(move || {
            *m2.lock() = true;
            cv2.notify_all();
        });
        let mut g = m.lock();
        while !*g {
            g = g.wait(&cv);
        }
        #[cfg(debug_assertions)]
        assert_eq!(held_ranks(), vec![RANK_EXCHANGE_RING], "entry survives the wait");
        drop(g);
        #[cfg(debug_assertions)]
        assert!(held_ranks().is_empty(), "drop releases the entry");
        t.join().expect("notifier thread");
    }
}
