//! Host-side tensor: a shape + contiguous f32/i32 storage — or a
//! [`PackedTensor`] in a sub-byte format — with conversions to/from
//! `xla::Literal`.
//!
//! The coordinator keeps all state (params, optimizer moments, batches)
//! as [`HostTensor`]s; the runtime marshals them across the PJRT
//! boundary. Row-major (C) layout throughout, matching XLA's default
//! literal layout.
//!
//! The `Packed` arm is how the stash actually occupies
//! `storage_bits()`-scale memory between uses: a packed tensor stays in
//! its format's bit layout until a use-site needs f32 — [`HostTensor::to_literal`]
//! decodes on the way into PJRT, so coordinator code handles packed and
//! dense tensors uniformly.

use xla::{ArrayElement, Literal};

use crate::quant::{Codec, FormatSpec, PackedTensor};
use crate::stash::SpillHandle;
use crate::{Error, Result};

/// Element type tag.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
    /// Sub-byte packed storage in the given format (decodes to f32).
    Packed(FormatSpec),
}

/// A host tensor (row-major).
#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

#[derive(Clone, Debug, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    /// Physically packed storage (`quant::packed`); `shape` mirrors the
    /// packed record's shape.
    Packed(PackedTensor),
    /// A packed tensor whose payload currently lives in a stash-store
    /// spill segment on disk ([`crate::stash`]). The tensor keeps its
    /// shape/format identity (manifest validation still works) but has
    /// no local payload: any attempt to read it without fetching it
    /// back through the owning `StashStore` errors loudly. Checkpoints
    /// stream the record straight from the segment file.
    Spilled(SpillHandle),
}

/// Minor-axis length the box-based formats quantize against: the last
/// dimension, or 1 for scalars / zero-sized axes.
fn minor_axis(shape: &[usize]) -> usize {
    shape.last().copied().filter(|&d| d > 0).unwrap_or(1)
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor { shape, data: TensorData::F32(data) }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor { shape, data: TensorData::I32(data) }
    }

    /// Wrap an already-packed tensor (shape comes from the record).
    pub fn packed(p: PackedTensor) -> Self {
        HostTensor { shape: p.shape().to_vec(), data: TensorData::Packed(p) }
    }

    /// A spilled tensor: shape stays host-side, the payload lives in
    /// the handle's spill segment (see [`crate::stash`]).
    pub fn spilled(shape: Vec<usize>, h: SpillHandle) -> Self {
        HostTensor { shape, data: TensorData::Spilled(h) }
    }

    pub fn scalar_f32(v: f32) -> Self {
        HostTensor::f32(vec![], vec![v])
    }

    pub fn scalar_i32(v: i32) -> Self {
        HostTensor::i32(vec![], vec![v])
    }

    pub fn zeros(shape: &[usize]) -> Self {
        HostTensor::zeros_dtype(shape, Dtype::F32)
    }

    /// Dtype-aware zeros: packed dtypes build the all-zero payload
    /// directly in the bit layout — no f32 alloc, no encode pass.
    pub fn zeros_dtype(shape: &[usize], dtype: Dtype) -> Self {
        match dtype {
            Dtype::F32 => HostTensor {
                shape: shape.to_vec(),
                data: TensorData::F32(vec![0.0; shape.iter().product()]),
            },
            Dtype::I32 => HostTensor {
                shape: shape.to_vec(),
                data: TensorData::I32(vec![0; shape.iter().product()]),
            },
            Dtype::Packed(spec) => {
                HostTensor::packed(PackedTensor::zeros(spec, shape, minor_axis(shape)))
            }
        }
    }

    /// Zeros with this tensor's shape *and* dtype (a packed reference
    /// yields packed zeros in the same format, built directly).
    pub fn zeros_like(&self) -> Self {
        HostTensor::zeros_dtype(&self.shape, self.dtype())
    }

    pub fn len(&self) -> usize {
        match &self.data {
            TensorData::F32(v) => v.len(),
            TensorData::I32(v) => v.len(),
            TensorData::Packed(p) => p.len(),
            TensorData::Spilled(_) => self.shape.iter().product(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> Dtype {
        match &self.data {
            TensorData::F32(_) => Dtype::F32,
            TensorData::I32(_) => Dtype::I32,
            TensorData::Packed(p) => Dtype::Packed(p.spec()),
            // A spilled tensor is logically packed in its format; only
            // its residence differs.
            TensorData::Spilled(h) => Dtype::Packed(h.spec),
        }
    }

    /// Bytes this tensor occupies at rest *in host memory* (packed
    /// tensors report their payload — what the stash-traffic claims are
    /// about; spilled tensors occupy disk, not DRAM, and report 0).
    pub fn storage_bytes(&self) -> usize {
        match &self.data {
            TensorData::F32(v) => v.len() * 4,
            TensorData::I32(v) => v.len() * 4,
            TensorData::Packed(p) => p.packed_len(),
            TensorData::Spilled(_) => 0,
        }
    }

    /// Quantize-and-pack into `spec`'s bit layout (stochastic formats use
    /// the `(step, stream)` rounding stream). A tensor already packed in
    /// `spec` is returned as-is — re-encoding is a no-op by the codec's
    /// idempotence, so skipping it preserves bit-identity cheaply.
    pub fn pack_stream(&self, spec: &FormatSpec, step: u64, stream: u64) -> Result<HostTensor> {
        match &self.data {
            TensorData::F32(v) => Ok(HostTensor::packed(spec.encode_stream(
                v,
                &self.shape,
                minor_axis(&self.shape),
                step,
                stream,
            ))),
            TensorData::Packed(p) if p.spec() == *spec => Ok(self.clone()),
            TensorData::Packed(p) => Ok(HostTensor::packed(spec.encode_stream(
                &p.decode(),
                &self.shape,
                minor_axis(&self.shape),
                step,
                stream,
            ))),
            TensorData::Spilled(_) => Err(Error::Shape(
                "cannot repack a spilled tensor: fetch it via the stash store first".into(),
            )),
            TensorData::I32(_) => Err(Error::Shape("cannot pack an i32 tensor".into())),
        }
    }

    /// [`HostTensor::pack_stream`] at the step-0 stream.
    pub fn pack(&self, spec: &FormatSpec) -> Result<HostTensor> {
        self.pack_stream(spec, 0, 0)
    }

    /// Decode to dense f32 (identity for dense tensors; a spilled
    /// tensor has no local payload and is returned unchanged — fetch it
    /// through the stash store first).
    pub fn unpack(&self) -> HostTensor {
        match &self.data {
            TensorData::Packed(p) => HostTensor::f32(self.shape.clone(), p.decode()),
            _ => self.clone(),
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            TensorData::Packed(_) => {
                Err(Error::Shape("packed tensor: unpack() before borrowing f32".into()))
            }
            TensorData::Spilled(h) => Err(Error::Shape(format!(
                "tensor is spilled to {:?}: fetch it via the stash store first",
                h.path
            ))),
            _ => Err(Error::Shape("expected f32 tensor".into())),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match &mut self.data {
            TensorData::F32(v) => Ok(v),
            TensorData::Packed(_) => {
                Err(Error::Shape("packed tensor: unpack() before borrowing f32".into()))
            }
            TensorData::Spilled(h) => Err(Error::Shape(format!(
                "tensor is spilled to {:?}: fetch it via the stash store first",
                h.path
            ))),
            _ => Err(Error::Shape("expected f32 tensor".into())),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            _ => Err(Error::Shape("expected i32 tensor".into())),
        }
    }

    /// Scalar extraction (any rank-0 or single-element tensor).
    pub fn item_f32(&self) -> Result<f32> {
        let v = self.as_f32()?;
        if v.len() != 1 {
            return Err(Error::Shape(format!("expected scalar, got {} elems", v.len())));
        }
        Ok(v[0])
    }

    /// Convert to an XLA literal (copies). Packed tensors decode here —
    /// the use-site boundary where sub-byte storage becomes f32 compute.
    pub fn to_literal(&self) -> Result<Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        let lit = match &self.data {
            TensorData::F32(v) => Literal::vec1(v.as_slice()),
            TensorData::I32(v) => Literal::vec1(v.as_slice()),
            TensorData::Packed(p) => Literal::vec1(p.decode().as_slice()),
            TensorData::Spilled(h) => {
                return Err(Error::Shape(format!(
                    "tensor is spilled to {:?}: fetch it via the stash store before dispatch",
                    h.path
                )))
            }
        };
        Ok(lit.reshape(&dims)?)
    }

    /// Convert from an XLA literal (copies).
    pub fn from_literal(lit: &Literal) -> Result<HostTensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            t if t == f32::TY => Ok(HostTensor::f32(dims, lit.to_vec::<f32>()?)),
            t if t == i32::TY => Ok(HostTensor::i32(dims, lit.to_vec::<i32>()?)),
            other => Err(Error::Shape(format!("unsupported literal type {other:?}"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_data_consistency() {
        let t = HostTensor::f32(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.dtype(), Dtype::F32);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn mismatched_shape_panics() {
        HostTensor::f32(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn scalars() {
        assert_eq!(HostTensor::scalar_f32(2.5).item_f32().unwrap(), 2.5);
        assert_eq!(HostTensor::scalar_i32(7).as_i32().unwrap(), &[7]);
    }

    #[test]
    fn dtype_mismatch_errors() {
        let t = HostTensor::scalar_i32(1);
        assert!(t.as_f32().is_err());
        assert!(t.item_f32().is_err());
    }

    #[test]
    fn literal_roundtrip_f32() {
        let t = HostTensor::f32(vec![2, 2], vec![1.0, -2.0, 3.5, 0.0]);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn literal_roundtrip_i32_and_scalar() {
        let t = HostTensor::i32(vec![3], vec![1, 2, 3]);
        let back = HostTensor::from_literal(&t.to_literal().unwrap()).unwrap();
        assert_eq!(t, back);
        let s = HostTensor::scalar_f32(4.25);
        let back = HostTensor::from_literal(&s.to_literal().unwrap()).unwrap();
        assert_eq!(s, back);
    }

    #[test]
    fn pack_unpack_is_quantize() {
        let spec = FormatSpec::bfp(4);
        let x: Vec<f32> = (0..48).map(|i| (i as f32 - 24.0) * 0.37).collect();
        let t = HostTensor::f32(vec![3, 16], x.clone());
        let p = t.pack(&spec).unwrap();
        assert_eq!(p.dtype(), Dtype::Packed(spec));
        assert_eq!(p.shape, t.shape);
        assert_eq!(p.len(), 48);
        assert!(p.storage_bytes() < t.storage_bytes() / 4, "bfp4 must pack sub-byte");
        let back = p.unpack();
        assert_eq!(back.as_f32().unwrap(), crate::quant::bfp_quantize(&x, 16, 4.0).as_slice());
        // Packing an already-packed tensor in the same format is identity.
        assert_eq!(p.pack(&spec).unwrap(), p);
        // Repacking into another format goes through decode.
        let wider = p.pack(&FormatSpec::bfp(16)).unwrap();
        assert_eq!(wider.dtype(), Dtype::Packed(FormatSpec::bfp(16)));
    }

    #[test]
    fn packed_borrow_and_item_error() {
        let t = HostTensor::f32(vec![4], vec![1.0, 2.0, 3.0, 4.0]);
        let p = t.pack(&FormatSpec::fixed(8)).unwrap();
        assert!(p.as_f32().is_err());
        assert!(p.item_f32().is_err());
        assert!(p.as_i32().is_err());
        assert!(HostTensor::scalar_i32(3).pack(&FormatSpec::fixed(8)).is_err());
    }

    #[test]
    fn zeros_like_preserves_dtype_without_reencode() {
        let d = HostTensor::zeros(&[2, 5]);
        assert_eq!(d.zeros_like().dtype(), Dtype::F32);
        let i = HostTensor::scalar_i32(3);
        assert_eq!(i.zeros_like().dtype(), Dtype::I32);
        let spec = FormatSpec::bfp(4);
        let p = HostTensor::f32(vec![2, 20], vec![1.0; 40]).pack(&spec).unwrap();
        let z = p.zeros_like();
        assert_eq!(z.dtype(), Dtype::Packed(spec));
        assert_eq!(z.shape, vec![2, 20]);
        // Identical to the encode path, but built directly.
        let via_encode = HostTensor::f32(vec![2, 20], vec![0.0; 40]).pack(&spec).unwrap();
        assert_eq!(z, via_encode);
    }

    #[test]
    fn spilled_tensor_keeps_identity_but_refuses_reads() {
        let h = SpillHandle {
            path: std::sync::Arc::new("/nonexistent/stash.seg".into()),
            offset: 0,
            record_len: 40,
            payload_len: 4,
            spec: FormatSpec::bfp(4),
        };
        let t = HostTensor::spilled(vec![2, 3], h);
        assert_eq!(t.len(), 6);
        assert_eq!(t.dtype(), Dtype::Packed(FormatSpec::bfp(4)));
        assert_eq!(t.storage_bytes(), 0, "spilled payload is on disk, not in DRAM");
        assert!(t.as_f32().is_err());
        assert!(t.item_f32().is_err());
        assert!(t.to_literal().is_err(), "the PJRT boundary must not page-fault silently");
        assert!(t.pack(&FormatSpec::bfp(4)).is_err());
        assert_eq!(t.unpack(), t, "unpack cannot materialize a spilled payload");
        // zeros_like of a spilled tensor builds resident packed zeros.
        let z = t.zeros_like();
        assert_eq!(z.dtype(), Dtype::Packed(FormatSpec::bfp(4)));
        assert!(z.storage_bytes() > 0);
    }

    #[test]
    fn packed_literal_decodes_at_use_site() {
        let x: Vec<f32> = (0..32).map(|i| (i as f32).sin() * 3.0).collect();
        let t = HostTensor::f32(vec![2, 16], x.clone());
        let p = t.pack(&FormatSpec::bfp(8)).unwrap();
        let lit = p.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        // The literal sees the decoded (quantized) values as plain f32.
        assert_eq!(back.dtype(), Dtype::F32);
        assert_eq!(back.as_f32().unwrap(), crate::quant::bfp_quantize(&x, 16, 8.0).as_slice());
    }
}
