//! Host-side tensor: a shape + contiguous f32/i32 storage, with
//! conversions to/from `xla::Literal`.
//!
//! The coordinator keeps all state (params, optimizer moments, batches)
//! as [`HostTensor`]s; the runtime marshals them across the PJRT
//! boundary. Row-major (C) layout throughout, matching XLA's default
//! literal layout.

use xla::{ArrayElement, Literal};

use crate::{Error, Result};

/// Element type tag.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

/// A host tensor (row-major).
#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

#[derive(Clone, Debug, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor { shape, data: TensorData::F32(data) }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor { shape, data: TensorData::I32(data) }
    }

    pub fn scalar_f32(v: f32) -> Self {
        HostTensor::f32(vec![], vec![v])
    }

    pub fn scalar_i32(v: i32) -> Self {
        HostTensor::i32(vec![], vec![v])
    }

    pub fn zeros(shape: &[usize]) -> Self {
        HostTensor::f32(shape.to_vec(), vec![0.0; shape.iter().product()])
    }

    pub fn len(&self) -> usize {
        match &self.data {
            TensorData::F32(v) => v.len(),
            TensorData::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> Dtype {
        match &self.data {
            TensorData::F32(_) => Dtype::F32,
            TensorData::I32(_) => Dtype::I32,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            _ => Err(Error::Shape("expected f32 tensor".into())),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match &mut self.data {
            TensorData::F32(v) => Ok(v),
            _ => Err(Error::Shape("expected f32 tensor".into())),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            _ => Err(Error::Shape("expected i32 tensor".into())),
        }
    }

    /// Scalar extraction (any rank-0 or single-element tensor).
    pub fn item_f32(&self) -> Result<f32> {
        let v = self.as_f32()?;
        if v.len() != 1 {
            return Err(Error::Shape(format!("expected scalar, got {} elems", v.len())));
        }
        Ok(v[0])
    }

    /// Convert to an XLA literal (copies).
    pub fn to_literal(&self) -> Result<Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        let lit = match &self.data {
            TensorData::F32(v) => Literal::vec1(v.as_slice()),
            TensorData::I32(v) => Literal::vec1(v.as_slice()),
        };
        Ok(lit.reshape(&dims)?)
    }

    /// Convert from an XLA literal (copies).
    pub fn from_literal(lit: &Literal) -> Result<HostTensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            t if t == f32::TY => Ok(HostTensor::f32(dims, lit.to_vec::<f32>()?)),
            t if t == i32::TY => Ok(HostTensor::i32(dims, lit.to_vec::<i32>()?)),
            other => Err(Error::Shape(format!("unsupported literal type {other:?}"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_data_consistency() {
        let t = HostTensor::f32(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.dtype(), Dtype::F32);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn mismatched_shape_panics() {
        HostTensor::f32(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn scalars() {
        assert_eq!(HostTensor::scalar_f32(2.5).item_f32().unwrap(), 2.5);
        assert_eq!(HostTensor::scalar_i32(7).as_i32().unwrap(), &[7]);
    }

    #[test]
    fn dtype_mismatch_errors() {
        let t = HostTensor::scalar_i32(1);
        assert!(t.as_f32().is_err());
        assert!(t.item_f32().is_err());
    }

    #[test]
    fn literal_roundtrip_f32() {
        let t = HostTensor::f32(vec![2, 2], vec![1.0, -2.0, 3.5, 0.0]);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn literal_roundtrip_i32_and_scalar() {
        let t = HostTensor::i32(vec![3], vec![1, 2, 3]);
        let back = HostTensor::from_literal(&t.to_literal().unwrap()).unwrap();
        assert_eq!(t, back);
        let s = HostTensor::scalar_f32(4.25);
        let back = HostTensor::from_literal(&s.to_literal().unwrap()).unwrap();
        assert_eq!(s, back);
    }
}
