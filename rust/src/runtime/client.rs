//! PJRT client wrapper: compile HLO-text artifacts once, execute many.
//!
//! [`Runtime`] owns the `PjRtClient` (CPU in this environment; the same
//! code path drives TPU/GPU PJRT plugins) and an executable cache keyed
//! by artifact path, so repeated loads (benches, multiple experiments in
//! one process) compile once.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::sync::{Arc, OnceLock};
use std::time::Instant;

use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use crate::runtime::tensor::HostTensor;
use crate::{Error, Result};

/// Process-wide PJRT runtime.
pub struct Runtime {
    client: PjRtClient,
    cache: Mutex<HashMap<PathBuf, Arc<Executable>>>,
}

/// A compiled artifact ready to execute.
pub struct Executable {
    exe: PjRtLoadedExecutable,
    pub path: PathBuf,
    pub compile_ms: f64,
}

// The PJRT CPU client is single-device and internally synchronized for
// our usage (compile + synchronous execute).
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}
unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}

static GLOBAL: OnceLock<Runtime> = OnceLock::new();

impl Runtime {
    /// Create a CPU PJRT runtime.
    pub fn cpu() -> Result<Self> {
        Ok(Runtime { client: PjRtClient::cpu()?, cache: Mutex::new(HashMap::new()) })
    }

    /// Process-wide shared runtime (creating PJRT clients is expensive
    /// and the CPU plugin is a singleton in practice).
    pub fn global() -> &'static Runtime {
        GLOBAL.get_or_init(|| Runtime::cpu().expect("failed to create PJRT CPU client"))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load + compile an HLO-text artifact (cached by path).
    pub fn load(&self, path: &Path) -> Result<Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(path) {
            return Ok(e.clone());
        }
        let start = Instant::now();
        let proto = HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| Error::Config(format!("non-utf8 path {path:?}")))?,
        )?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let compiled = Arc::new(Executable {
            exe,
            path: path.to_path_buf(),
            compile_ms: start.elapsed().as_secs_f64() * 1e3,
        });
        crate::debug!(
            "compiled {} in {:.0} ms",
            path.file_name().and_then(|s| s.to_str()).unwrap_or("?"),
            compiled.compile_ms
        );
        self.cache.lock().unwrap().insert(path.to_path_buf(), compiled.clone());
        Ok(compiled)
    }
}

impl Executable {
    /// Execute with host tensors; returns the decomposed output tuple.
    ///
    /// All artifacts are lowered with `return_tuple=True`, so the raw
    /// result is a single tuple literal which we split into per-output
    /// tensors.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let literals: Vec<Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let outs = self.run_literals(&literals)?;
        outs.iter().map(HostTensor::from_literal).collect()
    }

    /// Lower-level entry: literals in, decomposed tuple literals out.
    pub fn run_literals(&self, inputs: &[Literal]) -> Result<Vec<Literal>> {
        let result = self.exe.execute::<Literal>(inputs)?;
        let buffer = result
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| Error::Shape("execution returned no buffers".into()))?;
        let tuple = buffer.to_literal_sync()?;
        Ok(tuple.to_tuple()?)
    }
}

#[cfg(test)]
mod tests {
    // PJRT-dependent tests live in rust/tests/ (integration) so unit
    // `cargo test --lib` stays fast; here we only check cache plumbing
    // has the right error behavior without a client.

    #[test]
    fn load_missing_file_errors() {
        let rt = super::Runtime::global();
        assert!(rt.load(std::path::Path::new("/nonexistent/x.hlo.txt")).is_err());
    }
}
