//! PJRT runtime: load AOT artifacts (HLO text) and execute them on the
//! request path.
//!
//! This is the only boundary between the rust coordinator and the
//! XLA-compiled compute. The flow (see `/opt/xla-example/load_hlo`):
//!
//! ```text
//! artifacts/manifest.json ──► ArtifactManifest (param order + shapes)
//! artifacts/<name>.hlo.txt ─► HloModuleProto::from_text_file
//!                             ─► XlaComputation ─► client.compile
//!                             ─► PjRtLoadedExecutable  (cached)
//! step: Vec<Literal> ───────► execute ─► tuple literal ─► Vec<Literal>
//! ```
//!
//! HLO **text** is the interchange format — jax ≥ 0.5 serialized protos
//! use 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (DESIGN.md §2).

pub mod artifact;
pub mod client;
pub mod tensor;

pub use artifact::{
    train_kind_for, train_variant_for, ArtifactManifest, ModelManifest, ParamSpec,
};
pub use client::{Executable, Runtime};
pub use tensor::{Dtype, HostTensor, TensorData};
