//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the rust runtime.
//!
//! `manifest.json` records, for each model, the **flat parameter order**
//! (sorted names + shapes) and the artifact filenames. The runtime
//! marshals literals positionally against this order; getting it from a
//! file (rather than hard-coding) keeps the rust binary valid across
//! model-config changes without recompiling rust.

use std::path::{Path, PathBuf};

use crate::schedule::{FormatSpec, PrecisionConfig};
use crate::util::json::{self, Json};
use crate::{Error, Result};

/// Which train-artifact variant a precision config needs — the
/// artifact-side dispatch guard. The AOT pipeline (`aot.py`) exports
/// per-quantizer variants: `train_bfp` / `train_fixed` / `train_float`
/// bake a single quantizer subgraph (XLA compile time scales badly with
/// the subgraph count) and apply it **only on an exact mode match**
/// (identity on foreign modes), while `train_both` carries every
/// quantizer for heterogeneous per-slot configs. A cross-family config
/// therefore MUST route to `train_both`: a single-family variant would
/// silently leave the foreign slots unquantized (and before the exact-
/// match fix in `layers.py::quantize`, quantized them with the wrong
/// kernel). The fp32 mode (0) is the identity in every variant;
/// stochastic slots ride their family's grid.
pub fn train_variant_for(p: &PrecisionConfig) -> &'static str {
    let (mut fixed, mut bfp, mut float) = (false, false, false);
    for f in &p.slots {
        // Exhaustive on purpose: a future format family must decide its
        // artifact routing here explicitly (compiler error, not a
        // silent fall-through to some single-family variant).
        match f {
            FormatSpec::Fixed { .. } => fixed = true,
            FormatSpec::Bfp { .. } => bfp = true,
            FormatSpec::Float { .. } => float = true,
            FormatSpec::Fp32 => {}
        }
    }
    match (fixed, bfp, float) {
        (true, false, false) => "train_fixed",
        (false, false, true) => "train_float",
        // All-fp32 configs ride the (always-exported) BFP variant.
        (false, _, false) => "train_bfp",
        _ => "train_both",
    }
}

/// Resolve the train-artifact kind for `p` against the artifact kinds a
/// manifest actually carries — THE shared implementation behind both
/// `ModelManifest::train_artifact_for` and the session's `ExeCache`
/// (one copy, so the two cannot drift). Policy:
///
/// * the preferred single-family variant when present;
/// * else `train_both` — but only when that fallback genuinely covers
///   the config: a manifest without a `train_float` entry predates the
///   float family, so its `train_both` has no mode-4/5 arm and would
///   silently train a float config **unquantized** (while the report
///   scored it as FP8). That case fails loudly instead;
/// * a manifest with neither variant nor `train_both` fails loudly.
pub fn train_kind_for(
    artifacts: &std::collections::BTreeMap<String, String>,
    p: &PrecisionConfig,
) -> Result<&'static str> {
    // Float-era check FIRST: it must also catch cross-family float
    // configs whose preferred variant is train_both itself (a stale
    // manifest can carry a train_both that predates modes 4/5).
    if p.slots.iter().any(|f| f.is_float()) && !artifacts.contains_key("train_float") {
        return Err(Error::Manifest(format!(
            "config {} needs the float quantizer, but these artifacts predate it \
             (no 'train_float' entry — their train_both has no mode-4/5 arm, so the run \
             would silently not quantize); rerun `make artifacts`",
            p.spec_string()
        )));
    }
    let kind = train_variant_for(p);
    if artifacts.contains_key(kind) {
        return Ok(kind);
    }
    if artifacts.contains_key("train_both") {
        Ok("train_both")
    } else {
        Err(Error::Manifest(format!(
            "no '{kind}' (or fallback 'train_both') artifact for config {}",
            p.spec_string()
        )))
    }
}

/// One parameter tensor's name + shape.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Manifest entry for one model family ("nmt" or "cls").
#[derive(Clone, Debug)]
pub struct ModelManifest {
    /// Model hyper-parameters as recorded by aot.py (vocab, d_model, ...).
    pub config: std::collections::BTreeMap<String, i64>,
    /// Flat parameter order (sorted by name, matching jax's dict order).
    pub params: Vec<ParamSpec>,
    /// artifact-kind ("init"/"train"/...) -> filename.
    pub artifacts: std::collections::BTreeMap<String, String>,
}

impl ModelManifest {
    pub fn cfg(&self, key: &str) -> Result<usize> {
        self.config
            .get(key)
            .map(|&v| v as usize)
            .ok_or_else(|| Error::Manifest(format!("missing config key '{key}'")))
    }

    pub fn total_params(&self) -> usize {
        self.params.iter().map(ParamSpec::numel).sum()
    }

    pub fn artifact_file(&self, kind: &str) -> Result<&str> {
        self.artifacts
            .get(kind)
            .map(|s| s.as_str())
            .ok_or_else(|| Error::Manifest(format!("no '{kind}' artifact")))
    }

    /// The train artifact for a precision config ([`train_kind_for`]'s
    /// policy): the preferred single-family variant when the manifest
    /// has it, else a `train_both` that genuinely covers the config.
    /// Anything else errs — never a silently mis-dispatching fallback.
    pub fn train_artifact_for(&self, p: &PrecisionConfig) -> Result<&str> {
        self.artifact_file(train_kind_for(&self.artifacts, p)?)
    }
}

/// The parsed manifest + its directory (for resolving artifact paths).
#[derive(Clone, Debug)]
pub struct ArtifactManifest {
    pub dir: PathBuf,
    pub nmt: ModelManifest,
    pub cls: ModelManifest,
    /// Quantizer probe artifacts: name -> filename, plus their input shape.
    pub quant_artifacts: std::collections::BTreeMap<String, String>,
    pub quant_shape: Vec<usize>,
}

fn parse_model(j: &Json) -> Result<ModelManifest> {
    let config = j
        .req("config")?
        .as_obj()
        .ok_or_else(|| Error::Manifest("config not an object".into()))?
        .iter()
        .map(|(k, v)| {
            v.as_i64()
                .map(|n| (k.clone(), n))
                .ok_or_else(|| Error::Manifest(format!("config '{k}' not a number")))
        })
        .collect::<Result<_>>()?;
    let params = j
        .req("params")?
        .as_arr()
        .ok_or_else(|| Error::Manifest("params not an array".into()))?
        .iter()
        .map(|p| {
            let name = p
                .req("name")?
                .as_str()
                .ok_or_else(|| Error::Manifest("param name not a string".into()))?
                .to_string();
            let shape = p
                .req("shape")?
                .as_arr()
                .ok_or_else(|| Error::Manifest("param shape not an array".into()))?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| Error::Manifest("bad dim".into())))
                .collect::<Result<_>>()?;
            Ok(ParamSpec { name, shape })
        })
        .collect::<Result<Vec<_>>>()?;
    // The flat convention requires sorted order; verify rather than trust.
    for w in params.windows(2) {
        if w[0].name >= w[1].name {
            return Err(Error::Manifest(format!(
                "params not sorted: '{}' >= '{}'",
                w[0].name, w[1].name
            )));
        }
    }
    let artifacts = j
        .req("artifacts")?
        .as_obj()
        .ok_or_else(|| Error::Manifest("artifacts not an object".into()))?
        .iter()
        .map(|(k, v)| {
            v.as_str()
                .map(|s| (k.clone(), s.to_string()))
                .ok_or_else(|| Error::Manifest("artifact not a string".into()))
        })
        .collect::<Result<_>>()?;
    Ok(ModelManifest { config, params, artifacts })
}

impl ArtifactManifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let j = json::parse_file(&dir.join("manifest.json"))?;
        let version = j.req("version")?.as_i64().unwrap_or(0);
        if version != 1 {
            return Err(Error::Manifest(format!("unsupported manifest version {version}")));
        }
        let models = j.req("models")?;
        let quant = j.req("quant")?;
        let quant_artifacts = quant
            .req("artifacts")?
            .as_obj()
            .ok_or_else(|| Error::Manifest("quant artifacts not an object".into()))?
            .iter()
            .map(|(k, v)| (k.clone(), v.as_str().unwrap_or_default().to_string()))
            .collect();
        let quant_shape = quant
            .req("shape")?
            .as_arr()
            .ok_or_else(|| Error::Manifest("quant shape not an array".into()))?
            .iter()
            .map(|d| d.as_usize().unwrap_or(0))
            .collect();
        Ok(ArtifactManifest {
            dir: dir.to_path_buf(),
            nmt: parse_model(models.req("nmt")?)?,
            cls: parse_model(models.req("cls")?)?,
            quant_artifacts,
            quant_shape,
        })
    }

    /// The manifest entry for a model family ("nmt" / "cls").
    pub fn model(&self, name: &str) -> Result<&ModelManifest> {
        match name {
            "nmt" => Ok(&self.nmt),
            "cls" => Ok(&self.cls),
            other => Err(Error::Manifest(format!("unknown model '{other}'"))),
        }
    }

    /// Absolute path of a model artifact.
    pub fn model_path(&self, model: &str, kind: &str) -> Result<PathBuf> {
        Ok(self.dir.join(self.model(model)?.artifact_file(kind)?))
    }

    /// Absolute path of a quantizer probe artifact ("quant_bfp"/"quant_fixed").
    pub fn quant_path(&self, name: &str) -> Result<PathBuf> {
        self.quant_artifacts
            .get(name)
            .map(|f| self.dir.join(f))
            .ok_or_else(|| Error::Manifest(format!("no quant artifact '{name}'")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_manifest() -> String {
        r#"{
          "version": 1,
          "models": {
            "nmt": {
              "config": {"vocab": 256, "d_model": 128, "batch": 16},
              "params": [
                {"name": "a.w", "shape": [2, 3]},
                {"name": "b.w", "shape": [4]}
              ],
              "artifacts": {"train": "nmt_train.hlo.txt", "init": "nmt_init.hlo.txt"}
            },
            "cls": {
              "config": {"vocab": 256, "seq_len": 48},
              "params": [{"name": "emb", "shape": [256, 128]}],
              "artifacts": {"train": "cls_train.hlo.txt"}
            }
          },
          "quant": {"shape": [64, 64], "artifacts": {"quant_bfp": "quant_bfp.hlo.txt"}}
        }"#
        .to_string()
    }

    fn load_from_str(s: &str) -> Result<ArtifactManifest> {
        let dir = std::env::temp_dir().join(format!("dsq-manifest-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), s).unwrap();
        ArtifactManifest::load(&dir)
    }

    #[test]
    fn parses_fake_manifest() {
        let m = load_from_str(&fake_manifest()).unwrap();
        assert_eq!(m.nmt.cfg("vocab").unwrap(), 256);
        assert_eq!(m.nmt.params.len(), 2);
        assert_eq!(m.nmt.params[0].numel(), 6);
        assert_eq!(m.nmt.total_params(), 10);
        assert_eq!(m.cls.params[0].shape, vec![256, 128]);
        assert!(m.model_path("nmt", "train").unwrap().ends_with("nmt_train.hlo.txt"));
        assert!(m.quant_path("quant_bfp").unwrap().ends_with("quant_bfp.hlo.txt"));
        assert!(m.model_path("nmt", "decode").is_err());
        assert!(m.model_path("xxx", "train").is_err());
    }

    #[test]
    fn train_variant_routing_guards_cross_family_configs() {
        let v = |s: &str| train_variant_for(&PrecisionConfig::parse(s).unwrap());
        // Single-family configs take their baked variant.
        assert_eq!(v("bfp:16,4,4,16"), "train_bfp");
        assert_eq!(v("fixed:8,8,8,16"), "train_fixed");
        assert_eq!(v("fixedsr:16,4,4,16"), "train_fixed");
        assert_eq!(v("fp8e4m3,fp8e4m3,fp8e4m3,fp8e5m2"), "train_float");
        assert_eq!(v("e4m3,e4m3sr,e5m10,e5m2"), "train_float");
        assert_eq!(v("fp32"), "train_bfp");
        // The regression class: ANY cross-family mix must go to
        // train_both — a single-family variant is the identity on
        // foreign modes (and used to wrong-kernel them).
        assert_eq!(v("bfp16,bfp4,bfp4,fixed16sr"), "train_both");
        assert_eq!(v("fixed16,bfp4,bfp4,fixed16"), "train_both");
        assert_eq!(v("e4m3,bfp4,bfp4,e5m2"), "train_both");
        assert_eq!(v("fixed16,fixed4,fixed4,e5m2"), "train_both");
        assert_eq!(v("fp32,bfp4,e4m3,fp32"), "train_both");
    }

    #[test]
    fn manifest_train_artifact_for_prefers_variant_and_falls_back() {
        let mut artifacts = std::collections::BTreeMap::new();
        artifacts.insert("train_bfp".to_string(), "m_train_bfp.hlo.txt".to_string());
        artifacts.insert("train_both".to_string(), "m_train_both.hlo.txt".to_string());
        let stale = ModelManifest { config: Default::default(), params: vec![], artifacts };
        let p = |s: &str| PrecisionConfig::parse(s).unwrap();
        // Preferred single-family variant when present.
        assert_eq!(stale.train_artifact_for(&p("bfp8")).unwrap(), "m_train_bfp.hlo.txt");
        // Integer-family configs fall back to train_both safely (every
        // train_both generation carries modes 0-3).
        assert_eq!(
            stale.train_artifact_for(&p("bfp16,bfp4,bfp4,fixed16sr")).unwrap(),
            "m_train_both.hlo.txt"
        );
        assert_eq!(
            stale.train_artifact_for(&p("fixed:8,8,8,16")).unwrap(),
            "m_train_both.hlo.txt"
        );
        // A float config against artifacts that predate the float family
        // (no train_float entry anywhere) must fail LOUDLY: the stale
        // train_both has no mode-4/5 arm, so falling back would silently
        // train unquantized while the report scored the trace as FP8.
        let err = stale.train_artifact_for(&p("e4m3")).unwrap_err();
        assert!(err.to_string().contains("train_float"), "{err}");
        assert!(stale.train_artifact_for(&p("e4m3,bfp4,bfp4,e5m2")).is_err());
        // With a float-aware artifact set, float configs resolve: the
        // variant directly, and cross-family mixes through train_both.
        let mut artifacts = stale.artifacts.clone();
        artifacts.insert("train_float".to_string(), "m_train_float.hlo.txt".to_string());
        let fresh = ModelManifest { config: Default::default(), params: vec![], artifacts };
        assert_eq!(fresh.train_artifact_for(&p("e4m3")).unwrap(), "m_train_float.hlo.txt");
        assert_eq!(
            fresh.train_artifact_for(&p("e4m3,bfp4,bfp4,e5m2")).unwrap(),
            "m_train_both.hlo.txt"
        );
        // Neither variant nor train_both: loud error.
        let empty = ModelManifest {
            config: Default::default(),
            params: vec![],
            artifacts: Default::default(),
        };
        assert!(empty.train_artifact_for(&p("bfp8")).is_err());
    }

    #[test]
    fn rejects_unsorted_params() {
        let bad = fake_manifest().replace(
            r#"{"name": "a.w", "shape": [2, 3]},
                {"name": "b.w", "shape": [4]}"#,
            r#"{"name": "b.w", "shape": [4]},
                {"name": "a.w", "shape": [2, 3]}"#,
        );
        assert!(load_from_str(&bad).is_err());
    }

    #[test]
    fn rejects_wrong_version() {
        let bad = fake_manifest().replace("\"version\": 1", "\"version\": 2");
        assert!(load_from_str(&bad).is_err());
    }

    #[test]
    fn real_manifest_if_present() {
        // When `make artifacts` has run, validate the real file too.
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = ArtifactManifest::load(&dir).unwrap();
            assert!(m.nmt.params.len() > 50);
            assert!(m.nmt.total_params() > 10_000);
            assert_eq!(m.quant_shape, vec![64, 64]);
        }
    }
}
