//! Synthetic translation corpus.
//!
//! A "language pair" is defined by a seeded bijective token map `perm`
//! plus a structural transform:
//!
//! * [`Variant::Iwslt`] — `tgt = reverse(perm[src])` + EOS. Reversal
//!   forces genuinely position-dependent cross-attention (a copy task
//!   would be solvable with a trivial alignment); the token map forces
//!   the embeddings/logits path to learn a real mapping.
//! * [`Variant::Wmt`] — harder (the paper's WMT table shows lower BLEU
//!   at the same model size): `tgt_i = perm[(src_i + src_{i+1}) mod V]`
//!   then reversed — every output token depends on a *bigram*, so the
//!   model must combine adjacent source positions.
//!
//! Sentences are i.i.d. uniform over the open vocabulary with seeded
//! lengths; train/valid/test splits come from disjoint RNG streams, so
//! evaluation measures generalization of the learned transform, not
//! memorization.

use crate::util::rng::Pcg32;

use super::{EOS, FIRST_TOKEN};

/// Task difficulty variant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    /// Unigram map + reversal (IWSLT-like difficulty).
    Iwslt,
    /// Bigram map + reversal (WMT-like difficulty).
    Wmt,
}

/// Corpus configuration. `src_len`/`tgt_len` must match the artifact.
#[derive(Clone, Debug)]
pub struct TranslationConfig {
    pub vocab: i32,
    pub src_len: usize,
    pub tgt_len: usize,
    pub variant: Variant,
    pub seed: u64,
}

/// One sentence pair.
#[derive(Clone, Debug, PartialEq)]
pub struct SentencePair {
    pub src: Vec<i32>,
    pub tgt: Vec<i32>,
}

/// A seeded synthetic translation task.
#[derive(Clone, Debug)]
pub struct TranslationTask {
    pub cfg: TranslationConfig,
    perm: Vec<i32>,
}

impl TranslationTask {
    pub fn new(cfg: TranslationConfig) -> Self {
        assert!(cfg.vocab > FIRST_TOKEN + 1, "vocab too small");
        let mut rng = Pcg32::new(cfg.seed ^ 0x7A61);
        // Bijection over the open token range [FIRST_TOKEN, vocab).
        let n = (cfg.vocab - FIRST_TOKEN) as usize;
        let mut perm: Vec<i32> = (FIRST_TOKEN..cfg.vocab).collect();
        rng.shuffle(&mut perm);
        let _ = n;
        TranslationTask { cfg, perm }
    }

    #[inline]
    fn map(&self, tok: i32) -> i32 {
        self.perm[(tok - FIRST_TOKEN) as usize]
    }

    /// The ground-truth transform (also the oracle for BLEU upper bound).
    pub fn translate(&self, src: &[i32]) -> Vec<i32> {
        let mapped: Vec<i32> = match self.cfg.variant {
            Variant::Iwslt => src.iter().map(|&t| self.map(t)).collect(),
            Variant::Wmt => {
                let open = self.cfg.vocab - FIRST_TOKEN;
                (0..src.len())
                    .map(|i| {
                        let a = src[i] - FIRST_TOKEN;
                        let b = src[(i + 1) % src.len()] - FIRST_TOKEN;
                        self.map(FIRST_TOKEN + (a + b) % open)
                    })
                    .collect()
            }
        };
        let mut tgt: Vec<i32> = mapped.into_iter().rev().collect();
        if tgt.len() < self.cfg.tgt_len {
            tgt.push(EOS);
        } else {
            *tgt.last_mut().unwrap() = EOS;
        }
        tgt
    }

    /// Sample one source sentence from the given stream.
    pub fn sample_src(&self, rng: &mut Pcg32) -> Vec<i32> {
        let max = self.cfg.src_len.min(self.cfg.tgt_len - 1);
        let min_len = (max / 2).max(2);
        let len = rng.range(min_len as u32, max as u32 + 1) as usize;
        (0..len).map(|_| rng.range(FIRST_TOKEN as u32, self.cfg.vocab as u32) as i32).collect()
    }

    /// Sample a sentence pair.
    pub fn sample_pair(&self, rng: &mut Pcg32) -> SentencePair {
        let src = self.sample_src(rng);
        let tgt = self.translate(&src);
        SentencePair { src, tgt }
    }

    /// Independent RNG streams for splits (disjoint from each other).
    pub fn split_rng(&self, split: &str) -> Pcg32 {
        let tag = match split {
            "train" => 1u64,
            "valid" => 2,
            "test" => 3,
            other => panic!("unknown split '{other}'"),
        };
        Pcg32::new(self.cfg.seed.wrapping_mul(0x9E3779B97F4A7C15) ^ tag)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task(variant: Variant) -> TranslationTask {
        TranslationTask::new(TranslationConfig {
            vocab: 256,
            src_len: 24,
            tgt_len: 24,
            variant,
            seed: 7,
        })
    }

    #[test]
    fn translate_is_deterministic_and_seeded() {
        let t1 = task(Variant::Iwslt);
        let t2 = task(Variant::Iwslt);
        let src = vec![4, 5, 6, 7];
        assert_eq!(t1.translate(&src), t2.translate(&src));
        let t3 = TranslationTask::new(TranslationConfig {
            vocab: 256,
            src_len: 24,
            tgt_len: 24,
            variant: Variant::Iwslt,
            seed: 8,
        });
        assert_ne!(t1.translate(&src), t3.translate(&src));
    }

    #[test]
    fn iwslt_variant_is_mapped_reversal() {
        let t = task(Variant::Iwslt);
        let src = vec![10, 20, 30];
        let tgt = t.translate(&src);
        assert_eq!(tgt.len(), 4);
        assert_eq!(*tgt.last().unwrap(), EOS);
        // Reversal: tgt[0] = map(src[2]).
        assert_eq!(tgt[0], t.map(30));
        assert_eq!(tgt[2], t.map(10));
    }

    #[test]
    fn token_map_is_bijective() {
        let t = task(Variant::Iwslt);
        let mut seen = std::collections::HashSet::new();
        for tok in FIRST_TOKEN..256 {
            let m = t.map(tok);
            assert!((FIRST_TOKEN..256).contains(&m));
            assert!(seen.insert(m), "duplicate image {m}");
        }
    }

    #[test]
    fn wmt_variant_depends_on_bigrams() {
        let t = task(Variant::Wmt);
        let a = t.translate(&[10, 20, 30, 40]);
        let b = t.translate(&[10, 20, 31, 40]); // change one token
        // With bigram dependence, >1 output position changes.
        let diff = a.iter().zip(&b).filter(|(x, y)| x != y).count();
        assert!(diff >= 2, "bigram variant should propagate changes: {a:?} vs {b:?}");
    }

    #[test]
    fn sampled_pairs_fit_artifact_shapes() {
        let t = task(Variant::Iwslt);
        let mut rng = t.split_rng("train");
        for _ in 0..200 {
            let p = t.sample_pair(&mut rng);
            assert!(p.src.len() <= 24);
            assert!(p.tgt.len() <= 24);
            assert!(p.src.iter().all(|&x| (FIRST_TOKEN..256).contains(&x)));
            assert_eq!(*p.tgt.last().unwrap(), EOS);
        }
    }

    #[test]
    fn splits_are_disjoint_streams() {
        let t = task(Variant::Iwslt);
        let mut train = t.split_rng("train");
        let mut valid = t.split_rng("valid");
        let a: Vec<u32> = (0..16).map(|_| train.next_u32()).collect();
        let b: Vec<u32> = (0..16).map(|_| valid.next_u32()).collect();
        assert_ne!(a, b);
    }
}
