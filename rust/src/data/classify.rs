//! Synthetic entailment-style classification (the GLUE stand-in).
//!
//! An example is `[premise SEP hypothesis]`; the label is determined by
//! the overlap structure between the mapped premise and the hypothesis:
//!
//! * **entailment (0)** — the hypothesis is a contiguous, token-mapped
//!   fragment of the premise;
//! * **contradiction (1)** — the hypothesis is disjoint from the mapped
//!   premise (sampled from tokens the premise does not map to);
//! * **neutral (2, MNLI-style 3-way only)** — half fragment, half
//!   unrelated tokens.
//!
//! With `nclasses = 2` this is the QNLI shape (entail / not-entail),
//! with `nclasses = 3` the MNLI shape. The decision signal is
//! distributed across the sequence, so the mean-pooled encoder must
//! learn the premise↔hypothesis token correspondence — a real (if
//! small) inference task, not a keyword lookup.

use crate::util::rng::Pcg32;

use super::{FIRST_TOKEN, SEP};

/// Task configuration. `seq_len` must match the cls artifact.
#[derive(Clone, Debug)]
pub struct ClassifyConfig {
    pub vocab: i32,
    pub seq_len: usize,
    pub nclasses: usize,
    pub seed: u64,
}

/// One labeled example.
#[derive(Clone, Debug)]
pub struct Example {
    pub tokens: Vec<i32>,
    pub label: i32,
}

/// A seeded synthetic entailment task.
#[derive(Clone, Debug)]
pub struct ClassifyTask {
    pub cfg: ClassifyConfig,
    /// Premise->hypothesis token correspondence (bijective).
    map: Vec<i32>,
}

impl ClassifyTask {
    pub fn new(cfg: ClassifyConfig) -> Self {
        assert!(cfg.vocab > FIRST_TOKEN + 8, "vocab too small");
        assert!((2..=3).contains(&cfg.nclasses), "nclasses must be 2 or 3");
        assert!(cfg.seq_len >= 8, "seq_len too small");
        let mut rng = Pcg32::new(cfg.seed ^ 0xC1A55);
        let mut map: Vec<i32> = (FIRST_TOKEN..cfg.vocab).collect();
        rng.shuffle(&mut map);
        ClassifyTask { cfg, map }
    }

    #[inline]
    fn map(&self, tok: i32) -> i32 {
        self.map[(tok - FIRST_TOKEN) as usize]
    }

    /// Sample one example from the stream.
    pub fn sample(&self, rng: &mut Pcg32) -> Example {
        let label = rng.below(self.cfg.nclasses as u32) as i32;
        // Premise takes ~60% of the sequence, hypothesis the rest.
        let p_len = (self.cfg.seq_len * 3 / 5).saturating_sub(1).max(4);
        let h_len = self.cfg.seq_len - p_len - 1; // 1 for SEP
        let premise: Vec<i32> = (0..p_len)
            .map(|_| rng.range(FIRST_TOKEN as u32, self.cfg.vocab as u32) as i32)
            .collect();
        let mapped: Vec<i32> = premise.iter().map(|&t| self.map(t)).collect();
        let mapped_set: std::collections::HashSet<i32> = mapped.iter().copied().collect();

        fn unrelated(
            rng: &mut Pcg32,
            vocab: i32,
            mapped_set: &std::collections::HashSet<i32>,
        ) -> i32 {
            loop {
                let t = rng.range(FIRST_TOKEN as u32, vocab as u32) as i32;
                if !mapped_set.contains(&t) {
                    return t;
                }
            }
        }

        let hypothesis: Vec<i32> = match label {
            0 => {
                // Entailment: contiguous mapped fragment.
                let start = rng.below((p_len - h_len.min(p_len) + 1) as u32) as usize;
                (0..h_len).map(|i| mapped[(start + i) % p_len]).collect()
            }
            1 => (0..h_len).map(|_| unrelated(rng, self.cfg.vocab, &mapped_set)).collect(),
            _ => {
                // Neutral: first half fragment, second half unrelated.
                let start = rng.below(p_len as u32) as usize;
                (0..h_len)
                    .map(|i| {
                        if i < h_len / 2 {
                            mapped[(start + i) % p_len]
                        } else {
                            unrelated(rng, self.cfg.vocab, &mapped_set)
                        }
                    })
                    .collect()
            }
        };

        let mut tokens = premise;
        tokens.push(SEP);
        tokens.extend(hypothesis);
        debug_assert_eq!(tokens.len(), self.cfg.seq_len);
        Example { tokens, label }
    }

    pub fn split_rng(&self, split: &str) -> Pcg32 {
        let tag = match split {
            "train" => 11u64,
            "valid" => 12,
            "test" => 13,
            other => panic!("unknown split '{other}'"),
        };
        Pcg32::new(self.cfg.seed.wrapping_mul(0x9E3779B97F4A7C15) ^ tag)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task(nclasses: usize) -> ClassifyTask {
        ClassifyTask::new(ClassifyConfig { vocab: 256, seq_len: 48, nclasses, seed: 3 })
    }

    #[test]
    fn examples_have_artifact_shape() {
        let t = task(3);
        let mut rng = t.split_rng("train");
        for _ in 0..100 {
            let ex = t.sample(&mut rng);
            assert_eq!(ex.tokens.len(), 48);
            assert!((0..3).contains(&ex.label));
            assert_eq!(ex.tokens.iter().filter(|&&t| t == SEP).count(), 1);
        }
    }

    #[test]
    fn labels_cover_all_classes() {
        let t = task(3);
        let mut rng = t.split_rng("train");
        let mut seen = [0usize; 3];
        for _ in 0..300 {
            seen[t.sample(&mut rng).label as usize] += 1;
        }
        assert!(seen.iter().all(|&c| c > 50), "unbalanced: {seen:?}");
    }

    #[test]
    fn entailment_hypothesis_is_mapped_fragment() {
        let t = task(2);
        let mut rng = t.split_rng("train");
        for _ in 0..50 {
            let ex = t.sample(&mut rng);
            if ex.label != 0 {
                continue;
            }
            let sep = ex.tokens.iter().position(|&x| x == SEP).unwrap();
            let premise = &ex.tokens[..sep];
            let hyp = &ex.tokens[sep + 1..];
            let mapped: std::collections::HashSet<i32> =
                premise.iter().map(|&x| t.map(x)).collect();
            assert!(hyp.iter().all(|h| mapped.contains(h)));
        }
    }

    #[test]
    fn contradiction_hypothesis_is_disjoint() {
        let t = task(2);
        let mut rng = t.split_rng("train");
        for _ in 0..50 {
            let ex = t.sample(&mut rng);
            if ex.label != 1 {
                continue;
            }
            let sep = ex.tokens.iter().position(|&x| x == SEP).unwrap();
            let premise = &ex.tokens[..sep];
            let hyp = &ex.tokens[sep + 1..];
            let mapped: std::collections::HashSet<i32> =
                premise.iter().map(|&x| t.map(x)).collect();
            assert!(hyp.iter().all(|h| !mapped.contains(h)));
        }
    }

    #[test]
    fn two_way_task_has_no_neutral() {
        let t = task(2);
        let mut rng = t.split_rng("train");
        for _ in 0..100 {
            assert!(t.sample(&mut rng).label < 2);
        }
    }
}
