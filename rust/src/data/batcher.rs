//! Batch assembly for fixed-shape artifacts.
//!
//! Artifact shapes are baked at lowering, so every batch is exactly
//! `(B, S)`/`(B, T)` with PAD fill. The batcher buckets sentence pairs by
//! source length before grouping so padding waste stays low (the cheap
//! stand-in for fairseq's max-tokens batching, which the fixed-shape
//! constraint rules out), then shuffles bucket order per epoch.

use crate::util::rng::Pcg32;

use super::translation::SentencePair;
use super::{BOS, PAD};

/// One seq2seq batch in artifact layout (row-major `(B, len)`).
#[derive(Clone, Debug, PartialEq)]
pub struct Batch {
    pub src: Vec<i32>,
    pub tgt_in: Vec<i32>,
    pub tgt_out: Vec<i32>,
    pub batch: usize,
    pub src_len: usize,
    pub tgt_len: usize,
    /// Non-pad target tokens (loss normalizer).
    pub ntokens: usize,
}

/// One classification batch.
#[derive(Clone, Debug, PartialEq)]
pub struct ClsBatch {
    pub tokens: Vec<i32>,
    pub labels: Vec<i32>,
    pub batch: usize,
    pub seq_len: usize,
}

/// Fixed-shape batcher for sentence pairs.
#[derive(Clone, Debug)]
pub struct Batcher {
    pub batch: usize,
    pub src_len: usize,
    pub tgt_len: usize,
}

impl Batcher {
    pub fn new(batch: usize, src_len: usize, tgt_len: usize) -> Self {
        Batcher { batch, src_len, tgt_len }
    }

    /// Assemble one batch from exactly `self.batch` pairs (truncating
    /// overlong sentences — sample generators shouldn't produce them).
    pub fn assemble(&self, pairs: &[SentencePair]) -> Batch {
        assert_eq!(pairs.len(), self.batch, "need exactly B pairs");
        let (b, s, t) = (self.batch, self.src_len, self.tgt_len);
        let mut src = vec![PAD; b * s];
        let mut tgt_in = vec![PAD; b * t];
        let mut tgt_out = vec![PAD; b * t];
        let mut ntokens = 0;
        for (i, p) in pairs.iter().enumerate() {
            let sl = p.src.len().min(s);
            src[i * s..i * s + sl].copy_from_slice(&p.src[..sl]);
            let tl = p.tgt.len().min(t);
            // Teacher forcing: tgt_in = BOS + tgt[..-1], tgt_out = tgt.
            tgt_in[i * t] = BOS;
            for j in 0..tl.saturating_sub(1).min(t - 1) {
                tgt_in[i * t + j + 1] = p.tgt[j];
            }
            tgt_out[i * t..i * t + tl].copy_from_slice(&p.tgt[..tl]);
            ntokens += tl;
        }
        Batch { src, tgt_in, tgt_out, batch: b, src_len: s, tgt_len: t, ntokens }
    }

    /// Build an epoch of batches from a pool of pairs: length-bucket,
    /// group, shuffle batch order. Leftover pairs (< B) are dropped.
    pub fn epoch(&self, pool: &mut Vec<SentencePair>, rng: &mut Pcg32) -> Vec<Batch> {
        pool.sort_by_key(|p| p.src.len());
        let mut batches: Vec<Batch> =
            pool.chunks(self.batch).filter(|c| c.len() == self.batch).map(|c| self.assemble(c)).collect();
        rng.shuffle(&mut batches);
        batches
    }

    /// Fraction of src positions that are real tokens (padding efficiency).
    pub fn src_efficiency(batches: &[Batch]) -> f64 {
        let total: usize = batches.iter().map(|b| b.src.len()).sum();
        let real: usize =
            batches.iter().map(|b| b.src.iter().filter(|&&x| x != PAD).count()).sum();
        real as f64 / total.max(1) as f64
    }
}

/// Assemble a classification batch (exactly B examples).
pub fn assemble_cls(examples: &[super::classify::Example], seq_len: usize) -> ClsBatch {
    let b = examples.len();
    let mut tokens = vec![PAD; b * seq_len];
    let mut labels = vec![0i32; b];
    for (i, ex) in examples.iter().enumerate() {
        let l = ex.tokens.len().min(seq_len);
        tokens[i * seq_len..i * seq_len + l].copy_from_slice(&ex.tokens[..l]);
        labels[i] = ex.label;
    }
    ClsBatch { tokens, labels, batch: b, seq_len }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::translation::{TranslationConfig, TranslationTask, Variant};
    use crate::util::prop::Prop;

    fn make_pool(n: usize, seed: u64) -> (TranslationTask, Vec<SentencePair>) {
        let task = TranslationTask::new(TranslationConfig {
            vocab: 256,
            src_len: 24,
            tgt_len: 24,
            variant: Variant::Iwslt,
            seed,
        });
        let mut rng = task.split_rng("train");
        let pool = (0..n).map(|_| task.sample_pair(&mut rng)).collect();
        (task, pool)
    }

    #[test]
    fn assemble_shapes_and_teacher_forcing() {
        let (_, pool) = make_pool(16, 1);
        let b = Batcher::new(16, 24, 24);
        let batch = b.assemble(&pool);
        assert_eq!(batch.src.len(), 16 * 24);
        assert_eq!(batch.tgt_in.len(), 16 * 24);
        for i in 0..16 {
            assert_eq!(batch.tgt_in[i * 24], BOS);
            // tgt_in is tgt_out shifted right by one (the final target
            // token — EOS — never appears in the input).
            for j in 0..23 {
                if batch.tgt_in[i * 24 + j + 1] != PAD {
                    assert_eq!(batch.tgt_in[i * 24 + j + 1], batch.tgt_out[i * 24 + j]);
                }
            }
        }
        assert_eq!(
            batch.ntokens,
            pool.iter().map(|p| p.tgt.len()).sum::<usize>()
        );
    }

    #[test]
    fn epoch_batches_complete_and_shuffled() {
        let (task, mut pool) = make_pool(100, 2);
        let b = Batcher::new(16, 24, 24);
        let mut rng = task.split_rng("train");
        let batches = b.epoch(&mut pool, &mut rng);
        assert_eq!(batches.len(), 6); // 100/16 = 6 full batches
        for batch in &batches {
            assert_eq!(batch.src.len(), 16 * 24);
        }
    }

    #[test]
    fn bucketing_improves_padding_efficiency() {
        let (task, mut pool) = make_pool(400, 3);
        let b = Batcher::new(16, 24, 24);
        let mut rng = task.split_rng("train");
        // Unbucketed: assemble in arrival order.
        let unbucketed: Vec<Batch> =
            pool.chunks(16).filter(|c| c.len() == 16).map(|c| b.assemble(c)).collect();
        let bucketed = b.epoch(&mut pool, &mut rng);
        // Bucketing can't hurt global efficiency (same tokens, same
        // slots) — it matters for max-len-per-batch; just sanity check.
        let eu = Batcher::src_efficiency(&unbucketed);
        let eb = Batcher::src_efficiency(&bucketed);
        assert!((eu - eb).abs() < 1e-9);
        assert!(eb > 0.5);
    }

    #[test]
    fn cls_batch_assembly() {
        let t = crate::data::classify::ClassifyTask::new(crate::data::classify::ClassifyConfig {
            vocab: 256,
            seq_len: 48,
            nclasses: 3,
            seed: 4,
        });
        let mut rng = t.split_rng("train");
        let exs: Vec<_> = (0..16).map(|_| t.sample(&mut rng)).collect();
        let batch = assemble_cls(&exs, 48);
        assert_eq!(batch.tokens.len(), 16 * 48);
        assert_eq!(batch.labels.len(), 16);
    }

    #[test]
    fn batch_rows_never_exceed_shape_property() {
        Prop::new("batcher output always fits artifact shape").cases(40).run(
            |rng, size| {
                let n = 16 * (1 + size as usize / 30);
                let (task, pool) = make_pool(n, rng.next_u64());
                (task, pool)
            },
            |(task, pool)| {
                let b = Batcher::new(16, 24, 24);
                let mut pool = pool.clone();
                let mut rng = task.split_rng("train");
                for batch in b.epoch(&mut pool, &mut rng) {
                    if batch.src.len() != 16 * 24 || batch.tgt_in.len() != 16 * 24 {
                        return Err("wrong shape".into());
                    }
                    if batch.src.iter().any(|&t| !(0..256).contains(&t)) {
                        return Err("token out of range".into());
                    }
                }
                Ok(())
            },
        );
    }
}
