//! Synthetic corpora + batching.
//!
//! The paper's datasets (IWSLT'17/IWSLT'14, WMT'14, GLUE MNLI/QNLI) are
//! external gates; per DESIGN.md §4 they are replaced by seeded synthetic
//! tasks that exercise the identical training paths:
//!
//! * [`translation`] — seq2seq "translation": a deterministic,
//!   attention-requiring transformation of a source sentence (per-token
//!   bijective vocabulary map + sentence reversal; the harder WMT-style
//!   variant adds bigram dependence). BLEU against the reference is a
//!   real generation metric on this task.
//! * [`classify`] — entailment-style premise/hypothesis pairs with
//!   2- or 3-way labels decidable from token-overlap structure
//!   (QNLI ~ 2-way, MNLI ~ 3-way).
//! * [`batcher`] — fixed-shape batch assembly with padding (artifact
//!   shapes are baked at lowering), length bucketing to limit padding
//!   waste, and epoch shuffling.
//!
//! Token conventions match the L2 model: 0 = PAD, 1 = BOS, 2 = EOS,
//! 3 = SEP/marker, real tokens start at 4.

pub mod batcher;
pub mod classify;
pub mod translation;

pub use batcher::{Batch, Batcher, ClsBatch};
pub use classify::{ClassifyConfig, ClassifyTask};
pub use translation::{TranslationConfig, TranslationTask, Variant};

/// Reserved token ids (match python/compile/model.py).
pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
pub const SEP: i32 = 3;
/// First unreserved vocabulary id.
pub const FIRST_TOKEN: i32 = 4;
