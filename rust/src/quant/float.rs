//! Low-bit float fake quantization (the `e<E>m<M>` family: FP8
//! E4M3/E5M2, and bf16/fp16 as `e8m7`/`e5m10`) — rust mirror of
//! `python/compile/kernels/floatq.py` / `ref.float_quantize_ref`.
//!
//! Unlike the fixed/BFP kernels there is **no shared exponent and no
//! tensor-wide reduction**: every element carries its own exponent, so
//! quantization is embarrassingly parallel and — crucially — the
//! NaN/±inf semantics need no `amax` special-casing.
//!
//! ## Grid definition (IEEE-754 style, bias `2^(E-1) - 1`)
//!
//! For `E` exponent bits and `M` mantissa bits (total width `1 + E + M`):
//!
//! * normal range: exponents `e ∈ [e_min, e_max]` with
//!   `e_min = 1 - bias`, `e_max = bias`; within binade `e` the step is
//!   `2^(e - M)`;
//! * **subnormal support**: `|x| < 2^e_min` quantizes on the uniform
//!   grid `k · 2^(e_min - M)` (for `e5m10` this reproduces IEEE fp16
//!   subnormals exactly);
//! * **saturating overflow**: values beyond
//!   `max = 2^e_max · (2 - 2^-M)` — including ±inf — clamp to `±max`
//!   (OCP-FP8-style saturation; there is no inf encoding);
//! * **NaN propagates** as NaN (the packed codec reserves the all-ones
//!   exponent field for it);
//! * rounding is round-half-to-even, or unbiased stochastic rounding in
//!   the `sr` variant (one uniform draw per element, same [`Pcg32`]
//!   stream discipline as `fixed<b>sr`).
//!
//! One deliberate FTZ deviation, shared with the fixed/BFP kernels: the
//! step exponent is clamped to the normal-f32 range (`e - M ≥ -126`),
//! because XLA CPU runs with FTZ and a subnormal step would flush to
//! zero inside the artifact. Formats whose ideal grid dips below that
//! (only wide-exponent ones like `e8m7`) bottom out on a `2^-126` step;
//! f32-subnormal *inputs* read as zero ([`ftz`]), as everywhere else.

use crate::util::rng::Pcg32;

use super::{floor_log2, ftz, pow2, EXP_MAX, EXP_MIN};

/// Legal exponent-width range for the float family.
pub const FLOAT_EXP_RANGE: (u32, u32) = (2, 8);
/// Legal mantissa-width range for the float family. Capped at 10 (fp16's
/// mantissa): wider low-bit floats are not a hardware point of interest
/// below fp32, and the cap keeps every float format well clear of the
/// ≥ 25-bit identity-passthrough regime.
pub const FLOAT_MAN_RANGE: (u32, u32) = (1, 10);

/// Derived grid parameters of an `e<E>m<M>` format.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FloatGrid {
    /// Minimum normal exponent `1 - bias`.
    pub e_min: i32,
    /// Maximum normal exponent `bias` (the top field is reserved for NaN).
    pub e_max: i32,
    /// Mantissa bits.
    pub man: i32,
    /// Largest finite value `2^e_max · (2 - 2^-M)`; quantization
    /// saturates here.
    pub max: f32,
}

/// Grid parameters for `E` exponent / `M` mantissa bits.
pub fn float_grid(exp_bits: u32, man_bits: u32) -> FloatGrid {
    debug_assert!(
        (FLOAT_EXP_RANGE.0..=FLOAT_EXP_RANGE.1).contains(&exp_bits),
        "exp width {exp_bits} out of {FLOAT_EXP_RANGE:?}"
    );
    debug_assert!(
        (FLOAT_MAN_RANGE.0..=FLOAT_MAN_RANGE.1).contains(&man_bits),
        "man width {man_bits} out of {FLOAT_MAN_RANGE:?}"
    );
    let bias = (1i32 << (exp_bits - 1)) - 1;
    let man = man_bits as i32;
    FloatGrid {
        e_min: 1 - bias,
        e_max: bias,
        man,
        max: pow2(bias) * (2.0 - pow2(-man)),
    }
}

/// Quantize one value to the grid with round-half-to-even. Mirrors
/// `ref.float_quantize_ref` op for op (exponent clip, clamped
/// power-of-two step, round, saturate).
#[inline]
fn quantize_elem(v: f32, g: &FloatGrid) -> f32 {
    let x = ftz(v);
    if x.is_nan() {
        return f32::NAN;
    }
    let e = floor_log2(x).clamp(g.e_min, g.e_max);
    let step = pow2((e - g.man).clamp(EXP_MIN, EXP_MAX));
    let mag = (x / step).round_ties_even();
    (mag * step).clamp(-g.max, g.max)
}

/// Quantize `x` in place to the `e<exp_bits>m<man_bits>` grid.
pub fn float_quantize_into(x: &mut [f32], exp_bits: u32, man_bits: u32) {
    let g = float_grid(exp_bits, man_bits);
    for v in x.iter_mut() {
        *v = quantize_elem(*v, &g);
    }
}

/// Out-of-place variant.
pub fn float_quantize(x: &[f32], exp_bits: u32, man_bits: u32) -> Vec<f32> {
    let mut out = x.to_vec();
    float_quantize_into(&mut out, exp_bits, man_bits);
    out
}

/// Stochastic-rounding variant (the `e<E>m<M>sr` spelling): same grid,
/// but each value rounds up with probability equal to its fractional
/// distance — unbiased for unsaturated values. Exactly one uniform draw
/// is consumed per element (NaNs included), so a given `rng` state
/// quantizes a given buffer bit-identically; callers derive the stream
/// from the step index ([`crate::quant::FormatSpec::quantize_into_step`]).
pub fn float_quantize_sr_into(x: &mut [f32], exp_bits: u32, man_bits: u32, rng: &mut Pcg32) {
    let g = float_grid(exp_bits, man_bits);
    for v in x.iter_mut() {
        let u = rng.f32();
        let xi = ftz(*v);
        if xi.is_nan() {
            *v = f32::NAN;
            continue;
        }
        let e = floor_log2(xi).clamp(g.e_min, g.e_max);
        let step = pow2((e - g.man).clamp(EXP_MIN, EXP_MAX));
        let t = xi / step;
        let lo = t.floor();
        // `t - lo` in [0,1); both candidate points lie on the grid (the
        // upper one may be the next binade's first point, which the
        // wider step there also represents exactly).
        let mag = if t - lo > u { lo + 1.0 } else { lo };
        *v = (mag * step).clamp(-g.max, g.max);
    }
}

/// Out-of-place stochastic-rounding variant.
pub fn float_quantize_sr(x: &[f32], exp_bits: u32, man_bits: u32, rng: &mut Pcg32) -> Vec<f32> {
    let mut out = x.to_vec();
    float_quantize_sr_into(&mut out, exp_bits, man_bits, rng);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{gen_f32s, Prop};
    use crate::util::rng::Pcg32;

    fn q_e4m3(x: f32) -> f32 {
        float_quantize(&[x], 4, 3)[0]
    }

    #[test]
    fn e4m3_known_values() {
        // bias 7: e_max 7, max = 128 * 1.875 = 240; e_min -6, min
        // subnormal 2^-9.
        let g = float_grid(4, 3);
        assert_eq!(g.max, 240.0);
        assert_eq!(g.e_min, -6);
        assert_eq!(q_e4m3(1.0), 1.0);
        assert_eq!(q_e4m3(240.0), 240.0);
        assert_eq!(q_e4m3(300.0), 240.0, "saturating overflow");
        assert_eq!(q_e4m3(-1e30), -240.0);
        assert_eq!(q_e4m3(f32::INFINITY), 240.0, "inf saturates");
        assert_eq!(q_e4m3(f32::NEG_INFINITY), -240.0);
        assert!(q_e4m3(f32::NAN).is_nan(), "NaN propagates");
        // Binade [1, 2): step 1/8; 1.3 is 10.4 eighths, rounds to 10.
        assert_eq!(q_e4m3(1.3), 1.25);
        // Ties to even: 1.0625 is exactly between 1.0 and 1.125 -> 1.0.
        assert_eq!(q_e4m3(1.0625), 1.0);
        assert_eq!(q_e4m3(1.1875), 1.25, "1.1875 ties up to even 1.25");
        // Subnormal grid: step 2^-9; 2^-9 is the smallest nonzero value.
        assert_eq!(q_e4m3(pow2(-9)), pow2(-9));
        assert_eq!(q_e4m3(pow2(-10)), 0.0, "half the min subnormal ties to even 0");
        assert_eq!(q_e4m3(1.6 * pow2(-10)), pow2(-9));
        // f32 subnormal inputs are FTZ'd.
        assert_eq!(q_e4m3(f32::MIN_POSITIVE / 2.0), 0.0);
    }

    #[test]
    fn e5m2_known_values() {
        // bias 15: max = 2^15 * 1.75 = 57344; e_min -14.
        let g = float_grid(5, 2);
        assert_eq!(g.max, 57344.0);
        assert_eq!(g.e_min, -14);
        let q = |x| float_quantize(&[x], 5, 2)[0];
        assert_eq!(q(57344.0), 57344.0);
        assert_eq!(q(1e9), 57344.0);
        assert_eq!(q(3.0), 3.0); // 1.5 * 2 is representable at m=2
        assert_eq!(q(pow2(-16)), pow2(-16)); // subnormal: step 2^-16
    }

    #[test]
    fn e5m10_matches_ieee_fp16_grid() {
        // e5m10 is IEEE binary16 (with saturation instead of inf): max
        // 65504, subnormal step 2^-24, round-half-even.
        let q = |x| float_quantize(&[x], 5, 10)[0];
        assert_eq!(q(65504.0), 65504.0);
        assert_eq!(q(65503.0), 65504.0);
        assert_eq!(q(1e9), 65504.0, "saturates instead of inf");
        assert_eq!(q(1.0 + pow2(-11)), 1.0, "halfway ties to even");
        assert_eq!(q(1.0 + 3.0 * pow2(-11)), 1.0 + pow2(-9), "1025.5 ties up to even 1026");
        assert_eq!(q(pow2(-24)), pow2(-24), "smallest fp16 subnormal");
        assert_eq!(q(pow2(-25)), 0.0, "below: ties to even zero");
        // 2^-14 is the smallest normal; just below it the subnormal grid
        // still resolves 10 bits.
        assert_eq!(q(pow2(-14) - pow2(-24)), pow2(-14) - pow2(-24));
    }

    #[test]
    fn e8m7_bottoms_out_on_the_ftz_step() {
        // bf16's ideal bottom step 2^(-126-7) is f32-subnormal; the grid
        // clamps it to 2^-126 (the documented FTZ deviation), so tiny
        // normals survive but with reduced resolution.
        let q = |x: f32| float_quantize(&[x], 8, 7)[0];
        assert_eq!(q(1.5), 1.5);
        assert_eq!(q(pow2(-126)), pow2(-126));
        // 1.25 * 2^-125 = 2.5 * 2^-126: not an integer multiple of the
        // clamped 2^-126 step, so it rounds (ties to even 2).
        assert_eq!(q(1.25 * pow2(-125)), pow2(-125));
        let v = 3.0 * pow2(-126);
        assert_eq!(q(v), v, "integer multiples of 2^-126 are on the clamped grid");
    }

    #[test]
    fn idempotent_property() {
        Prop::new("float quantization is idempotent").cases(60).run(
            |rng, size| {
                let fmts = [(4u32, 3u32), (5, 2), (5, 10), (8, 7), (3, 4)];
                (
                    gen_f32s(rng, 8 * (1 + size as usize / 12), 14.0),
                    fmts[rng.below(fmts.len() as u32) as usize],
                )
            },
            |(x, (e, m))| {
                let q1 = float_quantize(x, *e, *m);
                let q2 = float_quantize(&q1, *e, *m);
                if q1 == q2 {
                    Ok(())
                } else {
                    Err("q(q(x)) != q(x)".into())
                }
            },
        );
    }

    #[test]
    fn error_monotone_in_mantissa_bits_property() {
        // At fixed exponent width, more mantissa bits never increase the
        // error: each grid is a refinement of the previous (plus a higher
        // saturation point).
        Prop::new("float error monotone non-increasing in man bits").cases(40).run(
            |rng, size| (gen_f32s(rng, 8 * (1 + size as usize / 20), 6.0), 2 + rng.below(7)),
            |(x, e)| {
                let err = |m: u32| {
                    float_quantize(x, *e, m)
                        .iter()
                        .zip(x.iter())
                        .map(|(q, x)| ((q - x) as f64).abs())
                        .sum::<f64>()
                };
                let errs: Vec<f64> = (1..=10).map(err).collect();
                for w in errs.windows(2) {
                    if w[1] > w[0] * 1.0000001 + 1e-12 {
                        return Err(format!("error increased with man bits: {errs:?}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn sr_lands_on_adjacent_grid_points() {
        let mut rng = Pcg32::new(7);
        for (e, m) in [(4u32, 3u32), (5, 2)] {
            let x = gen_f32s(&mut rng, 512, 5.0);
            let q = float_quantize_sr(&x, e, m, &mut Pcg32::new(3));
            let g = float_grid(e, m);
            for (&xi, &qi) in x.iter().zip(&q) {
                if xi.abs() >= g.max {
                    assert_eq!(qi.abs(), g.max, "saturated value must clamp");
                    continue;
                }
                // |q - x| < one step of x's binade.
                let eexp = floor_log2(xi).clamp(g.e_min, g.e_max);
                let step = pow2((eexp - g.man).clamp(EXP_MIN, EXP_MAX));
                assert!(
                    (qi - xi).abs() < step * (1.0 + 1e-6),
                    "e{e}m{m}: |{qi} - {xi}| >= step {step}"
                );
                // And the output is a fixed point of nearest quantization
                // (i.e. on the grid).
                assert_eq!(float_quantize(&[qi], e, m)[0], qi, "off-grid SR output");
            }
        }
    }

    #[test]
    fn sr_unbiased_at_fp8_property() {
        // E[q_sr(x)] = x for unsaturated values, at both fp8 formats.
        Prop::new("float stochastic rounding is unbiased at e4m3/e5m2").cases(10).run(
            |rng, _| {
                let fmts = [(4u32, 3u32), (5, 2)];
                (gen_f32s(rng, 48, 3.0), fmts[rng.below(2) as usize])
            },
            |(x, (e, m))| {
                let g = float_grid(*e, *m);
                let trials = 600u64;
                let mut mean = vec![0f64; x.len()];
                for t in 0..trials {
                    let q = float_quantize_sr(x, *e, *m, &mut Pcg32::new(0xF10A7 + t));
                    for (acc, &qi) in mean.iter_mut().zip(&q) {
                        *acc += qi as f64 / trials as f64;
                    }
                }
                for (&xi, &mi) in x.iter().zip(&mean) {
                    if xi.abs() >= g.max || xi == 0.0 {
                        continue; // saturation is biased by design
                    }
                    let eexp = floor_log2(xi).clamp(g.e_min, g.e_max);
                    let step = pow2((eexp - g.man).clamp(EXP_MIN, EXP_MAX)) as f64;
                    // 4-sigma Bernoulli bound on a `step` grid.
                    let tol = 4.0 * step / (trials as f64).sqrt() + 1e-12;
                    if (mi - xi as f64).abs() > tol {
                        return Err(format!("e{e}m{m} biased: x={xi} mean={mi} tol={tol}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn sr_deterministic_in_rng_state_and_draws_per_element() {
        let x = vec![1.3f32, f32::NAN, 0.7, -2.2];
        let a = float_quantize_sr(&x, 4, 3, &mut Pcg32::new(5));
        let b = float_quantize_sr(&x, 4, 3, &mut Pcg32::new(5));
        assert_eq!(a.len(), b.len());
        for (va, vb) in a.iter().zip(&b) {
            assert!(crate::quant::same_f32(*va, *vb));
        }
        // NaN elements still consume a draw: the tail elements after the
        // NaN must match the nearest-path RNG alignment.
        let mut rng1 = Pcg32::new(9);
        let _ = float_quantize_sr(&x, 4, 3, &mut rng1);
        let mut rng2 = Pcg32::new(9);
        for _ in 0..4 {
            rng2.f32();
        }
        assert_eq!(rng1.f32(), rng2.f32(), "one uniform per element, NaNs included");
    }

    #[test]
    fn nan_inf_semantics_pinned() {
        // No tensor-wide amax: an all-NaN tensor stays all-NaN (contrast
        // with fixed/BFP's zero-grid early-out, which preserves NaN but
        // flushes everything else), and ±inf saturate per element.
        let x = vec![f32::NAN; 8];
        assert!(float_quantize(&x, 5, 2).iter().all(|v| v.is_nan()));
        let y = vec![f32::INFINITY, f32::NEG_INFINITY, f32::NAN, 0.0, -0.0, 1.0];
        let q = float_quantize(&y, 4, 3);
        assert_eq!(q[0], 240.0);
        assert_eq!(q[1], -240.0);
        assert!(q[2].is_nan());
        assert_eq!(q[3], 0.0);
        assert_eq!(q[4], 0.0);
        assert!(q[4].is_sign_negative(), "-0.0 is preserved (invisible to ==)");
        assert_eq!(q[5], 1.0);
    }

    #[test]
    fn sign_preserved() {
        let mut rng = Pcg32::new(3);
        let x = gen_f32s(&mut rng, 256, 10.0);
        let q = float_quantize(&x, 5, 2);
        for (&xi, &qi) in x.iter().zip(&q) {
            assert!(qi == 0.0 || qi.signum() == xi.signum(), "sign flip: {xi} -> {qi}");
        }
    }
}
