//! `PackedTensor` — the physical byte layout of every registered
//! [`FormatSpec`], making stash storage real instead of priced-only.
//!
//! Until this module existed, `FormatSpec::storage_bits()` *priced*
//! 4-bit DRAM traffic while every stashed tensor remained a dense
//! `Vec<f32>`. The [`Codec`] trait closes that gap: `encode` packs a
//! tensor into the format's true bit layout and [`PackedTensor::decode`]
//! recovers f32 — with the invariant (property-tested in this module)
//!
//! ```text
//! decode(encode(x)) == quantize(x)      // per f32 ==; NaN ≡ NaN
//! ```
//!
//! so a packed stash is indistinguishable from a fake-quantized dense
//! one, except it actually occupies `storage_bits()`-scale bytes. Two
//! deliberate non-bit-exactnesses, both invisible to `==`: NaN payloads
//! canonicalize to one sentinel NaN, and — in the integer-lane families,
//! whose lane has a single zero — a quantized `-0.0` decodes as `+0.0`
//! (the float family's sign-magnitude lane preserves it).
//!
//! Tensors may be **ragged**: `len % inner != 0` means the last row is
//! short, and the box-based layouts pack that trailing partial row as a
//! row of its own (exactly how `bfp_quantize_into` grids it).
//!
//! ## Payload layouts (pinned by the golden-bytes tests)
//!
//! * **fp32** — raw little-endian f32, 4 bytes/element.
//! * **fixed / fixedsr, width < 25** — one grid byte (biased shared
//!   exponent `e + 127`; `0` marks the degenerate zero-`amax` grid),
//!   then two's-complement mantissa lanes of `bits` each, packed
//!   LSB-first in row-major element order. The lane value `-2^(bits-1)`
//!   (unused by the quantizer, which clamps to `±(2^(bits-1)-1)`) is the
//!   NaN sentinel — written and decoded in the grid-byte-0 layout too,
//!   so an all-NaN tensor round-trips.
//! * **bfp, width < 25** — per box of [`BOX`] elements (boxes never span
//!   rows of `inner`, the last box of a row may be short): one biased
//!   shared-exponent byte (`0` = degenerate box), then that box's
//!   mantissa lanes, byte-aligned per box so the stash store's spill
//!   tier ([`crate::stash`]) can seek to any box of a spilled record.
//! * **float (`e<E>m<M>`)** — per element, a `(1 + E + M)`-bit IEEE-754
//!   style lane (sign, biased exponent field, mantissa; field 0 is the
//!   subnormal/flush grid, the all-ones field is NaN — saturation means
//!   no inf encoding), packed LSB-first with a byte-aligned tail. No
//!   grid byte: the exponents live in the lanes. At the FP8 widths this
//!   is exactly the byte-per-element container.
//! * **width ≥ 25** ([`PASSTHROUGH_BITS`]) — the quantizer is an exact
//!   identity on f32, so the payload is the raw 32-bit container (a
//!   sub-32-bit lane could not round-trip arbitrary f32). Never applies
//!   to the float family (mantissas cap at 10 bits).
//!
//! The serialized record ([`PackedTensor::write_into`]) prefixes the
//! payload with a versioned self-describing header:
//!
//! ```text
//! u8   PACKED_VERSION (1)
//! u8   family tag (0 fp32, 1 fixed, 2 fixedsr, 3 bfp, 4 float, 5 floatsr)
//! u8   width byte: bit width; for float tags, (exp_bits << 4) | man_bits
//! u8   flags (0; reserved)
//! u32  inner (minor-axis length, LE)
//! u32  ndims, then u64 dims... (LE)
//! u64  payload byte length (LE)
//! ...  payload
//! ```
//!
//! Checkpoints (`model/checkpoint.rs` v2) and the runtime's
//! `TensorData::Packed` arm both carry this record, so the on-disk and
//! in-memory forms are the same bytes.

use std::io::{Read, Write};

use crate::{Error, Result};

use super::float::{float_grid, FLOAT_EXP_RANGE, FLOAT_MAN_RANGE};
use super::format::{FormatSpec, Rounding};
use super::{floor_log2, ftz, pow2, quant_grid, BOX, EXP_MAX, EXP_MIN, PASSTHROUGH_BITS};

/// Version byte of the packed record header.
pub const PACKED_VERSION: u8 = 1;

/// A tensor stored in its format's physical bit layout.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedTensor {
    spec: FormatSpec,
    shape: Vec<usize>,
    /// Minor-axis length the box-based formats quantized against.
    inner: usize,
    payload: Vec<u8>,
}

/// The encode half of the codec, implemented on [`FormatSpec`] so the
/// same descriptor that quantizes and prices a format also packs it.
pub trait Codec {
    /// Pack `x` (row-major, `shape`-shaped, minor axis `inner`) into the
    /// format's bit layout. Stochastic formats use the `(step, stream)`
    /// rounding stream — the same parameters
    /// [`FormatSpec::quantize_into_stream`] takes, so
    /// `encode_stream(x, ...).decode()` reproduces that exact call.
    fn encode_stream(
        &self,
        x: &[f32],
        shape: &[usize],
        inner: usize,
        step: u64,
        stream: u64,
    ) -> PackedTensor {
        self.encode_stream_salted(x, shape, inner, step, stream, 0)
    }

    /// [`Codec::encode_stream`] with a caller-identity `salt` folded into
    /// the SR seed ([`FormatSpec::quantize_into_stream_salted`]) — the
    /// wire-encode entry point for replica exchange, where each rank must
    /// draw a decorrelated rounding stream for the same `(step, stream)`.
    /// Salt 0 is bit-identical to [`Codec::encode_stream`].
    fn encode_stream_salted(
        &self,
        x: &[f32],
        shape: &[usize],
        inner: usize,
        step: u64,
        stream: u64,
        salt: u64,
    ) -> PackedTensor;

    /// [`Codec::encode_stream`] at the step-0 stream (matching
    /// [`FormatSpec::quantize_into`]).
    fn encode(&self, x: &[f32], shape: &[usize], inner: usize) -> PackedTensor {
        self.encode_stream(x, shape, inner, 0, 0)
    }

    /// Exact payload size in bytes for a tensor of `len` elements with
    /// minor axis `inner` — a pure layout function of the format, never
    /// of the data (so the cost model can audit it; see
    /// `FormatSpec::observed_bytes`).
    fn packed_len(&self, len: usize, inner: usize) -> usize;
}

/// True when the format's quantizer is an exact identity on f32 and the
/// payload must therefore be the raw 32-bit container. Float formats are
/// never an identity (±inf saturate), so they always use real lanes.
fn is_passthrough(spec: &FormatSpec) -> bool {
    match *spec {
        FormatSpec::Fp32 => true,
        FormatSpec::Float { .. } => false,
        _ => spec.bits() as f32 >= PASSTHROUGH_BITS,
    }
}

/// Mantissa lane width in bits (only meaningful for non-passthrough).
fn lane_bits(spec: &FormatSpec) -> u32 {
    spec.bits()
}

/// NaN sentinel for a `bits`-wide two's-complement lane: the one value
/// (`-2^(bits-1)`) the quantizer's `±(2^(bits-1)-1)` clamp never emits.
fn nan_sentinel(bits: u32) -> u32 {
    1u32 << (bits - 1)
}

// ---------------------------------------------------------------------
// Bit-stream helpers (LSB-first, little-endian byte order).

struct BitWriter<'a> {
    out: &'a mut Vec<u8>,
    acc: u64,
    nbits: u32,
}

impl<'a> BitWriter<'a> {
    fn new(out: &'a mut Vec<u8>) -> Self {
        BitWriter { out, acc: 0, nbits: 0 }
    }

    /// Append the low `width` bits of `value` (width <= 24).
    fn push(&mut self, value: u32, width: u32) {
        self.acc |= ((value as u64) & ((1u64 << width) - 1)) << self.nbits;
        self.nbits += width;
        while self.nbits >= 8 {
            self.out.push(self.acc as u8);
            self.acc >>= 8;
            self.nbits -= 8;
        }
    }

    /// Pad the tail to a byte boundary with zero bits.
    fn align(&mut self) {
        if self.nbits > 0 {
            self.out.push(self.acc as u8);
            self.acc = 0;
            self.nbits = 0;
        }
    }
}

struct BitReader<'a> {
    buf: &'a [u8],
    pos: usize,
    acc: u64,
    nbits: u32,
}

impl<'a> BitReader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        BitReader { buf, pos: 0, acc: 0, nbits: 0 }
    }

    fn take(&mut self, width: u32) -> u32 {
        while self.nbits < width {
            self.acc |= (self.buf[self.pos] as u64) << self.nbits;
            self.pos += 1;
            self.nbits += 8;
        }
        let v = (self.acc & ((1u64 << width) - 1)) as u32;
        self.acc >>= width;
        self.nbits -= width;
        v
    }

    /// Drop any buffered sub-byte tail (the writer's `align` padding).
    fn align(&mut self) {
        self.acc = 0;
        self.nbits = 0;
    }
}

/// Sign-extend a `bits`-wide two's-complement lane to i32.
fn sign_extend(raw: u32, bits: u32) -> i32 {
    let sign = 1u32 << (bits - 1);
    (raw ^ sign).wrapping_sub(sign) as i32
}

/// One quantized value -> lane (integer magnitude on the `step` grid, or
/// the NaN sentinel). `q / step` is exact: q was produced as
/// `mag * step` with `|mag| <= 2^23` and a power-of-two step.
fn lane_of(q: f32, step: f32, bits: u32) -> u32 {
    if q.is_nan() {
        nan_sentinel(bits)
    } else {
        (q / step) as i32 as u32
    }
}

/// Lane -> f32 on the `step` grid.
fn value_of(raw: u32, step: f32, bits: u32) -> f32 {
    if raw == nan_sentinel(bits) {
        f32::NAN
    } else {
        sign_extend(raw, bits) as f32 * step
    }
}

/// One quantized float-family value -> `(1 + E + M)`-bit lane: sign,
/// biased exponent field, mantissa. Field 0 is the subnormal/flush grid
/// (step `2^max(e_min - M, -126)` — the FTZ-clamped bottom step, which
/// for narrow-exponent formats is exactly the IEEE subnormal grid);
/// the all-ones field is NaN. `q` must be on the grid (a
/// `float_quantize` output), so every division below is exact.
fn float_lane(q: f32, exp_bits: u32, man_bits: u32) -> u32 {
    let g = float_grid(exp_bits, man_bits);
    let m = man_bits;
    let nan_field = (1u32 << exp_bits) - 1;
    if q.is_nan() {
        // Canonical NaN lane: all-ones exponent, all-ones mantissa.
        return (nan_field << m) | ((1 << m) - 1);
    }
    let sign = (q.is_sign_negative() as u32) << (exp_bits + m);
    let a = q.abs();
    if a == 0.0 {
        return sign;
    }
    let bias = g.e_max; // bias == e_max for the IEEE-style layout
    // Everything below the unclamped-grid floor lives on the flush grid
    // (exponent field 0); e_floor == e_min whenever FTZ never clamps.
    let e_floor = g.e_min.max(EXP_MIN + g.man);
    let e = floor_log2(a);
    if e < e_floor {
        let flush_step = pow2((g.e_min - g.man).clamp(EXP_MIN, EXP_MAX));
        return sign | (a / flush_step) as u32;
    }
    let field = (e + bias) as u32;
    let step = pow2((e - g.man).clamp(EXP_MIN, EXP_MAX));
    let frac = (a / step) as u32 - (1u32 << m);
    sign | (field << m) | frac
}

/// Float-family lane -> f32.
fn float_value(raw: u32, exp_bits: u32, man_bits: u32) -> f32 {
    let g = float_grid(exp_bits, man_bits);
    let m = man_bits;
    let field = (raw >> m) & ((1 << exp_bits) - 1);
    let man = raw & ((1 << m) - 1);
    if field == (1 << exp_bits) - 1 {
        return f32::NAN;
    }
    let sign = if (raw >> (exp_bits + m)) & 1 == 1 { -1.0f32 } else { 1.0 };
    if field == 0 {
        let flush_step = pow2((g.e_min - g.man).clamp(EXP_MIN, EXP_MAX));
        return sign * man as f32 * flush_step;
    }
    let e = field as i32 - g.e_max; // subtract the bias
    let step = pow2((e - g.man).clamp(EXP_MIN, EXP_MAX));
    sign * ((1u32 << m) + man) as f32 * step
}

/// Biased shared-exponent byte: 0 marks a zero tensor/box, else
/// `e + 127` for the clamped exponent `e` in `[EXP_MIN, EXP_MAX]`.
fn exp_byte(amax: f32, bits: u32) -> u8 {
    if amax <= 0.0 {
        0
    } else {
        let (e, _, _) = quant_grid(amax, bits as f32);
        (e + 127) as u8
    }
}

/// Recover the grid step from a biased exponent byte (byte != 0).
fn step_of_exp_byte(b: u8, bits: u32) -> f32 {
    let e = b as i32 - 127;
    super::pow2((e - bits as i32 + 2).clamp(EXP_MIN, EXP_MAX))
}

fn raw_f32_bytes(x: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(x.len() * 4);
    for &v in x {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

impl Codec for FormatSpec {
    fn encode_stream_salted(
        &self,
        x: &[f32],
        shape: &[usize],
        inner: usize,
        step: u64,
        stream: u64,
        salt: u64,
    ) -> PackedTensor {
        assert_eq!(shape.iter().product::<usize>(), x.len(), "shape/data mismatch");
        assert!(inner > 0, "inner must be >= 1");
        let payload = if is_passthrough(self) {
            raw_f32_bytes(x)
        } else {
            // Quantize through the format's own kernel, then recover the
            // integer magnitudes exactly (q = mag * step with a
            // power-of-two step). Duplicating the element rule here
            // would invite drift; dividing cannot.
            let mut q = x.to_vec();
            self.quantize_into_stream_salted(&mut q, inner, step, stream, salt);
            let bits = lane_bits(self);
            let mut out = Vec::with_capacity(self.packed_len(x.len(), inner));
            match *self {
                FormatSpec::Fixed { .. } => {
                    let amax = x.iter().fold(0.0f32, |a, &v| a.max(ftz(v.abs())));
                    let eb = exp_byte(amax, bits);
                    out.push(eb);
                    let gstep = if eb == 0 { 1.0 } else { step_of_exp_byte(eb, bits) };
                    let mut w = BitWriter::new(&mut out);
                    for &qi in &q {
                        w.push(lane_of(qi, gstep, bits), bits);
                    }
                    w.align();
                }
                FormatSpec::Bfp { .. } => {
                    // chunks() yields the ragged trailing row/box shorts
                    // exactly as the quantizer grids them.
                    for (row, qrow) in x.chunks(inner).zip(q.chunks(inner)) {
                        for (boxed, qboxed) in row.chunks(BOX).zip(qrow.chunks(BOX)) {
                            let amax =
                                boxed.iter().fold(0.0f32, |a, &v| a.max(ftz(v.abs())));
                            let eb = exp_byte(amax, bits);
                            out.push(eb);
                            let gstep =
                                if eb == 0 { 1.0 } else { step_of_exp_byte(eb, bits) };
                            let mut w = BitWriter::new(&mut out);
                            for &qi in qboxed {
                                w.push(lane_of(qi, gstep, bits), bits);
                            }
                            w.align();
                        }
                    }
                }
                FormatSpec::Float { exp_bits, man_bits, .. } => {
                    let mut w = BitWriter::new(&mut out);
                    for &qi in &q {
                        w.push(float_lane(qi, exp_bits, man_bits), bits);
                    }
                    w.align();
                }
                // dsq-lint: allow(panic_hygiene, fp32 took the is_passthrough fast path above)
                FormatSpec::Fp32 => unreachable!("fp32 is passthrough"),
            }
            out
        };
        debug_assert_eq!(payload.len(), self.packed_len(x.len(), inner));
        PackedTensor { spec: *self, shape: shape.to_vec(), inner, payload }
    }

    fn packed_len(&self, len: usize, inner: usize) -> usize {
        assert!(inner > 0, "inner must be >= 1");
        if is_passthrough(self) {
            return 4 * len;
        }
        let bits = lane_bits(self) as usize;
        match *self {
            FormatSpec::Fixed { .. } => 1 + (bits * len).div_ceil(8),
            FormatSpec::Bfp { .. } => {
                // Bytes of one row of `r` elements: an exponent byte +
                // byte-aligned lanes per (possibly short) box.
                let row_bytes = |r: usize| {
                    let full = r / BOX;
                    let rem = r % BOX;
                    full * (1 + (bits * BOX).div_ceil(8))
                        + if rem > 0 { 1 + (bits * rem).div_ceil(8) } else { 0 }
                };
                // Ragged tensors: `len % inner` trailing elements form a
                // short final row of their own (row_bytes(0) == 0).
                (len / inner) * row_bytes(inner) + row_bytes(len % inner)
            }
            FormatSpec::Float { .. } => (bits * len).div_ceil(8),
            // dsq-lint: allow(panic_hygiene, fp32 returned via the is_passthrough arm above)
            FormatSpec::Fp32 => unreachable!("fp32 is passthrough"),
        }
    }
}

impl PackedTensor {
    pub fn spec(&self) -> FormatSpec {
        self.spec
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn inner(&self) -> usize {
        self.inner
    }

    /// Element count.
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The packed payload (no header).
    pub fn payload(&self) -> &[u8] {
        &self.payload
    }

    /// Payload size in bytes — the physical counterpart of
    /// `storage_bits() * len / 8`.
    pub fn packed_len(&self) -> usize {
        self.payload.len()
    }

    /// On-disk record size: header + payload.
    pub fn record_len(&self) -> usize {
        8 + 4 + 8 * self.shape.len() + 8 + self.payload.len()
    }

    /// All-zero packed tensor, built directly in the bit layout (no
    /// quantize/encode round trip): every layout zero-fills to the zero
    /// tensor (grid marker 0, zero lanes, zero f32 words).
    pub fn zeros(spec: FormatSpec, shape: &[usize], inner: usize) -> PackedTensor {
        let len = shape.iter().product();
        PackedTensor {
            spec,
            shape: shape.to_vec(),
            inner,
            payload: vec![0u8; spec.packed_len(len, inner)],
        }
    }

    /// Unpack to dense f32 — `==` to `spec.quantize(...)` of the tensor
    /// that was encoded (NaN payloads canonicalized, `-0.0` decodes as
    /// `+0.0`; see the module docs).
    pub fn decode(&self) -> Vec<f32> {
        let len = self.len();
        if is_passthrough(&self.spec) {
            return self
                .payload
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
        }
        let bits = lane_bits(&self.spec);
        let mut out = Vec::with_capacity(len);
        match self.spec {
            FormatSpec::Fixed { .. } => {
                let eb = self.payload[0];
                let mut r = BitReader::new(&self.payload[1..]);
                // Grid byte 0 is the degenerate zero-amax grid: every
                // live lane is 0, but the NaN sentinel must still read
                // out (an all-NaN tensor quantizes to all-NaN). The
                // nominal step 1.0 matches the encoder's.
                let step = if eb == 0 { 1.0 } else { step_of_exp_byte(eb, bits) };
                for _ in 0..len {
                    out.push(value_of(r.take(bits), step, bits));
                }
            }
            FormatSpec::Bfp { .. } => {
                let mut pos = 0usize;
                let mut done = 0usize;
                while done < len {
                    // Ragged tensors: the final row may be short.
                    let mut left = self.inner.min(len - done);
                    done += left;
                    while left > 0 {
                        let blen = left.min(BOX);
                        let eb = self.payload[pos];
                        pos += 1;
                        let lane_bytes = (bits as usize * blen).div_ceil(8);
                        let mut r = BitReader::new(&self.payload[pos..pos + lane_bytes]);
                        let step = if eb == 0 { 1.0 } else { step_of_exp_byte(eb, bits) };
                        for _ in 0..blen {
                            out.push(value_of(r.take(bits), step, bits));
                        }
                        r.align();
                        pos += lane_bytes;
                        left -= blen;
                    }
                }
            }
            FormatSpec::Float { exp_bits, man_bits, .. } => {
                let mut r = BitReader::new(&self.payload);
                for _ in 0..len {
                    out.push(float_value(r.take(bits), exp_bits, man_bits));
                }
            }
            // dsq-lint: allow(panic_hygiene, fp32 decoded via the is_passthrough fast path above)
            FormatSpec::Fp32 => unreachable!("fp32 is passthrough"),
        }
        out
    }

    /// Serialize the versioned record (header layout in the module docs).
    pub fn write_into(&self, w: &mut impl Write) -> Result<()> {
        w.write_all(&[PACKED_VERSION, codec_tag(&self.spec), width_byte(&self.spec), 0])?;
        w.write_all(&(self.inner as u32).to_le_bytes())?;
        w.write_all(&(self.shape.len() as u32).to_le_bytes())?;
        for &d in &self.shape {
            w.write_all(&(d as u64).to_le_bytes())?;
        }
        w.write_all(&(self.payload.len() as u64).to_le_bytes())?;
        w.write_all(&self.payload)?;
        Ok(())
    }

    /// Deserialize + validate a record written by [`Self::write_into`].
    pub fn read_from(r: &mut impl Read) -> Result<PackedTensor> {
        let mut head = [0u8; 4];
        r.read_exact(&mut head)?;
        let [version, tag, bits, flags] = head;
        if version != PACKED_VERSION {
            return Err(Error::Manifest(format!(
                "packed tensor version {version}, expected {PACKED_VERSION}"
            )));
        }
        if flags != 0 {
            return Err(Error::Manifest(format!("unknown packed-tensor flags {flags:#x}")));
        }
        let spec = spec_from_tag(tag, bits as u32)?;
        let mut b4 = [0u8; 4];
        r.read_exact(&mut b4)?;
        let inner = u32::from_le_bytes(b4) as usize;
        r.read_exact(&mut b4)?;
        let ndims = u32::from_le_bytes(b4) as usize;
        if ndims > 16 {
            return Err(Error::Manifest(format!("packed tensor rank {ndims} implausible")));
        }
        let mut shape = Vec::with_capacity(ndims);
        let mut b8 = [0u8; 8];
        for _ in 0..ndims {
            r.read_exact(&mut b8)?;
            shape.push(u64::from_le_bytes(b8) as usize);
        }
        let len: usize = shape.iter().product();
        if inner == 0 {
            return Err(Error::Manifest("packed tensor inner axis must be >= 1".into()));
        }
        r.read_exact(&mut b8)?;
        let plen = u64::from_le_bytes(b8) as usize;
        if plen != spec.packed_len(len, inner) {
            return Err(Error::Manifest(format!(
                "packed payload {plen} B, {spec} layout needs {} B for {len} elems",
                spec.packed_len(len, inner)
            )));
        }
        let mut payload = vec![0u8; plen];
        r.read_exact(&mut payload)?;
        Ok(PackedTensor { spec, shape, inner, payload })
    }
}

/// Family tag byte of the record header.
fn codec_tag(spec: &FormatSpec) -> u8 {
    match *spec {
        FormatSpec::Fp32 => 0,
        FormatSpec::Fixed { rounding: Rounding::Nearest, .. } => 1,
        FormatSpec::Fixed { rounding: Rounding::Stochastic, .. } => 2,
        FormatSpec::Bfp { .. } => 3,
        FormatSpec::Float { rounding: Rounding::Nearest, .. } => 4,
        FormatSpec::Float { rounding: Rounding::Stochastic, .. } => 5,
    }
}

/// Width byte of the record header: the plain bit width, except the
/// float tags, which need both grid parameters: `(exp_bits << 4) |
/// man_bits` (exp ≤ 8 and man ≤ 10 each fit a nibble).
fn width_byte(spec: &FormatSpec) -> u8 {
    match *spec {
        FormatSpec::Float { exp_bits, man_bits, .. } => ((exp_bits << 4) | man_bits) as u8,
        _ => spec.bits() as u8,
    }
}

fn spec_from_tag(tag: u8, bits: u32) -> Result<FormatSpec> {
    let bad = |msg: String| Error::Manifest(msg);
    let float_of = |rounding| {
        let (exp_bits, man_bits) = (bits >> 4, bits & 0xF);
        if !(FLOAT_EXP_RANGE.0..=FLOAT_EXP_RANGE.1).contains(&exp_bits)
            || !(FLOAT_MAN_RANGE.0..=FLOAT_MAN_RANGE.1).contains(&man_bits)
        {
            return Err(bad(format!("packed float widths e{exp_bits}m{man_bits} out of range")));
        }
        Ok(FormatSpec::Float { exp_bits, man_bits, rounding })
    };
    match tag {
        0 if bits == 32 => Ok(FormatSpec::Fp32),
        0 => Err(bad(format!("fp32 packed record with width {bits}"))),
        1 | 2 | 3 if !(2..=32).contains(&bits) => {
            Err(bad(format!("packed width {bits} out of [2,32]")))
        }
        1 => Ok(FormatSpec::Fixed { bits, rounding: Rounding::Nearest }),
        2 => Ok(FormatSpec::Fixed { bits, rounding: Rounding::Stochastic }),
        3 => Ok(FormatSpec::Bfp { bits }),
        4 => float_of(Rounding::Nearest),
        5 => float_of(Rounding::Stochastic),
        other => Err(bad(format!("unknown packed family tag {other}"))),
    }
}

/// Deterministic per-tensor SR stream id used by the state-stash layers
/// (checkpoints, coordinator): group index in the high word, tensor
/// index in the low, so every tensor of a model state decorrelates.
pub fn stash_stream(group: usize, index: usize) -> u64 {
    ((group as u64) << 32) | index as u64
}

/// `a == b` with NaN ≡ NaN (the codec canonicalizes NaN payloads, and
/// `quantize` propagates them — both are "the same quantized NaN").
/// `==` already identifies `-0.0` with `+0.0`, the codec's other
/// canonicalization.
pub fn same_f32(a: f32, b: f32) -> bool {
    a == b || (a.is_nan() && b.is_nan())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{registered_specs, FORMAT_REGISTRY};
    use crate::util::prop::{gen_f32s, Prop};
    use crate::util::rng::Pcg32;

    /// Round-trip check: decode(encode(x)) must be exactly quantize(x)
    /// under the same rounding stream.
    fn assert_roundtrip(spec: &FormatSpec, x: &[f32], shape: &[usize], inner: usize) {
        for (step, stream) in [(0u64, 0u64), (7, 3)] {
            let packed = spec.encode_stream(x, shape, inner, step, stream);
            assert_eq!(packed.packed_len(), spec.packed_len(x.len(), inner), "{spec}");
            let got = packed.decode();
            let mut want = x.to_vec();
            spec.quantize_into_stream(&mut want, inner, step, stream);
            assert_eq!(got.len(), want.len());
            for (i, (&g, &w)) in got.iter().zip(&want).enumerate() {
                assert!(
                    same_f32(g, w),
                    "{spec} (step {step}, stream {stream}): elem {i}: decoded {g}, quantized {w} (x={})",
                    x[i]
                );
            }
        }
    }

    #[test]
    fn roundtrip_known_fixed4() {
        // amax 4.0 -> e = 2, step = 1, mags [4, 1, -2, 0].
        let x = vec![4.0f32, 1.3, -2.5, 0.4];
        let p = FormatSpec::fixed(4).encode(&x, &[4], 4);
        assert_eq!(p.decode(), vec![4.0, 1.0, -2.0, 0.0]);
        assert_eq!(p.payload(), &[0x81, 0x14, 0x0E]);
    }

    #[test]
    fn serialized_header_golden_bytes() {
        // Pins the on-disk record header — PACKED_VERSION, family tag,
        // width byte, flags — so a header change is a deliberate edit
        // here, not a silent format break (`dsq lint` enforces that this
        // reference exists).
        let x = vec![4.0f32, 1.3, -2.5, 0.4];
        let p = FormatSpec::fixed(4).encode(&x, &[4], 4);
        let mut bytes = Vec::new();
        p.write_into(&mut bytes).unwrap();
        assert_eq!(PACKED_VERSION, 1);
        assert_eq!(&bytes[..4], &[1, 1, 4, 0], "version, fixed tag, width, flags");
        let back = PackedTensor::read_from(&mut bytes.as_slice()).unwrap();
        assert_eq!(back, p);
    }

    #[test]
    fn roundtrip_known_bfp4() {
        let mut x = vec![0.0f32; 16];
        x[..4].copy_from_slice(&[1.0, 0.3, -0.6, 0.125]);
        let p = FormatSpec::bfp(4).encode(&x, &[16], 16);
        let q = p.decode();
        assert_eq!(&q[..4], &[1.0, 0.25, -0.5, 0.0]);
        // exp byte 0x7F (e = 0), lanes [4, 1, -2, 0, 0, ...].
        assert_eq!(p.payload(), &[0x7F, 0x14, 0x0E, 0, 0, 0, 0, 0, 0]);
    }

    #[test]
    fn roundtrip_known_fp8() {
        // e4m3 lanes: sign | (e + 7) << 3 | frac; byte-per-element.
        let x = vec![1.0f32, -1.5, f32::NAN, 0.0];
        let p = FormatSpec::fp8e4m3().encode(&x, &[4], 4);
        assert_eq!(p.packed_len(), 4, "fp8 is one byte per element");
        assert_eq!(p.payload(), &[0x38, 0xBC, 0x7F, 0x00]);
        let d = p.decode();
        assert_eq!(d[0], 1.0);
        assert_eq!(d[1], -1.5);
        assert!(d[2].is_nan());
        assert_eq!(d[3], 0.0);
        // Saturation and subnormals round-trip too.
        let y = vec![1e9f32, -0.001, crate::quant::pow2(-9)];
        let p = FormatSpec::fp8e4m3().encode(&y, &[3], 3);
        let d = p.decode();
        assert_eq!(d[0], 240.0);
        assert_eq!(d[2], crate::quant::pow2(-9), "min subnormal uses exponent field 0");
    }

    #[test]
    fn roundtrip_float_formats_including_wide_exponent() {
        let mut rng = Pcg32::new(31);
        for spec in [
            FormatSpec::fp8e4m3(),
            FormatSpec::fp8e5m2(),
            FormatSpec::float_sr(4, 3),
            FormatSpec::float(5, 10), // fp16
            FormatSpec::float(8, 7),  // bf16 — exercises the FTZ-clamped flush grid
        ] {
            let mut x = gen_f32s(&mut rng, 3 * 21, 18.0);
            x[0] = f32::NAN;
            x[1] = f32::INFINITY;
            x[2] = -0.0;
            x[3] = crate::quant::pow2(-126) * 3.0; // deep in bf16's flush grid
            assert_roundtrip(&spec, &x, &[3, 21], 21);
        }
    }

    #[test]
    fn roundtrip_ragged_tensors() {
        // len % inner != 0: the trailing partial row packs as a short row.
        let mut rng = Pcg32::new(17);
        for spec in registered_specs(&[2, 3, 4, 8, 16, 24, 32]) {
            let x = gen_f32s(&mut rng, 2 * 24 + 10, 6.0);
            assert_roundtrip(&spec, &x, &[58], 24);
            let y = gen_f32s(&mut rng, 5, 4.0);
            assert_roundtrip(&spec, &y, &[5], 3);
        }
    }

    #[test]
    fn ragged_roundtrip_property() {
        Prop::new("ragged decode(encode(x)) == quantize(x)").cases(80).run(
            |rng, size| {
                let fam = &FORMAT_REGISTRY[rng.below(FORMAT_REGISTRY.len() as u32) as usize];
                let bits = rng.range(fam.min_bits, fam.max_bits + 1);
                let spec = fam.instantiate(bits).unwrap();
                let inner = 1 + rng.below(40) as usize;
                let rows = rng.below(3) as usize;
                let tail = rng.below(inner as u32) as usize;
                let x = gen_f32s(rng, rows * inner + tail, 4.0 + (size as f32) / 10.0);
                (spec, x, inner)
            },
            |(spec, x, inner)| {
                let shape = [x.len()];
                let packed = spec.encode(x, &shape, *inner);
                if packed.packed_len() != spec.packed_len(x.len(), *inner) {
                    return Err(format!(
                        "{spec}: payload {} != packed_len {}",
                        packed.packed_len(),
                        spec.packed_len(x.len(), *inner)
                    ));
                }
                let got = packed.decode();
                let want = spec.quantize(x, *inner);
                for (i, (&g, &w)) in got.iter().zip(&want).enumerate() {
                    if !same_f32(g, w) {
                        return Err(format!(
                            "{spec}: elem {i}: decoded {g}, quantized {w} (x={})",
                            x[i]
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn roundtrip_property_every_registered_format() {
        Prop::new("decode(encode(x)) == quantize(x) for every registered format")
            .cases(120)
            .run(
                |rng, size| {
                    let fam = &FORMAT_REGISTRY[rng.below(FORMAT_REGISTRY.len() as u32) as usize];
                    let bits = rng.range(fam.min_bits, fam.max_bits + 1);
                    let spec = fam.instantiate(bits).unwrap();
                    // Random rank-2 shape; inner is the minor axis, often
                    // not a multiple of the BFP box.
                    let rows = 1 + rng.below(3) as usize;
                    let inner = 1 + rng.below(3 * size + 40) as usize;
                    let mut x = gen_f32s(rng, rows * inner, 9.0);
                    // Sprinkle the special values the kernels must agree on.
                    for _ in 0..rng.below(4) {
                        let i = rng.below(x.len() as u32) as usize;
                        x[i] = *rng.choice(&[f32::NAN, f32::INFINITY, f32::NEG_INFINITY, 0.0, -0.0]);
                    }
                    (spec, x, rows, inner)
                },
                |(spec, x, rows, inner)| {
                    let shape = [*rows, *inner];
                    let packed = spec.encode(x, &shape, *inner);
                    let got = packed.decode();
                    let want = spec.quantize(x, *inner);
                    for (i, (&g, &w)) in got.iter().zip(&want).enumerate() {
                        if !same_f32(g, w) {
                            return Err(format!(
                                "{spec}: elem {i}: decoded {g}, quantized {w} (x={})",
                                x[i]
                            ));
                        }
                    }
                    Ok(())
                },
            );
    }

    #[test]
    fn roundtrip_empty_scalar_and_trailing_lanes() {
        for spec in registered_specs(&[2, 3, 4, 8, 16, 24, 32]) {
            // Empty tensor (shape with a zero dim).
            assert_roundtrip(&spec, &[], &[0, 5], 5);
            assert_roundtrip(&spec, &[], &[0], 1);
            // Scalar.
            assert_roundtrip(&spec, &[2.75], &[], 1);
            // Minor axis not a multiple of the box (short trailing box),
            // and lane counts not a multiple of 8 bits.
            let mut rng = Pcg32::new(42);
            let x = gen_f32s(&mut rng, 3 * 21, 6.0);
            assert_roundtrip(&spec, &x, &[3, 21], 21);
            let y = gen_f32s(&mut rng, 7, 4.0);
            assert_roundtrip(&spec, &y, &[7], 7);
        }
    }

    #[test]
    fn roundtrip_nan_inf_and_zero_tensors() {
        for spec in registered_specs(&[2, 4, 8, 16, 32]) {
            let x = vec![
                f32::NAN,
                f32::INFINITY,
                f32::NEG_INFINITY,
                0.0,
                -0.0,
                1.5,
                -3.25,
                f32::MIN_POSITIVE / 2.0,
            ];
            assert_roundtrip(&spec, &x, &[8], 8);
            // All-zero and all-NaN tensors (when the FTZ'd |max| is zero
            // the quantizers zero-fill everything except NaN, which
            // propagates — and must therefore survive the codec too).
            assert_roundtrip(&spec, &[0.0; 20], &[20], 20);
            assert_roundtrip(&spec, &[f32::NAN; 20], &[20], 20);
            // Extreme magnitudes: near f32::MAX the grid clamps, near the
            // subnormal range FTZ zeroes.
            assert_roundtrip(&spec, &[f32::MAX, -f32::MAX, 1e-38, -1e-44], &[4], 4);
        }
    }

    #[test]
    fn negative_zero_canonicalizes_to_positive() {
        // Pinned behavior: the integer lane has one zero, so a quantized
        // -0.0 (which the kernels preserve) decodes as +0.0. Equal under
        // ==, different bit pattern — documented in the module docs.
        let x = vec![-0.0f32, -0.1, 8.0];
        let q = FormatSpec::fixed(4).quantize(&x, 3);
        assert!(q[0].is_sign_negative(), "kernel keeps -0.0");
        let d = FormatSpec::fixed(4).encode(&x, &[3], 3).decode();
        assert_eq!(d, q, "== equality must hold");
        assert!(!d[0].is_sign_negative(), "codec canonicalizes the zero sign");
    }

    #[test]
    fn sr_payload_follows_the_stream() {
        let mut rng = Pcg32::new(3);
        let x = gen_f32s(&mut rng, 64, 5.0);
        let sr = FormatSpec::fixed_sr(5);
        let a = sr.encode_stream(&x, &[64], 64, 1, 0);
        let b = sr.encode_stream(&x, &[64], 64, 1, 0);
        assert_eq!(a, b, "same (step, stream) must pack bit-identically");
        let c = sr.encode_stream(&x, &[64], 64, 2, 0);
        assert_ne!(a.payload(), c.payload(), "different steps must repack differently");
    }

    #[test]
    fn salted_encode_matches_unsalted_at_salt_zero_and_decorrelates_ranks() {
        let mut rng = Pcg32::new(9);
        let x = gen_f32s(&mut rng, 64, 5.0);
        for spec in registered_specs(&[4u32, 8]) {
            let base = spec.encode_stream(&x, &[64], 64, 7, 3);
            let rank0 = spec.encode_stream_salted(&x, &[64], 64, 7, 3, 0);
            assert_eq!(base, rank0, "{spec}: salt 0 must reproduce the unsalted wire bytes");
            let rank1 = spec.encode_stream_salted(&x, &[64], 64, 7, 3, 1);
            if spec.is_stochastic() {
                assert_ne!(
                    rank0.payload(),
                    rank1.payload(),
                    "{spec}: ranks must pack decorrelated SR payloads"
                );
            } else {
                assert_eq!(rank0, rank1, "{spec}: deterministic formats ignore the salt");
            }
            // Decoded salted payloads are still exactly the salted quantize.
            let mut want = x.clone();
            spec.quantize_into_stream_salted(&mut want, 64, 7, 3, 1);
            let got = rank1.decode();
            for (i, (&g, &w)) in got.iter().zip(&want).enumerate() {
                assert!(same_f32(g, w), "{spec} elem {i}: decoded {g}, quantized {w}");
            }
        }
    }

    #[test]
    fn encode_is_stable_on_quantized_input() {
        // encode(quantize(x)) == encode(x): repacking an already-packed
        // tensor cannot drift (checkpoint save-load-save bit-identity).
        Prop::new("encode is idempotent through quantize").cases(60).run(
            |rng, size| {
                let spec = *rng.choice(&[
                    FormatSpec::bfp(4),
                    FormatSpec::bfp(7),
                    FormatSpec::fixed(3),
                    FormatSpec::fixed(8),
                    FormatSpec::fixed_sr(6),
                ]);
                (spec, gen_f32s(rng, 16 * (1 + size as usize / 20), 8.0))
            },
            |(spec, x)| {
                let inner = x.len();
                let once = spec.encode(x, &[inner], inner);
                let again = spec.encode(&once.decode(), &[inner], inner);
                if once == again {
                    Ok(())
                } else {
                    Err("re-encoding the decoded tensor changed the payload".into())
                }
            },
        );
    }

    #[test]
    fn zeros_matches_encoded_zero_tensor() {
        for spec in registered_specs(&[2, 4, 8, 16, 32]) {
            let z = PackedTensor::zeros(spec, &[3, 21], 21);
            let e = spec.encode(&[0.0; 63], &[3, 21], 21);
            assert_eq!(z, e, "{spec}: zeros() must equal encode(0s) bit-for-bit");
            assert!(z.decode().iter().all(|&v| v == 0.0));
        }
    }

    #[test]
    fn serialized_record_roundtrips() {
        let mut rng = Pcg32::new(9);
        for spec in registered_specs(&[2, 4, 8, 16, 32]) {
            let x = gen_f32s(&mut rng, 2 * 37, 6.0);
            let p = spec.encode(&x, &[2, 37], 37);
            let mut buf = Vec::new();
            p.write_into(&mut buf).unwrap();
            assert_eq!(buf.len(), p.record_len(), "{spec}");
            let back = PackedTensor::read_from(&mut buf.as_slice()).unwrap();
            assert_eq!(p, back, "{spec}");
        }
    }

    #[test]
    fn serialized_record_golden_bytes() {
        // Pins the header layout: version 1, tag, bits, flags, inner,
        // dims, payload length, payload. Any byte change here is an
        // on-disk format break and needs a version bump.
        let x = vec![4.0f32, 1.3, -2.5, 0.4];
        let p = FormatSpec::fixed(4).encode(&x, &[2, 2], 2);
        let mut buf = Vec::new();
        p.write_into(&mut buf).unwrap();
        assert_eq!(
            buf,
            vec![
                1, 1, 4, 0, // version, fixed tag, 4 bits, flags
                2, 0, 0, 0, // inner = 2
                2, 0, 0, 0, // ndims = 2
                2, 0, 0, 0, 0, 0, 0, 0, // dim 0
                2, 0, 0, 0, 0, 0, 0, 0, // dim 1
                3, 0, 0, 0, 0, 0, 0, 0, // payload length
                0x81, 0x14, 0x0E, // e=2 biased, lanes [4, 1], [-2, 0]
            ]
        );
        // And the SR/bfp/fp32 family tags are pinned too.
        let tag = |spec: FormatSpec| {
            let mut b = Vec::new();
            spec.encode(&[1.0], &[1], 1).write_into(&mut b).unwrap();
            (b[1], b[2])
        };
        assert_eq!(tag(FormatSpec::Fp32), (0, 32));
        assert_eq!(tag(FormatSpec::fixed(7)), (1, 7));
        assert_eq!(tag(FormatSpec::fixed_sr(7)), (2, 7));
        assert_eq!(tag(FormatSpec::bfp(7)), (3, 7));
        // Float tags carry (exp << 4) | man in the width byte.
        assert_eq!(tag(FormatSpec::fp8e4m3()), (4, 0x43));
        assert_eq!(tag(FormatSpec::fp8e5m2()), (4, 0x52));
        assert_eq!(tag(FormatSpec::float_sr(4, 3)), (5, 0x43));
        assert_eq!(tag(FormatSpec::float(5, 10)), (4, 0x5A));
    }

    #[test]
    fn read_rejects_bad_float_widths() {
        let p = FormatSpec::fp8e4m3().encode(&[1.0; 4], &[4], 4);
        let mut buf = Vec::new();
        p.write_into(&mut buf).unwrap();
        let mut bad = buf.clone();
        bad[2] = 0x10; // e1m0: both widths out of range
        assert!(PackedTensor::read_from(&mut bad.as_slice()).is_err());
        let mut bad = buf.clone();
        bad[2] = 0x9F; // e9m15
        assert!(PackedTensor::read_from(&mut bad.as_slice()).is_err());
        let back = PackedTensor::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(back, p);
    }

    #[test]
    fn all_nan_tensor_roundtrips_through_the_zero_grid() {
        // The degenerate grid (exp byte 0) still carries NaN sentinels:
        // quantize keeps NaN, so decode must too.
        for spec in [FormatSpec::fixed(4), FormatSpec::fixed_sr(6), FormatSpec::bfp(4)] {
            let x = vec![f32::NAN; 20];
            let p = spec.encode(&x, &[20], 20);
            let d = p.decode();
            assert!(d.iter().all(|v| v.is_nan()), "{spec}: {d:?}");
            // Mixed NaN/zero in a zero-amax tensor.
            let y = vec![f32::NAN, 0.0, -0.0, f32::NAN];
            let d = spec.encode(&y, &[4], 4).decode();
            assert!(d[0].is_nan() && d[3].is_nan());
            assert_eq!(d[1], 0.0);
            assert_eq!(d[2], 0.0);
        }
    }

    #[test]
    fn read_rejects_corrupt_records() {
        let p = FormatSpec::bfp(4).encode(&[1.0; 16], &[16], 16);
        let mut good = Vec::new();
        p.write_into(&mut good).unwrap();

        let mut wrong_version = good.clone();
        wrong_version[0] = 9;
        assert!(PackedTensor::read_from(&mut wrong_version.as_slice()).is_err());

        let mut wrong_tag = good.clone();
        wrong_tag[1] = 7;
        assert!(PackedTensor::read_from(&mut wrong_tag.as_slice()).is_err());

        let mut wrong_bits = good.clone();
        wrong_bits[2] = 1;
        assert!(PackedTensor::read_from(&mut wrong_bits.as_slice()).is_err());

        let mut wrong_len = good.clone();
        wrong_len[24] = 99; // payload-length field
        assert!(PackedTensor::read_from(&mut wrong_len.as_slice()).is_err());

        let mut truncated = good.clone();
        truncated.truncate(good.len() - 2);
        assert!(PackedTensor::read_from(&mut truncated.as_slice()).is_err());

        assert!(PackedTensor::read_from(&mut &b"garbage"[..]).is_err());
    }

    #[test]
    fn passthrough_widths_store_the_raw_container() {
        // Widths >= 25 quantize as identity; the payload must be the raw
        // f32 container or arbitrary values could not round-trip.
        let x = vec![1.5f32, -2e10, 3e-20, f32::NAN];
        for spec in [FormatSpec::fixed(25), FormatSpec::fixed(30), FormatSpec::bfp(32)] {
            let p = spec.encode(&x, &[4], 4);
            assert_eq!(p.packed_len(), 16, "{spec}");
            let q = p.decode();
            assert_eq!(&q[..3], &x[..3]);
            assert!(q[3].is_nan());
        }
    }

    #[test]
    fn packed_len_is_sub_byte_for_low_widths() {
        // The headline claim made physical: a bfp4 stash of 1600 elems
        // is 4.5 bits/elem, not 32.
        let spec = FormatSpec::bfp(4);
        let len = 1600;
        assert_eq!(spec.packed_len(len, len), (len / 16) * 9);
        let bits_per_elem = spec.packed_len(len, len) as f64 * 8.0 / len as f64;
        assert!(bits_per_elem < 4.6, "bfp4 stores {bits_per_elem} bits/elem");
        assert_eq!(FormatSpec::fixed(2).packed_len(1000, 1000), 1 + 250);
    }
}
