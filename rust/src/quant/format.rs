//! `FormatSpec` — the single descriptor every layer of the system
//! consumes for "which number format does this dataflow slot use".
//!
//! One `FormatSpec` value answers every question the stack asks about a
//! format:
//!
//! * **how to quantize** — [`FormatSpec::quantize_into`] dispatches to
//!   the rust mirror kernels (BFP / fixed / stochastic-rounding fixed);
//! * **what it costs** — [`FormatSpec::storage_bits`] and
//!   [`FormatSpec::mac_cost`] (implemented in [`crate::costmodel::formats`],
//!   next to the calibrated constants) feed the tables and the roofline;
//! * **how the artifact sees it** — [`FormatSpec::mode_scalar`] +
//!   [`FormatSpec::bits`] form the `(mode, bits)` pair of one qcfg slot
//!   ([`FormatSpec::slot_qcfg`]);
//! * **how it is spelled** — [`FormatSpec::spec_string`] /
//!   [`FormatSpec::parse`] round-trip the canonical spec strings
//!   (`"bfp4"`, `"fixed16"`, `"fixed8sr"`, `"fp32"`, `"e4m3"`).
//!
//! Formats are registered in [`FORMAT_REGISTRY`]: a [`FormatFamily`] per
//! spelling (keyword + optional rounding suffix) with its legal width
//! range and constructor. The parser, the CLI `--schedule` grammar, and
//! the benches all enumerate the registry, so adding a format is one
//! registry entry + one quantizer arm — no per-layer string matching.
//!
//! The float family ([`FormatSpec::Float`], kernel in
//! [`crate::quant::float`]) registers its two FP8 members (`fp8e4m3`,
//! `fp8e5m2`) as rows and additionally accepts the generic
//! `e<E>m<M>[sr]` spelling, so bf16 (`e8m7`), fp16 (`e5m10`) and
//! stochastic-rounding variants fall out of the same grammar with no
//! extra rows.
//!
//! # Adding a format
//!
//! Each item below is enforced by `dsq lint` (`registry_coverage` /
//! `qcfg_sync` in [`crate::analysis`]) — skipping one is a build
//! failure, not a latent bug:
//!
//! 1. a [`FORMAT_REGISTRY`] row ([`FormatFamily`]: keyword, suffix,
//!    width range, constructor, help);
//! 2. a quantizer arm in [`FormatSpec::quantize_into_stream`];
//! 3. codec arms in `quant/packed.rs`: `codec_tag` (a fresh tag
//!    number), `width_byte` if the width encoding is non-trivial, and
//!    the inverse `spec_from_tag` arm for that tag;
//! 4. cost-model arms in `costmodel/formats.rs`: `storage_bits` and
//!    `mac_cost`;
//! 5. if the family introduces a new `mode_scalar` value: the matching
//!    `MODE_*` constant in `python/compile/layers.py`, dispatch in its
//!    helpers, and (for a new compiled variant) `_VARIANTS` +
//!    `aot.py` exports + `runtime/artifact.rs` routing;
//! 6. nothing for the benches or `dsq formats` — both enumerate the
//!    registry, and the lint checks they still do.

use crate::util::rng::Pcg32;
use crate::{Error, Result};

use super::float::{
    float_quantize_into, float_quantize_sr_into, FLOAT_EXP_RANGE, FLOAT_MAN_RANGE,
};
use super::{bfp_quantize_into, fixed_quantize_into, fixed_quantize_sr_into};

/// Rounding rule a format applies when it snaps a value to its grid.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Rounding {
    /// Round-half-to-even (the XLA artifacts' `round_nearest_even`).
    Nearest,
    /// Unbiased stochastic rounding: round up with probability equal to
    /// the fractional distance, so `E[q(x)] = x` for unclamped values
    /// (Zhao et al. 2024 show this stabilizes very-low-bit training).
    /// The rounding stream is derived deterministically from the step
    /// index ([`FormatSpec::quantize_into_step`]).
    Stochastic,
}

/// A concrete number format for one tensor/operand slot.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FormatSpec {
    /// IEEE-754 binary32 (identity quantizer, real 32-bit hardware path).
    Fp32,
    /// Dynamic per-tensor fixed point with `bits` total width.
    Fixed { bits: u32, rounding: Rounding },
    /// Block floating point with `bits` mantissa width (box 16, 8-bit
    /// shared exponent — MSFP).
    Bfp { bits: u32 },
    /// Low-bit float with a per-element exponent (`e<E>m<M>`): FP8
    /// E4M3/E5M2, bf16 (`e8m7`), fp16 (`e5m10`), … Total width is
    /// `1 + exp_bits + man_bits`. IEEE-style grid with subnormal support
    /// and saturating overflow — see [`crate::quant::float`].
    Float { exp_bits: u32, man_bits: u32, rounding: Rounding },
}

/// Salt for the stochastic-rounding stream; mixed with the step index so
/// a given (format, step) re-quantizes bit-identically.
const SR_STREAM_SALT: u64 = 0x5EED_0F0D_D5A0_0001;

impl FormatSpec {
    /// Shorthand constructors for statically-known widths (panic on an
    /// out-of-range width; use [`FormatSpec::parse`] for untrusted input).
    pub fn fixed(bits: u32) -> FormatSpec {
        assert!((2..=32).contains(&bits), "fixed width {bits} out of [2,32]");
        FormatSpec::Fixed { bits, rounding: Rounding::Nearest }
    }

    pub fn fixed_sr(bits: u32) -> FormatSpec {
        assert!((2..=32).contains(&bits), "fixedsr width {bits} out of [2,32]");
        FormatSpec::Fixed { bits, rounding: Rounding::Stochastic }
    }

    pub fn bfp(bits: u32) -> FormatSpec {
        assert!((2..=32).contains(&bits), "bfp width {bits} out of [2,32]");
        FormatSpec::Bfp { bits }
    }

    pub fn float(exp_bits: u32, man_bits: u32) -> FormatSpec {
        Self::float_checked(exp_bits, man_bits, Rounding::Nearest).unwrap()
    }

    pub fn float_sr(exp_bits: u32, man_bits: u32) -> FormatSpec {
        Self::float_checked(exp_bits, man_bits, Rounding::Stochastic).unwrap()
    }

    /// FP8 E4M3 (range-light forward/stash tensors).
    pub fn fp8e4m3() -> FormatSpec {
        Self::float(4, 3)
    }

    /// FP8 E5M2 (the wide-range gradient format).
    pub fn fp8e5m2() -> FormatSpec {
        Self::float(5, 2)
    }

    /// Range-checked float constructor (the parser's entry point).
    pub fn float_checked(exp_bits: u32, man_bits: u32, rounding: Rounding) -> Result<FormatSpec> {
        if !(FLOAT_EXP_RANGE.0..=FLOAT_EXP_RANGE.1).contains(&exp_bits) {
            return Err(Error::Config(format!(
                "float exponent width {exp_bits} out of [{},{}]",
                FLOAT_EXP_RANGE.0, FLOAT_EXP_RANGE.1
            )));
        }
        if !(FLOAT_MAN_RANGE.0..=FLOAT_MAN_RANGE.1).contains(&man_bits) {
            return Err(Error::Config(format!(
                "float mantissa width {man_bits} out of [{},{}]",
                FLOAT_MAN_RANGE.0, FLOAT_MAN_RANGE.1
            )));
        }
        Ok(FormatSpec::Float { exp_bits, man_bits, rounding })
    }

    /// Total/mantissa width in bits (32 for fp32; `1 + E + M` for the
    /// float family).
    pub fn bits(&self) -> u32 {
        match *self {
            FormatSpec::Fp32 => 32,
            FormatSpec::Fixed { bits, .. } | FormatSpec::Bfp { bits } => bits,
            FormatSpec::Float { exp_bits, man_bits, .. } => 1 + exp_bits + man_bits,
        }
    }

    /// Same family, different width (fp32 and the float formats have no
    /// single width knob — a float format *is* its `(E, M)` pair — and
    /// are returned unchanged). Used to instantiate ladders and the
    /// `[16,4,4,16]` stashing pattern for the width-parameterized
    /// families.
    pub fn with_bits(&self, bits: u32) -> FormatSpec {
        match *self {
            FormatSpec::Fp32 => FormatSpec::Fp32,
            FormatSpec::Float { .. } => *self,
            FormatSpec::Fixed { rounding, .. } => {
                assert!((2..=32).contains(&bits), "fixed width {bits} out of [2,32]");
                FormatSpec::Fixed { bits, rounding }
            }
            FormatSpec::Bfp { .. } => {
                assert!((2..=32).contains(&bits), "bfp width {bits} out of [2,32]");
                FormatSpec::Bfp { bits }
            }
        }
    }

    /// The artifact runtime's mode selector for this format
    /// (`python/compile/layers.py::quantize`): 0 = fp32 identity,
    /// 1 = fixed nearest, 2 = BFP, 3 = fixed stochastic, 4 = float
    /// nearest, 5 = float stochastic. The stochastic modes (3, 5) apply
    /// their family's grid with nearest rounding inside the artifact —
    /// the stochastic stream runs host-side in the mirrors (see the
    /// `quant` module docs).
    pub fn mode_scalar(&self) -> f32 {
        match *self {
            FormatSpec::Fp32 => 0.0,
            FormatSpec::Fixed { rounding: Rounding::Nearest, .. } => 1.0,
            FormatSpec::Bfp { .. } => 2.0,
            FormatSpec::Fixed { rounding: Rounding::Stochastic, .. } => 3.0,
            FormatSpec::Float { rounding: Rounding::Nearest, .. } => 4.0,
            FormatSpec::Float { rounding: Rounding::Stochastic, .. } => 5.0,
        }
    }

    /// The width field of one qcfg slot: the plain bit width for the
    /// integer families, and `100·E + M` for float formats (two grid
    /// parameters in one runtime scalar — decoded by
    /// `python/compile/kernels/ref.py::float_quantize_ref`).
    pub fn qcfg_bits(&self) -> f32 {
        match *self {
            FormatSpec::Float { exp_bits, man_bits, .. } => (100 * exp_bits + man_bits) as f32,
            _ => self.bits() as f32,
        }
    }

    /// One qcfg slot: `[mode, bits]` (the runtime precision vector is
    /// four of these concatenated — [`crate::schedule::PrecisionConfig::as_qcfg`]).
    pub fn slot_qcfg(&self) -> [f32; 2] {
        [self.mode_scalar(), self.qcfg_bits()]
    }

    /// Registry family this spec belongs to — the spelling without the
    /// width digits ("fp", "fixed", "fixedsr", "bfp"). Float formats
    /// have no width knob, so each `(E, M, rounding)` is its own family
    /// ("e4m3", "e5m2sr", …).
    pub fn family_name(&self) -> String {
        match *self {
            FormatSpec::Fp32 => "fp".to_string(),
            FormatSpec::Fixed { rounding: Rounding::Nearest, .. } => "fixed".to_string(),
            FormatSpec::Fixed { rounding: Rounding::Stochastic, .. } => "fixedsr".to_string(),
            FormatSpec::Bfp { .. } => "bfp".to_string(),
            FormatSpec::Float { .. } => self.spec_string(),
        }
    }

    /// Canonical spec string: `"fp32"`, `"fixed16"`, `"fixed8sr"`,
    /// `"bfp4"`, `"e4m3"`, `"e5m2sr"`. Round-trips through
    /// [`FormatSpec::parse`] (the registry spellings `fp8e4m3` /
    /// `fp8e5m2` parse to the same specs the generic `e<E>m<M>` form
    /// canonicalizes to).
    pub fn spec_string(&self) -> String {
        match *self {
            FormatSpec::Fp32 => "fp32".to_string(),
            FormatSpec::Fixed { bits, rounding: Rounding::Nearest } => format!("fixed{bits}"),
            FormatSpec::Fixed { bits, rounding: Rounding::Stochastic } => format!("fixed{bits}sr"),
            FormatSpec::Bfp { bits } => format!("bfp{bits}"),
            FormatSpec::Float { exp_bits, man_bits, rounding } => {
                let sr = if rounding == Rounding::Stochastic { "sr" } else { "" };
                format!("e{exp_bits}m{man_bits}{sr}")
            }
        }
    }

    /// Parse a spec string. Grammar:
    ///
    /// * registry spellings `<keyword><width><suffix?>` — `"bfp4"`,
    ///   `"fixed16"`, `"fixed8sr"`, `"fp32"`, `"fp8e4m3"`;
    /// * the generic float spelling `e<E>m<M>[sr]` — `"e4m3"`,
    ///   `"e5m10"` (fp16), `"e8m7"` (bf16), `"e4m3sr"`.
    ///
    /// Case-insensitive; malformed or out-of-range specs are
    /// [`Error::Config`].
    pub fn parse(s: &str) -> Result<FormatSpec> {
        let t = s.trim().to_ascii_lowercase();
        if let Some(parsed) = parse_float_spec(&t) {
            return parsed;
        }
        let keyword_end = t.find(|c: char| c.is_ascii_digit()).unwrap_or(t.len());
        let (keyword, rest) = t.split_at(keyword_end);
        let digits_end = rest.find(|c: char| !c.is_ascii_digit()).unwrap_or(rest.len());
        let (digits, suffix) = rest.split_at(digits_end);
        let family = lookup(keyword, suffix).ok_or_else(|| {
            Error::Config(format!("unknown format '{s}' (registered: {})", registered_summary()))
        })?;
        if digits.is_empty() {
            return Err(Error::Config(format!("format '{s}' is missing a bit width")));
        }
        let bits: u32 = digits
            .parse()
            .map_err(|_| Error::Config(format!("bad bit width in format '{s}'")))?;
        family.instantiate(bits)
    }

    /// Quantize `x` in place; `inner` is the minor-axis length (used by
    /// box-based formats; per-tensor formats ignore it). Stochastic
    /// formats use the step-0 rounding stream — see
    /// [`FormatSpec::quantize_into_step`] for per-step determinism.
    pub fn quantize_into(&self, x: &mut [f32], inner: usize) {
        self.quantize_into_step(x, inner, 0);
    }

    /// [`FormatSpec::quantize_into`] with an explicit step index:
    /// stochastic formats seed their rounding stream from the step via
    /// [`Pcg32`], so re-running a training step reproduces the
    /// identical quantization. All tensors quantized at the same
    /// `(step, width)` share one stream — callers quantizing several
    /// tensors per step (e.g. the four dataflow slots) should use
    /// [`FormatSpec::quantize_into_stream`] with a distinct `stream`
    /// per tensor, or their rounding errors are perfectly correlated.
    pub fn quantize_into_step(&self, x: &mut [f32], inner: usize, step: u64) {
        self.quantize_into_stream(x, inner, step, 0);
    }

    /// Like [`FormatSpec::quantize_into_step`], with `stream`
    /// discriminating independent tensors within one step (slot index,
    /// layer id, …) so each gets a decorrelated rounding stream while
    /// staying deterministic in `(step, stream)`.
    pub fn quantize_into_stream(&self, x: &mut [f32], inner: usize, step: u64, stream: u64) {
        self.quantize_into_stream_salted(x, inner, step, stream, 0);
    }

    /// Like [`FormatSpec::quantize_into_stream`], with an additional
    /// caller identity `salt` folded into the SR seed. This is the
    /// replica seeding contract for data-parallel exchange: seeding on
    /// `(step, stream)` alone gives every replica the *same* rounding
    /// stream at a given step — perfectly correlated noise that biases
    /// the all-reduce mean instead of averaging out. Passing the replica
    /// rank as `salt` decorrelates the replicas; `salt == 0` reproduces
    /// the unsalted stream bit-for-bit (pinned by a regression test), so
    /// single-replica paths and rank 0 are unchanged.
    pub fn quantize_into_stream_salted(
        &self,
        x: &mut [f32],
        inner: usize,
        step: u64,
        stream: u64,
        salt: u64,
    ) {
        let sr_rng = |width_salt: u64| {
            Pcg32::new(
                SR_STREAM_SALT
                    ^ step.wrapping_mul(0x9E37_79B9_7F4A_7C15)
                    ^ stream.wrapping_mul(0xD1B5_4A32_D192_ED03)
                    ^ salt.wrapping_mul(0xA076_1D64_78BD_642F)
                    ^ width_salt,
            )
        };
        match *self {
            FormatSpec::Fp32 => {}
            FormatSpec::Bfp { bits } => bfp_quantize_into(x, inner, bits as f32),
            FormatSpec::Fixed { bits, rounding: Rounding::Nearest } => {
                fixed_quantize_into(x, bits as f32)
            }
            FormatSpec::Fixed { bits, rounding: Rounding::Stochastic } => {
                fixed_quantize_sr_into(x, bits as f32, &mut sr_rng(bits as u64))
            }
            FormatSpec::Float { exp_bits, man_bits, rounding: Rounding::Nearest } => {
                float_quantize_into(x, exp_bits, man_bits)
            }
            FormatSpec::Float { exp_bits, man_bits, rounding: Rounding::Stochastic } => {
                let salt = (100 * exp_bits + man_bits) as u64;
                float_quantize_sr_into(x, exp_bits, man_bits, &mut sr_rng(salt))
            }
        }
    }

    /// Out-of-place convenience over [`FormatSpec::quantize_into`].
    pub fn quantize(&self, x: &[f32], inner: usize) -> Vec<f32> {
        let mut out = x.to_vec();
        self.quantize_into(&mut out, inner);
        out
    }
}

impl std::fmt::Display for FormatSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.spec_string())
    }
}

/// One registered format family: a spelling (`keyword` + `suffix`), its
/// legal width range, and the constructor the parser calls.
pub struct FormatFamily {
    /// Leading keyword of the spec string ("fp", "fixed", "bfp").
    pub keyword: &'static str,
    /// Suffix after the width ("" or a rounding tag like "sr").
    pub suffix: &'static str,
    /// Inclusive legal width range.
    pub min_bits: u32,
    pub max_bits: u32,
    /// Constructor at a (range-checked) width.
    pub make: fn(u32) -> FormatSpec,
    /// One-line description for help text and docs.
    pub help: &'static str,
}

impl FormatFamily {
    /// Family spelling without the width: `"fixedsr"`, `"bfp"`, …
    pub fn name(&self) -> String {
        format!("{}{}", self.keyword, self.suffix)
    }

    /// Grammar spelling with the width range: `"fixed<2-32>sr"`,
    /// `"fp32"`, … (used by `dsq formats` and parser errors).
    pub fn spelling(&self) -> String {
        if self.min_bits == self.max_bits {
            format!("{}{}{}", self.keyword, self.min_bits, self.suffix)
        } else {
            format!("{}<{}-{}>{}", self.keyword, self.min_bits, self.max_bits, self.suffix)
        }
    }

    /// Range-check `bits` and construct the spec.
    pub fn instantiate(&self, bits: u32) -> Result<FormatSpec> {
        if !(self.min_bits..=self.max_bits).contains(&bits) {
            return Err(Error::Config(format!(
                "width {bits} out of range [{},{}] for format family '{}'",
                self.min_bits,
                self.max_bits,
                self.name()
            )));
        }
        Ok((self.make)(bits))
    }
}

fn make_fp32(_bits: u32) -> FormatSpec {
    FormatSpec::Fp32
}

fn make_fixed(bits: u32) -> FormatSpec {
    FormatSpec::Fixed { bits, rounding: Rounding::Nearest }
}

fn make_fixed_sr(bits: u32) -> FormatSpec {
    FormatSpec::Fixed { bits, rounding: Rounding::Stochastic }
}

fn make_bfp(bits: u32) -> FormatSpec {
    FormatSpec::Bfp { bits }
}

fn make_fp8e4m3(_bits: u32) -> FormatSpec {
    FormatSpec::Float { exp_bits: 4, man_bits: 3, rounding: Rounding::Nearest }
}

fn make_fp8e5m2(_bits: u32) -> FormatSpec {
    FormatSpec::Float { exp_bits: 5, man_bits: 2, rounding: Rounding::Nearest }
}

/// Parse the generic float spelling `e<E>m<M>[sr]`. Returns `None` when
/// `t` does not have that shape at all (so the registry grammar gets its
/// turn), and `Some(Err(..))` when it does but the widths are out of
/// range or the suffix is unknown.
fn parse_float_spec(t: &str) -> Option<Result<FormatSpec>> {
    let rest = t.strip_prefix('e')?;
    let mpos = rest.find('m')?;
    let (e_digits, m_and_rest) = rest.split_at(mpos);
    let m_rest = &m_and_rest[1..];
    let m_end = m_rest.find(|c: char| !c.is_ascii_digit()).unwrap_or(m_rest.len());
    let (m_digits, suffix) = m_rest.split_at(m_end);
    if e_digits.is_empty()
        || m_digits.is_empty()
        || !e_digits.chars().all(|c| c.is_ascii_digit())
    {
        return None;
    }
    let rounding = match suffix {
        "" => Rounding::Nearest,
        "sr" => Rounding::Stochastic,
        _ => {
            return Some(Err(Error::Config(format!(
                "bad float format suffix '{suffix}' in '{t}' (grammar: e<E>m<M>[sr])"
            ))))
        }
    };
    let exp_bits: u32 = match e_digits.parse() {
        Ok(v) => v,
        Err(_) => return Some(Err(Error::Config(format!("bad exponent width in '{t}'")))),
    };
    let man_bits: u32 = match m_digits.parse() {
        Ok(v) => v,
        Err(_) => return Some(Err(Error::Config(format!("bad mantissa width in '{t}'")))),
    };
    Some(FormatSpec::float_checked(exp_bits, man_bits, rounding))
}

/// Every format the system knows. The parser, the `--schedule` grammar,
/// the hot-path bench sweep, and the docs all read this table.
pub const FORMAT_REGISTRY: &[FormatFamily] = &[
    FormatFamily {
        keyword: "fp",
        suffix: "",
        min_bits: 32,
        max_bits: 32,
        make: make_fp32,
        help: "IEEE-754 binary32 (identity; unscored in the paper's tables)",
    },
    FormatFamily {
        keyword: "fixed",
        suffix: "",
        min_bits: 2,
        max_bits: 32,
        make: make_fixed,
        help: "dynamic per-tensor fixed point, round-half-to-even",
    },
    FormatFamily {
        keyword: "fixed",
        suffix: "sr",
        min_bits: 2,
        max_bits: 32,
        make: make_fixed_sr,
        help: "per-tensor fixed point with unbiased stochastic rounding",
    },
    FormatFamily {
        keyword: "bfp",
        suffix: "",
        min_bits: 2,
        max_bits: 32,
        make: make_bfp,
        help: "block floating point (MSFP: box 16, 8-bit shared exponent)",
    },
    FormatFamily {
        keyword: "fp",
        suffix: "e4m3",
        min_bits: 8,
        max_bits: 8,
        make: make_fp8e4m3,
        help: "FP8 E4M3 (per-element exponent; forward/stash slots a la FP8-LM)",
    },
    FormatFamily {
        keyword: "fp",
        suffix: "e5m2",
        min_bits: 8,
        max_bits: 8,
        make: make_fp8e5m2,
        help: "FP8 E5M2 (wide-range FP8; the float-form gradient format)",
    },
];

/// Look up a family by `(keyword, suffix)` pair.
fn lookup(keyword: &str, suffix: &str) -> Option<&'static FormatFamily> {
    FORMAT_REGISTRY.iter().find(|f| f.keyword == keyword && f.suffix == suffix)
}

/// Look up a family by its full name ("fixedsr", "bfp", …) — the form
/// used by `--schedule <family>:<b0,b1,b2,b3>` and `dsq-<family>`.
pub fn family(name: &str) -> Option<&'static FormatFamily> {
    let n = name.trim().to_ascii_lowercase();
    FORMAT_REGISTRY.iter().find(|f| f.name() == n)
}

/// `"fp32 | fixed<2-32> | … | fp8e4m3 | fp8e5m2 | e<2-8>m<1-10>[sr]"` —
/// for error messages and `--help`. The trailing entry is the generic
/// float grammar ([`parse_float_spec`]), which is not a registry row.
pub fn registered_summary() -> String {
    let mut parts: Vec<String> = FORMAT_REGISTRY.iter().map(FormatFamily::spelling).collect();
    parts.push(format!(
        "e<{}-{}>m<{}-{}>[sr]",
        FLOAT_EXP_RANGE.0, FLOAT_EXP_RANGE.1, FLOAT_MAN_RANGE.0, FLOAT_MAN_RANGE.1
    ));
    parts.join(" | ")
}

/// One representative spec per registered family at each width in
/// `widths` (widths outside a family's range are skipped) — the sweep
/// the hot-path bench and the round-trip property tests iterate.
pub fn registered_specs(widths: &[u32]) -> Vec<FormatSpec> {
    let mut out = Vec::new();
    for fam in FORMAT_REGISTRY {
        for &w in widths {
            if let Ok(spec) = fam.instantiate(w) {
                out.push(spec);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{bfp_quantize, fixed_quantize};
    use crate::util::prop::{gen_f32s, Prop};

    #[test]
    fn parse_canonical_specs() {
        assert_eq!(FormatSpec::parse("fp32").unwrap(), FormatSpec::Fp32);
        assert_eq!(FormatSpec::parse("fixed16").unwrap(), FormatSpec::fixed(16));
        assert_eq!(FormatSpec::parse("fixed8sr").unwrap(), FormatSpec::fixed_sr(8));
        assert_eq!(FormatSpec::parse("bfp4").unwrap(), FormatSpec::bfp(4));
        // Case/whitespace tolerant.
        assert_eq!(FormatSpec::parse(" BFP4 ").unwrap(), FormatSpec::bfp(4));
    }

    #[test]
    fn parse_float_specs() {
        // Registry rows and the generic grammar meet in the same specs.
        assert_eq!(FormatSpec::parse("fp8e4m3").unwrap(), FormatSpec::fp8e4m3());
        assert_eq!(FormatSpec::parse("fp8e5m2").unwrap(), FormatSpec::fp8e5m2());
        assert_eq!(FormatSpec::parse("e4m3").unwrap(), FormatSpec::fp8e4m3());
        assert_eq!(FormatSpec::parse("e5m2").unwrap(), FormatSpec::fp8e5m2());
        // bf16 / fp16 fall out of the generic spelling for free.
        assert_eq!(FormatSpec::parse("e8m7").unwrap(), FormatSpec::float(8, 7));
        assert_eq!(FormatSpec::parse("e5m10").unwrap(), FormatSpec::float(5, 10));
        assert_eq!(FormatSpec::parse("E4M3SR").unwrap(), FormatSpec::float_sr(4, 3));
        assert_eq!(FormatSpec::parse("e4m3").unwrap().bits(), 8);
        assert_eq!(FormatSpec::parse("e5m10").unwrap().bits(), 16);
        // Canonical spelling is the generic one.
        assert_eq!(FormatSpec::fp8e4m3().spec_string(), "e4m3");
        assert_eq!(FormatSpec::float_sr(5, 2).spec_string(), "e5m2sr");
    }

    #[test]
    fn parse_rejects_malformed() {
        for bad in [
            "", "bfp", "fixed", "fixedsr", "bfp0", "bfp1", "bfp33", "fixed64", "fp16", "fp",
            "int8", "bfp4x", "bfp4.5", "srfixed8", "fixed8rs", "8bfp",
            // Float grammar: widths out of range, bad suffixes, half-specs.
            "e1m3", "e9m3", "e4m0", "e4m11", "e4m3rs", "e4m3x", "e4m", "em3", "e4",
            "fp8e4m4", "fp9e4m3",
        ] {
            let err = FormatSpec::parse(bad);
            assert!(
                matches!(err, Err(Error::Config(_))),
                "'{bad}' should be Error::Config, got {err:?}"
            );
        }
    }

    #[test]
    fn spec_string_roundtrips_registry() {
        for spec in registered_specs(&[2, 3, 4, 8, 16, 24, 32]) {
            let s = spec.spec_string();
            assert_eq!(FormatSpec::parse(&s).unwrap(), spec, "round-trip of '{s}'");
        }
    }

    #[test]
    fn roundtrip_property_over_random_widths() {
        Prop::new("every registered family round-trips at every legal width").cases(60).run(
            |rng, _| {
                let fam = &FORMAT_REGISTRY[rng.below(FORMAT_REGISTRY.len() as u32) as usize];
                let bits = rng.range(fam.min_bits, fam.max_bits + 1);
                (fam.name(), bits)
            },
            |(name, bits)| {
                let fam = family(name).ok_or("family lookup failed")?;
                let spec = fam.instantiate(*bits).map_err(|e| e.to_string())?;
                let reparsed =
                    FormatSpec::parse(&spec.spec_string()).map_err(|e| e.to_string())?;
                if reparsed == spec {
                    Ok(())
                } else {
                    Err(format!("{spec:?} -> '{}' -> {reparsed:?}", spec.spec_string()))
                }
            },
        );
    }

    #[test]
    fn quantize_dispatch_matches_kernels() {
        let mut rng = Pcg32::new(1);
        let x = gen_f32s(&mut rng, 64, 8.0);
        assert_eq!(FormatSpec::Fp32.quantize(&x, 64), x);
        assert_eq!(FormatSpec::bfp(4).quantize(&x, 64), bfp_quantize(&x, 64, 4.0));
        assert_eq!(FormatSpec::fixed(8).quantize(&x, 64), fixed_quantize(&x, 8.0));
        assert_eq!(
            FormatSpec::fp8e4m3().quantize(&x, 64),
            crate::quant::float_quantize(&x, 4, 3)
        );
        assert_eq!(
            FormatSpec::float(5, 10).quantize(&x, 64),
            crate::quant::float_quantize(&x, 5, 10)
        );
    }

    #[test]
    fn stochastic_rounding_deterministic_per_step() {
        let mut rng = Pcg32::new(2);
        let x = gen_f32s(&mut rng, 256, 6.0);
        let sr = FormatSpec::fixed_sr(8);
        let mut a = x.clone();
        let mut b = x.clone();
        sr.quantize_into_step(&mut a, 256, 7);
        sr.quantize_into_step(&mut b, 256, 7);
        assert_eq!(a, b, "same step must requantize bit-identically");
        let mut c = x.clone();
        sr.quantize_into_step(&mut c, 256, 8);
        assert_ne!(a, c, "different steps must use different rounding streams");
        // Distinct per-tensor streams within one step decorrelate too.
        let mut d = x.clone();
        sr.quantize_into_stream(&mut d, 256, 7, 1);
        assert_ne!(a, d, "different streams must decorrelate within a step");
        let mut e = x.clone();
        sr.quantize_into_stream(&mut e, 256, 7, 1);
        assert_eq!(d, e, "(step, stream) must stay deterministic");
    }

    #[test]
    fn sr_matches_nearest_in_expectation_property() {
        // E[q_sr(x)] = x for unclamped values, so averaging over many
        // rounding streams must approach the input — and therefore sit
        // within half a step of round-to-nearest.
        Prop::new("stochastic rounding is unbiased").cases(15).run(
            |rng, _| gen_f32s(rng, 64, 3.0),
            |x| {
                let sr = FormatSpec::fixed_sr(6);
                let nearest = fixed_quantize(x, 6.0);
                let trials = 400u64;
                let mut mean = vec![0f64; x.len()];
                for step in 0..trials {
                    let q = {
                        let mut b = x.clone();
                        sr.quantize_into_step(&mut b, x.len(), step);
                        b
                    };
                    for (m, &qi) in mean.iter_mut().zip(&q) {
                        *m += qi as f64 / trials as f64;
                    }
                }
                // Shared per-tensor grid: recover the step from any
                // nonzero nearest/means pair via the fixed rule.
                let amax = x.iter().fold(0f32, |a, &v| a.max(v.abs()));
                let e = crate::quant::floor_log2(amax);
                let step = crate::quant::pow2((e - 6 + 2).clamp(-126, 127)) as f64;
                let maxmag = (crate::quant::pow2(6 - 1) - 1.0) as f64;
                for ((&xi, &ni), &mi) in x.iter().zip(&nearest).zip(&mean) {
                    if (xi as f64 / step).abs() >= maxmag {
                        continue; // clamped values are biased by design
                    }
                    // 3-sigma bound for a Bernoulli mean on a `step` grid.
                    let tol = 3.0 * step / (trials as f64).sqrt() + 1e-9;
                    if (mi - xi as f64).abs() > tol {
                        return Err(format!("biased: x={xi} mean={mi} tol={tol}"));
                    }
                    if (mi - ni as f64).abs() > step / 2.0 + tol {
                        return Err(format!(
                            "mean {mi} not within step/2 of nearest {ni} (x={xi})"
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn replica_salt_zero_reproduces_unsalted_streams_exactly() {
        // The replica seeding contract: salt 0 IS the legacy stream.
        // Every SR format, across several (step, stream) points, must
        // produce byte-identical output through the salted entry point —
        // a regression here silently breaks bit-compat of every
        // single-replica run and every rank-0 artifact.
        let mut rng = Pcg32::new(11);
        let x = gen_f32s(&mut rng, 256, 6.0);
        for sr in [FormatSpec::fixed_sr(8), FormatSpec::fixed_sr(4), FormatSpec::float_sr(4, 3)] {
            for (step, stream) in [(0u64, 0u64), (7, 0), (7, 3), (1 << 40, 9)] {
                let mut legacy = x.clone();
                sr.quantize_into_stream(&mut legacy, 256, step, stream);
                let mut salted = x.clone();
                sr.quantize_into_stream_salted(&mut salted, 256, step, stream, 0);
                assert_eq!(legacy, salted, "{sr} at ({step},{stream}): salt 0 must be identity");
            }
        }
    }

    #[test]
    fn replica_salts_decorrelate_and_stay_deterministic() {
        let mut rng = Pcg32::new(12);
        let x = gen_f32s(&mut rng, 256, 6.0);
        for sr in [FormatSpec::fixed_sr(8), FormatSpec::float_sr(4, 3)] {
            let q = |salt: u64| {
                let mut b = x.clone();
                sr.quantize_into_stream_salted(&mut b, 256, 7, 2, salt);
                b
            };
            assert_ne!(q(0), q(1), "{sr}: replica ranks must draw distinct streams");
            assert_ne!(q(1), q(2), "{sr}: replica ranks must draw distinct streams");
            assert_eq!(q(1), q(1), "{sr}: (step, stream, salt) must stay deterministic");
        }
        // Non-stochastic formats are salt-blind by construction.
        for f in [FormatSpec::Fp32, FormatSpec::bfp(4), FormatSpec::fixed(8)] {
            let mut a = x.clone();
            let mut b = x.clone();
            f.quantize_into_stream_salted(&mut a, 256, 7, 2, 0);
            f.quantize_into_stream_salted(&mut b, 256, 7, 2, 5);
            assert_eq!(a, b, "{f}: deterministic formats must ignore the salt");
        }
    }

    #[test]
    fn slot_qcfg_encoding() {
        assert_eq!(FormatSpec::Fp32.slot_qcfg(), [0.0, 32.0]);
        assert_eq!(FormatSpec::fixed(16).slot_qcfg(), [1.0, 16.0]);
        assert_eq!(FormatSpec::bfp(4).slot_qcfg(), [2.0, 4.0]);
        assert_eq!(FormatSpec::fixed_sr(8).slot_qcfg(), [3.0, 8.0]);
        // Float slots pack (E, M) into the width field as 100·E + M.
        assert_eq!(FormatSpec::fp8e4m3().slot_qcfg(), [4.0, 403.0]);
        assert_eq!(FormatSpec::fp8e5m2().slot_qcfg(), [4.0, 502.0]);
        assert_eq!(FormatSpec::float(5, 10).slot_qcfg(), [4.0, 510.0]);
        assert_eq!(FormatSpec::float_sr(4, 3).slot_qcfg(), [5.0, 403.0]);
    }

    #[test]
    fn with_bits_preserves_family() {
        assert_eq!(FormatSpec::bfp(16).with_bits(4), FormatSpec::bfp(4));
        assert_eq!(FormatSpec::fixed_sr(16).with_bits(8), FormatSpec::fixed_sr(8));
        assert_eq!(FormatSpec::Fp32.with_bits(4), FormatSpec::Fp32);
        // Float formats have no width knob: the (E, M) pair is the format.
        assert_eq!(FormatSpec::fp8e4m3().with_bits(16), FormatSpec::fp8e4m3());
    }

    #[test]
    fn float_sr_streams_deterministic_and_decorrelated() {
        let mut rng = Pcg32::new(4);
        let x = gen_f32s(&mut rng, 256, 4.0);
        let sr = FormatSpec::float_sr(4, 3);
        let mut a = x.clone();
        let mut b = x.clone();
        sr.quantize_into_step(&mut a, 256, 7);
        sr.quantize_into_step(&mut b, 256, 7);
        assert_eq!(a, b, "same step must requantize bit-identically");
        let mut c = x.clone();
        sr.quantize_into_step(&mut c, 256, 8);
        assert_ne!(a, c, "different steps must use different rounding streams");
        let mut d = x.clone();
        sr.quantize_into_stream(&mut d, 256, 7, 1);
        assert_ne!(a, d, "different streams must decorrelate within a step");
    }

    #[test]
    fn registry_names_unique() {
        let names: Vec<String> = FORMAT_REGISTRY.iter().map(|f| f.name()).collect();
        let mut deduped = names.clone();
        deduped.sort();
        deduped.dedup();
        assert_eq!(names.len(), deduped.len(), "duplicate family spelling: {names:?}");
    }
}
