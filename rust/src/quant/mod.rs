//! Number formats ([`FormatSpec`]) and the rust mirrors of the L1
//! quantizer kernels that execute them.
//!
//! The public surface is [`format::FormatSpec`]: one descriptor per
//! format that knows how to quantize a buffer
//! ([`FormatSpec::quantize_into`]), what it costs
//! (`storage_bits`/`mac_cost`, implemented beside the calibrated
//! constants in [`crate::costmodel::formats`]), how the artifacts encode
//! it (`slot_qcfg`), and its canonical spec string (`"bfp4"`,
//! `"fixed16"`, `"fixed8sr"`, `"fp32"`). New formats register in
//! [`format::FORMAT_REGISTRY`]; the raw kernels below are its execution
//! arms. The [`packed`] module adds the physical side of the surface:
//! [`packed::Codec`] encodes a tensor into the format's true bit layout
//! (`decode(encode(x)) == quantize(x)`, bit-exact), which is what the
//! runtime's `TensorData::Packed` arm, the v2 checkpoints, and the cost
//! model's `observed_bytes()` audit all carry.
//!
//! Kernel semantics are bit-identical to `python/compile/kernels/ref.py`
//! (and therefore to the Pallas kernels and the AOT artifacts — asserted
//! by the `artifact_roundtrip` integration test):
//!
//! * exponents come from the IEEE-754 bit pattern (`floor(log2|x|)` for
//!   normals), never from `log2` — exact on both sides;
//! * power-of-two scales are constructed exactly from bits ([`pow2`]);
//! * rounding is round-half-to-even (`f32::round_ties_even`, matching
//!   XLA's `round_nearest_even`) — except the stochastic-rounding
//!   formats, whose rounding stream exists only host-side: the artifact
//!   applies the same grid with nearest rounding (modes 3 and 5 in
//!   `python/compile/layers.py`), an artifact-side SR kernel is a
//!   ROADMAP open item;
//! * mantissa widths ≥ 25 are identity (wider than f32's significand)
//!   for the shared-exponent families; the [`float`] family
//!   (`e<E>m<M>`, FP8/bf16/fp16) caps its mantissa at 10 bits and is
//!   never an identity (±inf saturate to the format max).
//!
//! ## Non-finite semantics (host-side kernels, pinned by tests)
//!
//! These mirrors define NaN/±inf behavior **elementwise**: NaN in
//! propagates NaN out (never silently flushed — the all-NaN tensor whose
//! FTZ'd `amax` is zero keeps its NaNs while everything else in the
//! degenerate grid flushes to zero), and ±inf behave like huge finite
//! values (they clamp to the grid's max magnitude — or the float
//! family's saturation point). The packed codec agrees bit-for-bit
//! (NaN rides the lane sentinel / reserved exponent field). The python
//! reference kernels share these semantics only for the per-element
//! float family; the `amax`-reduction families differ on non-finite
//! *inputs* inside XLA (a NaN amax poisons `jnp.max` where the rust fold
//! skips it) — the artifact contract covers finite tensors, which is
//! what training traffic is (divergence aborts before NaNs reach a
//! quantizer).
//!
//! These mirrors serve three purposes: (1) cross-validating the AOT
//! artifacts from the rust side, (2) the cost model's error-analysis
//! ablations, (3) letting host-side components (e.g. checkpoint
//! compaction) reason about quantized values without a PJRT round trip.

pub mod bfp;
pub mod fixed;
pub mod float;
pub mod format;
pub mod packed;

pub use bfp::{bfp_dequantize_box_stats, bfp_quantize, bfp_quantize_into};
pub use fixed::{fixed_quantize, fixed_quantize_into, fixed_quantize_sr, fixed_quantize_sr_into};
pub use float::{
    float_grid, float_quantize, float_quantize_into, float_quantize_sr, float_quantize_sr_into,
    FloatGrid,
};
pub use format::{family, registered_specs, FormatFamily, FormatSpec, Rounding, FORMAT_REGISTRY};
pub use packed::{same_f32, stash_stream, Codec, PackedTensor, PACKED_VERSION};

/// Bounding-box size (elements sharing one exponent), paper §4 / MSFP.
pub const BOX: usize = 16;
/// Shared-exponent width in bits (8-bit biased exponent).
pub const EXP_BITS: u32 = 8;
/// Exponent clamp range implied by the 8-bit exponent.
pub const EXP_MIN: i32 = -126;
pub const EXP_MAX: i32 = 127;
/// Mantissa widths at or above this are an exact identity for f32 data.
pub const PASSTHROUGH_BITS: f32 = 25.0;

/// `floor(log2(|x|))` for normal f32; -127 for zero/subnormals
/// (callers clamp to [`EXP_MIN`], matching the kernels).
#[inline]
pub fn floor_log2(x: f32) -> i32 {
    (((x.abs().to_bits() >> 23) & 0xFF) as i32) - 127
}

/// Exact `2^k` as f32, including the subnormal range (k ≥ -149).
#[inline]
pub fn pow2(k: i32) -> f32 {
    if k >= -126 {
        debug_assert!(k <= 127);
        f32::from_bits(((k + 127) as u32) << 23)
    } else if k >= -149 {
        f32::from_bits(1u32 << (k + 149))
    } else {
        0.0
    }
}

/// Flush-to-zero for subnormal magnitudes: XLA CPU runs with FTZ/DAZ, so
/// the artifacts see subnormal inputs as zero; the mirror must agree
/// (real MSFP hardware has no subnormal support either).
#[inline]
pub fn ftz(x: f32) -> f32 {
    if x != 0.0 && x.abs() < f32::MIN_POSITIVE {
        0.0
    } else {
        x
    }
}

/// Shared quantization-grid derivation from a (FTZ'd) |max|:
/// clamped exponent, clamped power-of-two step, max representable
/// magnitude. Every fixed/BFP kernel and the box-stats reporter read
/// their grid from here so the copies cannot drift (the exact drift
/// `bfp_dequantize_box_stats` suffered before this helper existed).
#[inline]
pub fn quant_grid(amax: f32, bits: f32) -> (i32, f32, f32) {
    let e = floor_log2(amax).clamp(EXP_MIN, EXP_MAX);
    let step = pow2((e - bits as i32 + 2).clamp(EXP_MIN, EXP_MAX));
    let maxmag = pow2(bits as i32 - 1) - 1.0;
    (e, step, maxmag)
}

/// Quantize one value against shared exponent `e` with `m` mantissa bits
/// (sign + (m-1)-bit magnitude), mirroring `_quantize_with_exponent`.
///
/// The step exponent is clamped to the normal-f32 range — a subnormal
/// step would flush to zero under XLA's FTZ (see kernels/ref.py).
#[inline]
pub fn quantize_with_exponent(x: f32, e: i32, m: f32) -> f32 {
    let e = e.clamp(EXP_MIN, EXP_MAX);
    let step = pow2((e - m as i32 + 2).clamp(EXP_MIN, EXP_MAX));
    let maxmag = pow2(m as i32 - 1) - 1.0;
    let mag = (ftz(x) / step).round_ties_even().clamp(-maxmag, maxmag);
    mag * step
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn floor_log2_exact_on_powers() {
        assert_eq!(floor_log2(1.0), 0);
        assert_eq!(floor_log2(2.0), 1);
        assert_eq!(floor_log2(0.5), -1);
        assert_eq!(floor_log2(1024.0), 10);
        assert_eq!(floor_log2(-8.0), 3);
    }

    #[test]
    fn floor_log2_between_powers() {
        assert_eq!(floor_log2(1.5), 0);
        assert_eq!(floor_log2(3.999), 1);
        assert_eq!(floor_log2(0.75), -1);
    }

    #[test]
    fn floor_log2_zero_and_subnormal() {
        assert_eq!(floor_log2(0.0), -127);
        assert_eq!(floor_log2(f32::MIN_POSITIVE / 2.0), -127);
    }

    #[test]
    fn pow2_exact() {
        for k in -149..=127 {
            let p = pow2(k);
            assert!(p > 0.0);
            if k >= -126 {
                assert_eq!(p, 2.0f32.powi(k), "k={k}");
            }
        }
        assert_eq!(pow2(0), 1.0);
        assert_eq!(pow2(-149), f32::from_bits(1));
        assert_eq!(pow2(-150), 0.0);
    }

    #[test]
    fn quantize_with_exponent_matches_grid() {
        // e=0, m=4: step = 2^-2 = 0.25, maxmag = 7.
        let q = |x| quantize_with_exponent(x, 0, 4.0);
        assert_eq!(q(0.3), 0.25);
        assert_eq!(q(0.125), 0.0); // ties to even: 0.5 -> 0
        assert_eq!(q(0.375), 0.5); // 1.5 -> 2 (even)
        assert_eq!(q(10.0), 7.0 * 0.25); // clamped
        assert_eq!(q(-10.0), -7.0 * 0.25);
    }
}
