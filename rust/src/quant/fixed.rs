//! Dynamic per-tensor fixed-point fake quantization — rust mirror of
//! `python/compile/kernels/fixed.py`.
//!
//! One shared exponent for the whole tensor (from the global |max|); the
//! per-element rule is identical to BFP's. Its global scaling is exactly
//! the weakness the paper's Stashing(Fixed) rows expose: a heavy-tailed
//! tensor flushes most of its mass to zero at aggressive widths.
//!
//! Non-finite semantics (pinned by tests, shared with BFP and the float
//! kernel; see the `quant` module docs): the shared exponent comes from
//! the **finite** FTZ'd `|max|` (rust's `f32::max` skips NaN operands,
//! ±inf dominates and clamps the exponent to 127), NaN elements
//! propagate as NaN — including through the degenerate zero-`amax` grid,
//! where everything else flushes to zero — and ±inf clamp to the grid's
//! max magnitude like any oversized value.

use crate::util::rng::Pcg32;

use super::{ftz, quant_grid, PASSTHROUGH_BITS};

/// Fill the degenerate-grid result for a tensor whose FTZ'd |max| is
/// zero: all-zero / all-subnormal mass flushes to 0, NaN still
/// propagates (the packed codec round-trips it via its lane sentinel —
/// flushing it here would make `decode(encode(x)) != quantize(x)`).
#[inline]
pub(super) fn fill_zero_grid(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v = if v.is_nan() { f32::NAN } else { 0.0 };
    }
}

/// Quantize `x` in place with `bits` total mantissa width.
pub fn fixed_quantize_into(x: &mut [f32], bits: f32) {
    if bits >= PASSTHROUGH_BITS {
        return;
    }
    // FTZ to match the XLA artifacts (subnormals read as zero there).
    let amax = x.iter().fold(0.0f32, |a, &v| a.max(ftz(v.abs())));
    if amax <= 0.0 {
        fill_zero_grid(x);
        return;
    }
    // Hoist the per-tensor constants out of the element loop (§Perf);
    // identical element rule to quantize_with_exponent.
    let (_, step, maxmag) = quant_grid(amax, bits);
    for v in x.iter_mut() {
        *v = (ftz(*v) / step).round_ties_even().clamp(-maxmag, maxmag) * step;
    }
}

/// Out-of-place variant.
pub fn fixed_quantize(x: &[f32], bits: f32) -> Vec<f32> {
    let mut out = x.to_vec();
    fixed_quantize_into(&mut out, bits);
    out
}

/// Stochastic-rounding variant (the `fixed<b>sr` format): same grid as
/// [`fixed_quantize_into`], but each value rounds up with probability
/// equal to its fractional distance — unbiased, `E[q(x)] = x` for
/// unclamped values. One uniform draw is consumed per element, so a
/// given `rng` state quantizes a given buffer bit-identically; callers
/// derive the stream from the step index
/// ([`crate::quant::FormatSpec::quantize_into_step`]).
pub fn fixed_quantize_sr_into(x: &mut [f32], bits: f32, rng: &mut Pcg32) {
    if bits >= PASSTHROUGH_BITS {
        return;
    }
    let amax = x.iter().fold(0.0f32, |a, &v| a.max(ftz(v.abs())));
    if amax <= 0.0 {
        fill_zero_grid(x);
        return;
    }
    let (_, step, maxmag) = quant_grid(amax, bits);
    for v in x.iter_mut() {
        let t = ftz(*v) / step;
        let lo = t.floor();
        // `t - lo` in [0,1); draw exactly one uniform per element.
        let mag = if t - lo > rng.f32() { lo + 1.0 } else { lo };
        *v = mag.clamp(-maxmag, maxmag) * step;
    }
}

/// Out-of-place stochastic-rounding variant.
pub fn fixed_quantize_sr(x: &[f32], bits: f32, rng: &mut Pcg32) -> Vec<f32> {
    let mut out = x.to_vec();
    fixed_quantize_sr_into(&mut out, bits, rng);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::bfp::bfp_quantize;
    use crate::util::prop::{gen_f32s, Prop};
    use crate::util::rng::Pcg32;

    #[test]
    fn passthrough_at_25_bits() {
        let x = vec![1.5f32, -2e10, 3e-20];
        assert_eq!(fixed_quantize(&x, 25.0), x);
    }

    #[test]
    fn zero_tensor() {
        let x = vec![0.0f32; 8];
        assert_eq!(fixed_quantize(&x, 8.0), x);
    }

    #[test]
    fn known_values() {
        // amax = 4.0 -> e = 2, m = 4 -> step = 2^0 = 1, maxmag 7.
        let x = vec![4.0f32, 1.3, -2.5, 0.4];
        let q = fixed_quantize(&x, 4.0);
        assert_eq!(q, vec![4.0, 1.0, -2.0, 0.0]); // -2.5 ties-to-even -> -2
    }

    #[test]
    fn heavy_tail_flushes_small_values() {
        // The paper's fixed-point failure mode: one outlier kills resolution.
        let mut x = vec![0.01f32; 64];
        x[0] = 1000.0;
        let q = fixed_quantize(&x, 4.0);
        assert_eq!(q[1], 0.0, "per-tensor scaling must flush the tail");
        // ... while BFP keeps the other boxes alive:
        let qb = bfp_quantize(&x, 64, 4.0);
        assert!(qb[20] > 0.0, "per-box scaling must keep the tail");
    }

    #[test]
    fn idempotent_property() {
        Prop::new("fixed quantization is idempotent").cases(60).run(
            |rng, size| (gen_f32s(rng, 8 * (1 + size as usize / 12), 8.0), 2.0 + rng.below(14) as f32),
            |(x, b)| {
                let q1 = fixed_quantize(x, *b);
                let q2 = fixed_quantize(&q1, *b);
                if q1 == q2 {
                    Ok(())
                } else {
                    Err("q(q(x)) != q(x)".into())
                }
            },
        );
    }

    #[test]
    fn bfp_never_worse_than_fixed_property() {
        // With equal bit width, per-box scaling has error <= per-tensor
        // scaling on every element grid (same rule, finer exponents).
        Prop::new("bfp total error <= fixed total error").cases(40).run(
            |rng, size| (gen_f32s(rng, 16 * (1 + size as usize / 25), 10.0), 2.0 + rng.below(10) as f32),
            |(x, b)| {
                let err = |q: &[f32]| {
                    q.iter().zip(x.iter()).map(|(q, x)| ((q - x) as f64).abs()).sum::<f64>()
                };
                let ef = err(&fixed_quantize(x, *b));
                let eb = err(&bfp_quantize(x, x.len(), *b));
                if eb <= ef * 1.0000001 + 1e-12 {
                    Ok(())
                } else {
                    Err(format!("bfp {eb} > fixed {ef}"))
                }
            },
        );
    }

    #[test]
    fn sr_lands_on_the_grid_within_one_step() {
        Prop::new("stochastic rounding picks an adjacent grid point").cases(60).run(
            |rng, size| {
                (gen_f32s(rng, 8 * (1 + size as usize / 12), 6.0), 2.0 + rng.below(10) as f32)
            },
            |(x, b)| {
                let mut rng = Pcg32::new(99);
                let q = fixed_quantize_sr(x, *b, &mut rng);
                let amax = x.iter().fold(0f32, |a, &v| a.max(v.abs()));
                let e = crate::quant::floor_log2(amax).clamp(-126, 127);
                let step = crate::quant::pow2((e - *b as i32 + 2).clamp(-126, 127));
                let maxmag = crate::quant::pow2(*b as i32 - 1) - 1.0;
                for (&xi, &qi) in x.iter().zip(&q) {
                    let clamped = (xi / step).abs() > maxmag;
                    if !clamped && (qi - xi).abs() >= step * (1.0 + 1e-6) {
                        return Err(format!("|q-x|={} >= step={step}", (qi - xi).abs()));
                    }
                    let mag = qi / step;
                    if (mag - mag.round()).abs() > mag.abs().max(1.0) * 1e-6 {
                        return Err(format!("off-grid output {qi} (step {step})"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn nan_inf_semantics_pinned() {
        // NaN propagates elementwise — including through the degenerate
        // zero-amax grid — and ±inf clamp like oversized finite values.
        let q = fixed_quantize(&[f32::NAN; 6], 8.0);
        assert!(q.iter().all(|v| v.is_nan()), "all-NaN tensor must stay NaN: {q:?}");
        // All-subnormal tensors still flush (FTZ semantics).
        let sub = f32::MIN_POSITIVE / 4.0;
        assert_eq!(fixed_quantize(&[sub; 6], 8.0), vec![0.0; 6]);
        // Mixed NaN + subnormal: NaN survives, subnormals flush.
        let q = fixed_quantize(&[f32::NAN, sub, 0.0], 8.0);
        assert!(q[0].is_nan());
        assert_eq!(&q[1..], &[0.0, 0.0]);
        // Mixed NaN + normal values: the grid comes from the finite max.
        let q = fixed_quantize(&[f32::NAN, 4.0, 1.3], 4.0);
        assert!(q[0].is_nan());
        assert_eq!(&q[1..], &[4.0, 1.0]);
        // ±inf dominates the (clamped) exponent and saturates.
        let q = fixed_quantize(&[f32::INFINITY, f32::NEG_INFINITY, 1.0], 4.0);
        assert!(q[0].is_finite() && q[0] > 0.0, "inf must clamp to the grid max: {}", q[0]);
        assert_eq!(q[1], -q[0]);
        // The SR variant shares the semantics.
        let mut rng = Pcg32::new(2);
        let q = fixed_quantize_sr(&[f32::NAN, sub], 8.0, &mut rng);
        assert!(q[0].is_nan());
        assert_eq!(q[1], 0.0);
    }

    #[test]
    fn sr_zero_and_passthrough() {
        let mut rng = Pcg32::new(1);
        assert_eq!(fixed_quantize_sr(&[0.0; 8], 8.0, &mut rng), vec![0.0; 8]);
        let x = vec![1.5f32, -2e10, 3e-20];
        assert_eq!(fixed_quantize_sr(&x, 25.0, &mut rng), x);
    }

    #[test]
    fn max_value_representable() {
        let mut rng = Pcg32::new(5);
        for _ in 0..50 {
            let x = gen_f32s(&mut rng, 32, 12.0);
            let q = fixed_quantize(&x, 8.0);
            let amax_idx =
                x.iter().enumerate().max_by(|a, b| a.1.abs().total_cmp(&b.1.abs())).unwrap().0;
            let rel = (q[amax_idx] - x[amax_idx]).abs() / x[amax_idx].abs();
            assert!(rel < 0.01, "max poorly represented: {} -> {}", x[amax_idx], q[amax_idx]);
        }
    }
}
