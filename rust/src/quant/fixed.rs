//! Dynamic per-tensor fixed-point fake quantization — rust mirror of
//! `python/compile/kernels/fixed.py`.
//!
//! One shared exponent for the whole tensor (from the global |max|); the
//! per-element rule is identical to BFP's. Its global scaling is exactly
//! the weakness the paper's Stashing(Fixed) rows expose: a heavy-tailed
//! tensor flushes most of its mass to zero at aggressive widths.

use super::{floor_log2, ftz, PASSTHROUGH_BITS};

/// Quantize `x` in place with `bits` total mantissa width.
pub fn fixed_quantize_into(x: &mut [f32], bits: f32) {
    if bits >= PASSTHROUGH_BITS {
        return;
    }
    // FTZ to match the XLA artifacts (subnormals read as zero there).
    let amax = x.iter().fold(0.0f32, |a, &v| a.max(ftz(v.abs())));
    if amax <= 0.0 {
        x.fill(0.0);
        return;
    }
    // Hoist the per-tensor constants out of the element loop (§Perf);
    // identical element rule to quantize_with_exponent.
    let e = floor_log2(amax).clamp(super::EXP_MIN, super::EXP_MAX);
    let step = super::pow2((e - bits as i32 + 2).clamp(super::EXP_MIN, super::EXP_MAX));
    let maxmag = super::pow2(bits as i32 - 1) - 1.0;
    for v in x.iter_mut() {
        *v = (ftz(*v) / step).round_ties_even().clamp(-maxmag, maxmag) * step;
    }
}

/// Out-of-place variant.
pub fn fixed_quantize(x: &[f32], bits: f32) -> Vec<f32> {
    let mut out = x.to_vec();
    fixed_quantize_into(&mut out, bits);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::bfp::bfp_quantize;
    use crate::util::prop::{gen_f32s, Prop};
    use crate::util::rng::Pcg32;

    #[test]
    fn passthrough_at_25_bits() {
        let x = vec![1.5f32, -2e10, 3e-20];
        assert_eq!(fixed_quantize(&x, 25.0), x);
    }

    #[test]
    fn zero_tensor() {
        let x = vec![0.0f32; 8];
        assert_eq!(fixed_quantize(&x, 8.0), x);
    }

    #[test]
    fn known_values() {
        // amax = 4.0 -> e = 2, m = 4 -> step = 2^0 = 1, maxmag 7.
        let x = vec![4.0f32, 1.3, -2.5, 0.4];
        let q = fixed_quantize(&x, 4.0);
        assert_eq!(q, vec![4.0, 1.0, -2.0, 0.0]); // -2.5 ties-to-even -> -2
    }

    #[test]
    fn heavy_tail_flushes_small_values() {
        // The paper's fixed-point failure mode: one outlier kills resolution.
        let mut x = vec![0.01f32; 64];
        x[0] = 1000.0;
        let q = fixed_quantize(&x, 4.0);
        assert_eq!(q[1], 0.0, "per-tensor scaling must flush the tail");
        // ... while BFP keeps the other boxes alive:
        let qb = bfp_quantize(&x, 64, 4.0);
        assert!(qb[20] > 0.0, "per-box scaling must keep the tail");
    }

    #[test]
    fn idempotent_property() {
        Prop::new("fixed quantization is idempotent").cases(60).run(
            |rng, size| (gen_f32s(rng, 8 * (1 + size as usize / 12), 8.0), 2.0 + rng.below(14) as f32),
            |(x, b)| {
                let q1 = fixed_quantize(x, *b);
                let q2 = fixed_quantize(&q1, *b);
                if q1 == q2 {
                    Ok(())
                } else {
                    Err("q(q(x)) != q(x)".into())
                }
            },
        );
    }

    #[test]
    fn bfp_never_worse_than_fixed_property() {
        // With equal bit width, per-box scaling has error <= per-tensor
        // scaling on every element grid (same rule, finer exponents).
        Prop::new("bfp total error <= fixed total error").cases(40).run(
            |rng, size| (gen_f32s(rng, 16 * (1 + size as usize / 25), 10.0), 2.0 + rng.below(10) as f32),
            |(x, b)| {
                let err = |q: &[f32]| {
                    q.iter().zip(x.iter()).map(|(q, x)| ((q - x) as f64).abs()).sum::<f64>()
                };
                let ef = err(&fixed_quantize(x, *b));
                let eb = err(&bfp_quantize(x, x.len(), *b));
                if eb <= ef * 1.0000001 + 1e-12 {
                    Ok(())
                } else {
                    Err(format!("bfp {eb} > fixed {ef}"))
                }
            },
        );
    }

    #[test]
    fn max_value_representable() {
        let mut rng = Pcg32::new(5);
        for _ in 0..50 {
            let x = gen_f32s(&mut rng, 32, 12.0);
            let q = fixed_quantize(&x, 8.0);
            let amax_idx =
                x.iter().enumerate().max_by(|a, b| a.1.abs().total_cmp(&b.1.abs())).unwrap().0;
            let rel = (q[amax_idx] - x[amax_idx]).abs() / x[amax_idx].abs();
            assert!(rel < 0.01, "max poorly represented: {} -> {}", x[amax_idx], q[amax_idx]);
        }
    }
}
